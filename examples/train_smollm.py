"""End-to-end driver: train a ~360M-architecture LM (reduced width for CPU)
for a few hundred steps with the full production stack — sharded step,
checkpointing, deterministic data, fault-tolerant trainer.

    PYTHONPATH=src python examples/train_smollm.py [--steps 300] [--full]

``--full`` uses the real smollm-360m config (only sensible on real
hardware); the default reduced config trains visibly in minutes on CPU.
"""
import argparse
import tempfile


from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_smoke_mesh
from repro.models.config import ShapeConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config("smollm-360m") if args.full else get_smoke_config("smollm-360m")
    mesh = make_smoke_mesh()
    shape = ShapeConfig("train", seq_len=64, global_batch=8, kind="train")
    data = SyntheticLM(cfg.vocab, shape.global_batch, shape.seq_len, seed=0)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    trainer = Trainer(
        cfg, shape, mesh, data,
        TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=100, max_steps=args.steps,
                      lr=3e-3, warmup=20),
    )
    print(f"arch={cfg.name} params~{cfg.n_params()/1e6:.1f}M ckpt={ckpt_dir}")
    trainer.run()
    losses = [m["loss"] for m in trainer.metrics if "loss" in m]
    print(f"step 0 loss {losses[0]:.3f} -> step {len(losses)-1} loss {losses[-1]:.3f}")
    print(f"checkpoints: {trainer.ckpt.all_steps()}")
    assert losses[-1] < losses[0]
    print("OK")


if __name__ == "__main__":
    main()
