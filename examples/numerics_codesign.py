"""The paper's software/hardware co-design story (Section 4.4.2) as a
runnable example: application-level co-simulation exposes a numerics bug,
per-invocation statistics localize it, a datatype change fixes it.

    PYTHONPATH=src python examples/numerics_codesign.py
"""
import numpy as np

from repro.core import apps, cosim
from repro.core.codegen import Executor
from repro.core.compile import compile_program

print("1. train ResNet-20 (reduced) on a deterministic synthetic task")
expr, params = apps.build_resnet20()
X, y = cosim.make_teacher_task(apps.build_resnet20, (1, 12, 12, 8), n=512)
trained = cosim.train_app(expr, params, X, y, steps=400, lr=3e-3)

print("2. compile for FlexASR + HLSCNN (flexible matching)")
res = compile_program(expr, targets=("flexasr", "hlscnn"), flexible=True)
print("   offloads:", res.accelerator_calls)

n = 30
ref, _ = cosim.eval_classification(res.program, trained, X, y, Executor("ideal"), n)
print(f"3. reference accuracy (host fp32): {ref:.1%}")

ex8 = Executor("ila", target_options={"hlscnn": {"wgt_bits": 8}})
orig, _ = cosim.eval_classification(res.program, trained, X, y, ex8, n)
print(f"4. ORIGINAL design (8-bit fixed-point conv weights): {orig:.1%}")
print("   per-invocation debugging statistics (given to the 'accelerator")
print("   developers' to localize the bug):")
per_op = {}
for s in ex8.stats:
    per_op.setdefault(s.op, []).append(s.rel_err)
for op, errs in per_op.items():
    print(f"     {op:16s} mean rel err {np.mean(errs):.1%}")
print("   per-target summary:", ex8.stats_summary())

ex16 = Executor("ila", target_options={"hlscnn": {"wgt_bits": 16}})
upd, _ = cosim.eval_classification(res.program, trained, X, y, ex16, n)
print(f"5. UPDATED design (16-bit weights): {upd:.1%}")
print(f"\n   collapse {ref:.1%} -> {orig:.1%}, recovery -> {upd:.1%}"
      "  (cf. Table 4: 91.55% -> 29.15% -> 91.85%)")
