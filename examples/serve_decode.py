"""Serving example: prefill a prompt, then batched autoregressive decode
with the KV cache, on a reduced tinyllama config.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import api

cfg = get_smoke_config("tinyllama-1.1b")
params = api.init_params(cfg, jax.random.PRNGKey(0))

B, PROMPT, GEN = 4, 16, 24
rng = np.random.default_rng(0)
prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, PROMPT)), jnp.int32)

def _force(*trees):
    # JAX dispatch is async: block so the timer measures compute, not enqueue
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()


cache = api.init_cache(cfg, B, PROMPT + GEN)
t0 = time.perf_counter()
logits, cache = api.prefill(cfg, params, prompt, cache)
_force(logits, cache)
print(f"prefill {PROMPT} tokens x{B}: {time.perf_counter()-t0:.2f}s")

decode = jax.jit(lambda p, c, t, pos: api.decode_step(cfg, p, c, t, pos),
                 static_argnums=())
tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
out_tokens = [tok]
t0 = time.perf_counter()
for i in range(GEN - 1):
    logits, cache = api.decode_step(cfg, params, cache, tok, PROMPT + i)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens.append(tok)
_force(tok, cache)
dt = time.perf_counter() - t0
gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
print(f"decoded {GEN-1} steps x{B} seqs in {dt:.2f}s ({dt/(GEN-1)*1e3:.0f} ms/step)")
print("generations:\n", gen)
print("OK")
