"""Quickstart: the D2A flow end to end on one program.

    PYTHONPATH=src python examples/quickstart.py

1. Build a small DL program in the compiler IR.
2. Flexible matching (equality saturation) maps it onto accelerator
   instructions — including the linear layer the exact matcher misses.
3. Lower to ILA command streams ("MMIO writes") and co-simulate with the
   accelerator's AdaptivFloat numerics.
4. Compare against the fp32 host reference.
"""
import numpy as np

from repro.core import ir
from repro.core.codegen import Executor
from repro.core.compile import compile_program

rng = np.random.default_rng(0)

# 1. a linear layer written the "wrong" way for exact matching:
#    add(reshape(dense(x, w), s), b)  — semantically bias_add(dense(x, w), b)
x = ir.Var("x", (8, 64))
w = ir.Var("w", (32, 64))
b = ir.Var("b", (32,))
program = ir.call("add", ir.reshape(ir.dense(x, w), (8, 32)), b)
program = ir.call("relu", program)
print("source program:", program)

# 2. exact vs flexible matching
exact = compile_program(program, targets=("flexasr",), flexible=False)
flexible = compile_program(program, targets=("flexasr",), flexible=True)
print("\nexact matching offloads:   ", exact.accelerator_calls)
print("flexible matching offloads:", flexible.accelerator_calls)
print("matched program:", flexible.program)

# 3. execute: fp32 reference vs bit-accurate ILA co-simulation
env = {
    "x": rng.standard_normal((8, 64)).astype(np.float32),
    "w": (rng.standard_normal((32, 64)) * 0.1).astype(np.float32),
    "b": (rng.standard_normal((32,)) * 0.1).astype(np.float32),
}
ref = np.asarray(Executor("ideal").run(flexible.program, env))
ila = Executor("ila")
got = np.asarray(ila.run(flexible.program, env))

err = np.linalg.norm(ref - got) / np.linalg.norm(ref)
print(f"\nfp32 reference vs AdaptivFloat co-simulation: rel err {err:.2%}")
for s in ila.stats:
    print(f"  invocation: {s.op} on {s.backend}: rel_err={s.rel_err:.2%} "
          f"range [{s.out_min:.2f}, {s.out_max:.2f}]")

# 4. the TPU fast path computes the same numerics
kern = np.asarray(Executor("kernel").run(flexible.program, env))
print("Pallas fast path == ILA simulation:", np.array_equal(got, kern))
