"""Fault-tolerant training driver.

Responsibilities at fleet scale, and how each is realized here:

* **Checkpoint/restart** — periodic async checkpoints; on (re)start the
  driver restores the latest complete checkpoint and, because the data
  pipeline is a pure function of step (data/pipeline.py), replays exactly
  the remaining batches. ``run()`` survives injected step failures.
* **Failure detection** — a pluggable ``failure_hook(step)`` raising
  ``WorkerFailure`` stands in for the real heartbeat/health service; the
  driver treats it like a lost worker: roll back to the last checkpoint,
  rebuild the jitted step (fresh devices), continue.
* **Straggler mitigation** — per-step wall-time EWMA; steps slower than
  ``straggler_factor`` x the EWMA are logged and counted. On real fleets
  this signal feeds the scheduler to re-shard away from slow hosts; here it
  is surfaced in metrics (and covered by a unit test with an artificial
  sleep).
* **Elastic scaling** — ``reshard(new_mesh)`` re-lowers the step for a new
  mesh and device_puts the state with the new shardings (checkpoint format
  is mesh-independent, see checkpoint/manager.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..launch import steps as st
from ..models import api
from ..models.config import ArchConfig, ShapeConfig


class WorkerFailure(RuntimeError):
    """Raised by the failure hook to simulate a lost worker/preemption."""


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_steps: int = 200
    straggler_factor: float = 3.0
    max_restarts: int = 5
    async_checkpoint: bool = True
    lr: float = 3e-4
    warmup: int = 2000


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeConfig,
        mesh,
        data,
        tcfg: TrainerConfig,
        failure_hook: Optional[Callable[[int], None]] = None,
        optimizer: Optional[str] = None,
    ):
        self.cfg, self.shape, self.mesh, self.data, self.tcfg = cfg, shape, mesh, data, tcfg
        self.failure_hook = failure_hook
        self.optimizer = optimizer
        self.ckpt = CheckpointManager(tcfg.ckpt_dir)
        self.metrics: List[Dict[str, Any]] = []
        self.straggler_steps: List[int] = []
        self._build()

    # -- construction -------------------------------------------------------
    def _build(self):
        self.bundle = st.make_train_step(
            self.cfg, self.shape, self.mesh, optimizer=self.optimizer,
            lr=self.tcfg.lr, warmup=self.tcfg.warmup,
            total_steps=max(self.tcfg.max_steps, self.tcfg.warmup + 1))
        self.step_fn = jax.jit(
            self.bundle.fn,
            in_shardings=self.bundle.in_shardings,
            out_shardings=self.bundle.out_shardings,
            donate_argnums=self.bundle.donate_argnums,
        )

    def init_state(self, seed: int = 0):
        from .. import optim

        params = api.init_params(self.cfg, jax.random.PRNGKey(seed))
        opt = optim.make_optimizer(self.optimizer or st.pick_optimizer(self.cfg))
        opt_state = opt.init(params)
        p_sh, o_sh = self.bundle.in_shardings[0], self.bundle.in_shardings[1]
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        return params, opt_state

    # -- elastic ------------------------------------------------------------
    def reshard(self, new_mesh, params, opt_state):
        """Move to a different mesh (elastic scale up/down)."""
        self.mesh = new_mesh
        self._build()
        p_sh, o_sh = self.bundle.in_shardings[0], self.bundle.in_shardings[1]
        params = jax.device_put(jax.tree_util.tree_map(np.asarray, params), p_sh)
        opt_state = jax.device_put(jax.tree_util.tree_map(np.asarray, opt_state), o_sh)
        return params, opt_state

    # -- main loop ----------------------------------------------------------
    def run(self, seed: int = 0):
        restarts = 0
        while True:
            try:
                return self._run_once(seed)
            except WorkerFailure as e:
                restarts += 1
                if restarts > self.tcfg.max_restarts:
                    raise RuntimeError(f"exceeded max restarts: {e}")
                self.metrics.append({"event": "restart", "cause": str(e)})
                # quiesce in-flight async checkpoint writes before restoring,
                # or the restart can race the newest checkpoint's commit and
                # silently resume from an older step
                self.ckpt.wait()
                self._build()   # fresh executable (new workers)

    def _run_once(self, seed: int):
        # restore or init
        try:
            params_like, opt_like = self._abstract_state()
            p_sh, o_sh = self.bundle.in_shardings[0], self.bundle.in_shardings[1]
            params, opt_state, step0, extra = self.ckpt.restore(
                params_like, opt_like, shardings=(p_sh, o_sh)
            )
            start = step0 + 1
        except FileNotFoundError:
            params, opt_state = self.init_state(seed)
            start = 0

        ewma = None
        for step in range(start, self.tcfg.max_steps):
            t0 = time.time()   # whole-iteration time: data + step + sync
            if self.failure_hook is not None:
                self.failure_hook(step)      # may raise WorkerFailure
            batch = self.data.batch_at(step)
            batch = {k: jax.device_put(v, s) for (k, v), s in
                     zip(batch.items(), [self.bundle.in_shardings[2][k] for k in batch])}
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch, np.int32(step)
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > self.tcfg.straggler_factor * ewma and step > start + 3:
                self.straggler_steps.append(step)
            self.metrics.append(
                {"step": step, "loss": float(metrics["loss"]),
                 "grad_norm": float(metrics["grad_norm"]), "dt": dt}
            )
            if (step + 1) % self.tcfg.ckpt_every == 0 or step + 1 == self.tcfg.max_steps:
                save = self.ckpt.save_async if self.tcfg.async_checkpoint else self.ckpt.save
                save(step, params, opt_state, extra={"data": {"step": step}})
        self.ckpt.wait()
        return params, opt_state

    def _abstract_state(self):
        from .. import optim

        params_like = st.abstract_params(self.cfg)
        opt = optim.make_optimizer(self.optimizer or st.pick_optimizer(self.cfg))
        opt_like = jax.eval_shape(opt.init, params_like)
        return params_like, opt_like
