"""Sharded, atomic, elastic checkpointing.

Design (scaled for this container; production deltas documented inline):

* Each checkpoint is a directory ``step_<k>/`` holding one ``.npy`` per
  pytree leaf plus ``manifest.json`` (treedef, shapes, dtypes, step, data
  state). A checkpoint only "exists" once ``manifest.json`` is renamed into
  place (atomic-commit: torn writes are never visible).
* ``save_async`` snapshots to host memory synchronously (so training can
  donate buffers) and writes on a background thread — the standard
  overlap-checkpoint-with-compute trick.
* **Elastic restore**: leaves are stored as *global* arrays, so a restore
  may target any mesh/sharding (``device_put`` with the new NamedSharding).
  At >10k-chip scale you store per-shard files keyed by (leaf, shard index)
  and re-stripe on restore; the manifest format already carries the
  shape/dtype metadata needed for that (see EXPERIMENTS.md §Dry-run notes).
* ``keep`` rotates old checkpoints; the latest complete one wins on restore
  (a crashed save leaves no manifest and is garbage-collected).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in path
        )
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, params, opt_state, extra: Optional[Dict] = None):
        self.wait()
        self._save_sync(step, self._snapshot(params), self._snapshot(opt_state), extra or {})

    def save_async(self, step: int, params, opt_state, extra: Optional[Dict] = None):
        """Snapshot to host now; write in the background."""
        self.wait()
        p_host = self._snapshot(params)
        o_host = self._snapshot(opt_state)
        self._thread = threading.Thread(
            target=self._save_sync, args=(step, p_host, o_host, extra or {}), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _snapshot(self, tree):
        return jax.tree_util.tree_map(lambda a: np.asarray(a), tree)

    def _save_sync(self, step, params, opt_state, extra):
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "leaves": {}, "time": time.time()}
        for name, tree in (("params", params), ("opt", opt_state)):
            flat, treedef = _flatten_with_paths(tree)
            manifest[f"{name}_treedef"] = str(treedef)
            for key, leaf in flat:
                fn = f"{name}__{key.replace('/', '__')}.npy"
                arr = np.asarray(leaf)
                orig_dtype = str(arr.dtype)
                if arr.dtype.kind not in "fiub" or orig_dtype == "bfloat16":
                    # numpy can't serialize ml_dtypes (bf16/fp8): upcast to
                    # f32 on disk, restore casts back (dtype recorded)
                    arr = arr.astype(np.float32)
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"][f"{name}/{key}"] = {
                    "file": fn,
                    "shape": list(np.shape(leaf)),
                    "dtype": orig_dtype,
                }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                os.path.join(self.dir, d, "manifest.json")
            ):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        params_like,
        opt_like,
        step: Optional[int] = None,
        shardings: Optional[Tuple[Any, Any]] = None,
    ):
        """Restore onto pytrees shaped like (params_like, opt_like).

        ``shardings``: optional (param_shardings, opt_shardings) — enables
        **elastic** restore onto a different mesh than the one that saved.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        def load_tree(name, like, shard_tree):
            flat, treedef = _flatten_with_paths(like)
            leaves = []
            shard_flat = None
            if shard_tree is not None:
                flat_sh, _ = _flatten_with_paths(shard_tree)
                shard_flat = [s for _, s in flat_sh]
            for i, (key, leaf) in enumerate(flat):
                meta = manifest["leaves"][f"{name}/{key}"]
                arr = np.load(os.path.join(d, meta["file"]))
                if list(arr.shape) != list(np.shape(leaf)):
                    raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {np.shape(leaf)}")
                if str(arr.dtype) != meta["dtype"]:
                    import ml_dtypes  # noqa: F401  (registers bf16/fp8 dtype names)

                    arr = arr.astype(np.dtype(meta["dtype"]))
                if shard_flat is not None:
                    arr = jax.device_put(arr, shard_flat[i])
                leaves.append(arr)
            return jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(like), leaves
            )

        p_sh = shardings[0] if shardings else None
        o_sh = shardings[1] if shardings else None
        params = load_tree("params", params_like, p_sh)
        opt = load_tree("opt", opt_like, o_sh)
        return params, opt, step, manifest.get("extra", {})
