"""Sharding rules: map every parameter / optimizer / batch / cache leaf to a
PartitionSpec for the production mesh.

Strategy (DESIGN.md §5):
  * TP over 'model': attention heads, FFN hidden, vocab, MoE experts (EP),
    Mamba inner channels.
  * FSDP over 'data' (+'pod'): the remaining large axis of every weight.
  * DP over 'pod'+'data' for the batch.
  * decode caches: batch over 'data' when batch >= mesh data size, else
    sequence over 'data' (long_500k SP); kv-heads/inner dim over 'model'.

Rules are path-regex -> spec-template, resolved against the actual pytree, so
new parameters fail loudly rather than silently replicating.
"""
from __future__ import annotations

import re
from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.config import ArchConfig


def _dp(mesh) -> Any:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else axes[0]


# (regex over "/"-joined path, spec builder given (ndim, dp))
# Specs are written for the *unstacked* leaf; a leading layer axis (detected
# by ndim mismatch) gets None prepended automatically.
_RULES = [
    # embeddings / heads
    (r"(^|/)embed$", lambda dp: P("model", dp)),
    (r"(^|/)tok_embed$", lambda dp: P("model", dp)),
    (r"(^|/)lm_head$", lambda dp: P(dp, "model")),
    # attention (GQA)
    (r"/attn/q$|/self_attn/q$|/cross_attn/q$", lambda dp: P(dp, "model")),
    (r"/attn/k$|/self_attn/k$|/cross_attn/k$", lambda dp: P(dp, "model")),
    (r"/attn/v$|/self_attn/v$|/cross_attn/v$", lambda dp: P(dp, "model")),
    (r"/attn/o$|/self_attn/o$|/cross_attn/o$", lambda dp: P("model", dp)),
    # attention (MLA)
    (r"/attn/q_down$", lambda dp: P(dp, "model")),
    (r"/attn/q_up$", lambda dp: P(dp, "model")),
    (r"/attn/kv_down$", lambda dp: P(dp, None)),
    (r"/attn/kv_up$", lambda dp: P(dp, "model")),
    (r"/attn/q_norm$|/attn/kv_norm$", lambda dp: P(None)),
    # MLPs
    (r"/mlp/w_gate$|/mlp/w_up$", lambda dp: P(dp, "model")),
    (r"/mlp/w_down$", lambda dp: P("model", dp)),
    # MoE
    (r"/moe/router$", lambda dp: P(dp, None)),
    (r"/moe/w_gate$|/moe/w_up$", lambda dp: P("model", dp, None)),
    (r"/moe/w_down$", lambda dp: P("model", None, dp)),
    (r"/moe/shared/w_gate$|/moe/shared/w_up$", lambda dp: P(dp, "model")),
    (r"/moe/shared/w_down$", lambda dp: P("model", dp)),
    # Mamba
    (r"/ssm/in_proj$", lambda dp: P(dp, "model")),
    (r"/ssm/conv_w$", lambda dp: P(None, "model")),
    (r"/ssm/conv_b$", lambda dp: P("model")),
    (r"/ssm/x_proj$", lambda dp: P("model", None)),
    (r"/ssm/dt_proj$", lambda dp: P(None, "model")),
    # per-channel scalars (A_log, dt_bias, D) are tiny: replicate — their
    # stacked ranks differ between mamba1/mamba2 so axis-mapping is ambiguous
    (r"/ssm/dt_bias$|/ssm/A_log$|/ssm/D$", lambda dp: P(None)),
    (r"/ssm/norm_g$", lambda dp: P("model")),
    (r"/ssm/out_proj$", lambda dp: P("model", dp)),
    # MTP
    (r"/mtp/proj$", lambda dp: P(dp, "model")),
    # norms & everything 1-D per-feature
    (r"ln|norm", lambda dp: P(None)),
]


def _spec_for(path: str, ndim: int, shape, dp) -> P:
    for pat, builder in _RULES:
        if re.search(pat, path):
            spec = builder(dp)
            # mamba2 A_log/dt_bias/D are (H,) not (di,N): adjust rank
            parts = list(spec)
            if len(parts) > ndim:
                parts = parts[:ndim] if ndim > 0 else []
            while len(parts) < ndim:
                parts.insert(0, None)      # stacked layer axis etc.
            # drop 'model' on axes not divisible by mesh model size later
            return P(*parts)
    # default: replicate small leaves, FSDP-shard big ones on last axis
    if ndim == 0:
        return P()
    return P(*([None] * ndim))


def param_specs(params, mesh) -> Any:
    dp = _dp(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def leaf_spec(path, leaf):
        p = "/".join(str(k.key) if hasattr(k, "key") else str(k) for k in path)
        spec = _spec_for(p, leaf.ndim, leaf.shape, dp)
        # sanity: drop mesh axes that don't divide the dim (uneven sharding is
        # legal but wasteful; padding distorts the roofline numbers)
        parts = []
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                parts.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([sizes[a] for a in axes]))
            parts.append(ax if dim % total == 0 else None)
        return P(*parts)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def batch_specs(cfg: ArchConfig, mesh) -> Dict[str, P]:
    dp = _dp(mesh)
    out = {"tokens": P(dp, None)}
    if cfg.family == "audio":
        out["frames"] = P(dp, None, None)
    return out


def cache_specs(cfg: ArchConfig, cache_shapes, mesh, batch: int) -> Any:
    """cache_shapes: pytree of ShapeDtypeStructs from api.init_cache."""
    dp = _dp(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = dp if isinstance(dp, tuple) else (dp,)
    dp_total = int(np.prod([sizes[a] for a in dp_axes]))
    batch_sharded = batch % dp_total == 0

    def leaf_spec(path, leaf):
        p = "/".join(str(k.key) if hasattr(k, "key") else str(k) for k in path)
        nd = len(leaf.shape)
        if "attn_k" in p or "attn_v" in p or p in ("k", "v") or p.endswith("/k") or p.endswith("/v") or "xk" in p or "xv" in p:
            # (L_or_ng, B, S, KV, hd)
            parts = [None] * nd
            if batch_sharded:
                parts[-4] = dp
            else:
                parts[-3] = dp         # SP: shard the sequence (long_500k)
            if leaf.shape[-2] % sizes["model"] == 0:
                parts[-2] = "model"
            elif leaf.shape[-1] % sizes["model"] == 0:
                parts[-1] = "model"
            return P(*parts)
        if "c_kv" in p or "k_rope" in p:
            # (L, B, S, r)
            parts = [None] * nd
            if batch_sharded:
                parts[1] = dp
            else:
                parts[2] = dp
            if leaf.shape[-1] % sizes["model"] == 0:
                parts[-1] = "model"
            return P(*parts)
        if "/conv" in p or p.endswith("conv"):
            # (L, B, K-1, ch)
            parts = [None] * nd
            if batch_sharded:
                parts[1] = dp
            if leaf.shape[-1] % sizes["model"] == 0:
                parts[-1] = "model"
            return P(*parts)
        if p.endswith("/h") or p == "h":
            # mamba1 (L,B,di,N) / mamba2 (L,B,H,P,N)
            parts = [None] * nd
            if batch_sharded:
                parts[1] = dp
            if leaf.shape[2] % sizes["model"] == 0:
                parts[2] = "model"
            return P(*parts)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)


def to_shardings(spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
