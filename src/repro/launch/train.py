"""Training launcher.

    python -m repro.launch.train --arch smollm-360m --steps 200 \
        [--smoke] [--ckpt-dir DIR] [--batch 8] [--seq 64]

``--smoke`` selects the reduced same-family config (CPU-runnable); without
it the full published config is used (real hardware). The mesh is the
production mesh when >1 device is visible, else the single-device mesh.
"""
from __future__ import annotations

import argparse
import tempfile

import jax

from ..configs import get_config, get_smoke_config
from ..data.pipeline import SyntheticLM
from ..models.config import ShapeConfig
from ..runtime.trainer import Trainer, TrainerConfig
from .mesh import make_production_mesh, make_smoke_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if len(jax.devices()) >= 256:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_smoke_mesh()
    shape = ShapeConfig("train", seq_len=args.seq, global_batch=args.batch, kind="train")
    data = SyntheticLM(cfg.vocab, args.batch, args.seq, seed=0)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    trainer = Trainer(
        cfg, shape, mesh, data,
        TrainerConfig(ckpt_dir=ckpt, ckpt_every=args.ckpt_every,
                      max_steps=args.steps, lr=args.lr, warmup=args.warmup),
    )
    print(f"training {cfg.name} on mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"ckpt={ckpt}")
    trainer.run()
    losses = [m["loss"] for m in trainer.metrics if "loss" in m]
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} over {len(losses)} steps; "
          f"checkpoints {trainer.ckpt.all_steps()}")


if __name__ == "__main__":
    main()
