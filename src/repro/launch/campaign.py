"""Fault-injection campaign launcher.

    python -m repro.launch.campaign \
        [--targets flexasr,hlscnn,vecunit] [--apps resmlp,lstm-wlm] \
        [--faults identity,trunc_width,round_floor,drop_cfg,stale_state] \
        [--engine pipelined] [--devices-per-target 2] [--ladder full] \
        [--n-eval 32] [--train-steps 120] [--seed 0] \
        [--workers 4 --mutant-timeout 300 --retries 1] \
        [--json CAMPAIGN.json] [--resume]

Enumerates (target x instruction x fault) mutants from the fault library
(``repro.core.faults``), runs each through the tiered detection ladder
(``repro.core.campaign``: VT2 abstract -> co-simulated fragments ->
per-op golden-vs-mutant diff -> full-application metric deltas -> the
calibrated per-example statistical tier), prints the escape-analysis
matrix, mutants/sec throughput and the canonical matrix digest, and
writes the machine-readable ``CAMPAIGN.json`` (uploaded as a CI
artifact by the campaign smoke job).

``--workers N`` (N > 1) selects the fault-tolerant sharded runner:
mutants fan out across N worker subprocesses with per-mutant timeouts,
crash isolation and bounded retry. With ``--json`` the campaign
checkpoints after every mutant, and ``--resume`` continues an
interrupted run from that file (config fingerprint permitting) — the
resumed escape matrix is bit-identical to an uninterrupted one
(compare ``matrix digest`` lines).
"""
from __future__ import annotations

import argparse
import json

from ..core.campaign import (
    format_matrix, matrix_digest, run_campaign, run_campaign_sharded,
)
from ..core.faults import DIAGNOSTIC_FAULT_CLASSES, FAULT_CLASSES
from ..core.ila import TARGETS


def _csv(s):
    return [x.strip() for x in s.split(",") if x.strip()] if s else None


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--targets", default=None,
                    help="comma-separated target names (default: all "
                         f"registered: {TARGETS.names()})")
    ap.add_argument("--faults", default=None,
                    help="comma-separated fault classes (default: full "
                         f"library: {list(FAULT_CLASSES)}; diagnostic "
                         f"extras: {list(DIAGNOSTIC_FAULT_CLASSES)})")
    ap.add_argument("--apps", default="resmlp,lstm-wlm",
                    help="applications for the app-metric tier")
    ap.add_argument("--engine", default="pipelined",
                    choices=["compiled", "pipelined", "jit", "eager"])
    ap.add_argument("--devices-per-target", type=int, default=2)
    ap.add_argument("--ladder", default="full", choices=["full", "escalate"],
                    help="full = every tier on every mutant (complete "
                         "matrix); escalate = stop at first detection")
    ap.add_argument("--n-eval", type=int, default=32)
    ap.add_argument("--train-steps", type=int, default=120)
    ap.add_argument("--op-samples", type=int, default=2)
    ap.add_argument("--op-boundary", type=int, default=0,
                    help="range-directed op-tier samples per intrinsic: "
                         "activation operands straddling the statically "
                         "computed saturation boundary (ilalint."
                         "boundary_inputs), aimed at sat_wrap-class "
                         "faults; 0 (default) keeps the uniform-only pool")
    ap.add_argument("--acc-delta", type=float, default=0.02,
                    help="app-tier detection threshold: |accuracy delta|")
    ap.add_argument("--ppl-ratio", type=float, default=1.02,
                    help="app-tier detection threshold: perplexity ratio")
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds mutant sampling, app training AND the "
                         "evaluation-subset draw — identical seeds "
                         "reproduce the matrix bit-for-bit")
    ap.add_argument("--stat-floor", type=float, default=1e-3,
                    help="statistical-tier minimum detection threshold on "
                         "the paired per-example shift")
    ap.add_argument("--stat-calib-seeds", type=int, default=2,
                    help="identity-null calibration subsets per (target, "
                         "app); 0 disables the statistical tier")
    ap.add_argument("--workers", type=int, default=1,
                    help=">1 selects the fault-tolerant sharded runner "
                         "with this many worker subprocesses")
    ap.add_argument("--mutant-timeout", type=float, default=300.0,
                    help="sharded runner: per-mutant wall-clock budget; a "
                         "hanging mutant is terminated and recorded as "
                         "outcome 'timeout'")
    ap.add_argument("--retries", type=int, default=1,
                    help="sharded runner: retry budget for transient "
                         "worker failures")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable campaign result here "
                         "(also the per-mutant checkpoint file)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the --json checkpoint if present")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record telemetry spans (per-mutant tier spans; "
                         "sharded workers ship theirs back per result) and "
                         "export a Perfetto trace_event JSON at exit")
    ap.add_argument("--metrics", default=None, metavar="OUT.json",
                    help="export a JSON snapshot of the telemetry metrics "
                         "(escape counters, mutant_s histogram, throughput)")
    args = ap.parse_args()

    # importing repro.accel registers the bundled targets
    from .. import accel  # noqa: F401
    from ..core.telemetry import TELEMETRY

    if args.trace:
        TELEMETRY.enable()

    params = dict(
        targets=_csv(args.targets),
        faults=_csv(args.faults),
        apps=_csv(args.apps) or (),
        engine=args.engine,
        devices_per_target=args.devices_per_target,
        ladder=args.ladder,
        n_eval=args.n_eval,
        train_steps=args.train_steps,
        op_samples=args.op_samples,
        op_boundary=args.op_boundary,
        acc_delta=args.acc_delta,
        ppl_ratio=args.ppl_ratio,
        seed=args.seed,
        stat_floor=args.stat_floor,
        stat_calib_seeds=args.stat_calib_seeds,
    )
    if args.workers > 1:
        result = run_campaign_sharded(
            workers=args.workers,
            mutant_timeout=args.mutant_timeout,
            retries=args.retries,
            checkpoint=args.json,
            resume=args.resume,
            progress=print,
            **params,
        )
    else:
        result = run_campaign(
            checkpoint=args.json, resume=args.resume, progress=print,
            **params,
        )
    print()
    print(format_matrix(result))
    print(f"\nmatrix digest: {matrix_digest(result)}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    if args.trace:
        path = TELEMETRY.export_trace(args.trace)
        print(f"trace: {TELEMETRY.spans_recorded} span(s) "
              f"({TELEMETRY.spans_dropped} dropped) -> {path}")
    if args.metrics:
        bad = TELEMETRY.check_names()
        assert not bad, f"metric names violate the documented schema: {bad}"
        print(f"metrics: -> {TELEMETRY.export_metrics(args.metrics)}")


if __name__ == "__main__":
    main()
