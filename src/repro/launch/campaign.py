"""Fault-injection campaign launcher.

    python -m repro.launch.campaign \
        [--targets flexasr,hlscnn,vecunit] [--apps resmlp,lstm-wlm] \
        [--faults identity,trunc_width,round_floor,drop_cfg,stale_state] \
        [--engine pipelined] [--devices-per-target 2] [--ladder full] \
        [--n-eval 32] [--train-steps 120] [--json CAMPAIGN.json]

Enumerates (target x instruction x fault) mutants from the fault library
(``repro.core.faults``), runs each through the tiered detection ladder
(``repro.core.campaign``: VT2 abstract -> co-simulated fragments ->
per-op golden-vs-mutant diff -> full-application metric deltas on the
pipelined multi-device Executor), prints the escape-analysis matrix and
mutants/sec throughput, and optionally writes the machine-readable
``CAMPAIGN.json`` (uploaded as a CI artifact by the campaign smoke job).
"""
from __future__ import annotations

import argparse
import json

from ..core.campaign import format_matrix, run_campaign
from ..core.faults import FAULT_CLASSES
from ..core.ila import TARGETS


def _csv(s):
    return [x.strip() for x in s.split(",") if x.strip()] if s else None


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--targets", default=None,
                    help="comma-separated target names (default: all "
                         f"registered: {TARGETS.names()})")
    ap.add_argument("--faults", default=None,
                    help="comma-separated fault classes (default: full "
                         f"library: {list(FAULT_CLASSES)})")
    ap.add_argument("--apps", default="resmlp,lstm-wlm",
                    help="applications for the app-metric tier")
    ap.add_argument("--engine", default="pipelined",
                    choices=["compiled", "pipelined", "jit", "eager"])
    ap.add_argument("--devices-per-target", type=int, default=2)
    ap.add_argument("--ladder", default="full", choices=["full", "escalate"],
                    help="full = every tier on every mutant (complete "
                         "matrix); escalate = stop at first detection")
    ap.add_argument("--n-eval", type=int, default=32)
    ap.add_argument("--train-steps", type=int, default=120)
    ap.add_argument("--op-samples", type=int, default=2)
    ap.add_argument("--acc-delta", type=float, default=0.02,
                    help="app-tier detection threshold: |accuracy delta|")
    ap.add_argument("--ppl-ratio", type=float, default=1.02,
                    help="app-tier detection threshold: perplexity ratio")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable campaign result here")
    args = ap.parse_args()

    # importing repro.accel registers the bundled targets
    from .. import accel  # noqa: F401

    result = run_campaign(
        targets=_csv(args.targets),
        faults=_csv(args.faults),
        apps=_csv(args.apps) or (),
        engine=args.engine,
        devices_per_target=args.devices_per_target,
        ladder=args.ladder,
        n_eval=args.n_eval,
        train_steps=args.train_steps,
        op_samples=args.op_samples,
        acc_delta=args.acc_delta,
        ppl_ratio=args.ppl_ratio,
        seed=args.seed,
        progress=print,
    )
    print()
    print(format_matrix(result))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
