"""Serving launcher: prefill + batched autoregressive decode, or a
persistent co-simulation service over the accelerator ILAs.

LLM decode:

    python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
        [--batch 4] [--prompt 16] [--gen 16]

Co-sim serving (ROADMAP: persistent Executor with warm fragment caches):

    python -m repro.launch.serve --cosim resmlp --devices-per-target 2 \
        [--requests 4] [--batch 8]

compiles the named application once (cost-driven flexible matching), keeps
one Executor alive across requests — fragment caches stay warm, compiled
data runners stay traced — and serves minibatch requests through
``Executor.run_many``. ``--devices-per-target`` sizes the simulated device
fleet per accelerator; the Executor's scheduler spreads signature-grouped
SimJob batches over it by estimated cycles (greedy LPT). After the request
loop the per-device utilization and cache-health tables are printed.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _force(*trees):
    """Block until every array in the pytrees is computed — JAX dispatch is
    async, so timing without this measures enqueue, not compute."""
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()


def serve_cosim(args) -> None:
    from ..core import apps, ir
    from ..core.codegen import Executor
    from ..core.compile import compile_program

    by_name = {k.lower(): v for k, v in apps.APPLICATIONS.items()}
    if args.cosim.lower() not in by_name:
        raise SystemExit(
            f"unknown application {args.cosim!r}; "
            f"available: {sorted(apps.APPLICATIONS)}"
        )
    builder, _dsl = by_name[args.cosim.lower()]
    expr, params = builder()
    res = compile_program(expr)
    print(f"compiled {args.cosim}: offloads={res.accelerator_calls} "
          f"policy={res.stats['extraction']['policy']}")

    xshape = next(v for v in ir.postorder(expr)
                  if isinstance(v, ir.Var) and v.name == "x").shape
    ex = Executor("ila", devices_per_target=args.devices_per_target)
    rng = np.random.default_rng(args.seed)
    for req in range(args.requests):
        envs = [
            dict(params, x=rng.standard_normal(xshape).astype(np.float32))
            for _ in range(args.batch)
        ]
        t0 = time.perf_counter()
        outs = ex.run_many(res.program, envs)
        _force(outs)
        dt = time.perf_counter() - t0
        print(f"request {req}: batch={args.batch} "
              f"{dt:.3f}s ({dt / args.batch * 1e3:.1f} ms/sample)"
              f"{'   [cold caches]' if req == 0 else ''}")

    print("\nper-target summary (devices: jobs / est cycles / utilization):")
    for tname, row in sorted(ex.stats_summary().items()):
        devs = row.pop("devices", {})
        print(f"  {tname}: invocations={row['invocations']} "
              f"commands={row['commands']} est_cycles={row['est_cycles']:.0f} "
              f"max_rel_err={row['max_rel_err']:.4f}")
        for dname, d in sorted(devs.items()):
            print(f"    {dname}: jobs={d['jobs']} groups={d['groups']} "
                  f"est_cycles={d['est_cycles']:.0f} "
                  f"utilization={d['utilization']:.2f}")
    print("\ncache health:", ex.cache_info())


def serve_llm(args) -> None:
    from ..configs import get_config, get_smoke_config
    from ..models import api

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    B = args.batch
    max_len = args.prompt + args.gen
    cache = api.init_cache(cfg, B, max_len)

    if cfg.family == "audio":
        frames = jnp.asarray(
            rng.standard_normal((B, api.AUDIO_ENC_FRAMES, cfg.d_model)), jnp.bfloat16)
        t0 = time.perf_counter()
        _, cache = api.prefill(cfg, params, frames, cache)
        tok = jnp.zeros((B, 1), jnp.int32)
        start = 0
    else:
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, args.prompt)), jnp.int32)
        t0 = time.perf_counter()
        logits, cache = api.prefill(cfg, params, prompt, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        start = args.prompt
    _force(tok, cache)
    print(f"prefill: {time.perf_counter()-t0:.2f}s")

    outs = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = api.decode_step(cfg, params, cache, tok, start + i)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(tok)
    _force(tok, cache)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    print(f"decode: {args.gen-1} steps x{B} in {dt:.2f}s ({dt/(args.gen-1)*1e3:.0f} ms/step)")
    print(gen)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="LLM decode mode: model config name")
    ap.add_argument("--cosim", default=None,
                    help="co-sim serving mode: application name (repro.core.apps)")
    ap.add_argument("--devices-per-target", type=int, default=1,
                    help="simulated device instances per accelerator target")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.cosim is not None:
        serve_cosim(args)
    elif args.arch is not None:
        serve_llm(args)
    else:
        ap.error("one of --arch (LLM decode) or --cosim (co-sim serving) is required")


if __name__ == "__main__":
    main()
