"""Serving launcher: prefill + batched autoregressive decode, or a
persistent co-simulation service over the accelerator ILAs.

LLM decode:

    python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
        [--batch 4] [--prompt 16] [--gen 16]

Co-sim serving (ROADMAP: persistent Executor with warm fragment caches):

    python -m repro.launch.serve --cosim resmlp --devices-per-target 2 \
        [--requests 4] [--batch 8] [--engine pipelined] [--mesh auto] \
        [--warmup 1]

compiles the named application once (cost-driven flexible matching), keeps
one Executor alive across requests — fragment caches stay warm, compiled
data runners stay traced — and serves minibatch requests through
``Executor.run_many``. ``--devices-per-target`` sizes the simulated device
fleet per accelerator; the Executor's scheduler spreads signature-grouped
SimJob batches over it by estimated cycles (greedy LPT).

``--warmup N`` requests are excluded from the reported steady-state
throughput (cold and warm numbers print side by side). Warmup always runs
on the synchronous ``compiled`` engine, whose per-group timings calibrate
every target's wall-clock CostModel (``Executor.calibrate_from_timings``);
measured requests then run on ``--engine`` (default ``pipelined``, or
``REPRO_ENGINE``) — the async serving path, with host packing overlapping
device simulation and, under ``--mesh auto``, the vmapped batch axis
sharded over the host's devices. ``--engine fused`` serves through the
fused fast-path runners (docs/simulation.md), reporting fused cold vs
steady ms/sample alongside the compiled warmup numbers. The
compiled/jit/eager/pipelined engines are bit-exact, so the switch never
changes results; the fused tier is tolerance-validated against compiled
in conformance. After the request loop the per-device utilization,
pipeline-stage and cache-health tables are printed.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def _force(*trees):
    """Block until every array in the pytrees is computed — JAX dispatch is
    async, so timing without this measures enqueue, not compute."""
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()


def serve_cosim(args) -> None:
    from ..core import apps, ila, ir
    from ..core.codegen import Executor
    from ..core.compile import compile_program

    by_name = {k.lower(): v for k, v in apps.APPLICATIONS.items()}
    if args.cosim.lower() not in by_name:
        raise SystemExit(
            f"unknown application {args.cosim!r}; "
            f"available: {sorted(apps.APPLICATIONS)}"
        )
    builder, _dsl = by_name[args.cosim.lower()]
    expr, params = builder()
    res = compile_program(expr)
    print(f"compiled {args.cosim}: offloads={res.accelerator_calls} "
          f"policy={res.stats['extraction']['policy']}")
    mesh = ila.set_stream_mesh(args.mesh) if args.mesh != "off" else None
    if args.mesh != "off":
        print(f"stream mesh: {mesh if mesh is not None else 'disabled (single device host)'}")

    xshape = next(v for v in ir.postorder(expr)
                  if isinstance(v, ir.Var) and v.name == "x").shape
    # the serving path defaults to the async engine (unlike the Executor's
    # process-wide compiled default): --engine > REPRO_ENGINE > pipelined.
    # The chunk size is clamped so even the default --batch splits into
    # >= 2 pack/sim chunks per node — a single-chunk batch has nothing for
    # the pipeline to overlap.
    engine = args.engine or os.environ.get("REPRO_ENGINE") or "pipelined"
    ex = Executor("ila", engine=engine,
                  devices_per_target=args.devices_per_target,
                  pipeline_chunk=max(1, min(8, -(-args.batch // 2))))
    rng = np.random.default_rng(args.seed)

    def request(req: int) -> float:
        envs = [
            dict(params, x=rng.standard_normal(xshape).astype(np.float32))
            for _ in range(args.batch)
        ]
        t0 = time.perf_counter()
        outs = ex.run_many(res.program, envs)
        _force(outs)
        return time.perf_counter() - t0

    # Warmup: synchronous engine — fills every cache AND records exact
    # per-group sim timings that calibrate the wall-clock cost models the
    # pipelined scheduler prices groups with. Engines are bit-exact, so
    # switching after warmup never changes served results.
    warmup = max(args.warmup, 1)
    ex.engine = "compiled"
    cold_dts = [request(r) for r in range(warmup)]
    for r, dt in enumerate(cold_dts):
        print(f"warmup {r}: batch={args.batch} {dt:.3f}s "
              f"({dt / args.batch * 1e3:.1f} ms/sample)"
              f"{'   [cold caches]' if r == 0 else ''}")
    fits = ex.calibrate_from_timings()
    for tname, fit in sorted(fits.items()):
        print(f"calibrated {tname}: "
              f"sim {fit.get('sim_us_per_command', 0):.1f} us/cmd, "
              f"pack {fit.get('pack_us_per_command', 0):.1f} us/cmd "
              f"({fit.get('n_groups', 0):.0f} groups)")
    ex.engine = engine
    engine_cold = None
    if engine != "compiled":
        # one excluded request on the measured engine: its batch chunking
        # traces its own vmap shapes (and, for engine=fused, resolves +
        # traces the per-fragment fused runners), which must not pollute
        # steady state — but it IS the engine's cold number, reported below
        engine_cold = request(warmup)
        print(f"warmup {warmup}: engine={engine} {engine_cold:.3f}s [engine traces]")
    ex.reset_stats()   # measured section starts clean (incl. device rows)

    warm_dts = [request(warmup + r) for r in range(args.requests)]
    for r, dt in enumerate(warm_dts):
        print(f"request {r}: engine={engine} batch={args.batch} {dt:.3f}s "
              f"({dt / args.batch * 1e3:.1f} ms/sample)")

    cold_ms = cold_dts[0] / args.batch * 1e3
    warm_ms = float(np.mean(warm_dts)) / args.batch * 1e3 if warm_dts else float("nan")
    print(f"\ncold vs steady state: {cold_ms:.1f} ms/sample (first request, "
          f"compiled) vs {warm_ms:.1f} ms/sample (mean of {len(warm_dts)} "
          f"measured, {engine}) -> {cold_ms / warm_ms:.1f}x")
    if engine_cold is not None:
        ec_ms = engine_cold / args.batch * 1e3
        print(f"{engine} cold vs steady: {ec_ms:.1f} ms/sample (first "
              f"{engine} request, engine traces) vs {warm_ms:.1f} ms/sample "
              f"-> {ec_ms / warm_ms:.1f}x")

    print("\nper-target summary (devices: jobs / est cycles / utilization):")
    for tname, row in sorted(ex.stats_summary().items()):
        devs = row.pop("devices", {})
        print(f"  {tname}: invocations={row['invocations']} "
              f"commands={row['commands']} est_cycles={row['est_cycles']:.0f} "
              f"max_rel_err={row['max_rel_err']:.4f}")
        for dname, d in sorted(devs.items()):
            print(f"    {dname}: jobs={d['jobs']} groups={d['groups']} "
                  f"est_cycles={d['est_cycles']:.0f} "
                  f"utilization={d['utilization']:.2f}")
    if engine in ("pipelined", "fused"):
        stages = ex.pipeline_summary()
        print("pipeline stages (measured requests): "
              f"pack {stages['pack_s']:.3f}s / dispatch {stages['dispatch_s']:.3f}s "
              f"/ readback {stages['readback_s']:.3f}s "
              f"(overlap ~{stages['overlap_s']:.3f}s)")
    print("\ncache health:", ex.cache_info())
    if mesh is not None:
        ila.set_stream_mesh(None)


def serve_llm(args) -> None:
    from ..configs import get_config, get_smoke_config
    from ..models import api

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    B = args.batch
    max_len = args.prompt + args.gen
    cache = api.init_cache(cfg, B, max_len)

    if cfg.family == "audio":
        frames = jnp.asarray(
            rng.standard_normal((B, api.AUDIO_ENC_FRAMES, cfg.d_model)), jnp.bfloat16)
        t0 = time.perf_counter()
        _, cache = api.prefill(cfg, params, frames, cache)
        tok = jnp.zeros((B, 1), jnp.int32)
        start = 0
    else:
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, args.prompt)), jnp.int32)
        t0 = time.perf_counter()
        logits, cache = api.prefill(cfg, params, prompt, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        start = args.prompt
    _force(tok, cache)
    print(f"prefill: {time.perf_counter()-t0:.2f}s")

    outs = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = api.decode_step(cfg, params, cache, tok, start + i)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(tok)
    _force(tok, cache)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    print(f"decode: {args.gen-1} steps x{B} in {dt:.2f}s ({dt/(args.gen-1)*1e3:.0f} ms/step)")
    print(gen)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="LLM decode mode: model config name")
    ap.add_argument("--cosim", default=None,
                    help="co-sim serving mode: application name (repro.core.apps)")
    ap.add_argument("--devices-per-target", type=int, default=1,
                    help="simulated device instances per accelerator target")
    ap.add_argument("--engine", default=None,
                    choices=["compiled", "pipelined", "fused", "jit", "eager"],
                    help="co-sim engine for measured requests (default: "
                         "REPRO_ENGINE or pipelined); warmup always runs "
                         "compiled to calibrate the cost models")
    ap.add_argument("--mesh", default="off",
                    help='"off" (default), "auto" (all host devices) or an '
                         "int: shard the vmapped batch axis over a device mesh")
    ap.add_argument("--warmup", type=int, default=1,
                    help="warmup requests excluded from steady-state stats")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.cosim is not None:
        serve_cosim(args)
    elif args.arch is not None:
        serve_llm(args)
    else:
        ap.error("one of --arch (LLM decode) or --cosim (co-sim serving) is required")


if __name__ == "__main__":
    main()
