"""Serving launcher: prefill + batched autoregressive decode.

    python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
        [--batch 4] [--prompt 16] [--gen 16]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models import api


def _force(*trees):
    """Block until every array in the pytrees is computed — JAX dispatch is
    async, so timing without this measures enqueue, not compute."""
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    B = args.batch
    max_len = args.prompt + args.gen
    cache = api.init_cache(cfg, B, max_len)

    if cfg.family == "audio":
        frames = jnp.asarray(
            rng.standard_normal((B, api.AUDIO_ENC_FRAMES, cfg.d_model)), jnp.bfloat16)
        t0 = time.perf_counter()
        _, cache = api.prefill(cfg, params, frames, cache)
        tok = jnp.zeros((B, 1), jnp.int32)
        start = 0
    else:
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, args.prompt)), jnp.int32)
        t0 = time.perf_counter()
        logits, cache = api.prefill(cfg, params, prompt, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        start = args.prompt
    _force(tok, cache)
    print(f"prefill: {time.perf_counter()-t0:.2f}s")

    outs = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = api.decode_step(cfg, params, cache, tok, start + i)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(tok)
    _force(tok, cache)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    print(f"decode: {args.gen-1} steps x{B} in {dt:.2f}s ({dt/(args.gen-1)*1e3:.0f} ms/step)")
    print(gen)


if __name__ == "__main__":
    main()
