"""Serving launcher: prefill + batched autoregressive decode, or a
continuous-batching co-simulation service over the accelerator ILAs.

LLM decode:

    python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
        [--batch 4] [--prompt 16] [--gen 16]

Co-sim serving (ROADMAP: serving front end over the simulated fleet):

    python -m repro.launch.serve --cosim resmlp --devices-per-target 2 \
        [--requests 16] [--batch 2] [--engine pipelined] [--mesh auto] \
        [--warmup 1] [--concurrency 4] [--queue-depth 16] \
        [--arrival poisson:8] [--no-coalesce] [--no-overlap]

compiles the named application once (cost-driven flexible matching) and
serves it through :class:`repro.core.serving.CosimServer`: a bounded
request queue + single dispatch thread where request k+1's host packing
overlaps request k's simulation tail (``submit_many``/``prepack_many``),
queued same-app requests coalesce into one vmapped dispatch, and
admission control rejects work beyond ``--queue-depth``. Warmup runs on
the synchronous ``compiled`` engine — filling every fragment cache and
calibrating each target's wall-clock CostModel — then measured requests
run on ``--engine`` (default ``pipelined``, or ``REPRO_ENGINE``).

``--concurrency N`` bounds the load generator's outstanding requests;
``--arrival poisson:RATE`` draws exponential inter-arrival gaps at RATE
requests/second (default ``asap``: back-to-back). The run reports
sustained QPS, p50/p95/p99 request latency, rejections, then the
per-device utilization, pipeline-stage and cache-health tables. All
engines serve bit-identical results for a given ``--seed`` (request
operands derive from ``(seed, request_id)``, independent of queue or
coalescing order). See ``docs/serving.md``.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def _force(*trees):
    """Block until every array in the pytrees is computed — JAX dispatch is
    async, so timing without this measures enqueue, not compute."""
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()


def _parse_arrival(spec: str):
    """"asap" -> None (back-to-back); "poisson:RATE" -> RATE (req/s)."""
    if spec == "asap":
        return None
    if spec.startswith("poisson:"):
        rate = float(spec.split(":", 1)[1])
        if rate <= 0:
            raise SystemExit(f"--arrival poisson rate must be > 0, got {rate}")
        return rate
    raise SystemExit(f'--arrival must be "asap" or "poisson:RATE", got {spec!r}')


def serve_cosim(args) -> None:
    from ..core import apps, ila
    from ..core.compile import compile_program
    from ..core.serving import CosimServer, percentiles_ms
    from ..core.telemetry import TELEMETRY

    if args.trace:
        # span recording (Perfetto export at exit); metrics counters are
        # always on — this only turns on the timed-region ring buffer
        TELEMETRY.enable()

    by_name = {k.lower(): v for k, v in apps.APPLICATIONS.items()}
    if args.cosim.lower() not in by_name:
        raise SystemExit(
            f"unknown application {args.cosim!r}; "
            f"available: {sorted(apps.APPLICATIONS)}"
        )
    builder, _dsl = by_name[args.cosim.lower()]
    expr, params = builder()
    res = compile_program(expr)
    print(f"compiled {args.cosim}: offloads={res.accelerator_calls} "
          f"policy={res.stats['extraction']['policy']}")
    mesh = ila.set_stream_mesh(args.mesh) if args.mesh != "off" else None
    if args.mesh != "off":
        print(f"stream mesh: {mesh if mesh is not None else 'disabled (single device host)'}")

    # the serving path defaults to the async engine (unlike the Executor's
    # process-wide compiled default): --engine > REPRO_ENGINE > pipelined.
    # The chunk size is clamped so even the default --batch splits into
    # >= 2 pack/sim chunks per node — a single-chunk batch has nothing for
    # the pipeline to overlap.
    engine = args.engine or os.environ.get("REPRO_ENGINE") or "pipelined"
    rate = _parse_arrival(args.arrival)
    server = CosimServer(
        engine=engine,
        devices_per_target=args.devices_per_target,
        pipeline_chunk=max(1, min(8, -(-args.batch // 2))),
        queue_depth=args.queue_depth,
        max_batch=args.max_batch or max(4 * args.batch, 8),
        coalesce=not args.no_coalesce,
        overlap=not args.no_overlap,
        seed=args.seed,
    )
    server.add_program(args.cosim.lower(), res.program, params)
    ex = server.executor

    warmup = max(args.warmup, 1)
    t0 = time.perf_counter()
    server.start(warmup=warmup, warm_batch=args.batch)
    warm_s = time.perf_counter() - t0
    cold_ms = warm_s / (warmup * args.batch) * 1e3
    print(f"warmup: {warmup} request(s) x batch {args.batch} in {warm_s:.3f}s "
          f"({cold_ms:.1f} ms/sample incl. compile+traces, compiled engine) "
          f"-> serving on {engine}")

    arrival_rng = np.random.default_rng(args.seed)
    handles = []
    t_load = time.perf_counter()
    for _r in range(args.requests):
        outstanding = [h for h in handles if not h.done()]
        while len(outstanding) >= max(1, args.concurrency):
            outstanding[0].wait()
            outstanding = [h for h in outstanding if not h.done()]
        handles.append(server.submit(args.cosim.lower(), batch=args.batch))
        if rate is not None:
            time.sleep(arrival_rng.exponential(1.0 / rate))
    for h in handles:
        h.wait()
    load_s = time.perf_counter() - t_load
    server.close(drain=True)

    served = [h for h in handles if h.status == "done"]
    rejected = [h for h in handles if h.rejected]
    print(f"load: {len(served)}/{len(handles)} served, "
          f"{len(rejected)} rejected "
          f"({args.arrival}, concurrency {args.concurrency}, "
          f"queue depth {args.queue_depth})")

    # steady-state stats — guarded: with --requests 0 (or every request
    # rejected / a ~0s warm request) there is nothing to ratio against
    if served and load_s > 0:
        lats = [h.latency_s for h in served]
        pct = percentiles_ms(lats)
        qps = len(served) / load_s
        warm_ms = float(np.mean(lats)) / args.batch * 1e3
        print(f"sustained: {qps:.1f} req/s ({qps * args.batch:.1f} samples/s) "
              f"| latency p50 {pct['p50_ms']:.1f} / p95 {pct['p95_ms']:.1f} "
              f"/ p99 {pct['p99_ms']:.1f} ms")
        summ = server.summary()
        print(f"coalescing: {summ['batches']} dispatch batch(es), "
              f"mean {summ['mean_batch']:.1f} req/batch, "
              f"max {summ['coalesced_max']}")
        if warm_ms > 0 and np.isfinite(warm_ms) and np.isfinite(cold_ms):
            print(f"cold vs steady state: {cold_ms:.1f} ms/sample (warmup, "
                  f"compiled) vs {warm_ms:.1f} ms/sample (mean of "
                  f"{len(served)} served, {engine}) "
                  f"-> {cold_ms / warm_ms:.1f}x")
    else:
        print("no measured requests (0 requested or all rejected); "
              "skipping steady-state stats")

    print("\nper-target summary (devices: jobs / est cycles / utilization):")
    for tname, row in sorted(ex.stats_summary().items()):
        devs = row.pop("devices", {})
        print(f"  {tname}: invocations={row['invocations']} "
              f"commands={row['commands']} est_cycles={row['est_cycles']:.0f} "
              f"max_rel_err={row['max_rel_err']:.4f}")
        for dname, d in sorted(devs.items()):
            print(f"    {dname}: jobs={d['jobs']} groups={d['groups']} "
                  f"est_cycles={d['est_cycles']:.0f} "
                  f"utilization={d['utilization']:.2f}")
    if engine in ("pipelined", "fused"):
        stages = ex.pipeline_summary()
        print("pipeline stages (measured requests): "
              f"pack {stages['pack_s']:.3f}s / dispatch {stages['dispatch_s']:.3f}s "
              f"/ readback {stages['readback_s']:.3f}s "
              f"(overlap ~{stages['overlap_s']:.3f}s)")
    print("\ncache health:", ex.cache_info())

    # drift probes: how far the CostModel's pricing sits from measured
    # latency (docs/observability.md, "Drift probes") — request-level
    # drift is in the serving.drift_ratio histogram of --metrics
    from ..core.ila import TARGETS
    drifts = {
        t.name: t.cost_model.drift_summary()
        for t in TARGETS.all()
        if t.cost_model is not None and t.cost_model.drift_summary()
    }
    if drifts:
        print("cost-model drift (actual us / predicted cycles):")
        for tname, d in sorted(drifts.items()):
            print(f"  {tname}: geomean {d['ratio_geomean']:.2f} "
                  f"(spread {d['log_ratio_std']:.2f}, n={d['n']:.0f}, "
                  f"{'latency-calibrated' if d['calibrated'] else 'analytic'})")
    else:
        # pipelined serving: per-group drift needs a synchronous
        # materialize (and warmup calibration just reset the probes), so
        # fall back to the request-level ratio admission control ran under
        dr = server.metrics.find("serving.drift_ratio")
        if dr and dr[0].snapshot()["count"]:
            s = dr[0].snapshot()
            print(f"admission drift (service us / priced cycles): "
                  f"p50 {s['p50']:.2f} p95 {s['p95']:.2f} "
                  f"(n={s['count']}, latency-calibrated)")

    if args.trace:
        path = TELEMETRY.export_trace(args.trace)
        print(f"trace: {TELEMETRY.spans_recorded} span(s) "
              f"({TELEMETRY.spans_dropped} dropped) -> {path} "
              f"(open in https://ui.perfetto.dev or chrome://tracing)")
    if args.metrics:
        bad = TELEMETRY.check_names()
        assert not bad, f"metric names violate the documented schema: {bad}"
        print(f"metrics: -> {TELEMETRY.export_metrics(args.metrics)}")
    if mesh is not None:
        ila.set_stream_mesh(None)


def serve_llm(args) -> None:
    from ..configs import get_config, get_smoke_config
    from ..models import api

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    B = args.batch
    max_len = args.prompt + args.gen
    cache = api.init_cache(cfg, B, max_len)

    if cfg.family == "audio":
        frames = jnp.asarray(
            rng.standard_normal((B, api.AUDIO_ENC_FRAMES, cfg.d_model)), jnp.bfloat16)
        t0 = time.perf_counter()
        _, cache = api.prefill(cfg, params, frames, cache)
        tok = jnp.zeros((B, 1), jnp.int32)
        start = 0
    else:
        prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, args.prompt)), jnp.int32)
        t0 = time.perf_counter()
        logits, cache = api.prefill(cfg, params, prompt, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        start = args.prompt
    _force(tok, cache)
    print(f"prefill: {time.perf_counter()-t0:.2f}s")

    outs = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = api.decode_step(cfg, params, cache, tok, start + i)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        outs.append(tok)
    _force(tok, cache)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    print(f"decode: {args.gen-1} steps x{B} in {dt:.2f}s ({dt/(args.gen-1)*1e3:.0f} ms/step)")
    print(gen)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="LLM decode mode: model config name")
    ap.add_argument("--cosim", default=None,
                    help="co-sim serving mode: application name (repro.core.apps)")
    ap.add_argument("--devices-per-target", type=int, default=1,
                    help="simulated device instances per accelerator target")
    ap.add_argument("--engine", default=None,
                    choices=["compiled", "pipelined", "fused", "jit", "eager"],
                    help="co-sim engine for measured requests (default: "
                         "REPRO_ENGINE or pipelined); warmup always runs "
                         "compiled to calibrate the cost models")
    ap.add_argument("--mesh", default="off",
                    help='"off" (default), "auto" (all host devices) or an '
                         "int: shard the vmapped batch axis over a device mesh")
    ap.add_argument("--warmup", type=int, default=1,
                    help="warmup requests excluded from steady-state stats")
    ap.add_argument("--requests", type=int, default=16,
                    help="measured requests the load generator submits")
    ap.add_argument("--concurrency", type=int, default=4,
                    help="load generator: max outstanding requests")
    ap.add_argument("--queue-depth", type=int, default=16,
                    help="server admission control: max queued requests "
                         "(beyond this, submissions are rejected)")
    ap.add_argument("--arrival", default="asap",
                    help='"asap" (back-to-back) or "poisson:RATE" '
                         "(exponential inter-arrival gaps, RATE req/s)")
    ap.add_argument("--max-batch", type=int, default=0,
                    help="coalescing cap in samples per dispatch "
                         "(0: 4x --batch)")
    ap.add_argument("--no-coalesce", action="store_true",
                    help="disable cross-request coalescing (serial baseline)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="drain the pipeline at every request's assemble "
                         "barrier (pre-serving baseline)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record telemetry spans and export a Perfetto/"
                         "chrome://tracing trace_event JSON at exit")
    ap.add_argument("--metrics", default=None, metavar="OUT.json",
                    help="export a JSON snapshot of every telemetry metric "
                         "(counters/gauges/histograms) at exit")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.cosim is not None:
        serve_cosim(args)
    elif args.arch is not None:
        serve_llm(args)
    else:
        ap.error("one of --arch (LLM decode) or --cosim (co-sim serving) is required")


if __name__ == "__main__":
    main()
