"""Jitted train / serve steps with explicit in/out shardings.

``make_train_step``/``make_serve_step`` return (fn, in_shardings,
out_shardings, abstract inputs) ready for ``jax.jit(...).lower().compile()``
— used by both the real launcher and the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import optim
from ..models import api, layers as mlayers
from ..models.config import ArchConfig, ShapeConfig
from . import sharding as shd


def pick_optimizer(cfg: ArchConfig) -> str:
    """671B-class models can't hold fp32 Adam state on one pod: use the
    factored optimizer (DESIGN.md §5)."""
    return "adafactor" if cfg.n_params() > 100e9 else "adamw"


@dataclasses.dataclass
class StepBundle:
    fn: Any                      # python callable (params/opt/batch...) -> ...
    in_shardings: Any
    out_shardings: Any
    abstract_args: Tuple         # ShapeDtypeStructs matching fn's signature
    donate_argnums: Tuple = ()


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0), dtype))


def default_microbatches(cfg: ArchConfig, shape: ShapeConfig) -> int:
    """Gradient-accumulation factor: large-activation cells (MoE / huge
    models at 1M-token batches) scan microbatches so per-layer residuals fit
    HBM (§Perf iteration: deepseek train 255GB -> per-microbatch slices)."""
    tokens = shape.global_batch * shape.seq_len
    if cfg.n_params() > 100e9 or (cfg.n_experts and tokens > 262_144):
        return min(16, max(1, shape.global_batch // 16))
    return 1


def make_train_step(
    cfg: ArchConfig,
    shape: ShapeConfig,
    mesh,
    optimizer: Optional[str] = None,
    grad_compress_pod: bool = False,
    lr: float = 3e-4,
    warmup: int = 2000,
    total_steps: int = 100_000,
    microbatches: Optional[int] = None,
) -> StepBundle:
    opt_name = optimizer or pick_optimizer(cfg)
    opt = optim.make_optimizer(opt_name)
    mb = microbatches if microbatches is not None else default_microbatches(cfg, shape)

    params_abs = abstract_params(cfg)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    pspecs = shd.param_specs(params_abs, mesh)
    ospecs = jax.eval_shape(opt.init, pspecs) if False else _opt_specs(opt_abs, pspecs)
    bspecs = shd.batch_specs(cfg, mesh)

    batch_abs = api.train_input_specs(cfg, shape)
    # loss_fn expects tokens (B, S+1): train_input_specs provides that.

    def train_step(params, opt_state, batch, step):
        with mlayers.mesh_hints(mesh):
            if mb > 1:
                # gradient accumulation: scan microbatches; grads in f32
                micro = {k: v.reshape((mb, v.shape[0] // mb) + v.shape[1:])
                         for k, v in batch.items()}
                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def acc(carry, mbatch):
                    gsum, lsum = carry
                    lv, g = jax.value_and_grad(
                        lambda p: api.loss_fn(cfg, p, mbatch))(params)
                    gsum = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(jnp.float32), gsum, g)
                    return (gsum, lsum + lv), ()

                (gsum, lsum), _ = jax.lax.scan(
                    acc, (g0, 0.0), micro, unroll=mlayers.scan_unroll())
                grads = jax.tree_util.tree_map(lambda g: g / mb, gsum)
                lvalue = lsum / mb
            else:
                lvalue, grads = jax.value_and_grad(
                    lambda p: api.loss_fn(cfg, p, batch))(params)
        grads, gnorm = optim.clip_by_global_norm(grads, 1.0)
        lr_t = optim.cosine_schedule(step, lr, warmup, total_steps)
        new_params, new_opt = opt.update(params, grads, opt_state, lr_t)
        metrics = {"loss": lvalue, "grad_norm": gnorm, "lr": lr_t}
        return new_params, new_opt, metrics

    in_shardings = (
        shd.to_shardings(pspecs, mesh),
        shd.to_shardings(ospecs, mesh),
        shd.to_shardings(_dict_specs(batch_abs, bspecs), mesh),
        NamedSharding(mesh, P()),
    )
    out_shardings = (
        shd.to_shardings(pspecs, mesh),
        shd.to_shardings(ospecs, mesh),
        NamedSharding(mesh, P()),
    )
    step_abs = jax.ShapeDtypeStruct((), jnp.int32)
    return StepBundle(
        fn=train_step,
        in_shardings=in_shardings,
        out_shardings=out_shardings,
        abstract_args=(params_abs, opt_abs, batch_abs, step_abs),
        donate_argnums=(0, 1),
    )


def _dict_specs(batch_abs, bspecs):
    return {k: bspecs.get(k, P(*([None] * len(v.shape)))) for k, v in batch_abs.items()}


def _opt_specs(opt_abs, pspecs):
    """Optimizer state sharding: `m` mirrors params (ZeRO); `v` mirrors
    params for AdamW, or is replicated for Adafactor's factored row/col
    stats (O(m+n) per matrix — cheap; co-sharding them is a perf-pass
    refinement tracked in EXPERIMENTS.md §Perf)."""
    import jax.tree_util as jtu

    out = {}
    for k, sub in opt_abs.items():
        if k == "step":
            out[k] = P()
            continue
        same = jtu.tree_structure(sub) == jtu.tree_structure(pspecs)
        if same:
            out[k] = pspecs
        else:
            out[k] = jtu.tree_map(lambda l: P(*([None] * len(l.shape))), sub)
    return out


def make_serve_step(cfg: ArchConfig, shape: ShapeConfig, mesh, kind: str) -> StepBundle:
    """kind: 'decode' (one token vs deep cache) or 'prefill'."""
    params_abs = abstract_params(cfg)
    pspecs = shd.param_specs(params_abs, mesh)
    B, S = shape.global_batch, shape.seq_len

    if kind == "decode":
        cache_abs = jax.eval_shape(lambda: api.init_cache(cfg, B, S))
        cspecs = shd.cache_specs(cfg, cache_abs, mesh, B)
        tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos_abs = jax.ShapeDtypeStruct((), jnp.int32)

        def serve_step(params, cache, tokens, pos):
            with mlayers.mesh_hints(mesh):
                logits, new_cache = api.decode_step(cfg, params, cache, tokens, pos)
            return logits, new_cache

        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_total = int(np.prod([sizes[a] for a in dp]))
        tok_spec = P(dp if len(dp) > 1 else dp[0], None) if B % dp_total == 0 else P(None, None)
        in_shardings = (
            shd.to_shardings(pspecs, mesh),
            shd.to_shardings(cspecs, mesh),
            NamedSharding(mesh, tok_spec),
            NamedSharding(mesh, P()),
        )
        vocab_ax = "model" if cfg.vocab % sizes["model"] == 0 else None
        out_shardings = (
            NamedSharding(mesh, P(tok_spec[0], None, vocab_ax)),
            shd.to_shardings(cspecs, mesh),
        )
        return StepBundle(serve_step, in_shardings, out_shardings,
                          (params_abs, cache_abs, tok_abs, pos_abs), donate_argnums=(1,))

    # prefill
    inp_abs = api.prefill_input_specs(cfg, shape)
    cache_abs = jax.eval_shape(lambda: api.init_cache(cfg, B, S))
    cspecs = shd.cache_specs(cfg, cache_abs, mesh, B)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_ax = dp if len(dp) > 1 else dp[0]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_total = int(np.prod([sizes[a] for a in dp]))
    bspec = P(dp_ax, None) if B % dp_total == 0 else P(None, dp_ax)

    def prefill_step(params, inputs, cache):
        arg = inputs.get("tokens", inputs.get("frames"))
        with mlayers.mesh_hints(mesh):
            logits, new_cache = api.prefill(cfg, params, arg, cache)
        return logits, new_cache

    inp_specs = {}
    for k, v in inp_abs.items():
        inp_specs[k] = bspec if k == "tokens" else P(bspec[0], None, None)
    in_shardings = (
        shd.to_shardings(pspecs, mesh),
        shd.to_shardings(inp_specs, mesh),
        shd.to_shardings(cspecs, mesh),
    )
    vocab_ax = "model" if cfg.vocab % sizes["model"] == 0 else None
    out_shardings = (
        NamedSharding(mesh, P(None if B % dp_total else dp_ax, None, vocab_ax)),
        shd.to_shardings(cspecs, mesh),
    )
    return StepBundle(prefill_step, in_shardings, out_shardings,
                      (params_abs, inp_abs, cache_abs), donate_argnums=(2,))
