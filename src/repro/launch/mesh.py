"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before the first jax call.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """Single-device mesh for CPU smoke tests (axes sized 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes that carry data parallelism (pod included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
