"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) cell, lower + compile the
appropriate step (train_step / prefill / decode serve_step) against
ShapeDtypeStruct inputs — no allocation — and record:

  * memory_analysis()  — bytes per device (fits / doesn't fit v5e HBM)
  * cost_analysis()    — HLO FLOPs & bytes (roofline compute/memory terms)
  * collective bytes   — parsed from the optimized HLO text (roofline
    collective term): all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute operand sizes

Results land in a JSON file consumed by the roofline report + EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
# The VERY FIRST lines must configure the fake device count, before ANY
# other import that could initialize jax.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import numpy as np

from ..configs import ARCH_IDS, DASHED, get_config
from ..models import api
from ..models.config import SHAPES
from ..roofline.analysis import collective_bytes_from_hlo
from . import steps as st
from .mesh import make_production_mesh


def shape_kind_step(cfg, shape, mesh):
    if shape.kind == "train":
        return st.make_train_step(cfg, shape, mesh), "train_step"
    if shape.kind == "prefill":
        return st.make_serve_step(cfg, shape, mesh, "prefill"), "prefill_step"
    return st.make_serve_step(cfg, shape, mesh, "decode"), "serve_step"


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             capture_hlo: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = api.cell_is_supported(cfg, shape)
    cell = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "skipped" if not ok else None, "reason": why or None,
    }
    if not ok:
        return cell
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        bundle, step_name = shape_kind_step(cfg, shape, mesh)
        with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else mesh:
            jitted = jax.jit(
                bundle.fn,
                in_shardings=bundle.in_shardings,
                out_shardings=bundle.out_shardings,
                donate_argnums=bundle.donate_argnums,
            )
            lowered = jitted.lower(*bundle.abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            # accounting pass: re-lower (never compile) with model scans
            # fully unrolled -> trip-count-correct flops/bytes. NB: must use
            # a FRESH jit wrapper — the original one has a cached trace that
            # would ignore the unroll contextvar.
            acct = {}
            try:
                from ..models import layers as mlayers

                fresh = jax.jit(
                    lambda *a: bundle.fn(*a),
                    in_shardings=bundle.in_shardings,
                    out_shardings=bundle.out_shardings,
                )
                with mlayers.accounting_unroll():
                    acct_lowered = fresh.lower(*bundle.abstract_args)
                aca = acct_lowered.cost_analysis() or {}
                # lowered.cost_analysis is GLOBAL (pre-partitioning);
                # normalize to per-device to match compiled.cost_analysis
                n_dev_ = int(np.prod(mesh.devices.shape))
                acct = {
                    "acct_flops": float(aca.get("flops", 0.0)) / n_dev_,
                    "acct_bytes": float(aca.get("bytes accessed", 0.0)) / n_dev_,
                    "acct_flops_global": float(aca.get("flops", 0.0)),
                }
            except Exception as e:
                acct = {"acct_error": f"{type(e).__name__}: {e}"}
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        coll = {}
        if capture_hlo:
            try:
                hlo = compiled.as_text()
            except Exception:
                hlo = lowered.as_text()
            coll = collective_bytes_from_hlo(hlo)
        n_dev = int(np.prod(mesh.devices.shape))
        cell.update({
            "status": "ok",
            "step": step_name,
            "n_devices": n_dev,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "arg_bytes_per_dev": int(ma.argument_size_in_bytes),
            "out_bytes_per_dev": int(ma.output_size_in_bytes),
            "temp_bytes_per_dev": int(ma.temp_size_in_bytes),
            "peak_bytes_per_dev": int(
                ma.argument_size_in_bytes + ma.temp_size_in_bytes + ma.output_size_in_bytes
            ),
            "hlo_flops": float(ca.get("flops", 0.0)),
            "hlo_bytes": float(ca.get("bytes accessed", 0.0)),
            **acct,
            "collectives": coll,
            "model_params": cfg.n_params(),
            "model_active_params": cfg.n_active_params(),
        })
    except Exception as e:  # a failing cell is a bug in the system
        cell.update({"status": "failed", "error": f"{type(e).__name__}: {e}",
                     "traceback": traceback.format_exc()[-2000:]})
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--no-hlo", action="store_true", help="skip collective parsing (faster)")
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [DASHED.get(args.arch, args.arch)]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                print(f"=== {arch} x {shape} x {'2x16x16' if mp else '16x16'} ===", flush=True)
                r = run_cell(arch, shape, multi_pod=mp, capture_hlo=not args.no_hlo)
                print(json.dumps({k: v for k, v in r.items() if k != "traceback"}), flush=True)
                results.append(r)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_fail = sum(1 for r in results if r["status"] == "failed")
    print(f"DONE: {n_ok} ok, {n_skip} skipped, {n_fail} failed -> {args.out}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
