"""Static ILA verifier launcher.

    python -m repro.launch.lint \
        [--targets flexasr,hlscnn,vta,vecunit] [--seed 0] [--samples 1] \
        [--json LINT.json] [--fail-on warn]

Runs the three static-analysis passes (``repro.core.ilalint``: decode
soundness, state dataflow/hazards over planner-emitted probe streams,
numeric range analysis) over every selected registered target — **zero
simulated commands** — and prints each result. ``error`` and ``warn``
results are *findings* (golden targets must report none); ``note``
results record fault-surface facts (order-sensitive configuration,
carried recurrent state, statically reachable wrap boundaries).

``--fail-on warn`` (the default) exits non-zero when any finding at or
above that severity survives; ``--fail-on error`` tolerates warnings.
``--json LINT.json`` writes the machine-readable result.
"""
from __future__ import annotations

import argparse
import json
import sys

from ..core import ilalint
from ..core.ila import TARGETS


def _csv(s):
    return [x.strip() for x in s.split(",") if x.strip()] if s else None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--targets", default=None,
                    help="comma-separated target names (default: all "
                         f"registered: {TARGETS.names()})")
    ap.add_argument("--seed", type=int, default=0,
                    help="probe-stream sampling seed (crc32-mixed per "
                         "target and intrinsic)")
    ap.add_argument("--samples", type=int, default=1,
                    help="sampled operand draws per intrinsic when "
                         "collecting probe streams")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable lint result here")
    ap.add_argument("--fail-on", default="warn", choices=["warn", "error"],
                    help="exit non-zero when a finding at or above this "
                         "severity is reported (default: warn)")
    args = ap.parse_args(argv)

    # importing repro.accel registers the bundled targets
    from .. import accel  # noqa: F401

    per_target = ilalint.lint_registry(
        _csv(args.targets), seed=args.seed, samples=args.samples
    )
    failing = 0
    notes = 0
    for name, findings in per_target.items():
        print(f"== {name}: {len(findings)} result(s)")
        for f in findings:
            print(f"   {f}")
            if ilalint.severity_at_least(f, args.fail_on):
                failing += 1
            elif f.severity == "note":
                notes += 1
    n_find = sum(
        1 for fs in per_target.values() for f in fs if f.severity != "note"
    )
    print(f"\n{n_find} finding(s), {notes} note(s) across "
          f"{len(per_target)} target(s); "
          f"{failing} at or above --fail-on={args.fail_on}")

    if args.json:
        payload = {
            "schema": 1,
            "seed": args.seed,
            "samples": args.samples,
            "fail_on": args.fail_on,
            "targets": {
                name: [f.to_dict() for f in findings]
                for name, findings in per_target.items()
            },
            "findings": n_find,
            "failing": failing,
        }
        with open(args.json, "w") as fp:
            json.dump(payload, fp, indent=1, sort_keys=True)
            fp.write("\n")
        print(f"wrote {args.json}")
    return 1 if failing else 0


if __name__ == "__main__":
    sys.exit(main())
