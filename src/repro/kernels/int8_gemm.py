"""Pallas TPU kernel: int8 x int8 -> int32 GEMM (the VTA datapath on the MXU).

TPU adaptation of VTA's 16x16 int8 GEMM core: instead of a systolic tile
ISA, one MXU-aligned Pallas kernel. Block shapes are multiples of the MXU
native 128 lane dimension; operands are staged HBM -> VMEM by BlockSpec
tiling and accumulated in int32 across the K grid axis (revisiting the
output block, standard Pallas accumulation pattern).

Grid: (M/bm, N/bn, K/bk) with K innermost so the output block stays resident
in VMEM across the accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    o_ref[...] += jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """a:(M,K) int8, b:(N,K) int8 -> (M,N) int32. Shapes must tile evenly
    (ops.py pads); VMEM working set = bm*bk + bn*bk (int8) + bm*bn (int32)."""
    M, K = a.shape
    N, K2 = b.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bn, bk), lambda m, n, k: (n, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(a, b)
