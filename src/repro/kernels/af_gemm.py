"""Pallas TPU kernel: AdaptivFloat quantized GEMM (the FlexASR PE array).

TPU adaptation of FlexASR's AdaptivFloat linear layer: quantize-on-load to
the AF lattice *inside* the kernel (fusing the paper's store->load transfer
elimination of Section 5.1 into the VMEM pipeline: the AF lattice projection
happens while tiles are staged, costing no extra HBM traffic), fp32 MXU
accumulation, AF re-quantization of the output tile.

Exponent biases are per-tensor scalars, prefetched to SMEM-like (1,1) blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..accel.numerics import AdaptivFloatSpec

_SPEC = AdaptivFloatSpec(8, 3)


def _af_quant(x, exp_bias, n_exp: int, n_man: int):
    """AdaptivFloat lattice projection (mirrors numerics.af_quantize)."""
    e_lo = exp_bias
    e_hi = exp_bias + (2.0 ** n_exp - 1.0)
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    safe = jnp.where(ax > 0, ax, 1.0)
    e = jnp.clip(jnp.floor(jnp.log2(safe)), e_lo, e_hi)
    scale = jnp.exp2(e)
    man = jnp.clip(ax / scale, 1.0, 2.0 - 2.0 ** (-n_man))
    man_q = jnp.round(man * 2.0 ** n_man) / 2.0 ** n_man
    bump = man_q >= 2.0
    e2 = jnp.clip(e + bump, e_lo, e_hi)
    man_q = jnp.where(bump & (e2 > e), 1.0, jnp.minimum(man_q, 2.0 - 2.0 ** (-n_man)))
    q = man_q * jnp.exp2(e2)
    vmax = (2.0 - 2.0 ** (-n_man)) * jnp.exp2(e_hi)
    vmin = jnp.exp2(e_lo)
    q = jnp.minimum(q, vmax)
    q = jnp.where(ax < vmin * 0.5, 0.0, q)
    return sign * q


def _kernel(bx_ref, bw_ref, bo_ref, x_ref, w_ref, b_ref, o_ref, *, n_exp, n_man, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xq = _af_quant(x_ref[...].astype(jnp.float32), bx_ref[0, 0], n_exp, n_man)
    wq = _af_quant(w_ref[...].astype(jnp.float32), bw_ref[0, 0], n_exp, n_man)
    o_ref[...] += jax.lax.dot_general(
        xq, wq, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        y = o_ref[...] + b_ref[...]
        o_ref[...] = _af_quant(y, bo_ref[0, 0], n_exp, n_man)


@functools.partial(
    jax.jit, static_argnames=("spec", "bm", "bn", "bk", "interpret")
)
def af_gemm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    exp_bias_x: jnp.ndarray,
    exp_bias_w: jnp.ndarray,
    exp_bias_o: jnp.ndarray,
    *,
    spec: AdaptivFloatSpec = _SPEC,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """x:(M,K) fp32, w:(N,K) fp32, b:(N,) -> AFq(AFq(x)@AFq(w)^T + b):(M,N)."""
    M, K = x.shape
    N, K2 = w.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    scalar = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    kern = functools.partial(_kernel, n_exp=spec.n_exp, n_man=spec.n_man, nk=nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda m, n, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda m, n, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda m, n, k: (0, 0)),
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bn, bk), lambda m, n, k: (n, k)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(scalar(exp_bias_x), scalar(exp_bias_w), scalar(exp_bias_o), x, w, b.reshape(1, N))
