"""Public jit'd wrappers around the Pallas kernels.

Handles padding to block multiples, dtype plumbing, and the interpret-mode
switch (CPU container -> interpret=True; on a real TPU set
``REPRO_PALLAS_INTERPRET=0``).
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from ..accel import numerics
from ..accel.numerics import AdaptivFloatSpec
from . import af_gemm as _af, flash_attention as _fl, int8_gemm as _i8

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _pad_to(x, m, axis):
    s = x.shape[axis]
    pad = (-s) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def int8_gemm(a: jnp.ndarray, b: jnp.ndarray, *, bm=128, bn=128, bk=128) -> jnp.ndarray:
    """(M,K) int8 @ (N,K)^T int8 -> (M,N) int32, arbitrary shapes."""
    M, N = a.shape[0], b.shape[0]
    ap = _pad_to(_pad_to(a, bm, 0), bk, 1)
    bp = _pad_to(_pad_to(b, bn, 0), bk, 1)
    out = _i8.int8_gemm(ap, bp, bm=bm, bn=bn, bk=bk, interpret=INTERPRET)
    return out[:M, :N]


def af_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    spec: AdaptivFloatSpec = AdaptivFloatSpec(8, 3),
    *,
    bm=128,
    bn=128,
    bk=128,
) -> jnp.ndarray:
    """FlexASR linear-layer semantics on the MXU; auto exponent biases."""
    bx = numerics.af_exp_bias(x, spec)
    bw = numerics.af_exp_bias(w, spec)
    ideal = x @ w.T + b[None, :]
    bo = numerics.af_exp_bias(ideal, spec)
    M, N = x.shape[0], w.shape[0]
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bn, 0), bk, 1)
    bp = _pad_to(b, bn, 0)
    out = _af.af_gemm(
        xp, wp, bp, bx, bw, bo, spec=spec, bm=bm, bn=bn, bk=bk, interpret=INTERPRET
    )
    return out[:M, :N]


def flash_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal=True, bq=128, bk=128
) -> jnp.ndarray:
    """(B,Hq,S,D) x (B,Hkv,Sk,D) -> (B,Hq,S,D); pads S/Sk to block size."""
    B, Hq, S, D = q.shape
    Sk = k.shape[2]
    qp = _pad_to(q, bq, 2)
    kp = _pad_to(k, bk, 2)
    vp = _pad_to(v, bk, 2)
    if kp.shape[2] > Sk:
        # padded KV must never win the softmax: rely on causal mask for
        # causal=True; for non-causal, mask via -inf scores using a pad flag
        pass
    out = _fl.flash_attention(qp, kp, vp, causal=causal, bq=bq, bk=bk, interpret=INTERPRET)
    return out[:, :, :S, :]
