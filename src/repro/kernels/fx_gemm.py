"""Pallas TPU kernel: fixed-point quantized GEMM (the HLSCNN conv PE array).

HLSCNN's conv2d lowers host-side to im2col patches; this kernel fuses the
fixed-point lattice projections — 16-bit activations, 8/16-bit weights per
the CFG_DTYPE register — into the VMEM tile pipeline with fp32 MXU
accumulation and a fixed-point re-quantization of the output tile, mirroring
``kernels/af_gemm.py``'s AdaptivFloat idiom. Quantization is idempotent on
already-projected values (the lattice scales are powers of two), so callers
may pre-quantize/mask activations host-side without double-rounding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..accel.numerics import FixedPointSpec


def _fx_quant(x, scale: float, qmin: float, qmax: float):
    """Fixed-point lattice projection (mirrors numerics.fx_quantize)."""
    q = jnp.clip(jnp.round(x * scale), qmin, qmax)
    return q / scale


def _kernel(x_ref, w_ref, o_ref, *, xs, ws, os_, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xq = _fx_quant(x_ref[...].astype(jnp.float32), *xs)
    wq = _fx_quant(w_ref[...].astype(jnp.float32), *ws)
    o_ref[...] += jax.lax.dot_general(
        xq, wq, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = _fx_quant(o_ref[...], *os_)


def _params(spec: FixedPointSpec):
    return (float(spec.scale), float(spec.qmin), float(spec.qmax))


@functools.partial(
    jax.jit, static_argnames=("x_spec", "w_spec", "o_spec", "bm", "bn", "bk", "interpret")
)
def fx_gemm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    x_spec: FixedPointSpec,
    w_spec: FixedPointSpec,
    o_spec: FixedPointSpec,
    bm: int = 16,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """x:(M,K) fp32, w:(N,K) fp32 -> FXq_o(FXq_x(x) @ FXq_w(w)^T):(M,N)."""
    M, K = x.shape
    N, K2 = w.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    kern = functools.partial(
        _kernel, xs=_params(x_spec), ws=_params(w_spec), os_=_params(o_spec), nk=nk
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bn, bk), lambda m, n, k: (n, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, w)
