"""Pallas TPU kernels (+ ops.py jit wrappers, ref.py oracles).

int8_gemm  — VTA's int8 GEMM core on the MXU
af_gemm    — FlexASR's AdaptivFloat linear layer (quantize-on-load fused)
flash_attention — online-softmax attention with GQA (serving/training hotspot)
"""
