"""Pallas TPU kernel: FlashAttention with GQA + causal masking.

The framework's serving/training compute hotspot. Online-softmax streaming
over KV blocks: the query block stays VMEM-resident across the KV grid axis;
running max/denominator/accumulator live in VMEM scratch. Block shapes are
MXU-aligned (128 multiples); KV is streamed so the working set is
O(bq*d + bk*d + bq*bk) regardless of sequence length.

GQA: q heads map onto kv heads via the BlockSpec index map (no KV
replication in HBM — the gather happens in the VMEM staging).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, causal, bq, bk, nkv):
    kv = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(kv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (bq, d)
    k = k_ref[0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0].astype(jnp.float32)          # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_idx = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_idx = kv * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_idx >= k_idx, s, NEG_INF)

    m_prev = m_scr[...]                        # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(kv == nkv - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret")
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """q:(B,Hq,S,D) k,v:(B,Hkv,S,D) with Hq % Hkv == 0 -> (B,Hq,S,D)."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    Sk = k.shape[2]
    assert Hq % Hkv == 0 and S % bq == 0 and Sk % bk == 0
    group = Hq // Hkv
    qf = q.reshape(B * Hq, S, D)
    kf = k.reshape(B * Hkv, Sk, D)
    vf = v.reshape(B * Hkv, Sk, D)
    nq, nkv = S // bq, Sk // bk
    scale = 1.0 / (D ** 0.5)

    def q_map(bh, qi, kv):
        return (bh, qi, 0)

    def kv_map(bh, qi, kv):
        b = bh // Hq
        h = bh % Hq
        return (b * Hkv + h // group, kv, 0)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, bq=bq, bk=bk, nkv=nkv
    )
    out = pl.pallas_call(
        kern,
        grid=(B * Hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, D), q_map),
            pl.BlockSpec((1, bk, D), kv_map),
            pl.BlockSpec((1, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, S, D)
