"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function is the bit-accurate semantic reference its kernel must match
(``assert_allclose`` in tests across shape/dtype sweeps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..accel import numerics
from ..accel.numerics import AdaptivFloatSpec


def int8_gemm_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a:(M,K) int8, b:(N,K) int8 -> (M,N) int32 (exact integer GEMM)."""
    return jnp.dot(
        a.astype(jnp.int32), b.astype(jnp.int32).T, preferred_element_type=jnp.int32
    )


def af_gemm_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    exp_bias_w: float,
    exp_bias_x: float,
    exp_bias_o: float,
    spec: AdaptivFloatSpec = AdaptivFloatSpec(8, 3),
) -> jnp.ndarray:
    """FlexASR LinearLayer semantics: AFq(AFq(x) @ AFq(w)^T + b).

    Matches ``flexasr._fn_linear`` (fp32 accumulation, AF re-quantized out).
    """
    xq = numerics.af_quantize(x, spec, exp_bias=exp_bias_x)
    wq = numerics.af_quantize(w, spec, exp_bias=exp_bias_w)
    y = xq @ wq.T + b[None, :]
    return numerics.af_quantize(y, spec, exp_bias=exp_bias_o)


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = True
) -> jnp.ndarray:
    """q,k,v: (B, H, S, D) -> (B, H, S, D), fp32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) / jnp.sqrt(float(d))
    if causal:
        S, Sk = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((S, Sk), bool), k=Sk - S)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
