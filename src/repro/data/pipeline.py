"""Deterministic, resumable, per-host-sharded token pipeline.

Two backends:
  * ``SyntheticLM``    — seeded Markov-chain token stream (no dataset files
    offline; DESIGN.md §7). Fully deterministic given (seed, step, shard).
  * ``MemmapTokens``   — flat binary token file (np.memmap), strided per
    host shard; the production path.

Determinism contract: ``batch_at(step)`` is a pure function of
(seed, step, host_shard), so a restarted job that resumes from checkpoint
step k sees exactly the batches it would have seen — required for
fault-tolerant restart (runtime/trainer.py) and elastic re-sharding (a
host's stream depends only on its shard index, not on wall-clock history).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class ShardInfo:
    host_id: int = 0
    n_hosts: int = 1


class SyntheticLM:
    """Markov-chain synthetic text; vocabulary-sized transition table."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 shard: ShardInfo = ShardInfo()):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.shard = shard
        base = np.random.default_rng(seed)
        # shared transition structure so the task is learnable
        self._trans_logits = base.standard_normal((min(vocab, 512),)).astype(np.float32)

    @property
    def local_batch(self) -> int:
        assert self.batch % self.shard.n_hosts == 0
        return self.batch // self.shard.n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """(local_batch, seq+1) tokens; pure function of (seed, step, shard)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4099 + self.shard.host_id
        )
        b = self.local_batch
        toks = np.zeros((b, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, b)
        # cheap structured stream: x_{t+1} = (a*x_t + noise) mod vocab
        a = 6364136223846793005 % self.vocab or 1
        noise = rng.integers(0, 7, (b, self.seq))
        for t in range(self.seq):
            toks[:, t + 1] = (toks[:, t] * a + noise[:, t] + 1) % self.vocab
        return {"tokens": toks}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1

    def state(self, step: int) -> Dict:
        return {"kind": "synthetic", "seed": self.seed, "step": step}


class MemmapTokens:
    """Flat int32 token file; host h reads contiguous stripes h, h+n, ..."""

    def __init__(self, path: str, batch: int, seq: int, shard: ShardInfo = ShardInfo()):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.batch, self.seq, self.shard = batch, seq, shard
        self.per_sample = seq + 1
        self.n_samples = len(self.tokens) // self.per_sample

    @property
    def local_batch(self) -> int:
        return self.batch // self.shard.n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        b = self.local_batch
        idx = (step * self.batch + self.shard.host_id * b + np.arange(b)) % self.n_samples
        out = np.stack([
            self.tokens[i * self.per_sample : (i + 1) * self.per_sample] for i in idx
        ])
        return {"tokens": out.astype(np.int32)}

    def iterate(self, start_step: int = 0):
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def write_token_file(path: str, tokens: np.ndarray):
    np.asarray(tokens, np.int32).tofile(path)
