"""FlexASR accelerator ILA (Tambe et al., ISSCC'21) — JAX model.

FlexASR is a speech/NLP accelerator with coarse-grained operations (linear
layer, LSTM, temporal max/mean pooling, layer norm, attention) computing in
the **AdaptivFloat** custom numeric. Its software/hardware interface is MMIO:
the driver writes 128-bit words to configure, load data, and trigger
functions (Figure 1 of the paper). The ILA lifts each MMIO command to an
instruction over architectural state (Figure 6).

Architectural state (sizes are the model's parameters, like the real device's
SRAM sizing):

  gb_large   (GB_ROWS, V)  global buffer, V=16 lanes (128b words of fp8 AF)
  pe_w       (MAX_OUT, MAX_IN)   PE weight memory        (linear / LSTM Wi)
  pe_wh      (MAX_4H, MAX_H)     recurrent weight memory (LSTM Wh)
  pe_b       (MAX_OUT,)          bias memory
  h_state/c_state (MAX_H,)       LSTM hidden/cell state
  + configuration registers (dims, base addresses, activation mode,
    AdaptivFloat exponent biases, function select)

Instruction set (opcode == decoded MMIO address range):

  WRITE_V      store one V-lane row into gb_large[addr]
  WRITE_W      store one V-lane row slice into pe_w
  WRITE_WH     store one V-lane row slice into pe_wh
  WRITE_B      store one V-lane slice into pe_b
  PE_CFG_RNN_LAYER_SIZING   num_in / num_out
  PE_CFG_MNGR               is_bias, base addresses
  PE_CFG_ACT_MNGR           activation function select
  GB_CFG_MMNGR              gb base_in / base_out
  GB_CFG_GB_CONTROL         mode (linear/lstm/maxpool/meanpool/layernorm/attn),
                            num_timestep
  CFG_NUMERICS              AdaptivFloat exponent biases (wgt/act/out)
  FN_START                  trigger the configured function
  (read-out is host-side: slice gb_large from final state, like MMIO reads)

Semantics of FN_START in AdaptivFloat: operands are quantized to the AF
lattice with the configured exponent biases, MACs accumulate in fp32 (the
PEs accumulate wide), and results are re-quantized to AF before being stored
back to the global buffer — matching the real datapath closely enough that
operation-level relative errors reproduce Table 2's magnitudes.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..core import ir
from ..core.egraph import P, V as PV, Rewrite, shape_of
from ..core.ila import (
    ILA, BulkWrite, Command, CompiledFragment, DataStream, FusedRunner,
    PackedStream, _shard_batched, fingerprint, fused_lowering,
    fused_pad_streams,
)
from . import numerics
from .numerics import AdaptivFloatSpec
from .target import (
    AcceleratorTarget, CostModel, Intrinsic, SimJob, VT2Case, register_target,
)

V = 16            # interface lanes (128-bit MMIO word of 8-bit AF values)
GB_ROWS = 4096    # global buffer rows
MAX_IN = 128
MAX_OUT = 256     # also holds LSTM's 4H gate rows
MAX_H = 64
MAX_TS = 128
AF = AdaptivFloatSpec(n_bits=8, n_exp=3)

# opcodes (the "MMIO address map")
WRITE_V = 0x10
WRITE_W = 0x11
WRITE_WH = 0x12
WRITE_B = 0x13
PE_CFG_RNN_LAYER_SIZING = 0x20
PE_CFG_MNGR = 0x21
PE_CFG_ACT_MNGR = 0x22
GB_CFG_MMNGR = 0x23
GB_CFG_GB_CONTROL = 0x24
CFG_NUMERICS = 0x25
FN_START = 0x30

MODE_LINEAR = 1
MODE_LSTM = 2
MODE_MAXPOOL = 3
MODE_MEANPOOL = 4
MODE_LAYERNORM = 5
MODE_ATTENTION = 6

ACT_NONE = 0
ACT_RELU = 1
ACT_SIGMOID = 2
ACT_TANH = 3

flexasr = ILA("flexasr", vwidth=V)

TARGET = AcceleratorTarget(
    "flexasr",
    flexasr,
    display_name="FlexASR",
    capabilities={
        "max_in": MAX_IN, "max_out": MAX_OUT, "max_h": MAX_H, "max_ts": MAX_TS,
        "numerics": "adaptivfloat8",
    },
    doc="speech/NLP accelerator: linear/LSTM/pooling/layernorm/attention in AdaptivFloat",
    # VT2 fragments share the same fp32 compute paths; a hair of slack for
    # the maxpool case's different-but-exact windowing route
    vt2_tol=1e-6,
)
FRAGMENTS = TARGET.fragments
# AdaptivFloat renormalizes per tensor, but the write datapath's wrap point
# for unit-scale activation data sits at |x| ~ 4.5 (numerics.BLOCK_SCALED_SAT);
# application residual streams reach +/-6 — the static range pass reports the
# reachable-wrap boundary the sat_wrap campaign fault exploits. h_state /
# c_state are recurrent by design: carried across fragments (LSTM), the
# stale_state fault surface.
TARGET.declare_lint(
    input_range=(-6.0, 6.0), carried_state=("h_state", "c_state"),
)

flexasr.state("gb_large", lambda: jnp.zeros((GB_ROWS + MAX_TS * (MAX_IN // V), V), jnp.float32))
flexasr.state("pe_w", lambda: jnp.zeros((MAX_OUT, MAX_IN), jnp.float32))
flexasr.state("pe_wh", lambda: jnp.zeros((MAX_OUT, MAX_H), jnp.float32))
flexasr.state("pe_b", lambda: jnp.zeros((MAX_OUT,), jnp.float32))
flexasr.state("h_state", lambda: jnp.zeros((MAX_H,), jnp.float32))
flexasr.state("c_state", lambda: jnp.zeros((MAX_H,), jnp.float32))
for reg in (
    "num_in", "num_out", "num_ts", "is_bias", "act_mode", "base_in",
    "base_out", "base_aux", "mode", "exp_bias_w", "exp_bias_a", "exp_bias_o",
    "num_aux",
):
    flexasr.state(reg, (lambda: jnp.zeros((), jnp.float32)))


def _set_row(buf, addr, data):
    return jax.lax.dynamic_update_slice(buf, data[None, :], (addr, 0))


@flexasr.instruction("write_v", WRITE_V, "store one V-lane row into gb_large")
def _write_v(st, addr, data):
    st = dict(st)
    st["gb_large"] = _set_row(st["gb_large"], addr, data)
    return st


@flexasr.instruction("write_w", WRITE_W, "store one V-lane slice into pe weight row")
def _write_w(st, addr, data):
    # addr encodes row * (MAX_IN//V) + col_block
    st = dict(st)
    row = addr // (MAX_IN // V)
    col = (addr % (MAX_IN // V)) * V
    st["pe_w"] = jax.lax.dynamic_update_slice(st["pe_w"], data[None, :], (row, col))
    return st


@flexasr.instruction("write_wh", WRITE_WH, "store one V-lane slice into recurrent weight row")
def _write_wh(st, addr, data):
    st = dict(st)
    row = addr // (MAX_H // V)
    col = (addr % (MAX_H // V)) * V
    st["pe_wh"] = jax.lax.dynamic_update_slice(st["pe_wh"], data[None, :], (row, col))
    return st


@flexasr.instruction("write_b", WRITE_B, "store one V-lane slice of bias")
def _write_b(st, addr, data):
    st = dict(st)
    st["pe_b"] = jax.lax.dynamic_update_slice(st["pe_b"], data, (addr * V,))
    return st


def _cfg(names):
    def update(st, addr, data):
        st = dict(st)
        for i, n in enumerate(names):
            st[n] = data[i]
        return st

    return update


flexasr.instruction("pe_cfg_rnn_layer_sizing", PE_CFG_RNN_LAYER_SIZING)(
    _cfg(["num_in", "num_out"])
)
flexasr.instruction("pe_cfg_mngr", PE_CFG_MNGR)(_cfg(["is_bias"]))
flexasr.instruction("pe_cfg_act_mngr", PE_CFG_ACT_MNGR)(_cfg(["act_mode"]))
flexasr.instruction("gb_cfg_mmngr", GB_CFG_MMNGR)(_cfg(["base_in", "base_out", "base_aux", "num_aux"]))
flexasr.instruction("gb_cfg_gb_control", GB_CFG_GB_CONTROL)(_cfg(["mode", "num_ts"]))
flexasr.instruction("cfg_numerics", CFG_NUMERICS)(
    _cfg(["exp_bias_w", "exp_bias_a", "exp_bias_o"])
)


# -- FN_START: the coarse compute, in AdaptivFloat ---------------------------


def _afq(x, bias):
    return numerics.af_quantize(x, AF, exp_bias=bias)


def _gb_read(st, base, rows):
    """Read ``rows`` consecutive V-rows from gb_large starting at ``base``
    (static row count, dynamic base)."""
    return jax.lax.dynamic_slice(st["gb_large"], (base.astype(jnp.int32), 0), (rows, V))


def _gb_matrix(st, base, n_vec_rows):
    """View a (MAX_TS, MAX_IN) tensor stored as MAX_TS*(MAX_IN//V) rows."""
    rows = _gb_read(st, base, MAX_TS * (MAX_IN // V))
    return rows.reshape(MAX_TS, MAX_IN)


def _act(y, mode):
    return jax.lax.switch(
        mode.astype(jnp.int32),
        [
            lambda v: v,
            lambda v: jnp.maximum(v, 0.0),
            lambda v: 1.0 / (1.0 + jnp.exp(-v)),
            lambda v: jnp.tanh(v),
        ],
        y,
    )


def _mask1(n, size):
    return (jnp.arange(size) < n.astype(jnp.int32)).astype(jnp.float32)


def _fn_linear(st):
    X = _gb_matrix(st, st["base_in"], None)                     # (MAX_TS, MAX_IN)
    m_in = _mask1(st["num_in"], MAX_IN)
    m_out = _mask1(st["num_out"], MAX_OUT)
    m_ts = _mask1(st["num_ts"], MAX_TS)
    Wq = _afq(st["pe_w"], st["exp_bias_w"]) * m_out[:, None] * m_in[None, :]
    Xq = _afq(X, st["exp_bias_a"]) * m_ts[:, None] * m_in[None, :]
    b = st["pe_b"][:MAX_OUT] * m_out * st["is_bias"]
    Y = Xq @ Wq.T + b[None, :]
    Y = _act(Y, st["act_mode"])
    Y = _afq(Y, st["exp_bias_o"]) * m_ts[:, None] * m_out[None, :]
    # store back to gb at base_out, MAX_IN-wide rows (num_out <= MAX_IN lanes used)
    out_rows = Y[:, :MAX_IN].reshape(MAX_TS * (MAX_IN // V), V)
    st = dict(st)
    st["gb_large"] = jax.lax.dynamic_update_slice(
        st["gb_large"], out_rows, (st["base_out"].astype(jnp.int32), 0)
    )
    return st


def _fn_lstm(st):
    X = _gb_matrix(st, st["base_in"], None)                     # (MAX_TS, MAX_IN)
    m_in = _mask1(st["num_in"], MAX_IN)
    H = MAX_H
    m_h = _mask1(st["num_out"], H)
    Wi = _afq(st["pe_w"], st["exp_bias_w"]) * m_in[None, :]     # (4H, MAX_IN)
    Wh = _afq(st["pe_wh"], st["exp_bias_w"]) * m_h[None, :]     # (4H, H)
    b = st["pe_b"] * st["is_bias"]

    def cell(carry, x_t):
        h, c = carry
        xq = _afq(x_t, st["exp_bias_a"]) * m_in
        gates = Wi[: 4 * H] @ xq + Wh[: 4 * H] @ h + b[: 4 * H]
        i = jax.nn.sigmoid(gates[0 * H : 1 * H])
        f = jax.nn.sigmoid(gates[1 * H : 2 * H])
        g = jnp.tanh(gates[2 * H : 3 * H])
        o = jax.nn.sigmoid(gates[3 * H : 4 * H])
        c2 = _afq(f * c + i * g, st["exp_bias_o"]) * m_h
        h2 = _afq(o * jnp.tanh(c2), st["exp_bias_o"]) * m_h
        return (h2, c2), h2

    (h_f, c_f), hs = jax.lax.scan(cell, (st["h_state"], st["c_state"]), X)
    m_ts = _mask1(st["num_ts"], MAX_TS)
    hs = hs * m_ts[:, None]
    out = jnp.zeros((MAX_TS, MAX_IN), jnp.float32).at[:, :H].set(hs)
    out_rows = out.reshape(MAX_TS * (MAX_IN // V), V)
    st = dict(st)
    st["h_state"], st["c_state"] = h_f, c_f
    st["gb_large"] = jax.lax.dynamic_update_slice(
        st["gb_large"], out_rows, (st["base_out"].astype(jnp.int32), 0)
    )
    return st


def _fn_pool(st, kind):
    X = _gb_matrix(st, st["base_in"], None)            # (MAX_TS, MAX_IN) rows = timesteps
    # temporal pooling: pairwise over timestep axis (window (2,1) stride (2,1))
    pairs = X.reshape(MAX_TS // 2, 2, MAX_IN)
    Y = jnp.max(pairs, axis=1) if kind == "max" else jnp.mean(pairs, axis=1)
    Y = _afq(Y, st["exp_bias_o"])
    m_ts = _mask1(jnp.ceil(st["num_ts"] / 2), MAX_TS // 2)
    m_in = _mask1(st["num_in"], MAX_IN)
    Y = Y * m_ts[:, None] * m_in[None, :]
    out = jnp.zeros((MAX_TS, MAX_IN), jnp.float32).at[: MAX_TS // 2].set(Y)
    out_rows = out.reshape(MAX_TS * (MAX_IN // V), V)
    st = dict(st)
    st["gb_large"] = jax.lax.dynamic_update_slice(
        st["gb_large"], out_rows, (st["base_out"].astype(jnp.int32), 0)
    )
    return st


def _fn_layernorm(st):
    X = _gb_matrix(st, st["base_in"], None)
    m_in = _mask1(st["num_in"], MAX_IN)
    n = st["num_in"]
    Xq = _afq(X, st["exp_bias_a"]) * m_in[None, :]
    mu = jnp.sum(Xq, axis=-1, keepdims=True) / n
    var = jnp.sum(((Xq - mu) * m_in[None, :]) ** 2, axis=-1, keepdims=True) / n
    gamma = st["pe_w"][0, :MAX_IN]
    beta = st["pe_b"][:MAX_IN]
    Y = ((Xq - mu) / jnp.sqrt(var + 1e-5) * gamma[None, :] + beta[None, :]) * m_in[None, :]
    Y = _afq(Y, st["exp_bias_o"]) * m_in[None, :]
    m_ts = _mask1(st["num_ts"], MAX_TS)
    Y = Y * m_ts[:, None]
    out_rows = Y.reshape(MAX_TS * (MAX_IN // V), V)
    st = dict(st)
    st["gb_large"] = jax.lax.dynamic_update_slice(
        st["gb_large"], out_rows, (st["base_out"].astype(jnp.int32), 0)
    )
    return st


def _fn_attention(st):
    # Q at base_in (num_ts rows), K at base_aux, V at base_aux + MAX block
    Q = _gb_matrix(st, st["base_in"], None)            # (MAX_TS, MAX_IN)
    K = _gb_matrix(st, st["base_aux"], None)
    Vv = _gb_matrix(st, st["base_aux"] + MAX_TS * (MAX_IN // V), None)
    m_in = _mask1(st["num_in"], MAX_IN)
    m_q = _mask1(st["num_ts"], MAX_TS)
    m_k = _mask1(st["num_aux"], MAX_TS)
    Qq = _afq(Q, st["exp_bias_a"]) * m_q[:, None] * m_in[None, :]
    Kq = _afq(K, st["exp_bias_a"]) * m_k[:, None] * m_in[None, :]
    Vq = _afq(Vv, st["exp_bias_a"]) * m_k[:, None] * m_in[None, :]
    scores = (Qq @ Kq.T) / jnp.sqrt(st["num_in"])
    scores = jnp.where(m_k[None, :] > 0, scores, -jnp.inf)
    # softmax in the PE's fp accumulation, then AF re-quantized
    p = jax.nn.softmax(scores, axis=-1)
    p = _afq(p, jnp.zeros(()) - (2 ** AF.n_exp - 1))   # probs in [0,1]: bias pins max exp at 0
    Y = (p @ Vq) * m_q[:, None] * m_in[None, :]
    Y = _afq(Y, st["exp_bias_o"]) * m_q[:, None] * m_in[None, :]
    out_rows = Y.reshape(MAX_TS * (MAX_IN // V), V)
    st = dict(st)
    st["gb_large"] = jax.lax.dynamic_update_slice(
        st["gb_large"], out_rows, (st["base_out"].astype(jnp.int32), 0)
    )
    return st


@flexasr.instruction("fn_start", FN_START, "trigger the configured function")
def _fn_start(st, addr, data):
    mode = st["mode"].astype(jnp.int32)
    return jax.lax.switch(
        jnp.clip(mode - 1, 0, 5),
        [
            _fn_linear,
            _fn_lstm,
            lambda s: _fn_pool(s, "max"),
            lambda s: _fn_pool(s, "mean"),
            _fn_layernorm,
            _fn_attention,
        ],
        dict(st),
    )


# --------------------------------------------------------------------------
# Driver-side fragment builders (the IR-accelerator mappings, Figure 5)
#
# Each builder is split into a *setup* stream (weight/config load, built and
# simulated once per parameter set, cached as post-setup architectural state)
# and a *data* stream (activation rows + FN_START, re-packed per sample).
# ``build_*_fragment`` keeps the original one-shot API: setup + data
# concatenated into a single eager-simulable command list.
# --------------------------------------------------------------------------


def _rows_of(x: np.ndarray) -> np.ndarray:
    """Marshal a (T, D) tensor into V-lane rows padded to (MAX_TS, MAX_IN)."""
    T, D = x.shape
    buf = np.zeros((MAX_TS, MAX_IN), np.float32)
    buf[:T, :D] = np.asarray(x, np.float32)
    return buf.reshape(MAX_TS * (MAX_IN // V), V)


def _matrix_bulk(base: int, x: np.ndarray) -> BulkWrite:
    """(T, D) tensor -> bulk WRITE_V run: T*(MAX_IN//V) rows at ``base``."""
    n = x.shape[0] * (MAX_IN // V)
    return BulkWrite("gb_large", base, _rows_of(x)[:n], WRITE_V)


def _tail(entries) -> PackedStream:
    """Pack [(opcode, values), ...] config/trigger commands into a stream."""
    n = len(entries)
    ops = np.array([e[0] for e in entries], np.int32)
    addrs = np.zeros((n,), np.int32)
    data = np.zeros((n, V), np.float32)
    for i, (_, vals) in enumerate(entries):
        vals = np.asarray(vals, np.float32)
        data[i, : len(vals)] = vals
    return PackedStream(ops, addrs, data)


def _write_weight_cmds(w: np.ndarray) -> List[Command]:
    O, I = w.shape
    cmds = []
    for r in range(O):
        for cb in range((I + V - 1) // V):
            seg = np.zeros((V,), np.float32)
            seg[: min(V, I - cb * V)] = w[r, cb * V : cb * V + min(V, I - cb * V)]
            cmds.append(Command(WRITE_W, r * (MAX_IN // V) + cb, tuple(seg)))
    return cmds


def _write_wh_cmds(w: np.ndarray) -> List[Command]:
    O, H = w.shape
    cmds = []
    for r in range(O):
        for cb in range((H + V - 1) // V):
            seg = np.zeros((V,), np.float32)
            seg[: min(V, H - cb * V)] = w[r, cb * V : cb * V + min(V, H - cb * V)]
            cmds.append(Command(WRITE_WH, r * (MAX_H // V) + cb, tuple(seg)))
    return cmds


def _write_bias_cmds(b: np.ndarray) -> List[Command]:
    n = len(b)
    cmds = []
    for blk in range((n + V - 1) // V):
        seg = np.zeros((V,), np.float32)
        seg[: min(V, n - blk * V)] = b[blk * V : blk * V + min(V, n - blk * V)]
        cmds.append(Command(WRITE_B, blk, tuple(seg)))
    return cmds


def _exp_biases(*tensors):
    return [float(numerics.af_exp_bias(jnp.asarray(t), AF)) for t in tensors]


def _read_matrix(st, base: int, T: int, D: int) -> jnp.ndarray:
    rows = jax.lax.dynamic_slice(
        st["gb_large"], (base, 0), (MAX_TS * (MAX_IN // V), V)
    ).reshape(MAX_TS, MAX_IN)
    return rows[:T, :D]


BASE_IN = 0
BASE_OUT = MAX_TS * (MAX_IN // V)
BASE_AUX = 2 * MAX_TS * (MAX_IN // V)


def read_full(st) -> jnp.ndarray:
    """Fixed-shape output read (vmap-safe): the whole (MAX_TS, MAX_IN)
    output block; callers slice the valid [:T, :D] window host-side."""
    return _read_matrix(st, BASE_OUT, MAX_TS, MAX_IN)


def _setup_stream(weight_cmds: List[Command], cfg) -> PackedStream:
    return PackedStream.concat([PackedStream.from_commands(weight_cmds, V), _tail(cfg)])


# -- LinearLayer -------------------------------------------------------------


def linear_fragment(w, b, act: int = ACT_NONE, cache: bool = True) -> CompiledFragment:
    """Setup half of the LinearLayer mapping: weights + bias resident in PE
    memory, sizing/activation configured. Cached per parameter set."""
    w, b = np.asarray(w, np.float32), np.asarray(b, np.float32)
    O, I = w.shape
    assert I <= MAX_IN and O <= MAX_OUT and O <= MAX_IN

    key = ("fasr_linear", I, O, int(act), fingerprint(w, b))

    def build():
        (bw,) = _exp_biases(w)
        setup = _setup_stream(
            _write_weight_cmds(w) + _write_bias_cmds(b),
            [
                (PE_CFG_RNN_LAYER_SIZING, (I, O)),
                (PE_CFG_MNGR, (1.0,)),
                (PE_CFG_ACT_MNGR, (float(act),)),
                (GB_CFG_MMNGR, (BASE_IN, BASE_OUT, 0, 0)),
            ],
        )
        return CompiledFragment(
            flexasr, key, setup, meta={"w": w, "b": b, "bw": bw, "I": I, "O": O}
        )

    return FRAGMENTS.get(key, build) if cache else build()


def pack_linear_data(frag: CompiledFragment, x) -> DataStream:
    """Data half: activation rows + per-sample AF exponent windows + trigger.
    The driver sizes the output window from the ideal fp32 result, exactly
    as the one-shot builder did."""
    x = np.asarray(x, np.float32)
    T = x.shape[0]
    assert T <= MAX_TS and x.shape[1] == frag.meta["I"]
    (ba,) = _exp_biases(x)
    ideal = x @ frag.meta["w"].T + frag.meta["b"]
    (bo,) = _exp_biases(ideal)
    tail = _tail(
        [
            (GB_CFG_GB_CONTROL, (MODE_LINEAR, T)),
            (CFG_NUMERICS, (frag.meta["bw"], ba, bo)),
            (FN_START, ()),
        ]
    )
    return DataStream([_matrix_bulk(BASE_IN, x)], tail)


def build_linear_fragment(x, w, b, act: int = ACT_NONE):
    """nn.dense + bias_add -> FlexASR LinearLayer fragment (Figure 5)."""
    x = np.asarray(x, np.float32)
    T, O = x.shape[0], np.asarray(w).shape[0]
    frag = linear_fragment(w, b, act)
    cmds = frag.full_commands(pack_linear_data(frag, x))
    return cmds, lambda st: _read_matrix(st, BASE_OUT, T, O)


# -- LSTM --------------------------------------------------------------------


def lstm_fragment(wi, wh, b, cache: bool = True) -> CompiledFragment:
    wi, wh, b = (np.asarray(t, np.float32) for t in (wi, wh, b))
    I, H = wi.shape[1], wh.shape[1]
    assert I <= MAX_IN and 4 * H <= MAX_OUT and H <= MAX_H

    key = ("fasr_lstm", I, H, fingerprint(wi, wh, b))

    def build():
        (bw,) = _exp_biases(np.concatenate([wi.ravel(), wh.ravel()]))
        bo = 0.0 - (2 ** AF.n_exp - 1)  # h,c in (-1,1): top exponent 0
        # PE gate memory layout: gate g occupies rows [g*MAX_H, g*MAX_H + H)
        wi_p = np.zeros((4 * MAX_H, wi.shape[1]), np.float32)
        wh_p = np.zeros((4 * MAX_H, wh.shape[1]), np.float32)
        b_p = np.zeros((4 * MAX_H,), np.float32)
        for g in range(4):
            wi_p[g * MAX_H : g * MAX_H + H] = wi[g * H : (g + 1) * H]
            wh_p[g * MAX_H : g * MAX_H + H] = wh[g * H : (g + 1) * H]
            b_p[g * MAX_H : g * MAX_H + H] = b[g * H : (g + 1) * H]
        setup = _setup_stream(
            _write_weight_cmds(wi_p) + _write_wh_cmds(wh_p) + _write_bias_cmds(b_p),
            [
                (PE_CFG_RNN_LAYER_SIZING, (I, H)),
                (PE_CFG_MNGR, (1.0,)),
                (GB_CFG_MMNGR, (BASE_IN, BASE_OUT, 0, 0)),
            ],
        )
        return CompiledFragment(
            flexasr, key, setup,
            meta={"bw": bw, "bo": bo, "I": I, "H": H,
                  "wi_p": wi_p, "wh_p": wh_p, "b_p": b_p},
        )

    return FRAGMENTS.get(key, build) if cache else build()


def pack_lstm_data(frag: CompiledFragment, x) -> DataStream:
    x = np.asarray(x, np.float32)
    T = x.shape[0]
    assert T <= MAX_TS and x.shape[1] == frag.meta["I"]
    (ba,) = _exp_biases(x)
    tail = _tail(
        [
            (GB_CFG_GB_CONTROL, (MODE_LSTM, T)),
            (CFG_NUMERICS, (frag.meta["bw"], ba, frag.meta["bo"])),
            (FN_START, ()),
        ]
    )
    return DataStream([_matrix_bulk(BASE_IN, x)], tail)


def build_lstm_fragment(x, wi, wh, b):
    """Unrolled-LSTM IR fragment -> ONE FlexASR LSTM invocation (the
    paper's 566-ops-to-1-instruction granularity bridge)."""
    x = np.asarray(x, np.float32)
    T, H = x.shape[0], np.asarray(wh).shape[1]
    frag = lstm_fragment(wi, wh, b)
    cmds = frag.full_commands(pack_lstm_data(frag, x))
    return cmds, lambda st: _read_matrix(st, BASE_OUT, T, H)


# -- temporal pooling --------------------------------------------------------


def pool_fragment(D: int, kind: str = "max", cache: bool = True) -> CompiledFragment:
    assert D <= MAX_IN
    key = ("fasr_pool", D, kind)

    def build():
        setup = _tail(
            [
                (PE_CFG_RNN_LAYER_SIZING, (D, D)),
                (GB_CFG_MMNGR, (BASE_IN, BASE_OUT, 0, 0)),
            ]
        )
        mode = MODE_MAXPOOL if kind == "max" else MODE_MEANPOOL
        return CompiledFragment(flexasr, key, setup, meta={"mode": mode, "D": D})

    return FRAGMENTS.get(key, build) if cache else build()


def pack_pool_data(frag: CompiledFragment, x) -> DataStream:
    x = np.asarray(x, np.float32)
    T = x.shape[0]
    assert T <= MAX_TS and x.shape[1] == frag.meta["D"]
    (bo,) = _exp_biases(x)
    tail = _tail(
        [
            (GB_CFG_GB_CONTROL, (frag.meta["mode"], T)),
            (CFG_NUMERICS, (0.0, 0.0, bo)),
            (FN_START, ()),
        ]
    )
    return DataStream([_matrix_bulk(BASE_IN, x)], tail)


def build_pool_fragment(x, kind="max"):
    x = np.asarray(x, np.float32)
    T, D = x.shape
    frag = pool_fragment(D, kind)
    cmds = frag.full_commands(pack_pool_data(frag, x))
    return cmds, lambda st: _read_matrix(st, BASE_OUT, T // 2, D)


# -- layer norm --------------------------------------------------------------


def layernorm_fragment(gamma, beta, cache: bool = True) -> CompiledFragment:
    gamma, beta = np.asarray(gamma, np.float32), np.asarray(beta, np.float32)
    D = gamma.shape[0]
    assert D <= MAX_IN
    key = ("fasr_layernorm", D, fingerprint(gamma, beta))

    def build():
        setup = _setup_stream(
            _write_weight_cmds(gamma[None, :]) + _write_bias_cmds(beta),
            [
                (PE_CFG_RNN_LAYER_SIZING, (D, D)),
                (GB_CFG_MMNGR, (BASE_IN, BASE_OUT, 0, 0)),
            ],
        )
        return CompiledFragment(
            flexasr, key, setup, meta={"gamma": gamma, "beta": beta, "D": D}
        )

    return FRAGMENTS.get(key, build) if cache else build()


def pack_layernorm_data(frag: CompiledFragment, x) -> DataStream:
    x = np.asarray(x, np.float32)
    T = x.shape[0]
    assert T <= MAX_TS and x.shape[1] == frag.meta["D"]
    (ba,) = _exp_biases(x)
    # the driver sizes the output exponent window from the ideal result
    mu = x.mean(-1, keepdims=True)
    va = x.var(-1, keepdims=True)
    ideal = (x - mu) / np.sqrt(va + 1e-5) * frag.meta["gamma"] + frag.meta["beta"]
    (bo,) = _exp_biases(ideal)
    tail = _tail(
        [
            (GB_CFG_GB_CONTROL, (MODE_LAYERNORM, T)),
            (CFG_NUMERICS, (0.0, ba, bo)),
            (FN_START, ()),
        ]
    )
    return DataStream([_matrix_bulk(BASE_IN, x)], tail)


def build_layernorm_fragment(x, gamma, beta):
    x = np.asarray(x, np.float32)
    T, D = x.shape
    frag = layernorm_fragment(gamma, beta)
    cmds = frag.full_commands(pack_layernorm_data(frag, x))
    return cmds, lambda st: _read_matrix(st, BASE_OUT, T, D)


# -- attention ---------------------------------------------------------------


def attention_fragment(D: int, cache: bool = True) -> CompiledFragment:
    assert D <= MAX_IN
    key = ("fasr_attention", D)

    def build():
        setup = _tail([(PE_CFG_RNN_LAYER_SIZING, (D, D))])
        return CompiledFragment(flexasr, key, setup, meta={"D": D})

    return FRAGMENTS.get(key, build) if cache else build()


def pack_attention_data(frag: CompiledFragment, q, k, v) -> DataStream:
    q, k, v = (np.asarray(t, np.float32) for t in (q, k, v))
    Tq, D = q.shape
    Tk = k.shape[0]
    assert Tq <= MAX_TS and Tk <= MAX_TS and D == frag.meta["D"]
    (ba,) = _exp_biases(np.concatenate([q.ravel(), k.ravel(), v.ravel()]))
    s = (q @ k.T) / np.sqrt(q.shape[1])
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    (bo,) = _exp_biases(p @ v)
    tail = _tail(
        [
            (GB_CFG_MMNGR, (BASE_IN, BASE_OUT, BASE_AUX, Tk)),
            (GB_CFG_GB_CONTROL, (MODE_ATTENTION, Tq)),
            (CFG_NUMERICS, (0.0, ba, bo)),
            (FN_START, ()),
        ]
    )
    return DataStream(
        [
            _matrix_bulk(BASE_IN, q),
            _matrix_bulk(BASE_AUX, k),
            _matrix_bulk(BASE_AUX + MAX_TS * (MAX_IN // V), v),
        ],
        tail,
    )


def build_attention_fragment(q, k, v):
    q = np.asarray(q, np.float32)
    Tq, D = q.shape
    frag = attention_fragment(D)
    cmds = frag.full_commands(pack_attention_data(frag, q, k, v))
    return cmds, lambda st: _read_matrix(st, BASE_OUT, Tq, D)


# --------------------------------------------------------------------------
# IR -> intrinsic rewrites (instruction selection; guards = device capacity)
# --------------------------------------------------------------------------


def _linear_guard(eg, cid, s):
    b = shape_of(eg, s["b"])
    return len(shape_of(eg, s["c"])) == 1 and b[1] <= MAX_IN and b[0] <= MAX_IN


def _lstm_guard(eg, cid, s):
    wi = shape_of(eg, s["wi"])
    wh = shape_of(eg, s["wh"])
    return wi[1] <= MAX_IN and wh[1] <= MAX_H


def _attn_guard(eg, cid, s):
    q = shape_of(eg, s["q"])
    k = shape_of(eg, s["k"])
    # KV length is not driver-chunkable, hence the MAX_TS guard
    return q[-1] <= MAX_IN and q[-2] <= MAX_TS and k[-2] <= MAX_TS


def _rewrites():
    return [
        Rewrite(
            "fasr-linear",
            P("bias_add", P("dense", PV("a"), PV("b")), PV("c")),
            P("fasr_linear", PV("a"), PV("b"), PV("c")),
            guard=_linear_guard,
        ),
        Rewrite(
            "fasr-lstm",
            P("lstm", PV("x"), PV("wi"), PV("wh"), PV("b")),
            P("fasr_lstm", PV("x"), PV("wi"), PV("wh"), PV("b")),
            guard=_lstm_guard,
        ),
        Rewrite(
            "fasr-attention",
            P("attention", PV("q"), PV("k"), PV("v")),
            P("fasr_attention", PV("q"), PV("k"), PV("v")),
            guard=_attn_guard,
        ),
        Rewrite(
            "fasr-layernorm",
            P("layer_norm", PV("x"), PV("g"), PV("b"), attr_binds=("eps",)),
            P("fasr_layernorm", PV("x"), PV("g"), PV("b"), attr_binds=("eps",)),
            guard=lambda eg, cid, s: shape_of(eg, s["x"])[-1] <= MAX_IN,
        ),
        Rewrite(
            "fasr-maxpool",
            P(
                "reduce_max",
                P("windows", PV("T"), attrs=(("wh", 2), ("ww", 1), ("sh", 2), ("sw", 1))),
                attrs=(("axis", (2, 3)),),
            ),
            # no width guard: pooling is elementwise across features, so the
            # driver chunks wide matrices column-wise (plan_pool)
            P("fasr_load", P("fasr_maxpool", P("fasr_store", PV("T")))),
        ),
        Rewrite(
            "fasr-meanpool",
            P(
                "reduce_mean",
                P("windows", PV("T"), attrs=(("wh", 2), ("ww", 1), ("sh", 2), ("sw", 1))),
                attrs=(("axis", (2, 3)),),
            ),
            P("fasr_load", P("fasr_meanpool", P("fasr_store", PV("T")))),
        ),
        # Section 5.1: cancel redundant accelerator<->host round trips
        Rewrite(
            "fasr-store-load-cancel",
            P("fasr_store", P("fasr_load", PV("x"))),
            PV("x"),
        ),
    ]


# --------------------------------------------------------------------------
# Intrinsic planners (op -> SimJobs; driver chunking lives here)
#
# Planners are the *pack* stage of the pipelined Executor: they run in a
# pack worker thread and must stay pure numpy (GIL-releasing, no JAX
# dispatch). The fp32 references recorded for the rel-err stats are
# therefore computed with numpy mirrors of the IR oracle — diagnostics
# only, never fed into the simulated numerics.
# --------------------------------------------------------------------------


def _np_sigmoid(x: np.ndarray) -> np.ndarray:
    return 1.0 / (1.0 + np.exp(-x))


def _ideal_lstm(xs: np.ndarray, wi: np.ndarray, wh: np.ndarray, b: np.ndarray) -> np.ndarray:
    """numpy mirror of ``ir._lstm`` (fused i,f,g,o gates) for plan-time
    stats: ~1000x cheaper than per-sample eager-JAX dispatch on the pack
    worker's hot path."""
    T, B, _ = xs.shape
    H = wh.shape[1]
    h = np.zeros((B, H), np.float32)
    c = np.zeros((B, H), np.float32)
    outs = np.empty((T, B, H), np.float32)
    for t in range(T):
        gates = xs[t] @ wi.T + h @ wh.T + b
        i = _np_sigmoid(gates[:, 0 * H : 1 * H])
        f = _np_sigmoid(gates[:, 1 * H : 2 * H])
        g = np.tanh(gates[:, 2 * H : 3 * H])
        o = _np_sigmoid(gates[:, 3 * H : 4 * H])
        c = f * c + i * g
        h = o * np.tanh(c)
        outs[t] = h
    return outs


def _ideal_attention(q: np.ndarray, k: np.ndarray, v: np.ndarray) -> np.ndarray:
    """numpy mirror of ``ir._attention`` for plan-time stats."""
    s = (q @ np.swapaxes(k, -1, -2)) / np.sqrt(np.float32(q.shape[-1]))
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return p @ v


def kernel_linear(ctx, x, args):
    """Deployment fast path: the af_gemm Pallas kernel (same AF lattice)."""
    from ..kernels import ops as kops

    a, w, b = args
    orig_shape = a.shape
    a2 = a.reshape(-1, a.shape[-1])
    ideal_full = a2 @ w.T + b
    out = np.asarray(kops.af_linear(jnp.asarray(a2), jnp.asarray(w), jnp.asarray(b)))
    ctx.record("fasr_linear", "flexasr-kernel", out, ideal_full, 0)
    return out.reshape(orig_shape[:-1] + (w.shape[0],))


def plan_linear(ctx, x, args):
    a, w, b = args
    orig_shape = a.shape
    a2 = a.reshape(-1, a.shape[-1])
    O = w.shape[0]
    ideal_full = a2 @ w.T + b
    frag = linear_fragment(w, b)
    jobs = [
        SimJob(frag, pack_linear_data(frag, chunk), read_full,
               (slice(0, chunk.shape[0]), slice(0, O)))
        for chunk in ctx.chunk_rows(a2, MAX_TS)
    ]

    def assemble(outs):
        out = np.concatenate(outs, axis=0)
        ctx.record("fasr_linear", "flexasr", out, ideal_full, ctx.ncmds(jobs))
        return out.reshape(orig_shape[:-1] + (O,))

    return jobs, assemble


def plan_lstm(ctx, x, args):
    xs, wi, wh, b = args
    T, B, I = xs.shape
    H = wh.shape[1]
    ideal = _ideal_lstm(xs, wi, wh, b)
    frag = lstm_fragment(wi, wh, b)
    jobs = [
        SimJob(frag, pack_lstm_data(frag, xs[:, bi]), read_full,
               (slice(0, T), slice(0, H)))
        for bi in range(B)
    ]

    def assemble(outs):
        out = np.stack(outs, axis=1)
        ctx.record("fasr_lstm", "flexasr", out, ideal, ctx.ncmds(jobs))
        return out

    return jobs, assemble


def plan_pool(ctx, x, args, kind):
    (a,) = args
    T = a.shape[0]
    pairs = a[: T - T % 2].reshape(T // 2, 2, *a.shape[1:])
    ideal = pairs.max(1) if kind == "max" else pairs.mean(1)
    jobs, layout = [], []
    for chunk in ctx.chunk_rows(a, MAX_TS):
        # pooling is elementwise across features: chunk wide matrices
        # column-wise to fit the device's MAX_IN lanes
        cols = []
        for c0 in range(0, chunk.shape[1], MAX_IN):
            piece = chunk[:, c0 : c0 + MAX_IN]
            frag = pool_fragment(piece.shape[1], kind)
            jobs.append(
                SimJob(frag, pack_pool_data(frag, piece), read_full,
                       (slice(0, piece.shape[0] // 2), slice(0, piece.shape[1])))
            )
            cols.append(len(jobs) - 1)
        layout.append(cols)

    def assemble(outs):
        rows = [np.concatenate([outs[i] for i in cols], axis=1) for cols in layout]
        out = np.concatenate(rows, axis=0)
        ctx.record(f"fasr_{kind}pool", "flexasr", out, ideal, ctx.ncmds(jobs))
        return out

    return jobs, assemble


def plan_layernorm(ctx, x, args):
    a, g, b = args
    orig = a.shape
    a2 = a.reshape(-1, a.shape[-1])
    mu = a2.mean(-1, keepdims=True)
    va = a2.var(-1, keepdims=True)
    ideal = (a2 - mu) / np.sqrt(va + 1e-5) * g + b
    frag = layernorm_fragment(g, b)
    D = a2.shape[1]
    jobs = [
        SimJob(frag, pack_layernorm_data(frag, chunk), read_full,
               (slice(0, chunk.shape[0]), slice(0, D)))
        for chunk in ctx.chunk_rows(a2, MAX_TS)
    ]

    def assemble(outs):
        out = np.concatenate(outs, axis=0).reshape(orig)
        ctx.record("fasr_layernorm", "flexasr", out, ideal, ctx.ncmds(jobs))
        return out

    return jobs, assemble


def plan_attention(ctx, x, args):
    q, k, v = args
    ideal = _ideal_attention(q, k, v)
    D = q.shape[-1]
    frag = attention_fragment(D)
    if q.ndim == 2:
        jobs = [
            SimJob(frag, pack_attention_data(frag, q, k, v), read_full,
                   (slice(0, q.shape[0]), slice(0, v.shape[-1])))
        ]

        def assemble(outs):
            ctx.record("fasr_attention", "flexasr", outs[0], ideal, ctx.ncmds(jobs))
            return outs[0]

        return jobs, assemble
    # batch of heads: one invocation per (batch) slice, batched in sim
    q2 = q.reshape(-1, q.shape[-2], q.shape[-1])
    k2 = k.reshape(-1, k.shape[-2], k.shape[-1])
    v2 = v.reshape(-1, v.shape[-2], v.shape[-1])
    jobs = [
        SimJob(frag, pack_attention_data(frag, q2[i], k2[i], v2[i]), read_full,
               (slice(0, q2.shape[1]), slice(0, v2.shape[2])))
        for i in range(q2.shape[0])
    ]

    def assemble(outs):
        out = np.stack(outs).reshape(q.shape[:-1] + (v.shape[-1],))
        ctx.record("fasr_attention", "flexasr", out, ideal, ctx.ncmds(jobs))
        return out

    return jobs, assemble


# --------------------------------------------------------------------------
# Cost model: analytic commands / bytes / cycles from operand shapes
# --------------------------------------------------------------------------
#
# Commands mirror the fragment builders above (setup weight load + per-row
# data stream over V lanes, config/trigger tails per MAX_TS chunk); compute
# cycles assume the PE array retires V MACs per cycle. CostModel.calibrate
# trims the command predictions against what the planners actually emit.

COSTS = CostModel("flexasr", cycles_per_command=1.0)


def _cdiv(a: int, b: int) -> int:
    return -(-int(a) // int(b))


def _nrows(shape) -> int:
    return int(np.prod(shape[:-1])) if len(shape) > 1 else 1


@COSTS.op("fasr_linear")
def _cost_linear(attrs, shapes):
    a, w = shapes[0], shapes[1]
    rows, I, O = _nrows(a), a[-1], w[0]
    setup = O * _cdiv(I, V) + _cdiv(O, V) + 4
    data = rows * _cdiv(I, V) + 5 * _cdiv(rows, MAX_TS)
    return setup + data, 4 * (rows * I + O * I + O + rows * O), rows * O * I / V


@COSTS.op("fasr_lstm")
def _cost_lstm(attrs, shapes):
    (T, B, I), wi, wh = shapes[0], shapes[1], shapes[2]
    gates, H = wi[0], wh[1]
    setup = gates * _cdiv(I, V) + gates * _cdiv(H, V) + _cdiv(gates, V) + 4
    data = B * (T * _cdiv(I, V) + 5)
    moved = 4 * (T * B * I + gates * (I + H + 1) + T * B * H)
    return setup + data, moved, T * B * gates * (I + H) / V


def _cost_pool(attrs, shapes):
    T = shapes[0][0]
    D = int(np.prod(shapes[0][1:])) if len(shapes[0]) > 1 else 1
    chunks = _cdiv(T, MAX_TS) * _cdiv(D, MAX_IN)
    return T * _cdiv(D, V) + 5 * chunks, 4 * T * D * 3 // 2, T * D / V


COSTS.op("fasr_maxpool")(_cost_pool)
COSTS.op("fasr_meanpool")(_cost_pool)


@COSTS.op("fasr_layernorm")
def _cost_layernorm(attrs, shapes):
    rows, D = _nrows(shapes[0]), shapes[0][-1]
    setup = 2 * _cdiv(D, V) + 4
    data = rows * _cdiv(D, V) + 5 * _cdiv(rows, MAX_TS)
    return setup + data, 4 * (2 * rows * D + 2 * D), 3 * rows * D / V


@COSTS.op("fasr_attention")
def _cost_attention(attrs, shapes):
    q, k, v = shapes
    heads = int(np.prod(q[:-2])) if len(q) > 2 else 1
    Tq, D, Tk = q[-2], q[-1], k[-2]
    cmds = heads * ((Tq + 2 * Tk) * _cdiv(D, V) + 6)
    moved = 4 * heads * (Tq * D + 2 * Tk * D + Tq * v[-1])
    return cmds, moved, heads * Tq * Tk * (2 * D + 1) / V


def _cost_transfer(attrs, shapes):
    n = int(np.prod(shapes[0])) if shapes and shapes[0] else 1
    # pure data-movement marker: no interface commands of its own; one
    # V-word per cycle across the interface
    return 0, 4 * n, max(1.0, n / V)


COSTS.op("fasr_store")(_cost_transfer)
COSTS.op("fasr_load")(_cost_transfer)


# --------------------------------------------------------------------------
# Validation declarations (conformance samples, VT2 cases, VT3, Table 2)
# --------------------------------------------------------------------------


def _sample_linear(r):
    T, I, O = int(r.integers(1, 12)), int(r.integers(1, 33)), int(r.integers(1, 25))
    return [
        r.standard_normal((T, I)).astype(np.float32),
        (r.standard_normal((O, I)) * 0.1).astype(np.float32),
        (r.standard_normal((O,)) * 0.1).astype(np.float32),
    ], {}


def _sample_lstm(r):
    T, I, H = int(r.integers(2, 7)), int(r.integers(1, 17)), int(r.integers(1, 9))
    return [
        (r.standard_normal((T, 1, I)) * 0.5).astype(np.float32),
        (r.standard_normal((4 * H, I)) * 0.2).astype(np.float32),
        (r.standard_normal((4 * H, H)) * 0.2).astype(np.float32),
        (r.standard_normal((4 * H,)) * 0.1).astype(np.float32),
    ], {}


def _sample_pool(r):
    T, D = 2 * int(r.integers(1, 9)), int(r.integers(1, 49))
    return [r.standard_normal((T, D)).astype(np.float32)], {}


def _sample_layernorm(r):
    T, D = int(r.integers(1, 9)), int(r.integers(2, 49))
    return [
        r.standard_normal((T, D)).astype(np.float32),
        r.standard_normal((D,)).astype(np.float32),
        (r.standard_normal((D,)) * 0.1).astype(np.float32),
    ], {"eps": 1e-5}


def _sample_attention(r):
    Tq, Tk, D = int(r.integers(1, 9)), int(r.integers(1, 13)), int(r.integers(2, 33))
    return [
        r.standard_normal((Tq, D)).astype(np.float32),
        r.standard_normal((Tk, D)).astype(np.float32),
        r.standard_normal((Tk, D)).astype(np.float32),
    ], {}


def _vt2(dim_t, dim_d):
    a = ir.Var("a", (dim_t, dim_d))
    w = ir.Var("w", (dim_d, dim_d))
    c = ir.Var("c", (dim_d,))
    T = ir.Var("T", (dim_t, dim_d))
    g = ir.Var("g", (dim_d,))
    be = ir.Var("be", (dim_d,))
    return [
        VT2Case(
            "linear",
            ir.bias_add(ir.dense(a, w), c),
            ir.call("fasr_linear", a, w, c),
            {"a": (dim_t, dim_d), "w": (dim_d, dim_d), "c": (dim_d,)},
        ),
        VT2Case(
            "maxpool",
            ir.call("reduce_max", ir.call("windows", T, wh=2, ww=1, sh=2, sw=1), axis=(2, 3)),
            ir.call("fasr_load", ir.call("fasr_maxpool", ir.call("fasr_store", T))),
            {"T": (dim_t, dim_d)},
        ),
        VT2Case(
            "layernorm",
            ir.call("layer_norm", a, g, be, eps=1e-5),
            ir.call("fasr_layernorm", a, g, be, eps=1e-5),
            {"a": (dim_t, dim_d), "g": (dim_d,), "be": (dim_d,)},
        ),
    ]


def _vt3_linear(n: int = 3, seed: int = 0):
    """FlexASR ILA LinearLayer vs the af_gemm Pallas kernel: both project
    onto the same AdaptivFloat lattice, so they must agree bit-for-bit."""
    from ..kernels import ops as kops

    rng = np.random.default_rng(seed)
    worst = 0.0
    for _ in range(n):
        x = rng.standard_normal((16, 64)).astype(np.float32)
        w = (rng.standard_normal((32, 64)) * 0.1).astype(np.float32)
        b = (rng.standard_normal((32,)) * 0.1).astype(np.float32)
        cmds, rd = build_linear_fragment(x, w, b)
        ila_out = np.asarray(rd(flexasr.simulate(cmds)))
        kern_out = np.asarray(kops.af_linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)))
        worst = max(worst, float(np.abs(ila_out - kern_out).max()))
    return worst <= 1e-6, worst


def _mapping_cases(rng):
    """Table 2 rows: (operation, case_fn) with case_fn() -> (ref, simulated)."""

    def linear_case():
        x = rng.standard_normal((16, 64)).astype(np.float32)
        w = (rng.standard_normal((64, 64)) * 0.1).astype(np.float32)
        b = (rng.standard_normal((64,)) * 0.1).astype(np.float32)
        cmds, rd = build_linear_fragment(x, w, b)
        return x @ w.T + b, rd(flexasr.simulate(cmds))

    def lstm_case():
        x = (rng.standard_normal((16, 32)) * 0.5).astype(np.float32)
        wi = (rng.standard_normal((64, 32)) * 0.3).astype(np.float32)
        wh = (rng.standard_normal((64, 16)) * 0.3).astype(np.float32)
        b = (rng.standard_normal((64,)) * 0.1).astype(np.float32)
        cmds, rd = build_lstm_fragment(x, wi, wh, b)
        ref = ir._lstm(jnp.asarray(x[:, None]), jnp.asarray(wi), jnp.asarray(wh),
                       jnp.asarray(b))[:, 0]
        return ref, rd(flexasr.simulate(cmds))

    def ln_case():
        x = rng.standard_normal((16, 64)).astype(np.float32)
        g = rng.standard_normal((64,)).astype(np.float32)
        be = (rng.standard_normal((64,)) * 0.1).astype(np.float32)
        cmds, rd = build_layernorm_fragment(x, g, be)
        mu = x.mean(-1, keepdims=True)
        va = x.var(-1, keepdims=True)
        return (x - mu) / np.sqrt(va + 1e-5) * g + be, rd(flexasr.simulate(cmds))

    def maxpool_case():
        # device-representable inputs (written into the AF8 buffer), as the
        # paper's 0.00% row implies
        x = np.asarray(numerics.af_quantize(
            jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32)), AF))
        cmds, rd = build_pool_fragment(x, "max")
        return x.reshape(8, 2, 64).max(1), rd(flexasr.simulate(cmds))

    def meanpool_case():
        x = rng.standard_normal((16, 64)).astype(np.float32)
        cmds, rd = build_pool_fragment(x, "mean")
        return x.reshape(8, 2, 64).mean(1), rd(flexasr.simulate(cmds))

    def attn_case():
        q = rng.standard_normal((8, 64)).astype(np.float32)
        k = rng.standard_normal((16, 64)).astype(np.float32)
        v = rng.standard_normal((16, 64)).astype(np.float32)
        cmds, rd = build_attention_fragment(q, k, v)
        ref = ir._attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
        return ref, rd(flexasr.simulate(cmds))

    return [
        ("LinearLayer", linear_case),
        ("LSTM", lstm_case),
        ("LayerNorm", ln_case),
        ("MaxPool", maxpool_case),
        ("MeanPool", meanpool_case),
        ("Attention", attn_case),
    ]


# --------------------------------------------------------------------------
# Fused fast-path runners (engine="fused")
#
# The compiled tier replays every data stream against the architectural
# state: bulk dynamic_update_slice + scanned config tail + FN_START + gb
# readout, per sample. For the two hot shapes (LinearLayer, LSTM) all of
# that machinery computes a pure function of (activations, exponent
# windows) with weights frozen at fragment-build time — so a FusedRunner
# stacks the whole batch into dense arrays host-side and runs one fused
# batched kernel. The compiled tier stays the oracle: the XLA lowering
# replicates _fn_linear / _fn_lstm arithmetic step for step (bit-exact for
# linear; the LSTM hoists the input projection out of the scan, which
# reassociates fp32 sums, so it is tolerance-parity), and the Pallas
# lowering routes the linear shape through kernels/af_gemm.py. The LSTM
# recurrence has no output re-quantization at the gates, so its hoisted
# projection stays a plain matmul under either lowering (XLA/MXU fuse it
# natively) and the runner is always tagged "xla".
# --------------------------------------------------------------------------


def _fused_stack(datas: List[DataStream]):
    """Prepare half (pure numpy, pack-worker safe): stack linear/LSTM data
    streams into dense batch arrays — the (B, MAX_TS, MAX_IN) activation
    block exactly as the bulk writes land it in gb_large, plus per-sample
    ``num_ts`` and the CFG_NUMERICS act/out exponent windows from the tail."""
    datas = fused_pad_streams(datas)
    B = len(datas)
    xs = np.zeros((B, MAX_TS, MAX_IN), np.float32)
    num_ts = np.zeros((B,), np.float32)
    ba = np.zeros((B,), np.float32)
    bo = np.zeros((B,), np.float32)
    for i, d in enumerate(datas):
        (blk,) = d.bulk
        assert blk.buf == "gb_large" and blk.base == BASE_IN
        assert int(d.tail.ops[1]) == CFG_NUMERICS
        rows = np.asarray(blk.rows, np.float32)
        xs[i].reshape(MAX_TS * (MAX_IN // V), V)[: rows.shape[0]] = rows
        num_ts[i] = d.tail.data[0, 1]
        ba[i] = d.tail.data[1, 1]
        bo[i] = d.tail.data[1, 2]
    return xs, num_ts, ba, bo


def _fused_dispatch(per_sample):
    """Dispatch half: vmap the per-sample kernel over the batch axis, with
    the batch sharded across the stream mesh (same axis run_data_batch
    shards)."""
    vf = jax.jit(jax.vmap(per_sample))

    def dispatch(prepared):
        xs, num_ts, ba, bo = (_shard_batched(a) for a in prepared)
        return vf(xs, num_ts, ba, bo)

    return dispatch


def _fused_linear(frag: CompiledFragment) -> FusedRunner:
    meta, act = frag.meta, int(frag.key[3])
    I, O, bw = meta["I"], meta["O"], meta["bw"]
    # pe_w / pe_b exactly as the setup stream leaves them (zero padding)
    wp = np.zeros((MAX_OUT, MAX_IN), np.float32)
    wp[:O, :I] = meta["w"]
    bp = np.zeros((MAX_OUT,), np.float32)
    bp[:O] = meta["b"]
    m_in = (np.arange(MAX_IN) < I).astype(np.float32)
    m_out = (np.arange(MAX_OUT) < O).astype(np.float32)
    lowering = fused_lowering()

    if lowering == "pallas" and act == ACT_NONE:
        from ..kernels import ops as kops
        from ..kernels.af_gemm import af_gemm

        wp_j, bp_j, m_out_j = jnp.asarray(wp), jnp.asarray(bp), jnp.asarray(m_out)

        def one(x, n_ts, ba, bo):
            # activation rows/cols beyond (T, I) are zero, and AFq(0) == 0,
            # so the input masks are implicit; Y's bias rows past T are
            # cleared by the post-mask, exactly as _fn_linear's m_ts does
            y = af_gemm(x, wp_j, bp_j, ba, bw, bo, spec=AF,
                        interpret=kops.INTERPRET)
            m_ts = _mask1(n_ts, MAX_TS)
            return (y * m_ts[:, None] * m_out_j[None, :])[:, :MAX_IN]
    else:
        lowering = "xla"
        m_in_j, m_out_j = jnp.asarray(m_in), jnp.asarray(m_out)
        Wq = _afq(jnp.asarray(wp), bw) * m_out_j[:, None] * m_in_j[None, :]
        bvec = jnp.asarray(bp * m_out)
        act_fn = [
            lambda v: v,
            lambda v: jnp.maximum(v, 0.0),
            lambda v: 1.0 / (1.0 + jnp.exp(-v)),
            lambda v: jnp.tanh(v),
        ][act]

        def one(x, n_ts, ba, bo):
            m_ts = _mask1(n_ts, MAX_TS)
            Xq = _afq(x, ba) * m_ts[:, None] * m_in_j[None, :]
            Y = act_fn(Xq @ Wq.T + bvec[None, :])
            Y = _afq(Y, bo) * m_ts[:, None] * m_out_j[None, :]
            return Y[:, :MAX_IN]

    return FusedRunner(f"flexasr-linear-{lowering}", _fused_stack,
                       _fused_dispatch(one), read=read_full, lowering=lowering)


def _fused_lstm(frag: CompiledFragment) -> FusedRunner:
    meta = frag.meta
    I, H, bw = meta["I"], meta["H"], meta["bw"]
    wip = np.zeros((MAX_OUT, MAX_IN), np.float32)
    wip[:, :I] = meta["wi_p"]
    whp = np.zeros((MAX_OUT, MAX_H), np.float32)
    whp[:, :H] = meta["wh_p"]
    bvec = jnp.asarray(meta["b_p"])
    m_in = jnp.asarray((np.arange(MAX_IN) < I).astype(np.float32))
    m_h = jnp.asarray((np.arange(MAX_H) < H).astype(np.float32))
    Wi = _afq(jnp.asarray(wip), bw) * m_in[None, :]
    Wh = _afq(jnp.asarray(whp), bw) * m_h[None, :]

    def one(x, n_ts, ba, bo):
        Xq = _afq(x, ba) * m_in[None, :]
        Gx = Xq @ Wi.T  # (MAX_TS, 4H) input projection hoisted off the scan

        def cell(carry, gx_t):
            h, c = carry
            gates = gx_t + Wh @ h + bvec
            i = jax.nn.sigmoid(gates[0 * MAX_H : 1 * MAX_H])
            f = jax.nn.sigmoid(gates[1 * MAX_H : 2 * MAX_H])
            g = jnp.tanh(gates[2 * MAX_H : 3 * MAX_H])
            o = jax.nn.sigmoid(gates[3 * MAX_H : 4 * MAX_H])
            c2 = _afq(f * c + i * g, bo) * m_h
            h2 = _afq(o * jnp.tanh(c2), bo) * m_h
            return (h2, c2), h2

        zero = jnp.zeros((MAX_H,), jnp.float32)
        _, hs = jax.lax.scan(cell, (zero, zero), Gx)
        hs = hs * _mask1(n_ts, MAX_TS)[:, None]
        return jnp.zeros((MAX_TS, MAX_IN), jnp.float32).at[:, :MAX_H].set(hs)

    return FusedRunner("flexasr-lstm-xla", _fused_stack, _fused_dispatch(one),
                       read=read_full, lowering="xla")


def _fused_factory(frag: CompiledFragment):
    """``declare_fused`` hook: runners for the hot data-stream shapes."""
    if frag.key[0] == "fasr_linear":
        return _fused_linear(frag)
    if frag.key[0] == "fasr_lstm":
        return _fused_lstm(frag)
    return None


# --------------------------------------------------------------------------
# Registration: everything the core needs, through the public API
# --------------------------------------------------------------------------

TARGET.add_intrinsic(Intrinsic(
    "fasr_linear", planner=plan_linear, kernel=kernel_linear,
    sample=_sample_linear, tol=0.08,
    doc="bias_add(dense(x,w),b) -> FlexASR LinearLayer"))
TARGET.add_intrinsic(Intrinsic(
    "fasr_lstm", planner=plan_lstm, sample=_sample_lstm, tol=0.20,
    doc="unrolled LSTM -> one FlexASR LSTM instruction"))
TARGET.add_intrinsic(Intrinsic(
    "fasr_maxpool", planner=lambda ctx, x, a: plan_pool(ctx, x, a, "max"),
    sample=_sample_pool, tol=0.05, doc="temporal max pooling"))
TARGET.add_intrinsic(Intrinsic(
    "fasr_meanpool", planner=lambda ctx, x, a: plan_pool(ctx, x, a, "mean"),
    sample=_sample_pool, tol=0.05, doc="temporal mean pooling"))
TARGET.add_intrinsic(Intrinsic(
    "fasr_layernorm", planner=plan_layernorm, sample=_sample_layernorm,
    tol=0.10, doc="layer normalization"))
TARGET.add_intrinsic(Intrinsic(
    "fasr_attention", planner=plan_attention, sample=_sample_attention,
    tol=0.15, doc="scaled dot-product attention"))
TARGET.add_intrinsic(Intrinsic(
    "fasr_store", passthrough=True, doc="HBM -> accelerator transfer marker"))
TARGET.add_intrinsic(Intrinsic(
    "fasr_load", passthrough=True, doc="accelerator -> HBM transfer marker"))
TARGET.declare_fused(_fused_factory)
TARGET.add_rewrites(_rewrites)
TARGET.add_cost_model(COSTS)
TARGET.add_vt2_cases(_vt2)
TARGET.add_vt3_check("linear_ila_vs_af_gemm_kernel", _vt3_linear)
TARGET.add_mapping_cases(_mapping_cases)
register_target(TARGET)
