"""HLSCNN accelerator ILA (Whatmough et al., VLSI'19) — JAX model.

HLSCNN is a coarse-grained 2D-convolution accelerator operating on 8/16-bit
**fixed point** data in NHWC layout. Its single supported operation in the
paper's prototype is a non-grouped conv2d; padding is done on the host before
invocation (Appendix A).

The paper's key application-level finding (Table 4) lives here: the original
design quantized conv *weights* to 8-bit fixed point, collapsing ResNet-20
accuracy 91.55% -> 29.15%; the developers' update widened weights to 16 bits,
recovering 91.85%. The ILA exposes the weight datatype as a configuration so
the co-simulation can reproduce both designs.

Architectural state:

  act_mem   (ACT_WORDS, V)  activation SRAM (fixed-point values)
  wgt_mem   (WGT_WORDS, V)  weight SRAM
  out_mem   (OUT_WORDS, V)  output SRAM
  + conv geometry registers + datatype select

Instructions: WR_ACT / WR_WGT (one V-lane word per command), CFG_CONV
(geometry), CFG_DTYPE (weight width 8/16), CONV_START.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..core import ir
from ..core.egraph import P, V as PV, Rewrite, shape_of
from ..core.ila import (
    ILA, BulkWrite, Command, CompiledFragment, DataStream, FusedRunner,
    PackedStream, _shard_batched, fingerprint, fused_lowering,
    fused_pad_streams,
)
from . import numerics
from .target import (
    AcceleratorTarget, CostModel, Intrinsic, SimJob, VT2Case, register_target,
)

V = 16
ACT_WORDS = 8192
WGT_WORDS = 8192
OUT_WORDS = 8192

MAX_H = 16
MAX_W = 16
MAX_C = 32
MAX_K = 32
MAX_KH = 5
MAX_KW = 5

WR_ACT = 0x10
WR_WGT = 0x11
CFG_CONV = 0x20
CFG_DTYPE = 0x21
CONV_START = 0x30

hlscnn = ILA("hlscnn", vwidth=V)

TARGET = AcceleratorTarget(
    "hlscnn",
    hlscnn,
    display_name="HLSCNN",
    capabilities={
        "max_hw": MAX_H, "max_c": MAX_C, "max_k": MAX_K, "max_khw": MAX_KH,
        "numerics": "fixed8/16",
    },
    doc="coarse-grained conv2d accelerator in 8/16-bit fixed point",
    # both VT2 sides lower to the same lax conv in fp32
    vt2_tol=1e-6,
)
FRAGMENTS = TARGET.fragments
# 16-bit fixed / 8 fraction bits saturates at +/-128; conv activations of
# the bundled apps stay within +/-32, so wrap is statically unreachable
TARGET.declare_lint(input_range=(-32.0, 32.0))

hlscnn.state("act_mem", lambda: jnp.zeros((ACT_WORDS, V), jnp.float32))
hlscnn.state("wgt_mem", lambda: jnp.zeros((WGT_WORDS, V), jnp.float32))
hlscnn.state("out_mem", lambda: jnp.zeros((OUT_WORDS, V), jnp.float32))
for reg in ("in_h", "in_w", "in_c", "out_k", "k_h", "k_w", "s_h", "s_w", "wgt_bits"):
    hlscnn.state(reg, (lambda: jnp.zeros((), jnp.float32)))


def _wr(buf_name):
    def update(st, addr, data):
        st = dict(st)
        st[buf_name] = jax.lax.dynamic_update_slice(st[buf_name], data[None, :], (addr, 0))
        return st

    return update


hlscnn.instruction("wr_act", WR_ACT)(_wr("act_mem"))
hlscnn.instruction("wr_wgt", WR_WGT)(_wr("wgt_mem"))


def _cfg(names):
    def update(st, addr, data):
        st = dict(st)
        for i, n in enumerate(names):
            st[n] = data[i]
        return st

    return update


hlscnn.instruction("cfg_conv", CFG_CONV)(
    _cfg(["in_h", "in_w", "in_c", "out_k", "k_h", "k_w", "s_h", "s_w"])
)
hlscnn.instruction("cfg_dtype", CFG_DTYPE)(_cfg(["wgt_bits"]))


ACT_SPEC = numerics.HLSCNN_ACT
W8 = numerics.HLSCNN_WEIGHT_ORIGINAL
W16 = numerics.HLSCNN_WEIGHT_UPDATED


@hlscnn.instruction("conv_start", CONV_START, "run the configured fixed-point conv2d")
def _conv_start(st, addr, data):
    # unpack SRAMs into dense max-size tensors (masked by config regs)
    act = st["act_mem"].reshape(-1)[: MAX_H * MAX_W * MAX_C].reshape(1, MAX_H, MAX_W, MAX_C)
    wgt = st["wgt_mem"].reshape(-1)[: MAX_KH * MAX_KW * MAX_C * MAX_K].reshape(
        MAX_KH, MAX_KW, MAX_C, MAX_K
    )
    mh = (jnp.arange(MAX_H) < st["in_h"]).astype(jnp.float32)
    mw = (jnp.arange(MAX_W) < st["in_w"]).astype(jnp.float32)
    mc = (jnp.arange(MAX_C) < st["in_c"]).astype(jnp.float32)
    mk = (jnp.arange(MAX_K) < st["out_k"]).astype(jnp.float32)
    mkh = (jnp.arange(MAX_KH) < st["k_h"]).astype(jnp.float32)
    mkw = (jnp.arange(MAX_KW) < st["k_w"]).astype(jnp.float32)

    # quantize: activations 16-bit fixed; weights 8 or 16 per CFG_DTYPE
    act_q = numerics.fx_quantize(act, ACT_SPEC)
    w_q8 = numerics.fx_quantize(wgt, W8)
    w_q16 = numerics.fx_quantize(wgt, W16)
    wgt_q = jnp.where(st["wgt_bits"] >= 16, w_q16, w_q8)

    act_q = act_q * mh[None, :, None, None] * mw[None, None, :, None] * mc[None, None, None, :]
    wgt_q = (
        wgt_q
        * mkh[:, None, None, None]
        * mkw[None, :, None, None]
        * mc[None, None, :, None]
        * mk[None, None, None, :]
    )

    # full-size stride-1 conv; stride/geometry masking applied on readout.
    y = jax.lax.conv_general_dilated(
        act_q, wgt_q, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (1, MAX_H-MAX_KH+1, MAX_W-MAX_KW+1, MAX_K)
    # accumulators are wide (int32); output re-quantized to 16-bit fixed
    y = numerics.fx_quantize(y, ACT_SPEC)
    oh, ow = y.shape[1], y.shape[2]
    flat = jnp.zeros((OUT_WORDS * V,), jnp.float32)
    flat = flat.at[: oh * ow * MAX_K].set(y.reshape(-1))
    st = dict(st)
    st["out_mem"] = flat.reshape(OUT_WORDS, V)
    return st


# ---------------------------------------------------------------------------
# Driver-side fragment builder — split into a *setup* stream (weight SRAM +
# geometry/datatype config, cached per parameter set) and a *data* stream
# (activation SRAM + CONV_START, re-packed per sample).
# ---------------------------------------------------------------------------

FOH, FOW = MAX_H - MAX_KH + 1, MAX_W - MAX_KW + 1


def _words_rows(vec: np.ndarray) -> np.ndarray:
    """Flatten a tensor into V-lane SRAM words (n_words, V), zero-padded."""
    vec = np.asarray(vec, np.float32).reshape(-1)
    n_words = (len(vec) + V - 1) // V
    buf = np.zeros((n_words * V,), np.float32)
    buf[: len(vec)] = vec
    return buf.reshape(n_words, V)


def _write_words(opcode: int, vec: np.ndarray) -> List[Command]:
    rows = _words_rows(vec)
    return [Command(opcode, i, tuple(rows[i])) for i in range(rows.shape[0])]


def read_full(st) -> jnp.ndarray:
    """Fixed-shape output read (vmap-safe): the full stride-1 conv output;
    callers apply the per-sample stride/geometry slicing host-side."""
    return st["out_mem"].reshape(-1)[: FOH * FOW * MAX_K].reshape(1, FOH, FOW, MAX_K)


def conv2d_fragment(
    w, in_shape, strides=(1, 1), wgt_bits: int = 8, cache: bool = True
) -> CompiledFragment:
    """Setup half: weights resident in wgt SRAM, conv geometry + weight
    datatype configured. ``in_shape`` is the (post-padding) (h, w, c) input
    geometry — part of the device configuration, hence of the cache key."""
    w = np.asarray(w, np.float32)
    h, wd, c = in_shape
    kh, kw, ci, k = w.shape
    assert h <= MAX_H and wd <= MAX_W and c <= MAX_C and k <= MAX_K
    assert kh <= MAX_KH and kw <= MAX_KW
    sh, sw = strides
    key = ("hlscnn_conv2d", (h, wd, c), (sh, sw), int(wgt_bits), fingerprint(w))

    def build():
        wp = np.zeros((MAX_KH, MAX_KW, MAX_C, MAX_K), np.float32)
        wp[:kh, :kw, :c, :k] = w
        cmds = _write_words(WR_WGT, wp)
        cmds.append(Command(CFG_CONV, 0, (h, wd, c, k, kh, kw, sh, sw)))
        cmds.append(Command(CFG_DTYPE, 0, (float(wgt_bits),)))
        setup = PackedStream.from_commands(cmds, V)
        oh, ow = (h - kh) // sh + 1, (wd - kw) // sw + 1
        meta = {"h": h, "wd": wd, "c": c, "k": k, "oh": oh, "ow": ow,
                "sh": sh, "sw": sw, "kh": kh, "kw": kw,
                "wgt_bits": int(wgt_bits), "wp": wp}
        return CompiledFragment(hlscnn, key, setup, meta=meta)

    return FRAGMENTS.get(key, build) if cache else build()


def pack_conv2d_data(frag: CompiledFragment, x) -> DataStream:
    """Data half: one padded sample into act SRAM + trigger."""
    x = np.asarray(x, np.float32)
    m = frag.meta
    assert x.shape == (1, m["h"], m["wd"], m["c"])
    xp = np.zeros((1, MAX_H, MAX_W, MAX_C), np.float32)
    xp[:, : m["h"], : m["wd"], : m["c"]] = x
    bulk = BulkWrite("act_mem", 0, _words_rows(xp), WR_ACT)
    tail = PackedStream.single(CONV_START, 0, (), V)
    return DataStream([bulk], tail)


def out_slice(frag: CompiledFragment):
    """The valid-output window of read_full for this fragment's geometry."""
    m = frag.meta
    return (
        slice(None),
        slice(0, m["oh"] * m["sh"], m["sh"]),
        slice(0, m["ow"] * m["sw"], m["sw"]),
        slice(0, m["k"]),
    )


def build_conv2d_fragment(x, w, strides=(1, 1), padding=(0, 0), wgt_bits: int = 8):
    """conv2d (NHWC x HWIO) -> HLSCNN fragment. Host-side padding per the
    paper; ``wgt_bits`` selects original (8) vs updated (16) design."""
    x, w = np.asarray(x, np.float32), np.asarray(w, np.float32)
    if padding != (0, 0):
        x = np.pad(x, ((0, 0), (padding[0], padding[0]), (padding[1], padding[1]), (0, 0)))
    n, h, wd, c = x.shape
    assert n == 1
    frag = conv2d_fragment(w, (h, wd, c), strides, wgt_bits)
    cmds = frag.full_commands(pack_conv2d_data(frag, x))
    sl = out_slice(frag)

    def read_out(st):
        return read_full(st)[sl]

    return cmds, read_out


# --------------------------------------------------------------------------
# Target declaration: rewrites, planner, validation cases, registration
# --------------------------------------------------------------------------


def _conv_guard(eg, cid, s):
    n, h, w, c = shape_of(eg, s["x"])
    kh, kw, ci, k = shape_of(eg, s["w"])
    ph, pw = s["padding"]
    return (
        h + 2 * ph <= MAX_H
        and w + 2 * pw <= MAX_W
        and c <= MAX_C
        and k <= MAX_K
        and kh <= MAX_KH
        and kw <= MAX_KW
    )


def _rewrites():
    return [
        Rewrite(
            "hlscnn-conv2d",
            P("conv2d", PV("x"), PV("w"), attr_binds=("strides", "padding")),
            P("hlscnn_conv2d", PV("x"), PV("w"), attr_binds=("strides", "padding")),
            guard=_conv_guard,
        ),
    ]


def _ideal_conv2d(a: np.ndarray, w: np.ndarray, strides, padding) -> np.ndarray:
    """numpy (im2col) mirror of ``ir._conv2d`` — NHWC x HWIO, for plan-time
    stats. Planners are the pipelined Executor's pack stage and must not
    dispatch JAX from the pack worker thread."""
    if padding != (0, 0):
        a = np.pad(
            a, ((0, 0), (padding[0], padding[0]), (padding[1], padding[1]), (0, 0))
        )
    kh, kw, _ci, co = w.shape
    sh, sw = strides
    N, H, W, C = a.shape
    oh, ow = (H - kh) // sh + 1, (W - kw) // sw + 1
    cols = np.stack(
        [
            a[:, i : i + oh * sh : sh, j : j + ow * sw : sw, :]
            for i in range(kh)
            for j in range(kw)
        ],
        axis=3,
    )  # (N, OH, OW, KH*KW, C)
    out = cols.reshape(N * oh * ow, kh * kw * C) @ w.reshape(-1, co)
    return out.reshape(N, oh, ow, co)


def plan_conv2d(ctx, x, args):
    a, w = args
    strides = x.attr("strides")
    padding = x.attr("padding")
    wgt_bits = int(ctx.options.get("wgt_bits", 8))
    ideal = _ideal_conv2d(a, w, strides, padding)
    if padding != (0, 0):
        a = np.pad(
            a, ((0, 0), (padding[0], padding[0]), (padding[1], padding[1]), (0, 0))
        )
    frag = conv2d_fragment(w, a.shape[1:], strides, wgt_bits=wgt_bits)
    window = out_slice(frag)
    jobs = [
        SimJob(frag, pack_conv2d_data(frag, a[ni : ni + 1]), read_full, window)
        for ni in range(a.shape[0])
    ]

    def assemble(outs):
        out = np.concatenate(outs, axis=0)
        ctx.record("hlscnn_conv2d", "hlscnn", out, ideal, ctx.ncmds(jobs))
        return out

    return jobs, assemble


def _sample_conv2d(r):
    h = int(r.integers(4, 11))
    c = int(r.integers(1, 9))
    k = int(r.integers(1, 9))
    kh = int(r.integers(1, 4))
    return [
        r.standard_normal((1, h, h, c)).astype(np.float32),
        (r.standard_normal((kh, kh, c, k)) * 0.1).astype(np.float32),
    ], {"strides": (1, 1), "padding": (0, 0)}


def _vt2(dim_t, dim_d):
    x = ir.Var("x", (1, 8, 8, 4))
    wc = ir.Var("wc", (3, 3, 4, 8))
    return [
        VT2Case(
            "conv2d",
            ir.conv2d(x, wc, (1, 1), (0, 0)),
            ir.call("hlscnn_conv2d", x, wc, strides=(1, 1), padding=(0, 0)),
            {"x": (1, 8, 8, 4), "wc": (3, 3, 4, 8)},
        ),
    ]


def _mapping_cases(rng):
    def conv_case():
        x = rng.standard_normal((1, 12, 12, 8)).astype(np.float32)
        w = (rng.standard_normal((3, 3, 8, 16)) * 0.1).astype(np.float32)
        cmds, rd = build_conv2d_fragment(x, w, (1, 1), (0, 0), wgt_bits=16)
        out = rd(hlscnn.simulate(cmds))
        ref = ir._conv2d(jnp.asarray(x), jnp.asarray(w), (1, 1), (0, 0))
        return ref, out

    return [("Conv2D", conv_case)]


# --------------------------------------------------------------------------
# Fused fast-path runner (engine="fused")
#
# CONV_START is a pure function of the activation SRAM once weights and
# geometry are configured, so the fused tier stacks the whole batch of
# activation samples and runs one batched conv with the weight quantization
# (fx lattice + CFG_DTYPE select + geometry masks) hoisted to runner-build
# time. The XLA lowering replays _conv_start's exact lax.conv call
# (bit-exact vs the compiled oracle); the Pallas lowering lowers to im2col
# patches through kernels/fx_gemm.py (different reduction order, so
# tolerance-parity).
# --------------------------------------------------------------------------


def _conv_stack(datas: List[DataStream]):
    """Prepare half (pure numpy): stack activation SRAM images into one
    (B, MAX_H, MAX_W, MAX_C) array, exactly as the bulk writes land them."""
    datas = fused_pad_streams(datas)
    B = len(datas)
    xs = np.zeros((B, MAX_H * MAX_W * MAX_C), np.float32)
    for i, d in enumerate(datas):
        (blk,) = d.bulk
        assert blk.buf == "act_mem" and blk.base == 0
        xs[i] = np.asarray(blk.rows, np.float32).reshape(-1)[: MAX_H * MAX_W * MAX_C]
    return (xs.reshape(B, MAX_H, MAX_W, MAX_C),)


def _fused_conv2d(frag: CompiledFragment) -> FusedRunner:
    m = frag.meta
    wspec = W16 if m["wgt_bits"] >= 16 else W8
    # weight quantization + geometry masks, hoisted out of the per-batch path
    # (identical to _conv_start's: quantize the padded SRAM image, then mask)
    mkh = (np.arange(MAX_KH) < m["kh"]).astype(np.float32)
    mkw = (np.arange(MAX_KW) < m["kw"]).astype(np.float32)
    mc = (np.arange(MAX_C) < m["c"]).astype(np.float32)
    mk = (np.arange(MAX_K) < m["k"]).astype(np.float32)
    wgt_q = np.asarray(numerics.fx_quantize(jnp.asarray(m["wp"]), wspec))
    wgt_q = (wgt_q * mkh[:, None, None, None] * mkw[None, :, None, None]
             * mc[None, None, :, None] * mk[None, None, None, :])
    mh = jnp.asarray((np.arange(MAX_H) < m["h"]).astype(np.float32))
    mw = jnp.asarray((np.arange(MAX_W) < m["wd"]).astype(np.float32))
    mc_j = jnp.asarray(mc)
    lowering = fused_lowering()

    if lowering == "pallas":
        from ..kernels import ops as kops
        from ..kernels.fx_gemm import fx_gemm

        KFLAT = MAX_KH * MAX_KW * MAX_C
        KPAD = -(-KFLAT // 128) * 128
        wflat = np.zeros((128, KPAD), np.float32)
        wflat[:MAX_K, :KFLAT] = wgt_q.reshape(KFLAT, MAX_K).T
        wflat_j = jnp.asarray(wflat)

        def one(x):
            act_q = (numerics.fx_quantize(x, ACT_SPEC)
                     * mh[:, None, None] * mw[None, :, None] * mc_j[None, None, :])
            pats = jnp.stack(
                [act_q[i : i + FOH, j : j + FOW, :]
                 for i in range(MAX_KH) for j in range(MAX_KW)],
                axis=2,
            ).reshape(FOH * FOW, KFLAT)
            pats = jnp.pad(pats, ((0, 0), (0, KPAD - KFLAT)))
            y = fx_gemm(pats, wflat_j, x_spec=ACT_SPEC, w_spec=wspec,
                        o_spec=ACT_SPEC, interpret=kops.INTERPRET)
            return y[:, :MAX_K].reshape(1, FOH, FOW, MAX_K)
    else:
        lowering = "xla"
        wgt_j = jnp.asarray(wgt_q)

        def one(x):
            act_q = (numerics.fx_quantize(x[None], ACT_SPEC)
                     * mh[None, :, None, None] * mw[None, None, :, None]
                     * mc_j[None, None, None, :])
            y = jax.lax.conv_general_dilated(
                act_q, wgt_j, window_strides=(1, 1), padding="VALID",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            return numerics.fx_quantize(y, ACT_SPEC)

    vf = jax.jit(jax.vmap(one))

    def dispatch(prepared):
        (xs,) = prepared
        return vf(_shard_batched(xs))

    return FusedRunner(f"hlscnn-conv2d-{lowering}", _conv_stack, dispatch,
                       read=read_full, lowering=lowering)


def _fused_factory(frag: CompiledFragment):
    """``declare_fused`` hook: fused runner for the conv2d shape."""
    if frag.key[0] == "hlscnn_conv2d":
        return _fused_conv2d(frag)
    return None


COSTS = CostModel("hlscnn", cycles_per_command=1.0)


@COSTS.op("hlscnn_conv2d")
def _cost_conv2d(attrs, shapes):
    """Analytic conv cost: weight SRAM load (setup) + per-sample activation
    stream over V lanes + the MAC volume retired V lanes per cycle."""
    (n, h, w, c), (kh, kw, ci, co) = shapes[0], shapes[1]
    (sh, sw) = attrs.get("strides", (1, 1))
    (ph, pw) = attrs.get("padding", (0, 0))
    hp, wp = h + 2 * ph, w + 2 * pw
    oh, ow = (hp - kh) // sh + 1, (wp - kw) // sw + 1
    setup = -(-kh * kw * ci * co // V) + 6
    data = n * (-(-hp * wp * c // V) + 4)
    macs = n * oh * ow * kh * kw * ci * co
    moved = 4 * (n * hp * wp * c + kh * kw * ci * co + n * oh * ow * co)
    return setup + data, moved, macs / V


TARGET.add_intrinsic(Intrinsic(
    "hlscnn_conv2d", planner=plan_conv2d, sample=_sample_conv2d,
    tol=0.05, options={"wgt_bits": 16},
    doc="non-grouped 2D convolution in 8/16-bit fixed point"))
TARGET.declare_fused(_fused_factory)
TARGET.add_rewrites(_rewrites)
TARGET.add_cost_model(COSTS)
TARGET.add_vt2_cases(_vt2)
TARGET.add_mapping_cases(_mapping_cases)
register_target(TARGET)
