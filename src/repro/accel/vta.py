"""VTA accelerator ILA (Moreau et al., IEEE Micro'19) — JAX model.

Unlike FlexASR/HLSCNN, VTA is a *fine-grained programmable* accelerator with
an actual ISA: a processor-like design around a 16x16 int8 GEMM core with an
int32 accumulator register file, plus a vector ALU. "Operators" are sequences
of VTA instructions (Appendix A). We model the compute-relevant subset:

  LOAD_INP  dram -> inp SRAM   (int8 tile, 16x16)
  LOAD_WGT  dram -> wgt SRAM   (int8 tile, 16x16)
  LOAD_ACC  dram -> acc RF     (int32 tile — bias preload)
  GEMM      acc[d] += inp[i] @ wgt[w]^T   (int8 x int8 -> int32)
  ALU       acc[d] = op(acc[d], acc[s] | imm)   op in {add, max, shr, min}
  STORE     acc RF -> out dram (int8 narrowing with shift-based requant)

The ILA's "DRAM" is a host-visible array in the architectural state (the
paper models DMA through the accelerator interface the same way). GEMM
matches the real device: int8 operands, int32 accumulate, requantization via
arithmetic shift in the ALU — which makes the GEMM mapping *exact* for
integer inputs (Table 2 row 1: 0.00% error).
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ila import ILA, Command, IRAccelMapping, REGISTRY
from . import numerics

T = 16               # tile side (the 16x16 GEMM core)
N_INP = 64           # inp SRAM tiles
N_WGT = 64
N_ACC = 64
DRAM_TILES = 256     # host-visible scratch

LOAD_INP = 0x10
LOAD_WGT = 0x11
LOAD_ACC = 0x12
GEMM = 0x20
ALU = 0x21
STORE = 0x30
WR_DRAM = 0x40       # host writes a 16-value row into DRAM scratch

ALU_ADD = 0
ALU_MAX = 1
ALU_SHR = 2
ALU_MIN = 3

vta = ILA("vta", vwidth=T)
vta.state("dram", lambda: jnp.zeros((DRAM_TILES * T, T), jnp.float32))
vta.state("inp_sram", lambda: jnp.zeros((N_INP, T, T), jnp.float32))
vta.state("wgt_sram", lambda: jnp.zeros((N_WGT, T, T), jnp.float32))
vta.state("acc_rf", lambda: jnp.zeros((N_ACC, T, T), jnp.float32))


def _rd_tile(dram, tile_idx):
    return jax.lax.dynamic_slice(dram, (tile_idx * T, 0), (T, T))


@vta.instruction("wr_dram", WR_DRAM)
def _wr_dram(st, addr, data):
    st = dict(st)
    st["dram"] = jax.lax.dynamic_update_slice(st["dram"], data[None, :], (addr, 0))
    return st


def _load(buf):
    def update(st, addr, data):
        # data = (sram_idx, dram_tile)
        st = dict(st)
        sram_idx = data[0].astype(jnp.int32)
        tile = _rd_tile(st["dram"], data[1].astype(jnp.int32))
        if buf != "acc_rf":
            tile = jnp.clip(jnp.round(tile), -128, 127)  # int8 semantics
        st[buf] = jax.lax.dynamic_update_slice(st[buf], tile[None], (sram_idx, 0, 0))
        return st

    return update


vta.instruction("load_inp", LOAD_INP)(_load("inp_sram"))
vta.instruction("load_wgt", LOAD_WGT)(_load("wgt_sram"))
vta.instruction("load_acc", LOAD_ACC)(_load("acc_rf"))


@vta.instruction("gemm", GEMM, "acc[d] += inp[i] @ wgt[w]^T (int8 -> int32)")
def _gemm(st, addr, data):
    st = dict(st)
    d = data[0].astype(jnp.int32)
    i = data[1].astype(jnp.int32)
    w = data[2].astype(jnp.int32)
    inp = jax.lax.dynamic_slice(st["inp_sram"], (i, 0, 0), (1, T, T))[0]
    wgt = jax.lax.dynamic_slice(st["wgt_sram"], (w, 0, 0), (1, T, T))[0]
    acc = jax.lax.dynamic_slice(st["acc_rf"], (d, 0, 0), (1, T, T))[0]
    # int8 x int8 -> int32 exact in fp32 (|acc| < 2^24 for our tile counts)
    acc = acc + inp @ wgt.T
    st["acc_rf"] = jax.lax.dynamic_update_slice(st["acc_rf"], acc[None], (d, 0, 0))
    return st


@vta.instruction("alu", ALU, "acc[d] = op(acc[d], acc[s] or imm)")
def _alu(st, addr, data):
    st = dict(st)
    op = data[0].astype(jnp.int32)
    d = data[1].astype(jnp.int32)
    s = data[2].astype(jnp.int32)
    use_imm = data[3]
    imm = data[4]
    a = jax.lax.dynamic_slice(st["acc_rf"], (d, 0, 0), (1, T, T))[0]
    b_t = jax.lax.dynamic_slice(st["acc_rf"], (s, 0, 0), (1, T, T))[0]
    b = jnp.where(use_imm > 0, imm, b_t)
    out = jax.lax.switch(
        jnp.clip(op, 0, 3),
        [
            lambda ab: ab[0] + ab[1],
            lambda ab: jnp.maximum(ab[0], ab[1]),
            lambda ab: jnp.floor(ab[0] / jnp.exp2(ab[1])),   # arithmetic >>
            lambda ab: jnp.minimum(ab[0], ab[1]),
        ],
        (a, b),
    )
    st["acc_rf"] = jax.lax.dynamic_update_slice(st["acc_rf"], out[None], (d, 0, 0))
    return st


@vta.instruction("store", STORE, "acc[s] -> dram tile (optional int8 narrowing)")
def _store(st, addr, data):
    st = dict(st)
    s = data[0].astype(jnp.int32)
    dram_tile = data[1].astype(jnp.int32)
    narrow = data[2]
    acc = jax.lax.dynamic_slice(st["acc_rf"], (s, 0, 0), (1, T, T))[0]
    out = jnp.where(narrow > 0, jnp.clip(acc, -128, 127), acc)
    st["dram"] = jax.lax.dynamic_update_slice(st["dram"], out, (dram_tile * T, 0))
    return st


# ---------------------------------------------------------------------------
# Driver-side fragment builders — "operators are sequences of instructions"
# ---------------------------------------------------------------------------


def _tiles(m: np.ndarray) -> Tuple[np.ndarray, int, int]:
    """Pad (R, C) to tile multiples; return (tiles[rt, ct, T, T], rt, ct)."""
    r, c = m.shape
    rt, ct = (r + T - 1) // T, (c + T - 1) // T
    p = np.zeros((rt * T, ct * T), np.float32)
    p[:r, :c] = m
    return p.reshape(rt, T, ct, T).transpose(0, 2, 1, 3), rt, ct


def _write_dram_tile(cmds, tile_idx: int, tile: np.ndarray):
    for r in range(T):
        cmds.append(Command(WR_DRAM, tile_idx * T + r, tuple(tile[r])))


def build_gemm_fragment(a_int8: np.ndarray, b_int8: np.ndarray, requant_shift: int = 0):
    """dense(a, b) (int8) -> VTA instruction sequence.

    a:(M,K) b:(N,K); returns int32 accum (or int8 after shift/narrow if
    requant_shift > 0). Tiled over the 16x16 GEMM core.
    """
    a_t, mt, kt = _tiles(np.asarray(a_int8, np.float32))
    b_t, nt, kt2 = _tiles(np.asarray(b_int8, np.float32))
    assert kt == kt2
    assert mt * kt <= N_INP and nt * kt <= N_WGT and mt * nt <= N_ACC
    cmds: List[Command] = []
    # DMA in: inp tiles then wgt tiles
    dram_idx = 0
    for i in range(mt):
        for k in range(kt):
            _write_dram_tile(cmds, dram_idx, a_t[i, k])
            cmds.append(Command(LOAD_INP, 0, (i * kt + k, dram_idx)))
            dram_idx += 1
    for n in range(nt):
        for k in range(kt):
            _write_dram_tile(cmds, dram_idx, b_t[n, k])
            cmds.append(Command(LOAD_WGT, 0, (n * kt + k, dram_idx)))
            dram_idx += 1
    # zero accumulators via imm min/max trick: load from an always-zero tile
    zero_tile = dram_idx
    _write_dram_tile(cmds, zero_tile, np.zeros((T, T), np.float32))
    dram_idx += 1
    for m in range(mt):
        for n in range(nt):
            cmds.append(Command(LOAD_ACC, 0, (m * nt + n, zero_tile)))
    # GEMM micro-ops
    for m in range(mt):
        for n in range(nt):
            for k in range(kt):
                cmds.append(Command(GEMM, 0, (m * nt + n, m * kt + k, n * kt + k)))
    if requant_shift > 0:
        for m in range(mt):
            for n in range(nt):
                cmds.append(Command(ALU, 0, (ALU_SHR, m * nt + n, 0, 1.0, float(requant_shift))))
    out_base = dram_idx
    narrow = 1.0 if requant_shift > 0 else 0.0
    for m in range(mt):
        for n in range(nt):
            cmds.append(Command(STORE, 0, (m * nt + n, out_base + m * nt + n, narrow)))
    M, K = np.asarray(a_int8).shape
    N = np.asarray(b_int8).shape[0]

    def read_out(st):
        tiles = []
        for m in range(mt):
            row = []
            for n in range(nt):
                row.append(st["dram"][(out_base + m * nt + n) * T : (out_base + m * nt + n + 1) * T])
            tiles.append(jnp.concatenate(row, axis=1))
        full = jnp.concatenate(tiles, axis=0)
        return full[:M, :N]

    return cmds, read_out


def build_add_fragment(a_int: np.ndarray, b_int: np.ndarray):
    """elementwise add on the vector ALU (acc RF resident)."""
    a_t, rt, ct = _tiles(np.asarray(a_int, np.float32))
    b_t, _, _ = _tiles(np.asarray(b_int, np.float32))
    assert 2 * rt * ct <= N_ACC
    cmds: List[Command] = []
    dram_idx = 0
    for r in range(rt):
        for c in range(ct):
            _write_dram_tile(cmds, dram_idx, a_t[r, c])
            cmds.append(Command(LOAD_ACC, 0, (r * ct + c, dram_idx)))
            dram_idx += 1
            _write_dram_tile(cmds, dram_idx, b_t[r, c])
            cmds.append(Command(LOAD_ACC, 0, (rt * ct + r * ct + c, dram_idx)))
            dram_idx += 1
    for i in range(rt * ct):
        cmds.append(Command(ALU, 0, (ALU_ADD, i, rt * ct + i, 0.0, 0.0)))
    out_base = dram_idx
    for i in range(rt * ct):
        cmds.append(Command(STORE, 0, (i, out_base + i)))
    R, C = np.asarray(a_int).shape

    def read_out(st):
        tiles = []
        for r in range(rt):
            row = [st["dram"][(out_base + r * ct + c) * T : (out_base + r * ct + c + 1) * T] for c in range(ct)]
            tiles.append(jnp.concatenate(row, axis=1))
        return jnp.concatenate(tiles, axis=0)[:R, :C]

    return cmds, read_out


def build_relu_fragment(a_int: np.ndarray):
    a_t, rt, ct = _tiles(np.asarray(a_int, np.float32))
    cmds: List[Command] = []
    dram_idx = 0
    for r in range(rt):
        for c in range(ct):
            _write_dram_tile(cmds, dram_idx, a_t[r, c])
            cmds.append(Command(LOAD_ACC, 0, (r * ct + c, dram_idx)))
            dram_idx += 1
    for i in range(rt * ct):
        cmds.append(Command(ALU, 0, (ALU_MAX, i, 0, 1.0, 0.0)))
    out_base = dram_idx
    for i in range(rt * ct):
        cmds.append(Command(STORE, 0, (i, out_base + i)))
    R, C = np.asarray(a_int).shape

    def read_out(st):
        tiles = []
        for r in range(rt):
            row = [st["dram"][(out_base + r * ct + c) * T : (out_base + r * ct + c + 1) * T] for c in range(ct)]
            tiles.append(jnp.concatenate(row, axis=1))
        return jnp.concatenate(tiles, axis=0)[:R, :C]

    return cmds, read_out


REGISTRY.register(IRAccelMapping("vta-gemm", "vta", "vta_gemm", build_gemm_fragment,
                                 "tiled int8 GEMM on the 16x16 core"))
REGISTRY.register(IRAccelMapping("vta-add", "vta", "vta_add", build_add_fragment,
                                 "vector ALU elementwise add"))
REGISTRY.register(IRAccelMapping("vta-relu", "vta", "vta_relu", build_relu_fragment,
                                 "vector ALU relu (max with 0)"))
