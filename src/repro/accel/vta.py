"""VTA accelerator ILA (Moreau et al., IEEE Micro'19) — JAX model.

Unlike FlexASR/HLSCNN, VTA is a *fine-grained programmable* accelerator with
an actual ISA: a processor-like design around a 16x16 int8 GEMM core with an
int32 accumulator register file, plus a vector ALU. "Operators" are sequences
of VTA instructions (Appendix A). We model the compute-relevant subset:

  LOAD_INP  dram -> inp SRAM   (int8 tile, 16x16)
  LOAD_WGT  dram -> wgt SRAM   (int8 tile, 16x16)
  LOAD_ACC  dram -> acc RF     (int32 tile — bias preload)
  GEMM      acc[d] += inp[i] @ wgt[w]^T   (int8 x int8 -> int32)
  ALU       acc[d] = op(acc[d], acc[s] | imm)   op in {add, max, shr, min}
  STORE     acc RF -> out dram (int8 narrowing with shift-based requant)

The ILA's "DRAM" is a host-visible array in the architectural state (the
paper models DMA through the accelerator interface the same way). GEMM
matches the real device: int8 operands, int32 accumulate, requantization via
arithmetic shift in the ALU — which makes the GEMM mapping *exact* for
integer inputs (Table 2 row 1: 0.00% error).
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import ir
from ..core.egraph import P, V as PV, Rewrite
from ..core.ila import (
    ILA, BulkWrite, Command, CompiledFragment, DataStream,
    PackedStream, fingerprint,
)
from . import numerics
from .target import (
    AcceleratorTarget, CostModel, Intrinsic, SimJob, VT2Case, register_target,
)

T = 16               # tile side (the 16x16 GEMM core)
N_INP = 64           # inp SRAM tiles
N_WGT = 64
N_ACC = 64
DRAM_TILES = 256     # host-visible scratch

LOAD_INP = 0x10
LOAD_WGT = 0x11
LOAD_ACC = 0x12
GEMM = 0x20
ALU = 0x21
STORE = 0x30
WR_DRAM = 0x40       # host writes a 16-value row into DRAM scratch

ALU_ADD = 0
ALU_MAX = 1
ALU_SHR = 2
ALU_MIN = 3

vta = ILA("vta", vwidth=T)

TARGET = AcceleratorTarget(
    "vta",
    vta,
    display_name="VTA",
    capabilities={
        "tile": T, "n_inp": N_INP, "n_wgt": N_WGT, "n_acc": N_ACC,
        "numerics": "int8xint8->int32",
    },
    doc="fine-grained programmable accelerator: 16x16 int8 GEMM core + vector ALU",
    # dense and vta_gemm interpret through the same fp32 matmul: bit-exact
    vt2_tol=0.0,
)
FRAGMENTS = TARGET.fragments
# dram rows carry pre-quantized int8-grid operands: |x| <= 127, inside the
# +/-128 fixed-range saturation point — wrap statically unreachable
TARGET.declare_lint(input_range=(-127.0, 127.0))

vta.state("dram", lambda: jnp.zeros((DRAM_TILES * T, T), jnp.float32))
vta.state("inp_sram", lambda: jnp.zeros((N_INP, T, T), jnp.float32))
vta.state("wgt_sram", lambda: jnp.zeros((N_WGT, T, T), jnp.float32))
vta.state("acc_rf", lambda: jnp.zeros((N_ACC, T, T), jnp.float32))


def _rd_tile(dram, tile_idx):
    return jax.lax.dynamic_slice(dram, (tile_idx * T, 0), (T, T))


@vta.instruction("wr_dram", WR_DRAM)
def _wr_dram(st, addr, data):
    st = dict(st)
    st["dram"] = jax.lax.dynamic_update_slice(st["dram"], data[None, :], (addr, 0))
    return st


def _load(buf):
    def update(st, addr, data):
        # data = (sram_idx, dram_tile)
        st = dict(st)
        sram_idx = data[0].astype(jnp.int32)
        tile = _rd_tile(st["dram"], data[1].astype(jnp.int32))
        if buf != "acc_rf":
            tile = jnp.clip(jnp.round(tile), -128, 127)  # int8 semantics
        st[buf] = jax.lax.dynamic_update_slice(st[buf], tile[None], (sram_idx, 0, 0))
        return st

    return update


vta.instruction("load_inp", LOAD_INP)(_load("inp_sram"))
vta.instruction("load_wgt", LOAD_WGT)(_load("wgt_sram"))
vta.instruction("load_acc", LOAD_ACC)(_load("acc_rf"))


@vta.instruction("gemm", GEMM, "acc[d] += inp[i] @ wgt[w]^T (int8 -> int32)")
def _gemm(st, addr, data):
    st = dict(st)
    d = data[0].astype(jnp.int32)
    i = data[1].astype(jnp.int32)
    w = data[2].astype(jnp.int32)
    inp = jax.lax.dynamic_slice(st["inp_sram"], (i, 0, 0), (1, T, T))[0]
    wgt = jax.lax.dynamic_slice(st["wgt_sram"], (w, 0, 0), (1, T, T))[0]
    acc = jax.lax.dynamic_slice(st["acc_rf"], (d, 0, 0), (1, T, T))[0]
    # int8 x int8 -> int32 exact in fp32 (|acc| < 2^24 for our tile counts)
    acc = acc + inp @ wgt.T
    st["acc_rf"] = jax.lax.dynamic_update_slice(st["acc_rf"], acc[None], (d, 0, 0))
    return st


@vta.instruction("alu", ALU, "acc[d] = op(acc[d], acc[s] or imm)")
def _alu(st, addr, data):
    st = dict(st)
    op = data[0].astype(jnp.int32)
    d = data[1].astype(jnp.int32)
    s = data[2].astype(jnp.int32)
    use_imm = data[3]
    imm = data[4]
    a = jax.lax.dynamic_slice(st["acc_rf"], (d, 0, 0), (1, T, T))[0]
    b_t = jax.lax.dynamic_slice(st["acc_rf"], (s, 0, 0), (1, T, T))[0]
    b = jnp.where(use_imm > 0, imm, b_t)
    out = jax.lax.switch(
        jnp.clip(op, 0, 3),
        [
            lambda ab: ab[0] + ab[1],
            lambda ab: jnp.maximum(ab[0], ab[1]),
            lambda ab: jnp.floor(ab[0] / jnp.exp2(ab[1])),   # arithmetic >>
            lambda ab: jnp.minimum(ab[0], ab[1]),
        ],
        (a, b),
    )
    st["acc_rf"] = jax.lax.dynamic_update_slice(st["acc_rf"], out[None], (d, 0, 0))
    return st


@vta.instruction("store", STORE, "acc[s] -> dram tile (optional int8 narrowing)")
def _store(st, addr, data):
    st = dict(st)
    s = data[0].astype(jnp.int32)
    dram_tile = data[1].astype(jnp.int32)
    narrow = data[2]
    acc = jax.lax.dynamic_slice(st["acc_rf"], (s, 0, 0), (1, T, T))[0]
    out = jnp.where(narrow > 0, jnp.clip(acc, -128, 127), acc)
    st["dram"] = jax.lax.dynamic_update_slice(st["dram"], out, (dram_tile * T, 0))
    return st


# ---------------------------------------------------------------------------
# Driver-side fragment builders — "operators are sequences of instructions".
#
# Split for the fragment-compiler fast path: the *setup* stream stages the
# stationary operand (weight tiles -> wgt SRAM) and zeroes the accumulators;
# the *data* stream DMAs the moving operand, issues the GEMM/ALU micro-ops,
# and stores results. DRAM scratch layout is fixed per fragment so data
# streams for every invocation hit the same addresses:
#
#   [0, nt*kt)                 weight tiles          (setup)
#   nt*kt                      always-zero tile      (setup; acc preload)
#   (nt*kt+1, +mt*kt)          input tiles           (data, bulk write)
#   (nt*kt+1+mt*kt, +mt*nt)    output tiles          (data, STORE)
# ---------------------------------------------------------------------------


def _tiles(m: np.ndarray) -> Tuple[np.ndarray, int, int]:
    """Pad (R, C) to tile multiples; return (tiles[rt, ct, T, T], rt, ct)."""
    r, c = m.shape
    rt, ct = (r + T - 1) // T, (c + T - 1) // T
    p = np.zeros((rt * T, ct * T), np.float32)
    p[:r, :c] = m
    return p.reshape(rt, T, ct, T).transpose(0, 2, 1, 3), rt, ct


def _write_dram_tile(cmds, tile_idx: int, tile: np.ndarray):
    for r in range(T):
        cmds.append(Command(WR_DRAM, tile_idx * T + r, tuple(tile[r])))


def _tile_rows(tiles: np.ndarray) -> np.ndarray:
    """(n, T, T) tile stack -> (n*T, T) contiguous DRAM rows."""
    return np.ascontiguousarray(tiles).reshape(-1, T)


def _cmd_stream(entries) -> PackedStream:
    """[(opcode, values), ...] -> PackedStream (addr unused by these ops)."""
    n = len(entries)
    ops = np.array([e[0] for e in entries], np.int32)
    addrs = np.zeros((n,), np.int32)
    data = np.zeros((n, T), np.float32)
    for i, (_, vals) in enumerate(entries):
        vals = np.asarray(vals, np.float32)
        data[i, : len(vals)] = vals
    return PackedStream(ops, addrs, data)


def gemm_fragment(b_int8: np.ndarray, mt: int, cache: bool = True) -> CompiledFragment:
    """Setup half of the GEMM mapping: weight tiles resident in wgt SRAM and
    ``mt * nt`` accumulators zeroed, for data chunks of up to ``mt`` row
    tiles. Cached per (weight chunk, layout)."""
    b_t, nt, kt = _tiles(np.asarray(b_int8, np.float32))
    assert mt * kt <= N_INP and nt * kt <= N_WGT and mt * nt <= N_ACC
    inp_base = nt * kt + 1
    out_base = inp_base + mt * kt
    assert (out_base + mt * nt) <= DRAM_TILES
    key = ("vta_gemm", mt, nt, kt, fingerprint(b_int8))

    def build():
        cmds: List[Command] = []
        for n in range(nt):
            for k in range(kt):
                _write_dram_tile(cmds, n * kt + k, b_t[n, k])
                cmds.append(Command(LOAD_WGT, 0, (n * kt + k, n * kt + k)))
        # zero accumulators: preload every acc tile from an always-zero tile
        zero_tile = nt * kt
        _write_dram_tile(cmds, zero_tile, np.zeros((T, T), np.float32))
        for m in range(mt):
            for n in range(nt):
                cmds.append(Command(LOAD_ACC, 0, (m * nt + n, zero_tile)))
        setup = PackedStream.from_commands(cmds, T)
        meta = {
            "mt": mt, "nt": nt, "kt": kt, "inp_base": inp_base,
            "out_base": out_base, "N": int(np.asarray(b_int8).shape[0]),
        }
        return CompiledFragment(vta, key, setup, meta=meta)

    return FRAGMENTS.get(key, build) if cache else build()


def pack_gemm_data(frag: CompiledFragment, a_int8: np.ndarray, requant_shift: int = 0) -> DataStream:
    """Data half: input tiles + GEMM/requant/STORE micro-ops for one chunk
    of up to ``mt`` row tiles."""
    m = frag.meta
    a_t, mt_c, kt = _tiles(np.asarray(a_int8, np.float32))
    assert kt == m["kt"] and mt_c <= m["mt"]
    nt, inp_base, out_base = m["nt"], m["inp_base"], m["out_base"]
    bulk = BulkWrite(
        "dram", inp_base * T, _tile_rows(a_t.reshape(mt_c * kt, T, T)), WR_DRAM
    )
    entries = []
    for i in range(mt_c):
        for k in range(kt):
            entries.append((LOAD_INP, (i * kt + k, inp_base + i * kt + k)))
    for mi in range(mt_c):
        for n in range(nt):
            for k in range(kt):
                entries.append((GEMM, (mi * nt + n, mi * kt + k, n * kt + k)))
    if requant_shift > 0:
        for mi in range(mt_c):
            for n in range(nt):
                entries.append((ALU, (ALU_SHR, mi * nt + n, 0, 1.0, float(requant_shift))))
    narrow = 1.0 if requant_shift > 0 else 0.0
    for mi in range(mt_c):
        for n in range(nt):
            entries.append((STORE, (mi * nt + n, out_base + mi * nt + n, narrow)))
    return DataStream([bulk], _cmd_stream(entries))


def read_gemm_full(frag: CompiledFragment):
    """Vmap-safe fixed-shape read of the whole output region: (mt*T, nt*T);
    callers slice the valid [:M, :N] window."""
    m = frag.meta
    mt, nt, out_base = m["mt"], m["nt"], m["out_base"]

    def read(st):
        region = st["dram"][out_base * T : (out_base + mt * nt) * T]
        return region.reshape(mt, nt, T, T).transpose(0, 2, 1, 3).reshape(mt * T, nt * T)

    return read


def build_gemm_fragment(a_int8: np.ndarray, b_int8: np.ndarray, requant_shift: int = 0):
    """dense(a, b) (int8) -> VTA instruction sequence.

    a:(M,K) b:(N,K); returns int32 accum (or int8 after shift/narrow if
    requant_shift > 0). Tiled over the 16x16 GEMM core.
    """
    a = np.asarray(a_int8)
    mt = (a.shape[0] + T - 1) // T
    frag = gemm_fragment(b_int8, mt)
    cmds = frag.full_commands(pack_gemm_data(frag, a_int8, requant_shift))
    M, N = a.shape[0], np.asarray(b_int8).shape[0]
    read = read_gemm_full(frag)

    def read_out(st):
        return read(st)[:M, :N]

    return cmds, read_out


def alu_fragment(rt: int, ct: int, kind: str, cache: bool = True) -> CompiledFragment:
    """Vector-ALU ops have no stationary operand: the setup stream is empty
    and the whole invocation is a data stream. Cached per tile layout only
    (the fragment then exists to batch same-layout invocations).

    DRAM layout (``n = rt * ct`` tiles): a tiles [0, n), b tiles [n, 2n)
    (add only), outputs after the operand region.
    """
    n = rt * ct
    assert kind in ("add", "relu")
    n_ops = 2 * n if kind == "add" else n
    assert n_ops <= N_ACC and (n_ops + n) <= DRAM_TILES
    key = ("vta_alu", kind, rt, ct)

    def build():
        meta = {"rt": rt, "ct": ct, "kind": kind, "out_base": n_ops}
        return CompiledFragment(vta, key, PackedStream.empty(T), meta=meta)

    return FRAGMENTS.get(key, build) if cache else build()


def pack_alu_data(frag: CompiledFragment, a_int: np.ndarray, b_int=None) -> DataStream:
    m = frag.meta
    rt, ct, kind, out_base = m["rt"], m["ct"], m["kind"], m["out_base"]
    n = rt * ct
    a_t, rt2, ct2 = _tiles(np.asarray(a_int, np.float32))
    assert (rt2, ct2) == (rt, ct)
    bulk = [BulkWrite("dram", 0, _tile_rows(a_t.reshape(n, T, T)), WR_DRAM)]
    entries = [(LOAD_ACC, (i, i)) for i in range(n)]
    if kind == "add":
        b_t, _, _ = _tiles(np.asarray(b_int, np.float32))
        bulk.append(BulkWrite("dram", n * T, _tile_rows(b_t.reshape(n, T, T)), WR_DRAM))
        entries += [(LOAD_ACC, (n + i, n + i)) for i in range(n)]
        entries += [(ALU, (ALU_ADD, i, n + i, 0.0, 0.0)) for i in range(n)]
    else:
        entries += [(ALU, (ALU_MAX, i, 0, 1.0, 0.0)) for i in range(n)]
    entries += [(STORE, (i, out_base + i)) for i in range(n)]
    return DataStream(bulk, _cmd_stream(entries))


def read_alu_full(frag: CompiledFragment):
    """Vmap-safe read of the whole (rt*T, ct*T) output; slice [:R, :C]."""
    m = frag.meta
    rt, ct, out_base = m["rt"], m["ct"], m["out_base"]

    def read(st):
        region = st["dram"][out_base * T : (out_base + rt * ct) * T]
        return region.reshape(rt, ct, T, T).transpose(0, 2, 1, 3).reshape(rt * T, ct * T)

    return read


def _build_alu_fragment(kind, a_int, b_int=None):
    a = np.asarray(a_int)
    rt, ct = (a.shape[0] + T - 1) // T, (a.shape[1] + T - 1) // T
    frag = alu_fragment(rt, ct, kind)
    cmds = frag.full_commands(pack_alu_data(frag, a_int, b_int))
    R, C = a.shape
    read = read_alu_full(frag)

    def read_out(st):
        return read(st)[:R, :C]

    return cmds, read_out


def build_add_fragment(a_int: np.ndarray, b_int: np.ndarray):
    """elementwise add on the vector ALU (acc RF resident)."""
    return _build_alu_fragment("add", a_int, b_int)


def build_relu_fragment(a_int: np.ndarray):
    return _build_alu_fragment("relu", a_int)


# --------------------------------------------------------------------------
# Target declaration: rewrites, planners, validation cases, registration
# --------------------------------------------------------------------------


def _rewrites():
    return [
        Rewrite("vta-gemm", P("dense", PV("a"), PV("b")), P("vta_gemm", PV("a"), PV("b"))),
        Rewrite("vta-add", P("add", PV("a"), PV("b")), P("vta_add", PV("a"), PV("b"))),
        Rewrite("vta-relu", P("relu", PV("x")), P("vta_relu", PV("x"))),
    ]


def kernel_gemm(ctx, x, args):
    """Deployment fast path: the int8_gemm Pallas kernel."""
    from ..kernels import ops as kops

    a, b = args
    ideal = a @ b.T
    sa = np.abs(a).max() / 127.0 if np.abs(a).max() > 0 else 1.0
    sb = np.abs(b).max() / 127.0 if np.abs(b).max() > 0 else 1.0
    a8 = np.clip(np.round(a / sa), -127, 127)
    b8 = np.clip(np.round(b / sb), -127, 127)
    out32 = np.asarray(
        kops.int8_gemm(jnp.asarray(a8, jnp.int8), jnp.asarray(b8, jnp.int8))
    ).astype(np.float64)
    out = out32 * sa * sb
    ctx.record("vta_gemm", "vta-kernel", out, ideal, 0)
    return out.astype(np.float32)


def plan_gemm(ctx, x, args):
    a, b = args
    ideal = a @ b.T
    sa = np.abs(a).max() / 127.0 if np.abs(a).max() > 0 else 1.0
    sb = np.abs(b).max() / 127.0 if np.abs(b).max() > 0 else 1.0
    a8 = np.clip(np.round(a / sa), -127, 127)
    b8 = np.clip(np.round(b / sb), -127, 127)
    # tile rows so SRAM limits hold: mt*kt <= N_INP etc.
    kt = (a8.shape[1] + T - 1) // T
    max_m = max(1, (N_INP // kt)) * T
    max_n = max(1, (N_WGT // kt)) * T
    mt_layout = (min(max_m, a8.shape[0]) + T - 1) // T
    jobs, layout = [], []
    for mi in range(0, a8.shape[0], max_m):
        a_chunk = a8[mi : mi + max_m]
        row = []
        for nj in range(0, b8.shape[0], max_n):
            b_chunk = b8[nj : nj + max_n]
            frag = gemm_fragment(b_chunk, mt_layout)
            jobs.append(
                SimJob(frag, pack_gemm_data(frag, a_chunk), read_gemm_full(frag),
                       (slice(0, a_chunk.shape[0]), slice(0, b_chunk.shape[0])))
            )
            row.append(len(jobs) - 1)
        layout.append(row)

    def assemble(outs):
        out32 = np.concatenate(
            [np.concatenate([outs[i] for i in row], axis=1) for row in layout],
            axis=0,
        ).astype(np.float64)
        out = out32 * sa * sb
        ctx.record("vta_gemm", "vta", out, ideal, ctx.ncmds(jobs))
        return out.astype(np.float32)

    return jobs, assemble


def plan_add(ctx, x, args):
    a, b = args
    # elementwise adds stay in the accumulator's wide fixed point; the
    # driver scales both operands onto a shared int grid
    s = max(np.abs(a).max(), np.abs(b).max(), 1e-9) / (2 ** 20)
    ai = np.round(np.broadcast_to(a, np.broadcast_shapes(a.shape, b.shape)) / s)
    bi = np.round(np.broadcast_to(b, ai.shape) / s)
    a2 = ai.reshape(-1, ai.shape[-1]) if ai.ndim > 1 else ai.reshape(1, -1)
    b2 = bi.reshape(a2.shape)
    ct = (a2.shape[1] + T - 1) // T
    max_r = max(1, (N_ACC // 2) // ct) * T
    jobs = []
    for ri in range(0, a2.shape[0], max_r):
        ac, bc = a2[ri : ri + max_r], b2[ri : ri + max_r]
        rt = (ac.shape[0] + T - 1) // T
        frag = alu_fragment(rt, ct, "add")
        jobs.append(
            SimJob(frag, pack_alu_data(frag, ac, bc), read_alu_full(frag),
                   (slice(0, ac.shape[0]), slice(0, ac.shape[1])))
        )

    def assemble(outs):
        out = (np.concatenate(outs, axis=0) * s).reshape(ai.shape).astype(np.float32)
        ctx.record("vta_add", "vta", out, np.asarray(a) + np.asarray(b),
                   ctx.ncmds(jobs))
        return out

    return jobs, assemble


def plan_relu(ctx, x, args):
    (a,) = args
    s = max(np.abs(a).max(), 1e-9) / (2 ** 20)
    ai = np.round(a / s)
    a2 = ai.reshape(-1, ai.shape[-1]) if ai.ndim > 1 else ai.reshape(1, -1)
    ct = (a2.shape[1] + T - 1) // T
    max_r = max(1, (N_ACC // 2) // ct) * T
    jobs = []
    for ri in range(0, a2.shape[0], max_r):
        ac = a2[ri : ri + max_r]
        rt = (ac.shape[0] + T - 1) // T
        frag = alu_fragment(rt, ct, "relu")
        jobs.append(
            SimJob(frag, pack_alu_data(frag, ac), read_alu_full(frag),
                   (slice(0, ac.shape[0]), slice(0, ac.shape[1])))
        )

    def assemble(outs):
        out = (np.concatenate(outs, axis=0) * s).reshape(a.shape).astype(np.float32)
        ctx.record("vta_relu", "vta", out, np.maximum(a, 0), ctx.ncmds(jobs))
        return out

    return jobs, assemble


def _sample_gemm(r):
    M, K, N = int(r.integers(1, 21)), int(r.integers(1, 41)), int(r.integers(1, 21))
    return [
        r.integers(-120, 120, (M, K)).astype(np.float32),
        r.integers(-120, 120, (N, K)).astype(np.float32),
    ], {}


def _sample_add(r):
    R, C = int(r.integers(1, 21)), int(r.integers(1, 25))
    return [
        r.standard_normal((R, C)).astype(np.float32),
        r.standard_normal((R, C)).astype(np.float32),
    ], {}


def _sample_relu(r):
    R, C = int(r.integers(1, 21)), int(r.integers(1, 25))
    return [r.standard_normal((R, C)).astype(np.float32)], {}


def _vt2(dim_t, dim_d):
    a = ir.Var("a", (dim_t, dim_d))
    w = ir.Var("w", (dim_d, dim_d))
    return [
        VT2Case(
            "vta-gemm",
            ir.dense(a, w),
            ir.call("vta_gemm", a, w),
            {"a": (dim_t, dim_d), "w": (dim_d, dim_d)},
        ),
    ]


def _vt3_gemm(n: int = 3, seed: int = 0):
    """VTA ILA GEMM vs the int8_gemm Pallas kernel: exact equality."""
    from ..kernels import ops as kops

    rng = np.random.default_rng(seed)
    worst = 0.0
    for _ in range(n):
        a = rng.integers(-100, 100, (24, 48)).astype(np.float32)
        b = rng.integers(-100, 100, (20, 48)).astype(np.float32)
        cmds, rd = build_gemm_fragment(a, b)
        ila_out = np.asarray(rd(vta.simulate(cmds)))
        kern_out = np.asarray(
            kops.int8_gemm(jnp.asarray(a, jnp.int8), jnp.asarray(b, jnp.int8))
        ).astype(np.float32)
        worst = max(worst, float(np.abs(ila_out - kern_out).max()))
    return worst == 0.0, worst


def _mapping_cases(rng):
    def gemm_case():
        a = rng.integers(-100, 100, (16, 64)).astype(np.float32)
        b = rng.integers(-100, 100, (16, 64)).astype(np.float32)
        cmds, rd = build_gemm_fragment(a, b)
        out = rd(vta.simulate(cmds))
        return a @ b.T, out

    return [("GEMM", gemm_case)]


COSTS = CostModel("vta", cycles_per_command=1.0)


def _numel(shapes):
    return int(np.prod(np.broadcast_shapes(*shapes))) if shapes else 1


@COSTS.op("vta_gemm")
def _cost_gemm(attrs, shapes):
    (m, k), (n, _) = shapes[0], shapes[1]
    setup = -(-n * k // T) + 4          # weight tiles resident in wgt SRAM
    data = m * -(-k // T) + 4           # activation tile stream + launch
    moved = 4 * (m * k + n * k + m * n)
    return setup + data, moved, m * n * k / (T * T)


def _cost_alu(attrs, shapes):
    n = _numel(shapes)
    ops = len(shapes)                   # one tile stream per operand
    return ops * -(-n // T) + 4, 4 * (ops + 1) * n, n / T


COSTS.op("vta_add")(_cost_alu)
COSTS.op("vta_relu")(_cost_alu)


TARGET.add_intrinsic(Intrinsic(
    "vta_gemm", planner=plan_gemm, kernel=kernel_gemm, sample=_sample_gemm,
    tol=0.02, doc="tiled int8 GEMM on the 16x16 core"))
TARGET.add_intrinsic(Intrinsic(
    "vta_add", planner=plan_add, sample=_sample_add, tol=1e-4,
    doc="vector ALU elementwise add"))
TARGET.add_intrinsic(Intrinsic(
    "vta_relu", planner=plan_relu, sample=_sample_relu, tol=1e-4,
    doc="vector ALU relu (max with 0)"))
TARGET.add_rewrites(_rewrites)
TARGET.add_cost_model(COSTS)
TARGET.add_vt2_cases(_vt2)
TARGET.add_vt3_check("gemm_ila_vs_int8_gemm_kernel", _vt3_gemm)
TARGET.add_mapping_cases(_mapping_cases)
register_target(TARGET)
