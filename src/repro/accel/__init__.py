"""Accelerator backends, as :class:`~repro.accel.target.AcceleratorTarget`
plugins + the custom-numerics library.

Importing this package registers the bundled targets with the core registry
(``repro.core.ila.TARGETS``) — the *only* integration step a backend needs.
To add an accelerator: write one module against ``repro.accel.target`` (see
``vecunit.py`` and ``docs/targets.md``) and import it here.
"""
from . import flexasr, hlscnn, target, vecunit, vta  # noqa: F401
