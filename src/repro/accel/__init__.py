"""Accelerator ILA models (FlexASR / HLSCNN / VTA) + custom numerics."""
