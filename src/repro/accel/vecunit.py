"""VecUnit: a small element-wise vector accelerator — the plugin-API proof.

A deliberately simple fourth backend (in the spirit of the paper's claim
that ILA + mappings are all a new prototype accelerator needs): a 16-lane
element-wise vector unit computing in **int16 block fixed point** — values
are quantized to a signed 16-bit grid whose power-of-two scale is configured
per invocation by the driver (``CFG_NUM``), the way FlexASR's driver sizes
AdaptivFloat exponent windows. Supported functions:

  EW_MUL      out = a * b          (element-wise product; swish/SE gating)
  EW_SIGMOID  out = sigmoid(a)

Architectural state: three row buffers (operands a/b, output) of
``MAX_ROWS x MAX_COLS`` values stored as V-lane words, plus geometry/mode/
scale registers. Instruction set (MMIO-style, one V-lane word per command):

  WR_A / WR_B   store one V-lane row into the operand buffers
  CFG           mode, n_rows, n_cols
  CFG_NUM       scale exponents (a, b, out)
  EW_START      run the configured element-wise function

Everything the compiler, executor and validation layers need is declared
through :mod:`repro.accel.target` and registered at the bottom of this file —
**no ``repro/core`` module mentions this backend**. Compiled programs pick it
up via flexible matching (EfficientNet's swish-family ``mul``/``sigmoid``
gating offloads here out of the box), and the registry-driven conformance
suite covers it with zero bespoke tests. ``docs/targets.md`` walks through
this file as the "add your accelerator in ~200 lines" example.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import ir
from ..core.egraph import P, V as PV, Rewrite, shape_of
from ..core.ila import (
    ILA, BulkWrite, CompiledFragment, DataStream, PackedStream,
)
from .target import (
    AcceleratorTarget, CostModel, Intrinsic, SimJob, VT2Case, register_target,
)

V = 16              # interface lanes
MAX_COLS = 64       # row width in values (4 V-lane words)
MAX_ROWS = 64       # rows per invocation (driver chunks larger tensors)
QMAX = 2 ** 15 - 1  # int16 symmetric grid

WR_A = 0x10
WR_B = 0x11
CFG = 0x20
CFG_NUM = 0x21
EW_START = 0x30

MODE_MUL = 1
MODE_SIGMOID = 2

_WORDS = MAX_ROWS * MAX_COLS // V

vecunit = ILA("vecunit", vwidth=V)

TARGET = AcceleratorTarget(
    "vecunit",
    vecunit,
    display_name="VecUnit",
    capabilities={
        "max_rows": MAX_ROWS, "max_cols": MAX_COLS, "numerics": "int16-blockfp",
    },
    doc="element-wise vector unit (mul / sigmoid) in int16 block fixed point",
    # the abstract fragments are the *identical* fp32 expressions on both
    # sides — the VT2 bound is bit-exact, not the historical 1e-5 slack
    vt2_tol=0.0,
)
FRAGMENTS = TARGET.fragments
# unary ops (sigmoid) legitimately run with vec_b at its reset value, and
# sigmoid inputs are squashed well inside the block-scaled wrap point
TARGET.declare_lint(input_range=(-4.0, 4.0), reset_valid=("vec_b",))

vecunit.state("vec_a", lambda: jnp.zeros((_WORDS, V), jnp.float32))
vecunit.state("vec_b", lambda: jnp.zeros((_WORDS, V), jnp.float32))
vecunit.state("vec_out", lambda: jnp.zeros((_WORDS, V), jnp.float32))
for reg in ("mode", "n_rows", "n_cols", "exp_a", "exp_b", "exp_o"):
    vecunit.state(reg, (lambda: jnp.zeros((), jnp.float32)))


def _wr(buf):
    def update(st, addr, data):
        st = dict(st)
        st[buf] = jax.lax.dynamic_update_slice(st[buf], data[None, :], (addr, 0))
        return st

    return update


vecunit.instruction("wr_a", WR_A)(_wr("vec_a"))
vecunit.instruction("wr_b", WR_B)(_wr("vec_b"))


def _cfg(names):
    def update(st, addr, data):
        st = dict(st)
        for i, n in enumerate(names):
            st[n] = data[i]
        return st

    return update


vecunit.instruction("cfg", CFG)(_cfg(["mode", "n_rows", "n_cols"]))
vecunit.instruction("cfg_num", CFG_NUM)(_cfg(["exp_a", "exp_b", "exp_o"]))


def _q16(x, exp):
    """int16 block fixed point: round onto the 2^exp grid, saturate."""
    scale = jnp.exp2(exp)
    return jnp.clip(jnp.round(x / scale), -QMAX, QMAX) * scale


@vecunit.instruction("ew_start", EW_START, "run the configured element-wise fn")
def _ew_start(st, addr, data):
    A = st["vec_a"].reshape(MAX_ROWS, MAX_COLS)
    B = st["vec_b"].reshape(MAX_ROWS, MAX_COLS)
    mr = (jnp.arange(MAX_ROWS) < st["n_rows"]).astype(jnp.float32)
    mc = (jnp.arange(MAX_COLS) < st["n_cols"]).astype(jnp.float32)
    mask = mr[:, None] * mc[None, :]
    Aq = _q16(A, st["exp_a"]) * mask
    Bq = _q16(B, st["exp_b"]) * mask
    Y = jax.lax.switch(
        jnp.clip(st["mode"].astype(jnp.int32) - 1, 0, 1),
        [
            lambda ab: ab[0] * ab[1],
            lambda ab: 1.0 / (1.0 + jnp.exp(-ab[0])),
        ],
        (Aq, Bq),
    )
    Y = _q16(Y, st["exp_o"]) * mask
    st = dict(st)
    st["vec_out"] = Y.reshape(_WORDS, V)
    return st


# --------------------------------------------------------------------------
# Driver-side fragment builder (setup/data split; setup is empty — the whole
# invocation is a data stream, like VTA's vector-ALU fragments)
# --------------------------------------------------------------------------


def _exp_of(x: np.ndarray) -> float:
    """Driver-chosen power-of-two scale: amax representable on the grid."""
    amax = float(np.abs(x).max()) if x.size else 0.0
    if amax <= 0.0:
        return 0.0
    return float(np.ceil(np.log2(amax / QMAX)))


def _rows_of(x2: np.ndarray) -> np.ndarray:
    """(R, C) block -> V-lane word rows, zero-padded to the buffer layout."""
    R = x2.shape[0]
    buf = np.zeros((R, MAX_COLS), np.float32)
    buf[:, : x2.shape[1]] = x2
    return buf.reshape(R * (MAX_COLS // V), V)


def ew_fragment(kind: str, cache: bool = True) -> CompiledFragment:
    """No stationary operand: the setup stream is empty; the fragment exists
    to cache/batch same-kind invocations through one compiled runner."""
    assert kind in ("mul", "sigmoid")
    key = ("veu_ew", kind)

    def build():
        mode = MODE_MUL if kind == "mul" else MODE_SIGMOID
        return CompiledFragment(vecunit, key, PackedStream.empty(V), meta={"mode": mode})

    return FRAGMENTS.get(key, build) if cache else build()


def _tail(entries) -> PackedStream:
    n = len(entries)
    ops = np.array([e[0] for e in entries], np.int32)
    addrs = np.zeros((n,), np.int32)
    data = np.zeros((n, V), np.float32)
    for i, (_, vals) in enumerate(entries):
        vals = np.asarray(vals, np.float32)
        data[i, : len(vals)] = vals
    return PackedStream(ops, addrs, data)


def pack_ew_data(
    frag: CompiledFragment, a2: np.ndarray, b2: Optional[np.ndarray] = None
) -> DataStream:
    """Data stream for one (R, C) chunk: operand rows + geometry/scale
    config + trigger. The driver sizes the output scale from the ideal fp32
    result, as the FlexASR driver sizes AF exponent windows."""
    a2 = np.asarray(a2, np.float32)
    R, C = a2.shape
    assert R <= MAX_ROWS and C <= MAX_COLS
    ea = _exp_of(a2)
    bulk = [BulkWrite("vec_a", 0, _rows_of(a2), WR_A)]
    if frag.meta["mode"] == MODE_MUL:
        b2 = np.asarray(b2, np.float32)
        assert b2.shape == a2.shape
        eb = _exp_of(b2)
        eo = _exp_of(a2 * b2)
        bulk.append(BulkWrite("vec_b", 0, _rows_of(b2), WR_B))
    else:
        eb = 0.0
        eo = float(np.ceil(np.log2(1.0 / QMAX)))   # sigmoid range (0, 1)
    tail = _tail(
        [
            (CFG, (frag.meta["mode"], R, C)),
            (CFG_NUM, (ea, eb, eo)),
            (EW_START, ()),
        ]
    )
    return DataStream(bulk, tail)


def read_full(st) -> jnp.ndarray:
    """Vmap-safe fixed-shape read of the whole output block."""
    return st["vec_out"].reshape(MAX_ROWS, MAX_COLS)


def build_ew_fragment(kind: str, a: np.ndarray, b: Optional[np.ndarray] = None):
    """One-shot builder (eager parity / VT cases): commands + read-out."""
    a2 = np.asarray(a, np.float32).reshape(-1, a.shape[-1]) if np.ndim(a) > 1 \
        else np.asarray(a, np.float32).reshape(1, -1)
    b2 = None if b is None else np.asarray(b, np.float32).reshape(a2.shape)
    R, C = a2.shape
    frag = ew_fragment(kind)
    cmds = frag.full_commands(pack_ew_data(frag, a2, b2))
    return cmds, lambda st: read_full(st)[:R, :C]


# --------------------------------------------------------------------------
# IR -> intrinsic rewrites + planner
# --------------------------------------------------------------------------


def _same_shape_guard(eg, cid, s):
    # element-wise only: no broadcasting semantics on the device
    return shape_of(eg, s["a"]) == shape_of(eg, s["b"])


def _rewrites():
    return [
        Rewrite(
            "veu-mul",
            P("mul", PV("a"), PV("b")),
            P("veu_mul", PV("a"), PV("b")),
            guard=_same_shape_guard,
        ),
        Rewrite(
            "veu-sigmoid",
            P("sigmoid", PV("x")),
            P("veu_sigmoid", PV("x")),
        ),
    ]


def plan_ew(ctx, x, args, kind):
    """Flatten the (arbitrary-rank) tensor into MAX_COLS-wide rows and chunk
    by MAX_ROWS — element-wise ops are fully driver-chunkable. Operands are
    host-broadcast first (the rewrite guard only admits equal shapes, but
    the intrinsic's declared semantics allow broadcasting)."""
    shape = np.broadcast_shapes(*[np.shape(t) for t in args])
    args = [np.broadcast_to(np.asarray(t, np.float32), shape) for t in args]
    a = args[0]
    ideal = a * args[1] if kind == "mul" else 1.0 / (1.0 + np.exp(-a))
    n = a.size
    R_total = max(1, -(-n // MAX_COLS))
    padded = [np.zeros((R_total * MAX_COLS,), np.float32) for _ in args]
    for buf, t in zip(padded, args):
        buf[:n] = np.asarray(t, np.float32).ravel()
    blocks = [buf.reshape(R_total, MAX_COLS) for buf in padded]
    frag = ew_fragment(kind)
    jobs = []
    for r0 in range(0, R_total, MAX_ROWS):
        chunk = [blk[r0 : r0 + MAX_ROWS] for blk in blocks]
        jobs.append(
            SimJob(frag, pack_ew_data(frag, *chunk), read_full,
                   (slice(0, chunk[0].shape[0]), slice(0, MAX_COLS)))
        )

    def assemble(outs):
        out = np.concatenate(outs, axis=0).ravel()[:n].reshape(a.shape)
        ctx.record(f"veu_{kind}", "vecunit", out, ideal, ctx.ncmds(jobs))
        return out.astype(np.float32)

    return jobs, assemble


# --------------------------------------------------------------------------
# IR semantics (shape + ideal oracle) and validation declarations
# --------------------------------------------------------------------------


def _shape_mul(attrs, child_shapes):
    return tuple(np.broadcast_shapes(child_shapes[0], child_shapes[1]))


def _shape_unary(attrs, child_shapes):
    return tuple(child_shapes[0])


def _ideal_mul(attrs, args):
    return args[0] * args[1]


def _ideal_sigmoid(attrs, args):
    return 1.0 / (1.0 + jnp.exp(-args[0]))


def _sample_mul(r):
    if int(r.integers(2)):
        shape = (1, int(r.integers(2, 7)), int(r.integers(2, 7)), int(r.integers(1, 9)))
    else:
        shape = (int(r.integers(1, 30)), int(r.integers(1, 30)))
    return [
        r.standard_normal(shape).astype(np.float32),
        r.standard_normal(shape).astype(np.float32),
    ], {}


def _sample_sigmoid(r):
    shape = (int(r.integers(1, 30)), int(r.integers(1, 30)))
    return [(r.standard_normal(shape) * 2).astype(np.float32)], {}


def _vt2(dim_t, dim_d):
    a = ir.Var("a", (dim_t, dim_d))
    b = ir.Var("b", (dim_t, dim_d))
    return [
        VT2Case("ew-mul", ir.call("mul", a, b), ir.call("veu_mul", a, b),
                {"a": (dim_t, dim_d), "b": (dim_t, dim_d)}),
        VT2Case("ew-sigmoid", ir.call("sigmoid", a), ir.call("veu_sigmoid", a),
                {"a": (dim_t, dim_d)}),
    ]


def _mapping_cases(rng):
    def mul_case():
        a = rng.standard_normal((16, 48)).astype(np.float32)
        b = rng.standard_normal((16, 48)).astype(np.float32)
        cmds, rd = build_ew_fragment("mul", a, b)
        return a * b, rd(vecunit.simulate(cmds))

    def sigmoid_case():
        a = (rng.standard_normal((16, 48)) * 2).astype(np.float32)
        cmds, rd = build_ew_fragment("sigmoid", a)
        return 1.0 / (1.0 + np.exp(-a)), rd(vecunit.simulate(cmds))

    return [("EwMul", mul_case), ("Sigmoid", sigmoid_case)]


# Cost model: operand row streams + config tail per chunk; the 16-lane ALU
# retires V elements per cycle (sigmoid takes a few iterations per element).
COSTS = CostModel("vecunit", cycles_per_command=1.0)


def _cost_ew(n_operands):
    def cost(attrs, shapes):
        n = int(np.prod(np.broadcast_shapes(*shapes))) if shapes else 1
        rows = max(1, -(-n // MAX_COLS))
        chunks = -(-rows // MAX_ROWS)
        words = rows * (MAX_COLS // V)
        lanes = 1.0 if n_operands == 2 else 4.0   # sigmoid iterates per element
        return n_operands * words + 3 * chunks, 4 * (n_operands + 1) * n, lanes * n / V

    return cost


COSTS.op("veu_mul")(_cost_ew(2))
COSTS.op("veu_sigmoid")(_cost_ew(1))


TARGET.add_intrinsic(Intrinsic(
    "veu_mul", planner=lambda ctx, x, a: plan_ew(ctx, x, a, "mul"),
    shape=_shape_mul, ideal=_ideal_mul, sample=_sample_mul, tol=1e-3,
    doc="element-wise product in int16 block fixed point"))
TARGET.add_intrinsic(Intrinsic(
    "veu_sigmoid", planner=lambda ctx, x, a: plan_ew(ctx, x, a, "sigmoid"),
    shape=_shape_unary, ideal=_ideal_sigmoid, sample=_sample_sigmoid, tol=1e-3,
    doc="element-wise logistic sigmoid"))
TARGET.add_rewrites(_rewrites)
TARGET.add_cost_model(COSTS)
TARGET.add_vt2_cases(_vt2)
TARGET.add_mapping_cases(_mapping_cases)
register_target(TARGET)
