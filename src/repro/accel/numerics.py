"""Custom accelerator numerics, bit-accurate in JAX.

* **AdaptivFloat** (Tambe et al., DAC'20) — FlexASR's datatype: an n-bit
  float whose exponent range is shifted per-tensor by an integer bias chosen
  from the tensor's max magnitude. We implement quantization exactly:
  normalized mantissa rounded to m bits, exponent clamped to the 2^e window,
  values below the smallest normal flushed to zero, saturation at the top.

* **Fixed point** — HLSCNN's 8/16-bit two's-complement fixed point with a
  static number of fraction bits. The paper's ResNet-20 accuracy collapse
  came from 8-bit weight quantization; the "updated design" widens to 16.

* **int8 symmetric** — VTA's integer GEMM path (scale = amax/127).

All quantizers are ``quantize -> dequantize`` (fake-quant) so downstream
compute can run in fp32 while matching the accelerator's representable set.
They are jit-able and differentiable-through via straight-through estimators
(used when the framework trains quantization-aware).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# AdaptivFloat
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdaptivFloatSpec:
    n_bits: int = 8
    n_exp: int = 3  # exponent field width; mantissa = n_bits - 1 - n_exp

    @property
    def n_man(self) -> int:
        return self.n_bits - 1 - self.n_exp


def af_exp_bias(x: jnp.ndarray, spec: AdaptivFloatSpec) -> jnp.ndarray:
    """Per-tensor exponent bias: align the max representable exponent with
    the tensor's max magnitude (AdaptivFloat Algorithm 1)."""
    amax = jnp.max(jnp.abs(x))
    amax = jnp.where(amax == 0, 1.0, amax)
    e_max_target = jnp.floor(jnp.log2(amax))
    return e_max_target - (2 ** spec.n_exp - 1)


def af_quantize(
    x: jnp.ndarray, spec: AdaptivFloatSpec = AdaptivFloatSpec(), exp_bias=None
) -> jnp.ndarray:
    """Round ``x`` to the nearest AdaptivFloat-representable value."""
    if exp_bias is None:
        exp_bias = af_exp_bias(x, spec)
    m = spec.n_man
    e_lo = exp_bias                       # smallest normal exponent
    e_hi = exp_bias + (2 ** spec.n_exp - 1)  # largest exponent
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    # exponent of each value, clamped into the representable window
    safe = jnp.where(ax > 0, ax, 1.0)
    e = jnp.clip(jnp.floor(jnp.log2(safe)), e_lo, e_hi)
    scale = jnp.exp2(e)
    # mantissa in [1, 2): round to m bits
    man = jnp.clip(ax / scale, 1.0, 2.0 - 2.0 ** (-m))
    man_q = jnp.round(man * 2.0 ** m) / 2.0 ** m
    # rounding can push mantissa to 2.0 -> bump exponent (saturating)
    bump = man_q >= 2.0
    e2 = jnp.clip(e + bump, e_lo, e_hi)
    man_q = jnp.where(bump & (e2 > e), 1.0, jnp.minimum(man_q, 2.0 - 2.0 ** (-m)))
    q = man_q * jnp.exp2(e2)
    # saturate above the max normal; flush-to-zero below half the min normal
    vmax = (2.0 - 2.0 ** (-m)) * jnp.exp2(e_hi)
    vmin = jnp.exp2(e_lo)
    q = jnp.minimum(q, vmax)
    q = jnp.where(ax < vmin * 0.5, 0.0, q)
    return (sign * q).astype(x.dtype)


def af_ste(x, spec: AdaptivFloatSpec = AdaptivFloatSpec()):
    """Straight-through-estimator fake quant (identity gradient)."""
    return x + jax.lax.stop_gradient(af_quantize(x, spec) - x)


# --------------------------------------------------------------------------
# Fixed point
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FixedPointSpec:
    n_bits: int = 8
    n_frac: int = 6

    @property
    def scale(self) -> float:
        return float(2 ** self.n_frac)

    @property
    def qmin(self) -> int:
        return -(2 ** (self.n_bits - 1))

    @property
    def qmax(self) -> int:
        return 2 ** (self.n_bits - 1) - 1


# The paper's case study: HLSCNN originally used 8-bit fixed point for
# weights, sized for a wide *value range* (integer headroom) — which leaves
# few fraction bits, heavily quantizing the small-magnitude conv weights of
# trained nets (ResNet-20: 91.55% -> 29.15%). The developers' fix widened
# the datatype to 16 bits (same range, 8 more fraction bits), recovering
# 91.85%. We model exactly that: both specs cover +/-16; the original has a
# 2^-3 grid, the update a 2^-11 grid.
HLSCNN_WEIGHT_ORIGINAL = FixedPointSpec(n_bits=8, n_frac=3)
HLSCNN_WEIGHT_UPDATED = FixedPointSpec(n_bits=16, n_frac=11)
HLSCNN_ACT = FixedPointSpec(n_bits=16, n_frac=8)


def fx_quantize_int(x: jnp.ndarray, spec: FixedPointSpec) -> jnp.ndarray:
    """To the integer (two's complement) representation."""
    q = jnp.round(x * spec.scale)
    return jnp.clip(q, spec.qmin, spec.qmax).astype(jnp.int32)


def fx_dequantize(q: jnp.ndarray, spec: FixedPointSpec) -> jnp.ndarray:
    return q.astype(jnp.float32) / spec.scale


def fx_quantize(x: jnp.ndarray, spec: FixedPointSpec) -> jnp.ndarray:
    """Fake quant: round to the fixed-point lattice."""
    return fx_dequantize(fx_quantize_int(x, spec), spec)


# --------------------------------------------------------------------------
# int8 symmetric (VTA)
# --------------------------------------------------------------------------


def int8_scale(x: jnp.ndarray) -> jnp.ndarray:
    amax = jnp.max(jnp.abs(x))
    return jnp.where(amax == 0, 1.0, amax / 127.0)


def int8_quantize(x: jnp.ndarray, scale=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if scale is None:
        scale = int8_scale(x)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


# --------------------------------------------------------------------------
# Saturation points and rounding grids (shared by the fault library and the
# static range-analysis pass)
# --------------------------------------------------------------------------

# Block-scaled formats (AdaptivFloat, block-fp) renormalize per tensor, so
# their *absolute* overflow point depends on the data, not the spec. 4.5 is
# the modeling constant the fault library uses for the rare-overflow tail of
# unit-scale activations: values beyond it fall outside the window a
# per-block exponent chosen for |x| <~ 1 data can still represent.
BLOCK_SCALED_SAT = 4.5


def fixed_saturation(spec: FixedPointSpec) -> float:
    """Largest representable magnitude (up to one LSB) of a fixed-point
    format: 2^(integer bits)."""
    return float(2.0 ** (spec.n_bits - 1 - spec.n_frac))


def saturation_point(numerics: str) -> float:
    """Absolute saturation/wrap threshold for a target's declared numerics
    string (``AcceleratorTarget.capabilities["numerics"]``)."""
    if numerics.startswith(("fixed", "int8")):
        return fixed_saturation(HLSCNN_ACT)
    return BLOCK_SCALED_SAT


def rounding_grid(numerics: str) -> Optional[float]:
    """Quantization grid spacing near zero for a numerics family, or None
    when the family has no static grid (pure-integer paths rescale
    per-tensor, so a fixed grid is meaningless)."""
    if numerics.startswith("int8"):
        return None
    if numerics.startswith("fixed"):
        return 1.0 / HLSCNN_ACT.scale
    # block-scaled: one mantissa step below the unit binade
    return float(2.0 ** -(AdaptivFloatSpec().n_man + 1))
