"""The ``AcceleratorTarget`` plugin API.

The paper's thesis is that the ILA, as a formal software/hardware interface,
makes compiler + simulator support for a *new prototype accelerator* mostly
derivable: write the ILA and the IR-accelerator mappings, and flexible
matching, code generation and application-level validation come for free.
This module is that thesis as an API: one object per accelerator owning

* its :class:`~repro.core.ila.ILA` model and per-target fragment cache,
* its IR -> intrinsic rewrites (pattern + guard + target attribution),
* its intrinsic **planners** (op -> ``SimJob`` list + assemble fn, with the
  setup/data-stream split and driver chunking),
* its numerics/ideal reference hooks (shape + fp32-oracle semantics fed to
  the IR layer) and optional deployment kernels,
* its :class:`CostModel` — per-intrinsic analytic costs (interface command
  count, bytes moved, estimated cycles) derived from operand shapes, which
  drive cost-based extraction and the Executor's multi-device scheduler,
* its VT1–VT3 validation declarations (conformance samples, VT2 fragment
  pairs, VT3 ILA-vs-kernel checks, Table-2 mapping cases).

Registering the target (:func:`register_target`) wires all of it into the
registry-driven core: ``rules.accelerator_rewrites`` /
``compile.compile_program`` enumerate targets, ``codegen.Executor``
dispatches planning through the registry, ``validate`` runs whatever each
target declares, and the conformance suite (``tests/test_target_conformance``)
covers every declared intrinsic — a fourth backend needs zero edits to
``core/`` (see ``docs/targets.md`` for a worked example).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import ir
from ..core.egraph import Rewrite
from ..core.ila import (
    ILA,
    TARGETS,
    CompiledFragment,
    DataStream,
    FragmentCache,
    FusedRunner,
    fused_lowering,
)


@dataclasses.dataclass
class SimJob:
    """One fragment invocation: a data stream to run against a compiled
    fragment, a vmap-safe full-region read, and the valid output window."""

    frag: CompiledFragment
    data: DataStream
    read: Callable
    window: Tuple


@dataclasses.dataclass
class PlanContext:
    """What the Executor hands a planner: stat recording + per-target
    execution options (e.g. ``{"wgt_bits": 16}`` for HLSCNN's updated
    design), plus the driver-tiling helpers planners share."""

    record: Callable[..., None]
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @staticmethod
    def chunk_rows(x: np.ndarray, max_rows: int) -> List[np.ndarray]:
        return [x[i : i + max_rows] for i in range(0, x.shape[0], max_rows)]

    @staticmethod
    def ncmds(jobs: Sequence[SimJob]) -> int:
        return sum(len(j.frag.setup) + len(j.data) for j in jobs)

    @staticmethod
    def data_ncmds(jobs: Sequence[SimJob]) -> int:
        """Per-invocation (steady-state) command count: the data streams
        only, excluding the cached setup load. This is the volume the
        pipelined engine's pack and sim stages both scale with, and what
        :class:`GroupTiming` records for latency calibration."""
        return sum(len(j.data) for j in jobs)


@dataclasses.dataclass
class GroupTiming:
    """Measured wall-clock of one scheduled SimJob group, recorded by the
    Executor: ``pack_s`` is the host stage (planner packing, vectorized
    numpy), ``sim_s`` the dispatch-to-materialization stage (a synchronous
    engine times it exactly; the pipelined engine leaves it 0 because sims
    overlap). ``CostModel.calibrate_from_timings`` fits per-stage latency
    models from these."""

    target: str
    n_jobs: int
    n_commands: int
    pack_s: float = 0.0
    sim_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Predicted cost of one intrinsic invocation (or one SimJob batch).

    ``commands``     interface commands issued (MMIO writes), after any
                     per-op calibration scale;
    ``bytes_moved``  host<->device traffic in bytes;
    ``cycles``       estimated device cycles (command issue + compute);
    ``raw_commands`` the uncalibrated analytic command prediction —
                     what ``CostModel.calibrate`` fits against, so repeated
                     calibration converges regardless of the scale in
                     effect when the estimate was recorded.
    """

    commands: float
    bytes_moved: float
    cycles: float
    raw_commands: float = 0.0

    def __add__(self, other: "CostEstimate") -> "CostEstimate":
        return CostEstimate(
            self.commands + other.commands,
            self.bytes_moved + other.bytes_moved,
            self.cycles + other.cycles,
            self.raw_commands + other.raw_commands,
        )


class CostModel:
    """A target's declared analytic cost model, one pricing rule per
    intrinsic: ``fn(attrs, child_shapes) -> (commands, bytes_moved,
    compute_cycles)``. ILA models every accelerator through one uniform
    command interface, so cost decomposes uniformly too:

        cycles = cycles_per_command * commands + compute_cycles

    ``commands`` is the analytically predicted interface command count for
    the shapes at hand; :meth:`calibrate` fits a per-op correction from the
    *observed* command counts the Executor records (``Executor.stats``), so
    the analytic model converges on what the planners actually emit.
    Extraction (``core/compile.make_cost_fn``) and the Executor's device
    scheduler consume :meth:`estimate` / :meth:`job_cycles`.
    """

    def __init__(self, target: str, cycles_per_command: float = 1.0):
        self.target = target
        self.cycles_per_command = float(cycles_per_command)
        self._ops: Dict[str, Callable] = {}
        #: per-op multiplicative correction on the predicted command count,
        #: fitted by :meth:`calibrate` (1.0 = uncalibrated analytic model)
        self.command_scale: Dict[str, float] = {}
        #: wall-clock latency model fitted by :meth:`calibrate_from_timings`
        #: (empty = uncalibrated; keys: ``{pack,sim}_us_per_command``,
        #: ``{pack,sim}_overhead_us``, ``n_groups``). Once fitted, one
        #: "cycle" of this model means one microsecond of measured latency.
        self.latency: Dict[str, float] = {}
        #: streaming predicted-vs-actual drift accumulators fed by
        #: :meth:`record_drift` (count / log-ratio sums / extremes)
        self._drift = [0, 0.0, 0.0, float("inf"), float("-inf")]

    def record_drift(self, predicted_cycles: float, actual_us: float) -> None:
        """One drift observation: the scheduler priced a group at
        ``predicted_cycles`` and its simulation measured ``actual_us``.
        On a latency-calibrated model (1 cycle == 1 us) the ratio
        ``actual / predicted`` is the mispricing factor the admission
        controller and LPT placement are operating under; before
        calibration it is the analytic-to-wall-clock conversion. Ratios
        accumulate in log space so over- and under-prediction average
        symmetrically."""
        if predicted_cycles <= 0 or actual_us <= 0:
            return
        r = float(actual_us) / float(predicted_cycles)
        lr = math.log(r)
        d = self._drift
        d[0] += 1
        d[1] += lr
        d[2] += lr * lr
        d[3] = min(d[3], r)
        d[4] = max(d[4], r)

    def drift_summary(self) -> Optional[Dict[str, float]]:
        """Aggregate predicted-vs-actual drift: geometric-mean ratio of
        actual microseconds to predicted cycles, its log-space spread, and
        the extremes. None until :meth:`record_drift` has observations.
        A calibrated model tracking reality sits near ``ratio_geomean``
        1.0; a drifting one is the signal to re-run
        ``calibrate_from_timings``."""
        n, s, s2, lo, hi = self._drift
        if n == 0:
            return None
        mean = s / n
        var = max(0.0, s2 / n - mean * mean)
        return {
            "n": float(n),
            "ratio_geomean": math.exp(mean),
            "log_ratio_std": math.sqrt(var),
            "ratio_min": lo,
            "ratio_max": hi,
            "calibrated": 1.0 if self.latency else 0.0,
        }

    def reset_drift(self) -> None:
        self._drift = [0, 0.0, 0.0, float("inf"), float("-inf")]

    def op(self, name: str):
        """Decorator registering the pricing rule for intrinsic ``name``."""

        def deco(fn):
            self._ops[name] = fn
            return fn

        return deco

    def covers(self, op: str) -> bool:
        return op in self._ops

    def ops(self) -> List[str]:
        return list(self._ops)

    def estimate(self, op: str, attrs, child_shapes) -> CostEstimate:
        """Price one invocation of ``op`` on operands of ``child_shapes``."""
        fn = self._ops[op]
        raw, nbytes, compute = fn(
            dict(attrs or {}), [tuple(s) for s in child_shapes]
        )
        commands = float(raw) * self.command_scale.get(op, 1.0)
        cycles = self.cycles_per_command * commands + float(compute)
        return CostEstimate(commands, float(nbytes), cycles, float(raw))

    def job_cycles(self, n_commands: float, pipelined: bool = False) -> float:
        """Scheduler estimate for a SimJob batch of ``n_commands`` interface
        commands (the compute term is already proportional to the data
        stream for every bundled fragment, so commands dominate ranking).

        With a fitted :attr:`latency` model the estimate is measured
        microseconds. ``pipelined=True`` prices the group for a pipelined
        engine, where host packing overlaps device simulation: the group
        occupies the pipeline for ``max(pack, sim)`` rather than their sum
        (sum without overlap). Uncalibrated models have no pack term, so
        both forms reduce to the analytic ``cycles_per_command * n``.
        """
        n = float(n_commands)
        if self.latency:
            sim = (
                self.latency.get("sim_us_per_command", self.cycles_per_command) * n
                + self.latency.get("sim_overhead_us", 0.0)
            )
            pack = (
                self.latency.get("pack_us_per_command", 0.0) * n
                + self.latency.get("pack_overhead_us", 0.0)
            )
            return max(pack, sim) if pipelined else pack + sim
        return self.cycles_per_command * n

    def calibrate_from_timings(self, timings) -> Dict[str, float]:
        """Fit the wall-clock latency model from measured per-group timings
        (:class:`GroupTiming`, recorded in ``Executor.stats``-side logs).

        Each stage (host pack, device sim) is fitted as an affine model
        ``seconds ~= overhead + s_per_command * n_commands`` by least
        squares over this target's groups; negative slopes/intercepts from
        degenerate samples are clamped to a through-origin ratio fit. The
        fit lives in :attr:`latency` — the measured-latency replacement for
        the analytic per-command cost — and ``job_cycles`` switches to it
        (in microseconds: **1 cycle == 1 us** once fitted), so the
        scheduler ranks groups by measured latency (the ROADMAP's learned
        cost-model step) and the pipelined scheduler prices groups as
        ``max(pack, sim)``. :attr:`cycles_per_command` itself is left in
        analytic units on purpose: ``estimate()`` feeds *extraction*, which
        compares costs across targets, and rescaling one target's cycles to
        microseconds while competitors stay analytic would make those
        comparisons incommensurate. Returns the fitted model (empty if this
        target has no usable timings yet).
        """

        def affine(pts: List[Tuple[float, float]]) -> Optional[Tuple[float, float]]:
            if not pts:
                return None
            xs = np.asarray([p[0] for p in pts], np.float64)
            ys = np.asarray([p[1] for p in pts], np.float64)
            if len(pts) >= 2 and float(np.ptp(xs)) > 0:
                slope, intercept = np.polyfit(xs, ys, 1)
                if slope > 0 and intercept >= 0:
                    return float(slope), float(intercept)
            return float(ys.sum() / xs.sum()), 0.0

        sims, packs = [], []
        for t in timings:
            if t.target != self.target or t.n_commands <= 0:
                continue
            if t.sim_s > 0:
                sims.append((float(t.n_commands), t.sim_s))
            if t.pack_s > 0:
                packs.append((float(t.n_commands), t.pack_s))
        sim_fit, pack_fit = affine(sims), affine(packs)
        if sim_fit is not None:
            self.latency["sim_us_per_command"] = sim_fit[0] * 1e6
            self.latency["sim_overhead_us"] = sim_fit[1] * 1e6
        if pack_fit is not None:
            self.latency["pack_us_per_command"] = pack_fit[0] * 1e6
            self.latency["pack_overhead_us"] = pack_fit[1] * 1e6
        if sim_fit is not None or pack_fit is not None:
            self.latency["n_groups"] = float(len(sims) + len(packs))
            # pricing just changed: drift observed under the old model no
            # longer measures this model's error
            self.reset_drift()
        return dict(self.latency)

    def calibrate(self, stats) -> Dict[str, float]:
        """Fit per-op command-count scales from ``Executor.stats``.

        Each :class:`~repro.core.codegen.InvocationStat` carries the
        analytic prediction made at plan time (``stat.est``) and the
        observed interface command count (``stat.n_commands``); the fit is
        the per-op ratio of total observed to total predicted commands
        (so invocations weigh in proportion to their command volume),
        against the *raw* (uncalibrated) predictions — re-calibrating over
        stats recorded under any mix of earlier scales converges instead
        of compounding. Invocations that issued no interface commands
        (deployment-kernel fast paths record ``n_commands == 0``) are
        skipped: they observed nothing to fit against. Returns the fitted
        scales (also stored on the model, so subsequent :meth:`estimate`
        calls are calibrated).
        """
        pred: Dict[str, float] = {}
        obs: Dict[str, float] = {}
        for s in stats:
            if (
                getattr(s, "est", None) is None
                or not self.covers(s.op)
                or s.n_commands <= 0
            ):
                continue
            pred[s.op] = pred.get(s.op, 0.0) + s.est.raw_commands
            obs[s.op] = obs.get(s.op, 0.0) + float(s.n_commands)
        for op, p in pred.items():
            if p > 0:
                self.command_scale[op] = obs[op] / p
        return dict(self.command_scale)


@dataclasses.dataclass
class VT2Case:
    """A compiler-IR fragment and its accelerator fragment, as IR exprs over
    shared Vars — both interpreted with ideal (abstract-datatype) semantics
    for the VT2 equivalence checks (random + exhaustive finite-domain).

    ``tol`` is the rel-Frobenius bound for the random-simulation check.
    Cases may declare it explicitly; left as None it is stamped with the
    owning target's :attr:`AcceleratorTarget.vt2_tol` when the case is
    enumerated — so a backend whose two fragments are the *same* fp32
    expression declares 0.0 (bit-exact, no silent over-tolerance) while
    one whose fragments take different-but-equivalent compute paths keeps
    a small float slack.
    """

    name: str
    ir_fragment: ir.Expr
    accel_fragment: ir.Expr
    var_shapes: Dict[str, Tuple[int, ...]]
    tol: Optional[float] = None


@dataclasses.dataclass
class Intrinsic:
    """One accelerator intrinsic op, as the target declares it.

    planner      (ctx, call, args) -> (List[SimJob], assemble) — the ILA
                 co-simulation path (None for pass-through markers).
    kernel       optional deployment fast path (ctx, call, args) -> array.
    passthrough  data-movement marker (store/load): executes as identity and
                 is not counted as an invocation.
    shape/ideal  IR extension hooks: shape(attrs, child_shapes) -> shape and
                 ideal(attrs, args) -> array. None for ops the IR already
                 understands (the bundled vocabulary).
    sample       conformance-case generator: (rng) -> (args, attrs) drawing
                 random operands *within the declared capability limits*.
    tol          rel-Frobenius bound for ideal-vs-numerics conformance.
    options      recommended Executor target-options for conformance runs.
    """

    op: str
    planner: Optional[Callable] = None
    kernel: Optional[Callable] = None
    passthrough: bool = False
    shape: Optional[Callable] = None
    ideal: Optional[Callable] = None
    sample: Optional[Callable] = None
    tol: float = 0.05
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)
    doc: str = ""


@dataclasses.dataclass(frozen=True)
class LintDecl:
    """Static-analysis declarations for one target (``declare_lint``).

    input_range    inclusive (lo, hi) interval of operand values the
                   target's applications are expected to feed it; drives
                   the numeric range pass (None = range pass reports
                   nothing).
    carried_state  state buffers intentionally carried across fragment
                   boundaries (recurrent state) — reported at info level
                   as the ``stale_state`` fault surface instead of warned
                   about.
    reset_valid    config registers whose reset value is a legal operating
                   point (mode-dependent configs a valid stream may never
                   write) — exempt from uninitialized-read warnings.
    """

    input_range: Optional[Tuple[float, float]] = None
    carried_state: Tuple[str, ...] = ()
    reset_valid: Tuple[str, ...] = ()


class AcceleratorTarget:
    """One pluggable accelerator backend; see the module docstring."""

    def __init__(
        self,
        name: str,
        ila: ILA,
        display_name: Optional[str] = None,
        capabilities: Optional[Dict[str, Any]] = None,
        doc: str = "",
        vt2_tol: float = 1e-5,
    ):
        self.name = name
        self.ila = ila
        self.display_name = display_name or name
        self.capabilities = dict(capabilities or {})
        self.doc = doc
        #: rel-Frobenius tolerance for this target's VT2 random-simulation
        #: checks over abstract (fp32) semantics — part of the numerics
        #: declaration: 0.0 where both fragment sides evaluate the same
        #: fp32 expression, a small slack where the compute paths differ
        self.vt2_tol = float(vt2_tol)
        self.intrinsics: Dict[str, Intrinsic] = {}
        #: declared analytic cost model (None until ``add_cost_model``)
        self.cost_model: Optional[CostModel] = None
        #: per-target LRU of CompiledFragments (setup streams + cached state)
        self.fragments = FragmentCache()
        self._rewrite_fns: List[Callable[[], List[Rewrite]]] = []
        self._vt2_fns: List[Callable[..., List[VT2Case]]] = []
        #: name -> fn() -> (ok: bool, worst_abs_dev: float); ILA vs impl (VT3)
        self.vt3_checks: Dict[str, Callable[[], Tuple[bool, float]]] = {}
        self._mapping_fns: List[Callable] = []
        #: static-analysis declarations consumed by ``core.ilalint``
        self.lint = LintDecl()
        #: fused fast-path factories (``declare_fused``) + per-fragment
        #: resolution memo, keyed by (frag.key, active lowering)
        self._fused_fns: List[Callable[[CompiledFragment], Optional[FusedRunner]]] = []
        self._fused_cache: Dict[Tuple, Optional[FusedRunner]] = {}

    # -- declaration ------------------------------------------------------
    def declare_lint(self, **kw) -> "LintDecl":
        """Declare static-analysis facts the lint passes cannot infer from
        the ILA alone: the operand value range applications feed this
        target (``input_range``), state buffers intentionally carried
        across fragments (``carried_state``), and config registers whose
        reset value is a valid operating point (``reset_valid`` — silences
        uninitialized-read warnings for mode-dependent configs)."""
        self.lint = dataclasses.replace(self.lint, **kw)
        return self.lint
    def add_intrinsic(self, intr: Intrinsic) -> Intrinsic:
        self.intrinsics[intr.op] = intr
        return intr

    def add_cost_model(self, model: CostModel) -> CostModel:
        """Declare this target's cost model. Extraction falls back to a
        uniform accelerator-op cost for targets without one, but the
        conformance suite requires every registered target to price every
        intrinsic it claims."""
        self.cost_model = model
        return model

    def add_rewrites(self, fn: Callable[[], List[Rewrite]]) -> None:
        """Register a thunk producing this target's IR->intrinsic rewrites
        (evaluated lazily so rewrite lists stay cheap to rebuild)."""
        self._rewrite_fns.append(fn)

    def add_vt2_cases(self, fn: Callable[..., List[VT2Case]]) -> None:
        self._vt2_fns.append(fn)

    def add_vt3_check(self, name: str, fn: Callable[[], Tuple[bool, float]]) -> None:
        self.vt3_checks[name] = fn

    def add_mapping_cases(self, fn: Callable) -> None:
        """fn(rng) -> [(operation_label, case_fn)] where case_fn() returns
        (reference, simulated) for one random input (Table 2)."""
        self._mapping_fns.append(fn)

    def declare_fused(
        self, factory: Callable[[CompiledFragment], Optional[FusedRunner]]
    ) -> None:
        """Register a fused fast-path factory: ``factory(frag)`` returns a
        :class:`~repro.core.ila.FusedRunner` for fragment families it can
        lower (consulting :func:`~repro.core.ila.fused_lowering` for the
        Pallas-vs-XLA leg) or ``None`` to decline. The Executor's
        ``engine="fused"`` consults :meth:`fused_runner` per fragment and
        falls back to the compiled tier for undeclared signatures, so a
        target never *needs* to declare one — fusion is a pure
        acceleration, validated against the compiled oracle."""
        self._fused_fns.append(factory)

    def fused_runner(self, frag: CompiledFragment) -> Optional[FusedRunner]:
        """Resolve (and memoize) the fused runner for one compiled
        fragment. The memo key includes the active lowering so flipping
        ``REPRO_FUSED_FALLBACK``/``REPRO_FUSED_PALLAS`` re-resolves.

        Runners are built from the fragment's *golden* build-time meta, not
        from the ILA's instruction semantics — a fragment bound to a mutated
        ILA clone (campaign fault injection) shares the golden key but must
        not take the fast path, or the fault would be masked."""
        if frag.ila is not self.ila:
            return None
        key = (frag.key, fused_lowering())
        if key in self._fused_cache:
            return self._fused_cache[key]
        runner = None
        for fn in self._fused_fns:
            runner = fn(frag)
            if runner is not None:
                break
        self._fused_cache[key] = runner
        return runner

    # -- what the core layers consume -------------------------------------
    def rewrites(self) -> List[Rewrite]:
        out: List[Rewrite] = []
        for fn in self._rewrite_fns:
            out.extend(dataclasses.replace(r, target=self.name) for r in fn())
        return out

    def planner(self, op: str) -> Optional[Callable]:
        intr = self.intrinsics.get(op)
        return intr.planner if intr is not None else None

    def vt2_cases(self, dim_t: int = 16, dim_d: int = 64) -> List[VT2Case]:
        out: List[VT2Case] = []
        for fn in self._vt2_fns:
            for case in fn(dim_t, dim_d):
                if case.tol is None:
                    case = dataclasses.replace(case, tol=self.vt2_tol)
                out.append(case)
        return out

    def cosim_tol(self, ops: Optional[Sequence[str]] = None) -> float:
        """The declared co-simulation tolerance for a fragment touching
        ``ops`` (None = all): the loosest per-intrinsic ideal-vs-numerics
        bound among them. This is what fragment-level *simulation* checks
        (the fault campaign's VT3-analogue tier) may legitimately deviate by
        — derived from the numerics each intrinsic declares, so a
        low-precision backend is neither over- nor under-tolerant."""
        pool = [
            intr.tol
            for op, intr in self.intrinsics.items()
            if intr.planner is not None and (ops is None or op in ops)
        ]
        return max(pool) if pool else 0.05

    def mapping_cases(self, rng) -> List[Tuple[str, Callable]]:
        out: List[Tuple[str, Callable]] = []
        for fn in self._mapping_fns:
            out.extend(fn(rng))
        return out

    def cache_info(self) -> Dict[str, Any]:
        """Warm-cache health for the serving path: fragment-cache hit/miss
        plus the ILA's jit trace / compiled-runner counters."""
        return {
            "fragments": self.fragments.info(),
            "fused_runners": sum(
                1 for v in self._fused_cache.values() if v is not None
            ),
            **self.ila.jit_cache_info(),
        }


def register_target(target: AcceleratorTarget) -> AcceleratorTarget:
    """Register ``target`` with the core: the registry (rewrites, planning,
    validation enumeration) and the IR extension table (shape inference,
    ideal oracle, cost model, invocation attribution)."""
    TARGETS.register(target)
    for intr in target.intrinsics.values():
        ir.register_accel_op(
            intr.op,
            target.name,
            shape_fn=intr.shape,
            eval_fn=intr.ideal,
            counts=not intr.passthrough,
        )
    return target


def unregister_target(target: AcceleratorTarget) -> Dict[str, Any]:
    """Remove ``target`` from the registry and the IR extension table (the
    inverse of :func:`register_target`; used by tests that register
    synthetic targets, and by the fault campaign's mutant lifecycle, both
    of which must leave the process-wide registry bit-identical).

    Returns the removed IR extension specs keyed by op — feed them to
    :func:`repro.core.ir.restore_accel_op` after re-registering the same
    target to reinstate the exact original spec objects (a plain
    ``register_target`` would mint equal-but-new ones, which matters to
    identity-based leak checks)."""
    TARGETS.unregister(target.name)
    return {op: ir.unregister_accel_op(op) for op in target.intrinsics}
