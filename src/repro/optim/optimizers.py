"""Optimizers: AdamW (fp32 state) and Adafactor (factored second moment).

Adafactor exists because the 671B config cannot hold Adam's 2x fp32 state on
one v5e pod (DESIGN.md §5): factored v (row/col statistics, O(m+n) per
matrix) + bf16 momentum cuts optimizer bytes from 8x to ~2x params.

All state trees mirror the param tree, so pjit shards optimizer state with
the same PartitionSpecs as the parameters (ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def tree_map(f, *ts, **kw):
    return jax.tree_util.tree_map(f, *ts, **kw)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def cosine_schedule(step, base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = base_lr * jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": tree_map(f32, params),
        "v": tree_map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    step = state["step"] + 1
    m = tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
    v = tree_map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                 state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, bf16 momentum)
# ---------------------------------------------------------------------------


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def init_v(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),        # row stats
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "m": tree_map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
        "v": tree_map(init_v, params, is_leaf=lambda x: hasattr(x, "shape")),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(params, grads, state, lr, b1=0.9, decay=0.99, eps=1e-30, wd=0.0):
    step = state["step"] + 1

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + eps
        if _factored(p.shape):
            vr = decay * v["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * v["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = (
                vr[..., None]
                / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                * vc[..., None, :]
            )
            u = g32 * jax.lax.rsqrt(denom + eps)
            new_v = {"vr": vr, "vc": vc}
        else:
            vv = decay * v["v"] + (1 - decay) * g2
            u = g32 * jax.lax.rsqrt(vv + eps)
            new_v = {"v": vv}
        # update clipping (Adafactor's RMS-1 rule)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms)
        new_m = (b1 * m.astype(jnp.float32) + (1 - b1) * u).astype(jnp.bfloat16)
        new_p = (p.astype(jnp.float32) - lr * (new_m.astype(jnp.float32) + wd * p.astype(jnp.float32))).astype(p.dtype)
        return new_p, new_m, new_v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable


def make_optimizer(name: str) -> Optimizer:
    if name == "adamw":
        return Optimizer("adamw", adamw_init, adamw_update)
    if name == "adafactor":
        return Optimizer("adafactor", adafactor_init, adafactor_update)
    raise ValueError(name)
