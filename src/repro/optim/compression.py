"""Gradient compression with error feedback (cross-pod DP traffic saver).

int8 block-quantized gradients with a residual ("error feedback") carried in
optimizer state: compress(g + residual) is all-reduced; the quantization
error is added back next step, so the scheme is unbiased in the long run
(Seide et al. / Karimireddy et al.). Used on the 'pod' axis where the ICI
bisection is narrowest — an 8x byte reduction on the DP all-reduce.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

BLOCK = 256


def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8. Returns (q int8, scales f32)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def error_feedback_update(g: jnp.ndarray, residual: jnp.ndarray):
    """Quantize (g + residual); return (dequantized value, new residual)."""
    target = g.astype(jnp.float32) + residual
    q, s = compress_int8(target)
    deq = decompress_int8(q, s, g.shape)
    return deq.astype(g.dtype), target - deq
