from .compression import compress_int8, decompress_int8, error_feedback_update
from .optimizers import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
)
