from .optimizers import (
    adamw_init,
    adamw_update,
    adafactor_init,
    adafactor_update,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
)
from .compression import compress_int8, decompress_int8, error_feedback_update
