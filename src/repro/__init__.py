"""D2A-JAX: formal software/hardware interface (ILA) framework for
accelerator-backed LM systems. See README.md / DESIGN.md."""

__version__ = "0.1.0"
