"""Roofline analysis for the dry-run (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), in seconds:

  compute    = FLOPs / (chips * 197e12)          bf16 peak, TPU v5e
  memory     = HBM bytes / (chips * 819e9)
  collective = per-device collective bytes / 50e9 (ICI per-link)

Sources and their caveats (measured, not assumed):

* XLA's HloCostAnalysis counts while-loop bodies ONCE. Our models scan over
  layers, so ``compiled.cost_analysis()`` under-reports by ~n_layers x. The
  dry-run therefore does a SECOND, lowering-only pass with every model scan
  unrolled (``layers.accounting_unroll``) whose ``lowered.cost_analysis()``
  is trip-count-correct. (Verified: scan(10 matmuls) reports 1 matmul rolled,
  10 unrolled.)
* Collective bytes come from the *compiled* (post-GSPMD) per-device HLO
  text: we sum operand bytes of all-gather/all-reduce/reduce-scatter/
  all-to-all/collective-permute per computation, then multiply computations
  reached through `while` loops by their trip counts (parsed from the loop
  condition's comparison constant).
* MODEL_FLOPS = 6*N*D (dense train) / 6*N_active*D (MoE) / 2*N_active*B
  (decode) — the "useful FLOPs" yardstick; the ratio against HLO FLOPs
  exposes remat/padding waste.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Tuple

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]{1,0}' -> bytes. Tuples handled by caller."""
    m = re.match(r"(\w+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes_from_hlo(hlo: str) -> Dict[str, Any]:
    """Sum collective operand bytes in a post-SPMD HLO module, multiplying
    loop bodies by their trip counts.

    Returns {op_kind: bytes, ..., "total": bytes, "counts": {kind: n}}.
    """
    # 1. split into computations
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->", line)
        if m and "{" in line:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)

    entry = None
    for line in hlo.splitlines():
        m = re.match(r"\s*ENTRY\s+%?([\w.\-]+)", line)
        if m:
            entry = m.group(1)
            break

    # 2. per-computation: collective bytes + calls (while/call/fusion refs)
    comp_coll: Dict[str, Dict[str, int]] = {}
    comp_counts: Dict[str, Dict[str, int]] = {}
    comp_calls: Dict[str, List[Tuple[str, int]]] = {}  # (callee, multiplier)

    def trip_count(cond_comp: str) -> int:
        """Best effort: find `constant(N)` compared against the loop index."""
        best = 1
        for line in comps.get(cond_comp, ()):
            if "compare" in line:
                mm = re.findall(r"constant\((\d+)\)", line)
                if mm:
                    best = max(best, int(mm[-1]))
        if best == 1:
            # constant may be defined on its own line in the condition comp
            for line in comps.get(cond_comp, ()):
                mm = re.match(r".*=\s*s32\[\]\s*constant\((\d+)\)", line)
                if mm:
                    best = max(best, int(mm.group(1)))
        return best

    for name, lines in comps.items():
        coll: Dict[str, int] = {}
        cnts: Dict[str, int] = {}
        calls: List[Tuple[str, int]] = []
        for line in lines:
            for kind in _COLLECTIVES:
                if re.search(rf"=\s*[\w\[\],{{}}() ]*{kind}(\.|\()", line) or f" {kind}(" in line:
                    # operand shapes appear in the result type; use the
                    # result shape (same bytes for AR/A2A; AG output is the
                    # gathered size — closer to wire bytes than the input)
                    shapes = re.findall(r"(\w+\[[\d,]*\])", line.split("=")[0])
                    total = sum(_shape_bytes(s) for s in shapes)
                    if total == 0:
                        shapes = re.findall(r"(\w+\[[\d,]*\])", line)
                        total = sum(_shape_bytes(s) for s in shapes[:1])
                    coll[kind] = coll.get(kind, 0) + total
                    cnts[kind] = cnts.get(kind, 0) + 1
            mw = re.search(r"while\(.*\).*condition=%?([\w.\-]+).*body=%?([\w.\-]+)", line)
            if mw:
                tc = trip_count(mw.group(1))
                calls.append((mw.group(2), tc))
                calls.append((mw.group(1), tc))
            else:
                for mm in re.finditer(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)", line):
                    calls.append((mm.group(1), 1))
        comp_coll[name] = coll
        comp_counts[name] = cnts
        comp_calls[name] = calls

    # 3. accumulate from entry with multipliers
    totals: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    seen_stack = []

    def walk(name: str, mult: int, depth=0):
        if depth > 50 or name not in comps:
            return
        for k, v in comp_coll.get(name, {}).items():
            totals[k] = totals.get(k, 0) + v * mult
            counts[k] = counts.get(k, 0) + comp_counts[name].get(k, 0) * mult
        for callee, m in comp_calls.get(name, ()):
            if callee != name:
                walk(callee, mult * m, depth + 1)

    if entry:
        walk(entry, 1)
    else:  # fall back: flat sum
        for name in comps:
            for k, v in comp_coll[name].items():
                totals[k] = totals.get(k, 0) + v

    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return {"bytes": totals, "counts": counts}


# ---------------------------------------------------------------------------
# analytic model FLOPs
# ---------------------------------------------------------------------------


def model_flops(cfg, shape) -> Dict[str, float]:
    """MODEL_FLOPS for the cell: the 6ND yardstick + attention term."""
    B, S = shape.global_batch, shape.seq_len
    n_act = cfg.n_active_params()
    if shape.kind == "train":
        tokens = B * S
        base = 6.0 * n_act * tokens
        attn = 0.0
        if cfg.family in ("dense", "vlm", "moe"):
            attn = cfg.n_layers * 6.0 * B * S * S * cfg.n_heads * cfg.hd  # causal: x0.5, QK+PV: x2
        elif cfg.family == "hybrid":
            ng = cfg.n_layers // cfg.attn_every
            attn = ng * 6.0 * B * S * S * cfg.n_heads * cfg.hd
        elif cfg.family == "audio":
            enc_S = 1500
            attn = cfg.n_enc_layers * 12.0 * B * enc_S * enc_S * cfg.n_heads * cfg.hd \
                + cfg.n_dec_layers * (6.0 * B * S * S + 12.0 * B * S * enc_S) * cfg.n_heads * cfg.hd / (cfg.n_heads * cfg.hd) * (cfg.n_heads * cfg.hd)
        return {"matmul": base, "attention": attn, "total": base + attn}
    if shape.kind == "prefill":
        tokens = B * S
        base = 2.0 * n_act * tokens
        attn = 0.0
        if cfg.family in ("dense", "vlm", "moe"):
            attn = cfg.n_layers * 2.0 * B * S * S * cfg.n_heads * cfg.hd
        elif cfg.family == "hybrid":
            attn = (cfg.n_layers // cfg.attn_every) * 2.0 * B * S * S * cfg.n_heads * cfg.hd
        elif cfg.family == "audio":
            enc_S = 1500
            base = 2.0 * n_act * B * enc_S
            attn = cfg.n_enc_layers * 4.0 * B * enc_S * enc_S * cfg.n_heads * cfg.hd
        return {"matmul": base, "attention": attn, "total": base + attn}
    # decode: one token per sequence
    base = 2.0 * n_act * B
    attn = 0.0
    if cfg.family in ("dense", "vlm", "moe"):
        attn = cfg.n_layers * 4.0 * B * S * cfg.n_heads * cfg.hd
    elif cfg.family == "hybrid":
        attn = (cfg.n_layers // cfg.attn_every) * 4.0 * B * S * cfg.n_heads * cfg.hd
    elif cfg.family == "audio":
        attn = cfg.n_dec_layers * 4.0 * B * (S + 1500) * cfg.n_heads * cfg.hd
    return {"matmul": base, "attention": attn, "total": base + attn}


def roofline_terms(cell: Dict[str, Any], cfg, shape) -> Dict[str, Any]:
    """Combine dry-run measurements into the three roofline terms."""
    n_dev = cell.get("n_devices", 256)
    flops = cell.get("acct_flops") or cell.get("hlo_flops") or 0.0
    hbm_bytes = cell.get("acct_bytes") or cell.get("hlo_bytes") or 0.0
    coll = (cell.get("collectives") or {}).get("bytes", {}).get("total", 0)
    mf = model_flops(cfg, shape)
    # cost_analysis is per-program = per-device for SPMD modules
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    useful_per_dev = mf["total"] / n_dev
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_total": mf["total"],
        "model_flops_per_dev": useful_per_dev,
        "useful_over_hlo": (useful_per_dev / flops) if flops else None,
        "roofline_bound_s": max(t_compute, t_memory, t_coll),
        "mfu_bound": (useful_per_dev / PEAK_FLOPS) / max(t_compute, t_memory, t_coll)
        if max(t_compute, t_memory, t_coll) > 0 else None,
    }


def roofline_report(cells: List[Dict[str, Any]]) -> str:
    """Markdown table for EXPERIMENTS.md §Roofline."""
    from ..configs import get_config
    from ..models.config import SHAPES

    rows = []
    hdr = ("| arch | shape | mesh | step | t_compute | t_memory | t_collective "
           "| dominant | MODEL_FLOPs/HLO_FLOPs | bound MFU |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for c in cells:
        if c.get("status") != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | {c.get('mesh','')} | "
                        f"{c.get('status')} | {c.get('reason') or c.get('error','')[:40]} | | | | | |")
            continue
        cfg = get_config(c["arch"])
        shape = SHAPES[c["shape"]]
        t = roofline_terms(c, cfg, shape)
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['step']} "
            f"| {t['t_compute_s']*1e3:.2f} ms | {t['t_memory_s']*1e3:.2f} ms "
            f"| {t['t_collective_s']*1e3:.2f} ms | {t['dominant']} "
            f"| {t['useful_over_hlo'] and round(t['useful_over_hlo'],3)} "
            f"| {t['mfu_bound'] and round(t['mfu_bound'],3)} |"
        )
    return "\n".join(rows)
