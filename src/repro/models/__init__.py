"""Model zoo: the 10 assigned architectures behind a single functional API
(repro.models.api)."""
