"""State-space model blocks: Mamba1 (falcon-mamba) and Mamba2 (zamba2).

TPU adaptation (DESIGN.md §3): the CUDA selective-scan kernel has no TPU
analogue, so

* **Mamba1** uses a chunked ``lax.scan`` over time with rematerialized
  chunks: the (B, d_inner, N) expanded state is never stored across the
  whole sequence — only chunk-boundary states are saved for backward,
  matching the memory behavior of the fused GPU kernel.
* **Mamba2** uses the SSD *chunked matmul* form (Dao & Gu, 2024): scalar
  per-head decay lets intra-chunk work become (c x c) masked GEMMs on the
  MXU plus a tiny inter-chunk recurrence — the TPU-native formulation.

Both expose a single-step ``*_step`` for decode (O(1) state, which is what
makes ``long_500k`` feasible for these families).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import dense_init

SSM_CHUNK = 256


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------


def mamba1_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    d, di, N, R = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 8)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, R + 2 * N, dtype),
        "dt_proj": dense_init(ks[3], R, di, dtype),
        "dt_bias": jnp.full((di,), -2.0, jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _causal_conv1d(x, w, b, state=None):
    """x: (B,S,di); w: (K,di). Returns (y, new_state) with state (B,K-1,di)."""
    B, S, di = x.shape
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((B, K - 1, di), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + S, :] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, S:, :] if S >= K - 1 else xp[:, -(K - 1) :, :]
    return y + b[None, None, :], new_state


def mamba1_scan(p, x, h0=None):
    """Selective scan. x: (B,S,di) post-conv/act. Returns (y, h_final).

    h: (B, di, N). Chunked + remat'd: memory O(S/chunk * B*di*N) residuals.
    """
    B, S, di = x.shape
    N = p["A_log"].shape[1]
    R = p["dt_proj"].shape[0]
    A = -jnp.exp(p["A_log"])                                   # (di,N)

    proj = x @ p["x_proj"]                                      # (B,S,R+2N)
    dt = jax.nn.softplus(
        proj[..., :R].astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
        + p["dt_bias"]
    )                                                           # (B,S,di)
    Bm = proj[..., R : R + N].astype(jnp.float32)               # (B,S,N)
    Cm = proj[..., R + N :].astype(jnp.float32)                 # (B,S,N)
    xf = x.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        dA = jnp.exp(dt_t[..., None] * A[None])                 # (B,di,N)
        dBx = dt_t[..., None] * B_t[:, None, :] * x_t[..., None]
        h = h * dA + dBx
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    @jax.checkpoint
    def chunk_scan(h, chunk):
        return jax.lax.scan(step, h, chunk)

    if h0 is None:
        h0 = jnp.zeros((B, di, N), jnp.float32)
    nchunk = max(1, S // SSM_CHUNK) if S % SSM_CHUNK == 0 else 1
    xs = (
        xf.swapaxes(0, 1),
        dt.swapaxes(0, 1),
        Bm.swapaxes(0, 1),
        Cm.swapaxes(0, 1),
    )
    if nchunk > 1:
        xs = tuple(a.reshape(nchunk, S // nchunk, *a.shape[1:]) for a in xs)
        from .layers import scan_unroll
        h, ys = jax.lax.scan(lambda h_, c: chunk_scan(h_, c), h0, xs,
                             unroll=scan_unroll())
        ys = ys.reshape(S, B, di)
    else:
        h, ys = chunk_scan(h0, xs)
    y = ys.swapaxes(0, 1) + xf * p["D"][None, None, :]
    return y.astype(x.dtype), h


def mamba1_block(cfg: ArchConfig, p, x, state=None):
    """Full block: in_proj -> conv -> silu -> SSM -> gate -> out_proj.

    state: None (train/prefill) or dict(conv, h) for decode.
    """
    from .layers import DP, hint

    xz = hint(x @ p["in_proj"], DP, None, "model")
    di = cfg.d_inner
    xs, z = xz[..., :di], xz[..., di:]
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv1d(xs, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    h0 = None if state is None else state["h"]
    y, h = mamba1_scan(p, xc, h0)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_state = {"conv": new_conv, "h": h}
    return out, new_state


def mamba1_init_state(cfg: ArchConfig, batch, dtype=jnp.bfloat16):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Mamba2 (SSD chunked form)
# ---------------------------------------------------------------------------


def mamba2_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = di // cfg.ssm_head_dim
    ks = jax.random.split(key, 6)
    return {
        # projections for x, z, B, C, dt in one matmul (mamba2 style)
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, di + 2 * N), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * N,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_g": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def _segsum(a):
    """a: (..., c) log-decays -> (..., c, c) lower-tri cumulative sums."""
    c = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_ssd(x, a_log, Bm, Cm, h0=None, chunk=SSM_CHUNK):
    """SSD chunked scan.

    x:  (B, S, H, P)   values
    a_log: (B, S, H)   per-step log decay (<= 0)
    Bm, Cm: (B, S, N)  input/output projections (shared across heads)
    h0: (B, H, P, N) initial state
    Returns (y: (B,S,H,P), h_final).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    c = min(chunk, S)
    nc = S // c
    xr = x.reshape(Bsz, nc, c, H, P)
    ar = a_log.reshape(Bsz, nc, c, H)
    Br = Bm.reshape(Bsz, nc, c, N)
    Cr = Cm.reshape(Bsz, nc, c, N)

    # intra-chunk (diagonal block): y_intra[t] = sum_{s<=t} C_t.B_s prod decay
    L = jnp.exp(_segsum(ar.transpose(0, 1, 3, 2)))               # (B,nc,H,c,c)
    scores = jnp.einsum("bnck,bnsk->bncs", Cr, Br)               # (B,nc,c,c)
    y_intra = jnp.einsum("bncs,bnhcs,bnshp->bnchp", scores, L.astype(scores.dtype), xr)

    # chunk states: state_n = sum_s B_s x_s prod_{s..end} decay
    decay_to_end = jnp.exp(
        jnp.cumsum(ar, axis=2)[:, :, -1:, :] - jnp.cumsum(ar, axis=2)
    )                                                            # (B,nc,c,H)
    states = jnp.einsum("bnsk,bnsh,bnshp->bnhpk", Br, decay_to_end.astype(Br.dtype), xr)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(jnp.sum(ar, axis=2))                   # (B,nc,H)

    def inter(h, inp):
        st, dec = inp
        h_new = h * dec[..., None, None] + st
        return h_new, h                                          # emit state *before* this chunk

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), x.dtype)
    hT, h_prefix = jax.lax.scan(
        inter, h0,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
    )
    h_prefix = h_prefix.swapaxes(0, 1)                           # (B,nc,H,P,N)

    # contribution of carried state into each chunk position
    decay_from_start = jnp.exp(jnp.cumsum(ar, axis=2))           # (B,nc,c,H)
    y_inter = jnp.einsum(
        "bnck,bnhpk,bnch->bnchp", Cr, h_prefix, decay_from_start.astype(Cr.dtype)
    )
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, hT


def mamba2_block(cfg: ArchConfig, p, x, state=None):
    from .layers import DP, hint

    B, S, D = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    H = di // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    proj = hint(x @ p["in_proj"], DP, None, "model")
    z = proj[..., :di]
    xBC = proj[..., di : 2 * di + 2 * N]
    dt_raw = proj[..., 2 * di + 2 * N :]                        # (B,S,H)
    conv_state = None if state is None else state["conv"]
    xBC, new_conv = _causal_conv1d(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., :di].reshape(B, S, H, P)
    Bm = xBC[..., di : di + N].astype(jnp.float32)
    Cm = xBC[..., di + N :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a_log = -jnp.exp(p["A_log"])[None, None, :] * dt            # (B,S,H) <= 0
    h0 = None if state is None else state["h"]
    # ZOH discretization: h = exp(dt*A) h + dt * B x  (input absorbs dt)
    y, hT = mamba2_ssd(xs.astype(jnp.float32) * dt[..., None], a_log, Bm, Cm, h0)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]   # skip path
    y = y.reshape(B, S, di)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    rms = jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-5)
    y = (y * rms * p["norm_g"].astype(jnp.float32)).astype(x.dtype)
    out = y @ p["out_proj"]
    return out, {"conv": new_conv, "h": hT.astype(jnp.float32)}


def mamba2_init_state(cfg: ArchConfig, batch, dtype=jnp.bfloat16):
    di, N = cfg.d_inner, cfg.ssm_state
    H = di // cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di + 2 * N), dtype),
        "h": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
    }
