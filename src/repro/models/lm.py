"""Unified language-model builder for all assigned architecture families.

* dense / vlm     — pre-norm GQA transformer (rotary, GLU MLP)
* moe             — attention (GQA or MLA) + MoE FFN
* ssm             — Mamba1 stack (attention-free)
* hybrid          — Mamba2 stack with a SHARED attention+MLP block applied
                    every ``attn_every`` layers (Zamba2's weight-shared design)
* audio (whisper) — encoder-decoder, see ``whisper.py``

Layers are homogeneous and stacked (params have a leading (L, ...) axis) so
the forward pass is a single ``lax.scan`` — keeping HLO size independent of
depth, which is what makes the 61-layer/671B dry-run compile tractable.
``remat_policy`` wraps the scanned block with ``jax.checkpoint``.

API (pure functions, pjit-ready):
  init_params(cfg, rng)                         -> params
  forward(cfg, params, tokens)                  -> logits             (train)
  loss_fn(cfg, params, batch)                   -> scalar loss
  init_cache(cfg, batch, max_len)               -> cache
  decode_step(cfg, params, cache, tok, pos)     -> (logits, cache)
  prefill(cfg, params, tokens, cache)           -> (logits, cache)
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import layers as L, ssm as S
from .config import ArchConfig


def _split_tree(key, n):
    return jax.random.split(key, n)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _block_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """One layer's params (unstacked)."""
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if cfg.family in ("dense", "vlm"):
        return {
            "ln1": jnp.ones((d,), dtype),
            "attn": L.attention_params(cfg, ks[0], dtype),
            "ln2": jnp.ones((d,), dtype),
            "mlp": L.mlp_params(cfg, ks[1], dtype=dtype),
        }
    if cfg.family == "moe":
        return {
            "ln1": jnp.ones((d,), dtype),
            "attn": L.attention_params(cfg, ks[0], dtype),
            "ln2": jnp.ones((d,), dtype),
            "moe": L.moe_params(cfg, ks[1], dtype),
        }
    if cfg.family == "ssm":
        return {
            "ln1": jnp.ones((d,), dtype),
            "ssm": S.mamba1_params(cfg, ks[0], dtype),
        }
    if cfg.family == "hybrid":
        return {
            "ln1": jnp.ones((d,), dtype),
            "ssm": S.mamba2_params(cfg, ks[0], dtype),
        }
    raise ValueError(cfg.family)


def init_params(cfg: ArchConfig, rng, dtype=jnp.bfloat16) -> Dict[str, Any]:
    k_embed, k_layers, k_head, k_shared, k_mtp = jax.random.split(rng, 5)
    d = cfg.d_model
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, d), jnp.float32) * 0.02).astype(dtype),
        "final_norm": jnp.ones((d,), dtype),
    }
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: _block_params(cfg, k, dtype))(layer_keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, d, cfg.vocab, dtype)
    if cfg.family == "hybrid":
        # Zamba2 shared attention block (ONE set of weights, reused)
        params["shared_attn"] = {
            "ln1": jnp.ones((d,), dtype),
            "attn": L.attention_params(cfg, k_shared, dtype),
            "ln2": jnp.ones((d,), dtype),
            "mlp": L.mlp_params(cfg, jax.random.fold_in(k_shared, 1), dtype=dtype),
        }
    if cfg.mtp_depth:
        # DeepSeek-V3 multi-token prediction: one extra transformer block +
        # projection predicting token t+2 from [h_t ; emb(t+1)]
        params["mtp"] = {
            "proj": L.dense_init(k_mtp, 2 * d, d, dtype),
            "block": _block_params(
                dataclass_replace(cfg, family="moe" if cfg.family == "moe" else cfg.family),
                jax.random.fold_in(k_mtp, 1),
                dtype,
            ),
            "norm": jnp.ones((d,), dtype),
        }
    return params


def dataclass_replace(cfg, **kw):
    import dataclasses

    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _attn_block(cfg, p, x, positions, cache=None, cache_pos=None, causal=True):
    attn_fn = L.mla_attention if cfg.use_mla else L.gqa_attention
    h, new_cache = attn_fn(cfg, p["attn"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                           positions, causal=causal, cache=cache, cache_pos=cache_pos)
    x = x + h
    hn = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        x = x + L.moe_ffn(cfg, p["moe"], hn)
    else:
        x = x + L.glu_mlp(cfg, p["mlp"], hn)
    return x, new_cache


def _ssm_block(cfg, p, x, state=None):
    fn = S.mamba1_block if cfg.ssm_variant == "mamba1" else S.mamba2_block
    h, new_state = fn(cfg, p["ssm"], L.rms_norm(x, p["ln1"], cfg.norm_eps), state)
    return x + h, new_state


# ---------------------------------------------------------------------------
# forward (train / no cache)
# ---------------------------------------------------------------------------


def forward(cfg: ArchConfig, params, tokens, remat: bool = True,
            input_embeds: Optional[jnp.ndarray] = None, return_hidden: bool = False):
    """tokens: (B,S) int32 (or ``input_embeds`` (B,S,D) for frontend stubs)."""
    if input_embeds is not None:
        x = input_embeds
        B, Sq, _ = x.shape
    else:
        B, Sq = tokens.shape
        x = params["embed"][tokens].astype(params["embed"].dtype)
    x = L.hint(x, L.DP, None, None)
    positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))

    if cfg.family in ("dense", "vlm", "moe"):
        def block(x_, lp):
            y, _ = _attn_block(cfg, lp, x_, positions)
            return y, None
    elif cfg.family == "ssm":
        def block(x_, lp):
            y, _ = _ssm_block(cfg, lp, x_)
            return y, None
    elif cfg.family == "hybrid":
        def block(x_, lp):
            y, _ = _ssm_block(cfg, lp, x_)
            return y, None
    else:
        raise ValueError(cfg.family)

    if remat:
        block = jax.checkpoint(block, prevent_cse=False)

    if cfg.family == "hybrid":
        # groups of attn_every ssm blocks followed by the shared attn block
        k = cfg.attn_every
        ng = cfg.n_layers // k
        lp = jax.tree_util.tree_map(
            lambda a: a[: ng * k].reshape((ng, k) + a.shape[1:]), params["layers"]
        )

        def group(x_, glp):
            y, _ = jax.lax.scan(block, x_, glp, unroll=L.scan_unroll())
            y, _ = _attn_block(cfg, params["shared_attn"], y, positions)
            return y, None

        if remat:
            group = jax.checkpoint(group, prevent_cse=False)
        x, _ = jax.lax.scan(group, x, lp, unroll=L.scan_unroll())
        # remaining tail layers (n_layers % attn_every)
        rem = cfg.n_layers - ng * k
        if rem:
            tail = jax.tree_util.tree_map(lambda a: a[ng * k :], params["layers"])
            x, _ = jax.lax.scan(block, x, tail, unroll=L.scan_unroll())
    else:
        x, _ = jax.lax.scan(block, x, params["layers"], unroll=L.scan_unroll())

    hidden = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = L.hint(hidden @ head, L.DP, None, "model")
    if return_hidden:
        return logits, hidden
    return logits


def loss_fn(cfg: ArchConfig, params, batch, remat: bool = True):
    """batch: dict(tokens (B,S+1)) -> mean next-token cross-entropy.

    With ``cfg.mtp_depth``, adds the DeepSeek-V3 multi-token-prediction
    auxiliary loss (predict t+2 from the backbone state at t).
    """
    tokens = batch["tokens"]
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    embeds = batch.get("input_embeds")
    need_hidden = bool(cfg.mtp_depth)
    kw = dict(remat=remat, return_hidden=need_hidden)
    if embeds is not None:
        out = forward(cfg, params, inp, input_embeds=embeds[:, :-1], **kw)
    else:
        out = forward(cfg, params, inp, **kw)
    logits, hidden = out if need_hidden else (out, None)
    loss = xent(logits, tgt)
    if cfg.mtp_depth:
        # MTP: at position t, combine h_t with emb(token_{t+1}) to predict
        # token_{t+2} through one extra block (DeepSeek-V3 Section 2.2)
        mp = params["mtp"]
        B, Sq = inp.shape
        h = hidden[:, : Sq - 1]                                 # (B,S-1,D)
        nxt = params["embed"][inp[:, 1:]].astype(h.dtype)       # emb(t+1)
        z = jnp.concatenate([h, nxt], axis=-1) @ mp["proj"]
        positions = jnp.broadcast_to(jnp.arange(Sq - 1)[None], (B, Sq - 1))
        z, _ = _attn_block(cfg, mp["block"], z, positions)
        z = L.rms_norm(z, mp["norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        mtp_logits = z @ head
        loss = loss + 0.3 * xent(mtp_logits, tgt[:, 1:])        # token t+2
    return loss


def xent(logits, targets):
    """Sharding-friendly cross-entropy: never materializes a replicated
    log-softmax. The target logit is extracted by a one-hot contraction that
    stays sharded over the vocab (model) axis; logsumexp reduces over it.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)                     # (B,S)
    onehot = L.hint(
        jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.bfloat16),
        L.DP, None, "model",
    ).astype(logits.dtype)
    tgt_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return (lse - tgt_logit).mean()


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    Lc = cfg.n_layers
    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.use_mla:
            return {
                "c_kv": jnp.zeros((Lc, batch, max_len, cfg.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((Lc, batch, max_len, cfg.rope_head_dim), dtype),
            }
        return {
            "k": jnp.zeros((Lc, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((Lc, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        }
    if cfg.family == "ssm":
        st = S.mamba1_init_state(cfg, batch, dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (Lc,) + a.shape) * 0, st)
    if cfg.family == "hybrid":
        st = S.mamba2_init_state(cfg, batch, dtype)
        cache = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (Lc,) + a.shape) * 0, st)
        cache = {"ssm": cache}
        # the shared attention block has ONE weight set but is applied once
        # per group — each application needs its own KV history
        ng = cfg.n_layers // cfg.attn_every
        cache["attn_k"] = jnp.zeros((ng, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype)
        cache["attn_v"] = jnp.zeros((ng, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype)
        return cache
    raise ValueError(cfg.family)


def decode_step(cfg: ArchConfig, params, cache, tokens, pos,
                input_embeds: Optional[jnp.ndarray] = None):
    """One decode step. tokens: (B, 1); pos: scalar int32 (current length).

    Attention archs attend over the KV cache; SSM archs update O(1) state.
    Returns (logits (B,1,V), new_cache).
    """
    if input_embeds is not None:
        x = input_embeds
    else:
        x = params["embed"][tokens].astype(params["embed"].dtype)
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)

    if cfg.family in ("dense", "vlm", "moe"):
        def block(x_, xs):
            lp, lcache = xs
            y, new_c = _attn_block(cfg, lp, x_, positions, cache=lcache, cache_pos=pos)
            return y, new_c

        x, new_cache = jax.lax.scan(block, x, (params["layers"], cache), unroll=L.scan_unroll())
    elif cfg.family == "ssm":
        def block(x_, xs):
            lp, lstate = xs
            y, new_s = _ssm_block(cfg, lp, x_, state=lstate)
            return y, new_s

        x, new_cache = jax.lax.scan(block, x, (params["layers"], cache), unroll=L.scan_unroll())
    elif cfg.family == "hybrid":
        k = cfg.attn_every
        ng = cfg.n_layers // k
        lp = jax.tree_util.tree_map(
            lambda a: a[: ng * k].reshape((ng, k) + a.shape[1:]), params["layers"])
        sc = jax.tree_util.tree_map(
            lambda a: a[: ng * k].reshape((ng, k) + a.shape[1:]), cache["ssm"])

        def inner(x_, xs):
            lp_, st_ = xs
            y, new_s = _ssm_block(cfg, lp_, x_, state=st_)
            return y, new_s

        def group(x_, xs):
            glp, gst, gk, gv = xs
            y, new_s = jax.lax.scan(inner, x_, (glp, gst), unroll=L.scan_unroll())
            y, new_ac = _attn_block(cfg, params["shared_attn"], y, positions,
                                    cache={"k": gk, "v": gv}, cache_pos=pos)
            return y, (new_s, new_ac["k"], new_ac["v"])

        x, (new_sc, new_k, new_v) = jax.lax.scan(
            group, x, (lp, sc, cache["attn_k"], cache["attn_v"]), unroll=L.scan_unroll())
        rem = cfg.n_layers - ng * k
        new_cache = {"ssm": jax.tree_util.tree_map(
            lambda a: a.reshape((ng * k,) + a.shape[2:]), new_sc)}
        if rem:
            tail_lp = jax.tree_util.tree_map(lambda a: a[ng * k :], params["layers"])
            tail_st = jax.tree_util.tree_map(lambda a: a[ng * k :], cache["ssm"])
            x, new_tail = jax.lax.scan(inner, x, (tail_lp, tail_st))
            new_cache["ssm"] = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b], 0), new_cache["ssm"], new_tail)
        new_cache["attn_k"] = new_k
        new_cache["attn_v"] = new_v
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_cache


def prefill(cfg: ArchConfig, params, tokens, cache):
    """Prefill: run the full prompt once, filling the cache. tokens: (B,S)."""
    B, Sq = tokens.shape
    x = params["embed"][tokens].astype(params["embed"].dtype)
    positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))

    if cfg.family in ("dense", "vlm", "moe"):
        def block(x_, xs):
            lp, lcache = xs
            y, new_c = _attn_block(cfg, lp, x_, positions, cache=lcache, cache_pos=0)
            return y, new_c

        x, new_cache = jax.lax.scan(block, x, (params["layers"], cache), unroll=L.scan_unroll())
    elif cfg.family in ("ssm", "hybrid"):
        # run the training-style forward but carry states
        if cfg.family == "ssm":
            def block(x_, xs):
                lp, lstate = xs
                y, new_s = _ssm_block(cfg, lp, x_, state=lstate)
                return y, new_s

            x, new_cache = jax.lax.scan(block, x, (params["layers"], cache), unroll=L.scan_unroll())
        else:
            # hybrid prefill mirrors decode_step's grouped structure
            return _hybrid_prefill(cfg, params, x, positions, cache)
    # prefill emits only the last position's logits
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_cache


def _hybrid_prefill(cfg, params, x, positions, cache):
    k = cfg.attn_every
    ng = cfg.n_layers // k
    lp = jax.tree_util.tree_map(
        lambda a: a[: ng * k].reshape((ng, k) + a.shape[1:]), params["layers"])
    sc = jax.tree_util.tree_map(
        lambda a: a[: ng * k].reshape((ng, k) + a.shape[1:]), cache["ssm"])

    def inner(x_, xs):
        lp_, st_ = xs
        y, new_s = _ssm_block(cfg, lp_, x_, state=st_)
        return y, new_s

    def group(x_, xs):
        glp, gst, gk, gv = xs
        y, new_s = jax.lax.scan(inner, x_, (glp, gst))
        y, new_ac = _attn_block(cfg, params["shared_attn"], y, positions,
                                cache={"k": gk, "v": gv}, cache_pos=0)
        return y, (new_s, new_ac["k"], new_ac["v"])

    x, (new_sc, new_k, new_v) = jax.lax.scan(
        group, x, (lp, sc, cache["attn_k"], cache["attn_v"]), unroll=L.scan_unroll())
    new_cache = {
        "ssm": jax.tree_util.tree_map(lambda a: a.reshape((ng * k,) + a.shape[2:]), new_sc),
        "attn_k": new_k,
        "attn_v": new_v,
    }
    rem = cfg.n_layers - ng * k
    if rem:
        tail_lp = jax.tree_util.tree_map(lambda a: a[ng * k :], params["layers"])
        tail_st = jax.tree_util.tree_map(lambda a: a[ng * k :], cache["ssm"])
        x, new_tail = jax.lax.scan(inner, x, (tail_lp, tail_st))
        new_cache["ssm"] = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], 0), new_cache["ssm"], new_tail)
    # prefill emits only the last position's logits
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_cache
