"""Model facade: family dispatch + input specs for every (arch x shape).

``input_specs`` returns ShapeDtypeStruct stand-ins (no allocation) for the
dry-run; ``make_batch`` materializes small real batches for smoke tests.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import lm, whisper
from .config import ArchConfig, ShapeConfig

AUDIO_ENC_FRAMES = 1500   # whisper 30s @ 50Hz (backbone-level stub length)


def init_params(cfg: ArchConfig, rng, dtype=jnp.bfloat16):
    if cfg.family == "audio":
        return whisper.init_params(cfg, rng, dtype)
    return lm.init_params(cfg, rng, dtype)


def loss_fn(cfg: ArchConfig, params, batch, remat=True):
    if cfg.family == "audio":
        return whisper.loss_fn(cfg, params, batch, remat)
    return lm.loss_fn(cfg, params, batch, remat)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    if cfg.family == "audio":
        return whisper.init_cache(cfg, batch, max_len, AUDIO_ENC_FRAMES, dtype)
    return lm.init_cache(cfg, batch, max_len, dtype)


def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    if cfg.family == "audio":
        return whisper.decode_step(cfg, params, cache, tokens, pos)
    return lm.decode_step(cfg, params, cache, tokens, pos)


def prefill(cfg: ArchConfig, params, tokens_or_frames, cache):
    if cfg.family == "audio":
        return whisper.prefill(cfg, params, tokens_or_frames, cache)
    return lm.prefill(cfg, params, tokens_or_frames, cache)


def forward(cfg: ArchConfig, params, tokens, **kw):
    if cfg.family == "audio":
        raise ValueError("audio family uses loss_fn/encode/decode_train")
    return lm.forward(cfg, params, tokens, **kw)


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins) and smoke batches
# ---------------------------------------------------------------------------


def train_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        return {
            "frames": jax.ShapeDtypeStruct((B, AUDIO_ENC_FRAMES, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Specs for serve_step: one new token against a seq_len-deep cache."""
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    specs["cache"] = cache
    return specs


def prefill_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        return {"frames": jax.ShapeDtypeStruct((B, AUDIO_ENC_FRAMES, cfg.d_model), jnp.bfloat16)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def make_train_batch(cfg: ArchConfig, batch: int, seq: int, rng: np.random.Generator):
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq + 1)), jnp.int32)}
    if cfg.family == "audio":
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, 16, cfg.d_model)), jnp.bfloat16)
    return out


def cell_is_supported(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Which (arch x shape) cells run (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "long_500k skipped: full-attention arch (O(S) KV cache / quadratic prefill); run for SSM/hybrid only"
    return True, ""
