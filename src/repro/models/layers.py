"""Shared model layers: norms, rotary, GQA/MLA attention, GLU MLPs, MoE.

Conventions:
  activations x: (B, S, D); weights are per-layer dicts (stacked over layers
  by the model builders and consumed through lax.scan).
  dtype: bf16 activations/params, fp32 norms/softmax/router.

Attention uses a chunked online-softmax formulation (lazy softmax) when the
KV length exceeds ``CHUNK_THRESHOLD`` so the lowered HLO never materializes
the full (S, S) score matrix — the same memory shape a fused flash kernel
gives, expressed portably for GSPMD (the Pallas flash kernel in
``repro.kernels`` is the TPU fast path validated against the same math).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .config import ArchConfig

CHUNK_THRESHOLD = 2048   # KV lengths above this use the chunked path
ATTN_CHUNK = 1024

# ---------------------------------------------------------------------------
# activation-sharding hints
#
# GSPMD propagates most shardings from the parameter/batch specs, but a few
# places need explicit constraints or it picks contraction-sharded layouts
# that replicate activations (e.g. the LM head matmul whose contraction dim
# is FSDP-sharded on the weight side). The launcher/dry-run enables hints
# with ``mesh_hints(mesh)``; without it (CPU smoke tests) hints are no-ops.
# ---------------------------------------------------------------------------

import contextlib
import contextvars

from jax.sharding import PartitionSpec as _P

_MESH_HINTS: contextvars.ContextVar = contextvars.ContextVar("mesh_hints", default=None)

DP = ("pod", "data")   # data-parallel axes (filtered to those present)


@contextlib.contextmanager
def mesh_hints(mesh):
    sizes = {a: s for a, s in zip(mesh.axis_names, mesh.devices.shape)}
    token = _MESH_HINTS.set((mesh, sizes))
    try:
        yield
    finally:
        _MESH_HINTS.reset(token)


# XLA's HloCostAnalysis counts while-loop bodies ONCE (ignoring trip count),
# so a scan-over-layers model under-reports FLOPs by ~L x. The roofline
# accounting pass re-lowers with every model scan fully unrolled (lowering
# only — never compiled) to get trip-count-correct flops/bytes.
_UNROLL: contextvars.ContextVar = contextvars.ContextVar("acct_unroll", default=False)


@contextlib.contextmanager
def accounting_unroll():
    token = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(token)


def scan_unroll():
    """unroll= argument for model-level lax.scans."""
    return True if _UNROLL.get() else 1


def hint(x, *spec):
    """with_sharding_constraint that silently drops axes which are absent
    from the mesh or do not divide the dimension. Uses a concrete
    NamedSharding (no ambient-mesh requirement at trace time)."""
    hints = _MESH_HINTS.get()
    if hints is None:
        return x
    mesh, sizes = hints
    parts = []
    for dim, ax in zip(x.shape, tuple(spec) + (None,) * x.ndim):
        if ax is None:
            parts.append(None)
            continue
        axes = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,)) if a in sizes)
        if not axes:
            parts.append(None)
            continue
        total = 1
        for a in axes:
            total *= sizes[a]
        parts.append((axes if len(axes) > 1 else axes[0]) if dim % total == 0 else None)
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, _P(*parts)))


def hint_heads(x):
    """(B, S, H, hd): shard H over 'model' when divisible; otherwise fall
    back to sharding the *sequence* over 'model' (attention is per-query-row
    parallel, so SP is the clean fallback for e.g. smollm's 15 heads on a
    16-way mesh)."""
    hints = _MESH_HINTS.get()
    if hints is None or "model" not in hints[1]:
        return x
    sizes = hints[1]
    if x.shape[2] % sizes["model"] == 0:
        return hint(x, DP, None, "model", None)
    if x.shape[1] % sizes["model"] == 0:
        return hint(x, DP, "model", None, None)
    return hint(x, DP, None, None, "model")


def rms_norm(x, gamma, eps=1e-5):
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * scale * gamma.astype(jnp.float32)).astype(x.dtype)


def rotary(x, positions, theta=10_000.0):
    """x: (..., S, H, Dh) with positions (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def _act(name):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "geglu": jax.nn.gelu,
    }[name]


# ---------------------------------------------------------------------------
# initialization helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in, d_out, dtype=jnp.bfloat16):
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    d, hd = cfg.d_model, cfg.hd
    if cfg.use_mla:
        return {
            "q_down": dense_init(ks[0], d, cfg.q_lora_rank, dtype),
            "q_up": dense_init(ks[1], cfg.q_lora_rank, cfg.n_heads * (hd + cfg.rope_head_dim), dtype),
            "kv_down": dense_init(ks[2], d, cfg.kv_lora_rank + cfg.rope_head_dim, dtype),
            "kv_up": dense_init(ks[3], cfg.kv_lora_rank, cfg.n_heads * 2 * hd, dtype),
            "o": dense_init(ks[4], cfg.n_heads * hd, d, dtype),
            "q_norm": jnp.ones((cfg.q_lora_rank,), dtype),
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), dtype),
        }
    return {
        "q": dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "k": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "v": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "o": dense_init(ks[3], cfg.n_heads * hd, d, dtype),
    }


def _sdpa_dense(q, k, v, causal, q_offset=0):
    """q: (B,S,H,Dh), k/v: (B,Sk,Hkv,Dh). Materializes (S,Sk) scores."""
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / np.sqrt(Dh)
    if causal:
        qi = jnp.arange(S)[:, None] + q_offset
        ki = jnp.arange(k.shape[1])[None, :]
        s = jnp.where((qi >= ki)[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def _sdpa_chunked(q, k, v, causal):
    """Online-softmax over KV chunks: flash-equivalent memory in pure JAX.

    q/k share Dh; v may have its own head dim (MLA: qk 192, v 128).
    """
    B, S, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    nc = Sk // ATTN_CHUNK
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    kc = k.reshape(B, nc, ATTN_CHUNK, H, Dh)
    vc = v.reshape(B, nc, ATTN_CHUNK, H, Dv)
    scale = 1.0 / np.sqrt(Dh)
    qi = jnp.arange(S)[:, None]

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, xs):
        # checkpointed: the (S, chunk) score/probability blocks are
        # recomputed in backward instead of being saved per chunk
        m, l, acc = carry
        kb, vb, ci = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        if causal:
            ki = ci * ATTN_CHUNK + jnp.arange(ATTN_CHUNK)[None, :]
            s = jnp.where((qi >= ki)[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * jnp.transpose(alpha, (0, 2, 1, 3)) + jnp.einsum(
            "bhqk,bkhd->bqhd", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, S, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, S, 1), jnp.float32)
    a0 = jnp.zeros((B, S, H, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(nc)),
        unroll=scan_unroll(),
    )
    l = jnp.where(l == 0, 1.0, l)
    out = acc / jnp.transpose(l, (0, 2, 1, 3))
    return out.astype(q.dtype)


def sdpa(q, k, v, causal=True, q_offset=0):
    if k.shape[1] > CHUNK_THRESHOLD and k.shape[1] % ATTN_CHUNK == 0:
        return _sdpa_chunked(q, k, v, causal)
    return _sdpa_dense(q, k, v, causal, q_offset)


def gqa_attention(cfg: ArchConfig, p, x, positions, causal=True, cache=None, cache_pos=None):
    """Returns (out, new_cache). cache: dict(k,v) of (B, S_max, Hkv, Dh)."""
    B, S, D = x.shape
    hd = cfg.hd
    q = hint_heads((x @ p["q"]).reshape(B, S, cfg.n_heads, hd))
    k = hint_heads((x @ p["k"]).reshape(B, S, cfg.n_kv_heads, hd))
    v = hint_heads((x @ p["v"]).reshape(B, S, cfg.n_kv_heads, hd))
    q = rotary(q, positions, cfg.rope_theta)
    k = rotary(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        kk, vv = ck, cv
        # mask out cache slots beyond current position via causal offset
        out = _sdpa_dense(q, kk, vv, causal=True, q_offset=cache_pos)
    else:
        out = sdpa(q, k, v, causal=causal)
    out = hint_heads(out).reshape(B, S, cfg.n_heads * hd)
    return hint(out @ p["o"], DP, None, None), new_cache


def mla_attention(cfg: ArchConfig, p, x, positions, causal=True, cache=None, cache_pos=None):
    """DeepSeek MLA. Cache stores the *compressed* c_kv (+ rope key) —
    (kv_lora + rope_head_dim) per token instead of 2*H*Dh.
    """
    B, S, D = x.shape
    hd, rd = cfg.hd, cfg.rope_head_dim
    H = cfg.n_heads
    cq = rms_norm(x @ p["q_down"], p["q_norm"], cfg.norm_eps)
    q = hint_heads((cq @ p["q_up"]).reshape(B, S, H, hd + rd))
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = rotary(q_rope, positions, cfg.rope_theta)

    ckv_full = x @ p["kv_down"]                         # (B,S,kv_lora+rd)
    c_kv = ckv_full[..., : cfg.kv_lora_rank]
    k_rope = rotary(ckv_full[..., cfg.kv_lora_rank :][:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    if cache is not None:
        cc = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, cache_pos, 0))
        cr = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, cache_pos, 0))
        new_cache = {"c_kv": cc, "k_rope": cr}
        # -- absorbed decode (§Perf iteration 2) ---------------------------
        # The naive path re-expands K/V for the WHOLE cache every step
        # (O(S * kv_lora * 2*H*hd) flops/token). Absorbing kv_up into the
        # query/output projections runs attention in the compressed latent
        # space: O(S * H * (kv_lora + rd)) — a ~100x decode-flops cut.
        kv_up = p["kv_up"].reshape(cfg.kv_lora_rank, H, 2, hd)
        w_uk = jnp.transpose(kv_up[:, :, 0], (1, 0, 2))   # (H, kv_lora, hd)
        w_uv = jnp.transpose(kv_up[:, :, 1], (1, 0, 2))   # (H, kv_lora, hd)
        c_n = rms_norm(cc, p["kv_norm"], cfg.norm_eps)    # (B, Sc, kv_lora)
        q_lat = jnp.einsum("bshd,hkd->bshk", q_nope, w_uk.astype(q_nope.dtype))
        scale = 1.0 / np.sqrt(hd + rd)
        s_lat = jnp.einsum("bshk,btk->bhst", q_lat, c_n) * scale
        s_rope = jnp.einsum("bshr,btr->bhst", q_rope, cr.astype(q_rope.dtype)) * scale
        scores = (s_lat + s_rope).astype(jnp.float32)
        ti = jnp.arange(cc.shape[1])[None, None, None, :]
        qi = jnp.arange(S)[None, None, :, None] + cache_pos
        scores = jnp.where(qi >= ti, scores, -1e30)
        pr = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,btk->bshk", pr.astype(c_n.dtype), c_n)
        out = jnp.einsum("bshk,hkd->bshd", ctx, w_uv.astype(ctx.dtype))
        out = hint_heads(out).reshape(B, S, H * hd)
        return hint(out @ p["o"], DP, None, None), new_cache
    # -- train / prefill: materialized K/V (MXU-friendly batched form) -----
    new_cache = None
    c_n = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    kv = hint_heads((c_n @ p["kv_up"]).reshape(B, -1, H, 2 * hd))
    k_nope, v = kv[..., :hd], kv[..., hd:]
    # concat nope + shared rope dims
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (rd,))
    k_full = jnp.concatenate([k_nope, k_rope_b.astype(k_nope.dtype)], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = sdpa(q_full, k_full, v, causal=causal)
    out = hint_heads(out).reshape(B, S, H * hd)
    return hint(out @ p["o"], DP, None, None), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_params(cfg: ArchConfig, key, d_ff=None, dtype=jnp.bfloat16):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, cfg.d_model, d_ff, dtype),
        "w_up": dense_init(k2, cfg.d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, cfg.d_model, dtype),
    }


def glu_mlp(cfg: ArchConfig, p, x):
    act = _act(cfg.act)
    h = act(hint(x @ p["w_gate"], DP, None, "model")) * hint(x @ p["w_up"], DP, None, "model")
    return hint(h @ p["w_down"], DP, None, None)


# ---------------------------------------------------------------------------
# MoE (sort-based token-choice dispatch with capacity)
# ---------------------------------------------------------------------------


def moe_params(cfg: ArchConfig, key, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_expert_ff
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * scale).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32) / np.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_params(cfg, ks[4], d_ff=cfg.n_shared_experts * cfg.d_expert_ff, dtype=dtype)
    return p


MOE_GROUP = 32_768   # max tokens dispatched per group (bounds E*C*D buffer)


def moe_ffn(cfg: ArchConfig, p, x):
    """x: (B,S,D) -> (B,S,D). Token-choice top-k with capacity dropping.

    Sort-based dispatch: tokens sorted by assigned expert, scattered into an
    (E, C, D) buffer (capacity C), per-expert gated-GLU GEMMs, combined back
    with gate weights. Expert dim shards over 'model' (EP): GSPMD realizes
    the token->expert exchange as all-to-all on the scatter/gather.

    Long inputs are dispatched in groups of MOE_GROUP tokens (scan) so the
    capacity buffer stays O(MOE_GROUP) — the grouped all-to-all schedule
    real MoE systems use for prefill.
    """
    B, S, D = x.shape
    T_all = B * S
    if T_all > MOE_GROUP and T_all % MOE_GROUP == 0:
        ng = T_all // MOE_GROUP
        xg = x.reshape(ng, MOE_GROUP, D)

        # checkpointed: each group's dispatch gathers are recomputed in
        # backward instead of stacking (ng, SL, D) residuals (§Perf iter 1)
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def per_group(_, xg_i):
            return (), _moe_group(cfg, p, xg_i)

        _, yg = jax.lax.scan(per_group, (), xg, unroll=scan_unroll())
        return yg.reshape(B, S, D)
    return _moe_group(cfg, p, x.reshape(T_all, D)).reshape(B, S, D)


def _moe_group(cfg: ArchConfig, p, x2):
    D = x2.shape[-1]
    T = x2.shape[0]
    x = x2[None]  # keep shapes below unchanged
    E, K = cfg.n_experts, cfg.top_k
    scores = jax.nn.softmax((x2.astype(jnp.float32) @ p["router"]), axis=-1)
    gvals, gidx = jax.lax.top_k(scores, K)                     # (T,K)
    gvals = (gvals / jnp.sum(gvals, axis=-1, keepdims=True)).astype(x.dtype)

    SL = T * K
    C = max(8, int(cfg.capacity_factor * SL / E))
    flat_e = gidx.reshape(SL)
    perm = jnp.argsort(flat_e)
    sorted_e = flat_e[perm]                                    # (SL,)
    tok = perm // K
    pos = jnp.arange(SL) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    keep = pos < C
    dest = jnp.where(keep, sorted_e * C + pos, E * C)          # drop slot at end
    gathered = hint(x2[tok], DP, None)      # keep token copies dp-sharded
    buf = jnp.zeros((E * C + 1, D), x.dtype)
    buf = buf.at[dest].add(gathered)
    xe = hint(buf[: E * C].reshape(E, C, D), "model", None, None)   # EP

    act = _act(cfg.act)
    h = act(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w_up"]
    )
    h = hint(h, "model", None, None)
    ye = hint(jnp.einsum("ecf,efd->ecd", h, p["w_down"]), "model", None, None).reshape(E * C, D)
    ye = jnp.concatenate([ye, jnp.zeros((1, D), ye.dtype)], axis=0)

    contrib = hint(ye[dest], DP, None) * (gvals.reshape(SL)[perm])[:, None] * keep[:, None].astype(x.dtype)
    out = hint(jnp.zeros((T, D), x.dtype).at[tok].add(contrib), DP, None)
    if cfg.n_shared_experts:
        out = out + glu_mlp(cfg, p["shared"], x2)
    return out
