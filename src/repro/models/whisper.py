"""Whisper-style encoder-decoder backbone ([audio]).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, D). The backbone is standard:

  encoder — bidirectional self-attention blocks
  decoder — causal self-attention + cross-attention blocks

Decode uses a KV cache for decoder self-attention plus precomputed
cross-attention K/V from the encoder output.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig


def _enc_block_params(cfg, key, dtype):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "ln1": jnp.ones((d,), dtype),
        "attn": L.attention_params(cfg, ks[0], dtype),
        "ln2": jnp.ones((d,), dtype),
        "mlp": L.mlp_params(cfg, ks[1], dtype=dtype),
    }


def _dec_block_params(cfg, key, dtype):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln1": jnp.ones((d,), dtype),
        "self_attn": L.attention_params(cfg, ks[0], dtype),
        "ln_x": jnp.ones((d,), dtype),
        "cross_attn": L.attention_params(cfg, ks[1], dtype),
        "ln2": jnp.ones((d,), dtype),
        "mlp": L.mlp_params(cfg, ks[2], dtype=dtype),
    }


def init_params(cfg: ArchConfig, rng, dtype=jnp.bfloat16) -> Dict[str, Any]:
    ke, kd, kt, kh = jax.random.split(rng, 4)
    d = cfg.d_model
    enc_keys = jax.random.split(ke, cfg.n_enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_dec_layers)
    return {
        "tok_embed": (jax.random.normal(kt, (cfg.vocab, d), jnp.float32) * 0.02).astype(dtype),
        "enc_layers": jax.vmap(lambda k: _enc_block_params(cfg, k, dtype))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _dec_block_params(cfg, k, dtype))(dec_keys),
        "enc_norm": jnp.ones((d,), dtype),
        "dec_norm": jnp.ones((d,), dtype),
    }


def encode(cfg: ArchConfig, params, frames):
    """frames: (B, S_enc, D) precomputed embeddings (frontend stub)."""
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def block(x, lp):
        h, _ = L.gqa_attention(cfg, lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                               positions, causal=False)
        x = x + h
        x = x + L.glu_mlp(cfg, lp["mlp"], L.rms_norm(x, lp["ln2"], cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(block, prevent_cse=False), frames, params["enc_layers"], unroll=L.scan_unroll())
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_attention(cfg, p, x, enc_out):
    B, S, D = x.shape
    hd = cfg.hd
    q = (x @ p["q"]).reshape(B, S, cfg.n_heads, hd)
    k = (enc_out @ p["k"]).reshape(B, -1, cfg.n_kv_heads, hd)
    v = (enc_out @ p["v"]).reshape(B, -1, cfg.n_kv_heads, hd)
    out = L.sdpa(q, k, v, causal=False)
    return out.reshape(B, S, cfg.n_heads * hd) @ p["o"]


def decode_train(cfg: ArchConfig, params, tokens, enc_out):
    """Teacher-forced decoder pass. tokens: (B, S_dec)."""
    B, S = tokens.shape
    x = params["tok_embed"][tokens].astype(params["tok_embed"].dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def block(x_, lp):
        h, _ = L.gqa_attention(cfg, lp["self_attn"],
                               L.rms_norm(x_, lp["ln1"], cfg.norm_eps),
                               positions, causal=True)
        x_ = x_ + h
        x_ = x_ + _cross_attention(cfg, lp["cross_attn"],
                                   L.rms_norm(x_, lp["ln_x"], cfg.norm_eps), enc_out)
        x_ = x_ + L.glu_mlp(cfg, lp["mlp"], L.rms_norm(x_, lp["ln2"], cfg.norm_eps))
        return x_, None

    x, _ = jax.lax.scan(jax.checkpoint(block, prevent_cse=False), x, params["dec_layers"], unroll=L.scan_unroll())
    x = L.rms_norm(x, params["dec_norm"], cfg.norm_eps)
    return x @ params["tok_embed"].T


def loss_fn(cfg: ArchConfig, params, batch, remat: bool = True):
    """batch: dict(frames (B,S_enc,D), tokens (B,S_dec+1))."""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = decode_train(cfg, params, inp, enc_out)
    from .lm import xent

    return xent(logits, tgt)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int, dtype=jnp.bfloat16):
    Ld = cfg.n_dec_layers
    return {
        "k": jnp.zeros((Ld, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((Ld, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        # cross K/V precomputed at prefill from encoder output
        "xk": jnp.zeros((Ld, batch, enc_len, cfg.n_kv_heads, cfg.hd), dtype),
        "xv": jnp.zeros((Ld, batch, enc_len, cfg.n_kv_heads, cfg.hd), dtype),
    }


def prefill(cfg: ArchConfig, params, frames, cache):
    """Encoder pass + cross-K/V precompute (no decoder tokens yet)."""
    enc_out = encode(cfg, params, frames)
    B = enc_out.shape[0]
    hd = cfg.hd

    def per_layer(lp):
        k = (enc_out @ lp["cross_attn"]["k"]).reshape(B, -1, cfg.n_kv_heads, hd)
        v = (enc_out @ lp["cross_attn"]["v"]).reshape(B, -1, cfg.n_kv_heads, hd)
        return k.astype(cache["xk"].dtype), v.astype(cache["xv"].dtype)

    xk, xv = jax.vmap(per_layer)(params["dec_layers"])
    # no decoder tokens yet: return a placeholder logits block so the
    # prefill step signature matches the LM families
    logits = jnp.zeros((B, 1, cfg.vocab), enc_out.dtype)
    return logits, dict(cache, xk=xk, xv=xv)


def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    """One decoder token. tokens: (B,1)."""
    B = tokens.shape[0]
    x = params["tok_embed"][tokens].astype(params["tok_embed"].dtype)
    positions = jnp.full((B, 1), pos, jnp.int32)
    hd = cfg.hd

    def block(x_, xs):
        lp, lk, lv, lxk, lxv = xs
        h, nc = L.gqa_attention(cfg, lp["self_attn"],
                                L.rms_norm(x_, lp["ln1"], cfg.norm_eps),
                                positions, causal=True,
                                cache={"k": lk, "v": lv}, cache_pos=pos)
        x_ = x_ + h
        xq = L.rms_norm(x_, lp["ln_x"], cfg.norm_eps)
        q = (xq @ lp["cross_attn"]["q"]).reshape(B, 1, cfg.n_heads, hd)
        out = L._sdpa_dense(q, lxk, lxv, causal=False)
        x_ = x_ + out.reshape(B, 1, cfg.n_heads * hd) @ lp["cross_attn"]["o"]
        x_ = x_ + L.glu_mlp(cfg, lp["mlp"], L.rms_norm(x_, lp["ln2"], cfg.norm_eps))
        return x_, nc

    x, new_kv = jax.lax.scan(
        block, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        unroll=L.scan_unroll())
    x = L.rms_norm(x, params["dec_norm"], cfg.norm_eps)
    logits = x @ params["tok_embed"].T
    return logits, dict(cache, k=new_kv["k"], v=new_kv["v"])
