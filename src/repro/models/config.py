"""Architecture configuration schema for the assigned model zoo."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                 # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    # mlp
    d_ff: int = 0
    act: str = "silu"                 # silu | gelu | geglu (gated variants)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    # SSM
    ssm_state: int = 0
    ssm_variant: str = ""             # mamba1 | mamba2
    d_inner: int = 0
    d_conv: int = 4
    ssm_head_dim: int = 64
    dt_rank: int = 0
    # hybrid (zamba2): shared attention block applied every k SSM blocks
    attn_every: int = 0
    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # multi-token prediction (deepseek-v3)
    mtp_depth: int = 0
    # modality frontend stub dims ([audio]/[vlm]): embeddings precomputed
    frontend_stub: str = ""           # "" | audio_frames | image_patches
    # norm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """sub-quadratic decode: SSM / hybrid archs only (DESIGN.md §4)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def n_params(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d = self.d_model
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            per = (self.n_heads + 2 * self.n_kv_heads) * self.hd * d + self.n_heads * self.hd * d
            per += 3 * d * self.d_ff
            n += self.n_layers * per
        elif self.family == "moe":
            if self.use_mla:
                attn = (
                    d * self.q_lora_rank
                    + self.q_lora_rank * self.n_heads * (self.hd + self.rope_head_dim)
                    + d * (self.kv_lora_rank + self.rope_head_dim)
                    + self.kv_lora_rank * self.n_heads * 2 * self.hd
                    + self.n_heads * self.hd * d
                )
            else:
                attn = (self.n_heads + 2 * self.n_kv_heads) * self.hd * d + self.n_heads * self.hd * d
            moe = (self.n_experts + self.n_shared_experts) * 3 * d * self.d_expert_ff + d * self.n_experts
            n += self.n_layers * (attn + moe)
        elif self.family == "ssm":
            di = self.d_inner or 2 * d
            per = d * 2 * di + di * self.d_conv + di * (self.dt_rank or d // 16) * 2
            per += di * 2 * self.ssm_state + di * d
            n += self.n_layers * per
        elif self.family == "hybrid":
            di = self.d_inner or 2 * d
            nh = di // self.ssm_head_dim
            per = d * 2 * di + di * self.d_conv + di + 2 * nh * self.ssm_state * di // nh * nh // nh
            per += d * 2 * di + di * d  # rough proj terms
            n += self.n_layers * per
            attn = 4 * d * self.n_heads * self.hd + 3 * d * self.d_ff
            n += attn  # shared block counted once
        elif self.family == "audio":
            per = 4 * d * d + 2 * d * self.d_ff
            n += (self.n_enc_layers + 2 * self.n_dec_layers) * per
        return int(n)

    def n_active_params(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.use_mla:
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * (self.hd + self.rope_head_dim)
                + d * (self.kv_lora_rank + self.rope_head_dim)
                + self.kv_lora_rank * self.n_heads * 2 * self.hd
                + self.n_heads * self.hd * d
            )
        else:
            attn = (self.n_heads + 2 * self.n_kv_heads) * self.hd * d + self.n_heads * self.hd * d
        act = (self.top_k + self.n_shared_experts) * 3 * d * self.d_expert_ff + d * self.n_experts
        return int(n + self.n_layers * (attn + act))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
