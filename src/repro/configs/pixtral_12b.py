"""pixtral-12b [vlm]: Pixtral-ViT frontend (stub) + Mistral-Nemo backbone.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
[hf:mistralai/Pixtral-12B-2409; unverified]
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, vocab=131072,
    n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, act="silu", rope_theta=1_000_000.0,
    frontend_stub="image_patches",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="pixtral-smoke", family="vlm",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, act="silu", frontend_stub="image_patches",
    )
