"""gemma-7b [dense]: GeGLU, head_dim=256.

28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000. [arXiv:2403.08295; hf]
(d_ff=24576 is the published 2x gated hidden total; per-branch 8192x... we
use the config value directly as the gated hidden width.)
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, vocab=256000,
    n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, act="geglu", tie_embeddings=True,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="gemma-smoke", family="dense",
        n_layers=2, d_model=64, vocab=512,
        n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, act="geglu", tie_embeddings=True,
    )
