"""qwen3-moe-30b-a3b [moe]: 128 experts top-8.

48L d_model=2048 32H (GQA kv=4) d_ff(expert)=768 vocab=151936, head_dim=128.
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, vocab=151936,
    n_heads=32, n_kv_heads=4, head_dim=128,
    n_experts=128, top_k=8, d_expert_ff=768, n_shared_experts=0,
    act="silu", rope_theta=1_000_000.0,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, head_dim=16,
        n_experts=8, top_k=2, d_expert_ff=32, n_shared_experts=0,
        act="silu",
    )
