"""tinyllama-1.1b [dense]: llama2-arch small.

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000. [arXiv:2401.02385; hf]
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, vocab=32000,
    n_heads=32, n_kv_heads=4, head_dim=64,
    d_ff=5632, act="silu",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="tinyllama-smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=176, act="silu",
    )
