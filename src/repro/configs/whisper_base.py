"""whisper-base [audio]: encoder-decoder; conv frontend is a STUB
(input_specs provides precomputed frame embeddings).

6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865. [arXiv:2212.04356]
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=12, n_enc_layers=6, n_dec_layers=6,
    d_model=512, vocab=51865,
    n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, act="gelu",
    frontend_stub="audio_frames",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="whisper-smoke", family="audio",
        n_layers=4, n_enc_layers=2, n_dec_layers=2,
        d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, act="gelu",
        frontend_stub="audio_frames",
    )
