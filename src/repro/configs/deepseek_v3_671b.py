"""deepseek-v3-671b [moe]: MLA + 1 shared + 256 routed experts top-8, MTP.

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280. MLA: q_lora=1536,
kv_lora=512, rope_head_dim=64, qk_nope/v head_dim=128. [arXiv:2412.19437; hf]

Deviation (DESIGN.md §7): the real model's first 3 layers are dense FFN; we
model all 61 as MoE (homogeneous layer scan), which changes <0.5% of params.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, vocab=129280,
    n_heads=128, n_kv_heads=128, head_dim=128,
    n_experts=256, top_k=8, d_expert_ff=2048, n_shared_experts=1,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
    act="silu", mtp_depth=1,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-smoke", family="moe",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, head_dim=16,
        n_experts=8, top_k=2, d_expert_ff=32, n_shared_experts=1,
        use_mla=True, q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
        act="silu", mtp_depth=1,
    )
