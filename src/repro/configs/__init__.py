"""Assigned architecture configs (``--arch <id>``).

Each module exports ``CONFIG`` (the exact published config) and
``smoke_config()`` (a reduced same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict

from ..models.config import ArchConfig

ARCH_IDS = [
    "pixtral_12b",
    "deepseek_v3_671b",
    "qwen3_moe_30b_a3b",
    "zamba2_7b",
    "falcon_mamba_7b",
    "gemma_7b",
    "granite_8b",
    "smollm_360m",
    "tinyllama_1_1b",
    "whisper_base",
]

# canonical dashed ids from the assignment
DASHED = {
    "pixtral-12b": "pixtral_12b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "zamba2-7b": "zamba2_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "gemma-7b": "gemma_7b",
    "granite-8b": "granite_8b",
    "smollm-360m": "smollm_360m",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "whisper-base": "whisper_base",
}


def _module(arch: str) -> str:
    return DASHED.get(arch, arch).replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ArchConfig:
    return importlib.import_module(f"repro.configs.{_module(arch)}").CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    return importlib.import_module(f"repro.configs.{_module(arch)}").smoke_config()


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
