"""granite-8b [dense]: llama-arch (code model).

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152. [arXiv:2405.04324; hf]
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, vocab=49152,
    n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, act="silu",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="granite-smoke", family="dense",
        n_layers=2, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, act="silu",
    )
