"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64.
The shared attention+MLP block (one set of weights) is applied every 6
Mamba2 blocks (Zamba2's shared-block design). [arXiv:2411.15242; unverified]
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, vocab=32000,
    n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, act="gelu",
    ssm_variant="mamba2", ssm_state=64, d_inner=7168, ssm_head_dim=64,
    d_conv=4, attn_every=6,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=4, d_model=64, vocab=256,
        n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, act="gelu",
        ssm_variant="mamba2", ssm_state=16, d_inner=128, ssm_head_dim=32,
        d_conv=4, attn_every=2,
    )
