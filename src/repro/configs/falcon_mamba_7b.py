"""falcon-mamba-7b [ssm]: pure Mamba1, attention-free.

64L d_model=4096 d_ff=0 vocab=65024, ssm_state=16, d_inner=8192,
dt_rank=256, d_conv=4. [arXiv:2410.05355; unverified]
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, vocab=65024,
    ssm_variant="mamba1", ssm_state=16, d_inner=8192, dt_rank=256, d_conv=4,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="falcon-mamba-smoke", family="ssm",
        n_layers=2, d_model=64, vocab=256,
        ssm_variant="mamba1", ssm_state=8, d_inner=128, dt_rank=8, d_conv=4,
    )
