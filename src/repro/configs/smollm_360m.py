"""smollm-360m [dense]: llama-arch small.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
[hf:HuggingFaceTB/SmolLM-360M; hf]
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, vocab=49152,
    n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, act="silu", tie_embeddings=True,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="smollm-smoke", family="dense",
        n_layers=2, d_model=60, vocab=256,
        n_heads=3, n_kv_heads=1, head_dim=20,
        d_ff=160, act="silu", tie_embeddings=True,
    )
