"""The six DL applications of Section 4.2, as compiler-IR programs.

Each builder returns ``(expr, params)`` where ``expr`` is the IR program over
a Var for the input (named "x", plus weight Vars) and ``params`` maps weight
names to initialized arrays. Sizes are scaled so the accelerator ILAs can
co-simulate them end-to-end (the paper likewise "selected applications with
reasonable size for human inspection"), but the *structures* match:

  efficientnet  — conv stages with sigmoid (swish-family) gating + SE-ish mix
  lstm_wlm      — LSTM + linear logit head (the word-language-model)
  mobilenet_v2  — pointwise conv / depthwise (host-resident) / residuals
  resmlp        — patchify + MLP-mixer-style token/channel linear layers
  resnet20      — conv/relu blocks with identity residuals + linear head
  transformer   — MHA (per-head attention intrinsics) + FFN + layernorm

``dw_conv2d`` (grouped/depthwise) is intentionally *unsupported* by every
accelerator mapping — the paper kept grouped convolutions on the host
(Appendix A) — so MobileNet exhibits the same partial-offload shape.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from . import ir


def _init(rng, *shape, scale=None):
    scale = scale or (1.0 / np.sqrt(np.prod(shape[-1:])))
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def _linear(x, params, rng, name, din, dout):
    w = ir.Var(f"{name}_w", (dout, din))
    b = ir.Var(f"{name}_b", (dout,))
    params[f"{name}_w"] = _init(rng, dout, din)
    params[f"{name}_b"] = np.zeros((dout,), np.float32)
    return ir.bias_add(ir.dense(x, w), b)


def _dense_only(x, params, rng, name, din, dout):
    w = ir.Var(f"{name}_w", (dout, din))
    params[f"{name}_w"] = _init(rng, dout, din)
    return ir.dense(x, w)


def _conv(x, params, rng, name, cin, cout, k=3, strides=(1, 1), padding=(0, 0)):
    w = ir.Var(f"{name}_w", (k, k, cin, cout))
    params[f"{name}_w"] = _init(rng, k, k, cin, cout, scale=1.0 / np.sqrt(k * k * cin))
    return ir.conv2d(x, w, strides, padding)


def _layernorm(x, params, rng, name, d):
    g = ir.Var(f"{name}_g", (d,))
    b = ir.Var(f"{name}_b", (d,))
    params[f"{name}_g"] = np.ones((d,), np.float32)
    params[f"{name}_b"] = np.zeros((d,), np.float32)
    return ir.call("layer_norm", x, g, b, eps=1e-5)


# ---------------------------------------------------------------------------


def build_efficientnet(seed=0, img=12, cin=8, width=16, blocks=3, n_classes=10):
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    x = ir.Var("x", (1, img, img, cin))
    h = _conv(x, params, rng, "stem", cin, width, k=3)
    size = img - 2
    c = width
    for i in range(blocks):
        y = _conv(h, params, rng, f"b{i}_conv", c, c, k=3, padding=(1, 1))
        y = ir.call("sigmoid", y)       # swish-family gating
        y = ir.call("mul", y, h)
        h = ir.call("add", y, h)        # residual
    h = ir.reshape(h, (size * size, c))
    h = _linear(h, params, rng, "head_mid", c, c)
    h = ir.call("relu", h)
    h = ir.call("reduce_mean", h, axis=0)
    h = ir.reshape(h, (1, c))
    logits = _linear(h, params, rng, "head", c, n_classes)
    return logits, params


def build_lstm_wlm(seed=0, vocab=32, embed=32, hidden=32, T=16):
    """Embedded tokens come in as x:(T, 1, embed); LSTM -> linear logits."""
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    x = ir.Var("x", (T, 1, embed))
    wi = ir.Var("lstm_wi", (4 * hidden, embed))
    wh = ir.Var("lstm_wh", (4 * hidden, hidden))
    b = ir.Var("lstm_b", (4 * hidden,))
    params["lstm_wi"] = _init(rng, 4 * hidden, embed)
    params["lstm_wh"] = _init(rng, 4 * hidden, hidden)
    params["lstm_b"] = np.zeros((4 * hidden,), np.float32)
    h = ir.call("lstm", x, wi, wh, b)                 # (T, 1, H)
    h = ir.reshape(h, (T, hidden))
    logits = _linear(h, params, rng, "logits", hidden, vocab)
    return logits, params


def build_mobilenet_v2(seed=0, img=12, cin=8, width=16, blocks=3, n_classes=10):
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    x = ir.Var("x", (1, img, img, cin))
    h = _conv(x, params, rng, "stem", cin, width, k=1)
    c = width
    for i in range(blocks):
        # expand (pointwise) -> depthwise (host) -> project (pointwise)
        e = _conv(h, params, rng, f"b{i}_exp", c, 2 * c, k=1)
        e = ir.call("relu", e)
        e = _dwconv(e, params, rng, f"b{i}_dw", 2 * c)
        e = ir.call("relu", e)
        p = _conv(e, params, rng, f"b{i}_proj", 2 * c, c, k=1)
        h = ir.call("add", p, h)
    h = ir.reshape(h, (img * img, c))
    h = ir.call("reduce_mean", h, axis=0)
    h = ir.reshape(h, (1, c))
    # final classifier is a bias-less dense (the paper's flexible-matching
    # finding: offloaded to FlexASR only via the dense+0 rewrite)
    logits = _dense_only(h, params, rng, "head", c, n_classes)
    return logits, params


def _dwconv(x, params, rng, name, c):
    """Depthwise conv: stays a host op (no accelerator mapping)."""
    w = ir.Var(f"{name}_w", (3, 3, c, 1))
    params[f"{name}_w"] = _init(rng, 3, 3, c, 1, scale=1.0 / 3.0)
    return ir.call("dw_conv2d", x, w, strides=(1, 1), padding=(1, 1))


def build_resmlp(seed=0, n_patch=16, d=64, layers=4, n_classes=10):
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    x = ir.Var("x", (n_patch, d))       # patch embeddings (host patchify stub)
    h = x
    for i in range(layers):
        # token-mixing linear across patches
        t = ir.call("transpose", h, axes=(1, 0))
        t = _linear(t, params, rng, f"l{i}_tok", n_patch, n_patch)
        t = ir.call("transpose", t, axes=(1, 0))
        h = ir.call("add", h, t)
        # channel-mixing MLP
        m = _layernorm(h, params, rng, f"l{i}_ln", d)
        m = _linear(m, params, rng, f"l{i}_fc1", d, 2 * d)
        m = ir.call("relu", m)
        m = _linear(m, params, rng, f"l{i}_fc2", 2 * d, d)
        h = ir.call("add", h, m)
    h = ir.call("reduce_mean", h, axis=0)
    h = ir.reshape(h, (1, d))
    logits = _linear(h, params, rng, "head", d, n_classes)
    return logits, params


def build_resnet20(seed=0, img=12, cin=8, width=16, blocks=3, n_classes=10):
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    x = ir.Var("x", (1, img, img, cin))
    h = _conv(x, params, rng, "stem", cin, width, k=3, padding=(1, 1))
    h = ir.call("relu", h)
    c = width
    for i in range(blocks):
        y = _conv(h, params, rng, f"b{i}_c1", c, c, k=3, padding=(1, 1))
        y = ir.call("relu", y)
        y = _conv(y, params, rng, f"b{i}_c2", c, c, k=3, padding=(1, 1))
        h = ir.call("relu", ir.call("add", y, h))     # identity mapping
    h = ir.reshape(h, (img * img, c))
    h = ir.call("reduce_mean", h, axis=0)
    h = ir.reshape(h, (1, c))
    logits = _linear(h, params, rng, "head", c, n_classes)
    return logits, params


def build_transformer(seed=0, T=16, d=64, heads=2, layers=2, n_classes=32):
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    x = ir.Var("x", (T, d))
    h = x
    dh = d // heads
    for i in range(layers):
        hn = _layernorm(h, params, rng, f"l{i}_ln1", d)
        # per-head QKV projections + attention intrinsic + output proj
        head_outs = []
        for j in range(heads):
            q = _dense_only(hn, params, rng, f"l{i}h{j}_q", d, dh)
            k = _dense_only(hn, params, rng, f"l{i}h{j}_k", d, dh)
            v = _dense_only(hn, params, rng, f"l{i}h{j}_v", d, dh)
            head_outs.append(ir.call("attention", q, k, v))
        cat = ir.call("concat", *head_outs, axis=1)
        o = _linear(cat, params, rng, f"l{i}_o", d, d)
        h = ir.call("add", h, o)
        hn2 = _layernorm(h, params, rng, f"l{i}_ln2", d)
        f = _linear(hn2, params, rng, f"l{i}_fc1", d, 2 * d)
        f = ir.call("relu", f)
        f = _linear(f, params, rng, f"l{i}_fc2", 2 * d, d)
        h = ir.call("add", h, f)
    logits = _linear(h, params, rng, "logits", d, n_classes)
    return logits, params


APPLICATIONS = {
    "EfficientNet": (build_efficientnet, "MxNet"),
    "LSTM-WLM": (build_lstm_wlm, "PyTorch"),
    "MobileNet-V2": (build_mobilenet_v2, "PyTorch"),
    "ResMLP": (build_resmlp, "PyTorch"),
    "ResNet-20": (build_resnet20, "MxNet"),
    "Transformer": (build_transformer, "PyTorch"),
}
