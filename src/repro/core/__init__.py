"""D2A core: compiler IR, e-graph flexible matching, ILA formalism,
code generation, and compilation-results validation."""
