"""Unified telemetry: spans, a metrics registry, and Perfetto trace export.

Every layer of the stack used to carry its own ad-hoc timing — the
Executor's ``stage_seconds`` dict, the serving layer's ``_rejected``
counter dict, the campaign's per-mutant ``seconds`` — with no way to
answer "where did this request's p95 actually go?" *across* layers. This
module is the one subsystem they all report into:

* **Spans** — nestable, ``trace_id``-correlated timed regions with a
  context-manager/decorator API (:meth:`Telemetry.span`, :func:`traced`)
  plus an explicit-timestamps form (:meth:`Telemetry.record_span`) for
  regions measured across threads (a request's queue wait starts on the
  submitting thread and ends on the dispatch thread). Spans land in a
  bounded ring buffer — saturation *drops the oldest and counts the drop*
  (:attr:`Telemetry.spans_dropped`); there is no silent truncation — and
  export as Chrome ``trace_event`` JSON (:meth:`Telemetry.export_trace`)
  loadable in Perfetto / ``chrome://tracing``, so one served request
  renders as a single correlated flame: queue wait -> admission ->
  coalesce -> prepack -> dispatch -> sim tail -> readback ->
  de-interleave.

* **Metrics registry** (:class:`MetricsRegistry`) — named counters,
  gauges, and **streaming-percentile histograms** (p50/p95/p99 via the
  P-square algorithm: five markers per quantile, O(1) per observation, no
  stored samples), snapshot-able to JSON (:meth:`Telemetry.export_metrics`)
  and dumpable as Prometheus-style text (:meth:`Telemetry.prometheus_text`).
  Components own *scoped* registries (one per Executor / CosimServer)
  attached to the process-wide :data:`TELEMETRY` singleton by weakref, so
  a global snapshot sees every live component without components sharing
  mutable state.

* **Tracing is disabled by default** and the disabled fast path is one
  attribute check: ``TELEMETRY.enabled``. Hot paths guard on it before
  building any span arguments, and :meth:`Telemetry.span` returns a
  shared no-op context manager when disabled — the disabled mode
  allocates nothing (pinned by the zero-allocation smoke test and the
  ``serving_telemetry_overhead`` bench row). Metrics counters are *not*
  gated: they replace pre-existing always-on accounting (stage timers,
  reject counts) at the same cost.

Metric naming convention (checked by :func:`check_metric_names` and the
CI schema step; see ``docs/observability.md``):

    <layer>.<name>[.<name>]   — lowercase ``[a-z0-9_]`` segments joined
                                 by dots; the first segment is the owning
                                 layer (``serving``, ``pipeline``,
                                 ``executor``, ``fragments``,
                                 ``campaign``, ``telemetry``); unit
                                 suffixes ``_s``/``_ms``/``_us``/
                                 ``_cycles``/``_ratio`` where applicable.

Span names follow the same convention; a span's ``cat`` (trace category)
is its first segment, so Perfetto can filter one layer's lane.
"""
from __future__ import annotations

import functools
import json
import os
import re
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

#: the documented metric/span naming convention (docs/observability.md)
NAME_LAYERS = ("serving", "pipeline", "executor", "fragments", "campaign",
               "telemetry")
NAME_RE = re.compile(
    r"^(" + "|".join(NAME_LAYERS) + r")\.[a-z][a-z0-9_]*(\.[a-z][a-z0-9_]*)*$"
)

#: perf_counter origin for trace timestamps (microseconds since import)
_EPOCH = time.perf_counter()


def _now_us() -> float:
    return (time.perf_counter() - _EPOCH) * 1e6


def check_metric_names(names: Iterable[str]) -> List[str]:
    """Return the names violating the documented convention (empty = ok)."""
    return [n for n in names if not NAME_RE.match(n)]


# ---------------------------------------------------------------------------
# Streaming percentiles: the P-square algorithm (Jain & Chlamtac 1985)
# ---------------------------------------------------------------------------


class _P2:
    """One quantile estimated online with five markers — O(1) memory and
    O(1) per observation, no stored samples. Below five observations the
    estimate is the exact order statistic of what has been seen."""

    __slots__ = ("q", "n", "heights", "pos", "want", "dwant")

    def __init__(self, q: float):
        self.q = float(q)
        self.n = 0
        self.heights: List[float] = []
        self.pos = [1.0, 2.0, 3.0, 4.0, 5.0]
        self.want = [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0]
        self.dwant = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]

    def add(self, x: float) -> None:
        self.n += 1
        h = self.heights
        if self.n <= 5:
            h.append(x)
            h.sort()
            return
        # locate the cell and clamp the extremes
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and not (h[k] <= x < h[k + 1]):
                k += 1
        pos, want = self.pos, self.want
        for i in range(k + 1, 5):
            pos[i] += 1.0
        for i in range(5):
            want[i] += self.dwant[i]
        for i in (1, 2, 3):
            d = want[i] - pos[i]
            if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or (
                d <= -1.0 and pos[i - 1] - pos[i] < -1.0
            ):
                d = 1.0 if d > 0 else -1.0
                # parabolic prediction, linear fallback when non-monotone
                hp = h[i] + d / (pos[i + 1] - pos[i - 1]) * (
                    (pos[i] - pos[i - 1] + d)
                    * (h[i + 1] - h[i]) / (pos[i + 1] - pos[i])
                    + (pos[i + 1] - pos[i] - d)
                    * (h[i] - h[i - 1]) / (pos[i] - pos[i - 1])
                )
                if not (h[i - 1] < hp < h[i + 1]):
                    j = i + (1 if d > 0 else -1)
                    hp = h[i] + d * (h[j] - h[i]) / (pos[j] - pos[i])
                h[i] = hp
                pos[i] += d

    def value(self) -> float:
        if self.n == 0:
            return float("nan")
        h = self.heights
        if self.n <= 5:
            # exact small-sample quantile (linear interpolation, like numpy)
            idx = self.q * (len(h) - 1)
            lo = int(idx)
            hi = min(lo + 1, len(h) - 1)
            return h[lo] + (h[hi] - h[lo]) * (idx - lo)
        return h[2]


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic accumulator (float increments allowed: stage seconds)."""

    __slots__ = ("name", "labels", "_v", "_lock")

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = labels
        self._v = 0.0
        self._lock = threading.Lock()

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._v += v

    @property
    def value(self) -> float:
        return self._v

    def reset(self) -> None:
        with self._lock:
            self._v = 0.0

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "counter", "value": self._v}


class Gauge:
    """Last-write-wins level (queue depth, backlog cycles); ``set_max``
    keeps a running maximum (worst relative error)."""

    __slots__ = ("name", "labels", "_v", "_lock")

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = labels
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        self._v = float(v)

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._v += v

    def set_max(self, v: float) -> None:
        with self._lock:
            if v > self._v:
                self._v = float(v)

    @property
    def value(self) -> float:
        return self._v

    def reset(self) -> None:
        self._v = 0.0

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "gauge", "value": self._v}


class Histogram:
    """Streaming distribution: count/sum/min/max plus P-square estimates of
    p50/p95/p99 — percentiles without storing samples."""

    __slots__ = ("name", "labels", "count", "sum", "min", "max", "_p2s",
                 "_lock")

    QUANTILES = (0.5, 0.95, 0.99)

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._init()

    def _init(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._p2s = [_P2(q) for q in self.QUANTILES]

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            for p2 in self._p2s:
                p2.add(v)

    def percentile(self, q: float) -> float:
        """The streaming estimate for one of the tracked quantiles."""
        for p2 in self._p2s:
            if p2.q == q:
                return p2.value()
        raise KeyError(f"histogram tracks {self.QUANTILES}, not {q}")

    def reset(self) -> None:
        with self._lock:
            self._init()

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            if self.count == 0:
                return {"type": "histogram", "count": 0, "sum": 0.0}
            return {
                "type": "histogram",
                "count": self.count,
                "sum": self.sum,
                "mean": self.sum / self.count,
                "min": self.min,
                "max": self.max,
                "p50": self._p2s[0].value(),
                "p95": self._p2s[1].value(),
                "p99": self._p2s[2].value(),
            }


class MetricsRegistry:
    """Named metrics for one component, get-or-create by (name, labels).

    Components (an Executor, a CosimServer) own a registry scoped by a
    unique name and attach it to the process singleton
    (:meth:`Telemetry.attach`) so global snapshots see every live
    component; the component keeps direct references to its hot metrics,
    so reads/increments never pay a registry lookup.
    """

    _seq = 0
    _seq_lock = threading.Lock()

    def __init__(self, scope: str = ""):
        if scope:
            with MetricsRegistry._seq_lock:
                MetricsRegistry._seq += 1
                scope = f"{scope}{MetricsRegistry._seq}"
        self.scope = scope
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], Any] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: Dict[str, str]):
        key = (name, tuple(sorted(labels.items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, dict(labels))
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r}{labels} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}"
                )
            return m

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels: str) -> Histogram:
        return self._get(Histogram, name, labels)

    def find(self, name: str) -> List[Any]:
        """Every metric registered under ``name`` (any label set)."""
        with self._lock:
            return [m for (n, _), m in self._metrics.items() if n == name]

    def names(self) -> List[str]:
        with self._lock:
            return sorted({n for (n, _) in self._metrics})

    def reset(self) -> None:
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m.reset()

    def snapshot(self) -> List[Dict[str, Any]]:
        """One entry per metric: name, scope, labels, type + values."""
        with self._lock:
            metrics = list(self._metrics.values())
        return [
            dict(name=m.name, scope=self.scope, labels=dict(m.labels),
                 **m.snapshot())
            for m in metrics
        ]


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------


class _NoopSpan:
    """The disabled-mode span: a single shared instance, no state, no
    allocation. ``set`` swallows late-bound args."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args) -> None:
        pass


_NOOP_SPAN = _NoopSpan()


class _Span:
    """An enabled span: records wall-clock at enter/exit, inherits the
    thread's current trace id and span stack (nesting), and lands in the
    owning :class:`Telemetry` ring buffer on exit."""

    __slots__ = ("_tel", "name", "trace_id", "args", "_t0")

    def __init__(self, tel: "Telemetry", name: str,
                 trace_id: Optional[Any], args: Dict[str, Any]):
        self._tel = tel
        self.name = name
        self.trace_id = trace_id
        self.args = args
        self._t0 = 0.0

    def set(self, **args: Any) -> None:
        """Attach args discovered after the span opened (e.g. outcome)."""
        self.args.update(args)

    def __enter__(self) -> "_Span":
        tel = self._tel
        tls = tel._tls
        if self.trace_id is None:
            self.trace_id = getattr(tls, "trace", None)
        stack = getattr(tls, "stack", None)
        if stack is None:
            stack = tls.stack = []
        if stack:
            self.args.setdefault("parent", stack[-1].name)
        stack.append(self)
        self._t0 = _now_us()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = _now_us()
        tls = self._tel._tls
        stack = getattr(tls, "stack", None)
        if stack and stack[-1] is self:
            stack.pop()
        self._tel._emit(self.name, self._t0, t1 - self._t0,
                        self.trace_id, self.args)
        return False


class _TraceCtx:
    """Context manager binding the thread-local current trace id (spans
    opened inside inherit it unless they pass their own)."""

    __slots__ = ("_tel", "_trace", "_prev")

    def __init__(self, tel: "Telemetry", trace_id: Any):
        self._tel = tel
        self._trace = trace_id
        self._prev = None

    def __enter__(self):
        tls = self._tel._tls
        self._prev = getattr(tls, "trace", None)
        tls.trace = self._trace
        return self

    def __exit__(self, *exc):
        self._tel._tls.trace = self._prev
        return False


class Telemetry:
    """The process-wide telemetry hub: enable/disable, the span ring
    buffer, trace export, and the global + attached metrics registries.
    Thread-safe throughout; see the module docstring."""

    DEFAULT_CAPACITY = 16384

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.enabled = False
        self._capacity = int(capacity)
        self._ring: "deque[Dict[str, Any]]" = deque()
        self._lock = threading.Lock()
        self.spans_recorded = 0
        self.spans_dropped = 0
        self._tls = threading.local()
        #: tid map: real thread idents and synthetic track names -> small
        #: stable ints, with display names for trace metadata
        self._tids: Dict[Any, int] = {}
        self._tid_names: Dict[int, str] = {}
        self.metrics = MetricsRegistry()
        self._attached: List[Any] = []  # weakrefs to component registries

    # -- lifecycle -------------------------------------------------------
    def enable(self, capacity: Optional[int] = None) -> None:
        if capacity is not None:
            self._capacity = int(capacity)
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Clear spans + drop counters + the global registry (attached
        component registries are owned by their components)."""
        with self._lock:
            self._ring.clear()
            self.spans_recorded = 0
            self.spans_dropped = 0
        self.metrics.reset()

    # -- registries ------------------------------------------------------
    def attach(self, registry: MetricsRegistry) -> MetricsRegistry:
        """Register a component registry (held by weakref) so global
        snapshots include it for as long as the component lives."""
        import weakref

        with self._lock:
            self._attached.append(weakref.ref(registry))
        return registry

    def registries(self) -> List[MetricsRegistry]:
        out = [self.metrics]
        with self._lock:
            live = []
            for ref in self._attached:
                reg = ref()
                if reg is not None:
                    live.append(ref)
                    out.append(reg)
            self._attached = live
        return out

    def counter(self, name: str, **labels: str) -> Counter:
        return self.metrics.counter(name, **labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self.metrics.gauge(name, **labels)

    def histogram(self, name: str, **labels: str) -> Histogram:
        return self.metrics.histogram(name, **labels)

    # -- spans -----------------------------------------------------------
    def span(self, name: str, trace_id: Optional[Any] = None,
             **args: Any):
        """Open a timed region (use as a context manager). Disabled mode
        returns the shared no-op span — zero allocation when called with
        only the name."""
        if not self.enabled:
            return _NOOP_SPAN
        return _Span(self, name, trace_id, args)

    def trace(self, trace_id: Any) -> _TraceCtx:
        """Bind the thread's current trace id for the enclosed region."""
        return _TraceCtx(self, trace_id)

    def current_trace(self) -> Optional[Any]:
        return getattr(self._tls, "trace", None)

    def record_span(self, name: str, t0_s: float, t1_s: float,
                    trace_id: Optional[Any] = None,
                    track: Optional[str] = None, **args: Any) -> None:
        """Record a span from explicit ``time.perf_counter()`` endpoints —
        for regions measured across threads (queue wait) or discovered
        after the fact. ``track`` names a synthetic timeline (e.g. one
        lane per in-flight request) instead of the calling thread. A
        ``trace_id`` of None inherits the thread's bound trace."""
        if not self.enabled:
            return
        if trace_id is None:
            trace_id = getattr(self._tls, "trace", None)
        t0 = (t0_s - _EPOCH) * 1e6
        self._emit(name, t0, max(0.0, (t1_s - t0_s)) * 1e6, trace_id, args,
                   track=track)

    def _tid_for(self, key: Any, display: str) -> int:
        tid = self._tids.get(key)
        if tid is None:
            tid = len(self._tids) + 1
            self._tids[key] = tid
            self._tid_names[tid] = display
        return tid

    def _emit(self, name: str, ts_us: float, dur_us: float,
              trace_id: Optional[Any], args: Dict[str, Any],
              track: Optional[str] = None) -> None:
        if track is not None:
            tkey, display = ("track", track), track
        else:
            t = threading.current_thread()
            tkey, display = t.ident, t.name
        ev = {
            "name": name,
            "ts": ts_us,
            "dur": dur_us,
            "tid_key": (tkey, display),
        }
        if trace_id is not None:
            ev["trace_id"] = trace_id
        if args:
            ev["args"] = args
        with self._lock:
            if len(self._ring) >= self._capacity:
                self._ring.popleft()
                self.spans_dropped += 1
            self._ring.append(ev)
            self.spans_recorded += 1

    def spans(self) -> List[Dict[str, Any]]:
        """A snapshot of the ring buffer (oldest first)."""
        with self._lock:
            return list(self._ring)

    def drain_spans(self) -> List[Dict[str, Any]]:
        """Return and clear the buffered spans (the sharded campaign's
        worker-side export: each mutant's spans ship with its result)."""
        with self._lock:
            out = list(self._ring)
            self._ring.clear()
        return out

    def ingest(self, spans: Sequence[Dict[str, Any]],
               source: str = "remote") -> None:
        """Merge spans exported by another process (``drain_spans`` on a
        sharded worker) into this buffer, re-keyed onto per-source
        timelines so worker lanes stay distinct in the exported trace."""
        for ev in spans:
            ev = dict(ev)
            key = ev.get("tid_key")
            display = key[1] if isinstance(key, (tuple, list)) else "thread"
            ev["tid_key"] = (("ingest", source, tuple(key) if key else None),
                             f"{source}:{display}")
            with self._lock:
                if len(self._ring) >= self._capacity:
                    self._ring.popleft()
                    self.spans_dropped += 1
                self._ring.append(ev)
                self.spans_recorded += 1

    # -- export ----------------------------------------------------------
    def trace_events(self) -> List[Dict[str, Any]]:
        """Chrome ``trace_event`` list: one complete ("X") event per span
        plus process/thread metadata. ``trace_id`` rides in ``args`` so
        Perfetto's search correlates one request/mutant across threads."""
        pid = os.getpid()
        events: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": "repro"},
        }]
        spans = self.spans()
        seen_tids: Dict[int, str] = {}
        for ev in spans:
            key, display = ev["tid_key"]
            tid = self._tid_for(key if not isinstance(key, list) else tuple(key),
                                display)
            seen_tids[tid] = display
            args = dict(ev.get("args", {}))
            if "trace_id" in ev:
                args["trace_id"] = ev["trace_id"]
            out = {
                "name": ev["name"],
                "cat": ev["name"].split(".", 1)[0],
                "ph": "X",
                "ts": ev["ts"],
                "dur": ev["dur"],
                "pid": pid,
                "tid": tid,
            }
            if args:
                out["args"] = args
            events.append(out)
        for tid, display in sorted(seen_tids.items()):
            events.append({
                "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                "args": {"name": display},
            })
        return events

    def export_trace(self, path: str) -> str:
        """Write the Perfetto/chrome://tracing-loadable JSON trace."""
        data = {
            "traceEvents": self.trace_events(),
            "displayTimeUnit": "ms",
            "otherData": {
                "spans_recorded": self.spans_recorded,
                "spans_dropped": self.spans_dropped,
                "capacity": self._capacity,
            },
        }
        with open(path, "w") as f:
            json.dump(data, f)
            f.write("\n")
        return path

    def metrics_snapshot(self) -> List[Dict[str, Any]]:
        """Every metric of every live registry, plus the telemetry
        subsystem's own span accounting."""
        entries: List[Dict[str, Any]] = []
        for reg in self.registries():
            entries.extend(reg.snapshot())
        entries.append({
            "name": "telemetry.spans_recorded", "scope": "", "labels": {},
            "type": "counter", "value": float(self.spans_recorded),
        })
        entries.append({
            "name": "telemetry.spans_dropped", "scope": "", "labels": {},
            "type": "counter", "value": float(self.spans_dropped),
        })
        return entries

    def export_metrics(self, path: str) -> str:
        data = {
            "schema": 1,
            "generated_unix": time.time(),
            "metrics": self.metrics_snapshot(),
        }
        with open(path, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    def prometheus_text(self) -> str:
        """Prometheus exposition-style dump (``.`` -> ``_`` in names, the
        scope as a label; histograms expose count/sum/quantile series)."""
        lines: List[str] = []
        for e in self.metrics_snapshot():
            base = e["name"].replace(".", "_")
            labels = dict(e["labels"])
            if e.get("scope"):
                labels["scope"] = e["scope"]

            def fmt(extra: Dict[str, str] = {}) -> str:
                lab = {**labels, **extra}
                if not lab:
                    return ""
                inner = ",".join(
                    f'{k}="{v}"' for k, v in sorted(lab.items()))
                return "{" + inner + "}"

            if e["type"] == "histogram":
                lines.append(f"{base}_count{fmt()} {e.get('count', 0)}")
                lines.append(f"{base}_sum{fmt()} {e.get('sum', 0.0)}")
                for q in ("p50", "p95", "p99"):
                    if q in e:
                        lines.append(
                            f"{base}{fmt({'quantile': '0.' + q[1:]})} {e[q]}")
            else:
                lines.append(f"{base}{fmt()} {e['value']}")
        return "\n".join(lines) + "\n"

    def check_names(self) -> List[str]:
        """Metric names violating the documented convention, across every
        live registry (the CI schema check)."""
        names = set()
        for reg in self.registries():
            names.update(reg.names())
        return check_metric_names(sorted(names))


#: the process-wide singleton every layer reports into
TELEMETRY = Telemetry()


def traced(name: str, **args: Any) -> Callable:
    """Decorator form of :meth:`Telemetry.span`: times every call of the
    wrapped function (no-op while telemetry is disabled)."""

    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not TELEMETRY.enabled:
                return fn(*a, **kw)
            with TELEMETRY.span(name, **args):
                return fn(*a, **kw)

        return wrapper

    return deco


# convenience module-level aliases (hot paths use TELEMETRY directly)
span = TELEMETRY.span
trace = TELEMETRY.trace
record_span = TELEMETRY.record_span
enable = TELEMETRY.enable
disable = TELEMETRY.disable
