"""Compilation-results validation (Section 2.3 / 4.4).

Three verification tasks (Figure 3), adapted as recorded in DESIGN.md §3:

* **VT1** — compiler-IR ILA vs the compiler implementation. Our IR
  interpreter *is* the executable IR-ILA model; VT1 checks it per-intrinsic
  against independent NumPy implementations (modular, per-instruction).
* **VT2** — program-fragment equivalence between the compiler-IR fragment
  and the accelerator fragment **over abstract data types** (fp32 stands in
  for the symbolic datatype, mirroring the paper's proofs that abstract away
  numerics): both sides evaluated with ideal semantics must agree exactly.
  The finite-domain *exhaustive* variant (Table 3 analogue) enumerates the
  whole input lattice for small shapes — a decidable, complete check.
* **VT3** — accelerator ILA vs implementation. With no RTL available, the
  implementation is whatever numerics-matched fast path (TPU Pallas kernel)
  the target ships; both are bit-accurate in the custom numeric and must
  agree.

Plus **simulation-based mapping validation** (Table 2): relative Frobenius
error of the ILA simulation (custom numerics) against the fp32 IR
interpreter over N random inputs.

All three tasks (and Table 2) run **generically over the target registry**:
each ``AcceleratorTarget`` declares its VT2 fragment pairs, VT3 checks and
mapping cases, and the runners here enumerate them — a newly registered
backend is validated with no edits to this module.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..accel.target import VT2Case  # noqa: F401  (re-export; registers targets)
from . import ir
from .ila import TARGETS


def frob_rel_err(ref: np.ndarray, out: np.ndarray) -> float:
    """Error = ||ref - out||_F / ||ref||_F (Section 4.4.1)."""
    ref = np.asarray(ref, np.float64)
    out = np.asarray(out, np.float64)
    d = np.linalg.norm(ref)
    return float(np.linalg.norm(ref - out) / d) if d > 0 else 0.0


# ---------------------------------------------------------------------------
# VT1: compiler-IR ILA (interpreter) vs independent implementations
# ---------------------------------------------------------------------------


def _np_dense(x, w):
    return x @ w.T


def _np_bias_add(x, b):
    return x + b


def _np_layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    va = x.var(-1, keepdims=True)
    return (x - mu) / np.sqrt(va + eps) * g + b


def _np_conv2d(x, w, strides, padding):
    n, h, wd, c = x.shape
    kh, kw, ci, co = w.shape
    sh, sw = strides
    ph, pw = padding
    xp = np.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    oh = (h + 2 * ph - kh) // sh + 1
    ow = (wd + 2 * pw - kw) // sw + 1
    out = np.zeros((n, oh, ow, co), np.float64)
    for i in range(oh):
        for j in range(ow):
            patch = xp[:, i * sh : i * sh + kh, j * sw : j * sw + kw, :]
            out[:, i, j, :] = np.tensordot(patch, w, axes=([1, 2, 3], [0, 1, 2]))
    return out


VT1_CASES = {
    "dense": (
        lambda r: (r.standard_normal((5, 7)).astype(np.float32),
                   r.standard_normal((3, 7)).astype(np.float32)),
        _np_dense,
    ),
    "bias_add": (
        lambda r: (r.standard_normal((5, 3)).astype(np.float32),
                   r.standard_normal((3,)).astype(np.float32)),
        _np_bias_add,
    ),
    "layer_norm": (
        lambda r: (r.standard_normal((5, 8)).astype(np.float32),
                   r.standard_normal((8,)).astype(np.float32),
                   r.standard_normal((8,)).astype(np.float32)),
        _np_layer_norm,
    ),
    "conv2d": (
        lambda r: (r.standard_normal((2, 6, 6, 3)).astype(np.float32),
                   r.standard_normal((3, 3, 3, 4)).astype(np.float32)),
        lambda x, w: _np_conv2d(x, w, (1, 1), (0, 0)),
    ),
}


def vt1_check(op: str, n: int = 20, seed: int = 0, tol: float = 1e-4) -> bool:
    """Modular per-instruction check: IR-ILA (interpreter) vs independent
    NumPy implementation, over random inputs."""
    gen, np_impl = VT1_CASES[op]
    rng = np.random.default_rng(seed)
    for _ in range(n):
        args = gen(rng)
        vs = tuple(ir.Var(f"_{i}", a.shape) for i, a in enumerate(args))
        attrs = {"strides": (1, 1), "padding": (0, 0)} if op == "conv2d" else {}
        expr = ir.call(op, *vs, **attrs)
        got = np.asarray(ir.interpret(expr, {f"_{i}": a for i, a in enumerate(args)}))
        want = np_impl(*args)
        if frob_rel_err(want, got) > tol:
            return False
    return True


# ---------------------------------------------------------------------------
# VT2: fragment-vs-fragment equivalence over abstract data types
# ---------------------------------------------------------------------------


def vt2_cases(dim_t: int = 16, dim_d: int = 64, targets=None) -> List[VT2Case]:
    """Every VT2 fragment-equivalence case the selected targets declare
    (None = all registered). Case shapes are parameterized by (dim_t, dim_d)
    where the target's operand geometry allows it."""
    out: List[VT2Case] = []
    for t in TARGETS.all(targets):
        out.extend(t.vt2_cases(dim_t, dim_d))
    return out


def vt2_check(case: VT2Case, n: int = 20, seed: int = 0, tol: Optional[float] = None) -> bool:
    """Random simulation over the abstract (fp32) semantics: both fragments
    must agree to float tolerance (the SMT proof's sound-but-incomplete
    testing analogue; the exhaustive variant below is complete).

    ``tol=None`` (the default) resolves the bound from the case itself:
    each target stamps its declared :attr:`AcceleratorTarget.vt2_tol` onto
    the cases it enumerates (0.0 where both fragments evaluate the same
    fp32 expression), replacing the historical hard-coded ``1e-5`` that was
    silently over-tolerant for bit-equal low-precision backends."""
    if tol is None:
        tol = case.tol if case.tol is not None else 1e-5
    rng = np.random.default_rng(seed)
    for _ in range(n):
        env = {k: rng.standard_normal(s).astype(np.float32) for k, s in case.var_shapes.items()}
        r1 = np.asarray(ir.interpret(case.ir_fragment, env))
        r2 = np.asarray(ir.interpret(case.accel_fragment, env))
        if r1.shape != r2.shape or frob_rel_err(r1, r2) > tol:
            return False
    return True


def vt2_exhaustive(case: VT2Case, lattice: Sequence[float], max_vars: int = 64) -> Tuple[bool, int]:
    """Finite-domain complete check (Table 3 analogue): enumerate *every*
    assignment of lattice values to the fragment inputs. Feasible for small
    shapes; returns (equivalent, n_assignments)."""
    names = sorted(case.var_shapes)
    sizes = [int(np.prod(case.var_shapes[n])) for n in names]
    total_elems = sum(sizes)
    if total_elems > max_vars:
        raise ValueError(f"{total_elems} scalar inputs > max_vars={max_vars}")
    count = 0
    for assign in itertools.product(lattice, repeat=total_elems):
        env = {}
        off = 0
        for n, sz in zip(names, sizes):
            env[n] = np.asarray(assign[off : off + sz], np.float32).reshape(case.var_shapes[n])
            off += sz
        r1 = np.asarray(ir.interpret(case.ir_fragment, env))
        r2 = np.asarray(ir.interpret(case.accel_fragment, env))
        count += 1
        if r1.shape != r2.shape or not np.allclose(r1, r2, atol=1e-5):
            return False, count
    return True, count


# ---------------------------------------------------------------------------
# VT3: accelerator ILA vs implementation
# ---------------------------------------------------------------------------
#
# With no RTL available, each target declares its own implementation checks
# (ILA vs the numerics-matched Pallas kernel it ships); this runner just
# enumerates whatever the registry declares.


def vt3_results(targets=None) -> Dict[str, Dict[str, Tuple[bool, float]]]:
    """Run every declared VT3 check: {target: {check: (ok, worst_abs_dev)}}.
    Targets with no separate implementation declare no checks (empty dict)."""
    out: Dict[str, Dict[str, Tuple[bool, float]]] = {}
    for t in TARGETS.all(targets):
        out[t.name] = {name: fn() for name, fn in t.vt3_checks.items()}
    return out


# ---------------------------------------------------------------------------
# Simulation-based mapping validation (Table 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MappingValidation:
    accelerator: str
    operation: str
    avg_err: float
    std_err: float
    n_inputs: int


def validate_mappings(n_inputs: int = 100, seed: int = 0, targets=None) -> List[MappingValidation]:
    """Table 2: per-mapping relative error statistics over random inputs,
    for every (accelerator, operation) case the selected targets declare."""
    out = []
    for t in TARGETS.all(targets):
        rng = np.random.default_rng(seed)
        for opname, case in t.mapping_cases(rng):
            errs = []
            for _ in range(n_inputs):
                ref, got = case()
                errs.append(frob_rel_err(np.asarray(ref), np.asarray(got)))
            out.append(
                MappingValidation(
                    t.display_name, opname, float(np.mean(errs)), float(np.std(errs)), n_inputs
                )
            )
    return out
