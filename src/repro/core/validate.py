"""Compilation-results validation (Section 2.3 / 4.4).

Three verification tasks (Figure 3), adapted as recorded in DESIGN.md §3:

* **VT1** — compiler-IR ILA vs the compiler implementation. Our IR
  interpreter *is* the executable IR-ILA model; VT1 checks it per-intrinsic
  against independent NumPy implementations (modular, per-instruction).
* **VT2** — program-fragment equivalence between the compiler-IR fragment
  and the accelerator fragment **over abstract data types** (fp32 stands in
  for the symbolic datatype, mirroring the paper's proofs that abstract away
  numerics): both sides evaluated with ideal semantics must agree exactly.
  The finite-domain *exhaustive* variant (Table 3 analogue) enumerates the
  whole input lattice for small shapes — a decidable, complete check.
* **VT3** — accelerator ILA vs implementation. With no RTL available, the
  implementation is the TPU Pallas fast path; both are bit-accurate in the
  custom numeric and must agree.

Plus **simulation-based mapping validation** (Table 2): relative Frobenius
error of the ILA simulation (custom numerics) against the fp32 IR
interpreter over N random inputs.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import ir
from .codegen import Executor
from ..accel import flexasr as fa
from ..accel import hlscnn as hc
from ..accel import vta as vt
from ..accel import numerics
from ..kernels import ops as kops


def frob_rel_err(ref: np.ndarray, out: np.ndarray) -> float:
    """Error = ||ref - out||_F / ||ref||_F (Section 4.4.1)."""
    ref = np.asarray(ref, np.float64)
    out = np.asarray(out, np.float64)
    d = np.linalg.norm(ref)
    return float(np.linalg.norm(ref - out) / d) if d > 0 else 0.0


# ---------------------------------------------------------------------------
# VT1: compiler-IR ILA (interpreter) vs independent implementations
# ---------------------------------------------------------------------------


def _np_dense(x, w):
    return x @ w.T


def _np_bias_add(x, b):
    return x + b


def _np_layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    va = x.var(-1, keepdims=True)
    return (x - mu) / np.sqrt(va + eps) * g + b


def _np_conv2d(x, w, strides, padding):
    n, h, wd, c = x.shape
    kh, kw, ci, co = w.shape
    sh, sw = strides
    ph, pw = padding
    xp = np.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    oh = (h + 2 * ph - kh) // sh + 1
    ow = (wd + 2 * pw - kw) // sw + 1
    out = np.zeros((n, oh, ow, co), np.float64)
    for i in range(oh):
        for j in range(ow):
            patch = xp[:, i * sh : i * sh + kh, j * sw : j * sw + kw, :]
            out[:, i, j, :] = np.tensordot(patch, w, axes=([1, 2, 3], [0, 1, 2]))
    return out


VT1_CASES = {
    "dense": (
        lambda r: (r.standard_normal((5, 7)).astype(np.float32),
                   r.standard_normal((3, 7)).astype(np.float32)),
        _np_dense,
    ),
    "bias_add": (
        lambda r: (r.standard_normal((5, 3)).astype(np.float32),
                   r.standard_normal((3,)).astype(np.float32)),
        _np_bias_add,
    ),
    "layer_norm": (
        lambda r: (r.standard_normal((5, 8)).astype(np.float32),
                   r.standard_normal((8,)).astype(np.float32),
                   r.standard_normal((8,)).astype(np.float32)),
        _np_layer_norm,
    ),
    "conv2d": (
        lambda r: (r.standard_normal((2, 6, 6, 3)).astype(np.float32),
                   r.standard_normal((3, 3, 3, 4)).astype(np.float32)),
        lambda x, w: _np_conv2d(x, w, (1, 1), (0, 0)),
    ),
}


def vt1_check(op: str, n: int = 20, seed: int = 0, tol: float = 1e-4) -> bool:
    """Modular per-instruction check: IR-ILA (interpreter) vs independent
    NumPy implementation, over random inputs."""
    gen, np_impl = VT1_CASES[op]
    rng = np.random.default_rng(seed)
    for _ in range(n):
        args = gen(rng)
        vs = tuple(ir.Var(f"_{i}", a.shape) for i, a in enumerate(args))
        attrs = {"strides": (1, 1), "padding": (0, 0)} if op == "conv2d" else {}
        expr = ir.call(op, *vs, **attrs)
        got = np.asarray(ir.interpret(expr, {f"_{i}": a for i, a in enumerate(args)}))
        want = np_impl(*args)
        if frob_rel_err(want, got) > tol:
            return False
    return True


# ---------------------------------------------------------------------------
# VT2: fragment-vs-fragment equivalence over abstract data types
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class VT2Case:
    """A compiler-IR fragment and its accelerator fragment, as IR exprs over
    shared Vars — both interpreted with ideal (abstract-datatype) semantics."""

    name: str
    ir_fragment: ir.Expr
    accel_fragment: ir.Expr
    var_shapes: Dict[str, Tuple[int, ...]]


def vt2_cases(dim_t: int = 16, dim_d: int = 64) -> List[VT2Case]:
    a = ir.Var("a", (dim_t, dim_d))
    w = ir.Var("w", (dim_d, dim_d))
    c = ir.Var("c", (dim_d,))
    lin = VT2Case(
        "linear",
        ir.bias_add(ir.dense(a, w), c),
        ir.call("fasr_linear", a, w, c),
        {"a": (dim_t, dim_d), "w": (dim_d, dim_d), "c": (dim_d,)},
    )
    T = ir.Var("T", (dim_t, dim_d))
    pool_ir = ir.call(
        "reduce_max", ir.call("windows", T, wh=2, ww=1, sh=2, sw=1), axis=(2, 3)
    )
    pool_acc = ir.call("fasr_load", ir.call("fasr_maxpool", ir.call("fasr_store", T)))
    pool = VT2Case("maxpool", pool_ir, pool_acc, {"T": (dim_t, dim_d)})
    x = ir.Var("x", (1, 8, 8, 4))
    wc = ir.Var("wc", (3, 3, 4, 8))
    conv = VT2Case(
        "conv2d",
        ir.conv2d(x, wc, (1, 1), (0, 0)),
        ir.call("hlscnn_conv2d", x, wc, strides=(1, 1), padding=(0, 0)),
        {"x": (1, 8, 8, 4), "wc": (3, 3, 4, 8)},
    )
    g = ir.Var("g", (dim_d,))
    be = ir.Var("be", (dim_d,))
    ln = VT2Case(
        "layernorm",
        ir.call("layer_norm", a, g, be, eps=1e-5),
        ir.call("fasr_layernorm", a, g, be, eps=1e-5),
        {"a": (dim_t, dim_d), "g": (dim_d,), "be": (dim_d,)},
    )
    d2 = VT2Case(
        "vta-gemm",
        ir.dense(a, w),
        ir.call("vta_gemm", a, w),
        {"a": (dim_t, dim_d), "w": (dim_d, dim_d)},
    )
    return [lin, pool, conv, ln, d2]


def vt2_check(case: VT2Case, n: int = 20, seed: int = 0, tol: float = 1e-5) -> bool:
    """Random simulation over the abstract (fp32) semantics: both fragments
    must agree to float tolerance (the SMT proof's sound-but-incomplete
    testing analogue; the exhaustive variant below is complete)."""
    rng = np.random.default_rng(seed)
    for _ in range(n):
        env = {k: rng.standard_normal(s).astype(np.float32) for k, s in case.var_shapes.items()}
        r1 = np.asarray(ir.interpret(case.ir_fragment, env))
        r2 = np.asarray(ir.interpret(case.accel_fragment, env))
        if r1.shape != r2.shape or frob_rel_err(r1, r2) > tol:
            return False
    return True


def vt2_exhaustive(case: VT2Case, lattice: Sequence[float], max_vars: int = 64) -> Tuple[bool, int]:
    """Finite-domain complete check (Table 3 analogue): enumerate *every*
    assignment of lattice values to the fragment inputs. Feasible for small
    shapes; returns (equivalent, n_assignments)."""
    names = sorted(case.var_shapes)
    sizes = [int(np.prod(case.var_shapes[n])) for n in names]
    total_elems = sum(sizes)
    if total_elems > max_vars:
        raise ValueError(f"{total_elems} scalar inputs > max_vars={max_vars}")
    count = 0
    for assign in itertools.product(lattice, repeat=total_elems):
        env = {}
        off = 0
        for n, sz in zip(names, sizes):
            env[n] = np.asarray(assign[off : off + sz], np.float32).reshape(case.var_shapes[n])
            off += sz
        r1 = np.asarray(ir.interpret(case.ir_fragment, env))
        r2 = np.asarray(ir.interpret(case.accel_fragment, env))
        count += 1
        if r1.shape != r2.shape or not np.allclose(r1, r2, atol=1e-5):
            return False, count
    return True, count


# ---------------------------------------------------------------------------
# VT3: accelerator ILA vs implementation (Pallas kernels)
# ---------------------------------------------------------------------------


def vt3_linear(n: int = 5, seed: int = 0) -> float:
    """FlexASR ILA LinearLayer vs the af_gemm Pallas kernel: both project
    onto the same AdaptivFloat lattice — max abs deviation returned."""
    rng = np.random.default_rng(seed)
    worst = 0.0
    for _ in range(n):
        x = rng.standard_normal((16, 64)).astype(np.float32)
        w = (rng.standard_normal((32, 64)) * 0.1).astype(np.float32)
        b = (rng.standard_normal((32,)) * 0.1).astype(np.float32)
        cmds, rd = fa.build_linear_fragment(x, w, b)
        ila_out = np.asarray(rd(fa.flexasr.simulate(cmds)))
        kern_out = np.asarray(kops.af_linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)))
        worst = max(worst, float(np.abs(ila_out - kern_out).max()))
    return worst


def vt3_gemm(n: int = 5, seed: int = 0) -> bool:
    """VTA ILA GEMM vs the int8_gemm Pallas kernel: exact equality."""
    rng = np.random.default_rng(seed)
    for _ in range(n):
        a = rng.integers(-100, 100, (24, 48)).astype(np.float32)
        b = rng.integers(-100, 100, (20, 48)).astype(np.float32)
        cmds, rd = vt.build_gemm_fragment(a, b)
        ila_out = np.asarray(rd(vt.vta.simulate(cmds)))
        kern_out = np.asarray(
            kops.int8_gemm(jnp.asarray(a, jnp.int8), jnp.asarray(b, jnp.int8))
        )
        if not np.array_equal(ila_out, kern_out.astype(np.float32)):
            return False
    return True


# ---------------------------------------------------------------------------
# Simulation-based mapping validation (Table 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MappingValidation:
    accelerator: str
    operation: str
    avg_err: float
    std_err: float
    n_inputs: int


def _table2_ops(seed=0):
    rng = np.random.default_rng(seed)

    def gemm_case():
        a = rng.integers(-100, 100, (16, 64)).astype(np.float32)
        b = rng.integers(-100, 100, (16, 64)).astype(np.float32)
        cmds, rd = vt.build_gemm_fragment(a, b)
        out = rd(vt.vta.simulate(cmds))
        return a @ b.T, out

    def conv_case():
        x = rng.standard_normal((1, 12, 12, 8)).astype(np.float32)
        w = (rng.standard_normal((3, 3, 8, 16)) * 0.1).astype(np.float32)
        cmds, rd = hc.build_conv2d_fragment(x, w, (1, 1), (0, 0), wgt_bits=16)
        out = rd(hc.hlscnn.simulate(cmds))
        ref = ir._conv2d(jnp.asarray(x), jnp.asarray(w), (1, 1), (0, 0))
        return ref, out

    def linear_case():
        x = rng.standard_normal((16, 64)).astype(np.float32)
        w = (rng.standard_normal((64, 64)) * 0.1).astype(np.float32)
        b = (rng.standard_normal((64,)) * 0.1).astype(np.float32)
        cmds, rd = fa.build_linear_fragment(x, w, b)
        return x @ w.T + b, rd(fa.flexasr.simulate(cmds))

    def lstm_case():
        x = (rng.standard_normal((16, 32)) * 0.5).astype(np.float32)
        wi = (rng.standard_normal((64, 32)) * 0.3).astype(np.float32)
        wh = (rng.standard_normal((64, 16)) * 0.3).astype(np.float32)
        b = (rng.standard_normal((64,)) * 0.1).astype(np.float32)
        cmds, rd = fa.build_lstm_fragment(x, wi, wh, b)
        ref = ir._lstm(jnp.asarray(x[:, None]), jnp.asarray(wi), jnp.asarray(wh), jnp.asarray(b))[:, 0]
        return ref, rd(fa.flexasr.simulate(cmds))

    def ln_case():
        x = rng.standard_normal((16, 64)).astype(np.float32)
        g = rng.standard_normal((64,)).astype(np.float32)
        be = (rng.standard_normal((64,)) * 0.1).astype(np.float32)
        cmds, rd = fa.build_layernorm_fragment(x, g, be)
        mu = x.mean(-1, keepdims=True)
        va = x.var(-1, keepdims=True)
        return (x - mu) / np.sqrt(va + 1e-5) * g + be, rd(fa.flexasr.simulate(cmds))

    def maxpool_case():
        # device-representable inputs (written into the AF8 buffer), as the
        # paper's 0.00% row implies
        x = np.asarray(numerics.af_quantize(
            jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32)), fa.AF))
        cmds, rd = fa.build_pool_fragment(x, "max")
        return x.reshape(8, 2, 64).max(1), rd(fa.flexasr.simulate(cmds))

    def meanpool_case():
        x = rng.standard_normal((16, 64)).astype(np.float32)
        cmds, rd = fa.build_pool_fragment(x, "mean")
        return x.reshape(8, 2, 64).mean(1), rd(fa.flexasr.simulate(cmds))

    def attn_case():
        q = rng.standard_normal((8, 64)).astype(np.float32)
        k = rng.standard_normal((16, 64)).astype(np.float32)
        v = rng.standard_normal((16, 64)).astype(np.float32)
        cmds, rd = fa.build_attention_fragment(q, k, v)
        ref = ir._attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
        return ref, rd(fa.flexasr.simulate(cmds))

    return [
        ("VTA", "GEMM", gemm_case),
        ("HLSCNN", "Conv2D", conv_case),
        ("FlexASR", "LinearLayer", linear_case),
        ("FlexASR", "LSTM", lstm_case),
        ("FlexASR", "LayerNorm", ln_case),
        ("FlexASR", "MaxPool", maxpool_case),
        ("FlexASR", "MeanPool", meanpool_case),
        ("FlexASR", "Attention", attn_case),
    ]


def validate_mappings(n_inputs: int = 100, seed: int = 0) -> List[MappingValidation]:
    """Table 2: per-mapping relative error statistics over random inputs."""
    out = []
    for accel, opname, case in _table2_ops(seed):
        errs = []
        for _ in range(n_inputs):
            ref, got = case()
            errs.append(frob_rel_err(np.asarray(ref), np.asarray(got)))
        out.append(
            MappingValidation(accel, opname, float(np.mean(errs)), float(np.std(errs)), n_inputs)
        )
    return out
