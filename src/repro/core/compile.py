"""The D2A compilation flow driver (Figure 2 / Figure 4).

DSL program (an IR expression from ``repro.core.apps`` or a model importer)
-> e-graph -> equality saturation over compiler-IR + IR-accelerator rewrites
-> cost-based extraction -> an executable program with accelerator
intrinsics, runnable through ``codegen.Executor``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

from . import ir
from .egraph import EGraph, extract, run_rewrites, default_cost
from . import rules as R


@dataclasses.dataclass
class CompileResult:
    program: ir.Expr
    stats: Dict[str, Any]
    accelerator_calls: Dict[str, int]
    n_relay_ops: int


def compile_program(
    e: ir.Expr,
    targets: Optional[Sequence[str]] = None,
    flexible: bool = True,
    iters: int = 12,
    node_limit: int = 40_000,
    cost_fn=default_cost,
) -> CompileResult:
    """Run flexible (or exact) matching and extract the best program.

    ``targets`` selects registered accelerator targets by name; the default
    (None) compiles against *every* registered target — a newly registered
    backend starts receiving offloads with no compiler change.
    """
    eg = EGraph()
    root = eg.add_expr(e)
    stats = run_rewrites(eg, R.all_rewrites(targets, flexible), iters, node_limit)
    best = extract(eg, root, cost_fn)
    stats["n_nodes"] = eg.n_nodes
    return CompileResult(
        program=best,
        stats=stats,
        accelerator_calls=ir.accelerator_calls(best),
        n_relay_ops=ir.count_ops(e),
    )
