"""The D2A compilation flow driver (Figure 2 / Figure 4).

DSL program (an IR expression from ``repro.core.apps`` or a model importer)
-> e-graph -> equality saturation over compiler-IR + IR-accelerator rewrites
-> cost-based extraction -> an executable program with accelerator
intrinsics, runnable through ``codegen.Executor``.

Extraction is **cost-driven and registry-driven**: every accelerator op is
priced by the :class:`~repro.accel.target.CostModel` its owning
:class:`~repro.accel.target.AcceleratorTarget` declares, so two targets
claiming the same computation are ranked by estimated cycles instead of the
proof-of-concept uniform accel-op cost. A :class:`SelectionPolicy` resolves
the ranking knobs: ``cheapest`` (default) takes the CostModel's word,
``prefer`` routes claimable ops to the named targets, ``forbid`` vetoes
targets outright (their rewrites are not even saturated).

Accel-op costs live in a bounded band ``1 + cycles/(cycles + K) in [1, 2)``
— strictly monotone in estimated cycles, so competing targets order
correctly, yet always below the cheapest host op (2.0), so *whether* to
offload is still decided exactly as the paper's maximize-accelerator-ops
objective does; the CostModel only decides *where*.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

from . import ir, rules as R
from .egraph import EGraph, extract_best, host_op_cost, run_rewrites
from .ila import TARGETS

#: cycle-normalization knee: r = cycles / (cycles + K) keeps accel-op costs
#: in [1, 2) while staying strictly monotone in estimated cycles
_CYCLE_KNEE = 1e6
#: accel ops of non-preferred targets move to this band under ``prefer``:
#: still far below heavy/medium host compute (100/1000) — so dense, conv,
#: reductions etc. stay offloaded where no preferred target can claim them
#: — but above a preferred target plus several cheap-glue ops
#: (pattern-introduction overhead like the dense -> dense+0 bias rewrite
#: must not mask the preference). Deliberate consequence: cheap-glue-band
#: host ops (cost 2.0: elementwise mul/sigmoid/relu/add) return to the
#: host rather than run on a non-preferred target.
_DEMOTED_BASE = 8.0


@dataclasses.dataclass(frozen=True)
class SelectionPolicy:
    """How extraction resolves ops claimed by multiple targets.

    ``cheapest`` (the default, empty policy): the target whose CostModel
    predicts the fewest cycles wins. ``prefer`` demotes every *other*
    target's intrinsics to a costlier band: heavy and medium host compute
    (dense/conv/lstm/attention/reductions/normalization) still offloads to
    a non-preferred target when no preferred one claims it, but cheap-glue
    elementwise ops (host cost 2.0 — mul, sigmoid, relu, add) return to
    the host instead of running on a non-preferred accelerator. ``forbid``
    removes the named targets entirely: their rewrites are not saturated
    and any of their intrinsics already in the e-graph price to infinity.
    """

    prefer: Tuple[str, ...] = ()
    forbid: Tuple[str, ...] = ()

    def describe(self) -> str:
        if not self.prefer and not self.forbid:
            return "cheapest"
        parts = []
        if self.prefer:
            parts.append(f"prefer={list(self.prefer)}")
        if self.forbid:
            parts.append(f"forbid={list(self.forbid)}")
        return " ".join(parts)


def make_cost_fn(
    policy: Optional[SelectionPolicy] = None,
    targets: Optional[Sequence[Any]] = None,
) -> Callable:
    """Build the extraction cost function for ``targets`` (AcceleratorTarget
    objects; default = every registered target) under ``policy``.

    Accelerator intrinsics are priced from the owning target's CostModel
    (cycle estimate from the e-class shape analysis of the operands);
    targets without a declared model fall back to the flat accel-op cost.
    Host ops keep :func:`~repro.core.egraph.host_op_cost`'s bands.
    """
    policy = policy or SelectionPolicy()
    targets = TARGETS.all() if targets is None else list(targets)
    prefer, forbid = set(policy.prefer), set(policy.forbid)
    by_op: Dict[str, Tuple[str, Any]] = {}
    for t in targets:
        for op in t.intrinsics:
            by_op[op] = (t.name, t.cost_model)

    def cost_fn(head, child_costs, child_shapes=()) -> float:
        base = sum(child_costs)
        if head[0] != "op":
            return base + 0.01
        op = head[1]
        ent = by_op.get(op)
        if ent is None:
            if op in ir.ACCEL_OPS:
                # an accelerator op no selected target claims: inextricable
                return math.inf
            return base + host_op_cost(op)
        tname, model = ent
        if tname in forbid:
            return math.inf
        band = _DEMOTED_BASE if (prefer and tname not in prefer) else 1.0
        if model is None or not model.covers(op) or any(
            s is None for s in child_shapes
        ):
            return base + band      # shape-blind fallback: flat accel cost
        cycles = model.estimate(op, dict(head[2]), child_shapes).cycles
        return base + band + cycles / (cycles + _CYCLE_KNEE)

    return cost_fn


@dataclasses.dataclass
class CompileResult:
    program: ir.Expr
    stats: Dict[str, Any]
    accelerator_calls: Dict[str, int]
    n_relay_ops: int


def compile_program(
    e: ir.Expr,
    targets: Optional[Sequence[str]] = None,
    flexible: bool = True,
    iters: int = 12,
    node_limit: int = 40_000,
    cost_fn=None,
    policy: Optional[SelectionPolicy] = None,
) -> CompileResult:
    """Run flexible (or exact) matching and extract the best program.

    ``targets`` selects registered accelerator targets by name; the default
    (None) compiles against *every* registered target — a newly registered
    backend starts receiving offloads with no compiler change. ``policy``
    steers which target wins an op claimed by several (see
    :class:`SelectionPolicy`); ``cost_fn`` overrides the registry cost
    function entirely (e.g. :func:`~repro.core.egraph.default_cost` for the
    paper's uniform proof-of-concept costs).

    ``stats["extraction"]`` reports the selection outcome: total extracted
    cost, the policy applied, and per-target op wins (how many intrinsic
    invocations each target received in the extracted program).
    """
    policy = policy or SelectionPolicy()
    selected = [t for t in TARGETS.all(targets) if t.name not in set(policy.forbid)]
    eg = EGraph()
    root = eg.add_expr(e)
    rewrites = R.all_rewrites(targets, flexible, exclude=policy.forbid)
    stats = run_rewrites(eg, rewrites, iters, node_limit)
    if cost_fn is None:
        cost_fn = make_cost_fn(policy, selected)
    best, cost = extract_best(eg, root, cost_fn)
    stats["n_nodes"] = eg.n_nodes
    calls = ir.accelerator_calls(best)
    stats["extraction"] = {
        "cost": cost,
        "policy": policy.describe(),
        "op_wins": {t: n for t, n in calls.items() if n > 0},
    }
    return CompileResult(
        program=best,
        stats=stats,
        accelerator_calls=calls,
        n_relay_ops=ir.count_ops(e),
    )
