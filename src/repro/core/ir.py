"""Compiler IR for the D2A flow.

A small, pure (side-effect-free) tensor IR in the spirit of Relay/Glenside:
immutable expression trees with shape inference and a reference interpreter
(the "IR interpreter" used as the validation oracle in the paper, Section
4.4). Expressions are hashable so they can be hash-consed into the e-graph.

Op vocabulary (the subset the paper's mappings and rewrites need):

  dense(x, w)              -- x:(M,K) @ w:(N,K)^T -> (M,N)   (Relay nn.dense)
  bias_add(x, b)           -- broadcast add over last axis
  add / sub / mul / maximum
  relu / sigmoid / tanh / negative
  reshape(x; shape)        -- static target shape
  transpose(x; axes)
  conv2d(x, w; strides, padding)  -- NHWC x, HWIO w (HLSCNN layout)
  im2col(x; kh, kw, sh, sw)       -- NHWC -> (N*OH*OW, KH*KW*C) patches
  windows(x; wh, ww, sh, sw)      -- 2D sliding windows (Glenside `windows`)
  reduce_max(x; axis) / reduce_mean(x; axis) / reduce_sum(x; axis)
  layer_norm(x, g, b; eps)
  softmax(x; axis)
  zeros(; shape) / ones(; shape)
  concat(xs...; axis)
  split_time(x; t)         -- helper for LSTM unrolling patterns
  lstm_cell(x, h, c, wi, wh, b)   -- one LSTM time step (fused gates)
  lstm(x, wi, wh, b)       -- full LSTM over time (the coarse FlexASR op)
  attention(q, k, v)       -- scaled dot-product attention (FlexASR op)

Accelerator ops (targets of IR-accelerator rewrites; opaque to IR rewrites):

  fasr_linear / fasr_lstm / fasr_maxpool / fasr_meanpool / fasr_layernorm /
  fasr_attention / fasr_store / fasr_load
  hlscnn_conv2d
  vta_gemm / vta_add / vta_relu

The vocabulary above is the *bundled* set. Plugin accelerator targets extend
it at registration time through :func:`register_accel_op`, which attaches a
shape rule and an ideal (fp32 oracle) evaluation rule for each new intrinsic
— shape inference, the interpreter, the e-graph shape analysis and
``accelerator_calls`` all consult the extension table, so a new backend never
needs to edit this module.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Accelerator-op extension registry (the plugin-target hook)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AccelOpSpec:
    """How the IR layer understands one plugin accelerator intrinsic.

    ``shape(attrs, child_shapes) -> shape`` and ``ideal(attrs, args) -> array``
    may be None for the bundled vocabulary (whose rules are built in below);
    ``counts`` is False for pass-through data-movement markers (store/load)
    that must not be tallied as accelerator invocations.
    """

    target: str
    shape: Optional[Callable] = None
    ideal: Optional[Callable] = None
    counts: bool = True


_ACCEL_EXT: Dict[str, AccelOpSpec] = {}


def register_accel_op(
    op: str,
    target: str,
    shape_fn: Optional[Callable] = None,
    eval_fn: Optional[Callable] = None,
    counts: bool = True,
) -> Optional[AccelOpSpec]:
    """Register an accelerator intrinsic op for ``target``.

    Makes the op a member of :data:`ACCEL_OPS` (cost model + Executor
    dispatch), attributes it to ``target`` in :func:`accelerator_calls`, and
    — when ``shape_fn``/``eval_fn`` are given — teaches shape inference and
    the ideal interpreter its semantics. Returns the spec this registration
    displaced (None for a first registration), so a transient re-registration
    — the fault campaign's mutant swap — can restore it exactly.
    """
    prev = _ACCEL_EXT.get(op)
    _ACCEL_EXT[op] = AccelOpSpec(target, shape_fn, eval_fn, counts)
    ACCEL_OPS.add(op)
    return prev


def unregister_accel_op(op: str) -> Optional[AccelOpSpec]:
    """Inverse of :func:`register_accel_op` (synthetic-target and mutant
    cleanup). Returns the removed spec (None if ``op`` was unknown) so the
    caller can later :func:`restore_accel_op` it, leaving the extension
    table bit-identical."""
    spec = _ACCEL_EXT.pop(op, None)
    if spec is not None:
        ACCEL_OPS.discard(op)
    return spec


def restore_accel_op(op: str, spec: Optional[AccelOpSpec]) -> None:
    """Reinstate the exact spec object a register/unregister displaced
    (``spec=None`` removes the op). With :func:`unregister_accel_op`'s
    return value this makes transient registrations — fault-campaign mutant
    swaps, synthetic test targets — leave the table bit-identical."""
    if spec is None:
        unregister_accel_op(op)
    else:
        _ACCEL_EXT[op] = spec
        ACCEL_OPS.add(op)


def accel_op_shape_fn(op: str) -> Optional[Callable]:
    spec = _ACCEL_EXT.get(op)
    return spec.shape if spec is not None else None


def accel_op_target(op: str) -> Optional[str]:
    """The target an intrinsic op invokes, or None for non-invoking ops."""
    spec = _ACCEL_EXT.get(op)
    if spec is not None:
        return spec.target if spec.counts else None
    return _BUILTIN_TRIGGER.get(op)


# --------------------------------------------------------------------------
# Expressions
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Expr:
    """Base class; all exprs are immutable and hashable."""


@dataclasses.dataclass(frozen=True)
class Var(Expr):
    name: str
    shape: Tuple[int, ...]
    dtype: str = "float32"

    def __repr__(self):
        return f"%{self.name}"


@dataclasses.dataclass(frozen=True)
class Const(Expr):
    """Scalar/small constant embedded in the program (by value)."""

    value: float

    def __repr__(self):
        return f"{self.value}"


@dataclasses.dataclass(frozen=True)
class Call(Expr):
    op: str
    args: Tuple[Expr, ...]
    attrs: Tuple[Tuple[str, Any], ...] = ()

    def attr(self, key, default=None):
        for k, v in self.attrs:
            if k == key:
                return v
        return default

    def __repr__(self):
        a = " ".join(repr(x) for x in self.args)
        if self.attrs:
            kv = " ".join(f":{k} {v}" for k, v in self.attrs)
            return f"({self.op} {a} {kv})"
        return f"({self.op} {a})"


def call(op: str, *args: Expr, **attrs) -> Call:
    return Call(op, tuple(args), tuple(sorted(attrs.items())))


# Sugar constructors -------------------------------------------------------

def dense(x, w):
    return call("dense", x, w)


def bias_add(x, b):
    return call("bias_add", x, b)


def add(a, b):
    return call("add", a, b)


def mul(a, b):
    return call("mul", a, b)


def reshape(x, shape):
    return call("reshape", x, shape=tuple(shape))


def conv2d(x, w, strides=(1, 1), padding=(0, 0)):
    return call("conv2d", x, w, strides=tuple(strides), padding=tuple(padding))


# --------------------------------------------------------------------------
# Shape inference
# --------------------------------------------------------------------------


class ShapeError(Exception):
    pass


def _conv_out(h, k, s, p):
    return (h + 2 * p - k) // s + 1


def infer_shape(e: Expr, env: Optional[Dict[str, Tuple[int, ...]]] = None) -> Tuple[int, ...]:
    """Infer the output shape of ``e``. ``env`` overrides Var shapes."""
    memo: Dict[Expr, Tuple[int, ...]] = {}

    def rec(x: Expr) -> Tuple[int, ...]:
        if x in memo:
            return memo[x]
        s = _infer(x, rec, env)
        memo[x] = s
        return s

    return rec(e)


def check_expr(
    e: Expr, env: Optional[Dict[str, Tuple[int, ...]]] = None
) -> Tuple[int, ...]:
    """Pre-codegen static checker: validate shapes and dtypes of every
    sub-expression *before* any planner or simulator touches the program.

    Walks ``e`` in postorder, shape-checking each node (so the error names
    the innermost inconsistent call, with its operand shapes, instead of
    whatever downstream planner trips first) and verifying that every
    accelerator call targets a registered op and consumes float32 operands
    (the command-stream payload dtype). Returns the program's output shape;
    raises :class:`ShapeError` with per-node context on violation.
    """
    memo: Dict[Expr, Tuple[int, ...]] = {}

    def rec(x: Expr) -> Tuple[int, ...]:
        if x in memo:
            return memo[x]
        s = _infer(x, rec, env)
        memo[x] = s
        return s

    for x in postorder(e):
        if isinstance(x, Var) and x.dtype != "float32":
            raise ShapeError(
                f"check: var %{x.name} has dtype {x.dtype!r}; the IR "
                "carries float32 tensors only"
            )
        if not isinstance(x, Call):
            continue
        if x.op in ACCEL_OPS and accel_op_target(x.op) is None \
                and x.op not in ("fasr_store", "fasr_load"):
            raise ShapeError(
                f"check: accelerator op {x.op!r} has no registered target"
            )
        try:
            shape = rec(x)
        except ShapeError as err:
            arg_shapes = [rec(a) for a in x.args]
            raise ShapeError(
                f"check: {x.op}{tuple(arg_shapes)} "
                f"attrs={dict(x.attrs)}: {err}"
            ) from err
        if any(int(d) <= 0 for d in shape):
            raise ShapeError(
                f"check: {x.op} infers non-positive dimension in {shape}"
            )
    return rec(e)


def _infer(x: Expr, rec, env) -> Tuple[int, ...]:
    if isinstance(x, Var):
        if env and x.name in env:
            return tuple(env[x.name])
        return x.shape
    if isinstance(x, Const):
        return ()
    assert isinstance(x, Call)
    op, args = x.op, x.args
    if op in ("add", "sub", "mul", "maximum"):
        a, b = rec(args[0]), rec(args[1])
        return tuple(np.broadcast_shapes(a, b))
    if op in ("relu", "sigmoid", "tanh", "negative", "softmax"):
        return rec(args[0])
    if op == "dense":
        a, w = rec(args[0]), rec(args[1])
        if a[-1] != w[-1]:
            raise ShapeError(f"dense {a} x {w}")
        return a[:-1] + (w[0],)
    if op == "bias_add":
        return rec(args[0])
    if op == "reshape":
        tgt = tuple(x.attr("shape"))
        src = rec(args[0])
        if int(np.prod(tgt)) != int(np.prod(src)):
            raise ShapeError(f"reshape {src} -> {tgt}")
        return tgt
    if op == "transpose":
        src = rec(args[0])
        axes = x.attr("axes")
        return tuple(src[a] for a in axes)
    if op == "conv2d":
        n, h, w_, c = rec(args[0])
        kh, kw, ci, co = rec(args[1])
        (sh, sw), (ph, pw) = x.attr("strides"), x.attr("padding")
        if ci != c:
            raise ShapeError(f"conv2d channels {c} vs {ci}")
        return (n, _conv_out(h, kh, sh, ph), _conv_out(w_, kw, sw, pw), co)
    if op == "dw_conv2d":
        n, h, w_, c = rec(args[0])
        kh, kw, ci, _ = rec(args[1])
        (sh, sw), (ph, pw) = x.attr("strides"), x.attr("padding")
        return (n, _conv_out(h, kh, sh, ph), _conv_out(w_, kw, sw, pw), c)
    if op == "pad2d":
        n, h, w_, c = rec(args[0])
        ph, pw = x.attr("pad")
        return (n, h + 2 * ph, w_ + 2 * pw, c)
    if op == "im2col":
        n, h, w_, c = rec(args[0])
        kh, kw = x.attr("kh"), x.attr("kw")
        sh, sw = x.attr("sh"), x.attr("sw")
        oh, ow = _conv_out(h, kh, sh, 0), _conv_out(w_, kw, sw, 0)
        return (n * oh * ow, kh * kw * c)
    if op == "windows":
        h, w_ = rec(args[0])
        wh, ww = x.attr("wh"), x.attr("ww")
        sh, sw = x.attr("sh"), x.attr("sw")
        return (_conv_out(h, wh, sh, 0), _conv_out(w_, ww, sw, 0), wh, ww)
    if op in ("reduce_max", "reduce_mean", "reduce_sum"):
        src = rec(args[0])
        ax = x.attr("axis")
        axes = (ax,) if isinstance(ax, int) else tuple(ax)
        axes = tuple(a % len(src) for a in axes)
        return tuple(s for i, s in enumerate(src) if i not in axes)
    if op == "layer_norm":
        return rec(args[0])
    if op == "zeros" or op == "ones":
        return tuple(x.attr("shape"))
    if op == "concat":
        shapes = [rec(a) for a in args]
        ax = x.attr("axis")
        out = list(shapes[0])
        out[ax] = sum(s[ax] for s in shapes)
        return tuple(out)
    if op == "lstm_cell":
        xs, hs = rec(args[0]), rec(args[1])
        return hs
    if op == "lstm":
        xs = rec(args[0])  # (T, B, I)
        wh = rec(args[2])  # (4H, H)
        return (xs[0], xs[1], wh[1])
    if op == "attention":
        q, k, v = rec(args[0]), rec(args[1]), rec(args[2])
        return q[:-1] + (v[-1],)
    if op == "flatten_window":
        # (OH, OW, WH, WW) -> (OH*OW, WH*WW)
        oh, ow, wh, ww = rec(args[0])
        return (oh * ow, wh * ww)
    # ---- accelerator ops: shapes follow their IR equivalents -------------
    if op == "fasr_linear":
        return _infer(call("bias_add", call("dense", args[0], args[1]), args[2]), rec, env)
    if op == "fasr_lstm":
        return _infer(call("lstm", *args), rec, env)
    if op in ("fasr_maxpool",):
        t = rec(args[0])  # (T, B) rows pooled pairwise over axis 0
        return (t[0] // 2,) + t[1:]
    if op in ("fasr_meanpool",):
        t = rec(args[0])
        return (t[0] // 2,) + t[1:]
    if op == "fasr_layernorm":
        return rec(args[0])
    if op == "fasr_attention":
        return _infer(call("attention", *args), rec, env)
    if op in ("fasr_store", "fasr_load", "vta_store", "vta_load"):
        return rec(args[0])
    if op == "hlscnn_conv2d":
        return _infer(
            call("conv2d", args[0], args[1], strides=x.attr("strides"), padding=x.attr("padding")),
            rec,
            env,
        )
    if op == "vta_gemm":
        return _infer(call("dense", args[0], args[1]), rec, env)
    if op in ("vta_add",):
        a, b = rec(args[0]), rec(args[1])
        return tuple(np.broadcast_shapes(a, b))
    if op in ("vta_relu",):
        return rec(args[0])
    spec = _ACCEL_EXT.get(op)
    if spec is not None and spec.shape is not None:
        return tuple(spec.shape(dict(x.attrs), [rec(a) for a in args]))
    raise ShapeError(f"unknown op {op}")


# --------------------------------------------------------------------------
# Reference interpreter (the "IR interpreter" oracle of Section 4.4)
# --------------------------------------------------------------------------


def _sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def _lstm_cell(x, h, c, wi, wh, b):
    """Fused-gate LSTM cell: gates = x@wi^T + h@wh^T + b, order i,f,g,o."""
    gates = x @ wi.T + h @ wh.T + b
    hdim = h.shape[-1]
    i = _sigmoid(gates[..., 0 * hdim : 1 * hdim])
    f = _sigmoid(gates[..., 1 * hdim : 2 * hdim])
    g = jnp.tanh(gates[..., 2 * hdim : 3 * hdim])
    o = _sigmoid(gates[..., 3 * hdim : 4 * hdim])
    c2 = f * c + i * g
    h2 = o * jnp.tanh(c2)
    return h2, c2


def _lstm(xs, wi, wh, b):
    T, B, _ = xs.shape
    H = wh.shape[1]
    h = jnp.zeros((B, H), xs.dtype)
    c = jnp.zeros((B, H), xs.dtype)
    outs = []
    for t in range(T):
        h, c = _lstm_cell(xs[t], h, c, wi, wh, b)
        outs.append(h)
    return jnp.stack(outs)


def _windows2d(x, wh, ww, sh, sw):
    H, W = x.shape
    oh, ow = (H - wh) // sh + 1, (W - ww) // sw + 1
    idx_h = jnp.arange(oh)[:, None, None, None] * sh + jnp.arange(wh)[None, None, :, None]
    idx_w = jnp.arange(ow)[None, :, None, None] * sw + jnp.arange(ww)[None, None, None, :]
    return x[idx_h, idx_w]  # (OH, OW, WH, WW)


def _im2col(x, kh, kw, sh, sw):
    N, H, W, C = x.shape
    oh, ow = (H - kh) // sh + 1, (W - kw) // sw + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(x[:, i : i + oh * sh : sh, j : j + ow * sw : sw, :])
    # (N, OH, OW, KH*KW, C) -> (N*OH*OW, KH*KW*C)
    patches = jnp.stack(cols, axis=3)
    return patches.reshape(N * oh * ow, kh * kw * C)


def _conv2d(x, w, strides, padding):
    import jax.lax as lax

    return lax.conv_general_dilated(
        x,
        w,
        window_strides=strides,
        padding=[(padding[0], padding[0]), (padding[1], padding[1])],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _attention(q, k, v):
    d = q.shape[-1]
    s = (q @ jnp.swapaxes(k, -1, -2)) / jnp.sqrt(jnp.asarray(d, q.dtype))
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v


def _fasr_pool(x, kind):
    """FlexASR temporal pooling: pairwise reduce over axis 0 (window (2,1))."""
    T = x.shape[0]
    pairs = x[: T - T % 2].reshape(T // 2, 2, *x.shape[1:])
    if kind == "max":
        return jnp.max(pairs, axis=1)
    return jnp.mean(pairs, axis=1)


# Accelerator ops interpreted with *ideal* (fp32) semantics here; the
# bit-accurate custom-numerics execution lives in repro.accel.* and is
# compared against this oracle by the validation layer.
def interpret(e: Expr, env: Dict[str, Any], accel_exact: bool = True) -> Any:
    """Evaluate expression ``e`` with variable bindings ``env``.

    accel_exact: interpret accelerator ops with exact fp32 semantics
    (abstract-datatype view, as in the paper's VT2 proofs). The numerics-
    accurate path is provided by repro.core.codegen via the ILA simulators.
    """
    memo: Dict[Expr, Any] = {}

    def rec(x: Expr):
        if x in memo:
            return memo[x]
        v = _eval(x, rec, env)
        memo[x] = v
        return v

    return rec(e)


def _eval(x: Expr, rec, env):
    if isinstance(x, Var):
        if x.name not in env:
            raise KeyError(f"unbound var %{x.name}")
        return jnp.asarray(env[x.name])
    if isinstance(x, Const):
        return jnp.asarray(x.value)
    assert isinstance(x, Call)
    op = x.op
    a = [rec(arg) for arg in x.args]
    if op == "add" or op == "vta_add":
        return a[0] + a[1]
    if op == "sub":
        return a[0] - a[1]
    if op == "mul":
        return a[0] * a[1]
    if op == "maximum":
        return jnp.maximum(a[0], a[1])
    if op == "relu" or op == "vta_relu":
        return jnp.maximum(a[0], 0)
    if op == "sigmoid":
        return _sigmoid(a[0])
    if op == "tanh":
        return jnp.tanh(a[0])
    if op == "negative":
        return -a[0]
    if op == "softmax":
        ax = x.attr("axis", -1)
        e_ = jnp.exp(a[0] - jnp.max(a[0], axis=ax, keepdims=True))
        return e_ / jnp.sum(e_, axis=ax, keepdims=True)
    if op == "dense" or op == "vta_gemm":
        return a[0] @ a[1].T
    if op == "bias_add":
        return a[0] + a[1]
    if op == "reshape":
        return a[0].reshape(x.attr("shape"))
    if op == "transpose":
        return jnp.transpose(a[0], x.attr("axes"))
    if op == "conv2d":
        return _conv2d(a[0], a[1], x.attr("strides"), x.attr("padding"))
    if op == "pad2d":
        ph, pw = x.attr("pad")
        return jnp.pad(a[0], ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    if op == "dw_conv2d":
        import jax.lax as lax

        c = a[0].shape[-1]
        p = x.attr("padding")
        # w: (kh, kw, C, 1) -> depthwise (HWIO with feature groups)
        w = jnp.transpose(a[1], (0, 1, 3, 2)).reshape(a[1].shape[0], a[1].shape[1], 1, c)
        return lax.conv_general_dilated(
            a[0], w, window_strides=x.attr("strides"),
            padding=[(p[0], p[0]), (p[1], p[1])],
            dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=c,
        )
    if op == "hlscnn_conv2d":
        return _conv2d(a[0], a[1], x.attr("strides"), x.attr("padding"))
    if op == "im2col":
        return _im2col(a[0], x.attr("kh"), x.attr("kw"), x.attr("sh"), x.attr("sw"))
    if op == "windows":
        return _windows2d(a[0], x.attr("wh"), x.attr("ww"), x.attr("sh"), x.attr("sw"))
    if op == "flatten_window":
        oh, ow, wh, ww = a[0].shape
        return a[0].reshape(oh * ow, wh * ww)
    if op == "reduce_max":
        return jnp.max(a[0], axis=x.attr("axis"))
    if op == "reduce_mean":
        return jnp.mean(a[0], axis=x.attr("axis"))
    if op == "reduce_sum":
        return jnp.sum(a[0], axis=x.attr("axis"))
    if op == "layer_norm" or op == "fasr_layernorm":
        eps = x.attr("eps", 1e-5)
        xx = a[0]
        mu = jnp.mean(xx, axis=-1, keepdims=True)
        var = jnp.var(xx, axis=-1, keepdims=True)
        return (xx - mu) / jnp.sqrt(var + eps) * a[1] + a[2]
    if op == "zeros":
        return jnp.zeros(x.attr("shape"))
    if op == "ones":
        return jnp.ones(x.attr("shape"))
    if op == "concat":
        return jnp.concatenate(a, axis=x.attr("axis"))
    if op == "lstm_cell":
        return _lstm_cell(*a)[0]
    if op == "lstm" or op == "fasr_lstm":
        return _lstm(*a)
    if op == "attention" or op == "fasr_attention":
        return _attention(*a)
    if op == "fasr_linear":
        return a[0] @ a[1].T + a[2]
    if op in ("fasr_store", "fasr_load", "vta_store", "vta_load"):
        return a[0]
    if op == "fasr_maxpool":
        return _fasr_pool(a[0], "max")
    if op == "fasr_meanpool":
        return _fasr_pool(a[0], "mean")
    spec = _ACCEL_EXT.get(op)
    if spec is not None and spec.ideal is not None:
        return spec.ideal(dict(x.attrs), a)
    raise ShapeError(f"interpret: unknown op {op}")


# --------------------------------------------------------------------------
# Traversal helpers
# --------------------------------------------------------------------------


def postorder(e: Expr):
    seen = set()
    out = []

    def rec(x):
        if id(x) in seen:
            return
        seen.add(id(x))
        if isinstance(x, Call):
            for a in x.args:
                rec(a)
        out.append(x)

    rec(e)
    return out


def count_ops(e: Expr, pred: Callable[[Call], bool] = lambda c: True) -> int:
    return sum(1 for x in postorder(e) if isinstance(x, Call) and pred(x))


def accelerator_calls(e: Expr) -> Dict[str, int]:
    """Count accelerator invocations by backend (Table 1 statistic).

    Keys cover every target known to the registry (bundled + plugins), so a
    target that received zero offloads still reports an explicit 0.
    """
    targets = set(_BUILTIN_TRIGGER.values())
    targets.update(s.target for s in _ACCEL_EXT.values())
    out: Dict[str, int] = {t: 0 for t in sorted(targets)}
    for x in postorder(e):
        if isinstance(x, Call):
            t = accel_op_target(x.op)
            if t is not None:
                out[t] += 1
    return out


# Bundled intrinsic -> target attribution (pass-through fasr_store/fasr_load
# deliberately absent: data movement is not an invocation).
_BUILTIN_TRIGGER: Dict[str, str] = {
    "fasr_linear": "flexasr",
    "fasr_lstm": "flexasr",
    "fasr_maxpool": "flexasr",
    "fasr_meanpool": "flexasr",
    "fasr_layernorm": "flexasr",
    "fasr_attention": "flexasr",
    "hlscnn_conv2d": "hlscnn",
    "vta_gemm": "vta",
    "vta_add": "vta",
    "vta_relu": "vta",
}

#: Mutable: plugin targets extend this via :func:`register_accel_op`.
ACCEL_OPS = set(_BUILTIN_TRIGGER) | {"fasr_store", "fasr_load"}
