"""Static verification of ILA models and their command streams.

The ILA is a *formal* software/hardware interface — every instruction is a
pure, traceable state-update function, and every planner emits concrete
command streams. That formality buys analyses that need **zero simulated
commands**: this module traces each ``Instruction.update`` to a jaxpr
(:func:`jax.make_jaxpr` — abstract evaluation only, nothing executes) and
runs three passes over every registered :class:`~repro.accel.target.\
AcceleratorTarget`:

1. **Decode soundness** — the bundled ILAs decode by opcode equality, so
   completeness and disjointness reduce to set checks over the registered
   instruction list versus the opcodes planners actually emit: overlapping
   claims (one opcode, two instructions — the ``decode_alias`` fault
   surface, and the shadowed instruction is unreachable), claims on the
   reserved NOP opcode, and emitted opcodes no instruction decodes.

2. **State dataflow / hazards** — per-instruction read/write sets come out
   of the jaxpr (a state leaf is *read* if its invar feeds any equation,
   *written* if its outvar is not the pass-through invar), then a linear
   walk over planner-emitted :class:`~.ila.PackedStream` probes flags
   reads of never-written state (uninitialized configuration), reports
   carried cross-fragment state (the ``stale_state`` surface) and the
   write-then-read pairs that make a stream order-sensitive (the
   ``cmd_reorder`` sensitivity predicate).

3. **Numeric range analysis** — an interval domain propagated from each
   target's *declared* operand range (``AcceleratorTarget.lint``) through
   its numerics family (:mod:`repro.accel.numerics`): where the interval
   crosses the family's saturation point, wrap/saturation is statically
   reachable ("wrap reachable for \\|x\\| > 4.5") — the ``sat_wrap``
   escape as a report instead of an application-accuracy collapse — and
   :func:`boundary_inputs` turns the reported boundary into targeted
   operands for the co-simulation tiers.

Severity model: ``error`` and ``warn`` are *findings* (golden targets must
have none — the false-positive budget); ``note`` records true facts about
fault surfaces (order sensitivity, carried state, reachable wrap) that are
properties of the design, not defects.

The same machinery gives the fault campaign its tier 0:
:func:`analyze_mutation` compares golden probe streams against a mutant's
host-side stream transform and classifies the difference — opcode/address
rewrites (decode violation), config-payload divergence whose registers are
read downstream (order sensitivity), or bulk-operand corruption (numeric;
deliberately deferred to the simulation tiers).
"""
from __future__ import annotations

import dataclasses
import weakref
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ir
from .ila import ILA, NOP_OPCODE, TARGETS, DataStream, PackedStream

SEVERITIES = ("note", "warn", "error")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One static-analysis result.

    severity  "error" | "warn" (findings) | "note" (fault-surface facts).
    pass_name "decode" | "hazard" | "range".
    subject   the instruction / register / stream the result is about.
    """

    severity: str
    pass_name: str
    target: str
    subject: str
    message: str

    def to_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return (f"[{self.severity:5s}] {self.target}/{self.pass_name} "
                f"{self.subject}: {self.message}")


def severity_at_least(f: Finding, floor: str) -> bool:
    return SEVERITIES.index(f.severity) >= SEVERITIES.index(floor)


# ---------------------------------------------------------------------------
# Instruction effects from jaxprs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InstrEffect:
    """Read/write footprint of one instruction over architectural state,
    extracted from the jaxpr of its update function (no execution)."""

    name: str
    opcode: int
    reads: frozenset            # state keys consumed by any equation
    writes: frozenset           # state keys whose output differs from input
    scalar_writes: frozenset    # writes to ndim-0 registers (configuration)
    buffer_writes: frozenset    # writes to tensor-shaped state
    reads_data: bool            # consumes the command payload
    reads_addr: bool            # consumes the command address

    @property
    def is_config_writer(self) -> bool:
        """Writes configuration registers and nothing else."""
        return bool(self.scalar_writes) and not self.buffer_writes

    @property
    def is_bulk_writer(self) -> bool:
        return bool(self.buffer_writes)


# jaxpr extraction is pure per (ILA, instruction set); cache per instance
_EFFECTS_CACHE: "weakref.WeakKeyDictionary[ILA, List[InstrEffect]]" = (
    weakref.WeakKeyDictionary()
)


def _trace_effect(ila: ILA, ins) -> InstrEffect:
    state = ila.init_state()
    keys = sorted(state)
    jaxpr = jax.make_jaxpr(ins.update)(
        state, jnp.zeros((), jnp.int32), jnp.zeros((ila.vwidth,), jnp.float32)
    )
    invars = jaxpr.jaxpr.invars
    # pytree flatten order: state leaves in sorted-key order, addr, data
    assert len(invars) == len(keys) + 2, (ila.name, ins.name, len(invars))
    by_invar = {id(v): k for v, k in zip(invars, keys)}
    addr_var, data_var = invars[-2], invars[-1]

    consumed = set()
    for eqn in jaxpr.jaxpr.eqns:
        for v in eqn.invars:
            consumed.add(id(v))
    reads = frozenset(k for v, k in zip(invars, keys) if id(v) in consumed)

    outvars = jaxpr.jaxpr.outvars
    assert len(outvars) == len(keys), (ila.name, ins.name, len(outvars))
    writes = set()
    for out, k, inv in zip(outvars, keys, invars):
        if out is not inv:  # pass-through state keeps its invar identity
            writes.add(k)
    scalar = frozenset(k for k in writes if np.ndim(state[k]) == 0)
    return InstrEffect(
        name=ins.name,
        opcode=ins.opcode,
        reads=reads,
        writes=frozenset(writes),
        scalar_writes=scalar,
        buffer_writes=frozenset(writes) - scalar,
        reads_data=id(data_var) in consumed,
        reads_addr=id(addr_var) in consumed,
    )


def effects(ila: ILA) -> List[InstrEffect]:
    """Per-instruction effects for every registered instruction, in
    registration order (duplicate opcodes kept — the decode pass needs
    them). Cached per ILA instance."""
    cached = _EFFECTS_CACHE.get(ila)
    if cached is not None:
        return cached
    out = [_trace_effect(ila, ins) for ins in ila.instructions]
    _EFFECTS_CACHE[ila] = out
    return out


def effects_by_opcode(ila: ILA) -> Dict[int, InstrEffect]:
    """Decode view of :func:`effects`: later registrations win, exactly
    like the ILA's opcode dispatch table."""
    return {e.opcode: e for e in effects(ila)}


# ---------------------------------------------------------------------------
# Probe streams: what the planners actually emit (zero simulation)
# ---------------------------------------------------------------------------


def probe_streams(
    target, seed: int = 0, samples: int = 1
) -> List[Tuple[str, PackedStream]]:
    """Concrete command streams for every planner-backed intrinsic: sampled
    operands run through the planner only — fragment setup plus data
    streams are packed host-side; ``CompiledFragment.setup_state`` stays
    lazy, so **nothing is simulated**. Sampling is crc32-seeded per
    (target, op) so probes are identical across processes."""
    out: List[Tuple[str, PackedStream]] = []
    for op, intr in target.intrinsics.items():
        if intr.planner is None or intr.sample is None:
            continue
        rng = np.random.default_rng(
            zlib.crc32(f"{target.name}:{op}:{seed}".encode())
        )
        ctx = _null_plan_context(intr)
        for _ in range(samples):
            args, attrs = intr.sample(rng)
            vs = tuple(ir.Var(f"_{i}", a.shape) for i, a in enumerate(args))
            x = ir.call(op, *vs, **attrs)
            jobs, _ = intr.planner(ctx, x, [np.asarray(a) for a in args])
            for j in jobs:
                data = (
                    j.data.to_stream()
                    if isinstance(j.data, DataStream)
                    else j.data
                )
                out.append((op, PackedStream.concat([j.frag.setup, data])))
    return out


def _null_plan_context(intr):
    from ..accel.target import PlanContext

    return PlanContext(record=lambda *a, **kw: None, options=dict(intr.options))


# ---------------------------------------------------------------------------
# Pass 1: decode soundness
# ---------------------------------------------------------------------------


def decode_pass(
    target, probes: Sequence[Tuple[str, PackedStream]]
) -> List[Finding]:
    ila = target.ila
    out: List[Finding] = []
    claimed: Dict[int, str] = {}
    for ins in ila.instructions:
        if ins.opcode in claimed:
            out.append(Finding(
                "error", "decode", target.name, ins.name,
                f"opcode {ins.opcode:#x} already decodes to "
                f"{claimed[ins.opcode]!r}; the earlier instruction is "
                f"shadowed and unreachable (decode_alias surface)",
            ))
        else:
            claimed[ins.opcode] = ins.name
        if ins.opcode == NOP_OPCODE and ins.name != "nop":
            out.append(Finding(
                "error", "decode", target.name, ins.name,
                f"claims the reserved NOP opcode {NOP_OPCODE:#x}",
            ))

    emitted: set = set()
    for op, stream in probes:
        for o in np.unique(stream.ops):
            emitted.add(int(o))
            if int(o) not in claimed:
                out.append(Finding(
                    "error", "decode", target.name, op,
                    f"planner emits opcode {int(o):#x} that no "
                    f"instruction decodes",
                ))
    uncovered = sorted(
        ins.name for ins in ila.instructions
        if ins.opcode not in emitted and ins.name != "nop"
    )
    if uncovered and probes:
        out.append(Finding(
            "note", "decode", target.name, ",".join(uncovered),
            "never emitted by the sampled probe streams "
            "(unreachable from the bundled planners)",
        ))
    return out


# ---------------------------------------------------------------------------
# Pass 2: state dataflow / hazards over probe streams
# ---------------------------------------------------------------------------


def hazard_pass(
    target, probes: Sequence[Tuple[str, PackedStream]]
) -> List[Finding]:
    ila = target.ila
    decl = target.lint
    by_op = effects_by_opcode(ila)
    scalar_keys = {k for k, v in ila.init_state().items() if np.ndim(v) == 0}
    exempt = set(decl.reset_valid) | set(decl.carried_state)

    uninit: Dict[Tuple[str, str], str] = {}   # (reader, reg) -> op
    carried: set = set()
    order_pairs: set = set()                  # (writer, reg, reader)
    for op, stream in probes:
        written: set = set()
        for o in stream.ops:
            e = by_op.get(int(o))
            if e is None:
                continue  # decode pass reports undecodable opcodes
            for r in sorted(e.reads):
                if r in written:
                    continue
                if r in decl.carried_state:
                    carried.add(r)
                elif r in e.writes:
                    continue  # read-modify-write of reset state (accumulate)
                elif r not in exempt:
                    uninit.setdefault((e.name, r), op)
            for w in sorted(e.writes & scalar_keys):
                order_pairs.add((e.name, w))
            written |= e.writes

    out: List[Finding] = []
    for (reader, reg), op in sorted(uninit.items()):
        out.append(Finding(
            "warn", "hazard", target.name, f"{reader}/{reg}",
            f"reads {reg!r} before any command in the {op} stream writes "
            f"it (uninitialized state; declare it reset_valid or "
            f"carried_state if intentional)",
        ))
    if carried:
        out.append(Finding(
            "note", "hazard", target.name, ",".join(sorted(carried)),
            "carried across fragment boundaries by declaration "
            "(stale_state fault surface)",
        ))
    # write-then-read over configuration registers: the reorder surface
    sensitive = _order_sensitive_regs(by_op, probes, scalar_keys)
    if sensitive:
        out.append(Finding(
            "note", "hazard", target.name, ",".join(sorted(sensitive)),
            "configuration written then read within one stream — command "
            "order is semantically significant (cmd_reorder surface)",
        ))
    return out


def _order_sensitive_regs(
    by_op, probes: Sequence[Tuple[str, PackedStream]], scalar_keys
) -> set:
    """Scalar registers with a write at position i and a read at j > i in
    some probe stream: reordering the write past the read changes the
    architectural result."""
    sensitive: set = set()
    for _, stream in probes:
        pending: set = set()
        for o in stream.ops:
            e = by_op.get(int(o))
            if e is None:
                continue
            sensitive |= pending & e.reads
            pending |= e.scalar_writes & scalar_keys
    return sensitive


# ---------------------------------------------------------------------------
# Pass 3: numeric range analysis (interval domain)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Interval:
    """Closed interval [lo, hi] — the abstract numeric domain."""

    lo: float
    hi: float

    @property
    def mag(self) -> float:
        return max(abs(self.lo), abs(self.hi))

    def __add__(self, o: "Interval") -> "Interval":
        return Interval(self.lo + o.lo, self.hi + o.hi)

    def __mul__(self, o: "Interval") -> "Interval":
        c = (self.lo * o.lo, self.lo * o.hi, self.hi * o.lo, self.hi * o.hi)
        return Interval(min(c), max(c))

    def scale(self, k: float) -> "Interval":
        return Interval(min(self.lo * k, self.hi * k),
                        max(self.lo * k, self.hi * k))

    def accumulate(self, o: "Interval", depth: int) -> "Interval":
        """Range of a depth-``depth`` sum of products (dot product)."""
        return (self * o).scale(float(depth))

    def clip(self, bound: float) -> "Interval":
        return Interval(max(self.lo, -bound), min(self.hi, bound))


def range_pass(target) -> List[Finding]:
    from ..accel import numerics

    decl = target.lint
    if decl.input_range is None:
        return []
    lo, hi = decl.input_range
    iv = Interval(float(lo), float(hi))
    family = str(target.capabilities.get("numerics", ""))
    sat = numerics.saturation_point(family)
    out: List[Finding] = []
    if iv.mag > sat:
        out.append(Finding(
            "note", "range", target.name, family or "numerics",
            f"wrap reachable for |x| > {sat:g}: declared operand range "
            f"[{lo:g}, {hi:g}] crosses the write-datapath saturation "
            f"point (sat_wrap surface; boundary_inputs() targets it)",
        ))
    return out


def boundary_inputs(target, n: int = 64, seed: int = 0) -> np.ndarray:
    """Targeted co-sim operands straddling the target's saturation point:
    half the values just inside, half just outside (sign-alternating), so
    one op-level diff separates saturate-correct from wrap-faulty
    datapaths — the draws random sampling almost never produces."""
    from ..accel import numerics

    family = str(target.capabilities.get("numerics", ""))
    sat = numerics.saturation_point(family)
    rng = np.random.default_rng(
        zlib.crc32(f"{target.name}:boundary:{seed}".encode())
    )
    mags = sat * rng.uniform(0.8, 1.2, size=n)
    signs = np.where(np.arange(n) % 2 == 0, 1.0, -1.0)
    return (mags * signs).astype(np.float32)


# ---------------------------------------------------------------------------
# Whole-target / whole-registry lint
# ---------------------------------------------------------------------------


def lint_target(target, seed: int = 0, samples: int = 1) -> List[Finding]:
    """All three passes over one target. Raises nothing: trace or planner
    failures become error findings."""
    try:
        probes = probe_streams(target, seed=seed, samples=samples)
    except Exception as e:  # planner bug: report, keep linting the ILA
        probes = []
        return [Finding(
            "error", "decode", target.name, "probes",
            f"probe collection failed: {type(e).__name__}: {e}",
        )] + decode_pass(target, probes) + range_pass(target)
    try:
        effects(target.ila)
    except Exception as e:
        return [Finding(
            "error", "hazard", target.name, "jaxpr",
            f"update-function tracing failed: {type(e).__name__}: {e}",
        )]
    return (decode_pass(target, probes)
            + hazard_pass(target, probes)
            + range_pass(target))


def lint_registry(
    names: Optional[Sequence[str]] = None, seed: int = 0, samples: int = 1
) -> Dict[str, List[Finding]]:
    names = list(names) if names else TARGETS.names()
    return {
        n: lint_target(TARGETS.get(n), seed=seed, samples=samples)
        for n in names
    }


# ---------------------------------------------------------------------------
# Campaign tier 0: classify a mutant's host-side stream transform
# ---------------------------------------------------------------------------


def analyze_mutation(
    target,
    probes: Sequence[Tuple[str, PackedStream]],
    host_xform: Callable,
) -> Tuple[bool, float, str]:
    """Run the golden probe streams through a mutant's host-side transform
    and classify the divergence — still zero simulated commands.

    Returns ``(detected, score, detail)`` where score is the fraction of
    probe streams the static passes flag. Detection rules:

    * opcode or address rewrites — decode soundness violation (the
      transformed stream no longer decodes to the golden instruction
      sequence): ``decode_alias``-class faults;
    * payload divergence on config-writer commands whose written registers
      a later command reads — the order-sensitivity predicate fires:
      ``cmd_reorder``-class faults;
    * payload divergence confined to bulk data-writer commands — numeric
      datapath corruption, *deliberately not* a static detection (value
      faults like ``sat_wrap`` are the simulation tiers' job; the range
      pass reports where to look).
    """
    by_op = effects_by_opcode(target.ila)
    flagged = 0
    bulk_only = 0
    reasons: List[str] = []
    for op, stream in probes:
        ops1, addrs1, data1 = host_xform(
            stream.ops.copy(), stream.addrs.copy(), stream.data.copy()
        )
        ops1 = np.asarray(ops1)
        addrs1 = np.asarray(addrs1)
        data1 = np.asarray(data1)
        if ops1.shape != stream.ops.shape or not np.array_equal(
            ops1, stream.ops
        ):
            flagged += 1
            if len(reasons) < 3:
                reasons.append(f"{op}: opcode stream rewritten"
                               + _first_opcode_diff(stream.ops, ops1, by_op))
            continue
        if not np.array_equal(addrs1, stream.addrs):
            flagged += 1
            if len(reasons) < 3:
                reasons.append(f"{op}: address stream rewritten")
            continue
        rows = np.flatnonzero(np.any(data1 != stream.data, axis=1))
        if rows.size == 0:
            continue
        hit = _config_payload_hazard(stream, rows, by_op)
        if hit is not None:
            flagged += 1
            if len(reasons) < 3:
                reasons.append(f"{op}: {hit}")
        else:
            bulk_only += 1
    if flagged:
        score = flagged / max(len(probes), 1)
        return True, score, "; ".join(reasons)
    detail = "streams identical under transform"
    if bulk_only:
        detail = (f"bulk operand payloads diverge on {bulk_only} stream(s) "
                  "— numeric datapath fault, deferred to simulation tiers")
    return False, 0.0, detail


def _first_opcode_diff(ops0: np.ndarray, ops1: np.ndarray, by_op) -> str:
    if ops0.shape != ops1.shape:
        return f" ({len(ops0)} -> {len(ops1)} commands)"
    i = int(np.flatnonzero(ops0 != ops1)[0])
    a, b = int(ops0[i]), int(ops1[i])
    na = by_op[a].name if a in by_op else f"{a:#x}"
    nb = by_op[b].name if b in by_op else f"{b:#x}"
    return f" (cmd {i}: {na} -> {nb})"


def _config_payload_hazard(
    stream: PackedStream, rows: np.ndarray, by_op
) -> Optional[str]:
    """Does any payload-diverging row configure a register that a later
    command in the stream reads? That is exactly the order-sensitivity
    predicate: the corrupted configuration is architecturally consumed."""
    for i in rows:
        e = by_op.get(int(stream.ops[i]))
        if e is None or not e.is_config_writer:
            continue
        downstream = set()
        for o in stream.ops[i + 1:]:
            later = by_op.get(int(o))
            if later is not None:
                downstream |= later.reads
        hot = sorted(e.scalar_writes & downstream)
        if hot:
            return (f"config payload of {e.name!r} diverges and "
                    f"{hot} are read downstream (order-sensitive)")
    return None
