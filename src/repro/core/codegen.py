"""Code generation + execution for matched programs.

After flexible matching extracts a program containing accelerator intrinsics,
this module plays the role of the paper's BYOC code generator + runtime: each
accelerator op is lowered to an ILA command stream (the "MMIO writes" of
Figure 5d) and either

* ``mode="ila"``     — executed on the ILA simulator, bit-accurate in the
  accelerator's custom numerics (the application-level co-simulation path,
  Section 2.3.2), or
* ``mode="kernel"``  — executed on the TPU-native Pallas fast path with the
  same numeric semantics where the target declares one (deployment path), or
* ``mode="ideal"``   — fp32 reference (the IR interpreter; oracle).

The Executor is **target-agnostic**: every intrinsic dispatches through the
:data:`~repro.core.ila.TARGETS` registry to the planner its
``AcceleratorTarget`` declared (``repro/accel/target.py``). Planners own the
driver-layer tiling (row-chunking, 16x16 tiles, column splits) and return
``SimJob`` lists; this module only schedules and batches them. Adding an
accelerator therefore never touches this file.

Execution engine
----------------

``engine="compiled"`` (default) routes every accelerator invocation through
the fragment-compiler fast path of :mod:`..core.ila`: each op is *planned*
into simulation jobs (CompiledFragment + per-sample DataStream + output
window), jobs sharing a fragment and stream signature are batched through
one ``vmap``-ed simulator call, and fragment setup (weight load) is
simulated once per parameter set and cached in the owning target's
fragment cache. Minibatched evaluation flows through :meth:`Executor.run_many`.

``engine="jit"`` re-derives and scans the full command stream per invocation
(the pre-fragment-compiler behavior); ``engine="eager"`` interprets commands
one by one. Both exist as bit-exact references for the compiled path.

Per-invocation statistics (op, rel-error vs ideal, value ranges) are
collected — the "handy debugging information" the paper's authors gave the
accelerator developers to diagnose the HLSCNN weight-quantization bug —
and aggregated per target by :meth:`Executor.stats_summary`;
:meth:`Executor.cache_info` surfaces per-target warm-cache health for the
serving path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from . import ir
from .ila import TARGETS
from ..accel.target import PlanContext, SimJob  # importing registers bundled targets


@dataclasses.dataclass
class InvocationStat:
    op: str
    backend: str
    rel_err: float
    out_min: float
    out_max: float
    n_commands: int


class Executor:
    """Executes an extracted IR program, offloading accelerator intrinsics.

    ``target_options`` carries per-target execution options keyed by target
    name (e.g. a weight-datatype selection for a backend with configurable
    numerics); planners read them through their
    :class:`~repro.accel.target.PlanContext`.
    """

    def __init__(
        self,
        mode: str = "ila",
        collect_stats: bool = True,
        jit_sim: bool = True,
        engine: Optional[str] = None,
        target_options: Optional[Dict[str, Dict[str, Any]]] = None,
    ):
        assert mode in ("ila", "kernel", "ideal")
        self.mode = mode
        self.collect_stats = collect_stats
        self.engine = engine or ("compiled" if jit_sim else "eager")
        assert self.engine in ("compiled", "jit", "eager")
        self.target_options = {k: dict(v) for k, v in (target_options or {}).items()}
        self.stats: List[InvocationStat] = []

    # ------------------------------------------------------------------
    def run(self, e: ir.Expr, env: Dict[str, Any]):
        memo: Dict[ir.Expr, Any] = {}

        def rec(x: ir.Expr):
            if x in memo:
                return memo[x]
            if isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS:
                args = [np.asarray(rec(a)) for a in x.args]
                v = self._exec_accel(x, args)
            else:
                v = ir._eval(x, rec, env)
            memo[x] = v
            return v

        return rec(e)

    def run_many(self, e: ir.Expr, envs: Sequence[Dict[str, Any]]):
        """Evaluate the program once per environment, batching accelerator
        invocations *across samples*: all B samples' jobs for one IR node
        run through one vmapped simulator call (sharing the node's cached
        fragment), while host glue ops evaluate per sample. Per-sample
        numerics (chunking, AF exponent windows) are identical to B calls
        of :meth:`run`."""
        B = len(envs)
        memo: Dict[ir.Expr, List[Any]] = {}

        def rec(x: ir.Expr) -> List[Any]:
            if x in memo:
                return memo[x]
            if isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS:
                args_b = [rec(a) for a in x.args]
                sample_args = [
                    [np.asarray(args_b[k][s]) for k in range(len(args_b))]
                    for s in range(B)
                ]
                if (
                    self.mode == "ila"
                    and self.engine == "compiled"
                    and TARGETS.has_planner(x.op)
                ):
                    plans, jobs = [], []
                    for s in range(B):
                        s_jobs, assemble = self._plan(x, sample_args[s])
                        plans.append((len(jobs), len(s_jobs), assemble))
                        jobs += s_jobs
                    outs = self._execute_jobs(jobs)
                    v = [asm(outs[o : o + n]) for (o, n, asm) in plans]
                else:
                    v = [self._exec_accel(x, sample_args[s]) for s in range(B)]
            else:
                v = [
                    ir._eval(x, (lambda a, s=s: rec(a)[s]), envs[s])
                    for s in range(B)
                ]
            memo[x] = v
            return v

        return rec(e)

    # ------------------------------------------------------------------
    def _record(self, op, backend, out, ideal, ncmds):
        if not self.collect_stats:
            return
        out = np.asarray(out, np.float64)
        ideal = np.asarray(ideal, np.float64)
        denom = np.linalg.norm(ideal)
        err = float(np.linalg.norm(ideal - out) / denom) if denom > 0 else 0.0
        self.stats.append(
            InvocationStat(op, backend, err, float(out.min()), float(out.max()), ncmds)
        )

    def _ctx(self, target) -> PlanContext:
        return PlanContext(
            record=self._record, options=self.target_options.get(target.name, {})
        )

    def _exec_accel(self, x: ir.Call, args: List[np.ndarray]):
        if self.mode == "ideal":
            return self._ideal(x, args)
        target, intr = TARGETS.intrinsic(x.op)
        if intr.passthrough:
            return args[0]
        if self.mode == "kernel" and intr.kernel is not None:
            return intr.kernel(self._ctx(target), x, args)
        jobs, assemble = self._plan(x, args)
        return assemble(self._execute_jobs(jobs))

    def _ideal(self, x: ir.Call, args):
        vs = [ir.Var(f"_{i}", np.shape(a)) for i, a in enumerate(args)]
        env = {f"_{i}": a for i, a in enumerate(args)}
        return ir.interpret(ir.Call(x.op, tuple(vs), x.attrs), env)

    def _plan(self, x: ir.Call, args) -> Tuple[List[SimJob], Callable]:
        target, intr = TARGETS.intrinsic(x.op)
        if intr.planner is None:
            raise NotImplementedError(
                f"target {target.name!r} declares no planner for {x.op!r}"
            )
        return intr.planner(self._ctx(target), x, args)

    # -- job execution ---------------------------------------------------
    def _execute_jobs(self, jobs: List[SimJob]) -> List[np.ndarray]:
        """Run simulation jobs, batching those that share a fragment and a
        data-stream signature through one vmapped simulator call."""
        results: List[Optional[np.ndarray]] = [None] * len(jobs)
        if self.engine != "compiled":
            for i, j in enumerate(jobs):
                cmds = j.frag.full_commands(j.data)
                ila = j.frag.ila
                st = ila.simulate_jit(cmds) if self.engine == "jit" else ila.simulate(cmds)
                results[i] = np.asarray(j.read(st))[j.window]
            return results
        groups: Dict[Tuple, List[int]] = {}
        for i, j in enumerate(jobs):
            groups.setdefault((id(j.frag), j.data.sig()), []).append(i)
        for idxs in groups.values():
            frag = jobs[idxs[0]].frag
            read = jobs[idxs[0]].read
            if len(idxs) == 1:
                j = jobs[idxs[0]]
                results[idxs[0]] = np.asarray(read(frag.run(j.data)))[j.window]
            else:
                sts = frag.run_batch([jobs[i].data for i in idxs])
                fulls = np.asarray(jax.vmap(read)(sts))
                for bi, i in enumerate(idxs):
                    results[i] = fulls[bi][jobs[i].window]
        return results

    # -- statistics & cache surfacing ------------------------------------
    def reset_stats(self) -> None:
        self.stats.clear()

    def stats_summary(self) -> Dict[str, Dict[str, float]]:
        """Aggregate invocation stats per target: invocation count, total
        interface commands, worst relative error vs the fp32 oracle."""
        out: Dict[str, Dict[str, float]] = {}
        for s in self.stats:
            tname = ir.accel_op_target(s.op) or s.backend
            d = out.setdefault(
                tname, {"invocations": 0, "commands": 0, "max_rel_err": 0.0}
            )
            d["invocations"] += 1
            d["commands"] += s.n_commands
            d["max_rel_err"] = max(d["max_rel_err"], s.rel_err)
        return out

    def cache_info(self, targets: Optional[Sequence[str]] = None) -> Dict[str, Dict]:
        """Per-target warm-cache health: fragment-cache hits/misses plus jit
        trace / compiled-runner counts (serving-path observability)."""
        return {t.name: t.cache_info() for t in TARGETS.all(targets)}
