"""Code generation + execution for matched programs.

After flexible matching extracts a program containing accelerator intrinsics,
this module plays the role of the paper's BYOC code generator + runtime: each
accelerator op is lowered to an ILA command stream (the "MMIO writes" of
Figure 5d) and either

* ``mode="ila"``     — executed on the ILA simulator, bit-accurate in the
  accelerator's custom numerics (the application-level co-simulation path,
  Section 2.3.2), or
* ``mode="kernel"``  — executed on the TPU-native Pallas fast path with the
  same numeric semantics where the target declares one (deployment path), or
* ``mode="ideal"``   — fp32 reference (the IR interpreter; oracle).

The Executor is **target-agnostic**: every intrinsic dispatches through the
:data:`~repro.core.ila.TARGETS` registry to the planner its
``AcceleratorTarget`` declared (``repro/accel/target.py``). Planners own the
driver-layer tiling (row-chunking, 16x16 tiles, column splits) and return
``SimJob`` lists; this module only schedules and batches them. Adding an
accelerator therefore never touches this file.

Execution engine
----------------

``engine="compiled"`` (default) routes every accelerator invocation through
the fragment-compiler fast path of :mod:`..core.ila`: each op is *planned*
into simulation jobs (CompiledFragment + per-sample DataStream + output
window), jobs sharing a fragment and stream signature are batched through
one ``vmap``-ed simulator call, and fragment setup (weight load) is
simulated once per parameter set and cached in the owning target's
fragment cache. Minibatched evaluation flows through :meth:`Executor.run_many`.

``engine="jit"`` re-derives and scans the full command stream per invocation
(the pre-fragment-compiler behavior); ``engine="eager"`` interprets commands
one by one. Both exist as bit-exact references for the compiled path.

``engine="pipelined"`` layers an asynchronous dispatch pipeline on top of
the compiled path: host packing (planner calls + batch stacking, vectorized
numpy that releases the GIL) runs in a pack worker thread for chunk *k+1*
while the main thread dispatches JAX simulation of chunk *k* (JAX dispatch
is async, so readback of chunk *k-1* overlaps both), and results
materialize only at ``assemble()`` barriers. Pipelining reorders
*scheduling* only — per-sample packing, grouping semantics and simulation
are the compiled engine's, so results stay bit-exact and deterministic
(materialization and stat recording follow submission order). Set
``REPRO_ENGINE=pipelined`` to make it the process default.

``engine="fused"`` keeps the pipelined engine's scheduling (pack worker,
async dispatch, assemble barriers) but, per signature group, consults the
owning target for a :class:`~repro.core.ila.FusedRunner` — a registered
fast path that lowers bulk-write + per-sample compute + read-out into one
fused computation on the stream payloads, skipping architectural-state
materialization (see ``docs/simulation.md``). Groups without a declared
runner execute on the compiled path unchanged, so the engine is safe for
every target; the compiled tier remains the bit-exactness oracle the fused
tier is conformance-checked against. ``REPRO_ENGINE=fused`` flips the
process default; ``REPRO_FUSED_FALLBACK=1`` forces runners' XLA-fused
fallback lowering even where Pallas is available.

Multi-device scheduling
-----------------------

The Executor owns a :class:`DeviceRegistry`: ``devices_per_target`` simulated
device instances per registered target, each with its **own fragment cache**
(its own "SRAM" — setup streams re-simulate per device, exactly as a real
driver loads weights into each physical accelerator). Signature-grouped
SimJob batches are assigned to devices by estimated cycles with greedy LPT
(longest processing time first onto the least-loaded device), the classic
2-approximation for makespan. Cycle estimates come from the owning target's
declared :class:`~repro.accel.target.CostModel`. Because ILA simulation is a
pure function of architectural state, device placement never changes
results — all engines stay bit-exact for any device count.

Per-invocation statistics (op, rel-error vs ideal, value ranges, predicted
cost) are collected — the "handy debugging information" the paper's authors
gave the accelerator developers to diagnose the HLSCNN weight-quantization
bug — and aggregated per target by :meth:`Executor.stats_summary`, which
also reports per-device utilization and estimated-cycle columns;
:meth:`Executor.cache_info` surfaces per-target warm-cache health for the
serving path.
"""
from __future__ import annotations

import dataclasses
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from ..accel.target import (  # importing registers bundled targets
    CostEstimate, GroupTiming, PlanContext, SimJob,
)
from . import ir
from .ila import TARGETS, CompiledFragment, FragmentCache
from .telemetry import TELEMETRY, MetricsRegistry

ENGINES = ("compiled", "pipelined", "fused", "jit", "eager")

#: process-wide pack worker for the pipelined engine. One thread by design:
#: numpy packing releases the GIL and overlaps XLA compute, but multiple
#: packing threads contend on the interpreter and run *slower* (measured).
_PACK_POOL: Optional[ThreadPoolExecutor] = None


def _pack_pool() -> ThreadPoolExecutor:
    global _PACK_POOL
    if _PACK_POOL is None:
        _PACK_POOL = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repro-pack"
        )
    return _PACK_POOL


@dataclasses.dataclass
class InvocationStat:
    op: str
    backend: str
    rel_err: float
    out_min: float
    out_max: float
    n_commands: int
    #: CostModel prediction made at plan time (None if the target declares
    #: no model); ``CostModel.calibrate`` fits command scales from these
    est: Optional[CostEstimate] = None


class _GroupResult:
    """One dispatched group's (possibly still in-flight) device result with
    memoized host materialization: ``np.asarray`` blocks until the async
    simulation completes, and every job of the group shares the single
    transfer."""

    __slots__ = ("_dev", "_np")

    def __init__(self, dev):
        self._dev = dev
        self._np = None

    def materialize(self) -> np.ndarray:
        if self._np is None:
            self._np = np.asarray(self._dev)
            self._dev = None
        return self._np


class _Deferred:
    """A run_many value whose host materialization is postponed: the
    simulation work behind it is already dispatched (async), but the
    readback barrier / host evaluation runs only at :meth:`force` — the
    mechanism behind :meth:`Executor.submit_many`'s deferred request
    tails. Idempotent: the thunk runs once and the result is cached."""

    __slots__ = ("_thunk", "_v")

    def __init__(self, thunk: Callable[[], List[Any]]):
        self._thunk = thunk
        self._v = None

    def force(self) -> List[Any]:
        if self._thunk is not None:
            self._v = self._thunk()
            self._thunk = None
        return self._v


def _forced(v):
    return v.force() if isinstance(v, _Deferred) else v


class Submission:
    """One in-flight :meth:`Executor.run_many` request.

    Returned by :meth:`Executor.submit_many`: every accelerator invocation
    has been planned and *dispatched* (simulation runs asynchronously on
    the devices), but the terminal readback barrier and any host epilogue
    ops downstream of the last accelerator call are deferred until
    :meth:`result`. A serving scheduler can therefore start packing the
    next request on the pack worker while this request's simulation tail
    is still in flight — instead of draining the pipeline at every
    request's assemble barrier. Results are bit-identical to
    :meth:`Executor.run_many` (deferral reorders *when* host code runs,
    never what it computes)."""

    __slots__ = ("_thunk", "_outs", "_done")

    def __init__(self, thunk: Optional[Callable[[], List[Any]]] = None,
                 outs: Optional[List[Any]] = None):
        self._thunk = thunk
        self._outs = outs
        self._done = thunk is None

    @property
    def done(self) -> bool:
        """True once :meth:`result` has materialized the outputs (or the
        submission was created already-complete, e.g. on a sync engine)."""
        return self._done

    def result(self) -> List[Any]:
        """Materialize and return the per-environment outputs (the readback
        barrier + deferred host epilogue). Idempotent."""
        if not self._done:
            self._outs = self._thunk()
            self._thunk = None
            self._done = True
        return self._outs


class Prepack:
    """Host packings staged ahead of a future submit_many/run_many over the
    same ``(program, envs)`` pair — see :meth:`Executor.prepack_many`."""

    __slots__ = ("program", "envs", "spans")

    def __init__(self, program: ir.Expr, envs: Sequence[Dict[str, Any]]):
        self.program = program
        self.envs = envs
        #: leading accel node -> list of pack-pool futures, one per
        #: pipeline_chunk span, each resolving to (planned, jobs, preps)
        self.spans: Dict[ir.Expr, List[Any]] = {}


class _NullDeviceType:
    """Placement stand-in for fragments of unregistered ILAs (no device
    pool): index 0 means "setup already cached", so no cold-load term."""

    index = 0

    @staticmethod
    def is_cold(frag) -> bool:
        return False


_NullDevice = _NullDeviceType()


class SimDevice:
    """One simulated accelerator instance of a target.

    Device 0 shares the target's process-wide fragment cache (the planners
    already build fragments there), so the single-device default is
    bit-and-cost-identical to the pre-device Executor. Devices >= 1 own a
    private :class:`~repro.core.ila.FragmentCache`: their setup streams
    re-simulate on first use — each device loads its own weights, like
    distinct physical accelerators — and stay warm per device thereafter.
    """

    def __init__(self, target, index: int):
        self.target = target
        self.index = index
        self.name = f"{target.name}[{index}]"
        self.fragments = target.fragments if index == 0 else FragmentCache()
        self.busy_cycles = 0.0
        self.n_jobs = 0
        self.n_groups = 0

    def resolve(self, frag: CompiledFragment) -> CompiledFragment:
        """This device's instance of ``frag`` (device-local setup state)."""
        if self.index == 0:
            return frag
        # keyed by ILA identity as well as fragment key: fragment keys hash
        # op/shapes/params only, so two ILAs with divergent semantics (the
        # fault campaign's golden target vs its mutants, run through one
        # long-lived Executor) can build same-key fragments. The cached
        # clone pins frag.ila alive, so the id cannot be recycled while the
        # entry is resident.
        return self.fragments.get(
            (frag.key, id(frag.ila)),
            lambda: CompiledFragment(frag.ila, frag.key, frag.setup, dict(frag.meta)),
        )

    def is_cold(self, frag: CompiledFragment) -> bool:
        """True when resolving ``frag`` here would re-simulate its setup
        stream (device-local weight load not yet cached)."""
        return self.index > 0 and (frag.key, id(frag.ila)) not in self.fragments

    def account(self, n_jobs: int, cycles: float) -> None:
        self.n_groups += 1
        self.n_jobs += n_jobs
        self.busy_cycles += cycles

    def reset_accounting(self) -> None:
        """Zero the scheduling accumulators (cycles/jobs/groups) without
        touching the device's fragment cache — the warm state survives a
        stats reset, exactly like a real device keeps its SRAM contents."""
        self.busy_cycles = 0.0
        self.n_jobs = 0
        self.n_groups = 0

    def summary(self) -> Dict[str, float]:
        return {
            "jobs": self.n_jobs,
            "groups": self.n_groups,
            "est_cycles": self.busy_cycles,
        }


class DeviceRegistry:
    """N simulated device instances per registered target, created lazily
    (targets may register after the Executor is constructed)."""

    def __init__(self, devices_per_target: Union[int, Dict[str, int]] = 1):
        self.devices_per_target = devices_per_target
        self._devices: Dict[str, List[SimDevice]] = {}

    def n_for(self, name: str) -> int:
        if isinstance(self.devices_per_target, dict):
            return max(1, int(self.devices_per_target.get(name, 1)))
        return max(1, int(self.devices_per_target))

    def devices(self, target) -> List[SimDevice]:
        devs = self._devices.get(target.name)
        if devs is None or len(devs) != self.n_for(target.name):
            devs = [SimDevice(target, i) for i in range(self.n_for(target.name))]
            self._devices[target.name] = devs
        return devs

    def owner(self, frag: CompiledFragment):
        """The registered target owning ``frag`` (matched by ILA identity);
        None for fragments of unregistered ILAs (executed unscheduled)."""
        for t in TARGETS.all():
            if t.ila is frag.ila:
                return t
        return None

    def pick(self, target) -> SimDevice:
        """Least-loaded device of ``target`` (the LPT assignment step)."""
        return min(self.devices(target), key=lambda d: (d.busy_cycles, d.index))

    def summary(self) -> Dict[str, Dict[str, Dict[str, float]]]:
        """Per-target per-device accounting with utilization relative to the
        target's makespan (most-loaded device = 1.0)."""
        out: Dict[str, Dict[str, Dict[str, float]]] = {}
        for tname, devs in self._devices.items():
            makespan = max((d.busy_cycles for d in devs), default=0.0)
            out[tname] = {
                d.name: dict(
                    d.summary(),
                    utilization=(d.busy_cycles / makespan) if makespan > 0 else 0.0,
                )
                for d in devs
            }
        return out


class Executor:
    """Executes an extracted IR program, offloading accelerator intrinsics.

    ``target_options`` carries per-target execution options keyed by target
    name (e.g. a weight-datatype selection for a backend with configurable
    numerics); planners read them through their
    :class:`~repro.accel.target.PlanContext`.

    ``devices_per_target`` sizes the :class:`DeviceRegistry`: an int applies
    to every target, a dict keys per-target counts by name. With more than
    one device per target, signature-grouped SimJob batches are scheduled
    greedy-LPT by CostModel cycle estimates (see the module docstring);
    results are bit-identical for any count.
    """

    def __init__(
        self,
        mode: str = "ila",
        collect_stats: bool = True,
        jit_sim: bool = True,
        engine: Optional[str] = None,
        target_options: Optional[Dict[str, Dict[str, Any]]] = None,
        devices_per_target: Union[int, Dict[str, int]] = 1,
        pipeline_chunk: int = 8,
    ):
        assert mode in ("ila", "kernel", "ideal")
        self.mode = mode
        self.collect_stats = collect_stats
        # explicit engine > REPRO_ENGINE env (lets CI/serving flip every
        # Executor in the process) > jit_sim legacy default
        self.engine = (
            engine
            or os.environ.get("REPRO_ENGINE")
            or ("compiled" if jit_sim else "eager")
        )
        assert self.engine in ENGINES, f"unknown engine {self.engine!r}"
        self.target_options = {k: dict(v) for k, v in (target_options or {}).items()}
        self.devices = DeviceRegistry(devices_per_target)
        #: samples planned per pack-pipeline stage in ``run_many`` (the
        #: pipelined engine packs chunk k+1 while chunk k simulates)
        self.pipeline_chunk = max(1, int(pipeline_chunk))
        self.stats: List[InvocationStat] = []
        #: jit(vmap(read)) per read fn — re-vmapping per group call would
        #: re-trace on the dispatch thread every time (holds a ref to the
        #: read fn so the id key cannot be recycled)
        self._batched_reads: Dict[int, Tuple[Callable, Callable]] = {}
        #: per-group wall-clock records feeding CostModel.calibrate_from_timings
        self.group_timings: List[GroupTiming] = []
        #: this executor's scoped metrics registry — the single source of
        #: truth for stage timers and invocation aggregates; attached to the
        #: process TELEMETRY singleton (weakref) so global snapshots see it
        self.metrics = TELEMETRY.attach(MetricsRegistry(scope="executor"))
        #: per-stage wall-clock counters (pack worker / dispatch / barrier);
        #: the legacy ``stage_seconds`` dict is now a read-only view property
        self._stage = {
            k: self.metrics.counter(f"pipeline.{k}")
            for k in ("pack_s", "dispatch_s", "readback_s")
        }
        self._groups_ctr = self.metrics.counter("pipeline.groups")
        self._inv_metrics: Dict[str, Tuple[Any, Any, Any, Any]] = {}
        #: programs already shape/dtype-checked (once per distinct Expr)
        self._checked: set = set()
        #: per-program deferral analysis for submit_many (Expr -> node set)
        self._defer_sets: Dict[ir.Expr, set] = {}

    @property
    def stage_seconds(self) -> Dict[str, float]:
        """Per-stage accumulated wall clock, read from the metrics registry
        (kept as a dict-shaped view for existing callers/tests)."""
        return {k: c.value for k, c in self._stage.items()}

    def _inv_for(self, tname: str):
        """The per-target invocation aggregate metrics (lazily created)."""
        m = self._inv_metrics.get(tname)
        if m is None:
            m = (
                self.metrics.counter("executor.invocations", target=tname),
                self.metrics.counter("executor.commands", target=tname),
                self.metrics.counter("executor.est_cycles", target=tname),
                self.metrics.gauge("executor.max_rel_err_ratio", target=tname),
            )
            self._inv_metrics[tname] = m
        return m

    # ------------------------------------------------------------------
    def _precheck(self, e: ir.Expr, env: Dict[str, Any]) -> None:
        """Static shape/dtype validation (:func:`ir.check_expr`) before any
        planner runs — an extraction candidate with an inconsistent shape
        fails here with the offending call named, not deep inside a
        planner. Cached per distinct program."""
        if e in self._checked:
            return
        ir.check_expr(e, {k: np.shape(v) for k, v in env.items()})
        self._checked.add(e)

    def run(self, e: ir.Expr, env: Dict[str, Any]):
        self._precheck(e, env)
        memo: Dict[ir.Expr, Any] = {}

        def rec(x: ir.Expr):
            if x in memo:
                return memo[x]
            if isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS:
                args = [np.asarray(rec(a)) for a in x.args]
                v = self._exec_accel(x, args)
            else:
                v = ir._eval(x, rec, env)
            memo[x] = v
            return v

        return rec(e)

    def run_many(self, e: ir.Expr, envs: Sequence[Dict[str, Any]]):
        """Evaluate the program once per environment, batching accelerator
        invocations *across samples*: all B samples' jobs for one IR node
        run through one vmapped simulator call (sharing the node's cached
        fragment), while host glue ops evaluate per sample. Per-sample
        numerics (chunking, AF exponent windows) are identical to B calls
        of :meth:`run`."""
        if envs:
            self._precheck(e, envs[0])
        B = len(envs)
        memo: Dict[ir.Expr, List[Any]] = {}

        def rec(x: ir.Expr) -> List[Any]:
            if x in memo:
                return memo[x]
            if isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS:
                args_b = [rec(a) for a in x.args]
                sample_args = [
                    [np.asarray(args_b[k][s]) for k in range(len(args_b))]
                    for s in range(B)
                ]
                if (
                    self.mode == "ila"
                    and self.engine in ("compiled", "pipelined", "fused")
                    and TARGETS.has_planner(x.op)
                ):
                    if self.engine in ("pipelined", "fused"):
                        v = self._node_pipelined(x, sample_args)
                    else:
                        plans, jobs = [], []
                        t0 = time.perf_counter()
                        for s in range(B):
                            s_jobs, assemble = self._plan(x, sample_args[s])
                            plans.append((len(jobs), len(s_jobs), assemble))
                            jobs += s_jobs
                        t1 = time.perf_counter()
                        dt = t1 - t0
                        self._stage["pack_s"].inc(dt)
                        if TELEMETRY.enabled:
                            TELEMETRY.record_span(
                                "pipeline.pack", t0, t1,
                                target=TARGETS.intrinsic(x.op)[0].name,
                                jobs=len(jobs))
                        if self.collect_stats:
                            self._groups_ctr.inc()
                            self.group_timings.append(GroupTiming(
                                TARGETS.intrinsic(x.op)[0].name, len(jobs),
                                PlanContext.data_ncmds(jobs), pack_s=dt,
                            ))
                        outs = self._execute_jobs(jobs)
                        v = [asm(outs[o : o + n]) for (o, n, asm) in plans]
                else:
                    v = [self._exec_accel(x, sample_args[s]) for s in range(B)]
            else:
                v = [
                    ir._eval(x, (lambda a, s=s: rec(a)[s]), envs[s])
                    for s in range(B)
                ]
            memo[x] = v
            return v

        return rec(e)

    # -- request-level submit/prepack API (continuous-batching serving) --
    def _defer_split(self, e: ir.Expr) -> set:
        """Nodes whose materialization :meth:`submit_many` defers: every
        node that (a) does not feed any accelerator call's operands and
        (b) has an accelerator call somewhere in its subtree. Those are
        exactly the nodes nothing further on the device depends on — the
        request's *tail*: terminal accelerator calls (readback barrier)
        and the host epilogue above them. Nodes feeding an accelerator
        operand are never deferred, so the dispatch order of simulation
        work is unchanged. Cached per distinct program."""
        cached = self._defer_sets.get(e)
        if cached is not None:
            return cached
        nodes = list(ir.postorder(e))
        feeds: set = set()
        for x in nodes:
            if isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS:
                for a in x.args:
                    feeds.update(ir.postorder(a))
        has_accel: Dict[ir.Expr, bool] = {}
        for x in nodes:  # postorder: children resolved first
            has_accel[x] = isinstance(x, ir.Call) and (
                x.op in ir.ACCEL_OPS
                or any(has_accel.get(a, False) for a in x.args)
            )
        deferred = {x for x in nodes if x not in feeds and has_accel[x]}
        self._defer_sets[e] = deferred
        return deferred

    def submit_many(
        self,
        e: ir.Expr,
        envs: Sequence[Dict[str, Any]],
        prepack: Optional[Prepack] = None,
    ) -> Submission:
        """Asynchronous :meth:`run_many`: plan and *dispatch* every
        accelerator invocation, but defer the terminal readback barrier and
        the host epilogue downstream of the last accelerator call to
        ``Submission.result()``. Between ``submit_many(k)`` returning and
        ``result(k)`` being called, the pack worker is free — a serving
        scheduler uses the gap to pre-pack request ``k+1``
        (:meth:`prepack_many`) while request ``k``'s simulation tail
        completes, instead of draining the pipeline per request.

        ``prepack`` hands in host packings staged earlier for the *same*
        program and environment list (anything else is ignored). On
        synchronous engines (or non-ILA modes) this degrades to an
        already-complete submission wrapping :meth:`run_many`: correct
        everywhere, overlapped only where the engine pipelines."""
        if self.mode != "ila" or self.engine not in ("pipelined", "fused") \
                or not envs:
            return Submission(outs=self.run_many(e, envs))
        self._precheck(e, envs[0])
        if prepack is not None and (
            prepack.program is not e or prepack.envs is not envs
        ):
            prepack = None
        deferred = self._defer_split(e)
        B = len(envs)
        memo: Dict[ir.Expr, Any] = {}

        def rec(x: ir.Expr):
            if x in memo:
                return memo[x]
            if isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS:
                # operand subtrees feed an accelerator call, so they are
                # never deferred: args_b holds plain per-sample lists
                args_b = [rec(a) for a in x.args]
                sample_args = [
                    [np.asarray(args_b[k][s]) for k in range(len(args_b))]
                    for s in range(B)
                ]
                if TARGETS.has_planner(x.op):
                    v = self._node_pipelined(
                        x, sample_args, defer=x in deferred,
                        prepacked=(
                            prepack.spans.get(x) if prepack is not None
                            else None
                        ),
                    )
                else:
                    v = [self._exec_accel(x, sample_args[s]) for s in range(B)]
            elif x in deferred:
                # host epilogue above the last accelerator call: record the
                # children now (dispatching any accel work below), evaluate
                # lazily at result() time
                for a in x.args:
                    rec(a)
                v = _Deferred(lambda x=x: [
                    ir._eval(x, (lambda a, s=s: _forced(memo[a])[s]), envs[s])
                    for s in range(B)
                ])
            else:
                v = [
                    ir._eval(x, (lambda a, s=s: rec(a)[s]), envs[s])
                    for s in range(B)
                ]
            memo[x] = v
            return v

        root = rec(e)
        if isinstance(root, _Deferred):
            return Submission(thunk=root.force)
        return Submission(outs=root)

    def prepack_many(
        self, e: ir.Expr, envs: Sequence[Dict[str, Any]]
    ) -> Prepack:
        """Stage the *leading* accelerator nodes' host packing (planner
        calls + batch stacking, pure numpy) on the pack worker, ahead of a
        later :meth:`submit_many`/:meth:`run_many` over the exact same
        ``(e, envs)``. Leading nodes are accelerator calls whose operand
        subtrees contain no other accelerator call — their operands are
        computable from the environments alone, so their packing needs
        nothing from the current request. The serving scheduler calls this
        for request ``k+1`` while request ``k``'s simulation tail is in
        flight: the single pack worker fills the readback gap instead of
        idling. Numerics are unchanged (same planners, same span grouping
        as :meth:`_node_pipelined`); on synchronous engines this is a
        no-op."""
        pre = Prepack(e, envs)
        if self.mode != "ila" or self.engine not in ("pipelined", "fused") \
                or not envs:
            return pre
        self._precheck(e, envs[0])
        B = len(envs)
        for x in ir.postorder(e):
            if not (isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS
                    and TARGETS.has_planner(x.op)):
                continue
            if any(
                isinstance(n, ir.Call) and n.op in ir.ACCEL_OPS
                for a in x.args for n in ir.postorder(a)
            ):
                continue  # not leading: operands depend on accel results
            sample_args = []
            for s in range(B):
                ememo: Dict[ir.Expr, Any] = {}

                def ev(a, s=s, ememo=ememo):
                    if a in ememo:
                        return ememo[a]
                    v = ir._eval(a, ev, envs[s])
                    ememo[a] = v
                    return v

                sample_args.append([np.asarray(ev(a)) for a in x.args])
            spans = [
                range(i, min(i + self.pipeline_chunk, B))
                for i in range(0, B, self.pipeline_chunk)
            ]
            plan_span = self._make_plan_span(x, sample_args)
            pre.spans[x] = [_pack_pool().submit(plan_span, sp) for sp in spans]
        return pre

    # ------------------------------------------------------------------
    def _record(self, op, backend, out, ideal, ncmds, est=None):
        if not self.collect_stats:
            return
        out = np.asarray(out, np.float64)
        ideal = np.asarray(ideal, np.float64)
        denom = np.linalg.norm(ideal)
        err = float(np.linalg.norm(ideal - out) / denom) if denom > 0 else 0.0
        self.stats.append(
            InvocationStat(
                op, backend, err, float(out.min()), float(out.max()), ncmds, est
            )
        )
        inv, cmds, cyc, rel = self._inv_for(ir.accel_op_target(op) or backend)
        inv.inc()
        cmds.inc(ncmds)
        if est is not None:
            cyc.inc(est.cycles)
        rel.set_max(err)

    def _estimate(self, target, x: ir.Call, args) -> Optional[CostEstimate]:
        """CostModel prediction for one invocation (None without a model)."""
        model = target.cost_model
        if model is None or not model.covers(x.op):
            return None
        return model.estimate(x.op, dict(x.attrs), [np.shape(a) for a in args])

    def _ctx(self, target, est: Optional[CostEstimate] = None) -> PlanContext:
        record = self._record if est is None else (
            lambda *a, _est=est, **kw: self._record(*a, est=_est, **kw)
        )
        return PlanContext(
            record=record, options=self.target_options.get(target.name, {})
        )

    def _exec_accel(self, x: ir.Call, args: List[np.ndarray]):
        if self.mode == "ideal":
            return self._ideal(x, args)
        target, intr = TARGETS.intrinsic(x.op)
        if intr.passthrough:
            return args[0]
        if self.mode == "kernel" and intr.kernel is not None:
            return intr.kernel(self._ctx(target, self._estimate(target, x, args)), x, args)
        jobs, assemble = self._plan(x, args)
        return assemble(self._execute_jobs(jobs))

    def _ideal(self, x: ir.Call, args):
        vs = [ir.Var(f"_{i}", np.shape(a)) for i, a in enumerate(args)]
        env = {f"_{i}": a for i, a in enumerate(args)}
        return ir.interpret(ir.Call(x.op, tuple(vs), x.attrs), env)

    def _plan(self, x: ir.Call, args) -> Tuple[List[SimJob], Callable]:
        target, intr = TARGETS.intrinsic(x.op)
        if intr.planner is None:
            raise NotImplementedError(
                f"target {target.name!r} declares no planner for {x.op!r}"
            )
        return intr.planner(self._ctx(target, self._estimate(target, x, args)), x, args)

    # -- job execution ---------------------------------------------------
    def _group_cycles(self, frag, idxs: List[int], jobs, target, device) -> float:
        """Estimated cycles for one signature group on ``device``: data
        commands for every job, plus the setup stream when this device has
        not simulated it yet (cold weight load). Under the pipelined engine
        a latency-calibrated CostModel prices the group ``max(pack, sim)``
        — the stage the group actually occupies the pipeline for — instead
        of their serial sum."""
        n = sum(len(jobs[i].data) for i in idxs)
        if device.is_cold(frag):
            n += len(frag.setup)
        model = target.cost_model if target is not None else None
        if model is None:
            return float(n)
        return model.job_cycles(n, pipelined=self.engine in ("pipelined", "fused"))

    def _fused_for(self, frag, read, target):
        """The fused fast-path runner for one job group, or None when the
        compiled tier should execute it: only under ``engine="fused"``, only
        for fragments whose owning target resolves a
        :class:`~repro.core.ila.FusedRunner` for the signature, and only
        when the runner fuses the group's read function (runners bake the
        read-out into the kernel; a planner using a different read falls
        back to the oracle path)."""
        if self.engine != "fused" or target is None:
            return None
        runner = target.fused_runner(frag)
        if runner is None or (runner.read is not None and runner.read is not read):
            return None
        return runner

    @staticmethod
    def _group_jobs(jobs: List[SimJob]) -> Dict[Tuple, List[int]]:
        """Batchable-group partition: jobs sharing a fragment and a
        data-stream signature run through one vmapped simulator call."""
        groups: Dict[Tuple, List[int]] = {}
        for i, j in enumerate(jobs):
            groups.setdefault((id(j.frag), j.data.sig()), []).append(i)
        return groups

    def _dispatch_jobs(
        self,
        jobs: List[SimJob],
        sync: bool = False,
        pack_ahead: bool = False,
        preps: Optional[Dict[Tuple, Any]] = None,
    ) -> List[Callable[[], np.ndarray]]:
        """Group jobs by (fragment, data signature), schedule the groups
        over the owning targets' simulated devices (greedy LPT on CostModel
        estimates) and *dispatch* their simulations, returning one lazy
        materializer per job (JAX dispatch is asynchronous, so the calls
        return while simulation is still in flight).

        ``sync=True`` (the compiled engine) materializes each group before
        dispatching the next — the pre-pipeline behavior — and records a
        :class:`~repro.accel.target.GroupTiming` with the group's exact
        dispatch-to-materialization wall clock for latency calibration.
        ``pack_ahead=True`` (the pipelined engine) stages each group's host
        packing (stacking, shared-payload detection) in the pack worker so
        it overlaps the previous group's simulation; ``preps`` passes in
        host packings already prepared elsewhere (``_node_pipelined`` packs
        them in the worker alongside planning), keyed like
        :meth:`_group_jobs`.
        """
        handles: List[Optional[Callable[[], np.ndarray]]] = [None] * len(jobs)
        groups = self._group_jobs(jobs)
        # longest-processing-time-first over each target's device pool; a
        # single-device pool preserves the original group order exactly
        order = []
        for key, idxs in groups.items():
            frag = jobs[idxs[0]].frag
            target = self.devices.owner(frag)
            rank = self._group_cycles(frag, idxs, jobs, target, _NullDevice)
            order.append((rank, idxs, target))
        multi = any(
            t is not None and self.devices.n_for(t.name) > 1 for _, _, t in order
        )
        if multi:
            order.sort(key=lambda e: -e[0])
        preps = dict(preps or {})
        if pack_ahead:
            for _rank, idxs, _t in order:
                if len(idxs) > 1:
                    frag = jobs[idxs[0]].frag
                    key = (id(frag), jobs[idxs[0]].data.sig())
                    if key not in preps:
                        runner = self._fused_for(frag, jobs[idxs[0]].read, _t)
                        datas = [jobs[i].data for i in idxs]
                        if runner is not None:
                            preps[key] = _pack_pool().submit(
                                lambda r=runner, ds=datas: ("fused", r.prepare(ds))
                            )
                        else:
                            preps[key] = _pack_pool().submit(
                                frag.prepare_batch, datas
                            )
        t_disp = time.perf_counter()
        for _rank, idxs, target in order:
            frag = jobs[idxs[0]].frag
            read = jobs[idxs[0]].read
            t_grp = time.perf_counter()
            grp_cycles = 0.0
            dev_name = frag.ila.name
            # fused resolution happens on the *shared* fragment, before any
            # device-local clone: runners compute from fragment meta, so a
            # fused group never pays a per-device setup re-simulation
            runner = self._fused_for(frag, read, target)
            n_cmds = sum(len(jobs[i].data) for i in idxs)
            if target is not None:
                device = self.devices.pick(target)
                # book against the chosen device, including its cold-setup
                # cost (the ranking pass above is placement-blind)
                if runner is None and device.is_cold(frag):
                    n_cmds += len(frag.setup)
                grp_cycles = self._group_cycles(
                    frag, idxs, jobs, target,
                    _NullDevice if runner is not None else device,
                )
                device.account(len(idxs), grp_cycles)
                dev_name = device.name
                if runner is None:
                    frag = device.resolve(frag)
            stack_dt = 0.0
            if len(idxs) == 1:
                t0 = time.perf_counter()
                j = jobs[idxs[0]]
                if runner is not None:
                    group = _GroupResult(runner.run([j.data]))
                    handles[idxs[0]] = (
                        lambda g=group, w=j.window: g.materialize()[0][w]
                    )
                else:
                    out = read(frag.run(j.data))
                    group = _GroupResult(out)
                    handles[idxs[0]] = (
                        lambda g=group, w=j.window: g.materialize()[w]
                    )
            else:
                datas = [jobs[i].data for i in idxs]

                def _prep():
                    if runner is not None:
                        return ("fused", runner.prepare(datas))
                    return frag.prepare_batch(datas)

                prep = preps.get((id(jobs[idxs[0]].frag), jobs[idxs[0]].data.sig()))
                if prep is not None:
                    prepared = prep.result() if hasattr(prep, "result") else prep
                elif sync:
                    # host half timed apart so the GroupTiming pack/sim
                    # split matches what the pipelined engine's pack stage
                    # actually covers (planner packing + group stacking)
                    t0 = time.perf_counter()
                    prepared = _prep()
                    stack_dt = time.perf_counter() - t0
                else:
                    prepared = _prep()
                # a staged prep can disagree with the resolved path when the
                # fused env flags flip between pack and dispatch — re-prep
                if (prepared[0] == "fused") != (runner is not None):
                    prepared = _prep()
                t0 = time.perf_counter()
                if runner is not None:
                    fulls = runner.dispatch(prepared[1])
                else:
                    sts = frag.run_prepared(prepared)
                    entry = self._batched_reads.get(id(read))
                    if entry is None:
                        entry = (read, jax.jit(jax.vmap(read)))
                        self._batched_reads[id(read)] = entry
                    fulls = entry[1](sts)
                group = _GroupResult(fulls)
                for bi, i in enumerate(idxs):
                    handles[i] = (
                        lambda g=group, b=bi, w=jobs[i].window: g.materialize()[b][w]
                    )
            if sync:
                group.materialize()
                sim_dt = time.perf_counter() - t0
                if self.collect_stats:
                    self._groups_ctr.inc()
                    self.group_timings.append(GroupTiming(
                        target.name if target is not None else frag.ila.name,
                        len(idxs), n_cmds, pack_s=stack_dt,
                        sim_s=sim_dt,
                    ))
                    # drift probe: the scheduler priced this group at
                    # grp_cycles; the simulation actually took sim_dt. On a
                    # latency-calibrated model (1 cycle == 1 us) the ratio
                    # is directly actionable (CostModel.drift_summary)
                    if target is not None and target.cost_model is not None \
                            and grp_cycles > 0:
                        target.cost_model.record_drift(
                            grp_cycles, sim_dt * 1e6)
            if TELEMETRY.enabled:
                TELEMETRY.record_span(
                    "pipeline.dispatch_group", t_grp, time.perf_counter(),
                    device=dev_name, jobs=len(idxs),
                    est_cycles=round(grp_cycles, 1))
        self._stage["dispatch_s"].inc(time.perf_counter() - t_disp)
        return handles

    def _execute_jobs(self, jobs: List[SimJob]) -> List[np.ndarray]:
        """Run simulation jobs to completion. The compiled engine executes
        group-by-group (synchronous); the pipelined engine dispatches every
        group asynchronously — host packing staged through the pack worker
        — and materializes at the end, in job order."""
        if self.engine in ("jit", "eager"):
            results = []
            for j in jobs:
                cmds = j.frag.full_commands(j.data)
                ila = j.frag.ila
                st = ila.simulate_jit(cmds) if self.engine == "jit" else ila.simulate(cmds)
                results.append(np.asarray(j.read(st))[j.window])
            return results
        sync = self.engine == "compiled"
        handles = self._dispatch_jobs(jobs, sync=sync, pack_ahead=not sync)
        t0 = time.perf_counter()
        results = [h() for h in handles]
        if not sync:
            t1 = time.perf_counter()
            self._stage["readback_s"].inc(t1 - t0)
            if TELEMETRY.enabled:
                TELEMETRY.record_span("pipeline.readback", t0, t1,
                                      jobs=len(jobs))
        return results

    def _make_plan_span(self, x: ir.Call, sample_args: List[List[np.ndarray]]):
        """Build the pack-stage closure for one accelerator node: plan every
        sample of a span (planner packing, pure numpy) AND pre-stack its
        batchable groups, so the main thread's dispatch is jit lookup +
        async call only. Shared by :meth:`_node_pipelined` (packing one
        span ahead within a request) and :meth:`prepack_many` (staging a
        whole later request's leading nodes)."""
        target, _intr = TARGETS.intrinsic(x.op)
        # the pack closure runs on the pack-worker thread, which has no
        # thread-local trace binding — capture the submitting thread's
        # current trace id now so the pack span stays request-correlated
        trace_id = TELEMETRY.current_trace() if TELEMETRY.enabled else None

        def plan_span(span):
            t0 = time.perf_counter()
            planned = [self._plan(x, sample_args[s]) for s in span]
            jobs = [j for js, _ in planned for j in js]
            preps = {}
            for key, idxs in self._group_jobs(jobs).items():
                if len(idxs) <= 1:
                    continue
                frag0 = jobs[idxs[0]].frag
                runner = self._fused_for(
                    frag0, jobs[idxs[0]].read, self.devices.owner(frag0)
                )
                datas = [jobs[i].data for i in idxs]
                preps[key] = (
                    ("fused", runner.prepare(datas))
                    if runner is not None
                    else frag0.prepare_batch(datas)
                )
            t1 = time.perf_counter()
            dt = t1 - t0
            self._stage["pack_s"].inc(dt)
            if TELEMETRY.enabled:
                TELEMETRY.record_span("pipeline.pack", t0, t1, trace_id,
                                      target=target.name, jobs=len(jobs))
            if self.collect_stats:
                self._groups_ctr.inc()
                self.group_timings.append(GroupTiming(
                    target.name, len(jobs), PlanContext.data_ncmds(jobs),
                    pack_s=dt,
                ))
            return planned, jobs, preps

        return plan_span

    def _node_pipelined(
        self,
        x: ir.Call,
        sample_args: List[List[np.ndarray]],
        defer: bool = False,
        prepacked: Optional[List[Any]] = None,
    ):
        """Pipelined execution of one accelerator IR node across the B
        samples of a ``run_many`` minibatch: samples are planned (host
        packing, pure numpy) in :attr:`pipeline_chunk`-sized chunks on the
        pack worker while the main thread dispatches the previous chunk's
        simulations to the device queues; results materialize at the final
        assemble barrier, in submission order (deterministic stats/order).
        Chunking only regroups the vmapped batches — per-sample numerics
        are grouping-independent, so results match the compiled engine
        bit-for-bit.

        ``defer=True`` (submit_many's terminal nodes) dispatches every span
        but returns a :class:`_Deferred` whose force runs the assemble
        barrier — the caller decides when to pay the readback.
        ``prepacked`` passes span packings already staged on the pack
        worker by :meth:`prepack_many` (one future per span); span
        boundaries depend only on B and :attr:`pipeline_chunk`, and a
        length mismatch falls back to packing here."""
        B = len(sample_args)
        if B == 0:
            return _Deferred(list) if defer else []
        spans = [
            range(i, min(i + self.pipeline_chunk, B))
            for i in range(0, B, self.pipeline_chunk)
        ]
        if prepacked is not None and len(prepacked) != len(spans):
            prepacked = None
        plan_span = self._make_plan_span(x, sample_args)

        def stage(ci):
            if prepacked is not None:
                return prepacked[ci]
            return _pack_pool().submit(plan_span, spans[ci])

        fut = stage(0)
        stages = []
        for ci in range(len(spans)):
            planned, jobs, preps = fut.result()
            if ci + 1 < len(spans):
                fut = stage(ci + 1)
            handles = self._dispatch_jobs(jobs, preps=preps)
            stages.append((planned, handles))

        trace_id = TELEMETRY.current_trace() if TELEMETRY.enabled else None

        def readback():
            t0 = time.perf_counter()
            v = []
            for planned, handles in stages:
                outs = [h() for h in handles]
                o = 0
                for js, asm in planned:
                    v.append(asm(outs[o : o + len(js)]))
                    o += len(js)
            t1 = time.perf_counter()
            self._stage["readback_s"].inc(t1 - t0)
            if TELEMETRY.enabled:
                TELEMETRY.record_span("pipeline.readback", t0, t1, trace_id,
                                      spans=len(stages))
            return v

        return _Deferred(readback) if defer else readback()

    # -- statistics & cache surfacing ------------------------------------
    def reset_stats(self) -> None:
        """Clear every accumulated statistic: invocation stats, per-group
        timing records, per-stage timers AND the per-device scheduling
        accumulators (cycles/jobs/groups) — so ``stats_summary()``
        utilization after a reset reflects only post-reset work (the
        serving path resets between warmup and measured requests). Warm
        state (fragment caches, compiled runners) is untouched."""
        self.stats.clear()
        self.group_timings.clear()
        self.metrics.reset()
        for devs in self.devices._devices.values():
            for d in devs:
                d.reset_accounting()

    def stats_summary(self) -> Dict[str, Dict[str, Any]]:
        """Aggregate invocation stats per target: invocation count, total
        interface commands, worst relative error vs the fp32 oracle, total
        CostModel-estimated cycles, and — once jobs have been scheduled —
        per-device rows (jobs, estimated cycles, utilization relative to
        the target's makespan). A thin view over the executor's metrics
        registry — ``_record`` aggregates into per-target counters as
        invocations happen, so this never re-scans ``self.stats``."""
        out: Dict[str, Dict[str, Any]] = {}
        for tname, (inv, cmds, cyc, rel) in self._inv_metrics.items():
            if inv.value == 0 and cmds.value == 0:
                continue  # reset since last use
            out[tname] = {
                "invocations": int(inv.value),
                "commands": int(cmds.value),
                "max_rel_err": rel.value,
                "est_cycles": cyc.value,
            }
        for tname, devs in self.devices.summary().items():
            out.setdefault(
                tname,
                {"invocations": 0, "commands": 0, "max_rel_err": 0.0,
                 "est_cycles": 0.0},
            )["devices"] = devs
        return out

    def calibrate_cost_models(self) -> Dict[str, Dict[str, float]]:
        """Run every registered target's ``CostModel.calibrate`` against the
        invocation stats collected so far (observed interface command counts
        vs the analytic predictions); returns the fitted per-op command
        scales keyed by target name."""
        out: Dict[str, Dict[str, float]] = {}
        for t in TARGETS.all():
            if t.cost_model is not None:
                out[t.name] = t.cost_model.calibrate(self.stats)
        return out

    def calibrate_from_timings(self) -> Dict[str, Dict[str, float]]:
        """Fit every registered target's wall-clock latency model
        (``CostModel.calibrate_from_timings``) from the per-group timings
        recorded so far. Synchronous (``compiled``) runs record exact
        per-group sim timings, so the serving path calibrates during its
        warmup requests and the pipelined scheduler then prices groups as
        measured ``max(pack, sim)`` microseconds. Returns the fitted models
        keyed by target name (targets without usable timings are omitted)."""
        out: Dict[str, Dict[str, float]] = {}
        for t in TARGETS.all():
            if t.cost_model is not None:
                fit = t.cost_model.calibrate_from_timings(self.group_timings)
                if fit:
                    out[t.name] = fit
        return out

    def pipeline_summary(self) -> Dict[str, float]:
        """Per-stage accumulated wall clock plus an overlap estimate:
        ``overlap_s`` is pack time hidden behind simulation (pack runs in
        the worker while the main thread dispatches/blocks), the pipelined
        engine's whole win. All values reset with :meth:`reset_stats`.
        A thin view over the registry's ``pipeline.*`` counters."""
        stages = self.stage_seconds
        packed = stages["pack_s"]
        busy = stages["dispatch_s"] + stages["readback_s"]
        return dict(
            stages,
            groups=self._groups_ctr.value,
            overlap_s=(
                min(packed, busy)
                if self.engine in ("pipelined", "fused")
                else 0.0
            ),
        )

    def cache_info(self, targets: Optional[Sequence[str]] = None) -> Dict[str, Dict]:
        """Per-target warm-cache health: fragment-cache hits/misses plus jit
        trace / compiled-runner counts (serving-path observability)."""
        return {t.name: t.cache_info() for t in TARGETS.all(targets)}
