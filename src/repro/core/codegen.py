"""Code generation + execution for matched programs.

After flexible matching extracts a program containing accelerator intrinsics,
this module plays the role of the paper's BYOC code generator + runtime: each
accelerator op is lowered to an ILA command stream (the "MMIO writes" of
Figure 5d) and either

* ``mode="ila"``     — executed on the ILA simulator, bit-accurate in the
  accelerator's custom numerics (the application-level co-simulation path,
  Section 2.3.2), or
* ``mode="kernel"``  — executed on the TPU-native Pallas fast path with the
  same numeric semantics (deployment path), or
* ``mode="ideal"``   — fp32 reference (the IR interpreter; oracle).

The driver layer tiles tensors that exceed device SRAM (row-chunking for
FlexASR, 16x16 tiling for VTA is inside its fragment builder) — the same
job a real device driver does.

Execution engine
----------------

``engine="compiled"`` (default) routes every accelerator invocation through
the fragment-compiler fast path of :mod:`..core.ila`: each op is *planned*
into simulation jobs (CompiledFragment + per-sample DataStream + output
window), jobs sharing a fragment and stream signature are batched through
one ``vmap``-ed simulator call, and fragment setup (weight load) is
simulated once per parameter set and cached. The batch/head/tile loops that
previously ran fragments one at a time — LSTM batch, attention heads,
conv2d batch, VTA/pool row tiles — all flow through this path, as does
minibatched evaluation via :meth:`Executor.run_many`.

``engine="jit"`` re-derives and scans the full command stream per invocation
(the pre-fragment-compiler behavior); ``engine="eager"`` interprets commands
one by one. Both exist as bit-exact references for the compiled path.

Per-invocation statistics (op, rel-error vs ideal, value ranges) are
collected — the "handy debugging information" the paper's authors gave the
accelerator developers to diagnose the HLSCNN weight-quantization bug.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ir
from .ila import CompiledFragment, DataStream
from ..accel import flexasr as fa
from ..accel import hlscnn as hc
from ..accel import vta as vt
from ..accel import numerics
from ..kernels import ops as kops


@dataclasses.dataclass
class InvocationStat:
    op: str
    backend: str
    rel_err: float
    out_min: float
    out_max: float
    n_commands: int


@dataclasses.dataclass
class SimJob:
    """One fragment invocation: a data stream to run against a compiled
    fragment, a vmap-safe full-region read, and the valid output window."""

    frag: CompiledFragment
    data: DataStream
    read: Callable
    window: Tuple


class Executor:
    """Executes an extracted IR program, offloading accelerator intrinsics."""

    def __init__(
        self,
        mode: str = "ila",
        hlscnn_wgt_bits: int = 8,
        collect_stats: bool = True,
        jit_sim: bool = True,
        engine: Optional[str] = None,
    ):
        assert mode in ("ila", "kernel", "ideal")
        self.mode = mode
        self.hlscnn_wgt_bits = hlscnn_wgt_bits
        self.collect_stats = collect_stats
        self.engine = engine or ("compiled" if jit_sim else "eager")
        assert self.engine in ("compiled", "jit", "eager")
        self.stats: List[InvocationStat] = []

    # ------------------------------------------------------------------
    def run(self, e: ir.Expr, env: Dict[str, Any]):
        memo: Dict[ir.Expr, Any] = {}

        def rec(x: ir.Expr):
            if x in memo:
                return memo[x]
            if isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS:
                args = [np.asarray(rec(a)) for a in x.args]
                v = self._exec_accel(x, args)
            else:
                v = ir._eval(x, rec, env)
            memo[x] = v
            return v

        return rec(e)

    def run_many(self, e: ir.Expr, envs: Sequence[Dict[str, Any]]):
        """Evaluate the program once per environment, batching accelerator
        invocations *across samples*: all B samples' jobs for one IR node
        run through one vmapped simulator call (sharing the node's cached
        fragment), while host glue ops evaluate per sample. Per-sample
        numerics (chunking, AF exponent windows) are identical to B calls
        of :meth:`run`."""
        B = len(envs)
        memo: Dict[ir.Expr, List[Any]] = {}

        def rec(x: ir.Expr) -> List[Any]:
            if x in memo:
                return memo[x]
            if isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS:
                args_b = [rec(a) for a in x.args]
                sample_args = [
                    [np.asarray(args_b[k][s]) for k in range(len(args_b))]
                    for s in range(B)
                ]
                if self.mode == "ila" and self.engine == "compiled" and x.op in self._PLANNERS:
                    plans, jobs = [], []
                    for s in range(B):
                        s_jobs, assemble = self._plan(x, sample_args[s])
                        plans.append((len(jobs), len(s_jobs), assemble))
                        jobs += s_jobs
                    outs = self._execute_jobs(jobs)
                    v = [asm(outs[o : o + n]) for (o, n, asm) in plans]
                else:
                    v = [self._exec_accel(x, sample_args[s]) for s in range(B)]
            else:
                v = [
                    ir._eval(x, (lambda a, s=s: rec(a)[s]), envs[s])
                    for s in range(B)
                ]
            memo[x] = v
            return v

        return rec(e)

    # ------------------------------------------------------------------
    def _record(self, op, backend, out, ideal, ncmds):
        if not self.collect_stats:
            return
        out = np.asarray(out, np.float64)
        ideal = np.asarray(ideal, np.float64)
        denom = np.linalg.norm(ideal)
        err = float(np.linalg.norm(ideal - out) / denom) if denom > 0 else 0.0
        self.stats.append(
            InvocationStat(op, backend, err, float(out.min()), float(out.max()), ncmds)
        )

    def _exec_accel(self, x: ir.Call, args: List[np.ndarray]):
        op = x.op
        if self.mode == "ideal":
            return self._ideal(x, args)
        if op in ("fasr_store", "fasr_load"):
            return args[0]
        if self.mode == "kernel" and op == "fasr_linear":
            return self._fasr_linear_kernel(x, args)
        if self.mode == "kernel" and op == "vta_gemm":
            return self._vta_gemm_kernel(x, args)
        jobs, assemble = self._plan(x, args)
        return assemble(self._execute_jobs(jobs))

    def _ideal(self, x: ir.Call, args):
        vs = [ir.Var(f"_{i}", np.shape(a)) for i, a in enumerate(args)]
        env = {f"_{i}": a for i, a in enumerate(args)}
        return ir.interpret(ir.Call(x.op, tuple(vs), x.attrs), env)

    # -- job execution ---------------------------------------------------
    def _execute_jobs(self, jobs: List[SimJob]) -> List[np.ndarray]:
        """Run simulation jobs, batching those that share a fragment and a
        data-stream signature through one vmapped simulator call."""
        results: List[Optional[np.ndarray]] = [None] * len(jobs)
        if self.engine != "compiled":
            for i, j in enumerate(jobs):
                cmds = j.frag.full_commands(j.data)
                ila = j.frag.ila
                st = ila.simulate_jit(cmds) if self.engine == "jit" else ila.simulate(cmds)
                results[i] = np.asarray(j.read(st))[j.window]
            return results
        groups: Dict[Tuple, List[int]] = {}
        for i, j in enumerate(jobs):
            groups.setdefault((id(j.frag), j.data.sig()), []).append(i)
        for idxs in groups.values():
            frag = jobs[idxs[0]].frag
            read = jobs[idxs[0]].read
            if len(idxs) == 1:
                j = jobs[idxs[0]]
                results[idxs[0]] = np.asarray(read(frag.run(j.data)))[j.window]
            else:
                sts = frag.run_batch([jobs[i].data for i in idxs])
                fulls = np.asarray(jax.vmap(read)(sts))
                for bi, i in enumerate(idxs):
                    results[i] = fulls[bi][jobs[i].window]
        return results

    def _plan(self, x: ir.Call, args) -> Tuple[List[SimJob], Callable]:
        return self._PLANNERS[x.op](self, x, args)

    def _chunk_rows(self, x, max_rows):
        return [x[i : i + max_rows] for i in range(0, x.shape[0], max_rows)]

    def _ncmds(self, jobs: List[SimJob]) -> int:
        return sum(len(j.frag.setup) + len(j.data) for j in jobs)

    # -- FlexASR ---------------------------------------------------------
    def _fasr_linear_kernel(self, x: ir.Call, args):
        a, w, b = args
        orig_shape = a.shape
        a2 = a.reshape(-1, a.shape[-1])
        ideal_full = a2 @ w.T + b
        out = np.asarray(kops.af_linear(jnp.asarray(a2), jnp.asarray(w), jnp.asarray(b)))
        self._record("fasr_linear", "flexasr-kernel", out, ideal_full, 0)
        return out.reshape(orig_shape[:-1] + (w.shape[0],))

    def _plan_fasr_linear(self, x: ir.Call, args):
        a, w, b = args
        orig_shape = a.shape
        a2 = a.reshape(-1, a.shape[-1])
        O = w.shape[0]
        ideal_full = a2 @ w.T + b
        frag = fa.linear_fragment(w, b)
        jobs = [
            SimJob(frag, fa.pack_linear_data(frag, chunk), fa.read_full,
                   (slice(0, chunk.shape[0]), slice(0, O)))
            for chunk in self._chunk_rows(a2, fa.MAX_TS)
        ]

        def assemble(outs):
            out = np.concatenate(outs, axis=0)
            self._record("fasr_linear", "flexasr", out, ideal_full, self._ncmds(jobs))
            return out.reshape(orig_shape[:-1] + (O,))

        return jobs, assemble

    def _plan_fasr_lstm(self, x: ir.Call, args):
        xs, wi, wh, b = args
        T, B, I = xs.shape
        H = wh.shape[1]
        ideal = np.asarray(
            ir._lstm(jnp.asarray(xs), jnp.asarray(wi), jnp.asarray(wh), jnp.asarray(b))
        )
        frag = fa.lstm_fragment(wi, wh, b)
        jobs = [
            SimJob(frag, fa.pack_lstm_data(frag, xs[:, bi]), fa.read_full,
                   (slice(0, T), slice(0, H)))
            for bi in range(B)
        ]

        def assemble(outs):
            out = np.stack(outs, axis=1)
            self._record("fasr_lstm", "flexasr", out, ideal, self._ncmds(jobs))
            return out

        return jobs, assemble

    def _plan_fasr_pool(self, x: ir.Call, args, kind):
        (a,) = args
        T = a.shape[0]
        pairs = a[: T - T % 2].reshape(T // 2, 2, *a.shape[1:])
        ideal = pairs.max(1) if kind == "max" else pairs.mean(1)
        jobs, layout = [], []
        for chunk in self._chunk_rows(a, fa.MAX_TS):
            # pooling is elementwise across features: chunk wide matrices
            # column-wise to fit the device's MAX_IN lanes
            cols = []
            for c0 in range(0, chunk.shape[1], fa.MAX_IN):
                piece = chunk[:, c0 : c0 + fa.MAX_IN]
                frag = fa.pool_fragment(piece.shape[1], kind)
                jobs.append(
                    SimJob(frag, fa.pack_pool_data(frag, piece), fa.read_full,
                           (slice(0, piece.shape[0] // 2), slice(0, piece.shape[1])))
                )
                cols.append(len(jobs) - 1)
            layout.append(cols)

        def assemble(outs):
            rows = [np.concatenate([outs[i] for i in cols], axis=1) for cols in layout]
            out = np.concatenate(rows, axis=0)
            self._record(f"fasr_{kind}pool", "flexasr", out, ideal, self._ncmds(jobs))
            return out

        return jobs, assemble

    def _plan_fasr_layernorm(self, x: ir.Call, args):
        a, g, b = args
        orig = a.shape
        a2 = a.reshape(-1, a.shape[-1])
        mu = a2.mean(-1, keepdims=True)
        va = a2.var(-1, keepdims=True)
        ideal = (a2 - mu) / np.sqrt(va + 1e-5) * g + b
        frag = fa.layernorm_fragment(g, b)
        D = a2.shape[1]
        jobs = [
            SimJob(frag, fa.pack_layernorm_data(frag, chunk), fa.read_full,
                   (slice(0, chunk.shape[0]), slice(0, D)))
            for chunk in self._chunk_rows(a2, fa.MAX_TS)
        ]

        def assemble(outs):
            out = np.concatenate(outs, axis=0).reshape(orig)
            self._record("fasr_layernorm", "flexasr", out, ideal, self._ncmds(jobs))
            return out

        return jobs, assemble

    def _plan_fasr_attention(self, x: ir.Call, args):
        q, k, v = args
        ideal = np.asarray(ir._attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)))
        D = q.shape[-1]
        frag = fa.attention_fragment(D)
        if q.ndim == 2:
            jobs = [
                SimJob(frag, fa.pack_attention_data(frag, q, k, v), fa.read_full,
                       (slice(0, q.shape[0]), slice(0, v.shape[-1])))
            ]

            def assemble(outs):
                self._record("fasr_attention", "flexasr", outs[0], ideal, self._ncmds(jobs))
                return outs[0]

            return jobs, assemble
        # batch of heads: one invocation per (batch) slice, batched in sim
        q2 = q.reshape(-1, q.shape[-2], q.shape[-1])
        k2 = k.reshape(-1, k.shape[-2], k.shape[-1])
        v2 = v.reshape(-1, v.shape[-2], v.shape[-1])
        jobs = [
            SimJob(frag, fa.pack_attention_data(frag, q2[i], k2[i], v2[i]), fa.read_full,
                   (slice(0, q2.shape[1]), slice(0, v2.shape[2])))
            for i in range(q2.shape[0])
        ]

        def assemble(outs):
            out = np.stack(outs).reshape(q.shape[:-1] + (v.shape[-1],))
            self._record("fasr_attention", "flexasr", out, ideal, self._ncmds(jobs))
            return out

        return jobs, assemble

    # -- HLSCNN -----------------------------------------------------------
    def _plan_hlscnn_conv2d(self, x: ir.Call, args):
        a, w = args
        strides = x.attr("strides")
        padding = x.attr("padding")
        ideal = np.asarray(ir._conv2d(jnp.asarray(a), jnp.asarray(w), strides, padding))
        if padding != (0, 0):
            a = np.pad(
                a, ((0, 0), (padding[0], padding[0]), (padding[1], padding[1]), (0, 0))
            )
        frag = hc.conv2d_fragment(
            w, a.shape[1:], strides, wgt_bits=self.hlscnn_wgt_bits
        )
        window = hc.out_slice(frag)
        jobs = [
            SimJob(frag, hc.pack_conv2d_data(frag, a[ni : ni + 1]), hc.read_full, window)
            for ni in range(a.shape[0])
        ]

        def assemble(outs):
            out = np.concatenate(outs, axis=0)
            self._record("hlscnn_conv2d", "hlscnn", out, ideal, self._ncmds(jobs))
            return out

        return jobs, assemble

    # -- VTA ---------------------------------------------------------------
    def _vta_gemm_kernel(self, x: ir.Call, args):
        a, b = args
        ideal = a @ b.T
        sa = np.abs(a).max() / 127.0 if np.abs(a).max() > 0 else 1.0
        sb = np.abs(b).max() / 127.0 if np.abs(b).max() > 0 else 1.0
        a8 = np.clip(np.round(a / sa), -127, 127)
        b8 = np.clip(np.round(b / sb), -127, 127)
        out32 = np.asarray(
            kops.int8_gemm(jnp.asarray(a8, jnp.int8), jnp.asarray(b8, jnp.int8))
        ).astype(np.float64)
        out = out32 * sa * sb
        self._record("vta_gemm", "vta", out, ideal, 0)
        return out.astype(np.float32)

    def _plan_vta_gemm(self, x: ir.Call, args):
        a, b = args
        ideal = a @ b.T
        sa = np.abs(a).max() / 127.0 if np.abs(a).max() > 0 else 1.0
        sb = np.abs(b).max() / 127.0 if np.abs(b).max() > 0 else 1.0
        a8 = np.clip(np.round(a / sa), -127, 127)
        b8 = np.clip(np.round(b / sb), -127, 127)
        # tile rows so SRAM limits hold: mt*kt <= N_INP etc.
        kt = (a8.shape[1] + vt.T - 1) // vt.T
        max_m = max(1, (vt.N_INP // kt)) * vt.T
        max_n = max(1, (vt.N_WGT // kt)) * vt.T
        mt_layout = (min(max_m, a8.shape[0]) + vt.T - 1) // vt.T
        jobs, layout = [], []
        for mi in range(0, a8.shape[0], max_m):
            a_chunk = a8[mi : mi + max_m]
            row = []
            for nj in range(0, b8.shape[0], max_n):
                b_chunk = b8[nj : nj + max_n]
                frag = vt.gemm_fragment(b_chunk, mt_layout)
                jobs.append(
                    SimJob(frag, vt.pack_gemm_data(frag, a_chunk), vt.read_gemm_full(frag),
                           (slice(0, a_chunk.shape[0]), slice(0, b_chunk.shape[0])))
                )
                row.append(len(jobs) - 1)
            layout.append(row)

        def assemble(outs):
            out32 = np.concatenate(
                [np.concatenate([outs[i] for i in row], axis=1) for row in layout],
                axis=0,
            ).astype(np.float64)
            out = out32 * sa * sb
            self._record("vta_gemm", "vta", out, ideal, self._ncmds(jobs))
            return out.astype(np.float32)

        return jobs, assemble

    def _plan_vta_add(self, x: ir.Call, args):
        a, b = args
        # elementwise adds stay in the accumulator's wide fixed point; the
        # driver scales both operands onto a shared int grid
        s = max(np.abs(a).max(), np.abs(b).max(), 1e-9) / (2 ** 20)
        ai = np.round(np.broadcast_to(a, np.broadcast_shapes(a.shape, b.shape)) / s)
        bi = np.round(np.broadcast_to(b, ai.shape) / s)
        a2 = ai.reshape(-1, ai.shape[-1]) if ai.ndim > 1 else ai.reshape(1, -1)
        b2 = bi.reshape(a2.shape)
        ct = (a2.shape[1] + vt.T - 1) // vt.T
        max_r = max(1, (vt.N_ACC // 2) // ct) * vt.T
        jobs = []
        for ri in range(0, a2.shape[0], max_r):
            ac, bc = a2[ri : ri + max_r], b2[ri : ri + max_r]
            rt = (ac.shape[0] + vt.T - 1) // vt.T
            frag = vt.alu_fragment(rt, ct, "add")
            jobs.append(
                SimJob(frag, vt.pack_alu_data(frag, ac, bc), vt.read_alu_full(frag),
                       (slice(0, ac.shape[0]), slice(0, ac.shape[1])))
            )

        def assemble(outs):
            out = (np.concatenate(outs, axis=0) * s).reshape(ai.shape).astype(np.float32)
            self._record("vta_add", "vta", out, np.asarray(a) + np.asarray(b),
                         self._ncmds(jobs))
            return out

        return jobs, assemble

    def _plan_vta_relu(self, x: ir.Call, args):
        (a,) = args
        s = max(np.abs(a).max(), 1e-9) / (2 ** 20)
        ai = np.round(a / s)
        a2 = ai.reshape(-1, ai.shape[-1]) if ai.ndim > 1 else ai.reshape(1, -1)
        ct = (a2.shape[1] + vt.T - 1) // vt.T
        max_r = max(1, (vt.N_ACC // 2) // ct) * vt.T
        jobs = []
        for ri in range(0, a2.shape[0], max_r):
            ac = a2[ri : ri + max_r]
            rt = (ac.shape[0] + vt.T - 1) // vt.T
            frag = vt.alu_fragment(rt, ct, "relu")
            jobs.append(
                SimJob(frag, vt.pack_alu_data(frag, ac), vt.read_alu_full(frag),
                       (slice(0, ac.shape[0]), slice(0, ac.shape[1])))
            )

        def assemble(outs):
            out = (np.concatenate(outs, axis=0) * s).reshape(a.shape).astype(np.float32)
            self._record("vta_relu", "vta", out, np.maximum(a, 0), self._ncmds(jobs))
            return out

        return jobs, assemble

    _PLANNERS = {
        "fasr_linear": _plan_fasr_linear,
        "fasr_lstm": _plan_fasr_lstm,
        "fasr_maxpool": lambda self, x, a: self._plan_fasr_pool(x, a, "max"),
        "fasr_meanpool": lambda self, x, a: self._plan_fasr_pool(x, a, "mean"),
        "fasr_layernorm": _plan_fasr_layernorm,
        "fasr_attention": _plan_fasr_attention,
        "hlscnn_conv2d": _plan_hlscnn_conv2d,
        "vta_gemm": _plan_vta_gemm,
        "vta_add": _plan_vta_add,
        "vta_relu": _plan_vta_relu,
    }
