"""Code generation + execution for matched programs.

After flexible matching extracts a program containing accelerator intrinsics,
this module plays the role of the paper's BYOC code generator + runtime: each
accelerator op is lowered to an ILA command stream (the "MMIO writes" of
Figure 5d) and either

* ``mode="ila"``     — executed on the ILA simulator, bit-accurate in the
  accelerator's custom numerics (the application-level co-simulation path,
  Section 2.3.2), or
* ``mode="kernel"``  — executed on the TPU-native Pallas fast path with the
  same numeric semantics (deployment path), or
* ``mode="ideal"``   — fp32 reference (the IR interpreter; oracle).

The driver layer tiles tensors that exceed device SRAM (row-chunking for
FlexASR, 16x16 tiling for VTA is inside its fragment builder) — the same
job a real device driver does.

Per-invocation statistics (op, rel-error vs ideal, value ranges) are
collected — the "handy debugging information" the paper's authors gave the
accelerator developers to diagnose the HLSCNN weight-quantization bug.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from . import ir
from ..accel import flexasr as fa
from ..accel import hlscnn as hc
from ..accel import vta as vt
from ..accel import numerics
from ..kernels import ops as kops


@dataclasses.dataclass
class InvocationStat:
    op: str
    backend: str
    rel_err: float
    out_min: float
    out_max: float
    n_commands: int


class Executor:
    """Executes an extracted IR program, offloading accelerator intrinsics."""

    def __init__(
        self,
        mode: str = "ila",
        hlscnn_wgt_bits: int = 8,
        collect_stats: bool = True,
        jit_sim: bool = True,
    ):
        assert mode in ("ila", "kernel", "ideal")
        self.mode = mode
        self.hlscnn_wgt_bits = hlscnn_wgt_bits
        self.collect_stats = collect_stats
        self.jit_sim = jit_sim
        self.stats: List[InvocationStat] = []

    def _sim(self, ila, cmds):
        return ila.simulate_jit(cmds) if self.jit_sim else ila.simulate(cmds)

    # ------------------------------------------------------------------
    def run(self, e: ir.Expr, env: Dict[str, Any]):
        memo: Dict[ir.Expr, Any] = {}

        def rec(x: ir.Expr):
            if x in memo:
                return memo[x]
            if isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS:
                args = [np.asarray(rec(a)) for a in x.args]
                v = self._exec_accel(x, args)
            else:
                v = ir._eval(x, rec, env)
            memo[x] = v
            return v

        return rec(e)

    # ------------------------------------------------------------------
    def _record(self, op, backend, out, ideal, ncmds):
        if not self.collect_stats:
            return
        out = np.asarray(out, np.float64)
        ideal = np.asarray(ideal, np.float64)
        denom = np.linalg.norm(ideal)
        err = float(np.linalg.norm(ideal - out) / denom) if denom > 0 else 0.0
        self.stats.append(
            InvocationStat(op, backend, err, float(out.min()), float(out.max()), ncmds)
        )

    def _exec_accel(self, x: ir.Call, args: List[np.ndarray]):
        op = x.op
        if self.mode == "ideal":
            return self._ideal(x, args)
        if op in ("fasr_store", "fasr_load"):
            return args[0]
        fn = {
            "fasr_linear": self._fasr_linear,
            "fasr_lstm": self._fasr_lstm,
            "fasr_maxpool": lambda x_, a: self._fasr_pool(x_, a, "max"),
            "fasr_meanpool": lambda x_, a: self._fasr_pool(x_, a, "mean"),
            "fasr_layernorm": self._fasr_layernorm,
            "fasr_attention": self._fasr_attention,
            "hlscnn_conv2d": self._hlscnn_conv2d,
            "vta_gemm": self._vta_gemm,
            "vta_add": self._vta_add,
            "vta_relu": self._vta_relu,
        }[op]
        return fn(x, args)

    def _ideal(self, x: ir.Call, args):
        vs = [ir.Var(f"_{i}", np.shape(a)) for i, a in enumerate(args)]
        env = {f"_{i}": a for i, a in enumerate(args)}
        return ir.interpret(ir.Call(x.op, tuple(vs), x.attrs), env)

    # -- FlexASR ---------------------------------------------------------
    def _run_fasr(self, builder, *tensors, ideal, opname):
        cmds, rd = builder(*tensors)
        st = self._sim(fa.flexasr, cmds)
        out = np.asarray(rd(st))
        self._record(opname, "flexasr", out, ideal, len(cmds))
        return out

    def _chunk_rows(self, x, max_rows):
        return [x[i : i + max_rows] for i in range(0, x.shape[0], max_rows)]

    def _fasr_linear(self, x: ir.Call, args):
        a, w, b = args
        orig_shape = a.shape
        a2 = a.reshape(-1, a.shape[-1])
        ideal_full = a2 @ w.T + b
        if self.mode == "kernel":
            out = np.asarray(kops.af_linear(jnp.asarray(a2), jnp.asarray(w), jnp.asarray(b)))
            self._record("fasr_linear", "flexasr-kernel", out, ideal_full, 0)
        else:
            outs = []
            for chunk in self._chunk_rows(a2, fa.MAX_TS):
                cmds, rd = fa.build_linear_fragment(chunk, w, b)
                st = self._sim(fa.flexasr, cmds)
                outs.append(np.asarray(rd(st)))
            out = np.concatenate(outs, axis=0)
            self._record("fasr_linear", "flexasr", out, ideal_full, 0)
        return out.reshape(orig_shape[:-1] + (w.shape[0],))

    def _fasr_lstm(self, x: ir.Call, args):
        xs, wi, wh, b = args
        T, B, I = xs.shape
        ideal = np.asarray(ir._lstm(jnp.asarray(xs), jnp.asarray(wi), jnp.asarray(wh), jnp.asarray(b)))
        outs = []
        for bi in range(B):
            cmds, rd = fa.build_lstm_fragment(xs[:, bi], wi, wh, b)
            st = self._sim(fa.flexasr, cmds)
            outs.append(np.asarray(rd(st)))
        out = np.stack(outs, axis=1)
        self._record("fasr_lstm", "flexasr", out, ideal, 0)
        return out

    def _fasr_pool(self, x: ir.Call, args, kind):
        (a,) = args
        T = a.shape[0]
        pairs = a[: T - T % 2].reshape(T // 2, 2, *a.shape[1:])
        ideal = pairs.max(1) if kind == "max" else pairs.mean(1)
        outs = []
        for chunk in self._chunk_rows(a, fa.MAX_TS):
            # pooling is elementwise across features: chunk wide matrices
            # column-wise to fit the device's MAX_IN lanes
            col_outs = []
            for c0 in range(0, chunk.shape[1], fa.MAX_IN):
                cmds, rd = fa.build_pool_fragment(chunk[:, c0 : c0 + fa.MAX_IN], kind)
                st = self._sim(fa.flexasr, cmds)
                col_outs.append(np.asarray(rd(st)))
            outs.append(np.concatenate(col_outs, axis=1))
        out = np.concatenate(outs, axis=0)
        self._record(f"fasr_{kind}pool", "flexasr", out, ideal, 0)
        return out

    def _fasr_layernorm(self, x: ir.Call, args):
        a, g, b = args
        orig = a.shape
        a2 = a.reshape(-1, a.shape[-1])
        mu = a2.mean(-1, keepdims=True)
        va = a2.var(-1, keepdims=True)
        ideal = (a2 - mu) / np.sqrt(va + 1e-5) * g + b
        outs = []
        for chunk in self._chunk_rows(a2, fa.MAX_TS):
            cmds, rd = fa.build_layernorm_fragment(chunk, g, b)
            st = self._sim(fa.flexasr, cmds)
            outs.append(np.asarray(rd(st)))
        out = np.concatenate(outs, axis=0).reshape(orig)
        self._record("fasr_layernorm", "flexasr", out, ideal, 0)
        return out

    def _fasr_attention(self, x: ir.Call, args):
        q, k, v = args
        ideal = np.asarray(ir._attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)))
        if q.ndim == 2:
            cmds, rd = fa.build_attention_fragment(q, k, v)
            out = np.asarray(rd(self._sim(fa.flexasr, cmds)))
        else:
            # batch of heads: one invocation per (batch) slice
            outs = []
            q2 = q.reshape(-1, q.shape[-2], q.shape[-1])
            k2 = k.reshape(-1, k.shape[-2], k.shape[-1])
            v2 = v.reshape(-1, v.shape[-2], v.shape[-1])
            for i in range(q2.shape[0]):
                cmds, rd = fa.build_attention_fragment(q2[i], k2[i], v2[i])
                outs.append(np.asarray(rd(self._sim(fa.flexasr, cmds))))
            out = np.stack(outs).reshape(q.shape[:-1] + (v.shape[-1],))
        self._record("fasr_attention", "flexasr", out, ideal, 0)
        return out

    # -- HLSCNN -----------------------------------------------------------
    def _hlscnn_conv2d(self, x: ir.Call, args):
        a, w = args
        strides = x.attr("strides")
        padding = x.attr("padding")
        ideal = np.asarray(ir._conv2d(jnp.asarray(a), jnp.asarray(w), strides, padding))
        outs = []
        for ni in range(a.shape[0]):
            cmds, rd = hc.build_conv2d_fragment(
                a[ni : ni + 1], w, strides, padding, wgt_bits=self.hlscnn_wgt_bits
            )
            st = self._sim(hc.hlscnn, cmds)
            outs.append(np.asarray(rd(st)))
        out = np.concatenate(outs, axis=0)
        self._record("hlscnn_conv2d", "hlscnn", out, ideal, 0)
        return out

    # -- VTA ---------------------------------------------------------------
    def _vta_gemm(self, x: ir.Call, args):
        a, b = args
        ideal = a @ b.T
        sa = np.abs(a).max() / 127.0 if np.abs(a).max() > 0 else 1.0
        sb = np.abs(b).max() / 127.0 if np.abs(b).max() > 0 else 1.0
        a8 = np.clip(np.round(a / sa), -127, 127)
        b8 = np.clip(np.round(b / sb), -127, 127)
        if self.mode == "kernel":
            out32 = np.asarray(
                kops.int8_gemm(jnp.asarray(a8, jnp.int8), jnp.asarray(b8, jnp.int8))
            ).astype(np.float64)
        else:
            # tile rows so SRAM limits hold: mt*kt <= N_INP etc.
            kt = (a8.shape[1] + vt.T - 1) // vt.T
            max_m = max(1, (vt.N_INP // kt)) * vt.T
            max_n = max(1, (vt.N_WGT // kt)) * vt.T
            outs = []
            for mi in range(0, a8.shape[0], max_m):
                rows = []
                for nj in range(0, b8.shape[0], max_n):
                    cmds, rd = vt.build_gemm_fragment(a8[mi : mi + max_m], b8[nj : nj + max_n])
                    st = self._sim(vt.vta, cmds)
                    rows.append(np.asarray(rd(st)))
                outs.append(np.concatenate(rows, axis=1))
            out32 = np.concatenate(outs, axis=0).astype(np.float64)
        out = out32 * sa * sb
        self._record("vta_gemm", "vta", out, ideal, 0)
        return out.astype(np.float32)

    def _vta_add(self, x: ir.Call, args):
        a, b = args
        # elementwise adds stay in the accumulator's wide fixed point; the
        # driver scales both operands onto a shared int grid
        s = max(np.abs(a).max(), np.abs(b).max(), 1e-9) / (2 ** 20)
        ai = np.round(np.broadcast_to(a, np.broadcast_shapes(a.shape, b.shape)) / s)
        bi = np.round(np.broadcast_to(b, ai.shape) / s)
        a2 = ai.reshape(-1, ai.shape[-1]) if ai.ndim > 1 else ai.reshape(1, -1)
        b2 = bi.reshape(a2.shape)
        ct = (a2.shape[1] + vt.T - 1) // vt.T
        max_r = max(1, (vt.N_ACC // 2) // ct) * vt.T
        outs = []
        for ri in range(0, a2.shape[0], max_r):
            cmds, rd = vt.build_add_fragment(a2[ri : ri + max_r], b2[ri : ri + max_r])
            st = self._sim(vt.vta, cmds)
            outs.append(np.asarray(rd(st)))
        out = (np.concatenate(outs, axis=0) * s).reshape(ai.shape).astype(np.float32)
        self._record("vta_add", "vta", out, np.asarray(a) + np.asarray(b), 0)
        return out

    def _vta_relu(self, x: ir.Call, args):
        (a,) = args
        s = max(np.abs(a).max(), 1e-9) / (2 ** 20)
        ai = np.round(a / s)
        a2 = ai.reshape(-1, ai.shape[-1]) if ai.ndim > 1 else ai.reshape(1, -1)
        ct = (a2.shape[1] + vt.T - 1) // vt.T
        max_r = max(1, (vt.N_ACC // 2) // ct) * vt.T
        outs = []
        for ri in range(0, a2.shape[0], max_r):
            cmds, rd = vt.build_relu_fragment(a2[ri : ri + max_r])
            st = self._sim(vt.vta, cmds)
            outs.append(np.asarray(rd(st)))
        out = (np.concatenate(outs, axis=0) * s).reshape(a.shape).astype(np.float32)
        self._record("vta_relu", "vta", out, np.maximum(a, 0), 0)
        return out
