"""Code generation + execution for matched programs.

After flexible matching extracts a program containing accelerator intrinsics,
this module plays the role of the paper's BYOC code generator + runtime: each
accelerator op is lowered to an ILA command stream (the "MMIO writes" of
Figure 5d) and either

* ``mode="ila"``     — executed on the ILA simulator, bit-accurate in the
  accelerator's custom numerics (the application-level co-simulation path,
  Section 2.3.2), or
* ``mode="kernel"``  — executed on the TPU-native Pallas fast path with the
  same numeric semantics where the target declares one (deployment path), or
* ``mode="ideal"``   — fp32 reference (the IR interpreter; oracle).

The Executor is **target-agnostic**: every intrinsic dispatches through the
:data:`~repro.core.ila.TARGETS` registry to the planner its
``AcceleratorTarget`` declared (``repro/accel/target.py``). Planners own the
driver-layer tiling (row-chunking, 16x16 tiles, column splits) and return
``SimJob`` lists; this module only schedules and batches them. Adding an
accelerator therefore never touches this file.

Execution engine
----------------

``engine="compiled"`` (default) routes every accelerator invocation through
the fragment-compiler fast path of :mod:`..core.ila`: each op is *planned*
into simulation jobs (CompiledFragment + per-sample DataStream + output
window), jobs sharing a fragment and stream signature are batched through
one ``vmap``-ed simulator call, and fragment setup (weight load) is
simulated once per parameter set and cached in the owning target's
fragment cache. Minibatched evaluation flows through :meth:`Executor.run_many`.

``engine="jit"`` re-derives and scans the full command stream per invocation
(the pre-fragment-compiler behavior); ``engine="eager"`` interprets commands
one by one. Both exist as bit-exact references for the compiled path.

Multi-device scheduling
-----------------------

The Executor owns a :class:`DeviceRegistry`: ``devices_per_target`` simulated
device instances per registered target, each with its **own fragment cache**
(its own "SRAM" — setup streams re-simulate per device, exactly as a real
driver loads weights into each physical accelerator). Signature-grouped
SimJob batches are assigned to devices by estimated cycles with greedy LPT
(longest processing time first onto the least-loaded device), the classic
2-approximation for makespan. Cycle estimates come from the owning target's
declared :class:`~repro.accel.target.CostModel`. Because ILA simulation is a
pure function of architectural state, device placement never changes
results — all engines stay bit-exact for any device count.

Per-invocation statistics (op, rel-error vs ideal, value ranges, predicted
cost) are collected — the "handy debugging information" the paper's authors
gave the accelerator developers to diagnose the HLSCNN weight-quantization
bug — and aggregated per target by :meth:`Executor.stats_summary`, which
also reports per-device utilization and estimated-cycle columns;
:meth:`Executor.cache_info` surfaces per-target warm-cache health for the
serving path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from . import ir
from .ila import CompiledFragment, FragmentCache, TARGETS
from ..accel.target import (  # importing registers bundled targets
    CostEstimate, PlanContext, SimJob,
)


@dataclasses.dataclass
class InvocationStat:
    op: str
    backend: str
    rel_err: float
    out_min: float
    out_max: float
    n_commands: int
    #: CostModel prediction made at plan time (None if the target declares
    #: no model); ``CostModel.calibrate`` fits command scales from these
    est: Optional[CostEstimate] = None


class _NullDeviceType:
    """Placement stand-in for fragments of unregistered ILAs (no device
    pool): index 0 means "setup already cached", so no cold-load term."""

    index = 0


_NullDevice = _NullDeviceType()


class SimDevice:
    """One simulated accelerator instance of a target.

    Device 0 shares the target's process-wide fragment cache (the planners
    already build fragments there), so the single-device default is
    bit-and-cost-identical to the pre-device Executor. Devices >= 1 own a
    private :class:`~repro.core.ila.FragmentCache`: their setup streams
    re-simulate on first use — each device loads its own weights, like
    distinct physical accelerators — and stay warm per device thereafter.
    """

    def __init__(self, target, index: int):
        self.target = target
        self.index = index
        self.name = f"{target.name}[{index}]"
        self.fragments = target.fragments if index == 0 else FragmentCache()
        self.busy_cycles = 0.0
        self.n_jobs = 0
        self.n_groups = 0

    def resolve(self, frag: CompiledFragment) -> CompiledFragment:
        """This device's instance of ``frag`` (device-local setup state)."""
        if self.index == 0:
            return frag
        return self.fragments.get(
            frag.key,
            lambda: CompiledFragment(frag.ila, frag.key, frag.setup, dict(frag.meta)),
        )

    def account(self, n_jobs: int, cycles: float) -> None:
        self.n_groups += 1
        self.n_jobs += n_jobs
        self.busy_cycles += cycles

    def summary(self) -> Dict[str, float]:
        return {
            "jobs": self.n_jobs,
            "groups": self.n_groups,
            "est_cycles": self.busy_cycles,
        }


class DeviceRegistry:
    """N simulated device instances per registered target, created lazily
    (targets may register after the Executor is constructed)."""

    def __init__(self, devices_per_target: Union[int, Dict[str, int]] = 1):
        self.devices_per_target = devices_per_target
        self._devices: Dict[str, List[SimDevice]] = {}

    def n_for(self, name: str) -> int:
        if isinstance(self.devices_per_target, dict):
            return max(1, int(self.devices_per_target.get(name, 1)))
        return max(1, int(self.devices_per_target))

    def devices(self, target) -> List[SimDevice]:
        devs = self._devices.get(target.name)
        if devs is None or len(devs) != self.n_for(target.name):
            devs = [SimDevice(target, i) for i in range(self.n_for(target.name))]
            self._devices[target.name] = devs
        return devs

    def owner(self, frag: CompiledFragment):
        """The registered target owning ``frag`` (matched by ILA identity);
        None for fragments of unregistered ILAs (executed unscheduled)."""
        for t in TARGETS.all():
            if t.ila is frag.ila:
                return t
        return None

    def pick(self, target) -> SimDevice:
        """Least-loaded device of ``target`` (the LPT assignment step)."""
        return min(self.devices(target), key=lambda d: (d.busy_cycles, d.index))

    def summary(self) -> Dict[str, Dict[str, Dict[str, float]]]:
        """Per-target per-device accounting with utilization relative to the
        target's makespan (most-loaded device = 1.0)."""
        out: Dict[str, Dict[str, Dict[str, float]]] = {}
        for tname, devs in self._devices.items():
            makespan = max((d.busy_cycles for d in devs), default=0.0)
            out[tname] = {
                d.name: dict(
                    d.summary(),
                    utilization=(d.busy_cycles / makespan) if makespan > 0 else 0.0,
                )
                for d in devs
            }
        return out


class Executor:
    """Executes an extracted IR program, offloading accelerator intrinsics.

    ``target_options`` carries per-target execution options keyed by target
    name (e.g. a weight-datatype selection for a backend with configurable
    numerics); planners read them through their
    :class:`~repro.accel.target.PlanContext`.

    ``devices_per_target`` sizes the :class:`DeviceRegistry`: an int applies
    to every target, a dict keys per-target counts by name. With more than
    one device per target, signature-grouped SimJob batches are scheduled
    greedy-LPT by CostModel cycle estimates (see the module docstring);
    results are bit-identical for any count.
    """

    def __init__(
        self,
        mode: str = "ila",
        collect_stats: bool = True,
        jit_sim: bool = True,
        engine: Optional[str] = None,
        target_options: Optional[Dict[str, Dict[str, Any]]] = None,
        devices_per_target: Union[int, Dict[str, int]] = 1,
    ):
        assert mode in ("ila", "kernel", "ideal")
        self.mode = mode
        self.collect_stats = collect_stats
        self.engine = engine or ("compiled" if jit_sim else "eager")
        assert self.engine in ("compiled", "jit", "eager")
        self.target_options = {k: dict(v) for k, v in (target_options or {}).items()}
        self.devices = DeviceRegistry(devices_per_target)
        self.stats: List[InvocationStat] = []

    # ------------------------------------------------------------------
    def run(self, e: ir.Expr, env: Dict[str, Any]):
        memo: Dict[ir.Expr, Any] = {}

        def rec(x: ir.Expr):
            if x in memo:
                return memo[x]
            if isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS:
                args = [np.asarray(rec(a)) for a in x.args]
                v = self._exec_accel(x, args)
            else:
                v = ir._eval(x, rec, env)
            memo[x] = v
            return v

        return rec(e)

    def run_many(self, e: ir.Expr, envs: Sequence[Dict[str, Any]]):
        """Evaluate the program once per environment, batching accelerator
        invocations *across samples*: all B samples' jobs for one IR node
        run through one vmapped simulator call (sharing the node's cached
        fragment), while host glue ops evaluate per sample. Per-sample
        numerics (chunking, AF exponent windows) are identical to B calls
        of :meth:`run`."""
        B = len(envs)
        memo: Dict[ir.Expr, List[Any]] = {}

        def rec(x: ir.Expr) -> List[Any]:
            if x in memo:
                return memo[x]
            if isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS:
                args_b = [rec(a) for a in x.args]
                sample_args = [
                    [np.asarray(args_b[k][s]) for k in range(len(args_b))]
                    for s in range(B)
                ]
                if (
                    self.mode == "ila"
                    and self.engine == "compiled"
                    and TARGETS.has_planner(x.op)
                ):
                    plans, jobs = [], []
                    for s in range(B):
                        s_jobs, assemble = self._plan(x, sample_args[s])
                        plans.append((len(jobs), len(s_jobs), assemble))
                        jobs += s_jobs
                    outs = self._execute_jobs(jobs)
                    v = [asm(outs[o : o + n]) for (o, n, asm) in plans]
                else:
                    v = [self._exec_accel(x, sample_args[s]) for s in range(B)]
            else:
                v = [
                    ir._eval(x, (lambda a, s=s: rec(a)[s]), envs[s])
                    for s in range(B)
                ]
            memo[x] = v
            return v

        return rec(e)

    # ------------------------------------------------------------------
    def _record(self, op, backend, out, ideal, ncmds, est=None):
        if not self.collect_stats:
            return
        out = np.asarray(out, np.float64)
        ideal = np.asarray(ideal, np.float64)
        denom = np.linalg.norm(ideal)
        err = float(np.linalg.norm(ideal - out) / denom) if denom > 0 else 0.0
        self.stats.append(
            InvocationStat(
                op, backend, err, float(out.min()), float(out.max()), ncmds, est
            )
        )

    def _estimate(self, target, x: ir.Call, args) -> Optional[CostEstimate]:
        """CostModel prediction for one invocation (None without a model)."""
        model = target.cost_model
        if model is None or not model.covers(x.op):
            return None
        return model.estimate(x.op, dict(x.attrs), [np.shape(a) for a in args])

    def _ctx(self, target, est: Optional[CostEstimate] = None) -> PlanContext:
        record = self._record if est is None else (
            lambda *a, _est=est, **kw: self._record(*a, est=_est, **kw)
        )
        return PlanContext(
            record=record, options=self.target_options.get(target.name, {})
        )

    def _exec_accel(self, x: ir.Call, args: List[np.ndarray]):
        if self.mode == "ideal":
            return self._ideal(x, args)
        target, intr = TARGETS.intrinsic(x.op)
        if intr.passthrough:
            return args[0]
        if self.mode == "kernel" and intr.kernel is not None:
            return intr.kernel(self._ctx(target, self._estimate(target, x, args)), x, args)
        jobs, assemble = self._plan(x, args)
        return assemble(self._execute_jobs(jobs))

    def _ideal(self, x: ir.Call, args):
        vs = [ir.Var(f"_{i}", np.shape(a)) for i, a in enumerate(args)]
        env = {f"_{i}": a for i, a in enumerate(args)}
        return ir.interpret(ir.Call(x.op, tuple(vs), x.attrs), env)

    def _plan(self, x: ir.Call, args) -> Tuple[List[SimJob], Callable]:
        target, intr = TARGETS.intrinsic(x.op)
        if intr.planner is None:
            raise NotImplementedError(
                f"target {target.name!r} declares no planner for {x.op!r}"
            )
        return intr.planner(self._ctx(target, self._estimate(target, x, args)), x, args)

    # -- job execution ---------------------------------------------------
    def _group_cycles(self, frag, idxs: List[int], jobs, target, device) -> float:
        """Estimated cycles for one signature group on ``device``: data
        commands for every job, plus the setup stream when this device has
        not simulated it yet (cold weight load)."""
        n = sum(len(jobs[i].data) for i in idxs)
        if device.index > 0 and frag.key not in device.fragments:
            n += len(frag.setup)
        model = target.cost_model if target is not None else None
        return model.job_cycles(n) if model is not None else float(n)

    def _execute_jobs(self, jobs: List[SimJob]) -> List[np.ndarray]:
        """Run simulation jobs, batching those that share a fragment and a
        data-stream signature through one vmapped simulator call, and
        scheduling the batches over the target's simulated devices
        (greedy LPT on CostModel cycle estimates)."""
        results: List[Optional[np.ndarray]] = [None] * len(jobs)
        if self.engine != "compiled":
            for i, j in enumerate(jobs):
                cmds = j.frag.full_commands(j.data)
                ila = j.frag.ila
                st = ila.simulate_jit(cmds) if self.engine == "jit" else ila.simulate(cmds)
                results[i] = np.asarray(j.read(st))[j.window]
            return results
        groups: Dict[Tuple, List[int]] = {}
        for i, j in enumerate(jobs):
            groups.setdefault((id(j.frag), j.data.sig()), []).append(i)
        # longest-processing-time-first over each target's device pool; a
        # single-device pool preserves the original group order exactly
        order = []
        for key, idxs in groups.items():
            frag = jobs[idxs[0]].frag
            target = self.devices.owner(frag)
            rank = self._group_cycles(frag, idxs, jobs, target, _NullDevice)
            order.append((rank, idxs, target))
        multi = any(
            t is not None and self.devices.n_for(t.name) > 1 for _, _, t in order
        )
        if multi:
            order.sort(key=lambda e: -e[0])
        for _rank, idxs, target in order:
            frag = jobs[idxs[0]].frag
            read = jobs[idxs[0]].read
            if target is not None:
                device = self.devices.pick(target)
                # book against the chosen device, including its cold-setup
                # cost (the ranking pass above is placement-blind)
                device.account(
                    len(idxs),
                    self._group_cycles(frag, idxs, jobs, target, device),
                )
                frag = device.resolve(frag)
            if len(idxs) == 1:
                j = jobs[idxs[0]]
                results[idxs[0]] = np.asarray(read(frag.run(j.data)))[j.window]
            else:
                sts = frag.run_batch([jobs[i].data for i in idxs])
                fulls = np.asarray(jax.vmap(read)(sts))
                for bi, i in enumerate(idxs):
                    results[i] = fulls[bi][jobs[i].window]
        return results

    # -- statistics & cache surfacing ------------------------------------
    def reset_stats(self) -> None:
        self.stats.clear()

    def stats_summary(self) -> Dict[str, Dict[str, Any]]:
        """Aggregate invocation stats per target: invocation count, total
        interface commands, worst relative error vs the fp32 oracle, total
        CostModel-estimated cycles, and — once jobs have been scheduled —
        per-device rows (jobs, estimated cycles, utilization relative to
        the target's makespan)."""
        out: Dict[str, Dict[str, Any]] = {}
        for s in self.stats:
            tname = ir.accel_op_target(s.op) or s.backend
            d = out.setdefault(
                tname,
                {"invocations": 0, "commands": 0, "max_rel_err": 0.0,
                 "est_cycles": 0.0},
            )
            d["invocations"] += 1
            d["commands"] += s.n_commands
            d["max_rel_err"] = max(d["max_rel_err"], s.rel_err)
            if s.est is not None:
                d["est_cycles"] += s.est.cycles
        for tname, devs in self.devices.summary().items():
            out.setdefault(
                tname,
                {"invocations": 0, "commands": 0, "max_rel_err": 0.0,
                 "est_cycles": 0.0},
            )["devices"] = devs
        return out

    def calibrate_cost_models(self) -> Dict[str, Dict[str, float]]:
        """Run every registered target's ``CostModel.calibrate`` against the
        invocation stats collected so far (observed interface command counts
        vs the analytic predictions); returns the fitted per-op command
        scales keyed by target name."""
        out: Dict[str, Dict[str, float]] = {}
        for t in TARGETS.all():
            if t.cost_model is not None:
                out[t.name] = t.cost_model.calibrate(self.stats)
        return out

    def cache_info(self, targets: Optional[Sequence[str]] = None) -> Dict[str, Dict]:
        """Per-target warm-cache health: fragment-cache hits/misses plus jit
        trace / compiled-runner counts (serving-path observability)."""
        return {t.name: t.cache_info() for t in TARGETS.all(targets)}
