"""Application-level co-simulation (Section 2.3.2 / Table 4).

Trains the Section-4.2 applications on deterministic synthetic tasks (no
WikiText-2 / CIFAR-10 offline — DESIGN.md §7), then evaluates the *compiled*
program three ways:

  reference  — fp32 on the host (the IR interpreter), Table 4 column 3
  original   — ILA co-simulation with the original numerics
               (HLSCNN 8-bit weights), column 4
  updated    — ILA co-simulation with the developers' fix
               (HLSCNN 16-bit weights), column 5

reproducing the paper's phenomenon: per-op errors of a few percent are fine
for FlexASR apps, but HLSCNN's 8-bit weight quantization collapses conv-net
accuracy, and the 16-bit update recovers it. Per-invocation statistics
(Executor.stats) provide the debugging data of the case study.

The IR interpreter is JAX-traceable, so training differentiates straight
through the *same* program that is later co-simulated.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ir
from .codegen import Executor


# ---------------------------------------------------------------------------
# tiny Adam (training substrate for the co-sim apps)
# ---------------------------------------------------------------------------


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# synthetic tasks
# ---------------------------------------------------------------------------


def make_teacher_task(builder, input_shape, n=512, seed=7, teacher_seed=99, temp=0.5):
    """Teacher-student labels: a same-architecture random teacher guarantees
    the task is representable by the student (deterministic, no datasets)."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n,) + tuple(input_shape)).astype(np.float32)
    t_expr, t_params = builder(seed=teacher_seed)
    tp = {k: jnp.asarray(v) for k, v in t_params.items()}

    def fwd(x):
        env = dict(tp)
        env["x"] = x
        return ir.interpret(t_expr, env).reshape(-1)

    logits = np.asarray(jax.vmap(fwd)(jnp.asarray(X)))
    # center per class over the dataset so the argmax labels are balanced
    # (a raw random teacher lets one class's bias dominate)
    logits = (logits - logits.mean(0)) / (logits.std(0) + 1e-6)
    y = np.argmax(logits / temp, axis=1)
    return X, y


def make_char_task(vocab=32, T=16, n=256, seed=7, order=1):
    """Deterministic-ish Markov text: learnable next-token prediction."""
    rng = np.random.default_rng(seed)
    trans = rng.dirichlet(np.full(vocab, 0.05), size=vocab)
    seqs = np.zeros((n, T + 1), np.int64)
    for i in range(n):
        s = rng.integers(vocab)
        for t in range(T + 1):
            seqs[i, t] = s
            s = rng.choice(vocab, p=trans[s])
    return seqs[:, :-1], seqs[:, 1:], trans


# ---------------------------------------------------------------------------
# training via the IR interpreter
# ---------------------------------------------------------------------------


def _xent(logits, y):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, y[..., None], axis=-1).mean()


def train_app(expr, params, X, y, steps=300, bs=32, lr=2e-3, seed=0, embed=None):
    """Train by differentiating through the IR interpreter."""
    keys = sorted(params)
    rng = np.random.default_rng(seed)

    def fwd(p, x):
        env = dict(p)
        env["x"] = x
        return ir.interpret(expr, env)

    def loss(p, xb, yb):
        if embed is not None:
            emb = p["_embed"]
            xe = emb[xb]                                 # (bs, T, E)
            logits = jax.vmap(lambda s: fwd(p, s[:, None, :]))(xe)
            return _xent(logits, yb)
        logits = jax.vmap(lambda s: fwd(p, s))(xb)
        return _xent(logits.reshape(xb.shape[0], -1), yb)

    p = {k: jnp.asarray(v) for k, v in params.items()}
    if embed is not None:
        p["_embed"] = jnp.asarray(
            rng.standard_normal((embed[0], embed[1])).astype(np.float32) * 0.3
        )
    st = adam_init(p)
    step = jax.jit(
        lambda p_, st_, xb, yb: (lambda g: adam_update(p_, g, st_, lr=lr))(
            jax.grad(loss)(p_, xb, yb)
        )
    )
    n = len(X)
    for i in range(steps):
        idx = rng.integers(0, n, bs)
        p, st = step(p, st, jnp.asarray(X[idx]), jnp.asarray(y[idx]))
    return {k: np.asarray(v) for k, v in p.items()}


# ---------------------------------------------------------------------------
# co-simulation evaluation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CosimResult:
    application: str
    platform: str
    reference: float
    original: float
    updated: Optional[float]
    metric: str
    n_eval: int
    sim_seconds_per_point: float
    invocation_stats: Dict[str, float]


def _pipeline_batch(executor: Executor, batch_size: int) -> int:
    """Feed ``run_many`` through the pipelined/fused engines with at least
    two pack/sim chunks per minibatch — a single-chunk minibatch has nothing
    to overlap, so the pack worker would idle (the fused engine shares the
    pipelined prepare/dispatch split). No-op for synchronous engines
    (identical numerics either way: batch composition never changes
    per-sample results)."""
    if getattr(executor, "engine", None) in ("pipelined", "fused"):
        return max(batch_size, 2 * executor.pipeline_chunk)
    return batch_size


def _iter_batch_outputs(executor: Executor, program, env_batches):
    """Depth-1 minibatch lookahead over ``Executor.submit_many``: minibatch
    k+1 is submitted — its host packing starts on the pack worker — before
    minibatch k's deferred readback barrier is paid, so the pipeline never
    drains at minibatch boundaries. On synchronous engines ``submit_many``
    degenerates to ``run_many`` and this is a plain loop. Yields each
    minibatch's outputs in submission order (bit-identical to ``run_many``
    per minibatch)."""
    pending = None
    for envs in env_batches:
        sub = executor.submit_many(program, envs)
        if pending is not None:
            yield pending.result()
        pending = sub
    if pending is not None:
        yield pending.result()


def eval_classification(program, params, X, y, executor: Executor, n_eval=100, batch_size=16):
    """Co-simulated accuracy, evaluated in minibatches: each batch's
    accelerator invocations run through one vmapped simulator call per IR
    node (``Executor.run_many``), with per-sample numerics identical to
    sample-at-a-time evaluation. With a pipelined executor the minibatch is
    sized to keep its pack/sim pipeline full (host packing of one chunk
    overlaps simulation of the previous)."""
    correct = 0
    batch_size = _pipeline_batch(executor, batch_size)
    t0 = time.perf_counter()
    batches = [range(i0, min(i0 + batch_size, n_eval))
               for i0 in range(0, n_eval, batch_size)]
    env_batches = ([dict(params, x=X[i]) for i in idx] for idx in batches)
    for idx, outs in zip(batches, _iter_batch_outputs(executor, program, env_batches)):
        for out, i in zip(outs, idx):
            logits = np.asarray(out).reshape(-1)
            correct += int(np.argmax(logits) == y[i])
    dt = (time.perf_counter() - t0) / n_eval
    return correct / n_eval, dt


def eval_outputs(program, params, make_x, indices, executor: Executor,
                 batch_size=16):
    """Raw per-example output tensors for selected dataset rows.

    ``make_x(i)`` builds the input for dataset row ``i``; rows are evaluated
    in ``run_many`` minibatches (numerics identical to per-sample ``run``).
    Returns one ndarray per requested row, in ``indices`` order — the
    primitive under paired golden-vs-mutant statistics: both sides see the
    exact same rows, so every per-example delta is semantic, not sampling
    noise."""
    batch_size = _pipeline_batch(executor, batch_size)
    idx = list(indices)
    chunks = [idx[i0 : i0 + batch_size] for i0 in range(0, len(idx), batch_size)]
    env_batches = ([dict(params, x=make_x(i)) for i in chunk] for chunk in chunks)
    outs = []
    for batch_outs in _iter_batch_outputs(executor, program, env_batches):
        outs.extend(np.asarray(o) for o in batch_outs)
    return outs


def eval_perplexity(program, params, Xtok, Ytok, executor: Executor, n_eval=50, batch_size=16):
    emb = params["_embed"]
    nll, count = 0.0, 0
    batch_size = _pipeline_batch(executor, batch_size)
    t0 = time.perf_counter()
    model_params = {k: v for k, v in params.items() if k != "_embed"}
    batches = [range(i0, min(i0 + batch_size, n_eval))
               for i0 in range(0, n_eval, batch_size)]
    env_batches = ([dict(model_params, x=emb[Xtok[i]][:, None, :]) for i in idx]
                   for idx in batches)
    for idx, outs in zip(batches, _iter_batch_outputs(executor, program, env_batches)):
        for out, i in zip(outs, idx):
            logits = np.asarray(out)
            logp = logits - logits.max(-1, keepdims=True)
            logp = logp - np.log(np.exp(logp).sum(-1, keepdims=True))
            nll += -logp[np.arange(len(Ytok[i])), Ytok[i]].sum()
            count += len(Ytok[i])
    dt = (time.perf_counter() - t0) / n_eval
    return float(np.exp(nll / count)), dt
