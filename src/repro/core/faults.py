"""Semantic fault injection into ILA instruction definitions.

The paper's headline result is that *application-level* validation through
the ILA caught an accelerator flaw that implementation-level checks missed.
This module turns that one-off case study into a repeatable experiment: a
library of **fault models** — each a small, hardware-plausible corruption of
one ILA instruction's state-update semantics — applied by cloning a
registered :class:`~repro.accel.target.AcceleratorTarget` into an ephemeral
**mutant** target. The campaign driver (:mod:`.campaign`) then measures
which validation tier first detects each mutant.

Design constraints, and how they are met:

* **Zero per-fault core edits.** A fault is data: a map from ILA instruction
  names to update-function wrappers. Mutants are ordinary
  ``AcceleratorTarget`` objects built by :func:`make_mutant`; they flow
  through the registry, the Executor, the scheduler and the validation
  runners exactly like the golden target. Applicability is decided by
  introspecting the target's ILA (instruction names, architectural-state
  register names), so a plugin backend following the bundled naming idioms
  picks up the library automatically.

* **Same name, mutated silicon.** A mutant keeps the golden target's
  registry name and intrinsic op set — it *is* that accelerator, with a bug
  — and is swapped in under :func:`swapped_in`, which replaces the golden
  registration in place (registry order preserved) and restores the exact
  prior objects on exit. A full campaign leaves ``TARGETS`` and the IR
  accel-op extension table bit-identical (see the leak-check test).

* **Warm golden caches are shared.** Mutant planners delegate to the golden
  planners — all host-side packing (fragment streams, exponent windows,
  ideal references) comes out of the golden target's warm caches — and only
  *rebind* each SimJob's fragment to a mutant-side ``CompiledFragment`` in
  the mutant's private cache, where the setup stream re-simulates under the
  mutated ILA. Thousands of mutant co-sim runs pay mutant-side simulation
  only, never repeat packing.

* **Faults hold on every engine.** Two injection mechanisms:

  - **ILA-update wrappers** (``wrappers``) mutate an instruction's
    state-update function — hardware faults. Trigger- and config-level
    wrappers keep the compiled fast path (the data runners unroll the tail
    through the *mutant* ILA's update table). A wrapper on a bulk row-write
    instruction invalidates the fragment compiler's slice-update lowering;
    such faults set ``mutates_bulk`` and the mutant planner converts each
    ``DataStream`` to its full ``PackedStream`` so the stream-scan tier
    (real instruction dispatch) executes them.
  - **Payload transforms** (``payload``) corrupt the command payloads of
    selected opcodes host-side, vectorized over whole streams — interface/
    DMA-path faults (wrong rounding in the write datapath, wraparound past
    the representable top). Every engine consumes the same transformed
    streams (eager/jit via ``full_commands``, compiled/pipelined via the
    rebound fragments), so semantics agree bit-for-bit, and the bulk
    slice-update lowering stays valid — payload mutants run at full
    fragment-compiler speed, which is what makes application-tier
    evaluation of *subtle* faults affordable.
  - **Stream transforms** (``stream``) rewrite whole command streams
    host-side — ``fn(ops, addrs, data) -> (ops, addrs, data)`` — modelling
    *protocol*-level faults that corrupt the command interface rather than
    any one instruction's datapath: a decoder that aliases two opcodes, a
    command queue that delivers config payloads one transaction late.
    They ride the same rebinding path as payload transforms (and may be
    combined with one), with one restriction enforced at transform time:
    bulk operand runs must come back with opcodes and addresses untouched,
    so the fragment compiler's slice-update lowering stays valid. Tail and
    setup streams may be rewritten freely.

Fault classes (``FAULT_CLASSES``): ``identity`` (control: must be bit-exact
and produce zero detections), ``trunc_width`` (sizing register off by one,
one variant per sizing register), ``sat_wrap`` (saturation replaced by
two's-complement-style wraparound), ``round_floor`` (round-to-nearest
replaced by truncation on operand writes), ``addr_swap`` (adjacent operand
rows land at swapped addresses), ``drop_cfg`` (a setup/config command is
silently dropped, one variant per droppable config), ``stale_state``
(persistent state leaks into an invocation instead of the driver-assumed
reset value, one variant per persistent register), and the multi-instruction
protocol faults ``decode_alias`` (the command decoder confuses an adjacent
opcode pair) and ``cmd_reorder`` (a config opcode's payloads are delivered
one transaction late).

``DIAGNOSTIC_FAULT_CLASSES`` (never enumerated by default — selected only
by explicit name) exercise the campaign runner itself rather than the
accelerator semantics: ``crash_inject`` raises during co-simulation and
``hang_inject`` stalls it, proving crash isolation and per-mutant timeouts
end-to-end.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..accel import numerics as numerics_mod
from ..accel.target import AcceleratorTarget, Intrinsic, SimJob
from . import ir
from .ila import (
    ILA, NOP_OPCODE, TARGETS, CompiledFragment, DataStream, PackedStream,
)

Wrapper = Callable[[Callable], Callable]


@dataclasses.dataclass
class FaultInstance:
    """One concrete applicable mutation of one target.

    ``wrappers`` maps ILA instruction names to update-function wrappers
    (``wrap(orig_update) -> new_update``); ``payload`` is a vectorized
    host-side payload transform ``fn(ops, data) -> data``; ``stream`` is a
    whole-stream protocol transform ``fn(ops, addrs, data) -> (ops, addrs,
    data)`` (see module docstring for when each mechanism applies — when
    both host-side transforms are present, ``stream`` runs first).
    ``instruction`` names the mutated instruction (or instruction pair) for
    reporting. ``mutates_bulk`` marks wrappers on bulk row-write
    instructions, which invalidates the fragment compiler's slice-update
    lowering (see module docstring)."""

    fault: str
    target: str
    instruction: str
    note: str
    wrappers: Dict[str, Wrapper] = dataclasses.field(default_factory=dict)
    payload: Optional[Callable[[np.ndarray, np.ndarray], np.ndarray]] = None
    stream: Optional[Callable[[np.ndarray, np.ndarray, np.ndarray],
                              Tuple[np.ndarray, np.ndarray, np.ndarray]]] = None
    mutates_bulk: bool = False

    @property
    def key(self) -> str:
        return f"{self.target}:{self.fault}@{self.instruction}"

    def host_xform(self):
        """The combined host-side stream corruption, or None. Signature
        ``fn(ops, addrs, data) -> (ops, addrs, data)``; the protocol
        transform runs before the payload transform (a reordered command's
        payload still goes through the corrupted write datapath)."""
        if self.payload is None and self.stream is None:
            return None

        def fn(ops, addrs, data):
            if self.stream is not None:
                ops, addrs, data = self.stream(ops, addrs, data)
            if self.payload is not None:
                data = self.payload(ops, data)
            return ops, addrs, data

        return fn

    def covers(self, target: AcceleratorTarget) -> Tuple[str, ...]:
        """Intrinsic ops this mutation can corrupt. An ILA-level fault
        underlies every co-simulated (planner-backed) intrinsic of the
        target; the identity fault trivially covers pass-through markers
        too (they never touch the ILA, so only the no-op applies)."""
        if self.fault == "identity":
            return tuple(target.intrinsics)
        return tuple(
            op for op, i in target.intrinsics.items() if i.planner is not None
        )


class FaultModel:
    """A fault class: a name plus a generator of applicable instances."""

    def __init__(self, name: str, description: str,
                 variants: Callable[[AcceleratorTarget], List[FaultInstance]]):
        self.name = name
        self.description = description
        self._variants = variants

    def variants(self, target: AcceleratorTarget) -> List[FaultInstance]:
        """Applicable instances for ``target`` ([] when the target's ILA
        exposes none of the idioms this fault corrupts)."""
        return self._variants(target)


# ---------------------------------------------------------------------------
# ILA-introspection helpers: the naming idioms the bundled backends share
# ---------------------------------------------------------------------------

#: compute-trigger instruction names (the 0x30 "start" command of each ILA)
_TRIGGERS = ("fn_start", "conv_start", "ew_start")
#: primary operand row-write instructions (bulk data path)
_DATA_WRITERS = ("write_v", "wr_act", "wr_a", "wr_dram")
#: config instructions whose silent loss is a classic driver/setup fault,
#: most-preferred first (numerics/datatype config, then operand staging)
_DROPPABLE_CFGS = ("cfg_numerics", "cfg_dtype", "cfg_num", "load_acc",
                   "pe_cfg_act_mngr")
#: sizing registers a truncation-width fault decrements (state-reg names)
_WIDTH_REGS = ("num_in", "num_out", "in_c", "n_cols")
#: persistent cross-invocation state a stale-leak fault pollutes
_STALE_REGS = ("h_state", "c_state")


def _instr(ila: ILA, names: Sequence[str]) -> Optional[str]:
    have = {i.name for i in ila.instructions}
    for n in names:
        if n in have:
            return n
    return None


def _instrs(ila: ILA, names: Sequence[str]) -> List[str]:
    """All instructions from the idiom list present in this ILA, in idiom
    order — per-instruction fault variants enumerate over these."""
    have = {i.name for i in ila.instructions}
    return [n for n in names if n in have]


def _opcode(ila: ILA, name: str) -> int:
    for i in ila.instructions:
        if i.name == name:
            return i.opcode
    raise KeyError(name)


def _payload_on(opcode: int, fn: Callable[[np.ndarray], np.ndarray]):
    """Vectorized payload transform applying ``fn`` to rows of ``opcode``
    commands only (config/trigger payloads pass through untouched)."""

    def xform(ops: np.ndarray, data: np.ndarray) -> np.ndarray:
        if data.size == 0:
            return data
        mask = (np.asarray(ops) == opcode)[:, None]
        return np.where(mask, fn(np.asarray(data, np.float32)), data).astype(
            np.float32
        )

    return xform


def _state_reg(ila: ILA, names: Sequence[str]) -> Optional[str]:
    for n in names:
        if n in ila._state_init:
            return n
    return None


def _state_regs(ila: ILA, names: Sequence[str]) -> List[str]:
    return [n for n in names if n in ila._state_init]


# ---------------------------------------------------------------------------
# The mutators
# ---------------------------------------------------------------------------


def _identity_variants(t: AcceleratorTarget) -> List[FaultInstance]:
    return [FaultInstance("identity", t.name, "-",
                          "no-op control mutant: must be bit-exact")]


def _trunc_width_variants(t: AcceleratorTarget) -> List[FaultInstance]:
    trig = _instr(t.ila, _TRIGGERS)
    regs = _state_regs(t.ila, _WIDTH_REGS)
    if trig is None or not regs:
        return []

    out = []
    for reg in regs:

        def wrap(orig, reg=reg):
            def update(st, addr, data):
                narrowed = dict(st)
                narrowed[reg] = jnp.maximum(narrowed[reg] - 1.0, 0.0)
                out = dict(orig(narrowed, addr, data))
                out[reg] = st[reg]  # transient: config readback is unchanged
                return out

            return update

        out.append(FaultInstance(
            "trunc_width", t.name,
            trig if len(regs) == 1 else f"{trig}/{reg}",
            f"compute reads sizing register {reg!r} one too small "
            "(last operand lane silently dropped)",
            wrappers={trig: wrap},
        ))
    return out


def _sat_wrap_variants(t: AcceleratorTarget) -> List[FaultInstance]:
    """Saturation -> wraparound in the operand write datapath: payload
    values beyond the representable top wrap two's-complement style
    instead of clamping. The threshold comes from the numerics declaration;
    block-scaled numerics (AdaptivFloat, blockfp) size their window from
    the tensor, so the overflow point is the payload's own top-of-range —
    modelled as wrapping the top quantization bin's worth of magnitude."""
    numerics = str(t.capabilities.get("numerics", ""))
    writer = _instr(t.ila, _DATA_WRITERS)
    if writer is None:
        return []
    # fixed-range interfaces (hlscnn 16-bit fixed / 8 frac, vta's int8-grid
    # dram rows) saturate at +/-128; block-scaled numerics size their window
    # from the tensor, so the overflow point sits in the far tail of
    # unit-scale data — small validation draws almost never reach it, but
    # application tensors (heavier-tailed residual-stream activations,
    # orders of magnitude more values) do: the classic rare-overflow fault
    # that only application-level validation sees
    vmax = numerics_mod.saturation_point(numerics)

    def fn(rows, vmax=vmax):
        return np.mod(rows + vmax, 2.0 * vmax) - vmax

    return [FaultInstance(
        "sat_wrap", t.name, writer,
        f"operand writes wrap past +/-{vmax:g} instead of saturating",
        payload=_payload_on(_opcode(t.ila, writer), fn),
    )]


def _round_floor_variants(t: AcceleratorTarget) -> List[FaultInstance]:
    """Wrong rounding mode in the operand write datapath: payloads land on
    the storage grid rounded toward -inf instead of to-nearest — a small
    *systematic* bias per value, well inside every per-op tolerance,
    engineered to accumulate across a full application."""
    numerics = str(t.capabilities.get("numerics", ""))
    writer = _instr(t.ila, _DATA_WRITERS)
    grid = numerics_mod.rounding_grid(numerics)
    if writer is None or grid is None:
        # integer-interface targets (VTA) carry pre-quantized integer
        # payloads: a rounding-mode fault has nothing to corrupt
        return []

    def fn(rows, grid=grid):
        return np.floor(rows / grid) * grid

    return [FaultInstance(
        "round_floor", t.name, writer,
        f"operand writes truncate toward -inf on a {grid:g} grid "
        "(systematic half-step bias)",
        payload=_payload_on(_opcode(t.ila, writer), fn),
    )]


def _addr_swap_variants(t: AcceleratorTarget) -> List[FaultInstance]:
    writer = _instr(t.ila, _DATA_WRITERS)
    if writer is None:
        return []

    def wrap(orig):
        def update(st, addr, data):
            return orig(st, jnp.bitwise_xor(addr.astype(jnp.int32), 1), data)

        return update

    return [FaultInstance(
        "addr_swap", t.name, writer,
        "adjacent operand rows land at swapped addresses (addr ^ 1)",
        wrappers={writer: wrap}, mutates_bulk=True,
    )]


def _drop_cfg_variants(t: AcceleratorTarget) -> List[FaultInstance]:
    out = []
    for cfg in _instrs(t.ila, _DROPPABLE_CFGS):

        def wrap(orig):
            def update(st, addr, data):
                return st  # the command is silently swallowed

            return update

        out.append(FaultInstance(
            "drop_cfg", t.name, cfg,
            f"setup command {cfg!r} is silently dropped "
            "(configuration stays at reset values)",
            wrappers={cfg: wrap},
        ))
    return out


def _stale_state_variants(t: AcceleratorTarget) -> List[FaultInstance]:
    trig = _instr(t.ila, _TRIGGERS)
    regs = _state_regs(t.ila, _STALE_REGS)
    if trig is None or not regs:
        return []

    out = []
    for reg in regs:

        def wrap(orig, reg=reg):
            def update(st, addr, data):
                polluted = dict(st)
                polluted[reg] = jnp.full_like(polluted[reg], 0.25)
                return orig(polluted, addr, data)

            return update

        out.append(FaultInstance(
            "stale_state", t.name,
            trig if len(regs) == 1 else f"{trig}/{reg}",
            f"persistent state {reg!r} holds a previous invocation's "
            "residue instead of the driver-assumed reset value",
            wrappers={trig: wrap},
        ))
    return out


def _decode_alias_variants(t: AcceleratorTarget) -> List[FaultInstance]:
    """Decoder confusion between an adjacent opcode pair (a <-> a^1): the
    classic single-bit decode fault. Operand row-writers are excluded (a
    bulk-path opcode swap would break the slice-update lowering — that
    regime is ``addr_swap``'s); at most two pairs per target keep the
    matrix bounded."""
    excluded = {
        i.opcode for i in t.ila.instructions
        if i.opcode == NOP_OPCODE or i.name.startswith(("write", "wr_"))
    }
    by_op = {i.opcode: i for i in t.ila.instructions}
    out: List[FaultInstance] = []
    for ins in sorted(t.ila.instructions, key=lambda i: i.opcode):
        a, b = ins.opcode, ins.opcode ^ 1
        if a > b or a in excluded or b in excluded or b not in by_op:
            continue
        other = by_op[b]

        def xform(ops, addrs, data, a=a, b=b):
            o = np.asarray(ops)
            swapped = np.where(o == a, b, np.where(o == b, a, o))
            return swapped.astype(np.int32), np.asarray(addrs), data

        out.append(FaultInstance(
            "decode_alias", t.name, f"{ins.name}~{other.name}",
            f"command decoder aliases opcodes {a:#x}<->{b:#x} "
            f"({ins.name!r} and {other.name!r} execute each other's "
            "payloads)",
            stream=xform,
        ))
        if len(out) >= 2:
            break
    return out


def _cmd_reorder_variants(t: AcceleratorTarget) -> List[FaultInstance]:
    """Command-queue reordering: every payload of one config opcode is
    delivered one transaction late — invocation k applies invocation k-1's
    config, and the first sees reset values. A protocol fault invisible to
    single-fragment checks when consecutive invocations share a config."""
    out: List[FaultInstance] = []
    for cfg in _instrs(t.ila, _DROPPABLE_CFGS):
        opcode = _opcode(t.ila, cfg)

        def xform(ops, addrs, data, opcode=opcode):
            o = np.asarray(ops)
            rows = np.flatnonzero(o == opcode)
            if rows.size == 0:
                return ops, addrs, data
            d = np.array(data, np.float32, copy=True)
            delayed = d[rows[:-1]].copy()
            d[rows[0]] = 0.0
            if rows.size > 1:
                d[rows[1:]] = delayed
            return o, np.asarray(addrs), d

        out.append(FaultInstance(
            "cmd_reorder", t.name, cfg,
            f"the command queue delivers {cfg!r} payloads one transaction "
            "late (the first lands on reset values)",
            stream=xform,
        ))
    return out


def _crash_inject_variants(t: AcceleratorTarget) -> List[FaultInstance]:
    writer = _instr(t.ila, _DATA_WRITERS)
    if writer is None:
        return []

    def xform(ops, data):
        raise RuntimeError(
            "crash_inject: deliberate diagnostic crash during co-simulation"
        )

    return [FaultInstance(
        "crash_inject", t.name, writer,
        "diagnostic: raises mid-co-simulation (exercises campaign crash "
        "isolation; never part of the default library)",
        payload=xform,
    )]


def _hang_inject_variants(t: AcceleratorTarget) -> List[FaultInstance]:
    writer = _instr(t.ila, _DATA_WRITERS)
    if writer is None:
        return []

    def xform(ops, data):
        time.sleep(float(os.environ.get("REPRO_HANG_SECONDS", "3600")))
        return data

    return [FaultInstance(
        "hang_inject", t.name, writer,
        "diagnostic: stalls mid-co-simulation (exercises per-mutant "
        "timeouts; never part of the default library)",
        payload=xform,
    )]


FAULT_CLASSES: Dict[str, FaultModel] = {
    m.name: m
    for m in (
        FaultModel("identity", "no-op control mutant", _identity_variants),
        FaultModel("trunc_width", "truncation-width off-by-one",
                   _trunc_width_variants),
        FaultModel("sat_wrap", "saturation becomes wraparound",
                   _sat_wrap_variants),
        FaultModel("round_floor", "round-to-nearest becomes floor",
                   _round_floor_variants),
        FaultModel("addr_swap", "swapped operand address",
                   _addr_swap_variants),
        FaultModel("drop_cfg", "dropped setup/config command",
                   _drop_cfg_variants),
        FaultModel("stale_state", "stale accumulator/state leak",
                   _stale_state_variants),
        FaultModel("decode_alias", "decoder aliases an opcode pair",
                   _decode_alias_variants),
        FaultModel("cmd_reorder", "config payloads delivered one late",
                   _cmd_reorder_variants),
    )
}

#: runner-diagnostic faults: selectable only by explicit name, never part
#: of default enumeration — they stress the campaign engine, not the ILA
DIAGNOSTIC_FAULT_CLASSES: Dict[str, FaultModel] = {
    m.name: m
    for m in (
        FaultModel("crash_inject", "raises during co-simulation",
                   _crash_inject_variants),
        FaultModel("hang_inject", "stalls during co-simulation",
                   _hang_inject_variants),
    )
}


def fault_instances(
    target: AcceleratorTarget, faults: Optional[Sequence[str]] = None
) -> List[FaultInstance]:
    """Applicable fault instances for ``target``, in library order.
    ``faults`` selects fault classes by name (None = the full default
    library; diagnostic classes must be named explicitly)."""
    library = dict(FAULT_CLASSES)
    library.update(DIAGNOSTIC_FAULT_CLASSES)
    names = list(FAULT_CLASSES) if faults is None else list(faults)
    out: List[FaultInstance] = []
    for n in names:
        if n not in library:
            raise KeyError(
                f"unknown fault class {n!r}; available: {list(library)}"
            )
        out.extend(library[n].variants(target))
    return out


# ---------------------------------------------------------------------------
# Mutant construction
# ---------------------------------------------------------------------------


def clone_ila(ila: ILA, wrappers: Optional[Dict[str, Wrapper]] = None) -> ILA:
    """Clone an ILA model, wrapping selected instruction updates. The clone
    shares state initializers and update callables with the source but owns
    its jit caches (a mutated instruction set must never reuse the golden
    ILA's compiled simulators or data runners)."""
    wrappers = wrappers or {}
    m = ILA(ila.name, vwidth=ila.vwidth)
    for k, f in ila._state_init.items():
        m.state(k, f)
    for ins in ila.instructions:
        if ins.opcode == NOP_OPCODE:
            continue  # auto-registered by ILA.__init__
        upd = ins.update
        w = wrappers.get(ins.name)
        if w is not None:
            upd = w(upd)
        m.instruction(ins.name, ins.opcode, ins.doc)(upd)
    unknown = set(wrappers) - {i.name for i in ila.instructions}
    if unknown:
        raise KeyError(f"fault wraps unknown instructions {sorted(unknown)}")
    return m


def _xform_stream(ps: PackedStream, fn) -> PackedStream:
    ops, addrs, data = fn(np.asarray(ps.ops), np.asarray(ps.addrs),
                          np.asarray(ps.data, np.float32))
    return PackedStream(np.asarray(ops, np.int32),
                        np.asarray(addrs, np.int32),
                        np.asarray(data, np.float32))


def _xform_data(ds: DataStream, fn) -> DataStream:
    bulk = []
    for b in ds.bulk:
        n = b.rows.shape[0]
        ops = np.full((n,), b.opcode, np.int32)
        addrs = np.arange(b.base, b.base + n, dtype=np.int32)
        o2, a2, rows = fn(ops, addrs, np.asarray(b.rows, np.float32))
        if not (np.array_equal(np.asarray(o2), ops)
                and np.array_equal(np.asarray(a2), addrs)):
            raise ValueError(
                "stream transform rewrote a bulk operand run's opcodes or "
                "addresses — that breaks the fragment compiler's "
                "slice-update lowering; protocol faults may only touch "
                "tail/setup commands (bulk semantics faults belong to "
                "mutates_bulk wrapper faults)"
            )
        bulk.append(dataclasses.replace(b, rows=np.asarray(rows, np.float32)))
    return DataStream(bulk, _xform_stream(ds.tail, fn))


def _mutant_planner(planner: Callable, mutant: AcceleratorTarget,
                    inst: FaultInstance) -> Callable:
    """Delegate to the golden planner (warm golden-side packing caches),
    then rebind every SimJob onto the mutant: the fragment resolves through
    the mutant's private cache — setup streams re-simulate under the
    mutant's ILA (payload faults corrupt them host-side first), exactly
    like a second physical device loading its own weights. Payload faults
    transform the per-invocation streams in place (the bulk fast path stays
    valid); bulk-mutating wrapper faults force the full-stream scan tier."""

    hx = inst.host_xform()

    def plan(ctx, x, args):
        jobs, assemble = planner(ctx, x, args)
        rebound = []
        for j in jobs:
            frag = mutant.fragments.get(
                j.frag.key,
                lambda f=j.frag: CompiledFragment(
                    mutant.ila, f.key,
                    (_xform_stream(f.setup, hx)
                     if hx is not None and len(f.setup)
                     else f.setup),
                    dict(f.meta),
                ),
            )
            data = j.data
            if hx is not None:
                data = (_xform_data(data, hx)
                        if isinstance(data, DataStream)
                        else _xform_stream(data, hx))
            elif inst.mutates_bulk and isinstance(data, DataStream):
                data = data.to_stream()
            rebound.append(SimJob(frag, data, j.read, j.window))
        return rebound, assemble

    return plan


def make_mutant(target: AcceleratorTarget, inst: FaultInstance) -> AcceleratorTarget:
    """Clone ``target`` into an ephemeral mutant carrying ``inst``.

    The mutant keeps the golden name and intrinsic op set (swap it in with
    :func:`swapped_in`), shares the golden cost model / rewrites / declared
    validation cases, owns a private fragment cache bound to its ILA, and
    drops VT3 checks (those closures are bound to the golden module-level
    ILA and would not exercise the mutation). Wrapper faults (and the
    identity control, which exercises the clone machinery) get a cloned
    ILA with fresh jit caches; host-side faults (payload and/or stream
    transforms without wrappers) corrupt command streams before simulation
    and share the golden ILA — and therefore its warm compiled
    simulators."""
    host_only = (
        (inst.payload is not None or inst.stream is not None)
        and not inst.wrappers
    )
    m = AcceleratorTarget(
        target.name,
        target.ila if host_only else clone_ila(target.ila, inst.wrappers),
        display_name=f"{target.display_name}[{inst.fault}]",
        capabilities=target.capabilities,
        doc=f"fault mutant of {target.name}: {inst.note}",
        vt2_tol=target.vt2_tol,
    )
    m.fault = inst
    m.cost_model = target.cost_model
    m._rewrite_fns = list(target._rewrite_fns)
    m._vt2_fns = list(target._vt2_fns)
    m._mapping_fns = list(target._mapping_fns)
    for op, intr in target.intrinsics.items():
        planner = intr.planner
        if planner is not None:
            planner = _mutant_planner(planner, m, inst)
        m.add_intrinsic(dataclasses.replace(intr, planner=planner))
    return m


@contextlib.contextmanager
def swapped_in(mutant: AcceleratorTarget):
    """Swap ``mutant`` in for the like-named golden registration.

    Replaces the target in the registry *in place* (order preserved) and
    re-points the IR accel-op extension specs at the mutant's intrinsics;
    on exit the exact prior target and spec objects are reinstated, so any
    number of swaps leaves the process-wide registries bit-identical. The
    registry swap runs first — it validates the mutant (known name, same
    op set) before anything is mutated — and the spec re-registration is
    covered by the restoring ``finally``, so a failure at any point leaves
    both registries untouched."""
    golden = TARGETS.replace(mutant)
    displaced_specs: Dict[str, Any] = {}
    try:
        for op, intr in mutant.intrinsics.items():
            displaced_specs[op] = ir.register_accel_op(
                op, mutant.name, intr.shape, intr.ideal, not intr.passthrough
            )
        yield golden
    finally:
        # every restoration step runs even if an earlier one fails — a
        # single bad spec must not leak the registry swap or the remaining
        # specs; the first failure is re-raised once everything possible
        # has been restored
        restore_err: Optional[BaseException] = None
        try:
            TARGETS.replace(golden)
        except BaseException as e:  # pragma: no cover - defensive
            restore_err = e
        for op, spec in displaced_specs.items():
            try:
                ir.restore_accel_op(op, spec)
            except BaseException as e:  # pragma: no cover - defensive
                restore_err = restore_err or e
        if restore_err is not None:  # pragma: no cover - defensive
            raise restore_err
