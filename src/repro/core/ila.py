"""Instruction-Level Abstraction (ILA) formalism in JAX.

Mirrors ILAng's model (Huang et al., TODAES'18; Figure 6 of the paper):

* an ILA has **architectural state** — named buffers/registers, here a dict
  of arrays (a pytree);
* each **instruction** corresponds to one command at the accelerator's
  interface (an MMIO write in the paper) and is given by a **decode**
  predicate over the command plus a **state-update function**;
* a **program fragment** is a sequence of commands; simulation folds the
  update functions over the fragment — exactly ILAng's auto-generated
  software simulator, but jit-able (``lax.scan`` + ``lax.switch``).

Commands are uniform records so fragments can be stacked into arrays:

    Command(opcode: int, addr: int, data: float32[V])

``V`` is the interface vector width (16 lanes for FlexASR, like the real
128-bit MMIO payload of Figure 1). Wide tensors are moved one V-lane row per
command — faithfully reproducing the granularity mismatch between IR tensors
and accelerator interface commands that D2A is designed to bridge.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

State = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Command:
    opcode: int
    addr: int = 0
    data: Tuple[float, ...] = ()

    def as_arrays(self, vwidth: int):
        d = np.zeros((vwidth,), np.float32)
        d[: len(self.data)] = self.data
        return np.int32(self.opcode), np.int32(self.addr), d


@dataclasses.dataclass(frozen=True)
class Instruction:
    """One ILA instruction: name + opcode + state-update semantics.

    ``update(state, addr, data) -> state`` must be pure & jit-able.
    ``decode`` defaults to opcode equality (address-map dispatch, like the
    MMIO address decode in Figure 6's ``SetDecode``).
    """

    name: str
    opcode: int
    update: Callable[[State, jnp.ndarray, jnp.ndarray], State]
    doc: str = ""


class ILA:
    """An accelerator (or compiler-IR) ILA model."""

    def __init__(self, name: str, vwidth: int = 16):
        self.name = name
        self.vwidth = vwidth
        self.instructions: List[Instruction] = []
        self._by_opcode: Dict[int, Instruction] = {}
        self._state_init: Dict[str, Callable[[], jnp.ndarray]] = {}

    # -- model construction ---------------------------------------------
    def state(self, name: str, init: Callable[[], jnp.ndarray]):
        self._state_init[name] = init

    def instruction(self, name: str, opcode: int, doc: str = ""):
        def deco(fn):
            ins = Instruction(name, opcode, fn, doc)
            self.instructions.append(ins)
            self._by_opcode[opcode] = ins
            return fn

        return deco

    def init_state(self) -> State:
        return {k: f() for k, f in self._state_init.items()}

    # -- simulation --------------------------------------------------------
    def simulate(self, commands: Sequence[Command], state: Optional[State] = None) -> State:
        """Reference (eager, per-command) simulation — the analogue of the
        ILAng-generated sequential C++ simulator."""
        st = dict(state) if state is not None else self.init_state()
        for cmd in commands:
            ins = self._by_opcode.get(cmd.opcode)
            if ins is None:
                raise KeyError(f"{self.name}: no instruction decodes opcode {cmd.opcode}")
            _, addr, data = cmd.as_arrays(self.vwidth)
            st = ins.update(st, jnp.asarray(addr), jnp.asarray(data))
        return st

    def pack_program(self, commands: Sequence[Command]):
        ops = np.array([c.opcode for c in commands], np.int32)
        addrs = np.array([c.addr for c in commands], np.int32)
        data = np.zeros((len(commands), self.vwidth), np.float32)
        for i, c in enumerate(commands):
            data[i, : len(c.data)] = c.data
        return jnp.asarray(ops), jnp.asarray(addrs), jnp.asarray(data)

    def make_jit_simulator(self):
        """Build a jit-compiled fragment simulator: lax.scan over the packed
        command stream with lax.switch dispatch on opcode.

        All instruction updates must preserve state shapes/dtypes (they do:
        ILA state is fixed architectural state, like hardware registers).
        """
        instrs = sorted(self.instructions, key=lambda i: i.opcode)
        opcode_to_branch = {ins.opcode: b for b, ins in enumerate(instrs)}
        # dense opcode -> branch lookup table
        max_op = max(opcode_to_branch) + 1
        lut = np.zeros((max_op,), np.int32)
        for op, b in opcode_to_branch.items():
            lut[op] = b
        lut = jnp.asarray(lut)

        branches = []
        for ins in instrs:
            def mk(u):
                def br(operand):
                    st, addr, data = operand
                    return u(st, addr, data)

                return br

            branches.append(mk(ins.update))

        def step(st, cmd):
            op, addr, data = cmd
            st2 = jax.lax.switch(lut[op], branches, (st, addr, data))
            return st2, ()

        @jax.jit
        def run(state, ops, addrs, data):
            final, _ = jax.lax.scan(step, state, (ops, addrs, data))
            return final

        return run

    def simulate_jit(self, commands: Sequence[Command], state: Optional[State] = None) -> State:
        """Jit-compiled simulation; the compiled scan is cached (jax.jit
        retraces only per distinct command-stream length)."""
        st = state if state is not None else self.init_state()
        if not hasattr(self, "_jit_run"):
            self._jit_run = self.make_jit_simulator()
        return self._jit_run(st, *self.pack_program(commands))


# --------------------------------------------------------------------------
# Fragments & mappings (Section 2.1.3)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Fragment:
    """A program fragment: a sequence of ILA commands for one accelerator
    operation, plus how tensors marshal in/out of architectural state."""

    ila: ILA
    commands: List[Command]

    def __len__(self):
        return len(self.commands)


@dataclasses.dataclass
class IRAccelMapping:
    """An IR-accelerator mapping (Figure 3): the compiler-IR pattern (as an
    IR op name + arity) on one side, and a fragment *builder* on the other.

    ``build_fragment(inputs...) -> (commands, read_out)`` assembles the
    command stream for concrete operand values and returns a function
    extracting the result from final architectural state.
    """

    name: str
    accelerator: str
    ir_op: str
    build_fragment: Callable[..., Tuple[List[Command], Callable[[State], jnp.ndarray]]]
    doc: str = ""


class MappingRegistry:
    def __init__(self):
        self._maps: Dict[str, IRAccelMapping] = {}

    def register(self, m: IRAccelMapping):
        self._maps[m.ir_op] = m

    def get(self, ir_op: str) -> Optional[IRAccelMapping]:
        return self._maps.get(ir_op)

    def all(self):
        return list(self._maps.values())


REGISTRY = MappingRegistry()
