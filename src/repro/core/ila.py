"""Instruction-Level Abstraction (ILA) formalism in JAX.

Mirrors ILAng's model (Huang et al., TODAES'18; Figure 6 of the paper):

* an ILA has **architectural state** — named buffers/registers, here a dict
  of arrays (a pytree);
* each **instruction** corresponds to one command at the accelerator's
  interface (an MMIO write in the paper) and is given by a **decode**
  predicate over the command plus a **state-update function**;
* a **program fragment** is a sequence of commands; simulation folds the
  update functions over the fragment — exactly ILAng's auto-generated
  software simulator, but jit-able (``lax.scan`` + ``lax.switch``).

Commands are uniform records so fragments can be stacked into arrays:

    Command(opcode: int, addr: int, data: float32[V])

``V`` is the interface vector width (16 lanes for FlexASR, like the real
128-bit MMIO payload of Figure 1). Wide tensors are moved one V-lane row per
command — faithfully reproducing the granularity mismatch between IR tensors
and accelerator interface commands that D2A is designed to bridge.

Fragment-compiler fast path
---------------------------

Per-sample co-simulation is throughput-bound by three costs the paper's
compiled-simulator approach (ILAng generates C++ rather than interpreting)
avoids: re-deriving the command stream, host-side re-packing, and jit
retracing per distinct stream length. This module provides:

* ``PackedStream``    — a command stream as dense host arrays (no per-command
  Python objects on the hot path);
* a reserved ``NOP`` instruction, auto-registered on every ILA, so streams
  pad to power-of-two **length buckets** (bounding retraces to O(log max_len)
  per accelerator);
* ``ILA.simulate_packed`` / ``ILA.simulate_batch`` — bucketed single-stream
  and ``jax.vmap``-batched simulation over stacked command streams;
* ``CompiledFragment`` — a *setup* stream (weight/config load) simulated once
  and cached as architectural state, so steady-state invocations only pack
  and simulate the per-sample *data* stream;
* ``FragmentCache``   — an LRU keyed on (op, operand shapes, params
  fingerprint) holding compiled fragments across Executor invocations.

Pipelined execution support
---------------------------

The batched tiers are split into a **host half** (pure numpy: padding,
stacking, shared-payload detection — safe to run in a pack worker thread,
releases the GIL) and a **dispatch half** (jit lookup + the asynchronous JAX
call, main thread). ``CompiledFragment.prepare_batch``/``run_prepared``
expose the split to the Executor's pipelined engine, which packs group k+1
while group k simulates and materializes results only at assemble barriers.

Mesh sharding
-------------

``set_stream_mesh`` configures a ``jax.sharding.Mesh`` over the host's
devices; the dispatch halves then shard the stacked **batch axis** of
``simulate_batch``/``run_data_batch`` with a ``NamedSharding`` (setup state
stays replicated — the runner is a pure pytree-in/out vmap), co-simulating a
fleet of independent streams across all local devices. Batch dims are padded
to a multiple of the mesh size; sharding reorders *placement*, never
numerics, so results stay bit-exact.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .telemetry import TELEMETRY

State = Dict[str, jnp.ndarray]

# Reserved opcode: identity state update, used only for bucket padding. No
# accelerator model may claim it (they all start their maps at 0x10).
NOP_OPCODE = 0

MIN_BUCKET = 16
MAX_DATA_RUNNERS = 128


def bucket_length(n: int, min_len: int = MIN_BUCKET) -> int:
    """Next power-of-two >= max(n, min_len): the padded stream length."""
    n = max(int(n), min_len)
    return 1 << (n - 1).bit_length()


#: batch-axis bucket ladder for the vmapped simulators. "pow2" (default)
#: pads the batch dimension to the next power of two; "serving" adds the
#: 3/4-of-pow2 steps (1,2,3,4,6,8,12,16,24,32,...) so coalesced
#: cross-request batches — whose sizes are sums of small request batches,
#: rarely near a power of two — waste less replay padding, at the cost of
#: at most one extra trace per octave. Process-wide because traced batch
#: runners are cached per padded shape.
_BATCH_LADDER = "pow2"


def set_batch_ladder(mode: str = "pow2") -> str:
    """Select the batch-axis bucket ladder ("pow2" or "serving"); returns
    the previous mode so callers (the serving layer) can restore it.
    Padding replays the last stream and callers slice [:B], so the ladder
    never changes results — only padded shapes (and hence retraces)."""
    global _BATCH_LADDER
    assert mode in ("pow2", "serving"), f"unknown batch ladder {mode!r}"
    prev = _BATCH_LADDER
    _BATCH_LADDER = mode
    return prev


def batch_bucket(n: int) -> int:
    """Padded batch size for ``n`` streams under the active ladder."""
    p = bucket_length(n, min_len=1)
    if _BATCH_LADDER == "serving" and p >= 4 and n <= (3 * p) // 4:
        return (3 * p) // 4
    return p


# --------------------------------------------------------------------------
# Stream mesh: shard the stacked batch axis over the host's devices
# --------------------------------------------------------------------------

#: process-wide mesh over which batched simulation shards its stream axis
#: (None = single-device dispatch, the default)
_STREAM_MESH: Optional["jax.sharding.Mesh"] = None


def set_stream_mesh(spec: Any = "auto") -> Optional["jax.sharding.Mesh"]:
    """Configure batch-axis sharding for ``simulate_batch``/``run_data_batch``.

    ``spec`` is ``None``/``"off"`` (disable), ``"auto"`` (all local devices),
    an int (first N devices), or a 1-D ``jax.sharding.Mesh``. Returns the
    active mesh, or None when the host has a single device (sharding would
    be a no-op, so it is disabled rather than building a trivial mesh).
    Start the process with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    to expose N virtual devices on a CPU-only host.
    """
    global _STREAM_MESH
    if spec is None or spec == "off":
        _STREAM_MESH = None
        return None
    if isinstance(spec, jax.sharding.Mesh):
        _STREAM_MESH = spec
        return _STREAM_MESH
    devs = jax.devices()
    if spec != "auto":
        devs = devs[: int(spec)]
    if len(devs) <= 1:
        _STREAM_MESH = None
        return None
    _STREAM_MESH = jax.sharding.Mesh(np.array(devs), ("stream",))
    return _STREAM_MESH


def stream_mesh() -> Optional["jax.sharding.Mesh"]:
    return _STREAM_MESH


def mesh_pad(n: int) -> int:
    """Round a padded batch size up to a multiple of the stream mesh size
    (identity without a mesh), so the NamedSharding divides evenly."""
    if _STREAM_MESH is None:
        return n
    m = int(_STREAM_MESH.devices.size)
    return -(-n // m) * m


def _shard_batched(x: np.ndarray):
    """Device-put a batch-leading array with its axis 0 sharded over the
    stream mesh; plain ``jnp.asarray`` without a mesh."""
    if _STREAM_MESH is None:
        return jnp.asarray(x)
    sh = jax.sharding.NamedSharding(
        _STREAM_MESH, jax.sharding.PartitionSpec("stream")
    )
    return jax.device_put(x, sh)


def _replicated(tree):
    """Replicate an unbatched pytree (setup state, shared payload rows)
    across the stream mesh; identity without a mesh."""
    if _STREAM_MESH is None:
        return tree
    sh = jax.sharding.NamedSharding(_STREAM_MESH, jax.sharding.PartitionSpec())
    return jax.device_put(tree, sh)


@dataclasses.dataclass(frozen=True)
class Command:
    opcode: int
    addr: int = 0
    data: Tuple[float, ...] = ()

    def as_arrays(self, vwidth: int):
        d = np.zeros((vwidth,), np.float32)
        d[: len(self.data)] = self.data
        return np.int32(self.opcode), np.int32(self.addr), d


@dataclasses.dataclass
class PackedStream:
    """A command stream as dense host arrays: ops (L,), addrs (L,),
    data (L, V). The hot-path representation — builders that pack tensors
    vectorize straight into these instead of materializing Command lists."""

    ops: np.ndarray
    addrs: np.ndarray
    data: np.ndarray

    def __len__(self) -> int:
        return int(self.ops.shape[0])

    @property
    def vwidth(self) -> int:
        return int(self.data.shape[1])

    @staticmethod
    def empty(vwidth: int) -> "PackedStream":
        return PackedStream(
            np.zeros((0,), np.int32), np.zeros((0,), np.int32),
            np.zeros((0, vwidth), np.float32),
        )

    @staticmethod
    def from_commands(cmds: Sequence[Command], vwidth: int) -> "PackedStream":
        ops = np.array([c.opcode for c in cmds], np.int32)
        addrs = np.array([c.addr for c in cmds], np.int32)
        data = np.zeros((len(cmds), vwidth), np.float32)
        for i, c in enumerate(cmds):
            data[i, : len(c.data)] = c.data
        return PackedStream(ops, addrs, data)

    @staticmethod
    def single(opcode: int, addr: int, values: Sequence[float], vwidth: int) -> "PackedStream":
        d = np.zeros((1, vwidth), np.float32)
        vals = np.asarray(values, np.float32)
        d[0, : len(vals)] = vals
        return PackedStream(np.array([opcode], np.int32), np.array([addr], np.int32), d)

    @staticmethod
    def concat(streams: Sequence["PackedStream"]) -> "PackedStream":
        streams = [s for s in streams if len(s)]
        if not streams:
            raise ValueError("concat of empty stream list")
        return PackedStream(
            np.concatenate([s.ops for s in streams]),
            np.concatenate([s.addrs for s in streams]),
            np.concatenate([s.data for s in streams], axis=0),
        )

    def to_commands(self) -> List[Command]:
        """Inverse of from_commands (compat path; not for the hot loop)."""
        return [
            Command(int(o), int(a), tuple(float(v) for v in d))
            for o, a, d in zip(self.ops, self.addrs, self.data)
        ]

    def sig(self) -> Tuple:
        """Batching signature: the command skeleton (opcodes + addresses as
        static values). Mirrors :meth:`DataStream.sig` so fully-packed
        streams — e.g. a fault-campaign mutant whose write instructions no
        longer satisfy the bulk slice-update lowering — group and batch
        through ``simulate_batch`` exactly like compiled data streams."""
        return (
            ("stream",),
            tuple(int(o) for o in self.ops),
            tuple(int(a) for a in self.addrs),
        )

    def padded(self, length: int, nop_opcode: int = NOP_OPCODE) -> "PackedStream":
        """Pad with NOPs to ``length`` (identity updates: semantics-free)."""
        n = len(self)
        if n == length:
            return self
        assert n < length, f"stream length {n} exceeds pad target {length}"
        ops = np.full((length,), nop_opcode, np.int32)
        addrs = np.zeros((length,), np.int32)
        data = np.zeros((length, self.vwidth), np.float32)
        ops[:n], addrs[:n], data[:n] = self.ops, self.addrs, self.data
        return PackedStream(ops, addrs, data)


@dataclasses.dataclass
class BulkWrite:
    """A run of row-write commands at contiguous addresses, targeting one
    state buffer: ``buf[base + i] = rows[i]``. Every data stream in our ILAs
    moves tensors this way (WRITE_V / WR_ACT / WR_DRAM), so the fragment
    compiler lowers the run to ONE ``dynamic_update_slice`` instead of
    scanning len(rows) commands — bit-identical, since contiguous row writes
    at distinct addresses compose to exactly that slice update."""

    buf: str
    base: int
    rows: np.ndarray  # (n, V)
    opcode: int       # the equivalent per-row instruction, for parity streams

    def to_stream(self) -> PackedStream:
        n = self.rows.shape[0]
        return PackedStream(
            np.full((n,), self.opcode, np.int32),
            np.arange(self.base, self.base + n, dtype=np.int32),
            np.asarray(self.rows, np.float32),
        )

    @property
    def sig(self) -> Tuple:
        return (self.buf, self.base, self.rows.shape)


@dataclasses.dataclass
class DataStream:
    """The per-invocation half of a compiled fragment: bulk tensor loads
    plus the irregular tail (config writes + FN_START trigger). The tail is
    scanned (NOP-bucketed); the bulk is applied as slice updates."""

    bulk: List[BulkWrite]
    tail: PackedStream

    def __len__(self) -> int:
        return sum(b.rows.shape[0] for b in self.bulk) + len(self.tail)

    def to_stream(self) -> PackedStream:
        """Full command-stream form (eager simulation / parity checks)."""
        return PackedStream.concat([b.to_stream() for b in self.bulk] + [self.tail])

    def sig(self) -> Tuple:
        """Compilation signature: bulk layout + the tail's *command skeleton*
        (opcodes + addresses as static values). Streams sharing a signature
        differ only in payloads and compile to one executor."""
        return (
            tuple(b.sig for b in self.bulk),
            tuple(int(o) for o in self.tail.ops),
            tuple(int(a) for a in self.tail.addrs),
        )


@dataclasses.dataclass(frozen=True)
class Instruction:
    """One ILA instruction: name + opcode + state-update semantics.

    ``update(state, addr, data) -> state`` must be pure & jit-able.
    ``decode`` defaults to opcode equality (address-map dispatch, like the
    MMIO address decode in Figure 6's ``SetDecode``).
    """

    name: str
    opcode: int
    update: Callable[[State, jnp.ndarray, jnp.ndarray], State]
    doc: str = ""


class ILA:
    """An accelerator (or compiler-IR) ILA model."""

    def __init__(self, name: str, vwidth: int = 16):
        self.name = name
        self.vwidth = vwidth
        self.instructions: List[Instruction] = []
        self._by_opcode: Dict[int, Instruction] = {}
        self._state_init: Dict[str, Callable[[], jnp.ndarray]] = {}
        # compiled-simulator bookkeeping: one trace per distinct bucketed
        # stream length (and per batch shape for the vmapped tier)
        self.n_traces_single = 0
        self.n_traces_batch = 0
        self.instruction("nop", NOP_OPCODE, "identity update (bucket padding)")(
            lambda st, addr, data: st
        )

    # -- model construction ---------------------------------------------
    def state(self, name: str, init: Callable[[], jnp.ndarray]):
        self._state_init[name] = init

    def instruction(self, name: str, opcode: int, doc: str = ""):
        def deco(fn):
            ins = Instruction(name, opcode, fn, doc)
            self.instructions.append(ins)
            self._by_opcode[opcode] = ins
            return fn

        return deco

    def init_state(self) -> State:
        return {k: f() for k, f in self._state_init.items()}

    # -- simulation --------------------------------------------------------
    def simulate(self, commands: Sequence[Command], state: Optional[State] = None) -> State:
        """Reference (eager, per-command) simulation — the analogue of the
        ILAng-generated sequential C++ simulator."""
        st = dict(state) if state is not None else self.init_state()
        for i, cmd in enumerate(commands):
            ins = self._by_opcode.get(cmd.opcode)
            if ins is None:
                raise self._decode_error(i, cmd.opcode, len(commands))
            _, addr, data = cmd.as_arrays(self.vwidth)
            st = ins.update(st, jnp.asarray(addr), jnp.asarray(data))
        return st

    def _decode_error(self, index: int, opcode: int, n: int) -> RuntimeError:
        """Diagnostic for an undecodable command: names the ILA, the
        offending command's position and opcode, and the nearest registered
        opcodes — a stream-generation bug is debuggable instead of a bare
        KeyError."""
        nearest = sorted(
            self.instructions, key=lambda ins: abs(ins.opcode - opcode)
        )[:4]
        lines = [
            f"  candidate: {ins.name!r} = {ins.opcode:#x} "
            f"(distance {abs(ins.opcode - opcode)})"
            for ins in nearest
        ]
        return RuntimeError(
            f"{self.name}: no instruction decodes opcode {opcode:#x} "
            f"(command {index}/{n}).\n"
            f"  {len(self.instructions)} instructions registered; "
            "nearest opcodes:\n" + "\n".join(lines)
        )

    def pack_program(self, commands: Sequence[Command]):
        ops = np.array([c.opcode for c in commands], np.int32)
        addrs = np.array([c.addr for c in commands], np.int32)
        data = np.zeros((len(commands), self.vwidth), np.float32)
        for i, c in enumerate(commands):
            data[i, : len(c.data)] = c.data
        return jnp.asarray(ops), jnp.asarray(addrs), jnp.asarray(data)

    def _make_step(self):
        """The scan step: lax.switch dispatch on opcode over all updates."""
        instrs = sorted(self.instructions, key=lambda i: i.opcode)
        opcode_to_branch = {ins.opcode: b for b, ins in enumerate(instrs)}
        # dense opcode -> branch lookup table
        max_op = max(opcode_to_branch) + 1
        lut = np.zeros((max_op,), np.int32)
        for op, b in opcode_to_branch.items():
            lut[op] = b
        lut = jnp.asarray(lut)

        branches = []
        for ins in instrs:
            def mk(u):
                def br(operand):
                    st, addr, data = operand
                    return u(st, addr, data)

                return br

            branches.append(mk(ins.update))

        def step(st, cmd):
            op, addr, data = cmd
            st2 = jax.lax.switch(lut[op], branches, (st, addr, data))
            return st2, ()

        return step

    def make_jit_simulator(self):
        """Build a jit-compiled fragment simulator: lax.scan over the packed
        command stream with lax.switch dispatch on opcode.

        All instruction updates must preserve state shapes/dtypes (they do:
        ILA state is fixed architectural state, like hardware registers).
        """
        step = self._make_step()

        def run(state, ops, addrs, data):
            self.n_traces_single += 1  # python side effect: counts traces
            final, _ = jax.lax.scan(step, state, (ops, addrs, data))
            return final

        return jax.jit(run)

    def make_batch_simulator(self):
        """vmap the scanned simulator over stacked command streams, sharing
        one initial state across the batch (independent fragment sims)."""
        step = self._make_step()

        def run_one(state, ops, addrs, data):
            final, _ = jax.lax.scan(step, state, (ops, addrs, data))
            return final

        def run(state, ops, addrs, data):
            self.n_traces_batch += 1
            return jax.vmap(run_one, in_axes=(None, 0, 0, 0))(state, ops, addrs, data)

        return jax.jit(run)

    def simulate_jit(self, commands: Sequence[Command], state: Optional[State] = None) -> State:
        """Jit-compiled simulation; the compiled scan is cached (jax.jit
        retraces only per distinct command-stream length)."""
        st = state if state is not None else self.init_state()
        if not hasattr(self, "_jit_run"):
            self._jit_run = self.make_jit_simulator()
        return self._jit_run(st, *self.pack_program(commands))

    # -- fragment-compiler fast path ------------------------------------
    def simulate_packed(
        self,
        stream: PackedStream,
        state: Optional[State] = None,
        bucket: bool = True,
    ) -> State:
        """Simulate a packed stream, NOP-padded to a power-of-two bucket so
        the jit scan retraces at most O(log max_len) times."""
        st = state if state is not None else self.init_state()
        if bucket:
            stream = stream.padded(bucket_length(len(stream)))
        if not hasattr(self, "_jit_run"):
            self._jit_run = self.make_jit_simulator()
        return self._jit_run(
            st, jnp.asarray(stream.ops), jnp.asarray(stream.addrs), jnp.asarray(stream.data)
        )

    def _host_stream_batch(self, streams: Sequence[PackedStream]):
        """Host half of :meth:`simulate_batch`: NOP-pad to the common length
        bucket, bucket the batch dim (replaying the last stream; a multiple
        of the stream mesh size when one is active) and stack to dense
        arrays. Pure numpy — safe in a pack worker thread."""
        assert streams, "simulate_batch needs at least one stream"
        L = bucket_length(max(len(s) for s in streams))
        B = len(streams)
        Bp = mesh_pad(batch_bucket(B))
        padded = [s.padded(L) for s in streams]
        padded += [padded[-1]] * (Bp - B)
        ops = np.stack([s.ops for s in padded])
        addrs = np.stack([s.addrs for s in padded])
        data = np.stack([s.data for s in padded])
        return ops, addrs, data

    def _dispatch_stream_batch(self, host, state: State) -> State:
        """Dispatch half: jit lookup + the (async) vmapped scan call, with
        the batch axis sharded over the stream mesh when one is active."""
        ops, addrs, data = host
        if not hasattr(self, "_jit_run_batch"):
            self._jit_run_batch = self.make_batch_simulator()
        return self._jit_run_batch(
            _replicated(state), _shard_batched(ops), _shard_batched(addrs),
            _shard_batched(data),
        )

    def simulate_batch(
        self,
        streams: Sequence[PackedStream],
        state: Optional[State] = None,
    ) -> State:
        """Simulate B independent streams (each from the same initial state)
        in one vmapped scan. Streams may have ragged true lengths: all are
        NOP-padded to the common bucket. The batch dimension is bucketed too
        (padding replays the last stream; callers slice [:B]).

        Returns the stacked final state pytree (leading axis = padded batch).
        """
        st = state if state is not None else self.init_state()
        return self._dispatch_stream_batch(self._host_stream_batch(streams), st)

    # -- compiled data-stream execution ---------------------------------
    def _data_runner(self, sig: Tuple, shared_mask: Tuple[bool, ...]):
        """Build the jitted executor for one data-stream signature: each
        bulk write lowers to ONE dynamic_update_slice, and the short tail
        *unrolls* with static opcodes — the command skeleton compiles away
        entirely (no per-step lax.switch), which is the compiled-simulator
        analogue of ILAng's generated C++ vs interpreting the command list.

        ``shared_mask[i]`` marks tail payload rows that are identical across
        a batch: those stay unbatched under vmap, so values derived from
        them (mode/geometry registers) keep scalar batch status and
        FN_START's mode dispatch executes exactly one branch. A batched
        dispatch index would execute every branch at every position.
        """
        if not hasattr(self, "_data_runners"):
            self._data_runners: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        key = (sig, shared_mask)
        run = self._data_runners.get(key)
        if run is not None:
            self._data_runners.move_to_end(key)
            return run
        bulk_sig, tail_ops, tail_addrs = sig
        updates = [self._by_opcode[op].update for op in tail_ops]
        shared_pos = [i for i, s in enumerate(shared_mask) if s]
        batched_pos = [i for i, s in enumerate(shared_mask) if not s]
        row_src = {}  # position -> (which argument, index within it)
        for k, i in enumerate(shared_pos):
            row_src[i] = ("shared", k)
        for k, i in enumerate(batched_pos):
            row_src[i] = ("batched", k)

        def apply(state, rows_list, shared_data, batched_data):
            st = dict(state)
            for (buf, base, _shape), rows in zip(bulk_sig, rows_list):
                st[buf] = jax.lax.dynamic_update_slice(st[buf], rows, (base, 0))
            for i, (update, addr) in enumerate(zip(updates, tail_addrs)):
                which, k = row_src[i]
                row = shared_data[k] if which == "shared" else batched_data[k]
                st = update(st, jnp.int32(addr), row)
            return st

        def run_single(state, rows_list, shared_data, batched_data):
            self.n_traces_single += 1
            return apply(state, rows_list, shared_data, batched_data)

        def run_batch(state, rows_list, shared_data, batched_data):
            self.n_traces_batch += 1
            return jax.vmap(apply, in_axes=(None, 0, None, 0))(
                state, rows_list, shared_data, batched_data
            )

        run = (jax.jit(run_single), jax.jit(run_batch))
        self._data_runners[key] = run
        # bound the compiled-executor cache: heavily ragged workloads (a
        # distinct operand shape per sample) would otherwise grow it without
        # limit; evicted signatures simply re-trace on next use
        while len(self._data_runners) > MAX_DATA_RUNNERS:
            self._data_runners.popitem(last=False)
        return run

    @staticmethod
    def _split_rows(tail_data: np.ndarray, shared_mask: Tuple[bool, ...]):
        shared = [tail_data[i] for i, s in enumerate(shared_mask) if s]
        batched = [tail_data[i] for i, s in enumerate(shared_mask) if not s]
        # keep fixed (possibly 0-length) shapes so the jit signature is stable
        V = tail_data.shape[1] if tail_data.ndim == 2 else 0
        sh = np.stack(shared) if shared else np.zeros((0, V), np.float32)
        ba = np.stack(batched) if batched else np.zeros((0, V), np.float32)
        return sh, ba

    def run_data(self, data: DataStream, state: Optional[State] = None) -> State:
        st = state if state is not None else self.init_state()
        mask = (True,) * len(data.tail)  # single stream: everything "shared"
        single, _ = self._data_runner(data.sig(), mask)
        shared, batched = self._split_rows(data.tail.data, mask)
        return single(
            st,
            [jnp.asarray(b.rows) for b in data.bulk],
            jnp.asarray(shared), jnp.asarray(batched),
        )

    def _host_data_batch(self, datas: Sequence[DataStream]):
        """Host half of :meth:`run_data_batch`: signature check, batch
        bucketing (a multiple of the stream mesh size when one is active),
        shared-payload detection and payload stacking. Pure numpy — safe in
        a pack worker thread."""
        assert datas, "run_data_batch needs at least one stream"
        sig = datas[0].sig()
        assert all(d.sig() == sig for d in datas), "mixed signatures in one batch"
        B = len(datas)
        Bp = mesh_pad(batch_bucket(B))
        datas = list(datas) + [datas[-1]] * (Bp - B)
        tail0 = datas[0].tail.data
        shared_mask = tuple(
            bool(all(np.array_equal(d.tail.data[i], tail0[i]) for d in datas[1:]))
            for i in range(tail0.shape[0])
        )
        rows_list = [
            np.stack([d.bulk[i].rows for d in datas])
            for i in range(len(sig[0]))
        ]
        splits = [self._split_rows(d.tail.data, shared_mask) for d in datas]
        shared = splits[0][0]
        batched = np.stack([s[1] for s in splits])
        return sig, shared_mask, rows_list, shared, batched

    def _dispatch_data_batch(self, host, state: State) -> State:
        """Dispatch half: compiled-runner lookup + the (async) vmapped call.
        Batch-leading payloads shard over the stream mesh when one is
        active; setup state and batch-shared rows replicate."""
        sig, shared_mask, rows_list, shared, batched = host
        _, batch = self._data_runner(sig, shared_mask)
        return batch(
            _replicated(state),
            [_shard_batched(r) for r in rows_list],
            _replicated(jnp.asarray(shared)), _shard_batched(batched),
        )

    def run_data_batch(self, datas: Sequence[DataStream], state: Optional[State] = None) -> State:
        """Batched compiled execution of streams sharing one signature (same
        bulk layout and tail command skeleton; payloads differ). The batch
        dim is bucketed to a power of two by replaying the last stream
        (callers slice [:B]). Payload rows that are identical across the
        batch stay unbatched — see :meth:`_data_runner`."""
        st = state if state is not None else self.init_state()
        return self._dispatch_data_batch(self._host_data_batch(datas), st)

    def jit_cache_info(self) -> Dict[str, int]:
        return {
            "traces_single": self.n_traces_single,
            "traces_batch": self.n_traces_batch,
            "data_runners": len(getattr(self, "_data_runners", {})),
        }


# --------------------------------------------------------------------------
# Fragments & mappings (Section 2.1.3)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Fragment:
    """A program fragment: a sequence of ILA commands for one accelerator
    operation, plus how tensors marshal in/out of architectural state."""

    ila: ILA
    commands: List[Command]

    def __len__(self):
        return len(self.commands)


def fingerprint(*arrays, extra: Tuple = ()) -> str:
    """Content fingerprint of parameter tensors (+ static attrs) — the
    params half of a fragment-cache key. blake2b over dtype/shape/bytes."""
    h = hashlib.blake2b(digest_size=16)
    for a in arrays:
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    if extra:
        h.update(repr(extra).encode())
    return h.hexdigest()


@dataclasses.dataclass
class CompiledFragment:
    """A fragment compiled for steady-state reuse.

    ``setup`` is the one-time stream (weight + static-config load) for one
    parameter set; its effect is simulated once and memoized as
    ``setup_state`` — architectural state with weights resident, exactly as
    a real driver leaves the device configured between invocations. Per
    invocation, callers pack only the *data* stream (activation load +
    FN_START) and run it from the cached setup state. ``meta`` carries
    builder-specific constants (exponent biases, layout dims) the data
    packer and read-out need.
    """

    ila: ILA
    key: Tuple
    setup: PackedStream
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    _setup_state: Optional[State] = dataclasses.field(default=None, repr=False)

    def setup_state(self) -> State:
        if self._setup_state is None:
            st = self.ila.init_state()
            if len(self.setup):
                st = self.ila.simulate_packed(self.setup, state=st)
            self._setup_state = st
        return self._setup_state

    def run(self, data: "DataStream | PackedStream") -> State:
        """One invocation: data stream from the cached post-setup state."""
        if isinstance(data, DataStream):
            return self.ila.run_data(data, state=self.setup_state())
        return self.ila.simulate_packed(data, state=self.setup_state())

    def run_batch(self, streams: Sequence["DataStream | PackedStream"]) -> State:
        """Batched invocations sharing this fragment's setup state; returns
        the stacked final state (leading axis covers the padded batch)."""
        return self.run_prepared(self.prepare_batch(streams))

    def prepare_batch(self, streams: Sequence["DataStream | PackedStream"]):
        """Host half of :meth:`run_batch` — padding, stacking and shared-
        payload detection in pure numpy. Safe to run in a pack worker
        thread; hand the result to :meth:`run_prepared` on the dispatch
        thread (the pipelined Executor's pack stage)."""
        if isinstance(streams[0], DataStream):
            return ("data", self.ila._host_data_batch(streams))
        return ("stream", self.ila._host_stream_batch(streams))

    def run_prepared(self, prepared) -> State:
        """Dispatch half of :meth:`run_batch`: resolve the setup state and
        issue the (async) vmapped simulator call for a prepared batch."""
        kind, host = prepared
        st = self.setup_state()
        if kind == "data":
            return self.ila._dispatch_data_batch(host, st)
        return self.ila._dispatch_stream_batch(host, st)

    def full_commands(self, data: "DataStream | PackedStream") -> List[Command]:
        """setup + data as one eager-simulable Command list (parity checks)."""
        stream = data.to_stream() if isinstance(data, DataStream) else data
        if len(self.setup) == 0:
            return stream.to_commands()
        return PackedStream.concat([self.setup, stream]).to_commands()


class FragmentCache:
    """LRU of CompiledFragments keyed by (op, shapes, params fingerprint).

    Thread-safe: the pipelined Executor's pack worker builds fragments while
    the dispatch thread resolves device-local copies, so lookup+insert (and
    the LRU reordering they imply) run under a lock.
    """

    def __init__(self, maxsize: int = 64):
        self.maxsize = maxsize
        self._entries: "OrderedDict[Tuple, CompiledFragment]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: Tuple, build: Callable[[], CompiledFragment]) -> CompiledFragment:
        with self._lock:
            frag = self._entries.get(key)
            if frag is not None:
                self.hits += 1
                if TELEMETRY.enabled:
                    TELEMETRY.counter("fragments.hits").inc()
                self._entries.move_to_end(key)
                return frag
            self.misses += 1
            if TELEMETRY.enabled:
                TELEMETRY.counter("fragments.misses").inc()
            frag = build()
            frag.key = key
            self._entries[key] = frag
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
            return frag

    def clear(self):
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0

    def __len__(self):
        return len(self._entries)

    def __contains__(self, key: Tuple) -> bool:
        return key in self._entries

    def info(self) -> Dict[str, int]:
        return {"size": len(self._entries), "hits": self.hits, "misses": self.misses}


# --------------------------------------------------------------------------
# Fused fast-path tier
# --------------------------------------------------------------------------


def fused_lowering() -> str:
    """Which lowering a fused runner should build: ``"pallas"`` when a
    native accelerator backend is available (or ``REPRO_FUSED_PALLAS=1``
    forces the Pallas leg, interpret mode included), ``"xla"`` otherwise.
    ``REPRO_FUSED_FALLBACK=1`` forces the XLA-fused fallback everywhere —
    the conformance suite uses it so the fallback leg is exercised even on
    hosts where Pallas lowers natively."""
    if os.environ.get("REPRO_FUSED_FALLBACK", "") == "1":
        return "xla"
    if os.environ.get("REPRO_FUSED_PALLAS", "") == "1":
        return "pallas"
    return "pallas" if jax.default_backend() != "cpu" else "xla"


def fused_pad_streams(datas: Sequence["DataStream"]) -> List["DataStream"]:
    """Pad a fused batch exactly like :meth:`ILA._host_data_batch` pads the
    compiled tier's: bucket per the active batch ladder (times the
    stream-mesh size) by replaying the last stream. Keeping the two tiers'
    padding identical bounds retraces the same way and keeps ``[b]`` handle
    indexing aligned."""
    B = len(datas)
    Bp = mesh_pad(batch_bucket(B))
    return list(datas) + [datas[-1]] * (Bp - B)


@dataclasses.dataclass
class FusedRunner:
    """A target-registered fast path for one compiled-fragment family.

    The compiled tier simulates a ``DataStream`` through architectural
    state: ``dynamic_update_slice`` bulk writes into the state buffers, an
    unrolled config tail, the FN_START update, then a read-out slice. A
    ``FusedRunner`` lowers that whole round trip — bulk write + per-sample
    compute + read-out — into one fused computation on the stream payloads
    themselves, skipping state materialization entirely.

    Contract: ``dispatch(prepare(datas))`` must return the stacked
    full-region read of the fragment's output — element ``b`` equal (within
    the owning intrinsic's declared tolerance; bit-exact where the numerics
    round-trip exactly) to ``read(frag.run(datas[b]))`` for the planner's
    read function, for every ``b < len(datas)``. Entries past ``len(datas)``
    (bucket padding) are unconstrained. The compiled tier stays the
    bit-exactness oracle — conformance diffs the two on every intrinsic.

    ``prepare`` is the host half (pure numpy — safe on the pipelined
    engine's pack worker thread); ``dispatch`` is the device half and
    should return asynchronously (un-materialized jax arrays), sharding
    batch-leading payloads with :func:`_shard_batched` so ``set_stream_mesh``
    composes. ``read`` optionally pins the planner read function the runner
    fuses; the Executor falls back to the compiled tier when a job's read
    differs.
    """

    name: str
    prepare: Callable[[Sequence["DataStream"]], Any]
    dispatch: Callable[[Any], jnp.ndarray]
    read: Optional[Callable] = None
    lowering: str = "xla"

    def run(self, datas: Sequence["DataStream"]) -> jnp.ndarray:
        return self.dispatch(self.prepare(datas))


# --------------------------------------------------------------------------
# Target registry (the AcceleratorTarget plugin surface)
# --------------------------------------------------------------------------


class TargetRegistry:
    """Process-wide registry of :class:`~repro.accel.target.AcceleratorTarget`
    plugins. The core compile/codegen/validate layers are written against
    this registry only — they never name a backend. Registering a target
    (``repro.accel.target.register_target``) is the whole integration step:
    its rewrites join flexible matching, its planners join the Executor, its
    declared validation cases join VT1–VT3 and the conformance suite.
    """

    def __init__(self):
        self._targets: "OrderedDict[str, Any]" = OrderedDict()
        self._by_op: Dict[str, Tuple[Any, Any]] = {}

    def register(self, target) -> None:
        for op in target.intrinsics:
            claimed = self._by_op.get(op)
            if claimed is not None and claimed[0].name != target.name:
                raise ValueError(
                    f"intrinsic {op!r} of target {target.name!r} is already "
                    f"claimed by target {claimed[0].name!r}; intrinsic op "
                    "names must be unique across targets"
                )
        self._targets[target.name] = target
        for op, intr in target.intrinsics.items():
            self._by_op[op] = (target, intr)

    def unregister(self, name: str):
        """Remove a registered target (inverse of :meth:`register`).
        Returns the removed target (None if ``name`` was not registered) so
        callers that must leave the registry bit-identical — the fault
        campaign, synthetic-target tests — can reinstate it."""
        target = self._targets.pop(name, None)
        if target is None:
            return None
        for op in target.intrinsics:
            claimed = self._by_op.get(op)
            if claimed is not None and claimed[0] is target:
                del self._by_op[op]
        return target

    def replace(self, target):
        """Swap ``target`` in under an existing registration of the same
        name, preserving registry order and requiring the same intrinsic op
        set (the fault campaign's mutant swap: same accelerator, mutated
        semantics). Returns the displaced target so the caller can swap it
        back, leaving the registry bit-identical."""
        old = self._targets.get(target.name)
        if old is None:
            raise KeyError(
                f"replace: no registered target named {target.name!r}"
            )
        if set(old.intrinsics) != set(target.intrinsics):
            raise ValueError(
                f"replace: target {target.name!r} intrinsic set changed "
                f"({sorted(set(old.intrinsics) ^ set(target.intrinsics))})"
            )
        self._targets[target.name] = target  # same key: order preserved
        for op, intr in target.intrinsics.items():
            self._by_op[op] = (target, intr)
        return old

    def names(self) -> List[str]:
        return list(self._targets)

    def get(self, name: str):
        if name not in self._targets:
            raise KeyError(
                f"unknown accelerator target {name!r}; registered: {self.names()}"
            )
        return self._targets[name]

    def all(self, names: Optional[Sequence[str]] = None) -> List[Any]:
        if names is None:
            return list(self._targets.values())
        return [self.get(n) for n in names]

    def intrinsic(self, op: str) -> Tuple[Any, Any]:
        """(target, intrinsic) owning intrinsic op ``op``; KeyError if none."""
        if op not in self._by_op:
            raise KeyError(f"no registered target declares intrinsic {op!r}")
        return self._by_op[op]

    def has_planner(self, op: str) -> bool:
        entry = self._by_op.get(op)
        return entry is not None and entry[1].planner is not None


#: the process-wide target registry; populated by importing ``repro.accel``
TARGETS = TargetRegistry()
