"""Fault-injection / differential-validation campaign driver.

Enumerates (target x instruction x fault) mutants — :mod:`.faults` — and
runs every mutant through a **tiered detection ladder**, measuring which
validation tier first distinguishes it from the golden design:

  ``vt2``       the declared VT2 fragment-equivalence checks over abstract
                (fp32) semantics, with each target's threaded tolerance.
                This is the formal-proof analogue: it validates the
                *mapping*, deliberately abstracting numerics away — so a
                fault injected into ILA instruction semantics passes it by
                construction. Quantifying exactly that blind spot is the
                point of running the tier.
  ``frag_sim``  the same declared fragment pairs, but with the accelerator
                side **co-simulated on the mutant ILA** against the fp32 IR
                interpreter, judged by the target's declared co-simulation
                tolerance (the loosest ideal-vs-numerics bound among the
                fragment's intrinsics). The VT3 testing analogue: ILA vs
                reference at fragment granularity.
  ``op_diff``   per-intrinsic golden-vs-mutant differential test: identical
                sampled operands through the golden and the mutant target;
                a relative deviation beyond the intrinsic's declared
                tolerance is a detection.
  ``app``       full-application co-simulation: every selected application
                that offloads work to the target is evaluated end-to-end
                (accuracy or perplexity) on golden and mutant; a metric
                delta beyond the campaign thresholds is a detection.

The output is an **escape-analysis matrix**: per mutant, the verdict of
every tier plus the first detecting tier. Mutants that pass the fragment
tiers (``vt2`` + ``frag_sim``) but are caught by an application metric are
the paper's thesis made quantitative — application-level validation
catching what fragment-level checks miss. The ``identity`` control mutant
must show zero detections at every tier (no false positives).

Scale: mutant runs execute on the Executor's ``pipelined`` engine over
``devices_per_target`` simulated devices by default, and all golden-side
host packing comes out of warm shared caches (see :mod:`.faults`), so a
campaign is thousands of co-sim invocations at steady-state cost — the
throughput is reported as mutants/sec and benchmarked in
``benchmarks/bench_campaign.py``.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import apps as apps_mod, cosim, ir, validate
from .codegen import Executor
from .compile import compile_program
from .faults import FaultInstance, fault_instances, make_mutant, swapped_in
from .ila import TARGETS


@dataclasses.dataclass
class TierResult:
    """One tier's verdict on one mutant. ``detected=None`` means the tier
    did not run (not applicable, or skipped by an escalation ladder)."""

    tier: str
    detected: Optional[bool]
    score: float = 0.0        # worst observed deviation / delta
    threshold: float = 0.0
    detail: str = ""

    def cell(self) -> str:
        if self.detected is None:
            return "-"
        return "CAUGHT" if self.detected else "pass"


@dataclasses.dataclass
class MutantReport:
    target: str
    fault: str
    instruction: str
    note: str
    tiers: Dict[str, TierResult]
    seconds: float = 0.0

    @property
    def key(self) -> str:
        return f"{self.target}:{self.fault}@{self.instruction}"

    @property
    def detected_at(self) -> Optional[str]:
        for name in TIER_ORDER:
            t = self.tiers.get(name)
            if t is not None and t.detected:
                return name
        return None

    @property
    def escaped_fragment_checks(self) -> bool:
        """Passed both fragment tiers (vt2 abstract + co-simulated)."""
        return all(
            self.tiers[n].detected is not True for n in ("vt2", "frag_sim")
        )

    @property
    def app_only(self) -> bool:
        """The paper's thesis case: every pre-application tier passed (or
        could not run), and an application metric caught the fault."""
        app = self.tiers.get("app")
        return (
            app is not None and bool(app.detected)
            and all(self.tiers[n].detected is not True
                    for n in ("vt2", "frag_sim", "op_diff"))
        )


@dataclasses.dataclass
class CampaignResult:
    reports: List[MutantReport]
    golden: Dict[str, Dict[str, Any]]      # app -> {metric, value, offloads}
    config: Dict[str, Any]
    seconds: float = 0.0

    @property
    def mutants_per_sec(self) -> float:
        return len(self.reports) / self.seconds if self.seconds > 0 else 0.0

    def summary(self) -> Dict[str, Any]:
        per_tier = {t: 0 for t in TIER_ORDER}
        for r in self.reports:
            d = r.detected_at
            if d is not None:
                per_tier[d] += 1
        return {
            "mutants": len(self.reports),
            "detected": sum(1 for r in self.reports if r.detected_at),
            "undetected": [
                r.key for r in _nonidentity(self.reports) if not r.detected_at
            ],
            "first_detection_by_tier": per_tier,
            "app_only": [r.key for r in self.reports if r.app_only],
            "mutants_per_sec": self.mutants_per_sec,
        }

    def to_json(self) -> Dict[str, Any]:
        return {
            "schema": 1,
            "config": self.config,
            "golden": self.golden,
            "mutants": [
                {
                    "target": r.target,
                    "fault": r.fault,
                    "instruction": r.instruction,
                    "note": r.note,
                    "seconds": r.seconds,
                    "detected_at": r.detected_at,
                    "escaped_fragment_checks": r.escaped_fragment_checks,
                    "app_only": r.app_only,
                    "tiers": {
                        n: {
                            "detected": t.detected,
                            "score": t.score,
                            "threshold": t.threshold,
                            "detail": t.detail,
                        }
                        for n, t in r.tiers.items()
                    },
                }
                for r in self.reports
            ],
            "summary": self.summary(),
            "seconds": self.seconds,
        }


def _nonidentity(reports):
    return [r for r in reports if r.fault != "identity"]


TIER_ORDER = ("vt2", "frag_sim", "op_diff", "app")


# ---------------------------------------------------------------------------
# Applications: build + train once, evaluate many mutants
# ---------------------------------------------------------------------------

#: campaign-facing app registry: name -> (builder kwargs shim, metric kind)
_APP_BUILDERS: Dict[str, Tuple[Callable, str]] = {
    "resmlp": (lambda seed=0: apps_mod.build_resmlp(seed=seed, layers=2), "acc"),
    "lstm-wlm": (apps_mod.build_lstm_wlm, "ppl"),
    "efficientnet": (apps_mod.build_efficientnet, "acc"),
    "resnet-20": (apps_mod.build_resnet20, "acc"),
    "mobilenet-v2": (apps_mod.build_mobilenet_v2, "acc"),
    "transformer": (lambda seed=0: apps_mod.build_transformer(seed=seed, layers=1), "acc"),
}


@dataclasses.dataclass
class _App:
    name: str
    kind: str                  # "acc" | "ppl"
    program: ir.Expr
    offloads: Dict[str, int]
    evaluate: Callable[[Executor], float]
    golden_metric: float = float("nan")


def _prepare_app(name: str, n_eval: int, train_steps: int, seed: int) -> _App:
    builder, kind = _APP_BUILDERS[name]
    expr, params = builder(seed=seed)
    if kind == "ppl":
        Xtok, Ytok, _ = cosim.make_char_task(n=max(n_eval, 64), seed=seed)
        embed_dim = next(
            v for v in ir.postorder(expr)
            if isinstance(v, ir.Var) and v.name == "x"
        ).shape[-1]
        vocab = int(Xtok.max()) + 1
        trained = cosim.train_app(
            expr, params, Xtok, Ytok, steps=train_steps, seed=seed,
            embed=(max(vocab, 32), embed_dim),
        )
        res = compile_program(expr)

        def evaluate(ex: Executor, program=res.program, p=trained) -> float:
            ppl, _dt = cosim.eval_perplexity(program, p, Xtok, Ytok, ex, n_eval)
            return ppl

    else:
        xshape = next(
            v for v in ir.postorder(expr)
            if isinstance(v, ir.Var) and v.name == "x"
        ).shape
        X, y = cosim.make_teacher_task(builder, xshape, n=max(4 * n_eval, 128), seed=seed)
        trained = cosim.train_app(
            expr, params, X, y, steps=train_steps, lr=3e-3, seed=seed
        )
        res = compile_program(expr)

        def evaluate(ex: Executor, program=res.program, p=trained) -> float:
            acc, _dt = cosim.eval_classification(program, p, X, y, ex, n_eval)
            return acc

    return _App(name, kind, res.program, dict(res.accelerator_calls), evaluate)


# ---------------------------------------------------------------------------
# Tier runners
# ---------------------------------------------------------------------------


def _target_options() -> Dict[str, Dict[str, Any]]:
    """Per-target execution options recommended by the declared intrinsics
    (e.g. HLSCNN's updated 16-bit weight datatype)."""
    out: Dict[str, Dict[str, Any]] = {}
    for t in TARGETS.all():
        merged: Dict[str, Any] = {}
        for intr in t.intrinsics.values():
            merged.update(intr.options)
        if merged:
            out[t.name] = merged
    return out


def _executor(engine: str, devices: int) -> Executor:
    return Executor(
        "ila", engine=engine, devices_per_target=devices,
        target_options=_target_options(), collect_stats=False,
    )


def _fragment_ops(e: ir.Expr) -> List[str]:
    return [
        x.op for x in ir.postorder(e)
        if isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS
    ]


def _tier_vt2(target, cases, n: int, seed: int) -> TierResult:
    worst_name = ""
    for case in cases:
        if not validate.vt2_check(case, n=n, seed=seed):
            worst_name = case.name
            break
    if not cases:
        return TierResult("vt2", None, detail="target declares no VT2 cases")
    return TierResult(
        "vt2", bool(worst_name), threshold=target.vt2_tol,
        detail=(f"failed case {worst_name!r}" if worst_name
                else f"{len(cases)} cases pass (abstract semantics)"),
    )


def _tier_frag_sim(target, cases, engine: str, devices: int, seed: int,
                   n_envs: int = 2) -> TierResult:
    if not cases:
        return TierResult("frag_sim", None, detail="no declared fragments")
    worst, worst_name, thr_used = 0.0, "", 0.0
    ex = _executor(engine, devices)   # shared: device caches warm across cases
    for case in cases:
        thr = target.cosim_tol(_fragment_ops(case.accel_fragment))
        rng = np.random.default_rng(seed)
        for _ in range(n_envs):
            env = {
                k: rng.standard_normal(s).astype(np.float32)
                for k, s in case.var_shapes.items()
            }
            ideal = np.asarray(ir.interpret(case.ir_fragment, env))
            got = np.asarray(ex.run(case.accel_fragment, env))
            err = validate.frob_rel_err(ideal, got)
            if err / max(thr, 1e-12) > worst / max(thr_used, 1e-12):
                worst, worst_name, thr_used = err, case.name, thr
    return TierResult(
        "frag_sim", worst > thr_used, score=worst, threshold=thr_used,
        detail=f"worst fragment {worst_name!r} rel err {worst:.4f} "
               f"(tol {thr_used:g})",
    )


def _golden_op_outputs(target, n_samples: int, seed: int,
                       engine: str, devices: int) -> Dict[str, List]:
    """Reference outputs of every sampled intrinsic on the *golden* target,
    cached per campaign so every mutant diffs against the same baselines."""
    out: Dict[str, List] = {}
    ex = _executor(engine, devices)
    for op, intr in target.intrinsics.items():
        if intr.planner is None or intr.sample is None:
            continue
        runs = []
        # stable across processes (str hash() is PYTHONHASHSEED-randomized)
        rng = np.random.default_rng(
            zlib.crc32(f"{target.name}:{op}:{seed}".encode())
        )
        for _ in range(n_samples):
            args, attrs = intr.sample(rng)
            vs = tuple(ir.Var(f"_{i}", a.shape) for i, a in enumerate(args))
            expr = ir.call(op, *vs, **attrs)
            env = {f"_{i}": a for i, a in enumerate(args)}
            runs.append((expr, env, np.asarray(ex.run(expr, env))))
        out[op] = runs
    return out


def _tier_op_diff(target, golden_runs: Dict[str, List],
                  engine: str, devices: int) -> TierResult:
    worst, worst_op, thr_used = 0.0, "", 0.0
    detected = False
    ex = _executor(engine, devices)   # shared: device caches warm across ops
    for op, runs in golden_runs.items():
        tol = target.intrinsics[op].tol
        for expr, env, golden_out in runs:
            got = np.asarray(ex.run(expr, env))
            err = validate.frob_rel_err(golden_out, got)
            if err / max(tol, 1e-12) > worst / max(thr_used, 1e-12):
                worst, worst_op, thr_used = err, op, tol
            detected = detected or err > tol
    if not golden_runs:
        return TierResult("op_diff", None, detail="no sampled intrinsics")
    return TierResult(
        "op_diff", detected, score=worst, threshold=thr_used,
        detail=f"worst op {worst_op!r} golden-vs-mutant rel diff "
               f"{worst:.4f} (tol {thr_used:g})",
    )


def _tier_app(target, campaign_apps: List[_App], engine: str, devices: int,
              acc_delta: float, ppl_ratio: float) -> TierResult:
    relevant = [a for a in campaign_apps if a.offloads.get(target.name, 0) > 0]
    if not relevant:
        return TierResult(
            "app", None, detail="no selected application offloads to target"
        )
    detected, details, worst, thr_used = False, [], 0.0, acc_delta
    for app in relevant:
        mutant_metric = app.evaluate(_executor(engine, devices))
        if app.kind == "acc":
            delta = abs(app.golden_metric - mutant_metric)
            hit = delta > acc_delta
            details.append(
                f"{app.name}: acc {app.golden_metric:.3f}->{mutant_metric:.3f}"
                f" (|d|={delta:.3f}{'*' if hit else ''})"
            )
            score, thr = delta, acc_delta
        else:
            ratio = max(mutant_metric, 1e-9) / max(app.golden_metric, 1e-9)
            ratio = max(ratio, 1.0 / ratio)
            hit = ratio > ppl_ratio
            details.append(
                f"{app.name}: ppl {app.golden_metric:.3f}->{mutant_metric:.3f}"
                f" (x{ratio:.3f}{'*' if hit else ''})"
            )
            score, thr = ratio, ppl_ratio
        if score / thr > worst / thr_used:
            worst, thr_used = score, thr
        detected = detected or hit
    return TierResult(
        "app", detected, score=worst, threshold=thr_used,
        detail="; ".join(details),
    )


# ---------------------------------------------------------------------------
# The campaign
# ---------------------------------------------------------------------------


def run_campaign(
    targets: Optional[Sequence[str]] = None,
    faults: Optional[Sequence[str]] = None,
    apps: Sequence[str] = ("resmlp", "lstm-wlm"),
    engine: str = "pipelined",
    devices_per_target: int = 2,
    ladder: str = "full",
    n_eval: int = 32,
    train_steps: int = 120,
    op_samples: int = 2,
    vt2_n: int = 4,
    acc_delta: float = 0.02,
    ppl_ratio: float = 1.02,
    seed: int = 0,
    progress: Optional[Callable[[str], None]] = None,
) -> CampaignResult:
    """Run the full campaign; see the module docstring.

    ``ladder="full"`` runs every tier on every mutant (the complete escape
    matrix); ``"escalate"`` stops at the first detecting tier (cheaper —
    the first-detection statistics are identical). All randomness is seeded:
    golden and mutant evaluations see identical inputs, so every reported
    delta is a real semantic difference, not sampling noise.
    """
    assert ladder in ("full", "escalate"), ladder
    say = progress or (lambda s: None)
    t_start = time.perf_counter()
    selected = TARGETS.all(targets)

    # -- golden baselines (compiled + trained + evaluated once) ------------
    say(f"preparing {len(apps)} application(s): build, train({train_steps} "
        f"steps), compile, golden eval({n_eval})")
    campaign_apps = [_prepare_app(a, n_eval, train_steps, seed) for a in apps]
    golden_info: Dict[str, Dict[str, Any]] = {}
    for app in campaign_apps:
        app.golden_metric = app.evaluate(_executor(engine, devices_per_target))
        golden_info[app.name] = {
            "metric": app.kind, "value": app.golden_metric,
            "offloads": app.offloads,
        }
        say(f"  golden {app.name}: {app.kind}={app.golden_metric:.4f} "
            f"offloads={app.offloads}")
    golden_ops = {
        t.name: _golden_op_outputs(t, op_samples, seed, engine,
                                   devices_per_target)
        for t in selected
    }

    # -- the mutant loop ---------------------------------------------------
    reports: List[MutantReport] = []
    for t in selected:
        cases = t.vt2_cases(8, 32)
        for inst in fault_instances(t, faults):
            t0 = time.perf_counter()
            mutant = make_mutant(t, inst)
            tiers: Dict[str, TierResult] = {}
            with swapped_in(mutant):
                tiers["vt2"] = _tier_vt2(mutant, mutant.vt2_cases(8, 32),
                                         vt2_n, seed)
                runner = [
                    ("frag_sim", lambda: _tier_frag_sim(
                        mutant, cases, engine, devices_per_target, seed)),
                    ("op_diff", lambda: _tier_op_diff(
                        t, golden_ops[t.name], engine, devices_per_target)),
                    ("app", lambda: _tier_app(
                        t, campaign_apps, engine, devices_per_target,
                        acc_delta, ppl_ratio)),
                ]
                for name, run in runner:
                    if ladder == "escalate" and any(
                        r.detected for r in tiers.values() if r.detected
                    ):
                        tiers[name] = TierResult(
                            name, None, detail="skipped (caught earlier)")
                        continue
                    tiers[name] = run()
            rep = MutantReport(
                t.name, inst.fault, inst.instruction, inst.note, tiers,
                seconds=time.perf_counter() - t0,
            )
            reports.append(rep)
            say(f"  {rep.key}: detected_at={rep.detected_at or 'never'} "
                f"({rep.seconds:.1f}s)")

    config = dict(
        targets=[t.name for t in selected], faults=list(faults or []),
        apps=list(apps), engine=engine,
        devices_per_target=devices_per_target, ladder=ladder,
        n_eval=n_eval, train_steps=train_steps, op_samples=op_samples,
        acc_delta=acc_delta, ppl_ratio=ppl_ratio, seed=seed,
    )
    return CampaignResult(
        reports, golden_info, config, seconds=time.perf_counter() - t_start
    )


def format_matrix(result: CampaignResult) -> str:
    """The human-readable escape-analysis matrix."""
    rows = [
        f"{'target':9s} {'fault':12s} {'instruction':13s} "
        + " ".join(f"{t:>9s}" for t in TIER_ORDER)
        + "  detected_at"
    ]
    rows.append("-" * len(rows[0]))
    for r in result.reports:
        cells = " ".join(f"{r.tiers[t].cell():>9s}" for t in TIER_ORDER)
        flag = " [app-only escape]" if r.app_only else ""
        rows.append(
            f"{r.target:9s} {r.fault:12s} {r.instruction:13s} {cells}"
            f"  {r.detected_at or 'never'}{flag}"
        )
    s = result.summary()
    rows.append("")
    rows.append(
        f"{s['mutants']} mutants in {result.seconds:.1f}s "
        f"({s['mutants_per_sec']:.2f} mutants/sec); "
        f"first detection by tier: {s['first_detection_by_tier']}"
    )
    if s["app_only"]:
        rows.append(
            "caught ONLY at application level (the paper's thesis, "
            f"quantified): {s['app_only']}"
        )
    if s["undetected"]:
        rows.append(f"undetected non-identity mutants: {s['undetected']}")
    return "\n".join(rows)
