"""Fault-injection / differential-validation campaign driver.

Enumerates (target x instruction x fault) mutants — :mod:`.faults` — and
runs every mutant through a **tiered detection ladder**, measuring which
validation tier first distinguishes it from the golden design:

  ``static``    tier 0 — the static verifier (:mod:`.ilalint`): golden
                planner-emitted probe streams are pushed through the
                mutant's host-side stream transform and classified against
                the jaxpr-derived instruction effects — **zero simulated
                commands**. Decode violations (opcode/address rewrites)
                and order-sensitive config corruption are caught here;
                bulk numeric payload corruption is deliberately deferred
                to the simulation tiers. Under ``ladder="escalate"`` a
                static detection skips every simulated tier.
  ``vt2``       the declared VT2 fragment-equivalence checks over abstract
                (fp32) semantics, with each target's threaded tolerance.
                This is the formal-proof analogue: it validates the
                *mapping*, deliberately abstracting numerics away — so a
                fault injected into ILA instruction semantics passes it by
                construction. Quantifying exactly that blind spot is the
                point of running the tier.
  ``frag_sim``  the same declared fragment pairs, but with the accelerator
                side **co-simulated on the mutant ILA** against the fp32 IR
                interpreter, judged by the target's declared co-simulation
                tolerance (the loosest ideal-vs-numerics bound among the
                fragment's intrinsics). The VT3 testing analogue: ILA vs
                reference at fragment granularity.
  ``op_diff``   per-intrinsic golden-vs-mutant differential test: identical
                sampled operands through the golden and the mutant target;
                a relative deviation beyond the intrinsic's declared
                tolerance is a detection.
  ``app``       full-application co-simulation: every selected application
                that offloads work to the target is evaluated end-to-end
                (accuracy or perplexity) on golden and mutant; a metric
                delta beyond the campaign thresholds is a detection.
  ``stat``      the calibrated statistical tier, sharing the ``app`` tier's
                evaluation pass: **paired per-example** golden-vs-mutant
                output deltas. The statistic is the mean relative logit
                displacement over the (seeded) evaluation subset; because
                golden and mutant see byte-identical inputs, the identity
                mutant scores *exactly* zero, and the detection threshold
                ``max(stat_floor, 2 x worst identity-null shift)`` is
                calibrated per (target, app) by evaluating the identity
                mutant on ``stat_calib_seeds`` independently seeded subsets
                — a measured false-positive budget. This is what catches
                distribution-shifting faults (``round_floor``'s half-step
                bias) that never flip a top-1 label.

The output is an **escape-analysis matrix**: per mutant, the verdict of
every tier plus the first detecting tier. Mutants that pass the fragment
tiers (``vt2`` + ``frag_sim``) but are caught by an application metric are
the paper's thesis made quantitative — application-level validation
catching what fragment-level checks miss. The ``identity`` control mutant
must show zero detections at every tier (no false positives).

Robustness: a mutant that *raises* during its ladder is recorded with
outcome ``crash`` (partial tiers kept, registries restored) instead of
killing the campaign; under the sharded runner a mutant that *hangs* is
terminated at ``mutant_timeout`` and recorded as ``timeout``. Campaign
state checkpoints to ``CAMPAIGN.json`` after every mutant (atomic
replace), and ``resume=True`` skips already-completed mutants after
verifying the config fingerprint — an interrupted campaign continues
instead of restarting. :func:`matrix_digest` hashes the deterministic
fields of the escape matrix so a resumed run can be proven bit-identical
to an uninterrupted one.

Scale: mutant runs execute on the Executor's ``pipelined`` engine over
``devices_per_target`` simulated devices by default, and all golden-side
host packing comes out of warm shared caches (see :mod:`.faults`), so a
campaign is thousands of co-sim invocations at steady-state cost.
:func:`run_campaign_sharded` additionally fans mutants out across worker
*subprocesses* (each owning its private device fleet and registries), with
bounded retry + backoff for transient failures — throughput is reported as
mutants/sec and benchmarked in ``benchmarks/bench_campaign.py``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import queue as queue_mod
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import apps as apps_mod, cosim, ilalint, ir, validate
from .codegen import Executor
from .compile import compile_program
from .faults import FaultInstance, fault_instances, make_mutant, swapped_in
from .ila import TARGETS
from .telemetry import TELEMETRY

TIER_ORDER = ("static", "vt2", "frag_sim", "op_diff", "app", "stat")

#: mutant outcomes beyond a clean ladder: the mutant raised mid-ladder
#: (crash isolation) or exceeded the sharded runner's per-mutant timeout
FAILURE_OUTCOMES = ("crash", "timeout")


@dataclasses.dataclass
class TierResult:
    """One tier's verdict on one mutant. ``detected=None`` means the tier
    did not run (not applicable, or skipped by an escalation ladder)."""

    tier: str
    detected: Optional[bool]
    score: float = 0.0        # worst observed deviation / delta
    threshold: float = 0.0
    detail: str = ""

    def cell(self) -> str:
        if self.detected is None:
            return "-"
        return "CAUGHT" if self.detected else "pass"


@dataclasses.dataclass
class MutantReport:
    target: str
    fault: str
    instruction: str
    note: str
    tiers: Dict[str, TierResult]
    seconds: float = 0.0
    outcome: str = "ok"       # "ok" | "crash" | "timeout"
    error: str = ""
    attempts: int = 1

    @property
    def key(self) -> str:
        return f"{self.target}:{self.fault}@{self.instruction}"

    @property
    def detected_at(self) -> Optional[str]:
        if self.outcome in FAILURE_OUTCOMES:
            return self.outcome
        for name in TIER_ORDER:
            t = self.tiers.get(name)
            if t is not None and t.detected:
                return name
        return None

    @property
    def escaped_fragment_checks(self) -> bool:
        """Passed both fragment tiers (vt2 abstract + co-simulated)."""
        return all(
            (self.tiers.get(n) is None or self.tiers[n].detected is not True)
            for n in ("vt2", "frag_sim")
        )

    def _only(self, tier: str, earlier: Tuple[str, ...]) -> bool:
        caught = self.tiers.get(tier)
        return (
            self.outcome == "ok"
            and caught is not None and bool(caught.detected)
            and all(
                (self.tiers.get(n) is None
                 or self.tiers[n].detected is not True)
                for n in earlier
            )
        )

    @property
    def app_only(self) -> bool:
        """The paper's thesis case: every pre-application tier passed (or
        could not run), and an application metric caught the fault."""
        return self._only("app", ("static", "vt2", "frag_sim", "op_diff"))

    @property
    def stat_only(self) -> bool:
        """The calibrated statistical tier's marginal value: every other
        tier — including the coarse app-metric threshold — passed, and only
        the paired per-example statistic caught the fault."""
        return self._only(
            "stat", ("static", "vt2", "frag_sim", "op_diff", "app"))

    def to_dict(self) -> Dict[str, Any]:
        return {
            "target": self.target,
            "fault": self.fault,
            "instruction": self.instruction,
            "note": self.note,
            "seconds": self.seconds,
            "outcome": self.outcome,
            "error": self.error,
            "attempts": self.attempts,
            "detected_at": self.detected_at,
            "escaped_fragment_checks": self.escaped_fragment_checks,
            "app_only": self.app_only,
            "stat_only": self.stat_only,
            "tiers": {
                n: {
                    "detected": t.detected,
                    "score": t.score,
                    "threshold": t.threshold,
                    "detail": t.detail,
                }
                for n, t in self.tiers.items()
            },
        }

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "MutantReport":
        return MutantReport(
            d["target"], d["fault"], d["instruction"], d.get("note", ""),
            {
                n: TierResult(n, tv.get("detected"), tv.get("score", 0.0),
                              tv.get("threshold", 0.0), tv.get("detail", ""))
                for n, tv in d.get("tiers", {}).items()
            },
            seconds=d.get("seconds", 0.0),
            outcome=d.get("outcome", "ok"),
            error=d.get("error", ""),
            attempts=d.get("attempts", 1),
        )


@dataclasses.dataclass
class CampaignResult:
    reports: List[MutantReport]
    golden: Dict[str, Dict[str, Any]]      # app -> {metric, value, offloads}
    config: Dict[str, Any]
    stat_calibration: Dict[str, Any] = dataclasses.field(default_factory=dict)
    seconds: float = 0.0

    @property
    def mutants_per_sec(self) -> float:
        return len(self.reports) / self.seconds if self.seconds > 0 else 0.0

    def summary(self) -> Dict[str, Any]:
        per_tier = {t: 0 for t in TIER_ORDER}
        for r in self.reports:
            d = r.detected_at
            if d in per_tier:
                per_tier[d] += 1
        return {
            "mutants": len(self.reports),
            "detected": sum(1 for r in self.reports if r.detected_at),
            "undetected": [
                r.key for r in _nonidentity(self.reports)
                if r.outcome == "ok" and not r.detected_at
            ],
            "first_detection_by_tier": per_tier,
            "app_only": [r.key for r in self.reports if r.app_only],
            "stat_only": [r.key for r in self.reports if r.stat_only],
            "crashes": [r.key for r in self.reports if r.outcome == "crash"],
            "timeouts": [r.key for r in self.reports
                         if r.outcome == "timeout"],
            "mutants_per_sec": self.mutants_per_sec,
        }

    def to_json(self) -> Dict[str, Any]:
        return {
            "schema": 2,
            "partial": False,
            "fingerprint": config_fingerprint(self.config),
            "config": self.config,
            "golden": self.golden,
            "stat_calibration": self.stat_calibration,
            "mutants": [r.to_dict() for r in self.reports],
            "summary": self.summary(),
            "seconds": self.seconds,
        }


def _nonidentity(reports):
    return [r for r in reports if r.fault != "identity"]


# ---------------------------------------------------------------------------
# Determinism plumbing: fingerprints, digests, checkpoints
# ---------------------------------------------------------------------------

#: config keys that determine the escape matrix bit-for-bit. Runner knobs
#: (workers, timeouts, retries, checkpoint paths) are deliberately absent:
#: a resumed or re-sharded campaign must produce the identical matrix.
_FINGERPRINT_KEYS = (
    "targets", "faults", "apps", "engine", "devices_per_target", "ladder",
    "n_eval", "train_steps", "op_samples", "op_boundary", "vt2_n",
    "acc_delta", "ppl_ratio", "seed", "stat_floor", "stat_calib_seeds",
)


def config_fingerprint(config: Dict[str, Any]) -> str:
    """Hash of the detection-relevant campaign config — the resume guard:
    a checkpoint may only seed a run whose matrix-determining knobs match."""
    det = {k: config.get(k) for k in _FINGERPRINT_KEYS}
    blob = json.dumps(det, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def matrix_digest(data) -> str:
    """Hash of the deterministic content of an escape matrix (verdicts,
    scores, thresholds, golden values — NOT wall-clock or attempt counts).
    A killed-and-resumed campaign must reproduce the uninterrupted run's
    digest bit-for-bit; CI asserts exactly that."""
    if isinstance(data, CampaignResult):
        data = data.to_json()
    canon = {
        "fingerprint": data.get("fingerprint"),
        "golden": {
            a: {
                "metric": g.get("metric"),
                "value": repr(float(g.get("value", 0.0))),
                "offloads": g.get("offloads"),
            }
            for a, g in data.get("golden", {}).items()
        },
        "mutants": [
            {
                "key": f"{m['target']}:{m['fault']}@{m['instruction']}",
                "outcome": m.get("outcome", "ok"),
                "detected_at": m.get("detected_at"),
                "tiers": {
                    n: {
                        "detected": tv.get("detected"),
                        "score": repr(float(tv.get("score", 0.0))),
                        "threshold": repr(float(tv.get("threshold", 0.0))),
                        "detail": tv.get("detail", ""),
                    }
                    for n, tv in sorted(m.get("tiers", {}).items())
                },
            }
            for m in sorted(
                data.get("mutants", []),
                key=lambda m: (m["target"], m["fault"], m["instruction"]),
            )
        ],
    }
    blob = json.dumps(canon, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def _save_checkpoint(path: str, config: Dict[str, Any],
                     golden: Dict[str, Any], stat_cal: Dict[str, Any],
                     mutants: List[Dict[str, Any]], seconds: float,
                     partial: bool) -> None:
    data = {
        "schema": 2,
        "partial": partial,
        "fingerprint": config_fingerprint(config),
        "config": config,
        "golden": golden,
        "stat_calibration": stat_cal,
        "mutants": mutants,
        "seconds": seconds,
    }
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)   # atomic: a kill mid-write never corrupts


def _load_checkpoint(path: str, config: Dict[str, Any]):
    """-> (completed: key -> report dict, seconds, golden, stat_cal)."""
    with open(path) as f:
        data = json.load(f)
    want = config_fingerprint(config)
    got = data.get("fingerprint")
    if got != want:
        raise ValueError(
            f"checkpoint {path!r} was produced by a different campaign "
            f"config (fingerprint {got} != {want}); refusing to resume — "
            "delete it or rerun with the original settings"
        )
    completed = {
        f"{m['target']}:{m['fault']}@{m['instruction']}": m
        for m in data.get("mutants", [])
    }
    return (completed, float(data.get("seconds", 0.0)),
            data.get("golden", {}), data.get("stat_calibration", {}))


# ---------------------------------------------------------------------------
# Applications: build + train once, evaluate many mutants per-example
# ---------------------------------------------------------------------------

#: campaign-facing app registry: name -> (builder kwargs shim, metric kind)
_APP_BUILDERS: Dict[str, Tuple[Callable, str]] = {
    "resmlp": (lambda seed=0: apps_mod.build_resmlp(seed=seed, layers=2), "acc"),
    "lstm-wlm": (apps_mod.build_lstm_wlm, "ppl"),
    "efficientnet": (apps_mod.build_efficientnet, "acc"),
    "resnet-20": (apps_mod.build_resnet20, "acc"),
    "mobilenet-v2": (apps_mod.build_mobilenet_v2, "acc"),
    "transformer": (lambda seed=0: apps_mod.build_transformer(seed=seed, layers=1), "acc"),
}


@dataclasses.dataclass
class PerExample:
    """One evaluation pass, resolved per example: flattened raw outputs
    (n, d), per-example losses (n,), and the aggregate app metric."""

    outputs: np.ndarray
    losses: np.ndarray
    metric: float


def paired_stats(golden: PerExample, mutant: PerExample) -> Dict[str, float]:
    """Paired golden-vs-mutant statistics over byte-identical inputs.

    ``shift``: mean relative per-example output displacement
    ``mean ||o_mut - o_gold|| / ||o_gold||`` — the detection statistic. A
    bit-exact mutant scores exactly 0.0; a systematic per-value bias (wrong
    rounding mode) scores at its relative magnitude, far above any
    calibrated identity-null threshold, even when no top-1 label flips.
    ``bias_t``: |t|-statistic of the paired per-example loss deltas
    (reported for diagnosis: it separates *systematic* loss bias from
    symmetric noise). ``mean_loss_delta``: its raw effect size."""
    g = np.asarray(golden.outputs, np.float64)
    m = np.asarray(mutant.outputs, np.float64)
    disp = np.linalg.norm(m - g, axis=1) / (np.linalg.norm(g, axis=1) + 1e-12)
    shift = float(disp.mean())
    d = np.asarray(mutant.losses, np.float64) - np.asarray(
        golden.losses, np.float64)
    if d.size > 1 and float(np.abs(d).max()) > 0.0:
        sem = float(d.std(ddof=1)) / float(np.sqrt(d.size))
        scale = max(float(np.abs(np.asarray(golden.losses)).mean()), 1e-12)
        bias_t = float(abs(d.mean()) / max(sem, 1e-9 * scale))
    else:
        bias_t = 0.0
    return {
        "shift": shift,
        "bias_t": bias_t,
        "mean_loss_delta": float(d.mean()) if d.size else 0.0,
    }


def _subset(pool: int, n: int, tag: str, seed: int) -> Tuple[int, ...]:
    """Seeded evaluation-subset sampler: ``n`` distinct dataset rows out of
    ``pool``, reproducible across processes (crc32, not PYTHONHASHSEED)."""
    rng = np.random.default_rng(zlib.crc32(f"{tag}:{seed}".encode()))
    take = min(n, pool)
    return tuple(int(i) for i in np.sort(
        rng.choice(pool, size=take, replace=False)))


@dataclasses.dataclass
class _App:
    name: str
    kind: str                  # "acc" | "ppl"
    program: ir.Expr
    offloads: Dict[str, int]
    pool: int                  # evaluation dataset size (subset source)
    per_example: Callable[[Executor, Sequence[int]], PerExample]
    golden_metric: float = float("nan")
    #: golden per-example results keyed by evaluation subset (computed once
    #: per campaign, BEFORE any mutant is swapped in)
    golden_pe: Dict[Tuple[int, ...], PerExample] = dataclasses.field(
        default_factory=dict)


def _softmax_logp(logits: np.ndarray) -> np.ndarray:
    z = logits - logits.max(-1, keepdims=True)
    return z - np.log(np.exp(z).sum(-1, keepdims=True))


def _prepare_app(name: str, n_eval: int, train_steps: int, seed: int) -> _App:
    builder, kind = _APP_BUILDERS[name]
    expr, params = builder(seed=seed)
    if kind == "ppl":
        pool = max(n_eval, 64)
        Xtok, Ytok, _ = cosim.make_char_task(n=pool, seed=seed)
        embed_dim = next(
            v for v in ir.postorder(expr)
            if isinstance(v, ir.Var) and v.name == "x"
        ).shape[-1]
        vocab = int(Xtok.max()) + 1
        trained = cosim.train_app(
            expr, params, Xtok, Ytok, steps=train_steps, seed=seed,
            embed=(max(vocab, 32), embed_dim),
        )
        res = compile_program(expr)
        emb = trained["_embed"]
        model_params = {k: v for k, v in trained.items() if k != "_embed"}

        def per_example(ex: Executor, idx, program=res.program) -> PerExample:
            outs = cosim.eval_outputs(
                program, model_params,
                lambda i: emb[Xtok[i]][:, None, :], idx, ex,
            )
            flat, losses = [], []
            for out, i in zip(outs, idx):
                logp = _softmax_logp(np.asarray(out, np.float64))
                losses.append(
                    float(-logp[np.arange(len(Ytok[i])), Ytok[i]].mean()))
                flat.append(np.asarray(out, np.float64).reshape(-1))
            losses_arr = np.array(losses, np.float64)
            # fixed-length sequences: per-token NLL == mean of per-seq means
            return PerExample(np.stack(flat), losses_arr,
                              float(np.exp(losses_arr.mean())))

    else:
        xshape = next(
            v for v in ir.postorder(expr)
            if isinstance(v, ir.Var) and v.name == "x"
        ).shape
        pool = max(4 * n_eval, 128)
        X, y = cosim.make_teacher_task(builder, xshape, n=pool, seed=seed)
        trained = cosim.train_app(
            expr, params, X, y, steps=train_steps, lr=3e-3, seed=seed
        )
        res = compile_program(expr)

        def per_example(ex: Executor, idx, program=res.program) -> PerExample:
            outs = cosim.eval_outputs(
                program, trained, lambda i: X[i], idx, ex)
            logits = np.stack(
                [np.asarray(o, np.float64).reshape(-1) for o in outs])
            labels = y[np.asarray(idx, np.int64)]
            logp = _softmax_logp(logits)
            losses = -logp[np.arange(len(idx)), labels]
            metric = float((logits.argmax(1) == labels).mean())
            return PerExample(logits, losses, metric)

    return _App(name, kind, res.program, dict(res.accelerator_calls), pool,
                per_example)


# ---------------------------------------------------------------------------
# Tier runners
# ---------------------------------------------------------------------------


def _target_options() -> Dict[str, Dict[str, Any]]:
    """Per-target execution options recommended by the declared intrinsics
    (e.g. HLSCNN's updated 16-bit weight datatype)."""
    out: Dict[str, Dict[str, Any]] = {}
    for t in TARGETS.all():
        merged: Dict[str, Any] = {}
        for intr in t.intrinsics.values():
            merged.update(intr.options)
        if merged:
            out[t.name] = merged
    return out


#: per-process (= per sharded worker) executor memo. Building a fresh
#: Executor per tier call per mutant re-created the device fleet and the
#: jit(vmap(read)) caches from cold for every mutant — the sharded-runner
#: regression where each worker re-warmed per *mutant*, not per worker.
#: Sharing one executor per (engine, devices) keeps device-local fragment
#: caches and batched-read jits warm from the golden ``_prepare`` pass
#: onward; mutant isolation holds because targets resolve through the
#: swapped registries at run time and device caches key on ILA identity.
_EXECUTORS: Dict[Tuple[str, int], Executor] = {}


def _executor(engine: str, devices: int) -> Executor:
    key = (engine, devices)
    ex = _EXECUTORS.get(key)
    if ex is None:
        ex = _EXECUTORS[key] = Executor(
            "ila", engine=engine, devices_per_target=devices,
            target_options=_target_options(), collect_stats=False,
        )
    else:
        # zero the LPT scheduling accumulators so every tier call sees the
        # same deterministic device placement a fresh Executor would.
        # Placement is observable for setup-stream faults (devices >= 1
        # re-simulate the mutant's setup; device 0 reuses the planner-built
        # state), so letting busy-cycle history from *other* mutants leak
        # into placement would make a mutant's outcome depend on execution
        # order — breaking the sharded runner's matrix-digest parity with
        # serial runs. Warm caches survive the reset.
        ex.reset_stats()
    return ex


def _fragment_ops(e: ir.Expr) -> List[str]:
    return [
        x.op for x in ir.postorder(e)
        if isinstance(x, ir.Call) and x.op in ir.ACCEL_OPS
    ]


def _tier_static(target, probes, inst: FaultInstance) -> TierResult:
    """Tier 0 — the static verifier (:mod:`.ilalint`): golden probe
    streams through the mutant's host-side stream transform, classified
    with **zero simulated commands**. Faults with no host-visible
    transform (pure ILA-update wrappers) are out of static scope and pass;
    a transform that *raises* while being applied (e.g. the crash-inject
    diagnostic fault) leaves the tier inconclusive so the simulation
    ladder still exercises it."""
    hx = inst.host_xform()
    if hx is None:
        return TierResult(
            "static", False,
            detail="no host-visible stream transform (ILA-update fault); "
                   "out of static scope")
    try:
        detected, score, detail = ilalint.analyze_mutation(
            target, probes, hx)
    except KeyboardInterrupt:
        raise
    except Exception as e:
        return TierResult(
            "static", None,
            detail=f"static analysis inconclusive: transform raised "
                   f"{type(e).__name__}: {e}")
    return TierResult("static", detected, score=score, detail=detail)


def _tier_vt2(target, cases, n: int, seed: int) -> TierResult:
    worst_name = ""
    for case in cases:
        if not validate.vt2_check(case, n=n, seed=seed):
            worst_name = case.name
            break
    if not cases:
        return TierResult("vt2", None, detail="target declares no VT2 cases")
    return TierResult(
        "vt2", bool(worst_name), threshold=target.vt2_tol,
        detail=(f"failed case {worst_name!r}" if worst_name
                else f"{len(cases)} cases pass (abstract semantics)"),
    )


def _tier_frag_sim(target, cases, engine: str, devices: int, seed: int,
                   n_envs: int = 2) -> TierResult:
    if not cases:
        return TierResult("frag_sim", None, detail="no declared fragments")
    worst, worst_name, thr_used = 0.0, "", 0.0
    ex = _executor(engine, devices)   # shared: device caches warm across cases
    for case in cases:
        thr = target.cosim_tol(_fragment_ops(case.accel_fragment))
        rng = np.random.default_rng(seed)
        for _ in range(n_envs):
            env = {
                k: rng.standard_normal(s).astype(np.float32)
                for k, s in case.var_shapes.items()
            }
            ideal = np.asarray(ir.interpret(case.ir_fragment, env))
            got = np.asarray(ex.run(case.accel_fragment, env))
            err = validate.frob_rel_err(ideal, got)
            if err / max(thr, 1e-12) > worst / max(thr_used, 1e-12):
                worst, worst_name, thr_used = err, case.name, thr
    return TierResult(
        "frag_sim", worst > thr_used, score=worst, threshold=thr_used,
        detail=f"worst fragment {worst_name!r} rel err {worst:.4f} "
               f"(tol {thr_used:g})",
    )


def _golden_op_outputs(target, n_samples: int, seed: int,
                       engine: str, devices: int,
                       boundary: int = 0) -> Dict[str, List]:
    """Reference outputs of every sampled intrinsic on the *golden* target,
    cached per campaign so every mutant diffs against the same baselines.

    ``boundary`` > 0 appends that many *range-directed* samples per op:
    the intrinsic's own operand draw, with its activation operand
    (``args[0]``) overwritten by :func:`ilalint.boundary_inputs` values
    straddling the target's statically computed saturation point. Uniform
    draws almost never land within the wrap window, which is exactly how
    ``sat_wrap``-class faults escape the op tier; aimed draws make the
    same one-op diff catch them. The default (0) keeps the historical
    uniform-only pool — and the escape matrix — unchanged."""
    out: Dict[str, List] = {}
    ex = _executor(engine, devices)
    for op, intr in target.intrinsics.items():
        if intr.planner is None or intr.sample is None:
            continue
        runs = []
        # stable across processes (str hash() is PYTHONHASHSEED-randomized)
        rng = np.random.default_rng(
            zlib.crc32(f"{target.name}:{op}:{seed}".encode())
        )
        for k in range(n_samples + boundary):
            args, attrs = intr.sample(rng)
            if k >= n_samples:
                x0 = np.asarray(args[0])
                bv = ilalint.boundary_inputs(
                    target, n=x0.size, seed=seed * 8191 + k
                )
                args = [bv.reshape(x0.shape)] + list(args[1:])
            vs = tuple(ir.Var(f"_{i}", a.shape) for i, a in enumerate(args))
            expr = ir.call(op, *vs, **attrs)
            env = {f"_{i}": a for i, a in enumerate(args)}
            runs.append((expr, env, np.asarray(ex.run(expr, env))))
        out[op] = runs
    return out


def _tier_op_diff(target, golden_runs: Dict[str, List],
                  engine: str, devices: int) -> TierResult:
    worst, worst_op, thr_used = 0.0, "", 0.0
    detected = False
    ex = _executor(engine, devices)   # shared: device caches warm across ops
    for op, runs in golden_runs.items():
        tol = target.intrinsics[op].tol
        for expr, env, golden_out in runs:
            got = np.asarray(ex.run(expr, env))
            err = validate.frob_rel_err(golden_out, got)
            if err / max(tol, 1e-12) > worst / max(thr_used, 1e-12):
                worst, worst_op, thr_used = err, op, tol
            detected = detected or err > tol
    if not golden_runs:
        return TierResult("op_diff", None, detail="no sampled intrinsics")
    return TierResult(
        "op_diff", detected, score=worst, threshold=thr_used,
        detail=f"worst op {worst_op!r} golden-vs-mutant rel diff "
               f"{worst:.4f} (tol {thr_used:g})",
    )


def _tier_app_and_stat(ctx: "_Ctx", t) -> Tuple[TierResult, TierResult]:
    """The application tier and the statistical tier share ONE mutant
    evaluation pass per app: per-example outputs feed both the aggregate
    metric delta (``app``) and the paired displacement statistic against
    the calibrated identity-null threshold (``stat``)."""
    cfg = ctx.config
    relevant = [a for a in ctx.campaign_apps
                if a.offloads.get(t.name, 0) > 0]
    if not relevant:
        na = "no selected application offloads to target"
        return (TierResult("app", None, detail=na),
                TierResult("stat", None, detail=na))
    acc_delta, ppl_ratio = cfg["acc_delta"], cfg["ppl_ratio"]
    calibrated = cfg["stat_calib_seeds"] > 0
    app_det, app_details, app_worst, app_thr = False, [], 0.0, acc_delta
    st_det, st_details, st_worst, st_thr = False, [], 0.0, cfg["stat_floor"]
    for app in relevant:
        idx = ctx.eval_idx[app.name]
        pe = app.per_example(
            _executor(cfg["engine"], cfg["devices_per_target"]), idx)
        gpe = app.golden_pe[idx]
        # -- aggregate metric (the PR 5 app tier, unchanged semantics) -----
        if app.kind == "acc":
            delta = abs(gpe.metric - pe.metric)
            hit = delta > acc_delta
            app_details.append(
                f"{app.name}: acc {gpe.metric:.3f}->{pe.metric:.3f}"
                f" (|d|={delta:.3f}{'*' if hit else ''})"
            )
            score, thr = delta, acc_delta
        else:
            ratio = max(pe.metric, 1e-9) / max(gpe.metric, 1e-9)
            ratio = max(ratio, 1.0 / ratio)
            hit = ratio > ppl_ratio
            app_details.append(
                f"{app.name}: ppl {gpe.metric:.3f}->{pe.metric:.3f}"
                f" (x{ratio:.3f}{'*' if hit else ''})"
            )
            score, thr = ratio, ppl_ratio
        if score / thr > app_worst / app_thr:
            app_worst, app_thr = score, thr
        app_det = app_det or hit
        # -- paired per-example statistic ----------------------------------
        if calibrated:
            thr = ctx.stat_cal["thresholds"].get(
                f"{t.name}:{app.name}", cfg["stat_floor"])
            s = paired_stats(gpe, pe)
            s_hit = s["shift"] > thr
            st_details.append(
                f"{app.name}: shift={s['shift']:.2e} (thr {thr:.2e}) "
                f"bias_t={s['bias_t']:.1f}{'*' if s_hit else ''}"
            )
            if s["shift"] / thr > st_worst / st_thr:
                st_worst, st_thr = s["shift"], thr
            st_det = st_det or s_hit
    app_tier = TierResult("app", app_det, score=app_worst, threshold=app_thr,
                          detail="; ".join(app_details))
    if not calibrated:
        return app_tier, TierResult(
            "stat", None, detail="uncalibrated (stat_calib_seeds=0)")
    return app_tier, TierResult("stat", st_det, score=st_worst,
                                threshold=st_thr,
                                detail="; ".join(st_details))


# ---------------------------------------------------------------------------
# Campaign context: everything prepared once, before any mutant swap
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Ctx:
    config: Dict[str, Any]
    selected: List[Any]
    campaign_apps: List[_App]
    golden_info: Dict[str, Dict[str, Any]]
    golden_ops: Dict[str, Dict[str, List]]
    vt2_cases: Dict[str, List]
    eval_idx: Dict[str, Tuple[int, ...]]
    stat_cal: Dict[str, Any]
    instances: Dict[str, Tuple[Any, FaultInstance]]
    #: golden planner-emitted probe streams per target, for the static tier
    probes: Dict[str, List] = dataclasses.field(default_factory=dict)


def _resolve_config(
    targets: Optional[Sequence[str]] = None,
    faults: Optional[Sequence[str]] = None,
    apps: Sequence[str] = ("resmlp", "lstm-wlm"),
    engine: str = "pipelined",
    devices_per_target: int = 2,
    ladder: str = "full",
    n_eval: int = 32,
    train_steps: int = 120,
    op_samples: int = 2,
    op_boundary: int = 0,
    vt2_n: int = 4,
    acc_delta: float = 0.02,
    ppl_ratio: float = 1.02,
    seed: int = 0,
    stat_floor: float = 1e-3,
    stat_calib_seeds: int = 2,
) -> Dict[str, Any]:
    assert ladder in ("full", "escalate"), ladder
    from .faults import FAULT_CLASSES
    return dict(
        targets=[t.name for t in TARGETS.all(targets)],
        faults=list(faults) if faults is not None else list(FAULT_CLASSES),
        apps=list(apps), engine=engine,
        devices_per_target=devices_per_target, ladder=ladder,
        n_eval=n_eval, train_steps=train_steps, op_samples=op_samples,
        op_boundary=op_boundary, vt2_n=vt2_n, acc_delta=acc_delta,
        ppl_ratio=ppl_ratio, seed=seed,
        stat_floor=stat_floor, stat_calib_seeds=stat_calib_seeds,
    )


def _enumerate_instances(selected, faults) -> Dict[str, Tuple[Any, FaultInstance]]:
    out: Dict[str, Tuple[Any, FaultInstance]] = {}
    for t in selected:
        for inst in fault_instances(t, faults):
            out[f"{t.name}:{inst.fault}@{inst.instruction}"] = (t, inst)
    return out


def _calibrate_stat(ctx_apps: List[_App], selected, config: Dict[str, Any],
                    say) -> Dict[str, Any]:
    """FP-budget calibration of the statistical tier: evaluate the identity
    mutant of each target on ``stat_calib_seeds`` independently seeded
    evaluation subsets, collect the null distribution of the paired shift
    statistic (exactly zero for a bit-exact stack), and set the per
    (target, app) detection threshold to ``max(stat_floor, 2 x worst
    null)``. The measured false-positive count against that threshold is
    recorded — the budget is empirical, not assumed."""
    n_seeds = config["stat_calib_seeds"]
    cal: Dict[str, Any] = {
        "floor": config["stat_floor"], "calib_seeds": n_seeds,
        "null_shifts": {}, "thresholds": {}, "false_positives": {},
    }
    if n_seeds <= 0 or not ctx_apps:
        return cal
    engine, devices = config["engine"], config["devices_per_target"]
    for t in selected:
        relevant = [a for a in ctx_apps if a.offloads.get(t.name, 0) > 0]
        if not relevant:
            continue
        (inst,) = fault_instances(t, ("identity",))
        mutant = make_mutant(t, inst)
        nulls: Dict[str, List[float]] = {a.name: [] for a in relevant}
        with swapped_in(mutant):
            for k in range(n_seeds):
                for a in relevant:
                    idx = _subset(a.pool, config["n_eval"],
                                  f"calib:{a.name}:{k}", config["seed"])
                    pe = a.per_example(_executor(engine, devices), idx)
                    s = paired_stats(a.golden_pe[idx], pe)
                    nulls[a.name].append(s["shift"])
        for a in relevant:
            key = f"{t.name}:{a.name}"
            thr = max(config["stat_floor"], 2.0 * max(nulls[a.name]))
            cal["null_shifts"][key] = nulls[a.name]
            cal["thresholds"][key] = thr
            cal["false_positives"][key] = sum(
                1 for v in nulls[a.name] if v > thr)
            say(f"  stat calibration {key}: nulls={nulls[a.name]} "
                f"threshold={thr:g} fp={cal['false_positives'][key]}")
    return cal


def _prepare(config: Dict[str, Any], say) -> _Ctx:
    """Build everything a campaign (or one sharded worker) needs: trained
    apps, golden per-example baselines for the main + calibration subsets,
    golden op outputs, VT2 cases, the stat calibration, and the mutant
    instance map. All golden evaluation happens HERE, before any mutant is
    ever swapped into the registries."""
    selected = TARGETS.all(config["targets"])
    n_eval, train_steps, seed = (config["n_eval"], config["train_steps"],
                                 config["seed"])
    engine, devices = config["engine"], config["devices_per_target"]
    say(f"preparing {len(config['apps'])} application(s): build, "
        f"train({train_steps} steps), compile, golden eval({n_eval})")
    campaign_apps = [_prepare_app(a, n_eval, train_steps, seed)
                     for a in config["apps"]]
    golden_info: Dict[str, Dict[str, Any]] = {}
    eval_idx: Dict[str, Tuple[int, ...]] = {}
    for app in campaign_apps:
        idx = _subset(app.pool, n_eval, f"eval:{app.name}", seed)
        eval_idx[app.name] = idx
        subsets = [idx] + [
            _subset(app.pool, n_eval, f"calib:{app.name}:{k}", seed)
            for k in range(config["stat_calib_seeds"])
        ]
        for s in subsets:
            if s not in app.golden_pe:
                app.golden_pe[s] = app.per_example(
                    _executor(engine, devices), s)
        app.golden_metric = app.golden_pe[idx].metric
        golden_info[app.name] = {
            "metric": app.kind, "value": app.golden_metric,
            "offloads": app.offloads,
        }
        say(f"  golden {app.name}: {app.kind}={app.golden_metric:.4f} "
            f"offloads={app.offloads}")
    golden_ops = {
        t.name: _golden_op_outputs(t, config["op_samples"], seed, engine,
                                   devices,
                                   boundary=config.get("op_boundary", 0))
        for t in selected
    }
    vt2_cases = {t.name: t.vt2_cases(8, 32) for t in selected}
    stat_cal = _calibrate_stat(campaign_apps, selected, config, say)
    instances = _enumerate_instances(selected, config["faults"])
    # golden probe streams for the static tier: planner packing only
    # (crc32-seeded, so sharded workers derive identical probes)
    probes = {t.name: ilalint.probe_streams(t, seed=seed, samples=1)
              for t in selected}
    return _Ctx(config, selected, campaign_apps, golden_info, golden_ops,
                vt2_cases, eval_idx, stat_cal, instances, probes)


def _run_one(ctx: _Ctx, t, inst: FaultInstance) -> MutantReport:
    """One mutant through the ladder, crash-isolated: an exception raised
    by the mutant (planning, simulation, or a deliberately injected fault)
    is recorded as outcome ``crash`` with whatever tiers completed;
    ``swapped_in`` guarantees registry restoration either way."""
    cfg = ctx.config
    t0 = time.perf_counter()
    mutant = make_mutant(t, inst)
    tiers: Dict[str, TierResult] = {}
    outcome, error = "ok", ""
    mkey = f"{t.name}:{inst.fault}@{inst.instruction}"

    def tier_span(name):
        # one span per tier, trace-correlated by mutant key; the sharded
        # runner ships these back with the result (worker-side export)
        return TELEMETRY.span("campaign.tier", trace_id=mkey, tier=name,
                              target=t.name, fault=inst.fault)

    try:
        with swapped_in(mutant):
            # tier 0: static verification against the golden probe streams
            # — no simulation; under an escalation ladder a static
            # detection skips every simulated tier below
            with tier_span("static") as sp:
                tiers["static"] = _tier_static(t, ctx.probes[t.name], inst)
                sp.set(detected=tiers["static"].detected)

            def app_and_stat():
                app_tier, stat_tier = _tier_app_and_stat(ctx, t)
                tiers["app"] = app_tier
                return stat_tier

            runner = [
                ("vt2", lambda: _tier_vt2(
                    mutant, mutant.vt2_cases(8, 32), cfg["vt2_n"],
                    cfg["seed"])),
                ("frag_sim", lambda: _tier_frag_sim(
                    mutant, ctx.vt2_cases[t.name], cfg["engine"],
                    cfg["devices_per_target"], cfg["seed"])),
                ("op_diff", lambda: _tier_op_diff(
                    t, ctx.golden_ops[t.name], cfg["engine"],
                    cfg["devices_per_target"])),
                # one shared evaluation pass fills BOTH app and stat
                ("stat", app_and_stat),
            ]
            for name, run in runner:
                if cfg["ladder"] == "escalate" and any(
                    r.detected for r in tiers.values() if r.detected
                ):
                    tiers[name] = TierResult(
                        name, None, detail="skipped (caught earlier)")
                    if name == "stat":
                        tiers.setdefault("app", TierResult(
                            "app", None, detail="skipped (caught earlier)"))
                    continue
                with tier_span(name) as sp:
                    tiers[name] = run()
                    sp.set(detected=tiers[name].detected)
    except KeyboardInterrupt:
        raise
    except Exception as e:
        outcome = "crash"
        error = f"{type(e).__name__}: {e}"
    t1 = time.perf_counter()
    rep = MutantReport(
        t.name, inst.fault, inst.instruction, inst.note, tiers,
        seconds=t1 - t0, outcome=outcome, error=error,
    )
    if TELEMETRY.enabled:
        TELEMETRY.record_span(
            "campaign.mutant", t0, t1, trace_id=mkey, outcome=outcome,
            detected_at=rep.detected_at or "never")
    return rep


def _count_report(rep: Dict[str, Any]) -> None:
    """Escape-matrix counters into the process metrics registry: every
    finished mutant increments ``campaign.mutants``, its first detecting
    tier (or ``campaign.escaped``), its outcome, and the per-mutant
    wall-clock histogram. Both runners call this — for the sharded runner
    it runs parent-side on the checkpointed report dict, so worker
    process boundaries don't lose counts."""
    TELEMETRY.counter("campaign.mutants").inc()
    det = rep.get("detected_at")
    if det:
        TELEMETRY.counter("campaign.detected", tier=det).inc()
    else:
        TELEMETRY.counter("campaign.escaped").inc()
    TELEMETRY.counter(
        "campaign.outcome", outcome=rep.get("outcome", "ok")).inc()
    TELEMETRY.histogram("campaign.mutant_s").observe(
        float(rep.get("seconds", 0.0)))


def _eta_suffix(done: int, total: int, elapsed_s: float) -> str:
    """The running throughput/ETA tail of a campaign progress line.
    ``done``/``elapsed_s`` cover only this run (resumed mutants excluded);
    the rate is published as ``campaign.mutants_per_s`` and the line reads
    it back from the registry — one source of truth for reporting."""
    rate = TELEMETRY.gauge("campaign.mutants_per_s")
    rate.set(done / elapsed_s if elapsed_s > 0 else 0.0)
    r = rate.value
    if r <= 0 or done >= total:
        return ""
    return f" | {r:.2f} mutants/s, ETA {(total - done) / r:.0f}s"


# ---------------------------------------------------------------------------
# The serial campaign (with checkpoint/resume)
# ---------------------------------------------------------------------------


def run_campaign(
    targets: Optional[Sequence[str]] = None,
    faults: Optional[Sequence[str]] = None,
    apps: Sequence[str] = ("resmlp", "lstm-wlm"),
    engine: str = "pipelined",
    devices_per_target: int = 2,
    ladder: str = "full",
    n_eval: int = 32,
    train_steps: int = 120,
    op_samples: int = 2,
    op_boundary: int = 0,
    vt2_n: int = 4,
    acc_delta: float = 0.02,
    ppl_ratio: float = 1.02,
    seed: int = 0,
    stat_floor: float = 1e-3,
    stat_calib_seeds: int = 2,
    checkpoint: Optional[str] = None,
    resume: bool = False,
    progress: Optional[Callable[[str], None]] = None,
) -> CampaignResult:
    """Run the full campaign; see the module docstring.

    ``ladder="full"`` runs every tier on every mutant (the complete escape
    matrix); ``"escalate"`` stops at the first detecting tier (cheaper —
    the first-detection statistics are identical). All randomness is seeded:
    golden and mutant evaluations see identical inputs (the evaluation
    subset itself is drawn from ``seed``), so every reported delta is a
    real semantic difference, not sampling noise. ``checkpoint`` names a
    JSON file updated atomically after every mutant; with ``resume=True``
    completed mutants recorded there (under a matching config fingerprint)
    are skipped.
    """
    say = progress or (lambda s: None)
    t_start = time.perf_counter()
    config = _resolve_config(
        targets=targets, faults=faults, apps=apps, engine=engine,
        devices_per_target=devices_per_target, ladder=ladder, n_eval=n_eval,
        train_steps=train_steps, op_samples=op_samples,
        op_boundary=op_boundary, vt2_n=vt2_n,
        acc_delta=acc_delta, ppl_ratio=ppl_ratio, seed=seed,
        stat_floor=stat_floor, stat_calib_seeds=stat_calib_seeds,
    )

    completed: Dict[str, Dict[str, Any]] = {}
    prior_seconds = 0.0
    ckpt_golden: Dict[str, Any] = {}
    ckpt_cal: Dict[str, Any] = {}
    if resume and checkpoint and os.path.exists(checkpoint):
        completed, prior_seconds, ckpt_golden, ckpt_cal = _load_checkpoint(
            checkpoint, config)
        say(f"resuming: {len(completed)} mutant(s) already completed")

    keys = list(_enumerate_instances(
        TARGETS.all(config["targets"]), config["faults"]))
    if all(k in completed for k in keys):
        # nothing left to run: finalize straight from the checkpoint
        reports = [MutantReport.from_dict(completed[k]) for k in keys]
        result = CampaignResult(reports, ckpt_golden, config, ckpt_cal,
                                seconds=prior_seconds)
        if checkpoint:
            _save_checkpoint(checkpoint, config, ckpt_golden, ckpt_cal,
                             [r.to_dict() for r in reports],
                             result.seconds, partial=False)
        return result

    ctx = _prepare(config, say)
    reports: List[MutantReport] = []
    n_run = 0
    n_todo = sum(1 for k in ctx.instances if k not in completed)
    t_run = time.perf_counter()
    for key, (t, inst) in ctx.instances.items():
        if key in completed:
            reports.append(MutantReport.from_dict(completed[key]))
            continue
        rep = _run_one(ctx, t, inst)
        reports.append(rep)
        completed[key] = rep.to_dict()
        n_run += 1
        _count_report(completed[key])
        if checkpoint:
            _save_checkpoint(
                checkpoint, config, ctx.golden_info, ctx.stat_cal,
                [r.to_dict() for r in reports],
                prior_seconds + time.perf_counter() - t_start, partial=True)
        say(f"  {rep.key}: detected_at={rep.detected_at or 'never'} "
            f"({rep.seconds:.1f}s)"
            + _eta_suffix(n_run, n_todo, time.perf_counter() - t_run))

    result = CampaignResult(
        reports, ctx.golden_info, config, ctx.stat_cal,
        seconds=prior_seconds + time.perf_counter() - t_start,
    )
    if checkpoint:
        _save_checkpoint(checkpoint, config, ctx.golden_info, ctx.stat_cal,
                         [r.to_dict() for r in reports], result.seconds,
                         partial=False)
    return result


# ---------------------------------------------------------------------------
# The fault-tolerant sharded runner
# ---------------------------------------------------------------------------


def _shard_worker(wid: int, config: Dict[str, Any], task_q, result_q) -> None:
    """Worker-subprocess loop: prepare a private campaign context (own JAX
    runtime, own registries, own device fleet), then run mutants by key.
    Mutant crashes are already absorbed by :func:`_run_one` (outcome
    ``crash``); anything escaping it — infrastructure failure — is reported
    as ``error`` for the parent's retry policy. The worker itself never
    dies from a mutant."""
    import traceback
    try:
        from .. import accel  # noqa: F401  (registers bundled targets)
        if config.get("_trace_spans"):
            # tracing requested in the parent: record spans here too and
            # ship each mutant's spans back with its result (the ring is
            # drained per mutant, so worker memory stays bounded)
            TELEMETRY.enable()
        ctx = _prepare(config, lambda s: None)
        TELEMETRY.drain_spans()  # prepare/warmup spans are not per-mutant
        result_q.put(("ready", wid, {
            "golden": ctx.golden_info, "stat_calibration": ctx.stat_cal,
        }))
    except BaseException:
        result_q.put(("init_failed", wid, traceback.format_exc(limit=20)))
        return
    while True:
        try:
            key = task_q.get(timeout=30)
        except queue_mod.Empty:
            # if the parent was SIGKILLed (CI kill-and-resume leg) we are
            # re-parented to init — exit instead of lingering forever
            if os.getppid() == 1:
                return
            continue
        if key is None:
            return
        result_q.put(("begin", wid, key))
        try:
            t, inst = ctx.instances[key]
            rep = _run_one(ctx, t, inst)
            spans = TELEMETRY.drain_spans() if TELEMETRY.enabled else []
            result_q.put(("done", wid, key, rep.to_dict(), spans))
        except BaseException:
            result_q.put(("error", wid, key, traceback.format_exc(limit=20)))


def _failure_report(meta: Tuple[str, str, str, str], outcome: str,
                    error: str, attempts: int, seconds: float) -> Dict[str, Any]:
    tname, fault, instruction, note = meta
    return MutantReport(
        tname, fault, instruction, note, {}, seconds=seconds,
        outcome=outcome, error=error, attempts=attempts,
    ).to_dict()


def run_campaign_sharded(
    workers: int = 2,
    mutant_timeout: float = 300.0,
    retries: int = 1,
    retry_backoff: float = 2.0,
    checkpoint: Optional[str] = None,
    resume: bool = False,
    progress: Optional[Callable[[str], None]] = None,
    trace_spans: Optional[bool] = None,
    **params,
) -> CampaignResult:
    """The fault-tolerant sharded campaign: mutants fan out across
    ``workers`` subprocesses, each owning a private device fleet and
    registries (spawned, so mutant state can never leak between workers or
    back into this process).

    Per-mutant robustness semantics:

    * a mutant that **raises** is absorbed inside the worker (outcome
      ``crash`` via :func:`_run_one`); a worker that *dies* mid-mutant
      (segfault, OOM-kill) is treated the same, after retries;
    * a mutant exceeding ``mutant_timeout`` seconds gets its worker
      terminated and is recorded as outcome ``timeout`` (never retried — a
      hang would hang again); a fresh worker replaces the killed one;
    * transient infrastructure failures retry up to ``retries`` times with
      ``retry_backoff * attempt`` seconds of backoff;
    * every completed mutant checkpoints to ``checkpoint`` atomically, and
      ``resume=True`` continues an interrupted campaign (config fingerprint
      permitting) with a bit-identical final matrix (:func:`matrix_digest`).

    ``trace_spans`` (default: inherit ``TELEMETRY.enabled``) makes each
    worker record telemetry spans and ship them back with every result;
    the parent merges them into its own span buffer (per-worker lanes), so
    an exported trace covers the whole sharded campaign. Runner knobs —
    including ``trace_spans`` — are outside the config fingerprint, so
    tracing never invalidates a resume.

    Remaining keyword arguments are :func:`run_campaign`'s campaign knobs.
    The escape matrix is deterministic and identical to the serial
    runner's; only wall-clock and attempt counts differ.
    """
    import multiprocessing as mp

    say = progress or (lambda s: None)
    t_start = time.perf_counter()
    config = _resolve_config(**params)
    run_cfg = dict(config, workers=workers, mutant_timeout=mutant_timeout,
                   retries=retries)
    if trace_spans is None:
        trace_spans = TELEMETRY.enabled
    worker_cfg = dict(config, _trace_spans=bool(trace_spans))

    selected = TARGETS.all(config["targets"])
    instances = _enumerate_instances(selected, config["faults"])
    keys = list(instances)
    meta = {
        k: (t.name, inst.fault, inst.instruction, inst.note)
        for k, (t, inst) in instances.items()
    }

    completed: Dict[str, Dict[str, Any]] = {}
    prior_seconds = 0.0
    golden_info: Dict[str, Any] = {}
    stat_cal: Dict[str, Any] = {}
    if resume and checkpoint and os.path.exists(checkpoint):
        completed, prior_seconds, golden_info, stat_cal = _load_checkpoint(
            checkpoint, config)
        completed = {k: v for k, v in completed.items() if k in meta}
        say(f"resuming: {len(completed)} mutant(s) already completed")

    pending = [k for k in keys if k not in completed]
    attempts = {k: 0 for k in pending}
    not_before = {k: 0.0 for k in pending}

    def finalize() -> CampaignResult:
        reports = [MutantReport.from_dict(completed[k]) for k in keys]
        result = CampaignResult(
            reports, golden_info, run_cfg, stat_cal,
            seconds=prior_seconds + time.perf_counter() - t_start,
        )
        if checkpoint:
            _save_checkpoint(checkpoint, run_cfg, golden_info, stat_cal,
                             [r.to_dict() for r in reports], result.seconds,
                             partial=False)
        return result

    if not pending:
        return finalize()

    n_resumed = len(completed)

    def record(key: str, rep: Dict[str, Any]) -> None:
        completed[key] = rep
        _count_report(rep)
        if checkpoint:
            _save_checkpoint(
                checkpoint, run_cfg, golden_info, stat_cal,
                [completed[k] for k in keys if k in completed],
                prior_seconds + time.perf_counter() - t_start, partial=True)
        say(f"  [{len(completed)}/{len(keys)}] {key}: "
            f"{rep.get('detected_at') or 'never'} "
            f"(outcome={rep.get('outcome', 'ok')}, "
            f"{rep.get('seconds', 0.0):.1f}s)"
            + _eta_suffix(len(completed) - n_resumed, len(keys) - n_resumed,
                          time.perf_counter() - t_start))

    mpctx = mp.get_context("spawn")
    result_q = mpctx.Queue()
    next_wid = 0

    def spawn():
        nonlocal next_wid
        wid = next_wid
        next_wid += 1
        q = mpctx.Queue()
        p = mpctx.Process(target=_shard_worker,
                          args=(wid, worker_cfg, q, result_q), daemon=True)
        p.start()
        # init covers app training + golden eval + calibration; give it a
        # generous independent watchdog so a wedged init cannot stall the
        # campaign forever
        return {"proc": p, "q": q, "wid": wid, "key": None, "deadline": None,
                "ready": False, "init_deadline": time.monotonic() + max(
                    900.0, 3.0 * mutant_timeout)}

    fleet = {w["wid"]: w for w in
             (spawn() for _ in range(max(1, min(workers, len(pending)))))}

    def requeue_or_fail(key: str, why: str) -> None:
        if attempts[key] <= retries:
            not_before[key] = time.monotonic() + retry_backoff * attempts[key]
            pending.append(key)
            say(f"  retrying {key} (attempt {attempts[key]} failed: {why})")
        else:
            record(key, _failure_report(meta[key], "crash", why,
                                        attempts[key], 0.0))

    try:
        while len(completed) < len(keys):
            now = time.monotonic()
            # dispatch to idle ready workers
            for w in fleet.values():
                if w["ready"] and w["key"] is None and w["proc"].is_alive():
                    k = next((k for k in pending if not_before[k] <= now),
                             None)
                    if k is None:
                        continue
                    pending.remove(k)
                    attempts[k] += 1
                    w["key"] = k
                    # fallback deadline in case "begin" is never received
                    w["deadline"] = now + mutant_timeout + 60.0
                    w["q"].put(k)
            # drain one message (with a poll timeout so watchdogs tick)
            try:
                msg = result_q.get(timeout=0.25)
            except queue_mod.Empty:
                msg = None
            if msg is not None:
                kind, wid = msg[0], msg[1]
                w = fleet.get(wid)
                if w is None:
                    pass  # late message from an already-killed worker
                elif kind == "ready":
                    w["ready"] = True
                    w["init_deadline"] = None
                    if not golden_info:
                        golden_info = msg[2]["golden"]
                        stat_cal = msg[2]["stat_calibration"]
                elif kind == "init_failed":
                    raise RuntimeError(
                        f"sharded campaign worker failed to initialize:\n"
                        f"{msg[2]}")
                elif kind == "begin":
                    w["deadline"] = time.monotonic() + mutant_timeout
                elif kind == "done":
                    key, rep = msg[2], msg[3]
                    rep["attempts"] = attempts.get(key, 1)
                    spans = msg[4] if len(msg) > 4 else []
                    if spans:
                        TELEMETRY.ingest(spans, source=f"worker{wid}")
                    record(key, rep)
                    w["key"], w["deadline"] = None, None
                elif kind == "error":
                    key = msg[2]
                    w["key"], w["deadline"] = None, None
                    requeue_or_fail(key, msg[3].strip().splitlines()[-1]
                                    if msg[3].strip() else "worker error")
            # watchdogs: per-mutant timeout, init timeout, worker death
            now = time.monotonic()
            for wid, w in list(fleet.items()):
                key = w["key"]
                if key is not None and w["deadline"] and now > w["deadline"]:
                    say(f"  {key}: exceeded mutant_timeout="
                        f"{mutant_timeout:g}s — terminating worker {wid}")
                    w["proc"].terminate()
                    w["proc"].join(10)
                    record(key, _failure_report(
                        meta[key], "timeout",
                        f"exceeded mutant_timeout={mutant_timeout:g}s",
                        attempts[key], mutant_timeout))
                    del fleet[wid]
                elif not w["proc"].is_alive():
                    del fleet[wid]
                    if not w["ready"]:
                        # died before ever reporting ready: environment
                        # problem, not a mutant — respawning would loop
                        raise RuntimeError(
                            "sharded campaign worker died during "
                            f"initialization (exitcode={w['proc'].exitcode})"
                            "; is the entry point spawn-safe "
                            "(__main__ importable)?")
                    if key is not None:
                        requeue_or_fail(
                            key, "worker process died "
                            f"(exitcode={w['proc'].exitcode})")
                elif (not w["ready"] and w["init_deadline"]
                      and now > w["init_deadline"]):
                    w["proc"].terminate()
                    w["proc"].join(10)
                    del fleet[wid]
                    raise RuntimeError(
                        "sharded campaign worker hung during initialization")
            # keep the fleet sized to the remaining work
            in_flight = sum(1 for w in fleet.values() if w["key"] is not None)
            todo = len(keys) - len(completed) - in_flight
            while todo > 0 and len(fleet) < max(1, min(workers, todo + in_flight)):
                w = spawn()
                fleet[w["wid"]] = w
                todo -= 1
    finally:
        for w in fleet.values():
            if w["proc"].is_alive():
                try:
                    w["q"].put(None)
                except Exception:
                    pass
        deadline = time.monotonic() + 5.0
        for w in fleet.values():
            w["proc"].join(max(0.1, deadline - time.monotonic()))
            if w["proc"].is_alive():
                w["proc"].terminate()
                w["proc"].join(5)

    return finalize()


def format_matrix(result: CampaignResult) -> str:
    """The human-readable escape-analysis matrix."""
    iw = max([13] + [len(r.instruction) for r in result.reports])
    rows = [
        f"{'target':9s} {'fault':12s} {'instruction':{iw}s} "
        + " ".join(f"{t:>9s}" for t in TIER_ORDER)
        + "  detected_at"
    ]
    rows.append("-" * len(rows[0]))
    for r in result.reports:
        cells = " ".join(
            f"{(r.tiers[t].cell() if t in r.tiers else '-'):>9s}"
            for t in TIER_ORDER
        )
        flag = ""
        if r.app_only:
            flag = " [app-only escape]"
        elif r.stat_only:
            flag = " [stat-only escape]"
        rows.append(
            f"{r.target:9s} {r.fault:12s} {r.instruction:{iw}s} {cells}"
            f"  {r.detected_at or 'never'}{flag}"
        )
    s = result.summary()
    rows.append("")
    rows.append(
        f"{s['mutants']} mutants in {result.seconds:.1f}s "
        f"({s['mutants_per_sec']:.2f} mutants/sec); "
        f"first detection by tier: {s['first_detection_by_tier']}"
    )
    if s["app_only"]:
        rows.append(
            "caught ONLY at application level (the paper's thesis, "
            f"quantified): {s['app_only']}"
        )
    if s["stat_only"]:
        rows.append(
            "caught ONLY by the calibrated statistical tier (escaped even "
            f"the app-metric threshold): {s['stat_only']}"
        )
    if s["crashes"]:
        rows.append(f"crashed mutants (isolated, campaign completed): "
                    f"{s['crashes']}")
    if s["timeouts"]:
        rows.append(f"timed-out mutants (terminated at the per-mutant "
                    f"deadline): {s['timeouts']}")
    if s["undetected"]:
        rows.append(f"undetected non-identity mutants: {s['undetected']}")
    return "\n".join(rows)
