"""Rewrite rules for flexible matching (Section 2.2 + Section 5.1 + Fig. 7).

Two families, exactly as in the paper:

* **Compiler-IR rewrites** — accelerator-independent equivalences that expose
  more match sites: linear-layer canonicalization, add commutativity,
  dense -> dense+0 bias introduction, conv2d -> im2col -> GEMM (the paper's
  "emergent effect" that lets VTA run convolutions), and the 2D-maxpool
  decomposition into FlexASR temporal (2,1)/(2,1) poolings of Figure 7.

* **IR-accelerator rewrites** — each replaces a compiler-IR pattern by the
  corresponding accelerator intrinsic (which codegen later lowers to an ILA
  command stream). These are *owned by the targets*: every registered
  ``AcceleratorTarget`` declares its own (pattern + capacity guard + data-
  transfer cancellations, cf. Section 5.1), and this module enumerates the
  registry. Adding an accelerator adds rewrites without editing this file.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence

import numpy as np

from .egraph import (
    P, V, Rewrite, add_op as _add_op,
    shape_of as _shape,
)


# --------------------------------------------------------------------------
# Compiler-IR rewrites
# --------------------------------------------------------------------------


def _linear_reshape_guard(eg, cid, s):
    """(add (reshape (dense a b) s) c): c must be a vector broadcastable over
    the reshaped dense output (the condition "when %c is a vector, for
    certain shapes %s" of Section 2.2.2)."""
    a = _shape(eg, s["a"])
    b = _shape(eg, s["b"])
    d = a[:-1] + (b[0],)
    c = _shape(eg, s["c"])
    tgt = tuple(s["shape"])
    if len(c) != 1 or c[0] != d[-1]:
        return False
    return tgt[-1] == d[-1] and int(np.prod(tgt)) == int(np.prod(d))


def _linear_reshape_applier(eg, cid, s):
    # -> (reshape (bias_add (dense a b) c) s)
    d = _add_op(eg, "dense", [s["a"], s["b"]])
    ba = _add_op(eg, "bias_add", [d, s["c"]])
    return _add_op(eg, "reshape", [ba], shape=tuple(s["shape"]))


def _dense_zero_applier(eg, cid, s):
    dshape = _shape(eg, cid)
    z = _add_op(eg, "zeros", [], shape=(dshape[-1],))
    d = _add_op(eg, "dense", [s["a"], s["b"]])
    return _add_op(eg, "bias_add", [d, z])


def _im2col_guard(eg, cid, s):
    return tuple(s["padding"]) == (0, 0)


def _hoist_pad_applier(eg, cid, s):
    padded = _add_op(eg, "pad2d", [s["x"]], pad=tuple(s["padding"]))
    return _add_op(
        eg, "conv2d", [padded, s["w"]], strides=tuple(s["strides"]), padding=(0, 0)
    )


def _im2col_applier(eg, cid, s):
    """conv2d(x, w) -> reshape(dense(im2col(x), wmat), out_shape).

    w is HWIO; wmat = reshape(transpose(w, OHWI), (CO, KH*KW*CI)).
    """
    xs = _shape(eg, s["x"])
    ws = _shape(eg, s["w"])
    n, h, wdim, c = xs
    kh, kw, ci, co = ws
    sh, sw = s["strides"]
    oh, ow = (h - kh) // sh + 1, (wdim - kw) // sw + 1
    patches = _add_op(eg, "im2col", [s["x"]], kh=kh, kw=kw, sh=sh, sw=sw)
    wt = _add_op(eg, "transpose", [s["w"]], axes=(3, 0, 1, 2))
    wmat = _add_op(eg, "reshape", [wt], shape=(co, kh * kw * ci))
    d = _add_op(eg, "dense", [patches, wmat])
    return _add_op(eg, "reshape", [d], shape=(n, oh, ow, co))


def _maxpool_decomp_guard(eg, cid, s):
    wh, ww = s["wh"], s["ww"]
    k = wh * ww
    # decomposable when the window has a power-of-two element count > 1
    return k > 1 and (k & (k - 1)) == 0


def _pool_decomp_applier(kind):
    """Figure 7: 2D pooling (wh,ww)/(sh,sw) == reshape of log2(wh*ww)
    pairwise-row poolings of the transposed flattened window matrix."""

    red = "reduce_max" if kind == "max" else "reduce_mean"

    def applier(eg, cid, s):
        wh, ww, sh, sw = s["wh"], s["ww"], s["sh"], s["sw"]
        tsh = _shape(eg, s["T"])
        hh, wwdim = tsh
        oh, ow = (hh - wh) // sh + 1, (wwdim - ww) // sw + 1
        k = int(math.log2(wh * ww))
        wins = _add_op(eg, "windows", [s["T"]], wh=wh, ww=ww, sh=sh, sw=sw)
        flat = _add_op(eg, "flatten_window", [wins])          # (OH*OW, WH*WW)
        cur = _add_op(eg, "transpose", [flat], axes=(1, 0))   # (WH*WW, OH*OW)
        for _ in range(k):
            w2 = _add_op(eg, "windows", [cur], wh=2, ww=1, sh=2, sw=1)
            cur = _add_op(eg, red, [w2], axis=(2, 3))
        return _add_op(eg, "reshape", [cur], shape=(oh, ow))

    return applier


def compiler_ir_rewrites() -> List[Rewrite]:
    return [
        Rewrite(
            "add-comm",
            P("add", V("a"), V("b")),
            P("add", V("b"), V("a")),
        ),
        Rewrite(
            "linear-reshape",
            P("add", P("reshape", P("dense", V("a"), V("b")), attr_binds=("shape",)), V("c")),
            guard=_linear_reshape_guard,
            applier=_linear_reshape_applier,
        ),
        Rewrite(
            "dense-zero-bias",
            P("dense", V("a"), V("b")),
            applier=_dense_zero_applier,
        ),
        Rewrite(
            # host-side padding (Appendix A: "our implementation pads on the
            # host before invoking the accelerator")
            "conv2d-hoist-pad",
            P("conv2d", V("x"), V("w"), attr_binds=("strides", "padding")),
            guard=lambda eg, cid, s: tuple(s["padding"]) != (0, 0),
            applier=_hoist_pad_applier,
        ),
        Rewrite(
            "conv2d-im2col",
            P(
                "conv2d",
                V("x"),
                V("w"),
                attr_binds=("strides", "padding"),
            ),
            guard=_im2col_guard,
            applier=_im2col_applier,
        ),
        Rewrite(
            "maxpool-decompose",
            P(
                "reduce_max",
                P("windows", V("T"), attr_binds=("wh", "ww", "sh", "sw")),
                attrs=(("axis", (2, 3)),),
            ),
            guard=_maxpool_decomp_guard,
            applier=_pool_decomp_applier("max"),
        ),
        # reshape(x, shape(x)) -> x
        Rewrite(
            "reshape-noop",
            P("reshape", V("x"), attr_binds=("shape",)),
            guard=lambda eg, cid, s: tuple(s["shape"]) == _shape(eg, s["x"]),
            applier=lambda eg, cid, s: eg.find(s["x"]),
        ),
    ]


# --------------------------------------------------------------------------
# IR-accelerator rewrites: registry-driven
# --------------------------------------------------------------------------
#
# Each registered AcceleratorTarget owns its IR -> intrinsic rewrites
# (pattern + capacity guard, attributed to the target for saturation
# statistics). This module only enumerates the registry — adding an
# accelerator never touches this file.

from .. import accel as _accel  # noqa: F401  (registers the bundled targets)
from .ila import TARGETS


def accelerator_rewrites(
    targets: Optional[Sequence[str]] = None,
    exclude: Sequence[str] = (),
) -> List[Rewrite]:
    """The IR-accelerator rewrites of every selected target (None = all
    registered, in registration order). ``exclude`` drops named targets —
    how a ``SelectionPolicy.forbid`` keeps a vetoed target's intrinsics out
    of the e-graph entirely rather than merely pricing them to infinity."""
    skip = set(exclude)
    out: List[Rewrite] = []
    for t in TARGETS.all(targets):
        if t.name not in skip:
            out += t.rewrites()
    return out


def all_rewrites(
    targets: Optional[Sequence[str]] = None,
    flexible: bool = True,
    exclude: Sequence[str] = (),
) -> List[Rewrite]:
    """flexible=False == the paper's *exact matching* baseline (only the
    IR-accelerator rewrites); flexible=True adds the compiler-IR rewrites."""
    out = accelerator_rewrites(targets, exclude)
    if flexible:
        out = compiler_ir_rewrites() + out
    return out
