"""Rewrite rules for flexible matching (Section 2.2 + Section 5.1 + Fig. 7).

Two families, exactly as in the paper:

* **Compiler-IR rewrites** — accelerator-independent equivalences that expose
  more match sites: linear-layer canonicalization, add commutativity,
  dense -> dense+0 bias introduction, conv2d -> im2col -> GEMM (the paper's
  "emergent effect" that lets VTA run convolutions), and the 2D-maxpool
  decomposition into FlexASR temporal (2,1)/(2,1) poolings of Figure 7.

* **IR-accelerator rewrites** — derived from the IR-accelerator mappings:
  each replaces a compiler-IR pattern by the corresponding accelerator
  intrinsic (which codegen later lowers to an ILA command stream).

* **Data-transfer cancellation** — (fasr_store (fasr_load ?x)) -> ?x of
  Section 5.1, removing redundant HBM<->accelerator round trips.
"""
from __future__ import annotations

import math
from typing import List

import numpy as np

from . import ir
from .egraph import EGraph, ENode, P, PatVar, Rewrite, V, op_head


# --------------------------------------------------------------------------
# helpers for appliers
# --------------------------------------------------------------------------


def _shape(eg: EGraph, cid: int):
    return eg.shape[eg.find(cid)]


def _add_op(eg: EGraph, op: str, children, **attrs) -> int:
    return eg.add(ENode(op_head(op, tuple(sorted(attrs.items()))), tuple(children)))


# --------------------------------------------------------------------------
# Compiler-IR rewrites
# --------------------------------------------------------------------------


def _linear_reshape_guard(eg, cid, s):
    """(add (reshape (dense a b) s) c): c must be a vector broadcastable over
    the reshaped dense output (the condition "when %c is a vector, for
    certain shapes %s" of Section 2.2.2)."""
    a = _shape(eg, s["a"])
    b = _shape(eg, s["b"])
    d = a[:-1] + (b[0],)
    c = _shape(eg, s["c"])
    tgt = tuple(s["shape"])
    if len(c) != 1 or c[0] != d[-1]:
        return False
    return tgt[-1] == d[-1] and int(np.prod(tgt)) == int(np.prod(d))


def _linear_reshape_applier(eg, cid, s):
    # -> (reshape (bias_add (dense a b) c) s)
    d = _add_op(eg, "dense", [s["a"], s["b"]])
    ba = _add_op(eg, "bias_add", [d, s["c"]])
    return _add_op(eg, "reshape", [ba], shape=tuple(s["shape"]))


def _dense_zero_applier(eg, cid, s):
    dshape = _shape(eg, cid)
    z = _add_op(eg, "zeros", [], shape=(dshape[-1],))
    d = _add_op(eg, "dense", [s["a"], s["b"]])
    return _add_op(eg, "bias_add", [d, z])


def _im2col_guard(eg, cid, s):
    return tuple(s["padding"]) == (0, 0)


def _hoist_pad_applier(eg, cid, s):
    padded = _add_op(eg, "pad2d", [s["x"]], pad=tuple(s["padding"]))
    return _add_op(
        eg, "conv2d", [padded, s["w"]], strides=tuple(s["strides"]), padding=(0, 0)
    )


def _im2col_applier(eg, cid, s):
    """conv2d(x, w) -> reshape(dense(im2col(x), wmat), out_shape).

    w is HWIO; wmat = reshape(transpose(w, OHWI), (CO, KH*KW*CI)).
    """
    xs = _shape(eg, s["x"])
    ws = _shape(eg, s["w"])
    n, h, wdim, c = xs
    kh, kw, ci, co = ws
    sh, sw = s["strides"]
    oh, ow = (h - kh) // sh + 1, (wdim - kw) // sw + 1
    patches = _add_op(eg, "im2col", [s["x"]], kh=kh, kw=kw, sh=sh, sw=sw)
    wt = _add_op(eg, "transpose", [s["w"]], axes=(3, 0, 1, 2))
    wmat = _add_op(eg, "reshape", [wt], shape=(co, kh * kw * ci))
    d = _add_op(eg, "dense", [patches, wmat])
    return _add_op(eg, "reshape", [d], shape=(n, oh, ow, co))


def _maxpool_decomp_guard(eg, cid, s):
    wh, ww = s["wh"], s["ww"]
    k = wh * ww
    # decomposable when the window has a power-of-two element count > 1
    return k > 1 and (k & (k - 1)) == 0


def _pool_decomp_applier(kind):
    """Figure 7: 2D pooling (wh,ww)/(sh,sw) == reshape of log2(wh*ww)
    pairwise-row poolings of the transposed flattened window matrix."""

    red = "reduce_max" if kind == "max" else "reduce_mean"

    def applier(eg, cid, s):
        wh, ww, sh, sw = s["wh"], s["ww"], s["sh"], s["sw"]
        tsh = _shape(eg, s["T"])
        hh, wwdim = tsh
        oh, ow = (hh - wh) // sh + 1, (wwdim - ww) // sw + 1
        k = int(math.log2(wh * ww))
        wins = _add_op(eg, "windows", [s["T"]], wh=wh, ww=ww, sh=sh, sw=sw)
        flat = _add_op(eg, "flatten_window", [wins])          # (OH*OW, WH*WW)
        cur = _add_op(eg, "transpose", [flat], axes=(1, 0))   # (WH*WW, OH*OW)
        for _ in range(k):
            w2 = _add_op(eg, "windows", [cur], wh=2, ww=1, sh=2, sw=1)
            cur = _add_op(eg, red, [w2], axis=(2, 3))
        return _add_op(eg, "reshape", [cur], shape=(oh, ow))

    return applier


def compiler_ir_rewrites() -> List[Rewrite]:
    return [
        Rewrite(
            "add-comm",
            P("add", V("a"), V("b")),
            P("add", V("b"), V("a")),
        ),
        Rewrite(
            "linear-reshape",
            P("add", P("reshape", P("dense", V("a"), V("b")), attr_binds=("shape",)), V("c")),
            guard=_linear_reshape_guard,
            applier=_linear_reshape_applier,
        ),
        Rewrite(
            "dense-zero-bias",
            P("dense", V("a"), V("b")),
            applier=_dense_zero_applier,
        ),
        Rewrite(
            # host-side padding (Appendix A: "our implementation pads on the
            # host before invoking the accelerator")
            "conv2d-hoist-pad",
            P("conv2d", V("x"), V("w"), attr_binds=("strides", "padding")),
            guard=lambda eg, cid, s: tuple(s["padding"]) != (0, 0),
            applier=_hoist_pad_applier,
        ),
        Rewrite(
            "conv2d-im2col",
            P(
                "conv2d",
                V("x"),
                V("w"),
                attr_binds=("strides", "padding"),
            ),
            guard=_im2col_guard,
            applier=_im2col_applier,
        ),
        Rewrite(
            "maxpool-decompose",
            P(
                "reduce_max",
                P("windows", V("T"), attr_binds=("wh", "ww", "sh", "sw")),
                attrs=(("axis", (2, 3)),),
            ),
            guard=_maxpool_decomp_guard,
            applier=_pool_decomp_applier("max"),
        ),
        # reshape(x, shape(x)) -> x
        Rewrite(
            "reshape-noop",
            P("reshape", V("x"), attr_binds=("shape",)),
            guard=lambda eg, cid, s: tuple(s["shape"]) == _shape(eg, s["x"]),
            applier=lambda eg, cid, s: eg.find(s["x"]),
        ),
    ]


# --------------------------------------------------------------------------
# IR-accelerator rewrites
# --------------------------------------------------------------------------


def _conv_to_hlscnn_applier(eg, cid, s):
    return _add_op(
        eg,
        "hlscnn_conv2d",
        [s["x"], s["w"]],
        strides=tuple(s["strides"]),
        padding=tuple(s["padding"]),
    )


def _ln_to_fasr_applier(eg, cid, s):
    return _add_op(eg, "fasr_layernorm", [s["x"], s["g"], s["b"]], eps=s["eps"])


# Device capacity limits (instruction-selection legality): a mapping only
# applies when operands fit the accelerator's architectural state. Row
# dimensions are driver-chunkable (codegen tiles them), so only feature
# dims are constrained.
FASR_MAX_D = 128   # flexasr.MAX_IN
FASR_MAX_T = 128   # flexasr.MAX_TS (attention KV length; not chunkable)
FASR_MAX_H = 64    # flexasr.MAX_H
HLSCNN_MAX_HW = 16
HLSCNN_MAX_C = 32
HLSCNN_MAX_K = 32
HLSCNN_MAX_KHW = 5


def _fasr_linear_guard(eg, cid, s):
    b = _shape(eg, s["b"])
    return len(_shape(eg, s["c"])) == 1 and b[1] <= FASR_MAX_D and b[0] <= FASR_MAX_D


def _fasr_lstm_guard(eg, cid, s):
    wi = _shape(eg, s["wi"])
    wh = _shape(eg, s["wh"])
    return wi[1] <= FASR_MAX_D and wh[1] <= FASR_MAX_H


def _fasr_attn_guard(eg, cid, s):
    q = _shape(eg, s["q"])
    k = _shape(eg, s["k"])
    return q[-1] <= FASR_MAX_D and q[-2] <= FASR_MAX_T and k[-2] <= FASR_MAX_T


def flexasr_rewrites() -> List[Rewrite]:
    return [
        Rewrite(
            "fasr-linear",
            P("bias_add", P("dense", V("a"), V("b")), V("c")),
            P("fasr_linear", V("a"), V("b"), V("c")),
            guard=_fasr_linear_guard,
        ),
        Rewrite(
            "fasr-lstm",
            P("lstm", V("x"), V("wi"), V("wh"), V("b")),
            P("fasr_lstm", V("x"), V("wi"), V("wh"), V("b")),
            guard=_fasr_lstm_guard,
        ),
        Rewrite(
            "fasr-attention",
            P("attention", V("q"), V("k"), V("v")),
            P("fasr_attention", V("q"), V("k"), V("v")),
            guard=_fasr_attn_guard,
        ),
        Rewrite(
            "fasr-layernorm",
            P("layer_norm", V("x"), V("g"), V("b"), attr_binds=("eps",)),
            guard=lambda eg, cid, s: _shape(eg, s["x"])[-1] <= FASR_MAX_D,
            applier=_ln_to_fasr_applier,
        ),
        Rewrite(
            "fasr-maxpool",
            P(
                "reduce_max",
                P("windows", V("T"), attrs=(("wh", 2), ("ww", 1), ("sh", 2), ("sw", 1))),
                attrs=(("axis", (2, 3)),),
            ),
            # no width guard: pooling is elementwise across features, so the
            # driver chunks wide matrices column-wise (codegen._fasr_pool)
            P("fasr_load", P("fasr_maxpool", P("fasr_store", V("T")))),
        ),
        Rewrite(
            "fasr-meanpool",
            P(
                "reduce_mean",
                P("windows", V("T"), attrs=(("wh", 2), ("ww", 1), ("sh", 2), ("sw", 1))),
                attrs=(("axis", (2, 3)),),
            ),
            P("fasr_load", P("fasr_meanpool", P("fasr_store", V("T")))),
        ),
        # Section 5.1: cancel redundant accelerator<->host round trips
        Rewrite(
            "fasr-store-load-cancel",
            P("fasr_store", P("fasr_load", V("x"))),
            V("x"),
        ),
    ]


def _hlscnn_guard(eg, cid, s):
    n, h, w, c = _shape(eg, s["x"])
    kh, kw, ci, k = _shape(eg, s["w"])
    ph, pw = s["padding"]
    return (
        h + 2 * ph <= HLSCNN_MAX_HW
        and w + 2 * pw <= HLSCNN_MAX_HW
        and c <= HLSCNN_MAX_C
        and k <= HLSCNN_MAX_K
        and kh <= HLSCNN_MAX_KHW
        and kw <= HLSCNN_MAX_KHW
    )


def hlscnn_rewrites() -> List[Rewrite]:
    return [
        Rewrite(
            "hlscnn-conv2d",
            P("conv2d", V("x"), V("w"), attr_binds=("strides", "padding")),
            guard=_hlscnn_guard,
            applier=_conv_to_hlscnn_applier,
        ),
    ]


def vta_rewrites() -> List[Rewrite]:
    return [
        Rewrite("vta-gemm", P("dense", V("a"), V("b")), P("vta_gemm", V("a"), V("b"))),
        Rewrite("vta-add", P("add", V("a"), V("b")), P("vta_add", V("a"), V("b"))),
        Rewrite("vta-relu", P("relu", V("x")), P("vta_relu", V("x"))),
    ]


def accelerator_rewrites(targets=("flexasr", "hlscnn", "vta")) -> List[Rewrite]:
    out: List[Rewrite] = []
    if "flexasr" in targets:
        out += flexasr_rewrites()
    if "hlscnn" in targets:
        out += hlscnn_rewrites()
    if "vta" in targets:
        out += vta_rewrites()
    return out


def all_rewrites(targets=("flexasr", "hlscnn", "vta"), flexible=True) -> List[Rewrite]:
    """flexible=False == the paper's *exact matching* baseline (only the
    IR-accelerator rewrites); flexible=True adds the compiler-IR rewrites."""
    out = accelerator_rewrites(targets)
    if flexible:
        out = compiler_ir_rewrites() + out
    return out
