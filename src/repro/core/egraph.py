"""E-graph + equality saturation (egg-style) for flexible matching.

The paper's prototype uses Glenside + egg for equality-saturation-based
instruction selection ("flexible matching", Section 2.2). We re-implement the
needed core natively: hash-consed e-nodes, union-find e-classes, congruence
closure via rebuild, pattern-based rewriting to fixpoint (with node limits),
and cost-based extraction.

An e-node is ``ENode(head, children)`` where ``head`` identifies the operator
plus its static attributes, and ``children`` are e-class ids. Leaves (vars /
constants) have empty children and carry their identity in ``head``.

A *shape analysis* is maintained per e-class (like egg's e-class analyses):
all members of a class must agree on shape, which shape-conditioned rewrites
(linear-layer reshape, maxpool decomposition, im2col) rely on.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import ir


# --------------------------------------------------------------------------
# E-nodes
# --------------------------------------------------------------------------

Head = Tuple  # ("op", op_name, attrs) | ("var", name, shape, dtype) | ("const", v)


@dataclasses.dataclass(frozen=True)
class ENode:
    head: Head
    children: Tuple[int, ...] = ()

    def map_children(self, f):
        return ENode(self.head, tuple(f(c) for c in self.children))


def op_head(op: str, attrs: Tuple[Tuple[str, Any], ...] = ()) -> Head:
    return ("op", op, tuple(attrs))


# --------------------------------------------------------------------------
# Patterns
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PatVar:
    name: str


@dataclasses.dataclass(frozen=True)
class PatNode:
    op: str
    args: Tuple[Any, ...] = ()
    attrs: Tuple[Tuple[str, Any], ...] = ()   # exact attrs to require (subset match)
    attr_binds: Tuple[str, ...] = ()           # attr names to capture into subst


def P(op: str, *args, attrs=(), attr_binds=()) -> PatNode:
    return PatNode(op, tuple(args), tuple(attrs), tuple(attr_binds))


def V(name: str) -> PatVar:
    return PatVar(name)


# --------------------------------------------------------------------------
# E-graph
# --------------------------------------------------------------------------


class EGraph:
    def __init__(self):
        self.parent: List[int] = []
        self.classes: Dict[int, List[ENode]] = {}
        self.hashcons: Dict[ENode, int] = {}
        self.shape: Dict[int, Tuple[int, ...]] = {}
        self.worklist: List[int] = []
        self.n_nodes = 0
        # op-index: head[:2] (("op", name)) -> e-class ids known to contain a
        # node with that operator. Entries may be stale (merged-away ids);
        # ``_op_candidates`` resolves through union-find and re-compresses.
        # Lets ``search`` skip e-matching classes that cannot match a
        # pattern's root operator instead of scanning every class per rule.
        self.op_index: Dict[Tuple, set] = {}

    # -- union-find ---------------------------------------------------------
    def find(self, a: int) -> int:
        while self.parent[a] != a:
            self.parent[a] = self.parent[self.parent[a]]
            a = self.parent[a]
        return a

    def canon(self, n: ENode) -> ENode:
        return n.map_children(self.find)

    # -- adding -------------------------------------------------------------
    def _new_class(self, n: ENode, shape) -> int:
        cid = len(self.parent)
        self.parent.append(cid)
        self.classes[cid] = [n]
        self.hashcons[n] = cid
        self.shape[cid] = shape
        self.n_nodes += 1
        if n.head[0] == "op":
            self.op_index.setdefault(n.head[:2], set()).add(cid)
        return cid

    def _op_candidates(self, op: str) -> set:
        """Root e-classes that may contain an ``op`` node (superset: stale
        entries are canonicalized through find and compressed in place)."""
        ids = self.op_index.get(("op", op))
        if not ids:
            return set()
        roots = {self.find(c) for c in ids}
        self.op_index[("op", op)] = roots
        return roots

    def add(self, n: ENode) -> int:
        n = self.canon(n)
        if n in self.hashcons:
            return self.find(self.hashcons[n])
        return self._new_class(n, self._node_shape(n))

    def _node_shape(self, n: ENode):
        kind = n.head[0]
        if kind == "var":
            return tuple(n.head[2])
        if kind == "const":
            return ()
        op, attrs = n.head[1], dict(n.head[2])
        child_shapes = [self.shape[self.find(c)] for c in n.children]
        return _op_shape(op, attrs, child_shapes)

    def add_expr(self, e: ir.Expr) -> int:
        memo: Dict[int, int] = {}

        def rec(x: ir.Expr) -> int:
            if id(x) in memo:
                return memo[id(x)]
            if isinstance(x, ir.Var):
                cid = self.add(ENode(("var", x.name, tuple(x.shape), x.dtype)))
            elif isinstance(x, ir.Const):
                cid = self.add(ENode(("const", x.value)))
            else:
                assert isinstance(x, ir.Call)
                kids = tuple(rec(a) for a in x.args)
                cid = self.add(ENode(op_head(x.op, x.attrs), kids))
            memo[id(x)] = cid
            return cid

        return rec(e)

    # -- merging ------------------------------------------------------------
    def merge(self, a: int, b: int) -> int:
        a, b = self.find(a), self.find(b)
        if a == b:
            return a
        # keep the smaller id as root (stable)
        if len(self.classes[a]) < len(self.classes[b]):
            a, b = b, a
        self.parent[b] = a
        self.classes[a].extend(self.classes[b])
        del self.classes[b]
        sa, sb = self.shape.get(a), self.shape.pop(b, None)
        if sa is None:
            self.shape[a] = sb
        self.worklist.append(a)
        return a

    def rebuild(self):
        """Restore congruence closure.

        Full-rehash fixpoint: re-canonicalize every node, merge congruent
        duplicates, repeat until stable. O(N) per pass; our graphs are small
        (<= ~40k nodes, <= ~12 saturation iterations) so this sound-and-simple
        strategy is preferred over egg's incremental parents-worklist repair.
        """
        self.worklist.clear()
        changed = True
        while changed:
            changed = False
            new_hashcons: Dict[ENode, int] = {}
            pending_merges: List[Tuple[int, int]] = []
            for cid in list(self.classes.keys()):
                root = self.find(cid)
                if root != cid or root not in self.classes:
                    continue
                for n in self.classes[root]:
                    cn = self.canon(n)
                    other = new_hashcons.get(cn)
                    if other is None:
                        new_hashcons[cn] = root
                    elif self.find(other) != root:
                        pending_merges.append((other, root))
            for a, b in pending_merges:
                if self.find(a) != self.find(b):
                    self.merge(a, b)
                    changed = True
            self.worklist.clear()
            if not changed:
                # final: dedupe class node lists & rewrite hashcons
                self.hashcons = {}
                for cid in list(self.classes.keys()):
                    root = self.find(cid)
                    seen = set()
                    uniq = []
                    for n in self.classes[root]:
                        cn = self.canon(n)
                        if cn not in seen:
                            seen.add(cn)
                            uniq.append(cn)
                        self.hashcons[cn] = root
                    self.classes[root] = uniq

    # -- e-matching ----------------------------------------------------------
    def ematch(self, pat, cid: int, subst: Dict[str, Any]):
        """Yield extended substitutions matching ``pat`` against e-class cid."""
        cid = self.find(cid)
        if isinstance(pat, PatVar):
            bound = subst.get(pat.name)
            if bound is None:
                s2 = dict(subst)
                s2[pat.name] = cid
                yield s2
            elif self.find(bound) == cid:
                yield subst
            return
        assert isinstance(pat, PatNode)
        for n in list(self.classes.get(cid, ())):
            if n.head[0] != "op" or n.head[1] != pat.op:
                continue
            attrs = dict(n.head[2])
            if any(attrs.get(k) != v for k, v in pat.attrs):
                continue
            if len(n.children) != len(pat.args):
                continue
            s0 = dict(subst)
            ok = True
            for k in pat.attr_binds:
                if k in s0 and s0[k] != attrs.get(k):
                    ok = False
                    break
                s0[k] = attrs.get(k)
            if not ok:
                continue
            stack = [s0]
            for sub_pat, child in zip(pat.args, n.children):
                nxt = []
                for s in stack:
                    nxt.extend(self.ematch(sub_pat, child, s))
                stack = nxt
                if not stack:
                    break
            yield from stack

    def search(self, pat):
        """All (eclass, subst) matches of ``pat`` anywhere in the graph.

        Root-operator patterns consult the op-index so only candidate
        classes are e-matched; iteration stays in ``classes`` order, so
        match order — hence ``run_rewrites`` behavior — is unchanged.
        """
        out = []
        if isinstance(pat, PatNode):
            cands = self._op_candidates(pat.op)
            if not cands:
                return out
            for cid in list(self.classes.keys()):
                if cid not in cands:
                    continue
                for s in self.ematch(pat, cid, {}):
                    out.append((self.find(cid), s))
            return out
        for cid in list(self.classes.keys()):
            for s in self.ematch(pat, cid, {}):
                out.append((self.find(cid), s))
        return out

    # -- instantiation --------------------------------------------------------
    def instantiate(self, template, subst: Dict[str, Any]) -> int:
        if isinstance(template, PatVar):
            return self.find(subst[template.name])
        if isinstance(template, ir.Const):
            return self.add(ENode(("const", template.value)))
        assert isinstance(template, PatNode)
        kids = tuple(self.instantiate(a, subst) for a in template.args)
        attrs = []
        for k, v in template.attrs:
            attrs.append((k, v))
        for k in template.attr_binds:
            attrs.append((k, subst[k]))
        return self.add(ENode(op_head(template.op, tuple(sorted(attrs))), kids))


def _op_shape(op, attrs, child_shapes):
    """Shape semantics mirrored from ir._infer but over raw shapes."""
    cs = child_shapes
    if op in ("add", "sub", "mul", "maximum", "vta_add"):
        return tuple(np.broadcast_shapes(cs[0], cs[1]))
    if op in ("relu", "sigmoid", "tanh", "negative", "softmax", "vta_relu",
              "bias_add", "layer_norm", "fasr_layernorm",
              "fasr_store", "fasr_load", "vta_store", "vta_load"):
        return cs[0]
    if op in ("dense", "vta_gemm"):
        return cs[0][:-1] + (cs[1][0],)
    if op in ("fasr_linear",):
        return cs[0][:-1] + (cs[1][0],)
    if op == "reshape":
        return tuple(attrs["shape"])
    if op == "transpose":
        return tuple(cs[0][a] for a in attrs["axes"])
    if op in ("conv2d", "hlscnn_conv2d"):
        n, h, w, c = cs[0]
        kh, kw, ci, co = cs[1]
        (sh, sw), (ph, pw) = attrs["strides"], attrs["padding"]
        return (n, (h + 2 * ph - kh) // sh + 1, (w + 2 * pw - kw) // sw + 1, co)
    if op == "pad2d":
        n, h, w, c = cs[0]
        ph, pw = attrs["pad"]
        return (n, h + 2 * ph, w + 2 * pw, c)
    if op == "dw_conv2d":
        n, h, w, c = cs[0]
        kh, kw = cs[1][0], cs[1][1]
        (sh, sw), (ph, pw) = attrs["strides"], attrs["padding"]
        return (n, (h + 2 * ph - kh) // sh + 1, (w + 2 * pw - kw) // sw + 1, c)
    if op == "im2col":
        n, h, w, c = cs[0]
        kh, kw, sh, sw = attrs["kh"], attrs["kw"], attrs["sh"], attrs["sw"]
        return (n * ((h - kh) // sh + 1) * ((w - kw) // sw + 1), kh * kw * c)
    if op == "windows":
        h, w = cs[0]
        wh, ww, sh, sw = attrs["wh"], attrs["ww"], attrs["sh"], attrs["sw"]
        return ((h - wh) // sh + 1, (w - ww) // sw + 1, wh, ww)
    if op == "flatten_window":
        oh, ow, wh, ww = cs[0]
        return (oh * ow, wh * ww)
    if op in ("reduce_max", "reduce_mean", "reduce_sum"):
        ax = attrs["axis"]
        axes = (ax,) if isinstance(ax, int) else tuple(ax)
        axes = tuple(a % len(cs[0]) for a in axes)
        return tuple(s for i, s in enumerate(cs[0]) if i not in axes)
    if op in ("zeros", "ones"):
        return tuple(attrs["shape"])
    if op == "concat":
        ax = attrs["axis"]
        out = list(cs[0])
        out[ax] = sum(s[ax] for s in cs)
        return tuple(out)
    if op in ("lstm", "fasr_lstm"):
        return (cs[0][0], cs[0][1], cs[2][1])
    if op == "lstm_cell":
        return cs[1]
    if op in ("attention", "fasr_attention"):
        return cs[0][:-1] + (cs[2][-1],)
    if op in ("fasr_maxpool", "fasr_meanpool"):
        return (cs[0][0] // 2,) + tuple(cs[0][1:])
    ext = ir.accel_op_shape_fn(op)
    if ext is not None:
        return tuple(ext(dict(attrs), list(cs)))
    return None


# -- helpers for rewrite guards/appliers (used by plugin targets too) -------


def shape_of(eg: EGraph, cid: int) -> Tuple[int, ...]:
    """The e-class shape analysis value for ``cid`` (canonicalized)."""
    return eg.shape[eg.find(cid)]


def add_op(eg: EGraph, op: str, children, **attrs) -> int:
    """Add an op e-node with sorted static attrs; returns its e-class id."""
    return eg.add(ENode(op_head(op, tuple(sorted(attrs.items()))), tuple(children)))


# --------------------------------------------------------------------------
# Rewrites and the saturation loop
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Rewrite:
    name: str
    lhs: Any                              # pattern
    rhs: Any = None                       # template, or None if applier used
    applier: Optional[Callable] = None    # fn(egraph, cid, subst) -> new cid | None
    guard: Optional[Callable] = None      # fn(egraph, cid, subst) -> bool
    target: str = "ir"                    # owning accelerator target ("ir" = generic)


def run_rewrites(
    eg: EGraph,
    rules: Sequence[Rewrite],
    iters: int = 12,
    node_limit: int = 40_000,
) -> Dict[str, Any]:
    """Equality saturation: apply rules to fixpoint / limits. Returns stats.

    ``stats["match_counts"]`` tallies pattern matches per rewrite, keyed by
    the owning target; ``stats["truncated"]`` / ``stats["dropped_matches"]``
    flag node-limit truncation explicitly — a truncated run is *not* the same
    as "no match found", and silent truncation used to look exactly like it.
    """
    stats: Dict[str, Any] = {
        "iterations": 0,
        "applications": 0,
        "saturated": False,
        "truncated": False,
        "dropped_matches": 0,
        "match_counts": {},
    }
    counts: Dict[str, Dict[str, int]] = stats["match_counts"]
    for it in range(iters):
        matches = []
        for r in rules:
            found = eg.search(r.lhs)
            if found:
                per = counts.setdefault(r.target, {})
                per[r.name] = per.get(r.name, 0) + len(found)
            for cid, subst in found:
                matches.append((r, cid, subst))
        changed = False
        for mi, (r, cid, subst) in enumerate(matches):
            if eg.n_nodes > node_limit:
                stats["truncated"] = True
                stats["dropped_matches"] += len(matches) - mi
                break
            cid = eg.find(cid)
            if r.guard is not None and not r.guard(eg, cid, subst):
                continue
            if r.applier is not None:
                new = r.applier(eg, cid, subst)
            else:
                new = eg.instantiate(r.rhs, subst)
            if new is None:
                continue
            if eg.find(new) != eg.find(cid):
                eg.merge(cid, new)
                changed = True
                stats["applications"] += 1
        eg.rebuild()
        stats["iterations"] = it + 1
        if not changed:
            stats["saturated"] = True
            break
        if eg.n_nodes > node_limit:
            stats["truncated"] = True
            break
    return stats


# --------------------------------------------------------------------------
# Extraction
# --------------------------------------------------------------------------


def host_op_cost(op: str) -> float:
    """Extraction cost of one *host* (non-accelerator) op: heavy compute is
    expensive, glue is cheap — make offloading win wherever a mapping
    exists (the paper's maximize-#accelerator-ops objective)."""
    if op in ("dense", "conv2d", "lstm", "attention", "lstm_cell"):
        return 1000.0               # heavy compute left on host: expensive
    if op in ("layer_norm", "softmax", "reduce_max", "reduce_mean", "reduce_sum"):
        return 100.0
    return 2.0                      # cheap glue


def default_cost(head: Head, child_costs: Sequence[float], child_shapes=()) -> float:
    """Paper's proof-of-concept cost: maximize #accelerator ops == make
    accelerator ops cheap and plain IR compute expensive. The registry
    cost model (``core/compile.make_cost_fn``) refines the flat accel-op
    cost with per-target CostModel cycle estimates; this remains the
    shape-blind fallback."""
    base = sum(child_costs)
    if head[0] != "op":
        return base + 0.01
    op = head[1]
    if op in ir.ACCEL_OPS:
        return base + 1.0           # accelerator invocation: cheap
    return base + host_op_cost(op)


def _describe_class(eg: EGraph, cid: int, best) -> str:
    """One diagnostic line for an unresolved e-class: its candidate heads
    and, per candidate, which child e-classes never got a finite cost."""
    parts = []
    for n in eg.classes.get(cid, ()):
        label = n.head[1] if n.head[0] == "op" else f"{n.head[0]}:{n.head[1]}"
        missing = sorted({eg.find(c) for c in n.children if eg.find(c) not in best})
        parts.append(f"{label}{'(blocked by e-classes ' + str(missing) + ')' if missing else '(infinite cost)'}")
    return f"e-class {cid} [shape={eg.shape.get(cid)}]: " + ", ".join(parts)


def extract_best(eg: EGraph, root: int, cost_fn=default_cost) -> Tuple[ir.Expr, float]:
    """Bottom-up DP extraction of the min-cost expression for ``root``.

    ``cost_fn(head, child_costs, child_shapes) -> float`` may return
    ``inf`` to veto a candidate (e.g. a forbidden target's intrinsic);
    non-finite candidates never resolve an e-class. Returns the expression
    and its total cost. On failure, the error names the unresolved root
    e-class, its candidate heads, which child e-classes blocked each
    candidate, and the registered accelerator targets consulted — so a
    mapping failure is debuggable instead of a bare "no expression".
    """
    root = eg.find(root)
    best: Dict[int, Tuple[float, ENode]] = {}
    changed = True
    guard = 0
    while changed:
        changed = False
        guard += 1
        if guard > 10_000:
            raise RuntimeError("extract: no fixpoint")
        for cid, nodes in eg.classes.items():
            for n in nodes:
                cc, cs = [], []
                ok = True
                for ch in n.children:
                    ch = eg.find(ch)
                    if ch not in best:
                        ok = False
                        break
                    cc.append(best[ch][0])
                    cs.append(eg.shape.get(ch))
                if not ok:
                    continue
                c = cost_fn(n.head, cc, cs)
                if not np.isfinite(c):
                    continue
                if cid not in best or c < best[cid][0]:
                    best[cid] = (c, n)
                    changed = True
    if root not in best:
        from .ila import TARGETS  # local import: ila never imports egraph

        unresolved = [c for c in eg.classes if c not in best]
        lines = [_describe_class(eg, root, best)]
        for cid in unresolved[:8]:
            if cid != root:
                lines.append(_describe_class(eg, cid, best))
        raise RuntimeError(
            "extract: root has no finite-cost expression.\n"
            f"  resolved {len(best)}/{len(eg.classes)} e-classes; "
            f"{len(unresolved)} unresolved.\n"
            f"  root {lines[0]}\n"
            + "".join(f"  also unresolved: {l}\n" for l in lines[1:])
            + f"  registered targets consulted: {TARGETS.names()} "
            "(an op claimed by no selected target, or forbidden by the "
            "selection policy, prices to infinity)"
        )

    memo: Dict[int, ir.Expr] = {}

    def build(cid: int) -> ir.Expr:
        cid = eg.find(cid)
        if cid in memo:
            return memo[cid]
        _, n = best[cid]
        if n.head[0] == "var":
            e = ir.Var(n.head[1], tuple(n.head[2]), n.head[3])
        elif n.head[0] == "const":
            e = ir.Const(n.head[1])
        else:
            args = tuple(build(c) for c in n.children)
            e = ir.Call(n.head[1], args, tuple(n.head[2]))
        memo[cid] = e
        return e

    return build(root), best[root][0]


def extract(eg: EGraph, root: int, cost_fn=default_cost) -> ir.Expr:
    """Min-cost expression for ``root`` (see :func:`extract_best`)."""
    return extract_best(eg, root, cost_fn)[0]
