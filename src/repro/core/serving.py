"""Continuous-batching co-simulation serving (the ROADMAP's serving front
end over the simulated accelerator fleet).

`launch/serve.py --cosim` used to be a one-request-at-a-time bench: every
request drained the execution pipeline at its final assemble barrier, and
small requests never shared a vmapped dispatch. This module turns the
persistent-Executor serving mode into a real front end:

* **Request queue + scheduler.** :class:`CosimServer` owns a bounded FIFO
  of :class:`RequestHandle`\\ s and a single dispatch thread (one thread by
  design: every Executor/jit-cache touch happens there, so the engine's
  single-threaded invariants hold no matter how many clients submit).

* **Request overlap.** The scheduler runs requests through
  :meth:`~repro.core.codegen.Executor.submit_many`, which defers each
  request's terminal readback barrier + host epilogue into a
  :class:`~repro.core.codegen.Submission`, and stages the *next* request's
  host packing on the pack worker (:meth:`Executor.prepack_many`) before
  paying the previous request's barrier — so request k+1's packing
  overlaps request k's simulation tail instead of the pipeline draining
  at every request boundary. Up to ``max_inflight`` submissions ride the
  device queues at once; results still complete in submission order.

* **Cross-request coalescing.** Queued requests for the same application
  are merged — up to ``max_batch`` samples — into one ``run_many``-shaped
  dispatch, so B concurrent batch-1 requests share the vmapped simulator
  calls (the 5-15x per-fragment batching win) instead of issuing B scalar
  dispatches. Outputs are de-interleaved per request. Per-sample numerics
  are batch-composition-independent across all engines, so coalesced
  results are bit-exact vs serving the same requests serially; with
  ``batch_ladder="serving"`` the vmapped batch axis pads on a finer
  (pow2 + 3/4-pow2) ladder so merged sizes waste less replay padding.

* **Admission control.** ``queue_depth`` bounds the queue; optionally
  ``max_backlog_cycles`` bounds the CostModel-estimated cycles of accepted
  but uncompleted work (each app's per-sample cost is priced once from its
  compiled program). Requests beyond either bound are *rejected*
  immediately — the fleet degrades by shedding load, not by queueing
  unboundedly.

Reproducibility: each request's operands derive from
``default_rng((seed, request_id))`` (:func:`request_rng`), never from a
shared stream — results are identical no matter how requests interleave,
queue, or coalesce, which is what makes the bit-exact coalescing tests
(and any replay of a served trace) possible.

See ``docs/serving.md`` for usage and semantics; ``benchmarks/
bench_serving.py`` measures QPS/latency percentiles under Poisson load.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import ila, ir
from .codegen import Executor
from .ila import TARGETS
from .telemetry import TELEMETRY, MetricsRegistry

# request lifecycle states
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
REJECTED = "rejected"
CANCELLED = "cancelled"
FAILED = "failed"

# rejection reasons (RequestHandle.reject_reason)
REJECT_QUEUE_FULL = "queue_full"
REJECT_BACKLOG = "backlog"
REJECT_SHUTDOWN = "shutdown"


#: synthetic per-request lanes in the exported trace (rid mod _REQ_LANES):
#: request-lifecycle spans overlap in time, so they render on their own
#: timelines instead of breaking the dispatch thread's flame nesting
_REQ_LANES = 16

_NULL_CTX = contextlib.nullcontext()


def _group_trace(group: Sequence["RequestHandle"]) -> str:
    """The trace id shared by every span of one coalesced dispatch group —
    ``req-3+4+5`` — so searching any member's ``req-<id>`` in Perfetto
    finds the whole correlated flame."""
    return "req-" + "+".join(str(h.id) for h in group)


def request_rng(seed: int, request_id: int) -> np.random.Generator:
    """The operand stream for one request: seeded by ``(seed, request_id)``
    so a request's inputs are a pure function of its id — independent of
    submission interleaving, queue order, and coalescing decisions."""
    return np.random.default_rng((int(seed), int(request_id)))


@dataclasses.dataclass
class ServedApp:
    """One application the server can execute: its extracted program, the
    parameter environment shared by every request, the input Var's shape,
    and the CostModel-estimated accelerator cycles one sample costs (the
    unit of admission backpressure)."""

    name: str
    program: ir.Expr
    params: Dict[str, Any]
    xshape: Tuple[int, ...]
    est_cycles_per_sample: float


class RequestHandle:
    """A submitted request: its environments, lifecycle status, and — once
    served — one output array per sample. Thread-safe: the submitting
    thread blocks in :meth:`result` until the dispatch thread completes
    (or rejects/cancels) the request."""

    def __init__(self, request_id: int, app: str, envs: List[Dict[str, Any]]):
        self.id = request_id
        self.app = app
        self.envs = envs
        self.status = QUEUED
        self.outputs: Optional[List[np.ndarray]] = None
        self.reject_reason: Optional[str] = None
        self.error: Optional[BaseException] = None
        self.est_cycles = 0.0
        self.t_submit = time.perf_counter()
        self.t_start: Optional[float] = None
        self.t_done: Optional[float] = None
        self.coalesced_with = 0  # other requests sharing the dispatch
        self._event = threading.Event()

    # -- caller side ----------------------------------------------------
    @property
    def rejected(self) -> bool:
        return self.status == REJECTED

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def result(self, timeout: Optional[float] = None) -> List[np.ndarray]:
        """Block until served and return one output per sample. Raises on
        rejection/cancellation/failure."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.id} not done after {timeout}s")
        if self.status == DONE:
            return self.outputs
        if self.status == FAILED and self.error is not None:
            raise self.error
        raise RuntimeError(
            f"request {self.id} {self.status}"
            + (f" ({self.reject_reason})" if self.reject_reason else "")
        )

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.t_submit

    # -- server side ----------------------------------------------------
    def _finish(self, status: str, reason: Optional[str] = None,
                error: Optional[BaseException] = None) -> None:
        self.status = status
        self.reject_reason = reason
        self.error = error
        self._event.set()


class CosimServer:
    """Continuous-batching serving front end over one persistent
    :class:`~repro.core.codegen.Executor` (see module docstring).

    Typical use::

        server = CosimServer(engine="pipelined", queue_depth=32)
        server.add_program("resmlp", program, params)   # or add_app("resmlp")
        server.start(warmup=1)                          # compiled warmup + calibration
        h = server.submit("resmlp", batch=4)
        outs = h.result()
        server.close(drain=True)

    ``coalesce=False`` / ``overlap=False`` select the serial
    one-request-at-a-time and draining-pipeline baselines the serving
    benchmark compares against; both knobs only re-schedule work — served
    results are bit-exact across every setting (deterministic engines).
    """

    def __init__(
        self,
        *,
        engine: Optional[str] = None,
        devices_per_target=1,
        pipeline_chunk: int = 4,
        queue_depth: int = 16,
        max_batch: int = 16,
        coalesce: bool = True,
        overlap: bool = True,
        max_inflight: int = 2,
        max_backlog_cycles: Optional[float] = None,
        seed: int = 0,
        batch_ladder: str = "serving",
        executor: Optional[Executor] = None,
    ):
        self.executor = executor or Executor(
            "ila", engine=engine, devices_per_target=devices_per_target,
            pipeline_chunk=pipeline_chunk,
        )
        self.queue_depth = int(queue_depth)
        self.max_batch = max(1, int(max_batch))
        self.coalesce = bool(coalesce)
        self.overlap = bool(overlap)
        self.max_inflight = max(1, int(max_inflight))
        self.max_backlog_cycles = max_backlog_cycles
        self.seed = int(seed)
        self.batch_ladder = batch_ladder
        self._apps: Dict[str, ServedApp] = {}
        self._queue: "deque[RequestHandle]" = deque()
        self._cond = threading.Condition()
        self._id_lock = threading.Lock()
        self._next_id = 0
        self._stopping = False
        self._thread: Optional[threading.Thread] = None
        self._prev_ladder: Optional[str] = None
        self._inflight_cycles = 0.0
        self._latencies: List[float] = []  # exact percentiles for summary()
        # serving statistics live in this server's scoped metrics registry
        # (attached to the process TELEMETRY singleton; summary() is a thin
        # view). Counters/gauges are always on — they replace the previous
        # ad-hoc dict accounting at the same cost; spans are enabled-gated.
        self.metrics = TELEMETRY.attach(MetricsRegistry(scope="serving"))
        self._m_served = self.metrics.counter("serving.served")
        self._m_batches = self.metrics.counter("serving.batches")
        self._m_submitted = self.metrics.counter("serving.submitted")
        self._m_queue = self.metrics.gauge("serving.queue_depth")
        self._m_backlog = self.metrics.gauge("serving.backlog_cycles")
        self._m_inflight = self.metrics.gauge("serving.inflight_batch")
        self._m_inflight_subs = self.metrics.gauge("serving.inflight_submissions")
        self._m_coalesced_max = self.metrics.gauge("serving.coalesced_max")
        self._m_coalesced_max.set(1.0)
        self._m_latency = self.metrics.histogram("serving.latency_ms")
        # predicted-vs-actual request pricing: actual served microseconds
        # over admission-control est_cycles (1.0 == perfectly priced once
        # the CostModel is latency-calibrated)
        self._m_drift = self.metrics.histogram("serving.drift_ratio")
        self._m_rejected: Dict[str, Any] = {}

    # -- application registry -------------------------------------------
    def add_program(self, name: str, program: ir.Expr,
                    params: Dict[str, Any]) -> ServedApp:
        """Register an already-extracted program (input Var must be named
        ``x``; every other free Var bound by ``params``)."""
        xshape = next(
            v.shape for v in ir.postorder(program)
            if isinstance(v, ir.Var) and v.name == "x"
        )
        app = ServedApp(
            name, program, dict(params), tuple(xshape),
            self._estimate_cycles(program, params, xshape),
        )
        self._apps[name] = app
        return app

    def add_app(self, name: str, **compile_kwargs) -> ServedApp:
        """Register a bundled application by name: build it, run flexible
        matching once, keep the extracted program for every request."""
        from . import apps as app_registry
        from .compile import compile_program

        by_name = {k.lower(): v for k, v in app_registry.APPLICATIONS.items()}
        if name.lower() not in by_name:
            raise KeyError(
                f"unknown application {name!r}; "
                f"available: {sorted(app_registry.APPLICATIONS)}"
            )
        builder, _dsl = by_name[name.lower()]
        expr, params = builder()
        res = compile_program(expr, **compile_kwargs)
        return self.add_program(name.lower(), res.program, params)

    def _estimate_cycles(self, program: ir.Expr, params: Dict[str, Any],
                         xshape: Tuple[int, ...]) -> float:
        """Price one sample of the program: CostModel-estimated cycles
        summed over its accelerator calls (0 for ops without a model) —
        the per-sample unit ``max_backlog_cycles`` backpressure is
        denominated in."""
        shape_env = {k: tuple(np.shape(v)) for k, v in params.items()}
        shape_env["x"] = tuple(xshape)
        total = 0.0
        for node in ir.postorder(program):
            if not (isinstance(node, ir.Call) and node.op in ir.ACCEL_OPS):
                continue
            try:
                target, _intr = TARGETS.intrinsic(node.op)
            except KeyError:
                continue
            model = target.cost_model
            if model is None or not model.covers(node.op):
                continue
            arg_shapes = [ir.infer_shape(a, shape_env) for a in node.args]
            est = model.estimate(node.op, dict(node.attrs), arg_shapes)
            if est is not None:
                total += float(est.cycles)
        return total

    # -- client side -----------------------------------------------------
    def request_envs(self, app: str, request_id: int,
                     batch: int = 1) -> List[Dict[str, Any]]:
        """The exact environments request ``request_id`` serves: params +
        per-sample operands from :func:`request_rng`. Public so serial
        baselines and replay harnesses can reconstruct any request's
        inputs bit-for-bit."""
        a = self._apps[app]
        rng = request_rng(self.seed, request_id)
        return [
            dict(a.params, x=rng.standard_normal(a.xshape).astype(np.float32))
            for _ in range(batch)
        ]

    def submit(self, app: str, batch: int = 1,
               envs: Optional[List[Dict[str, Any]]] = None) -> RequestHandle:
        """Submit one request (thread-safe). Operands are drawn from the
        request's own seeded stream unless explicit ``envs`` are passed.
        Returns immediately; a rejected handle has ``status ==
        "rejected"`` and a ``reject_reason``."""
        if app not in self._apps:
            raise KeyError(f"unknown app {app!r}; registered: {sorted(self._apps)}")
        with self._id_lock:
            rid = self._next_id
            self._next_id += 1
        if envs is None:
            envs = self.request_envs(app, rid, batch)
        h = RequestHandle(rid, app, envs)
        h.est_cycles = self._apps[app].est_cycles_per_sample * len(envs)
        with self._cond:
            if self._stopping:
                h._finish(REJECTED, REJECT_SHUTDOWN)
            elif len(self._queue) >= self.queue_depth:
                h._finish(REJECTED, REJECT_QUEUE_FULL)
            elif (
                self.max_backlog_cycles is not None
                and self._backlog_cycles() + h.est_cycles > self.max_backlog_cycles
            ):
                h._finish(REJECTED, REJECT_BACKLOG)
            else:
                self._queue.append(h)
                self._cond.notify()
            if h.status == REJECTED:
                ctr = self._m_rejected.get(h.reject_reason)
                if ctr is None:
                    ctr = self._m_rejected[h.reject_reason] = (
                        self.metrics.counter("serving.rejected",
                                             reason=h.reject_reason)
                    )
                ctr.inc()
            self._m_submitted.inc()
            self._m_queue.set(len(self._queue))
            self._m_backlog.set(self._backlog_cycles())
        if TELEMETRY.enabled:
            TELEMETRY.record_span(
                "serving.admission", h.t_submit, time.perf_counter(),
                trace_id=f"req-{rid}", rid=rid, app=app,
                outcome=h.status if h.status == REJECTED else "accepted",
                reason=h.reject_reason, est_cycles=round(h.est_cycles, 1))
        return h

    def _backlog_cycles(self) -> float:
        """Estimated cycles of accepted-but-unfinished work (queued +
        in-flight). Called under ``_cond``."""
        return self._inflight_cycles + sum(h.est_cycles for h in self._queue)

    # -- dispatch thread -------------------------------------------------
    def _overlap_active(self) -> bool:
        return self.overlap and self.executor.engine in ("pipelined", "fused")

    def _next_group(self, wait: bool = True) -> Optional[List[RequestHandle]]:
        """Dequeue the head request plus — under coalescing — every queued
        same-app request that fits in ``max_batch`` samples (FIFO among
        the merged; other apps keep their places). ``wait=False`` returns
        None immediately on an empty queue (the dispatch loop has in-flight
        work to finalize instead); ``wait=True`` blocks until a request
        arrives, returning None only at shutdown with an empty queue."""
        with self._cond:
            while wait and not self._queue and not self._stopping:
                self._cond.wait(timeout=0.05)
            if not self._queue:
                return None
            t0 = time.perf_counter()
            first = self._queue.popleft()
            group = [first]
            if self.coalesce:
                n = len(first.envs)
                taken = []
                for h in self._queue:
                    if h.app == first.app and n + len(h.envs) <= self.max_batch:
                        taken.append(h)
                        n += len(h.envs)
                for h in taken:
                    self._queue.remove(h)
                group += taken
            self._inflight_cycles += sum(h.est_cycles for h in group)
            self._m_queue.set(len(self._queue))
            self._m_backlog.set(self._backlog_cycles())
        if TELEMETRY.enabled:
            TELEMETRY.record_span(
                "serving.coalesce", t0, time.perf_counter(),
                trace_id=_group_trace(group), app=first.app,
                requests=len(group),
                samples=sum(len(h.envs) for h in group))
        return group

    def _loop(self) -> None:
        inflight: "deque[Tuple[Any, List[RequestHandle]]]" = deque()
        while True:
            # only block for arrivals when nothing is in flight: with work
            # pending, an empty queue means finalize now (nothing to overlap)
            group = self._next_group(wait=not inflight)
            if group is None:
                if inflight:
                    self._finalize(*inflight.popleft())
                    continue
                with self._cond:
                    if self._stopping and not self._queue:
                        return
                continue
            t_start = time.perf_counter()
            for h in group:
                h.status = RUNNING
                h.t_start = t_start
                h.coalesced_with = len(group) - 1
            a = self._apps[group[0].app]
            envs = [e for h in group for e in h.envs]
            enabled = TELEMETRY.enabled
            if enabled:
                grp = _group_trace(group)
                for h in group:
                    # queue wait straddles threads (submit thread -> here):
                    # recorded from explicit endpoints, on a synthetic
                    # per-request lane so overlapping waits don't fight for
                    # the dispatch thread's flame nesting
                    TELEMETRY.record_span(
                        "serving.queue_wait", h.t_submit, t_start,
                        trace_id=grp, track=f"req:{h.id % _REQ_LANES}",
                        rid=h.id)
            self._m_inflight.set(len(envs))
            try:
                if self._overlap_active():
                    # stage the new request's leading-node packing *before*
                    # paying any pending readback barrier: the pack worker
                    # fills the barrier gap instead of idling. The group
                    # trace id is bound thread-locally here so the executor
                    # spans this triggers (pipeline.pack on the pack worker,
                    # pipeline.dispatch_group, the deferred readback) stay
                    # correlated with this group's serving spans.
                    with TELEMETRY.trace(grp) if enabled else _NULL_CTX:
                        with TELEMETRY.span("serving.prepack",
                                            samples=len(envs)):
                            pre = self.executor.prepack_many(a.program, envs)
                        while len(inflight) >= self.max_inflight:
                            self._finalize(*inflight.popleft())
                        with TELEMETRY.span("serving.dispatch", app=a.name,
                                            requests=len(group),
                                            samples=len(envs)):
                            sub = self.executor.submit_many(
                                a.program, envs, prepack=pre)
                    inflight.append((sub, group))
                    self._m_inflight_subs.set(len(inflight))
                else:
                    # draining baseline: run to the assemble barrier and
                    # materialize before the next request is even dequeued
                    with TELEMETRY.trace(grp) if enabled else _NULL_CTX:
                        with TELEMETRY.span("serving.dispatch", app=a.name,
                                            requests=len(group),
                                            samples=len(envs)):
                            outs = self.executor.run_many(a.program, envs)
                    self._complete(group, outs)
            except Exception as e:  # a failed request must not kill the server
                for h in group:
                    self._retire(h)
                    h._finish(FAILED, error=e)

    def _finalize(self, sub, group: List[RequestHandle]) -> None:
        try:
            # sub.result() is the deferred assemble barrier: the simulation
            # tail + readback of an overlapped submission is paid here
            with TELEMETRY.span("serving.readback",
                                trace_id=(_group_trace(group)
                                          if TELEMETRY.enabled else None)):
                outs = sub.result()
            self._complete(group, outs)
        except Exception as e:
            for h in group:
                self._retire(h)
                h._finish(FAILED, error=e)

    def _complete(self, group: List[RequestHandle], outs: List[Any]) -> None:
        enabled = TELEMETRY.enabled
        t0 = time.perf_counter()
        o = 0
        for h in group:
            n = len(h.envs)
            h.outputs = [np.asarray(v) for v in outs[o:o + n]]
            o += n
            h.t_done = time.perf_counter()
            self._retire(h)
            h._finish(DONE)
        if enabled:
            grp = _group_trace(group)
            TELEMETRY.record_span(
                "serving.deinterleave", t0, time.perf_counter(),
                trace_id=grp, requests=len(group))
        with self._cond:
            self._m_served.inc(len(group))
            self._m_batches.inc()
            self._m_coalesced_max.set_max(len(group))
            self._latencies += [h.latency_s for h in group]
        for h in group:
            lat = h.latency_s
            self._m_latency.observe(lat * 1e3)
            if h.est_cycles > 0 and h.t_start is not None:
                # request drift: measured service microseconds over the
                # est_cycles admission control priced the request at
                self._m_drift.observe(
                    (h.t_done - h.t_start) * 1e6 / h.est_cycles)
            if enabled:
                TELEMETRY.record_span(
                    "serving.request", h.t_submit, h.t_done, trace_id=grp,
                    track=f"req:{h.id % _REQ_LANES}", rid=h.id, app=h.app,
                    coalesced_with=h.coalesced_with,
                    latency_ms=round(lat * 1e3, 3))

    def _retire(self, h: RequestHandle) -> None:
        with self._cond:
            self._inflight_cycles = max(
                0.0, self._inflight_cycles - h.est_cycles
            )

    # -- lifecycle -------------------------------------------------------
    def start(self, warmup: int = 1, warm_batch: Optional[int] = None) -> "CosimServer":
        """Start the dispatch thread. ``warmup`` > 0 first runs every
        registered app on the synchronous compiled engine (filling
        fragment caches AND recording the exact per-group timings that
        calibrate each target's wall-clock CostModel), then one trace
        request per app on the serving engine, then resets the stats so
        measured serving starts clean. Also switches the vmapped batch
        axis to the serving bucket ladder (restored by :meth:`close`)."""
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._prev_ladder = ila.set_batch_ladder(self.batch_ladder)
        if warmup > 0:
            ex = self.executor
            engine = ex.engine
            wb = warm_batch or self.max_batch
            rng = np.random.default_rng(self.seed)  # warmup-only stream
            warm_envs = {
                name: [
                    dict(a.params,
                         x=rng.standard_normal(a.xshape).astype(np.float32))
                    for _ in range(wb)
                ]
                for name, a in self._apps.items()
            }
            ex.engine = "compiled"
            for name, a in self._apps.items():
                for _ in range(warmup):
                    ex.run_many(a.program, warm_envs[name])
            ex.calibrate_from_timings()
            ex.engine = engine
            if engine != "compiled":
                # trace the serving engine's own vmap shapes (and fused
                # runners) so measured requests start warm
                for name, a in self._apps.items():
                    ex.submit_many(a.program, warm_envs[name]).result()
            ex.reset_stats()
        self._thread = threading.Thread(
            target=self._loop, name="repro-serve-dispatch", daemon=True
        )
        self._thread.start()
        return self

    def close(self, drain: bool = True, timeout: Optional[float] = None) -> None:
        """Stop serving. ``drain=True`` (default) serves every accepted
        request before the dispatch thread exits — accepted work is never
        dropped; ``drain=False`` cancels queued requests (in-flight
        submissions still complete). Restores the batch ladder."""
        with self._cond:
            self._stopping = True
            if not drain:
                while self._queue:
                    h = self._queue.popleft()
                    h._finish(CANCELLED)
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        if self._prev_ladder is not None:
            ila.set_batch_ladder(self._prev_ladder)
            self._prev_ladder = None

    # -- observability ---------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        """Serving statistics: served/rejected counts, dispatch batches,
        coalescing reach, and latency percentiles (ms) over completed
        requests. A thin view over the server's metrics registry (the
        ``serving.*`` names documented in docs/observability.md)."""
        with self._cond:
            lat = np.asarray(self._latencies, dtype=np.float64)
            served = int(self._m_served.value)
            batches = int(self._m_batches.value)
            out: Dict[str, Any] = {
                "served": served,
                "batches": batches,
                "coalesced_max": int(self._m_coalesced_max.value),
                "mean_batch": (served / batches) if batches else 0.0,
                "rejected": {r: int(c.value)
                             for r, c in self._m_rejected.items()},
                "queued": len(self._queue),
            }
        if lat.size:
            out.update(
                p50_ms=float(np.percentile(lat, 50) * 1e3),
                p95_ms=float(np.percentile(lat, 95) * 1e3),
                p99_ms=float(np.percentile(lat, 99) * 1e3),
                mean_ms=float(lat.mean() * 1e3),
            )
        return out


def percentiles_ms(latencies_s: Sequence[float]) -> Dict[str, float]:
    """p50/p95/p99/mean (milliseconds) of a latency sample — shared by the
    load generator and the serve CLI."""
    lat = np.asarray(list(latencies_s), dtype=np.float64)
    if lat.size == 0:
        return {"p50_ms": float("nan"), "p95_ms": float("nan"),
                "p99_ms": float("nan"), "mean_ms": float("nan")}
    return {
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p95_ms": float(np.percentile(lat, 95) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "mean_ms": float(lat.mean() * 1e3),
    }
