"""Benchmark driver: one function per paper table + framework benches.

Prints ``name,us_per_call,derived`` CSV (plus human-readable tables) and
writes the machine-readable ``BENCH_cosim.json`` (see benchmarks/_bench_io)
so the co-sim perf trajectory — steady-state throughput, cold-vs-warm,
pipelined-vs-sync speedup, batch crossover — is tracked across PRs.
Usage: PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import os
import sys


def main() -> None:
    fast = "--fast" in sys.argv
    if fast:
        os.environ.setdefault("REPRO_TABLE2_N", "5")
        os.environ.setdefault("REPRO_TABLE4_N", "10")
        os.environ.setdefault("REPRO_TABLE4_STEPS", "150")

    from benchmarks import (bench_campaign, bench_extraction, bench_kernels,
                            bench_sim_speed, roofline_report, table1_matching,
                            table2_mapping_validation, table3_formal,
                            table4_cosim)

    rows = []
    rows += table1_matching.run()
    rows += bench_extraction.run()
    rows += table2_mapping_validation.run()
    rows += table3_formal.run()
    rows += bench_sim_speed.run()
    rows += bench_campaign.run()
    rows += bench_kernels.run()
    rows += roofline_report.run()
    rows += table4_cosim.run()

    print("\n== CSV ==")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},\"{derived}\"")

    from benchmarks._bench_io import write_bench_json

    path = write_bench_json(rows, fresh=True)
    print(f"\nwrote {len(rows)} rows to {path}")


if __name__ == "__main__":
    main()
