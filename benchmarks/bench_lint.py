"""Static-verifier cost: lint latency and what tier 0 saves a campaign.

The static verifier (``repro.core.ilalint``) is the campaign's tier 0: it
runs **zero simulated commands**, so its only costs are (a) tracing every
``Instruction.update`` to a jaxpr once per ILA (cached process-wide) and
(b) classifying numpy command streams. This bench measures both sides of
that bargain:

* ``lint_cold`` / ``lint_warm`` — full-registry lint (all three passes,
  every registered target) with fresh vs cached jaxpr effect summaries.
  The warm number is what every later campaign pays for tier 0.
* ``campaign_protocol_escalate`` / ``campaign_protocol_full`` — an
  apps-free protocol-fault campaign (``decode_alias`` + ``cmd_reorder``)
  under ``ladder="escalate"`` (static detections skip every simulated
  tier) vs ``ladder="full"`` (every tier simulates regardless), both on
  warm golden caches. The gap is the simulation time tier 0 removes from
  the ladder for the fault classes it owns.

Run as __main__ the rows merge into BENCH_cosim.json (benchmarks/_bench_io).
"""
from __future__ import annotations

import time


def run():
    import repro.accel  # noqa: F401  (registers the bundled targets)
    from repro.core import ilalint
    from repro.core.campaign import run_campaign
    from repro.core.ila import TARGETS

    n_targets = len(TARGETS.names())

    print(f"\n== static-verifier latency ({n_targets} registered targets, "
          "3 passes, zero simulation) ==")
    ilalint._EFFECTS_CACHE.clear()
    t0 = time.perf_counter()
    cold = ilalint.lint_registry()
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = ilalint.lint_registry()
    warm_s = time.perf_counter() - t0
    n_cold = sum(len(v) for v in cold.values())
    n_warm = sum(len(v) for v in warm.values())
    assert n_cold == n_warm, "lint result changed between cold and warm runs"
    print(f"cold: {cold_s * 1e3:.1f} ms (fresh jaxpr traces), "
          f"warm: {warm_s * 1e3:.1f} ms (cached effects, "
          f"{cold_s / warm_s:.1f}x); {n_warm} results")
    rows = [
        ("lint_cold", cold_s / n_targets * 1e6,
         f"full 3-pass lint, fresh jaxpr effect traces, per target "
         f"({n_targets} targets, {n_cold} results)"),
        ("lint_warm", warm_s / n_targets * 1e6,
         f"full 3-pass lint, cached effects, per target "
         f"({cold_s / warm_s:.1f}x vs cold)"),
    ]

    kwargs = dict(
        targets=("flexasr", "vecunit", "hlscnn"),
        faults=("identity", "decode_alias", "cmd_reorder"),
        apps=(),                      # protocol-fault ladder cost only
        engine="compiled", devices_per_target=1,
        op_samples=1, vt2_n=2, stat_calib_seeds=0,
    )
    print("\n== protocol-fault ladder cost, escalate (tier-0 skips) "
          "vs full (every tier simulates) ==")
    run_campaign(ladder="full", **kwargs)   # warm the golden caches (untimed)
    t0 = time.perf_counter()
    esc = run_campaign(ladder="escalate", **kwargs)
    esc_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    full = run_campaign(ladder="full", **kwargs)
    full_s = time.perf_counter() - t0
    n = len(esc.reports)
    n_static = sum(1 for r in esc.reports if r.detected_at == "static")
    print(f"escalate: {n} mutants in {esc_s:.1f}s "
          f"({n_static} detected at tier 0, simulated tiers skipped)")
    print(f"full:     {n} mutants in {full_s:.1f}s "
          f"({full_s / esc_s:.2f}x vs escalate)")
    rows += [
        ("campaign_protocol_escalate", esc_s / n * 1e6,
         f"{n} protocol-fault mutants, escalate ladder: {n_static} "
         "static-tier detections skip all simulated tiers"),
        ("campaign_protocol_full", full_s / n * 1e6,
         f"same mutants, full ladder ({full_s / esc_s:.2f}x vs escalate): "
         "the simulation cost tier 0 removes for protocol faults"),
    ]
    return rows


if __name__ == "__main__":
    try:
        from benchmarks._bench_io import write_bench_json
    except ImportError:  # invoked as a script: benchmarks/ itself is on sys.path
        from _bench_io import write_bench_json

    rows = run()
    path = write_bench_json(rows)
    print(f"wrote {len(rows)} rows to {path}")
