"""ILA simulator speed (the paper's "30x faster than RTL simulation" claim).

No RTL offline, so we benchmark the simulator tiers we do have on the
FlexASR LinearLayer fragment:

  eager      — per-command reference interpretation (the slow baseline)
  jit scan   — full command stream re-derived, re-packed and scanned per
               invocation (the pre-fragment-compiler behavior; the analogue
               of ILAng's generated simulator run from scratch each time)
  compiled   — fragment-compiler fast path: cached setup state, vectorized
               data packing, unrolled tail (steady state; cold = first
               invocation for a parameter set, including setup simulation)
  batched    — the same, vmapped over a stack of data streams

Timing methodology: ``time.perf_counter``, device results forced with
``block_until_ready()`` inside the timed region, per-iteration min/median
reported. Also reported: fragment-cache hit/miss counts and jit trace
counts (retraces stay bounded — power-of-two bucketing for streams, one
compiled executor per data-stream signature).
"""
from __future__ import annotations

import statistics
import time

import jax
import numpy as np

from repro.accel import flexasr as fa


def _force(r):
    if hasattr(r, "block_until_ready"):
        r.block_until_ready()
    elif isinstance(r, dict):
        for v in r.values():
            if hasattr(v, "block_until_ready"):
                v.block_until_ready()
    return r


def _time(fn, n=10, warmup=1):
    for _ in range(warmup):
        _force(fn())
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        _force(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts), statistics.median(ts)


def batch_crossover(frag, make_data, sizes=(1, 2, 4, 8, 16, 32), n=8):
    """Batch-vs-sequential break-even: per-sample time of B sequential
    ``frag.run`` calls vs one vmapped ``frag.run_batch`` over B streams,
    per batch size. Returns (rows, crossover_B or None)."""
    rows = []
    crossover = None
    for B in sizes:
        datas = [make_data() for _ in range(B)]
        seq_min, _ = _time(lambda: [_force(frag.run(d)) for d in datas], n=n)
        bat_min, _ = _time(lambda: frag.run_batch(datas), n=n)
        seq_ps, bat_ps = seq_min / B, bat_min / B
        rows.append((B, seq_ps, bat_ps))
        if crossover is None and bat_ps < seq_ps:
            crossover = B
    return rows, crossover


def run():
    print("\n== ILA simulator speed (fragment compiler vs jit scan vs eager) ==")
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 128)).astype(np.float32)
    w = (rng.standard_normal((64, 128)) * 0.1).astype(np.float32)
    b = np.zeros((64,), np.float32)
    cmds, rd = fa.build_linear_fragment(x, w, b)
    xs = [rng.standard_normal((64, 128)).astype(np.float32) for _ in range(8)]

    # steady-state jit-scan tier: the pre-fragment-compiler behavior —
    # weight/config commands re-derived per invocation (cache=False), the
    # full stream re-packed, then scanned
    def seed_path():
        frag = fa.linear_fragment(w, b, cache=False)
        c = frag.full_commands(fa.pack_linear_data(frag, x))
        return fa.read_full(fa.flexasr.simulate_jit(c))

    jit_min, jit_med = _time(seed_path, n=5)

    # compiled tier, cold: fresh fragment (setup stream simulated) each time
    def cold_path():
        frag = fa.linear_fragment(w, b, cache=False)
        return fa.read_full(frag.run(fa.pack_linear_data(frag, x)))

    cold_min, cold_med = _time(cold_path, n=3)

    # compiled tier, steady state: cached setup, only data re-packed
    frag = fa.linear_fragment(w, b)
    frag.setup_state()

    def warm_path():
        return fa.read_full(frag.run(fa.pack_linear_data(frag, x)))

    warm_min, warm_med = _time(warm_path, n=20)

    # batched tier: 8 samples through one vmapped simulator call
    datas = [fa.pack_linear_data(frag, xi) for xi in xs]

    def batch_path():
        return jax.vmap(fa.read_full)(frag.run_batch(datas))

    batch_min, batch_med = _time(batch_path, n=10)
    per_sample_min = batch_min / len(xs)

    t0 = time.perf_counter()
    n_eager = 2
    for _ in range(n_eager):
        st = fa.flexasr.simulate(cmds)
    eager = (time.perf_counter() - t0) / n_eager

    # bit-exactness of every tier vs the eager reference
    ref = np.asarray(rd(fa.flexasr.simulate(cmds)))
    out_warm = np.asarray(warm_path())[:64, :64]
    out_batch = np.asarray(batch_path())[0][:64, :64]
    ref_b0 = np.asarray(
        fa.read_full(fa.flexasr.simulate(frag.full_commands(datas[0])))
    )[:64, :64]
    exact = np.array_equal(ref, out_warm) and np.array_equal(ref_b0, out_batch)

    speedup = jit_min / warm_min
    print(f"fragment: {len(cmds)} commands (FlexASR LinearLayer 64x128->64)")
    print(f"eager reference:    {eager*1e3:8.1f} ms/invocation")
    print(f"jit scan (seed):    {jit_min*1e3:8.1f} ms min / {jit_med*1e3:.1f} ms median")
    print(f"compiled cold:      {cold_min*1e3:8.1f} ms min / {cold_med*1e3:.1f} ms median")
    print(f"compiled steady:    {warm_min*1e3:8.1f} ms min / {warm_med*1e3:.1f} ms median"
          f"   ({speedup:.1f}x vs jit scan)")
    print(f"batched (8/call):   {per_sample_min*1e3:8.1f} ms/sample min")
    print(f"bit-exact vs eager reference: {exact}")
    print(f"flexasr target caches: {fa.TARGET.cache_info()}")
    assert exact, "compiled tiers must match the eager reference bit-for-bit"

    # batch-vs-sequential break-even (the ROADMAP claim, measured): at which
    # batch size does one vmapped run_batch beat B sequential frag.run calls?
    print("\n-- batch vs sequential crossover (FlexASR linear data streams) --")
    print(f"{'B':>4s} {'seq us/sample':>14s} {'batch us/sample':>16s} {'winner':>8s}")
    cross_rows, crossover = batch_crossover(
        frag, lambda: fa.pack_linear_data(
            frag, rng.standard_normal((64, 128)).astype(np.float32))
    )
    for B, seq_ps, bat_ps in cross_rows:
        winner = "batch" if bat_ps < seq_ps else "seq"
        print(f"{B:4d} {seq_ps*1e6:14.1f} {bat_ps*1e6:16.1f} {winner:>8s}")
    print("crossover: "
          + (f"vmapped batching wins from B={crossover} on this backend"
             if crossover is not None else
             "batching never wins on this backend (dispatch already amortized)"))

    return [
        ("sim_batch_crossover", float(crossover or 0),
         f"batch wins from B={crossover}" if crossover else "no crossover <= 32"),
        ("sim_steady_compiled", warm_min * 1e6, f"speedup={speedup:.1f}x"),
        ("sim_cold_compiled", cold_min * 1e6, "includes setup sim"),
        ("sim_batched_per_sample", per_sample_min * 1e6, "batch of 8"),
        ("sim_speed_jit", jit_min * 1e6, f"n_cmds={len(cmds)}"),
        ("sim_speed_eager", eager * 1e6, f"n_cmds={len(cmds)}"),
    ]


if __name__ == "__main__":
    run()
