"""ILA simulator speed (the paper's "30x faster than RTL simulation" claim).

No RTL offline, so we benchmark the simulator tiers we do have on the
FlexASR LinearLayer fragment:

  eager      — per-command reference interpretation (the slow baseline)
  jit scan   — full command stream re-derived, re-packed and scanned per
               invocation (the pre-fragment-compiler behavior; the analogue
               of ILAng's generated simulator run from scratch each time)
  compiled   — fragment-compiler fast path: cached setup state, vectorized
               data packing, unrolled tail (steady state; cold = first
               invocation for a parameter set, including setup simulation)
  batched    — the same, vmapped over a stack of data streams
  pipelined  — the Executor's async engine: host packing of chunk k+1 (pack
               worker thread) overlaps JAX simulation of chunk k, results
               materialize at assemble barriers (end-to-end co-sim eval on
               the pack-heavy FlexASR LSTM workload, vs the synchronous
               compiled engine; asserts bit-exact parity vs compiled AND
               the eager reference first)
  fused      — the fast-path tier (docs/simulation.md): the whole fragment
               batch lowered to one fused bulk-write + compute + readout
               kernel instead of the per-command dynamic_update_slice
               chain; tolerance-validated against the compiled oracle
               before timing, then measured both per-fragment (steady-state
               + crossover vs the compiled batched tier) and end-to-end
               (LSTM co-sim eval vs sync; the >= 1.3x acceptance row)
  mesh       — ``run_data_batch`` with its batch axis sharded over a
               ``jax.sharding.Mesh`` of the host's devices (skipped on
               single-device hosts; start with
               XLA_FLAGS=--xla_force_host_platform_device_count=N to try)

Timing methodology: ``time.perf_counter``, device results forced with
``block_until_ready()`` inside the timed region, per-iteration min/median
reported. Also reported: fragment-cache hit/miss counts and jit trace
counts (retraces stay bounded — power-of-two bucketing for streams, one
compiled executor per data-stream signature). Run as __main__ this writes
its rows into BENCH_cosim.json (benchmarks/_bench_io).
"""
from __future__ import annotations

import statistics
import time

import jax
import numpy as np

from repro.accel import flexasr as fa


def _force(r):
    if hasattr(r, "block_until_ready"):
        r.block_until_ready()
    elif isinstance(r, dict):
        for v in r.values():
            if hasattr(v, "block_until_ready"):
                v.block_until_ready()
    return r


def _time(fn, n=10, warmup=1):
    for _ in range(warmup):
        _force(fn())
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        _force(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts), statistics.median(ts)


def batch_crossover(frag, make_data, sizes=(1, 2, 4, 8, 16, 32), n=8):
    """Batch-vs-sequential break-even: per-sample time of B sequential
    ``frag.run`` calls vs one vmapped ``frag.run_batch`` over B streams,
    per batch size. Returns (rows, crossover_B or None)."""
    rows = []
    crossover = None
    for B in sizes:
        datas = [make_data() for _ in range(B)]
        seq_min, _ = _time(lambda: [_force(frag.run(d)) for d in datas], n=n)
        bat_min, _ = _time(lambda: frag.run_batch(datas), n=n)
        seq_ps, bat_ps = seq_min / B, bat_min / B
        rows.append((B, seq_ps, bat_ps))
        if crossover is None and bat_ps < seq_ps:
            crossover = B
    return rows, crossover


def fused_crossover(frag, runner, read, sizes=(1, 2, 4, 8, 16, 32), n=8,
                    make_data=None):
    """Fused-vs-compiled break-even: per-sample time of the compiled batched
    tier (vmapped ``frag.run_batch`` + readout) vs the fused runner's single
    bulk-write + compute + readout kernel, per batch size. Returns
    (rows, crossover_B or None — the first B where fused wins)."""
    rows = []
    crossover = None
    for B in sizes:
        datas = [make_data() for _ in range(B)]
        comp_min, _ = _time(lambda: jax.vmap(read)(frag.run_batch(datas)), n=n)
        fus_min, _ = _time(lambda: runner.run(datas), n=n)
        comp_ps, fus_ps = comp_min / B, fus_min / B
        rows.append((B, comp_ps, fus_ps))
        if crossover is None and fus_ps < comp_ps:
            crossover = B
    return rows, crossover


def pipelined_eval_speed(n_eval=64, batch=32, reps=5):
    """End-to-end co-sim eval of the pack-heavy FlexASR LSTM application:
    pipelined and fused engines vs the synchronous-compiled engine.
    Bit-exactness (pipelined) / declared-tolerance parity (fused) asserted
    against compiled — and compiled against the eager per-command
    reference — before timing. Returns benchmark rows (speedups,
    cold-vs-warm for both engines, optional mesh-sharded row)."""
    from repro.core import apps, cosim, ila, ir, validate
    from repro.core.codegen import Executor
    from repro.core.compile import compile_program

    print("\n-- pipelined vs sync engine: FlexASR LSTM co-sim eval "
          f"({n_eval} points, batch {batch}) --")
    expr, params = apps.build_lstm_wlm()
    res = compile_program(expr, targets=("flexasr",))
    xshape = next(v for v in ir.postorder(expr)
                  if isinstance(v, ir.Var) and v.name == "x").shape
    rng = np.random.default_rng(0)
    X = rng.standard_normal((n_eval,) + tuple(xshape)).astype(np.float32)
    y = rng.integers(0, 8, n_eval)

    # bit-exact parity gate: pipelined == compiled on every sample, and both
    # == the eager per-command reference on a subset (eager is ~1000x slower)
    envs = [dict(params, x=X[i]) for i in range(4)]
    out_c = Executor("ila", engine="compiled").run_many(res.program, envs)
    out_p = Executor("ila", engine="pipelined", pipeline_chunk=2).run_many(
        res.program, envs)
    for a, b in zip(out_c, out_p):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "pipelined engine drifted from compiled"
    out_e = Executor("ila", engine="eager").run_many(res.program, envs[:2])
    for a, b in zip(out_c, out_e):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "compiled engine drifted from the eager reference"
    out_f = Executor("ila", engine="fused").run_many(res.program, envs)
    fused_err = max(
        validate.frob_rel_err(np.asarray(a), np.asarray(b))
        for a, b in zip(out_c, out_f))
    assert fused_err <= 1e-4, \
        f"fused engine drifted from the compiled oracle: {fused_err:.2e}"
    print("bit-exact parity (pipelined == compiled == eager): True; "
          f"fused rel err vs compiled: {fused_err:.1e} "
          f"(lowering={ila.fused_lowering()})")

    ex_sync = Executor("ila", engine="compiled")
    ex_pipe = Executor("ila", engine="pipelined")
    t0 = time.perf_counter()
    cosim.eval_classification(res.program, params, X, y, ex_pipe,
                              n_eval=n_eval, batch_size=batch)
    cold = time.perf_counter() - t0

    def timed(ex):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            cosim.eval_classification(res.program, params, X, y, ex,
                                      n_eval=n_eval, batch_size=batch)
            ts.append(time.perf_counter() - t0)
        return min(ts), statistics.median(ts)

    ex_fused = Executor("ila", engine="fused")
    t0 = time.perf_counter()
    cosim.eval_classification(res.program, params, X, y, ex_fused,
                              n_eval=n_eval, batch_size=batch)
    fused_cold = time.perf_counter() - t0

    timed(ex_sync)  # warm the sync engine's traces before interleaving
    sync_min, sync_med = timed(ex_sync)
    pipe_min, pipe_med = timed(ex_pipe)
    fused_min, fused_med = timed(ex_fused)
    speedup = sync_min / pipe_min
    fused_speedup = sync_min / fused_min
    stages = ex_pipe.pipeline_summary()
    lowering = ila.fused_lowering()
    per_pt = lambda s: s / n_eval * 1e3
    print(f"compiled (sync):    {per_pt(sync_min):7.2f} ms/point min / "
          f"{per_pt(sync_med):.2f} median")
    print(f"pipelined:          {per_pt(pipe_min):7.2f} ms/point min / "
          f"{per_pt(pipe_med):.2f} median   ({speedup:.2f}x vs sync; "
          f"target >= 1.3x)")
    print(f"fused ({lowering}):       {per_pt(fused_min):7.2f} ms/point min / "
          f"{per_pt(fused_med):.2f} median   ({fused_speedup:.2f}x vs sync; "
          f"target >= 1.3x)")
    print(f"pipelined cold:     {per_pt(cold):7.2f} ms/point (first eval, "
          f"engine traces)")
    print(f"fused cold:         {per_pt(fused_cold):7.2f} ms/point (first "
          f"eval: runner resolution + traces)")
    print(f"pipeline stages: pack {stages['pack_s']:.2f}s / dispatch "
          f"{stages['dispatch_s']:.2f}s / readback {stages['readback_s']:.2f}s")
    rows = [
        ("cosim_eval_sync", sync_min / n_eval * 1e6, "compiled engine, LSTM eval"),
        ("cosim_eval_pipelined", pipe_min / n_eval * 1e6,
         f"speedup={speedup:.2f}x vs sync"),
        ("cosim_eval_pipelined_cold", cold / n_eval * 1e6,
         "first pipelined eval (cold engine traces)"),
        ("cosim_eval_fused", fused_min / n_eval * 1e6,
         f"speedup={fused_speedup:.2f}x vs sync (target >= 1.3x), "
         f"lowering={lowering}, rel err vs compiled {fused_err:.1e}"),
        ("cosim_eval_fused_cold", fused_cold / n_eval * 1e6,
         "first fused eval (runner resolution + engine traces)"),
    ]

    # mesh-sharded batch tier: only meaningful with >1 host device
    if len(jax.devices()) > 1:
        frag = fa.lstm_fragment(params["lstm_wi"], params["lstm_wh"],
                                params["lstm_b"])
        datas = [fa.pack_lstm_data(frag, rng.standard_normal(
            (xshape[0], xshape[2])).astype(np.float32)) for _ in range(16)]
        ref = np.asarray(jax.vmap(fa.read_full)(frag.run_batch(datas)))[:16]
        base_min, _ = _time(lambda: frag.run_batch(datas), n=reps)
        mesh = ila.set_stream_mesh("auto")
        try:
            out = np.asarray(jax.vmap(fa.read_full)(frag.run_batch(datas)))[:16]
            assert np.array_equal(ref, out), "mesh sharding changed results"
            mesh_min, _ = _time(lambda: frag.run_batch(datas), n=reps)
        finally:
            ila.set_stream_mesh(None)
        print(f"mesh-sharded run_data_batch ({mesh.devices.size} devices): "
              f"{mesh_min*1e3:.2f} ms vs {base_min*1e3:.2f} ms unsharded "
              f"({base_min/mesh_min:.2f}x), bit-exact")
        rows.append(("cosim_batch_mesh", mesh_min * 1e6,
                     f"{mesh.devices.size} devices, {base_min/mesh_min:.2f}x vs unsharded"))
    else:
        print("mesh-sharded row skipped: single-device host "
              "(XLA_FLAGS=--xla_force_host_platform_device_count=N to enable)")
    return rows


def run():
    print("\n== ILA simulator speed (fragment compiler vs jit scan vs eager) ==")
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 128)).astype(np.float32)
    w = (rng.standard_normal((64, 128)) * 0.1).astype(np.float32)
    b = np.zeros((64,), np.float32)
    cmds, rd = fa.build_linear_fragment(x, w, b)
    xs = [rng.standard_normal((64, 128)).astype(np.float32) for _ in range(8)]

    # steady-state jit-scan tier: the pre-fragment-compiler behavior —
    # weight/config commands re-derived per invocation (cache=False), the
    # full stream re-packed, then scanned
    def seed_path():
        frag = fa.linear_fragment(w, b, cache=False)
        c = frag.full_commands(fa.pack_linear_data(frag, x))
        return fa.read_full(fa.flexasr.simulate_jit(c))

    jit_min, jit_med = _time(seed_path, n=5)

    # compiled tier, cold: fresh fragment (setup stream simulated) each time
    def cold_path():
        frag = fa.linear_fragment(w, b, cache=False)
        return fa.read_full(frag.run(fa.pack_linear_data(frag, x)))

    cold_min, cold_med = _time(cold_path, n=3)

    # compiled tier, steady state: cached setup, only data re-packed
    frag = fa.linear_fragment(w, b)
    frag.setup_state()

    def warm_path():
        return fa.read_full(frag.run(fa.pack_linear_data(frag, x)))

    warm_min, warm_med = _time(warm_path, n=20)

    # batched tier: 8 samples through one vmapped simulator call
    datas = [fa.pack_linear_data(frag, xi) for xi in xs]

    def batch_path():
        return jax.vmap(fa.read_full)(frag.run_batch(datas))

    batch_min, batch_med = _time(batch_path, n=10)
    per_sample_min = batch_min / len(xs)

    t0 = time.perf_counter()
    n_eager = 2
    for _ in range(n_eager):
        st = fa.flexasr.simulate(cmds)
    eager = (time.perf_counter() - t0) / n_eager

    # bit-exactness of every tier vs the eager reference
    ref = np.asarray(rd(fa.flexasr.simulate(cmds)))
    out_warm = np.asarray(warm_path())[:64, :64]
    out_batch = np.asarray(batch_path())[0][:64, :64]
    ref_b0 = np.asarray(
        fa.read_full(fa.flexasr.simulate(frag.full_commands(datas[0])))
    )[:64, :64]
    exact = np.array_equal(ref, out_warm) and np.array_equal(ref_b0, out_batch)

    speedup = jit_min / warm_min
    print(f"fragment: {len(cmds)} commands (FlexASR LinearLayer 64x128->64)")
    print(f"eager reference:    {eager*1e3:8.1f} ms/invocation")
    print(f"jit scan (seed):    {jit_min*1e3:8.1f} ms min / {jit_med*1e3:.1f} ms median")
    print(f"compiled cold:      {cold_min*1e3:8.1f} ms min / {cold_med*1e3:.1f} ms median")
    print(f"compiled steady:    {warm_min*1e3:8.1f} ms min / {warm_med*1e3:.1f} ms median"
          f"   ({speedup:.1f}x vs jit scan)")
    print(f"batched (8/call):   {per_sample_min*1e3:8.1f} ms/sample min")
    print(f"bit-exact vs eager reference: {exact}")
    print(f"flexasr target caches: {fa.TARGET.cache_info()}")
    assert exact, "compiled tiers must match the eager reference bit-for-bit"

    # batch-vs-sequential break-even (the ROADMAP claim, measured): at which
    # batch size does one vmapped run_batch beat B sequential frag.run calls?
    print("\n-- batch vs sequential crossover (FlexASR linear data streams) --")
    print(f"{'B':>4s} {'seq us/sample':>14s} {'batch us/sample':>16s} {'winner':>8s}")
    cross_rows, crossover = batch_crossover(
        frag, lambda: fa.pack_linear_data(
            frag, rng.standard_normal((64, 128)).astype(np.float32))
    )
    for B, seq_ps, bat_ps in cross_rows:
        winner = "batch" if bat_ps < seq_ps else "seq"
        print(f"{B:4d} {seq_ps*1e6:14.1f} {bat_ps*1e6:16.1f} {winner:>8s}")
    print("crossover: "
          + (f"vmapped batching wins from B={crossover} on this backend"
             if crossover is not None else
             "batching never wins on this backend (dispatch already amortized)"))

    # fused fast-path tier vs the compiled batched tier, same fragment: one
    # bulk-write + compute + readout kernel vs the vmapped per-command
    # dynamic_update_slice chain + unrolled tail + separate readout
    from repro.core import ila as core_ila
    print("\n-- fused vs compiled batched (FlexASR linear data streams) --")
    runner = fa.TARGET.fused_runner(frag)
    assert runner is not None, "flexasr declared no fused runner"
    ref8 = np.asarray(jax.vmap(fa.read_full)(frag.run_batch(datas)))
    got8 = np.asarray(runner.run(datas))[: len(datas)]
    assert np.array_equal(ref8, got8), \
        "fused linear runner drifted from the compiled batched tier"
    print(f"{'B':>4s} {'compiled us/sample':>19s} {'fused us/sample':>16s} "
          f"{'winner':>8s}")
    f_rows, f_cross = fused_crossover(
        frag, runner, fa.read_full,
        make_data=lambda: fa.pack_linear_data(
            frag, rng.standard_normal((64, 128)).astype(np.float32)))
    for B, comp_ps, fus_ps in f_rows:
        winner = "fused" if fus_ps < comp_ps else "compiled"
        print(f"{B:4d} {comp_ps*1e6:19.1f} {fus_ps*1e6:16.1f} {winner:>8s}")
    comp_ps8 = next(c for B, c, f in f_rows if B == 8)
    fus_ps8 = next(f for B, c, f in f_rows if B == 8)
    lowering = core_ila.fused_lowering()
    print(f"fused steady (8/call, {lowering}): {fus_ps8*1e6:.1f} us/sample "
          f"({comp_ps8 / fus_ps8:.2f}x vs compiled batched); crossover: "
          + (f"fused wins from B={f_cross}" if f_cross is not None
             else "fused never wins <= 32 on this backend"))

    rows = [
        ("sim_batch_crossover", float(crossover or 0),
         f"batch wins from B={crossover}" if crossover else "no crossover <= 32"),
        ("sim_steady_compiled", warm_min * 1e6, f"speedup={speedup:.1f}x"),
        ("sim_cold_compiled", cold_min * 1e6, "includes setup sim"),
        ("sim_batched_per_sample", per_sample_min * 1e6, "batch of 8"),
        ("sim_speed_jit", jit_min * 1e6, f"n_cmds={len(cmds)}"),
        ("sim_speed_eager", eager * 1e6, f"n_cmds={len(cmds)}"),
        ("sim_steady_fused", fus_ps8 * 1e6,
         f"{comp_ps8 / fus_ps8:.2f}x vs compiled batched (8/call), "
         f"lowering={lowering}"),
        ("sim_fused_crossover", float(f_cross or 0),
         f"fused wins from B={f_cross}" if f_cross is not None
         else "fused never wins <= 32"),
    ]
    rows += pipelined_eval_speed()
    return rows


if __name__ == "__main__":
    try:
        from benchmarks._bench_io import write_bench_json
    except ImportError:  # invoked as a script: benchmarks/ itself is on sys.path
        from _bench_io import write_bench_json

    print("wrote", write_bench_json(run()))
