"""ILA simulator speed (the paper's "30x faster than RTL simulation" claim).

No RTL offline, so we benchmark the two simulator tiers we do have — the
jit-compiled lax.scan simulator vs the eager per-command reference — on the
FlexASR LinearLayer fragment. The jit tier is the analogue of ILAng's
generated C++ simulator; the eager tier stands in for the slow
interpretation baseline.
"""
from __future__ import annotations

import time

import numpy as np

from repro.accel import flexasr as fa


def run():
    print("\n== ILA simulator speed (jit scan vs eager reference) ==")
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 128)).astype(np.float32)
    w = (rng.standard_normal((64, 128)) * 0.1).astype(np.float32)
    b = np.zeros((64,), np.float32)
    cmds, rd = fa.build_linear_fragment(x, w, b)

    # warm both paths
    fa.flexasr.simulate_jit(cmds)
    t0 = time.time()
    n_jit = 20
    for _ in range(n_jit):
        st = fa.flexasr.simulate_jit(cmds)
    rd(st).block_until_ready()
    t_jit = (time.time() - t0) / n_jit

    t0 = time.time()
    n_eager = 2
    for _ in range(n_eager):
        st = fa.flexasr.simulate(cmds)
    t_eager = (time.time() - t0) / n_eager

    speedup = t_eager / t_jit
    print(f"fragment: {len(cmds)} commands (FlexASR LinearLayer 64x128->64)")
    print(f"eager reference: {t_eager*1e3:8.1f} ms/invocation")
    print(f"jit simulator:   {t_jit*1e3:8.1f} ms/invocation   ({speedup:.0f}x faster)")
    return [("sim_speed_jit", t_jit * 1e6, f"speedup={speedup:.1f}x"),
            ("sim_speed_eager", t_eager * 1e6, f"n_cmds={len(cmds)}")]


if __name__ == "__main__":
    run()
