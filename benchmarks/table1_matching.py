"""Table 1 analogue: end-to-end compilation statistics.

Per application x accelerator: static invocations under exact vs flexible
matching (per-target compiles, as the paper's rows 4-6 are)."""
from __future__ import annotations

import time

from repro.core import apps, ir
from repro.core.compile import compile_program


def run():
    rows = []
    print("\n== Table 1: compilation statistics (exact/flexible) ==")
    print(f"{'Application':14s} {'DSL':8s} {'#IR ops':>8s} {'FlexASR':>10s} "
          f"{'HLSCNN':>10s} {'VTA':>10s} {'sat?':>5s}")
    for name, (builder, dsl) in apps.APPLICATIONS.items():
        expr, _ = builder()
        n_ops = ir.count_ops(expr)
        cells = []
        saturated = True
        t0 = time.time()
        for tgt in ("flexasr", "hlscnn", "vta"):
            e = compile_program(expr, targets=(tgt,), flexible=False)
            f = compile_program(expr, targets=(tgt,), flexible=True)
            saturated &= f.stats["saturated"]
            cells.append(f"{e.accelerator_calls[tgt]}/{f.accelerator_calls[tgt]}")
        dt = time.time() - t0
        print(f"{name:14s} {dsl:8s} {n_ops:8d} {cells[0]:>10s} {cells[1]:>10s} "
              f"{cells[2]:>10s} {str(saturated):>5s}")
        rows.append((f"table1_{name}", dt * 1e6 / 6, f"exact/flex={cells}"))
    return rows


if __name__ == "__main__":
    run()
