"""Table 1 analogue: end-to-end compilation statistics.

Per application x accelerator: static invocations under exact vs flexible
matching (per-target compiles, as the paper's rows 4-6 are). Columns come
from the target registry, so a newly registered backend gets a column —
and its exact/flexible offload counts — automatically."""
from __future__ import annotations

import time

from repro.core import apps, ir
from repro.core.compile import compile_program
from repro.core.ila import TARGETS


def run():
    rows = []
    targets = TARGETS.all()
    print("\n== Table 1: compilation statistics (exact/flexible) ==")
    header = " ".join(f"{t.display_name:>10s}" for t in targets)
    print(f"{'Application':14s} {'DSL':8s} {'#IR ops':>8s} {header} {'sat?':>5s}")
    for name, (builder, dsl) in apps.APPLICATIONS.items():
        expr, _ = builder()
        n_ops = ir.count_ops(expr)
        cells = []
        saturated = True
        t0 = time.time()
        for t in targets:
            e = compile_program(expr, targets=(t.name,), flexible=False)
            f = compile_program(expr, targets=(t.name,), flexible=True)
            saturated &= f.stats["saturated"]
            cells.append(f"{e.accelerator_calls[t.name]}/{f.accelerator_calls[t.name]}")
        dt = time.time() - t0
        cell_str = " ".join(f"{c:>10s}" for c in cells)
        print(f"{name:14s} {dsl:8s} {n_ops:8d} {cell_str} {str(saturated):>5s}")
        rows.append((f"table1_{name}", dt * 1e6 / (2 * len(targets)),
                     f"exact/flex={cells}"))
    return rows


if __name__ == "__main__":
    run()
