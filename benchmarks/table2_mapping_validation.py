"""Table 2 analogue: simulation-based validation of IR-accelerator mappings.

Relative Frobenius error over N random inputs per (accelerator, operation).
"""
from __future__ import annotations

import os
import time

from repro.core.validate import validate_mappings

N = int(os.environ.get("REPRO_TABLE2_N", "25"))   # paper used 100


def run():
    print(f"\n== Table 2: mapping validation ({N} random inputs each) ==")
    print(f"{'Accelerator':12s} {'Operation':14s} {'Avg. Err.':>10s} {'Std. Dev.':>10s}")
    t0 = time.time()
    rows = validate_mappings(n_inputs=N)
    dt = time.time() - t0
    out = []
    for r in rows:
        print(f"{r.accelerator:12s} {r.operation:14s} {r.avg_err:10.2%} {r.std_err:10.2%}")
        out.append((f"table2_{r.accelerator}_{r.operation}",
                    dt * 1e6 / len(rows) / N, f"avg_err={r.avg_err:.4f}"))
    return out


if __name__ == "__main__":
    run()
