"""Kernel micro-benchmarks (interpret-mode wall times are NOT TPU times —
the derived column reports the roofline-bound TPU v5e time instead)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

PEAK = 197e12


def _time(fn, n=5):
    """min per-iteration time; device work forced inside the timed region."""
    out = fn()  # warm/compile
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn()
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run():
    print("\n== kernel benches (CPU interpret; derived = TPU roofline bound) ==")
    rng = np.random.default_rng(0)
    rows = []

    m = n = k = 512
    a = jnp.asarray(rng.integers(-127, 127, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 127, (n, k)), jnp.int8)
    dt = _time(lambda: ops.int8_gemm(a, b))
    flops = 2 * m * n * k
    rows.append(("int8_gemm_512", dt * 1e6, f"tpu_bound_us={flops/ (2*PEAK) * 1e6:.2f}"))

    x = jnp.asarray(rng.standard_normal((256, 512)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((256, 512)) * 0.1, jnp.float32)
    bb = jnp.zeros((256,), jnp.float32)
    dt = _time(lambda: ops.af_linear(x, w, bb))
    flops = 2 * 256 * 256 * 512
    rows.append(("af_gemm_256x512", dt * 1e6, f"tpu_bound_us={flops/PEAK*1e6:.2f}"))

    q = jnp.asarray(rng.standard_normal((1, 4, 512, 64)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    dt = _time(lambda: ops.flash_attention(q, kk, v))
    flops = 4 * 1 * 4 * 512 * 512 * 64 * 0.5
    rows.append(("flash_attn_512", dt * 1e6, f"tpu_bound_us={flops/PEAK*1e6:.2f}"))

    for name, us, derived in rows:
        print(f"{name:20s} {us:10.0f} us/call   {derived}")
    return rows


if __name__ == "__main__":
    try:
        from benchmarks._bench_io import write_bench_json
    except ImportError:  # invoked as a script: benchmarks/ itself is on sys.path
        from _bench_io import write_bench_json

    print("wrote", write_bench_json(run()))
