"""Roofline summary from the dry-run sweep artifacts (§Roofline)."""
from __future__ import annotations

import json
import os

from repro.configs import get_config
from repro.models.config import SHAPES
from repro.roofline.analysis import roofline_report, roofline_terms


def run():
    path = os.path.join(os.path.dirname(__file__), "..", "dryrun_singlepod.json")
    if not os.path.exists(path):
        print("\n(no dryrun_singlepod.json — run `python -m repro.launch.dryrun --all` first)")
        return []
    cells = json.load(open(path))
    print("\n== Roofline (single-pod 16x16, from the dry-run) ==")
    print(roofline_report(cells))
    rows = []
    for c in cells:
        if c.get("status") != "ok":
            continue
        t = roofline_terms(c, get_config(c["arch"]), SHAPES[c["shape"]])
        rows.append((f"roofline_{c['arch']}_{c['shape']}",
                     t["roofline_bound_s"] * 1e6,
                     f"dominant={t['dominant']},mfu_bound={t['mfu_bound']}"))
    return rows


if __name__ == "__main__":
    run()
