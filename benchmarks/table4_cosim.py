"""Table 4 analogue: application-level co-simulation.

Trains three of the Section-4.2 applications on deterministic synthetic
tasks and evaluates the COMPILED (accelerator-offloaded) program:

  reference — fp32 host (IR interpreter)
  original  — ILA co-simulation, original numerics (HLSCNN 8-bit weights)
  updated   — ILA co-simulation with the developers' fix (16-bit weights)

Reproduces the paper's phenomenon: FlexASR AdaptivFloat apps survive with
small degradation; HLSCNN's original 8-bit weight quantization collapses
conv-net accuracy; the 16-bit update recovers it. (Absolute values differ —
synthetic tasks, DESIGN.md §7.)
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import apps, cosim
from repro.core.codegen import Executor
from repro.core.compile import compile_program

N_EVAL = int(os.environ.get("REPRO_TABLE4_N", "40"))    # paper used 2000 imgs
TRAIN_STEPS = int(os.environ.get("REPRO_TABLE4_STEPS", "600"))


def _acc_row(name, platform, builder, input_shape, targets, steps=TRAIN_STEPS):
    expr, params = builder()
    X, y = cosim.make_teacher_task(builder, input_shape, n=512)
    trained = cosim.train_app(expr, params, X, y, steps=steps, lr=3e-3)
    res = compile_program(expr, targets=targets, flexible=True)
    ref, _ = cosim.eval_classification(res.program, trained, X, y, Executor("ideal"), N_EVAL)
    t0 = time.time()
    ex8 = Executor("ila", target_options={"hlscnn": {"wgt_bits": 8}})
    orig, dt = cosim.eval_classification(res.program, trained, X, y, ex8, N_EVAL)
    upd = None
    if "hlscnn" in targets:
        ex16 = Executor("ila", target_options={"hlscnn": {"wgt_bits": 16}})
        upd, _ = cosim.eval_classification(res.program, trained, X, y, ex16, N_EVAL)
    per_op = {}
    for s in ex8.stats:
        per_op.setdefault(s.op, []).append(s.rel_err)
    dbg = {k: float(np.mean(v)) for k, v in per_op.items()}
    return {
        "application": name, "platform": platform,
        "reference": ref, "original": orig, "updated": upd,
        "sim_s_per_point": dt, "offloads": res.accelerator_calls,
        "per_op_err": dbg,
    }


def run():
    print(f"\n== Table 4: application-level co-simulation ({N_EVAL} points) ==")
    rows = []
    rows.append(_acc_row("ResMLP", "FlexASR", lambda seed=0: apps.build_resmlp(seed=seed, layers=2),
                         (16, 64), ("flexasr",)))
    rows.append(_acc_row("ResNet-20", "FlexASR & HLSCNN",
                         lambda seed=0: apps.build_resnet20(seed=seed),
                         (1, 12, 12, 8), ("flexasr", "hlscnn")))
    rows.append(_acc_row("MobileNet-V2", "FlexASR & HLSCNN",
                         lambda seed=0: apps.build_mobilenet_v2(seed=seed),
                         (1, 12, 12, 8), ("flexasr", "hlscnn")))
    print(f"{'Application':14s} {'Platform':18s} {'Reference':>10s} {'Original':>10s} "
          f"{'Updated':>10s} {'s/point':>8s}")
    out = []
    for r in rows:
        upd = f"{r['updated']:.1%}" if r["updated"] is not None else "n/a"
        print(f"{r['application']:14s} {r['platform']:18s} {r['reference']:>10.1%} "
              f"{r['original']:>10.1%} {upd:>10s} {r['sim_s_per_point']:>8.2f}")
        print(f"    per-op errors (original): "
              f"{ {k: f'{v:.1%}' for k, v in r['per_op_err'].items()} }")
        out.append((f"table4_{r['application']}", r["sim_s_per_point"] * 1e6,
                    f"ref={r['reference']:.3f},orig={r['original']:.3f},upd={r['updated']}"))
    return out


if __name__ == "__main__":
    run()
