"""Extraction micro-benchmark: cost-driven flexible matching statistics.

Per application (all-targets compile):

* saturation and extraction wall time, measured separately — saturation is
  the e-matching fixpoint, extraction the cost-DP over the saturated
  e-graph (the part the per-target CostModels now drive);
* per-target **op wins**: how many intrinsic invocations each target's
  CostModel won in the extracted program.

Then a Table-1-style *policy diff*: offload columns under the default
``cheapest`` policy vs ``prefer=(<first target>,)`` — showing how the
SelectionPolicy re-routes ops that several targets claim without touching
any rewrite.
"""
from __future__ import annotations

import time

from repro.core import apps, ir, rules as R
from repro.core.compile import SelectionPolicy, compile_program, make_cost_fn
from repro.core.egraph import EGraph, extract_best, run_rewrites
from repro.core.ila import TARGETS


def run():
    rows = []
    targets = TARGETS.all()
    tnames = [t.name for t in targets]
    baselines = {}
    print("\n== Extraction benchmark (cost-driven flexible matching) ==")
    print(f"{'Application':14s} {'saturate':>10s} {'extract':>9s} {'nodes':>7s}  op wins")
    for name, (builder, _dsl) in apps.APPLICATIONS.items():
        expr, _ = builder()
        eg = EGraph()
        root = eg.add_expr(expr)
        t0 = time.perf_counter()
        run_rewrites(eg, R.all_rewrites(tnames, flexible=True))
        t_sat = time.perf_counter() - t0
        cost_fn = make_cost_fn(SelectionPolicy(), targets)
        t0 = time.perf_counter()
        best, _cost = extract_best(eg, root, cost_fn)
        t_ext = time.perf_counter() - t0
        baselines[name] = ir.accelerator_calls(best)
        wins = {t: n for t, n in baselines[name].items() if n > 0}
        print(f"{name:14s} {t_sat*1e3:8.1f}ms {t_ext*1e3:7.1f}ms {eg.n_nodes:7d}  {wins}")
        rows.append((f"extract_{name}", t_ext * 1e6, f"wins={wins}"))

    # policy diff: cheapest (the baseline above) vs prefer=<first target>
    pref = tnames[0]
    print(f"\n== Policy diff: cheapest vs prefer=('{pref}',) ==")
    header = " ".join(f"{t:>9s}" for t in tnames)
    print(f"{'Application':14s} {'policy':10s} {header}")
    for name, (builder, _dsl) in apps.APPLICATIONS.items():
        expr, _ = builder()
        base = baselines[name]
        prefd = compile_program(
            expr, policy=SelectionPolicy(prefer=(pref,))
        ).accelerator_calls
        moved = sum(abs(base[t] - prefd[t]) for t in tnames) // 2
        for label, calls in (("cheapest", base), ("prefer", prefd)):
            cells = " ".join(f"{calls[t]:>9d}" for t in tnames)
            print(f"{name:14s} {label:10s} {cells}")
        rows.append((f"policy_diff_{name}", 0.0, f"ops_moved={moved}"))
    return rows


if __name__ == "__main__":
    run()
