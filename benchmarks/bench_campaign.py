"""Fault-campaign throughput: mutants/sec — warm caches, and sharded.

The campaign engine (``repro.core.campaign``) turns the paper's one-off
application-level-validation case study into a fleet workload: thousands of
mutant co-simulations per campaign. Its throughput levers are

* the shared golden-side packing cache (``repro.core.faults``): mutant
  planners delegate to the golden planners, so across mutants only the
  *mutant-side* setup simulation and mutated-ILA traces are paid per
  mutant (campaign_cold vs campaign_warm);
* the fault-tolerant sharded runner (``run_campaign_sharded``): mutants
  fan out across worker subprocesses, each owning a private device fleet
  (campaign_shard{1,2,4}w). Each worker pays its own golden-cache warmup,
  so sharding wins exactly when per-mutant work dominates init — which it
  does at campaign scale.

This bench runs an apps-free campaign (fragment + per-op differential
tiers — the per-mutant hot path) serially twice (cold/warm), then sharded
at 1/2/4 workers, and reports us/mutant for each. The 4-worker >= 2x bar
(the PR 6 acceptance claim) is REPORTED, not asserted: campaign executors
are now memoized per process (one golden pre-warm per worker instead of
per mutant), which cut per-mutant cost on the serial side too, so at this
bench's small mutant count (22) worker init is barely amortized and the
2x bar is only reachable on multi-core hosts running much longer
campaigns. On hosts with < 4 CPU cores (e.g. a 1-core sandbox, where
sharding can only lose) the ratio is reported without any verdict.

Run as __main__ the rows merge into BENCH_cosim.json (benchmarks/_bench_io).
"""
from __future__ import annotations

import os
import time


def run():
    from repro.core.campaign import run_campaign, run_campaign_sharded

    kwargs = dict(
        targets=("flexasr", "vecunit", "hlscnn"),
        faults=("sat_wrap", "round_floor", "drop_cfg", "trunc_width",
                "decode_alias", "cmd_reorder"),
        apps=(),                      # mutant-machinery throughput only
        engine="pipelined", devices_per_target=2,
        op_samples=1, vt2_n=2,
    )
    print("\n== fault-campaign throughput (3 targets x 6 fault classes, "
          "pipelined, 2 devices/target) ==")
    t0 = time.perf_counter()
    cold = run_campaign(**kwargs)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = run_campaign(**kwargs)
    warm_s = time.perf_counter() - t0
    n = len(cold.reports)
    detected = sum(1 for r in warm.reports if r.detected_at)
    print(f"cold: {n} mutants in {cold_s:.1f}s "
          f"({cold.mutants_per_sec:.2f} mutants/sec)")
    print(f"warm: {n} mutants in {warm_s:.1f}s "
          f"({warm.mutants_per_sec:.2f} mutants/sec, "
          f"{cold_s / warm_s:.2f}x vs cold); "
          f"{detected}/{n} mutants detected")
    rows = [
        ("campaign_cold", cold_s / n * 1e6,
         f"{cold.mutants_per_sec:.2f} mutants/sec over {n} mutants, "
         "cold golden caches"),
        ("campaign_warm", warm_s / n * 1e6,
         f"{warm.mutants_per_sec:.2f} mutants/sec over {n} mutants, "
         f"warm golden caches ({cold_s / warm_s:.2f}x vs cold); "
         f"{detected}/{n} detected"),
    ]

    warm_mps = n / warm_s
    steady_mps = {}
    for workers in (1, 2, 4):
        # steady-state rate: first-to-last mutant completion, excluding the
        # per-worker one-time init (JAX import + golden cache warmup) that a
        # long-running campaign amortizes to nothing
        stamps = []
        t0 = time.perf_counter()
        res = run_campaign_sharded(
            workers=workers, mutant_timeout=600.0,
            progress=lambda s: stamps.append(time.perf_counter()), **kwargs)
        dt = time.perf_counter() - t0
        done = stamps[-len(res.reports):]
        steady = ((len(done) - 1) / (done[-1] - done[0])
                  if len(done) > 1 and done[-1] > done[0] else len(res.reports) / dt)
        steady_mps[workers] = steady
        mps = len(res.reports) / dt
        print(f"sharded {workers}w: {len(res.reports)} mutants in {dt:.1f}s "
              f"(total {mps:.2f}, steady-state {steady:.2f} mutants/sec = "
              f"{steady / warm_mps:.2f}x vs serial warm)")
        rows.append((
            f"campaign_shard{workers}w", dt / len(res.reports) * 1e6,
            f"{mps:.2f} mutants/sec total, {steady:.2f} steady-state over "
            f"{len(res.reports)} mutants, {workers} worker(s) "
            f"({steady / warm_mps:.2f}x vs serial warm)",
        ))

    cores = os.cpu_count() or 1
    if cores >= 4:
        # reported, not asserted (see module docstring): per-worker executor
        # memoization lowered the serial warm baseline as well, so the 2x bar
        # needs campaign lengths that amortize worker init — beyond this
        # bench's 22 mutants
        verdict = "OK" if steady_mps[4] >= 2.0 * warm_mps else "SHORT of 2x"
        print(f"4-worker sharding vs serial warm: "
              f"{steady_mps[4] / warm_mps:.2f}x [{verdict}]")
    else:
        print(f"host has {cores} core(s) < 4: sharded speedup reported, "
              f"no verdict")
    return rows


if __name__ == "__main__":
    try:
        from benchmarks._bench_io import write_bench_json
    except ImportError:  # invoked as a script: benchmarks/ itself is on sys.path
        from _bench_io import write_bench_json

    rows = run()
    path = write_bench_json(rows)
    print(f"wrote {len(rows)} rows to {path}")
