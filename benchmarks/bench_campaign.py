"""Fault-campaign throughput: mutants/sec, warm vs cold golden caches.

The campaign engine (``repro.core.campaign``) turns the paper's one-off
application-level-validation case study into a fleet workload: thousands of
mutant co-simulations per campaign. Its throughput lever is the shared
golden-side packing cache (``repro.core.faults``): mutant planners delegate
to the golden planners, so across mutants only the *mutant-side* setup
simulation and mutated-ILA traces are paid per mutant.

This bench runs an apps-free campaign (fragment + per-op differential
tiers — the per-mutant hot path) twice in-process and reports:

  campaign_cold    us/mutant, first run (golden caches cold, all traces)
  campaign_warm    us/mutant, second run (golden packing warm)

Run as __main__ the rows merge into BENCH_cosim.json (benchmarks/_bench_io).
"""
from __future__ import annotations

import time


def run():
    from repro.core.campaign import run_campaign

    kwargs = dict(
        targets=("vecunit", "hlscnn"),
        faults=("sat_wrap", "round_floor", "drop_cfg"),
        apps=(),                      # mutant-machinery throughput only
        engine="pipelined", devices_per_target=2,
        op_samples=1, vt2_n=2,
    )
    print("\n== fault-campaign throughput (2 targets x 3 fault classes, "
          "pipelined, 2 devices/target) ==")
    t0 = time.perf_counter()
    cold = run_campaign(**kwargs)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = run_campaign(**kwargs)
    warm_s = time.perf_counter() - t0
    n = len(cold.reports)
    detected = sum(1 for r in warm.reports if r.detected_at)
    print(f"cold: {n} mutants in {cold_s:.1f}s "
          f"({cold.mutants_per_sec:.2f} mutants/sec)")
    print(f"warm: {n} mutants in {warm_s:.1f}s "
          f"({warm.mutants_per_sec:.2f} mutants/sec, "
          f"{cold_s / warm_s:.2f}x vs cold); "
          f"{detected}/{n} mutants detected")
    return [
        ("campaign_cold", cold_s / n * 1e6,
         f"{cold.mutants_per_sec:.2f} mutants/sec over {n} mutants, "
         "cold golden caches"),
        ("campaign_warm", warm_s / n * 1e6,
         f"{warm.mutants_per_sec:.2f} mutants/sec over {n} mutants, "
         f"warm golden caches ({cold_s / warm_s:.2f}x vs cold); "
         f"{detected}/{n} detected"),
    ]


if __name__ == "__main__":
    try:
        from benchmarks._bench_io import write_bench_json
    except ImportError:  # invoked as a script: benchmarks/ itself is on sys.path
        from _bench_io import write_bench_json

    rows = run()
    path = write_bench_json(rows)
    print(f"wrote {len(rows)} rows to {path}")
