"""Table 3 analogue: formal verification of the FlexASR MaxPool mapping.

No SMT solver is available offline (DESIGN.md §3), so instead of BMC/CHC we
run a *complete finite-domain* equivalence check: enumerate every assignment
of a small value lattice to the fragment inputs (decidable and exhaustive
over that domain), plus a randomized check for larger shapes. Reported like
the paper: verification time vs matrix dimension.
"""
from __future__ import annotations

import time

from repro.core import ir, validate


def _case(rows, cols):
    T = ir.Var("T", (rows, cols))
    return validate.VT2Case(
        f"maxpool-{rows}x{cols}",
        ir.call("reduce_max", ir.call("windows", T, wh=2, ww=1, sh=2, sw=1), axis=(2, 3)),
        ir.call("fasr_load", ir.call("fasr_maxpool", ir.call("fasr_store", T))),
        {"T": (rows, cols)},
    )


def run():
    print("\n== Table 3: formal verification of the FlexASR MaxPool mapping ==")
    print(f"{'Matrix dim.':12s} {'method':26s} {'time (s)':>9s} {'result':>8s}")
    out = []
    # exhaustive (complete over the lattice) for small dims
    for rows, cols, lattice in ((2, 2, (-1.0, 0.0, 1.0)),
                                (2, 4, (-1.0, 1.0)),
                                (4, 2, (-1.0, 1.0))):
        case = _case(rows, cols)
        t0 = time.time()
        ok, n = validate.vt2_exhaustive(case, lattice)
        dt = time.time() - t0
        print(f"{rows}x{cols:<10d} exhaustive({len(lattice)}^{rows*cols})"
              f"{'':6s} {dt:9.2f} {str(ok):>8s}")
        out.append((f"table3_exh_{rows}x{cols}", dt * 1e6 / n, f"assignments={n}"))
    # randomized for the paper's larger dims
    for rows, cols in ((2, 16), (4, 16), (4, 32), (8, 64), (16, 64)):
        case = _case(rows, cols)
        t0 = time.time()
        ok = validate.vt2_check(case, n=200)
        dt = time.time() - t0
        print(f"{rows}x{cols:<10d} randomized(200)          {dt:9.2f} {str(ok):>8s}")
        out.append((f"table3_rand_{rows}x{cols}", dt * 1e6 / 200, "n=200"))
    return out


if __name__ == "__main__":
    run()
