"""Continuous-batching serving benchmark (repro.core.serving).

Measures the two scheduling wins the serving layer claims, under a
latency-SLO-style load generator (Poisson arrivals, mixed applications,
mixed request batch sizes 1..4, bounded outstanding requests):

* **Cross-request coalescing** — the same Poisson workload driven through
  a serial server (coalesce off, overlap off: one request per dispatch,
  pipeline drained between requests — the pre-serving behavior) vs the
  coalescing server (queued same-app requests merged into shared vmapped
  dispatches). Reported: sustained QPS ratio at p50/p95/p99 request
  latency. Acceptance: >= 1.5x QPS at equal-or-better p95. The measured
  runs double as a bit-exactness check: both servers receive the identical
  submit sequence, so matching request ids must produce byte-identical
  outputs.

* **Request overlap** — back-to-back requests on the pack-heavy
  FlexASR LSTM application (coalescing off on both sides to isolate the
  effect): draining scheduler (every request materializes at its assemble
  barrier before the next is dequeued) vs the overlapped scheduler
  (submit_many defers the readback tail; prepack_many stages the next
  request's host packing into the barrier gap). Acceptance: >= 1.2x on
  multi-core hosts; a single-core host timeshares the pack worker, XLA
  and the dispatch thread on one CPU, so the ratio is reported but not
  judged there (docs/serving.md, "When coalescing wins").

* **Telemetry overhead** — the coalesced workload with telemetry off
  (A/A pair bounding the noise floor) vs full span tracing on.
  Acceptance: the disabled-by-default fast path costs < 2% QPS on
  multi-core hosts (docs/observability.md).

The comparisons share one Executor per pair (identical warm caches on
both sides) and run the full workload once unmeasured first, so neither
side pays first-trace costs inside the timed region. Run as __main__ the
rows merge into BENCH_cosim.json (benchmarks/_bench_io).

    PYTHONPATH=src python -m benchmarks.bench_serving [--fast]

Env knobs: REPRO_SERVING_N (mixed-load requests, default 24),
REPRO_SERVING_LSTM_N (overlap-bench requests, default 8).
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

from repro.core import apps as app_registry
from repro.core.codegen import Executor
from repro.core.compile import compile_program
from repro.core.serving import CosimServer, percentiles_ms


def _compiled_apps(names):
    out = {}
    for name in names:
        builder, _dsl = {k.lower(): v for k, v in
                         app_registry.APPLICATIONS.items()}[name]
        expr, params = builder()
        out[name] = (compile_program(expr).program, params)
    return out


def _drive(server, workload, gaps, concurrency=12):
    """Submit (app, batch) requests with the given inter-arrival gaps,
    keeping at most ``concurrency`` outstanding; returns (handles, wall_s)
    with wall measured from first submit to last completion."""
    handles = []
    t0 = time.perf_counter()
    for (app, batch), gap in zip(workload, gaps):
        outstanding = [h for h in handles if not h.done()]
        while len(outstanding) >= concurrency:
            outstanding[0].wait()
            outstanding = [h for h in outstanding if not h.done()]
        handles.append(server.submit(app, batch=batch))
        if gap:
            time.sleep(gap)
    for h in handles:
        h.wait()
    return handles, time.perf_counter() - t0


def _serve_pass(executor, progs, workload, gaps, *, warmup, **server_kw):
    """One server configuration over the workload: an unmeasured warm pass
    filling every trace/bucket the measured pass can touch, then the
    measured Poisson pass. Returns (handles, wall_s, summary)."""
    from repro.core import ila

    srv = CosimServer(executor=executor, queue_depth=4 * len(workload) + 8,
                      **server_kw)
    for name, (prog, params) in progs.items():
        srv.add_program(name, prog, params)
    srv.start(warmup=warmup, warm_batch=4)
    # the coalescer's merged batch size is load-dependent: pre-trace every
    # batch bucket it can produce so no measured dispatch pays a retrace
    sizes = sorted({ila.batch_bucket(n) for n in range(1, srv.max_batch + 1)})
    for name, (prog, _params) in progs.items():
        for n in sizes:
            executor.run_many(prog, srv.request_envs(name, 1_000_000 + n, n))
    _drive(srv, workload, [0.0] * len(workload), concurrency=len(workload))
    handles, wall = _drive(srv, workload, gaps, concurrency=len(workload))
    summ = srv.summary()
    srv.close(drain=True)
    assert all(h.status == "done" for h in handles), (
        "measured request rejected/failed: " +
        str([(h.id, h.status, h.reject_reason) for h in handles
             if h.status != "done"]))
    return handles, wall, summ


def _samples(workload):
    return sum(b for _a, b in workload)


def bench_coalescing(n_requests=24, seed=0):
    """Serial vs coalescing server on a mixed-app, mixed-batch Poisson
    workload; returns (rows, serial_handles, coalesced_handles)."""
    progs = _compiled_apps(["resmlp"])
    rng = np.random.default_rng(seed)
    names = list(progs)
    workload = [(names[i % len(names)], 1 + i % 4) for i in range(n_requests)]

    # one chunk per dispatch: the vmapped simulator call has a large fixed
    # cost, so chopping a merged batch into small chunks forfeits exactly
    # the amortization coalescing exists to buy
    max_batch = 24
    ex = Executor("ila", engine="pipelined", pipeline_chunk=max_batch)
    # serial first: its throughput calibrates the offered Poisson rate
    # (3x serial capacity: clearly saturating, so coalescing has queued
    # same-app requests to merge, yet arrivals stay stochastic)
    sh, s_wall, _ = _serve_pass(
        ex, progs, workload, [0.0] * n_requests, warmup=1,
        coalesce=False, overlap=False, seed=seed)
    rate = 3.0 * len(workload) / s_wall
    gaps = list(rng.exponential(1.0 / rate, size=n_requests))
    sh, s_wall, _ = _serve_pass(
        ex, progs, workload, gaps, warmup=0,
        coalesce=False, overlap=False, seed=seed)
    # overlap off on BOTH sides: this row isolates coalescing (the overlap
    # row below isolates overlap the same way), and on a single-core host
    # the overlap threads would only add contention to the coalesced side
    ch, c_wall, c_summ = _serve_pass(
        ex, progs, workload, gaps, warmup=0,
        coalesce=True, overlap=False, max_batch=max_batch, seed=seed)

    # identical submit sequences => identical request ids => the seeded
    # per-request operands match, so outputs must be bit-identical
    for a, b in zip(sh, ch):
        assert a.id == b.id and a.app == b.app
        for x, y in zip(a.outputs, b.outputs):
            np.testing.assert_array_equal(
                x, y, err_msg=f"request {a.id}: coalesced != serial")

    s_qps, c_qps = len(sh) / s_wall, len(ch) / c_wall
    s_pct = percentiles_ms([h.latency_s for h in sh])
    c_pct = percentiles_ms([h.latency_s for h in ch])
    speed = c_qps / s_qps
    print(f"serial:    {s_qps:6.2f} req/s ({_samples(workload)/s_wall:6.1f} "
          f"samples/s)  p50 {s_pct['p50_ms']:7.1f}  p95 {s_pct['p95_ms']:7.1f} ms")
    print(f"coalesced: {c_qps:6.2f} req/s ({_samples(workload)/c_wall:6.1f} "
          f"samples/s)  p50 {c_pct['p50_ms']:7.1f}  p95 {c_pct['p95_ms']:7.1f} ms"
          f"  (mean {c_summ['mean_batch']:.1f} req/dispatch, "
          f"max {c_summ['coalesced_max']})")
    print(f"coalescing speedup: {speed:.2f}x QPS "
          f"(acceptance >= 1.5x at equal p95: "
          f"{'PASS' if speed >= 1.5 and c_pct['p95_ms'] <= s_pct['p95_ms'] else 'MISS'})")
    rows = [
        ("serving_serial_qps", 1e6 / s_qps,
         f"{s_qps:.2f} req/s p95 {s_pct['p95_ms']:.0f}ms (coalesce off, "
         f"overlap off; {n_requests} reqs batch 1-4 poisson)"),
        ("serving_coalesced_qps", 1e6 / c_qps,
         f"{c_qps:.2f} req/s p95 {c_pct['p95_ms']:.0f}ms = {speed:.2f}x serial "
         f"(mean {c_summ['mean_batch']:.1f} req/dispatch, bit-exact vs serial)"),
    ]
    return rows


def bench_overlap(n_requests=8, batch=16, seed=0):
    """Draining vs overlapped scheduler on the pack-heavy LSTM app,
    coalescing off on both sides (isolates the submit/prepack overlap).
    LSTM co-sim is host-dominated — per-sample stream packing and
    readback, with only a sliver of vmapped simulation — so the draining
    scheduler's request boundaries are almost pure stall: the readback
    tail + host epilogue of request k and the packing ramp of request
    k+1 serialize. batch 16 / chunk 4 keeps several spans per request in
    flight for the deferral to reorder around."""
    progs = _compiled_apps(["lstm-wlm"])
    workload = [("lstm-wlm", batch)] * n_requests
    gaps = [0.0] * n_requests  # back-to-back: the barrier gap is the story

    ex = Executor("ila", engine="pipelined", pipeline_chunk=4)
    dh, d_wall, _ = _serve_pass(
        ex, progs, workload, gaps, warmup=1,
        coalesce=False, overlap=False, seed=seed)
    oh, o_wall, _ = _serve_pass(
        ex, progs, workload, gaps, warmup=0,
        coalesce=False, overlap=True, seed=seed)
    for a, b in zip(dh, oh):
        for x, y in zip(a.outputs, b.outputs):
            np.testing.assert_array_equal(
                x, y, err_msg=f"request {a.id}: overlapped != drained")

    d_sps = _samples(workload) / d_wall
    o_sps = _samples(workload) / o_wall
    speed = o_sps / d_sps
    cores = os.cpu_count() or 1
    print(f"drain:   {d_sps:6.2f} samples/s  ({d_wall:.2f}s, lstm-wlm x{batch})")
    print(f"overlap: {o_sps:6.2f} samples/s  ({o_wall:.2f}s)")
    if cores >= 2:
        verdict = "PASS" if speed >= 1.2 else "MISS"
    else:
        # overlap moves pack/readback work onto concurrent threads; on a
        # single-core host every thread shares one CPU, so scheduling
        # overlap cannot beat draining (same gating as bench_campaign's
        # multi-worker row) — report the ratio, don't judge it
        verdict = "unmeasurable on a 1-core host"
    print(f"overlap speedup: {speed:.2f}x (acceptance >= 1.2x: {verdict})")
    return [
        ("serving_overlap_lstm", 1e6 * o_wall / _samples(workload),
         f"{speed:.2f}x vs draining scheduler ({o_sps:.1f} vs {d_sps:.1f} "
         f"samples/s, lstm-wlm batch {batch}, coalesce off, bit-exact, "
         f"{cores}-core host)"),
    ]


def bench_telemetry(n_requests=24, seed=0):
    """Telemetry cost on the coalesced serving path. Two claims, one
    workload (back-to-back coalesced resmlp, the layer's hot path):

    * the disabled-by-default fast path — an ``enabled`` guard plus one
      shared no-op span object — is free to within measurement noise.
      Measured as an A/A pair (two disabled passes bracketing the
      enabled pass); acceptance: |delta| < 2% QPS on multi-core hosts
      (a 1-core host timeshares the dispatch/pack threads, so the A/A
      spread itself exceeds the bound — reported, not judged).
    * full span tracing is cheap enough to leave on under load
      (reported as the enabled-vs-disabled QPS delta + span count).
    """
    from repro.core.telemetry import TELEMETRY

    progs = _compiled_apps(["resmlp"])
    workload = [("resmlp", 1 + i % 4) for i in range(n_requests)]
    gaps = [0.0] * n_requests
    max_batch = 24
    ex = Executor("ila", engine="pipelined", pipeline_chunk=max_batch)
    kw = dict(coalesce=True, overlap=False, max_batch=max_batch, seed=seed)

    TELEMETRY.disable()
    _serve_pass(ex, progs, workload, gaps, warmup=1, **kw)  # warm caches
    _h, off1, _ = _serve_pass(ex, progs, workload, gaps, warmup=0, **kw)
    TELEMETRY.enable()
    TELEMETRY.reset()
    _h, on, _ = _serve_pass(ex, progs, workload, gaps, warmup=0, **kw)
    spans = TELEMETRY.spans_recorded
    TELEMETRY.disable()
    TELEMETRY.reset()
    _h, off2, _ = _serve_pass(ex, progs, workload, gaps, warmup=0, **kw)

    off = 0.5 * (off1 + off2)
    qps_off, qps_on = n_requests / off, n_requests / on
    aa_delta = 100.0 * abs(off1 - off2) / off
    traced_cost = 100.0 * (qps_off - qps_on) / qps_off
    cores = os.cpu_count() or 1
    if cores >= 2:
        verdict = "PASS" if aa_delta < 2.0 else "MISS"
    else:
        verdict = "unmeasurable on a 1-core host"
    print(f"telemetry off: {qps_off:6.2f} req/s  (A/A passes {off1:.2f}s / "
          f"{off2:.2f}s, delta {aa_delta:.1f}%)")
    print(f"telemetry on:  {qps_on:6.2f} req/s  ({spans} spans recorded, "
          f"tracing cost {traced_cost:+.1f}% QPS)")
    print(f"disabled-path cost: < A/A noise {aa_delta:.1f}% "
          f"(acceptance < 2%: {verdict})")
    return [
        ("serving_telemetry_overhead", 1e6 * on / n_requests,
         f"tracing on {traced_cost:+.1f}% QPS ({spans} spans); disabled "
         f"fast path within A/A noise {aa_delta:.1f}% (<2% acceptance, "
         f"{cores}-core host: {verdict})"),
    ]


def run():
    fast = "--fast" in sys.argv
    n_mix = int(os.environ.get("REPRO_SERVING_N", "12" if fast else "24"))
    n_lstm = int(os.environ.get("REPRO_SERVING_LSTM_N", "4" if fast else "8"))
    print("== serving: cross-request coalescing (mixed Poisson load) ==")
    rows = bench_coalescing(n_requests=n_mix)
    print("\n== serving: request overlap (pack-heavy LSTM) ==")
    rows += bench_overlap(n_requests=n_lstm)
    print("\n== serving: telemetry overhead (disabled fast path + tracing) ==")
    rows += bench_telemetry(n_requests=n_mix)
    return rows


if __name__ == "__main__":
    rows = run()
    try:
        from benchmarks._bench_io import write_bench_json
    except ImportError:  # invoked as a script: benchmarks/ itself is on sys.path
        from _bench_io import write_bench_json

    path = write_bench_json(rows)
    print(f"\nwrote {len(rows)} rows to {path}")
