"""Machine-readable benchmark output shared by the bench drivers.

``write_bench_json`` merges rows into ``BENCH_cosim.json`` (schema below) so
the co-simulation perf trajectory is tracked across PRs: each row is one
measurement (``us_per_call``) plus a human-readable ``derived`` note. Partial
runs (a single bench invoked as ``__main__``) update their rows in place;
``benchmarks/run.py`` rewrites the full set.

    {
      "schema": 1,
      "generated_unix": 1700000000.0,
      "rows": {"<name>": {"us_per_call": 12.3, "derived": "..."}, ...}
    }
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Sequence, Tuple

BENCH_PATH = os.environ.get("REPRO_BENCH_JSON", "BENCH_cosim.json")


def write_bench_json(
    rows: Sequence[Tuple[str, float, str]], path: str = None, fresh: bool = False
) -> str:
    """Merge ``(name, us_per_call, derived)`` rows into the bench JSON.

    ``fresh=True`` (the full ``run.py`` sweep) discards rows from earlier
    runs instead of merging, so renamed/retired benchmarks don't linger.
    Returns the path written.
    """
    path = path or BENCH_PATH
    data: Dict = {"schema": 1, "rows": {}}
    if not fresh and os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if isinstance(prev, dict) and isinstance(prev.get("rows"), dict):
                data["rows"] = prev["rows"]
        except (OSError, ValueError):
            pass  # unreadable/corrupt file: rewrite from scratch
    data["generated_unix"] = time.time()
    for name, us, derived in rows:
        data["rows"][str(name)] = {"us_per_call": float(us), "derived": str(derived)}
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    return path
