"""Accelerator ILA tests: custom numerics, simulators, VT checks."""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st  # property tests skip if absent

from repro.accel import flexasr as fa, hlscnn as hc, numerics, vta as vt
from repro.core import ir, validate

rng = np.random.default_rng(0)


class TestAdaptivFloat:
    def test_representable_fixed_point_of_quantize(self):
        x = rng.standard_normal((64,)).astype(np.float32)
        spec = numerics.AdaptivFloatSpec(8, 3)
        q = numerics.af_quantize(jnp.asarray(x), spec)
        q2 = numerics.af_quantize(q, spec, exp_bias=numerics.af_exp_bias(jnp.asarray(x), spec))
        np.testing.assert_allclose(np.asarray(q), np.asarray(q2))

    def test_zero_and_sign(self):
        spec = numerics.AdaptivFloatSpec(8, 3)
        x = jnp.asarray([0.0, -0.5, 0.5, -2.0, 2.0])
        q = np.asarray(numerics.af_quantize(x, spec))
        assert q[0] == 0.0
        assert (np.sign(q[1:]) == np.array([-1, 1, -1, 1])).all()

    @given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=2, max_size=32))
    @settings(max_examples=30, deadline=None)
    def test_error_bounded_by_mantissa_ulp(self, xs):
        """Property: relative rounding error <= 2^-(m+1) within the normal
        range (no saturation / flush)."""
        spec = numerics.AdaptivFloatSpec(8, 3)
        x = np.asarray(xs, np.float32)
        if np.max(np.abs(x)) == 0:
            return
        bias = float(numerics.af_exp_bias(jnp.asarray(x), spec))
        vmin = 2.0 ** bias
        vmax = (2 - 2 ** -spec.n_man) * 2.0 ** (bias + 2 ** spec.n_exp - 1)
        q = np.asarray(numerics.af_quantize(jnp.asarray(x), spec))
        inside = (np.abs(x) >= vmin) & (np.abs(x) <= vmax)
        rel = np.abs(q[inside] - x[inside]) / np.abs(x[inside])
        assert rel.max(initial=0.0) <= 2.0 ** -(spec.n_man + 1) + 1e-6

    def test_fixed_point_grid(self):
        spec = numerics.FixedPointSpec(8, 3)
        x = jnp.asarray([0.124, -0.3, 5.0, 100.0])
        q = np.asarray(numerics.fx_quantize(x, spec))
        np.testing.assert_allclose(q * 8, np.round(q * 8))   # on the 2^-3 grid
        assert q[3] == spec.qmax / spec.scale                # saturates


class TestFlexASR:
    def test_linear_error_magnitude(self):
        x = rng.standard_normal((16, 64)).astype(np.float32)
        w = (rng.standard_normal((32, 64)) * 0.1).astype(np.float32)
        b = (rng.standard_normal((32,)) * 0.1).astype(np.float32)
        cmds, rd = fa.build_linear_fragment(x, w, b)
        out = np.asarray(rd(fa.flexasr.simulate(cmds)))
        err = validate.frob_rel_err(x @ w.T + b, out)
        assert 0 < err < 0.06   # AF8: a few percent (Table 2 magnitude)

    def test_jit_simulator_matches_eager(self):
        x = rng.standard_normal((8, 32)).astype(np.float32)
        w = (rng.standard_normal((16, 32)) * 0.1).astype(np.float32)
        b = np.zeros((16,), np.float32)
        cmds, rd = fa.build_linear_fragment(x, w, b)
        out_e = np.asarray(rd(fa.flexasr.simulate(cmds)))
        out_j = np.asarray(rd(fa.flexasr.simulate_jit(cmds)))
        np.testing.assert_allclose(out_e, out_j, atol=1e-6)

    def test_maxpool_exact_on_device_representable_inputs(self):
        x = np.asarray(numerics.af_quantize(
            jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32)), fa.AF))
        cmds, rd = fa.build_pool_fragment(x, "max")
        out = np.asarray(rd(fa.flexasr.simulate(cmds)))
        np.testing.assert_array_equal(out, x.reshape(8, 2, 64).max(1))

    def test_lstm_close_to_reference(self):
        T, I, H = 8, 32, 16
        x = (rng.standard_normal((T, I)) * 0.5).astype(np.float32)
        wi = (rng.standard_normal((4 * H, I)) * 0.2).astype(np.float32)
        wh = (rng.standard_normal((4 * H, H)) * 0.2).astype(np.float32)
        b = (rng.standard_normal((4 * H,)) * 0.1).astype(np.float32)
        cmds, rd = fa.build_lstm_fragment(x, wi, wh, b)
        out = np.asarray(rd(fa.flexasr.simulate(cmds)))
        ref = np.asarray(ir._lstm(jnp.asarray(x[:, None]), jnp.asarray(wi),
                                  jnp.asarray(wh), jnp.asarray(b)))[:, 0]
        assert validate.frob_rel_err(ref, out) < 0.08

    def test_granularity_mismatch_one_instruction(self):
        """The LSTM maps to ONE fn_start trigger regardless of timesteps
        (the paper's 566-ops-to-1 bridge)."""
        x = (rng.standard_normal((32, 16)) * 0.5).astype(np.float32)
        wi = (rng.standard_normal((32, 16)) * 0.2).astype(np.float32)
        wh = (rng.standard_normal((32, 8)) * 0.2).astype(np.float32)
        b = np.zeros((32,), np.float32)
        cmds, _ = fa.build_lstm_fragment(x, wi, wh, b)
        assert sum(1 for c in cmds if c.opcode == fa.FN_START) == 1


class TestVTA:
    def test_gemm_exact(self):
        a = rng.integers(-120, 120, (20, 40)).astype(np.float32)
        b = rng.integers(-120, 120, (24, 40)).astype(np.float32)
        cmds, rd = vt.build_gemm_fragment(a, b)
        out = np.asarray(rd(vt.vta.simulate(cmds)))
        np.testing.assert_array_equal(out, a @ b.T)

    def test_alu_relu(self):
        a = rng.integers(-100, 100, (8, 8)).astype(np.float32)
        cmds, rd = vt.build_relu_fragment(a)
        out = np.asarray(rd(vt.vta.simulate(cmds)))
        np.testing.assert_array_equal(out, np.maximum(a, 0))

    def test_requant_shift(self):
        a = np.full((4, 4), 64.0, np.float32)
        b = np.full((4, 4), 2.0, np.float32)
        cmds, rd = vt.build_gemm_fragment(a, b, requant_shift=4)
        out = np.asarray(rd(vt.vta.simulate(cmds)))
        # acc = 64*2*4 = 512; >>4 = 32
        np.testing.assert_array_equal(out, np.full((4, 4), 32.0))


class TestHLSCNN:
    def test_conv_8bit_much_worse_than_16bit(self):
        x = rng.standard_normal((1, 12, 12, 8)).astype(np.float32)
        w = (rng.standard_normal((3, 3, 8, 16)) * 0.05).astype(np.float32)
        errs = {}
        for bits in (8, 16):
            cmds, rd = hc.build_conv2d_fragment(x, w, (1, 1), (0, 0), wgt_bits=bits)
            out = np.asarray(rd(hc.hlscnn.simulate(cmds)))
            ref = np.asarray(ir._conv2d(jnp.asarray(x), jnp.asarray(w), (1, 1), (0, 0)))
            errs[bits] = validate.frob_rel_err(ref, out)
        assert errs[8] > 5 * errs[16]          # the paper's numerics bug
        assert errs[16] < 0.02

    def test_strided_padded_conv(self):
        x = rng.standard_normal((1, 12, 12, 8)).astype(np.float32)
        w = (rng.standard_normal((3, 3, 8, 16)) * 0.05).astype(np.float32)
        cmds, rd = hc.build_conv2d_fragment(x, w, (2, 2), (1, 1), wgt_bits=16)
        out = np.asarray(rd(hc.hlscnn.simulate(cmds)))
        ref = np.asarray(ir._conv2d(jnp.asarray(x), jnp.asarray(w), (2, 2), (1, 1)))
        assert out.shape == ref.shape
        assert validate.frob_rel_err(ref, out) < 0.02
