"""Fragment-compiler equivalence + retrace-bound tests.

Asserts bit-exact parity across every simulation tier — eager per-command
``simulate``, ``simulate_jit``, bucketed/NOP-padded ``simulate_packed``,
the compiled setup-state + data-stream fast path, and vmapped batching —
on FlexASR, HLSCNN and VTA fragments, plus regression tests that the
compiled-function caches stay bounded as stream lengths vary.
"""
import jax
import numpy as np
import pytest

from repro.accel import flexasr as fa, hlscnn as hc, vta as vt
from repro.core.ila import NOP_OPCODE, Command, PackedStream, bucket_length

rng = np.random.default_rng(7)


def _linear_case():
    w = (rng.standard_normal((16, 32)) * 0.1).astype(np.float32)
    b = (rng.standard_normal((16,)) * 0.1).astype(np.float32)
    frag = fa.linear_fragment(w, b)
    xs = [rng.standard_normal((6, 32)).astype(np.float32) for _ in range(2)]
    datas = [fa.pack_linear_data(frag, x) for x in xs]
    return frag, datas, fa.read_full, (slice(0, 6), slice(0, 16))


def _lstm_case():
    wi = (rng.standard_normal((32, 16)) * 0.2).astype(np.float32)
    wh = (rng.standard_normal((32, 8)) * 0.2).astype(np.float32)
    b = (rng.standard_normal((32,)) * 0.1).astype(np.float32)
    frag = fa.lstm_fragment(wi, wh, b)
    xs = [(rng.standard_normal((5, 16)) * 0.5).astype(np.float32) for _ in range(2)]
    datas = [fa.pack_lstm_data(frag, x) for x in xs]
    return frag, datas, fa.read_full, (slice(0, 5), slice(0, 8))


def _attention_case():
    frag = fa.attention_fragment(16)
    datas = [
        fa.pack_attention_data(
            frag,
            rng.standard_normal((6, 16)).astype(np.float32),
            rng.standard_normal((9, 16)).astype(np.float32),
            rng.standard_normal((9, 16)).astype(np.float32),
        )
        for _ in range(2)
    ]
    return frag, datas, fa.read_full, (slice(0, 6), slice(0, 16))


def _conv_case():
    w = (rng.standard_normal((3, 3, 4, 8)) * 0.05).astype(np.float32)
    frag = hc.conv2d_fragment(w, (8, 8, 4), (1, 1), wgt_bits=16)
    datas = [
        hc.pack_conv2d_data(frag, rng.standard_normal((1, 8, 8, 4)).astype(np.float32))
        for _ in range(2)
    ]
    return frag, datas, hc.read_full, hc.out_slice(frag)


def _gemm_case():
    b = rng.integers(-120, 120, (12, 20)).astype(np.float32)
    frag = vt.gemm_fragment(b, 1)
    datas = [
        vt.pack_gemm_data(frag, rng.integers(-120, 120, (10, 20)).astype(np.float32))
        for _ in range(2)
    ]
    return frag, datas, vt.read_gemm_full(frag), (slice(0, 10), slice(0, 12))


def _alu_case():
    frag = vt.alu_fragment(1, 2, "add")
    datas = [
        vt.pack_alu_data(
            frag,
            rng.integers(-100, 100, (10, 24)).astype(np.float32),
            rng.integers(-100, 100, (10, 24)).astype(np.float32),
        )
        for _ in range(2)
    ]
    return frag, datas, vt.read_alu_full(frag), (slice(0, 10), slice(0, 24))


CASES = {
    "fasr_linear": _linear_case,
    "fasr_lstm": _lstm_case,
    "fasr_attention": _attention_case,
    "hlscnn_conv2d": _conv_case,
    "vta_gemm": _gemm_case,
    "vta_alu_add": _alu_case,
}


@pytest.mark.parametrize("name", sorted(CASES))
def test_all_simulation_tiers_bit_exact(name):
    """eager == jit == bucketed-padded == compiled fast path == batched."""
    frag, datas, read, window = CASES[name]()
    ila = frag.ila
    refs = []
    for data in datas:
        cmds = frag.full_commands(data)
        st_eager = ila.simulate(cmds)
        ref = np.asarray(read(st_eager))[window]
        refs.append(ref)
        # jit scan over the exact stream
        out_jit = np.asarray(read(ila.simulate_jit(cmds)))[window]
        np.testing.assert_array_equal(ref, out_jit, err_msg=f"{name}: jit != eager")
        # NOP-padded to the power-of-two bucket
        packed = PackedStream.from_commands(cmds, ila.vwidth)
        out_bucket = np.asarray(read(ila.simulate_packed(packed)))[window]
        np.testing.assert_array_equal(ref, out_bucket, err_msg=f"{name}: bucketed != eager")
        # compiled fast path: cached setup state + data stream
        out_fast = np.asarray(read(frag.run(data)))[window]
        np.testing.assert_array_equal(ref, out_fast, err_msg=f"{name}: compiled != eager")
    # batched: both samples through one vmapped call
    sts = frag.run_batch(datas)
    fulls = np.asarray(jax.vmap(read)(sts))
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(
            ref, fulls[i][window], err_msg=f"{name}: batched[{i}] != eager"
        )


def test_nop_padding_is_identity():
    x = rng.standard_normal((4, 16)).astype(np.float32)
    w = (rng.standard_normal((8, 16)) * 0.1).astype(np.float32)
    b = np.zeros((8,), np.float32)
    cmds, rd = fa.build_linear_fragment(x, w, b)
    ref = np.asarray(rd(fa.flexasr.simulate(cmds)))
    padded = cmds + [Command(NOP_OPCODE)] * 37
    out = np.asarray(rd(fa.flexasr.simulate(padded)))
    np.testing.assert_array_equal(ref, out)


def test_bucketed_retraces_bounded():
    """Scanned-stream retraces are O(log max_len): many distinct stream
    lengths map onto few power-of-two buckets."""
    before = fa.flexasr.jit_cache_info()["traces_single"]
    buckets = set()
    for n in range(1, 120, 7):
        stream = PackedStream.from_commands([Command(NOP_OPCODE)] * n, fa.V)
        fa.flexasr.simulate_packed(stream)
        buckets.add(bucket_length(n))
    after = fa.flexasr.jit_cache_info()["traces_single"]
    assert after - before <= len(buckets)
    assert len(buckets) <= 4  # lengths 1..119 -> buckets {16, 32, 64, 128}


def test_data_runner_cache_bounded_across_repeats():
    """Steady-state invocations with fixed operand shapes never recompile:
    the compiled-executor cache grows only with distinct signatures."""
    w = (rng.standard_normal((8, 16)) * 0.1).astype(np.float32)
    b = np.zeros((8,), np.float32)
    frag = fa.linear_fragment(w, b)
    frag.run(fa.pack_linear_data(frag, rng.standard_normal((4, 16)).astype(np.float32)))
    runners_before = fa.flexasr.jit_cache_info()["data_runners"]
    traces_before = fa.flexasr.jit_cache_info()["traces_single"]
    for _ in range(10):
        x = rng.standard_normal((4, 16)).astype(np.float32)
        frag.run(fa.pack_linear_data(frag, x))
    info = fa.flexasr.jit_cache_info()
    assert info["data_runners"] == runners_before
    assert info["traces_single"] == traces_before


def test_fragment_cache_reuses_setup():
    cache = fa.TARGET.fragments   # per-target cache owned by the plugin

    w = (rng.standard_normal((8, 16)) * 0.1).astype(np.float32)
    b = np.zeros((8,), np.float32)
    f1 = fa.linear_fragment(w, b)
    hits_before = cache.hits
    f2 = fa.linear_fragment(w, b)
    assert f1 is f2 and cache.hits == hits_before + 1
    # distinct parameters -> distinct fragment (content fingerprint key)
    f3 = fa.linear_fragment(w + 1.0, b)
    assert f3 is not f1


def test_executor_engines_agree():
    """Compiled Executor == seed-style jit-scan Executor == run_many."""
    from repro.core import apps
    from repro.core.codegen import Executor
    from repro.core.compile import compile_program

    expr, params = apps.build_resmlp(seed=0)
    res = compile_program(expr)
    xs_shape = next(
        v.shape for v in _vars(res.program) if v.name == "x"
    )
    X = [rng.standard_normal(xs_shape).astype(np.float32) for _ in range(3)]
    ex_c = Executor("ila", engine="compiled")
    ex_j = Executor("ila", engine="jit")
    outs_c = [np.asarray(ex_c.run(res.program, dict(params, x=x))) for x in X]
    outs_j = [np.asarray(ex_j.run(res.program, dict(params, x=x))) for x in X]
    outs_m = ex_c.run_many(res.program, [dict(params, x=x) for x in X])
    for a, b, c in zip(outs_c, outs_j, outs_m):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, np.asarray(c))


def _vars(e, seen=None):
    from repro.core import ir

    seen = set() if seen is None else seen
    if isinstance(e, ir.Var):
        yield e
    elif isinstance(e, ir.Call):
        for a in e.args:
            if id(a) not in seen:
                seen.add(id(a))
                yield from _vars(a, seen)
