"""The unified telemetry layer (repro.core.telemetry) and its wiring.

* spans: nesting/parent attribution, trace-id inheritance, cross-thread
  record_span, thread-safety of concurrent recording;
* ring buffer: bounded memory with explicit drop accounting — saturation
  drops the oldest span and counts it, never silently truncates;
* metrics: P-square streaming percentiles vs numpy on known
  distributions (and exact small-sample quantiles), counter/gauge
  semantics, registry snapshots, the documented name convention;
* export: Perfetto/chrome trace_event JSON schema validity (metadata +
  complete events, stable tids, synthetic tracks);
* sharded campaign: worker-side span export merged into the parent
  buffer (unit-level drain/ingest + a real spawn-worker campaign);
* disabled mode: the fast path returns one shared no-op span and records
  nothing (the <2% serving-overhead budget's mechanism).
"""
import json
import threading

import numpy as np
import pytest

from repro.accel.target import CostModel, GroupTiming
from repro.core.telemetry import (
    TELEMETRY, Histogram, MetricsRegistry, Telemetry, check_metric_names,
)


@pytest.fixture
def tel():
    """A private Telemetry instance (tests must not perturb the process
    singleton other suites' Executors attach to)."""
    t = Telemetry(capacity=1024)
    t.enable()
    return t


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


def test_span_nesting_records_parent_and_order(tel):
    with tel.span("serving.request", rid=7) as outer:
        with tel.span("pipeline.pack"):
            pass
        with tel.span("pipeline.readback"):
            pass
        outer.set(outcome="done")
    spans = tel.spans()
    names = [s["name"] for s in spans]
    # children exit (and land in the ring) before the enclosing span
    assert names == ["pipeline.pack", "pipeline.readback", "serving.request"]
    by = {s["name"]: s for s in spans}
    assert by["pipeline.pack"]["args"]["parent"] == "serving.request"
    assert by["pipeline.readback"]["args"]["parent"] == "serving.request"
    assert "parent" not in by["serving.request"].get("args", {})
    assert by["serving.request"]["args"]["outcome"] == "done"
    # children are contained in the parent's [ts, ts+dur] window
    p = by["serving.request"]
    for c in ("pipeline.pack", "pipeline.readback"):
        assert by[c]["ts"] >= p["ts"]
        assert by[c]["ts"] + by[c]["dur"] <= p["ts"] + p["dur"] + 1e-3


def test_trace_id_inheritance_and_explicit_override(tel):
    with tel.trace("req-1"):
        assert tel.current_trace() == "req-1"
        with tel.span("serving.dispatch"):
            pass
        with tel.span("pipeline.pack", trace_id="req-override"):
            pass
    assert tel.current_trace() is None
    by = {s["name"]: s for s in tel.spans()}
    assert by["serving.dispatch"]["trace_id"] == "req-1"
    assert by["pipeline.pack"]["trace_id"] == "req-override"


def test_record_span_explicit_endpoints_and_track(tel):
    import time
    t0 = time.perf_counter()
    t1 = t0 + 0.25
    tel.record_span("serving.queue_wait", t0, t1, trace_id="req-3",
                    track="req:3", rid=3)
    (s,) = tel.spans()
    assert abs(s["dur"] - 0.25e6) < 1.0  # microseconds
    assert s["trace_id"] == "req-3"
    assert s["tid_key"][0] == ("track", "req:3")


def test_span_recording_is_thread_safe():
    tel = Telemetry(capacity=100_000)
    tel.enable()
    n_threads, per = 8, 400
    ctr = tel.counter("telemetry.test_total")

    def work(i):
        with tel.trace(f"t{i}"):
            for _ in range(per):
                with tel.span("campaign.tier"):
                    ctr.inc()

    threads = [threading.Thread(target=work, args=(i,)) for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert ctr.value == n_threads * per
    assert tel.spans_recorded == n_threads * per
    assert tel.spans_dropped == 0
    spans = tel.spans()
    assert len(spans) == n_threads * per
    # every span kept its own thread's trace binding
    per_trace = {}
    for s in spans:
        per_trace[s["trace_id"]] = per_trace.get(s["trace_id"], 0) + 1
    assert per_trace == {f"t{i}": per for i in range(n_threads)}


def test_ring_buffer_drops_oldest_and_counts():
    tel = Telemetry(capacity=8)
    tel.enable()
    for i in range(30):
        with tel.span("pipeline.pack", i=i):
            pass
    assert tel.spans_recorded == 30
    assert tel.spans_dropped == 22  # no silent truncation
    kept = [s["args"]["i"] for s in tel.spans()]
    assert kept == list(range(22, 30))  # oldest dropped first
    # the exported trace advertises the drop count
    events = tel.trace_events()
    assert len([e for e in events if e["ph"] == "X"]) == 8


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_p2_percentiles_track_numpy_on_known_distributions():
    rng = np.random.default_rng(7)
    for xs in (
        rng.lognormal(0.0, 1.0, 20_000),
        rng.standard_normal(20_000) * 3.0 + 10.0,
        rng.exponential(2.0, 20_000),
    ):
        h = Histogram("pipeline.test_ms", {})
        for x in xs:
            h.observe(float(x))
        for q in (0.5, 0.95, 0.99):
            est = h.percentile(q)
            ref = float(np.percentile(xs, q * 100))
            scale = float(np.percentile(np.abs(xs), 99)) or 1.0
            assert abs(est - ref) / scale < 0.05, (q, est, ref)
        snap = h.snapshot()
        assert snap["count"] == len(xs)
        assert snap["min"] == xs.min() and snap["max"] == xs.max()
        assert abs(snap["mean"] - xs.mean()) < 1e-6 * max(1.0, abs(xs.mean()))


def test_p2_small_samples_are_exact_order_statistics():
    h = Histogram("pipeline.test", {})
    for v in (5.0, 1.0, 3.0):
        h.observe(v)
    for q in (0.5, 0.95, 0.99):
        assert h.percentile(q) == pytest.approx(
            float(np.percentile([5.0, 1.0, 3.0], q * 100)))


def test_counter_gauge_semantics_and_reset():
    reg = MetricsRegistry()
    c = reg.counter("executor.invocations", target="vta")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = reg.gauge("serving.queue_depth")
    g.set(4)
    g.set_max(2)   # running max keeps the larger value
    assert g.value == 4.0
    g.set_max(9)
    assert g.value == 9.0
    # same (name, labels) -> same object; same name, new labels -> new
    assert reg.counter("executor.invocations", target="vta") is c
    assert reg.counter("executor.invocations", target="hlscnn") is not c
    with pytest.raises(TypeError):
        reg.gauge("executor.invocations", target="vta")
    reg.reset()
    assert c.value == 0.0 and g.value == 0.0


def test_registry_snapshot_and_prometheus_text(tel):
    tel.counter("campaign.mutants").inc(3)
    tel.histogram("serving.latency_ms").observe(5.0)
    snap = {e["name"]: e for e in tel.metrics_snapshot()}
    assert snap["campaign.mutants"]["value"] == 3.0
    assert snap["serving.latency_ms"]["count"] == 1
    assert "telemetry.spans_recorded" in snap
    text = tel.prometheus_text()
    assert "campaign_mutants 3.0" in text
    assert 'serving_latency_ms{quantile="0.50"}' in text


def test_metric_name_convention():
    assert check_metric_names([
        "serving.queue_depth", "pipeline.pack_s", "executor.invocations",
        "fragments.hits", "campaign.mutant_s", "telemetry.spans_dropped",
    ]) == []
    bad = ["Serving.queue", "queue_depth", "serving.", "serving.Queue",
           "unknown.layer", "serving.a-b"]
    assert check_metric_names(bad) == bad
    # the live process registries (executor/serving scopes attach here)
    assert TELEMETRY.check_names() == []


def test_attached_registries_are_weakly_held(tel):
    reg = MetricsRegistry(scope="executor")
    tel.attach(reg)
    reg.counter("executor.invocations").inc()
    assert any(e["name"] == "executor.invocations"
               for e in tel.metrics_snapshot())
    del reg
    import gc
    gc.collect()
    assert not any(e["name"] == "executor.invocations"
                   for e in tel.metrics_snapshot())


# ---------------------------------------------------------------------------
# Perfetto export
# ---------------------------------------------------------------------------


def test_trace_export_is_valid_trace_event_json(tel, tmp_path):
    with tel.trace("req-0"):
        with tel.span("serving.dispatch", app="resmlp"):
            with tel.span("pipeline.pack"):
                pass
    import time
    t0 = time.perf_counter()
    tel.record_span("serving.request", t0, t0 + 0.01, trace_id="req-0",
                    track="req:0", rid=0)
    path = str(tmp_path / "trace.json")
    tel.export_trace(path)
    data = json.load(open(path))
    assert set(data) >= {"traceEvents", "displayTimeUnit", "otherData"}
    events = data["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    ms = [e for e in events if e["ph"] == "M"]
    assert len(xs) == 3 and ms
    for e in xs:
        assert isinstance(e["name"], str)
        assert isinstance(e["ts"], (int, float))
        assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
        assert isinstance(e["tid"], int) and isinstance(e["pid"], int)
        assert e["cat"] in ("serving", "pipeline", "executor", "fragments",
                            "campaign", "telemetry")
    # every tid used by an event has a thread_name metadata record
    named = {e["tid"] for e in ms if e["name"] == "thread_name"}
    assert {e["tid"] for e in xs} <= named
    # the synthetic request lane is its own track, named req:0
    req = next(e for e in xs if e["name"] == "serving.request")
    lane_names = {e["tid"]: e["args"]["name"] for e in ms
                  if e["name"] == "thread_name"}
    assert lane_names[req["tid"]] == "req:0"
    # trace ids ride in args so Perfetto search correlates the flame
    assert all(e["args"]["trace_id"] == "req-0" for e in xs)


def test_drain_and_ingest_merge_worker_spans(tel):
    worker = Telemetry(capacity=64)
    worker.enable()
    with worker.span("campaign.tier", trace_id="vta:identity@wr_x",
                     tier="vt2"):
        pass
    shipped = worker.drain_spans()
    assert worker.spans() == []  # drained: worker memory stays bounded
    tel.ingest(shipped, source="worker3")
    (s,) = tel.spans()
    assert s["name"] == "campaign.tier"
    assert s["trace_id"] == "vta:identity@wr_x"
    assert s["tid_key"][1].startswith("worker3:")
    # merged spans export like native ones
    evs = [e for e in tel.trace_events() if e["ph"] == "X"]
    assert evs[0]["args"]["trace_id"] == "vta:identity@wr_x"


# ---------------------------------------------------------------------------
# disabled mode
# ---------------------------------------------------------------------------


def test_disabled_mode_is_zero_allocation_and_records_nothing():
    tel = Telemetry()
    assert not tel.enabled  # disabled by default
    s1 = tel.span("serving.request")
    s2 = tel.span("pipeline.pack")
    # one shared no-op object: the hot path allocates no span state
    assert s1 is s2
    for _ in range(100):
        with tel.span("serving.request") as s:
            s.set(outcome="ignored")
    import time
    tel.record_span("serving.request", time.perf_counter(),
                    time.perf_counter())
    assert tel.spans_recorded == 0
    assert tel.spans_dropped == 0
    assert tel.spans() == []


def test_enable_disable_roundtrip():
    tel = Telemetry()
    tel.enable(capacity=4)
    with tel.span("serving.dispatch"):
        pass
    assert tel.spans_recorded == 1
    tel.disable()
    with tel.span("serving.dispatch"):
        pass
    assert tel.spans_recorded == 1
    tel.reset()
    assert tel.spans() == [] and tel.spans_recorded == 0


# ---------------------------------------------------------------------------
# drift probes
# ---------------------------------------------------------------------------


def test_cost_model_drift_summary():
    m = CostModel("vta")
    assert m.drift_summary() is None
    m.record_drift(100.0, 200.0)   # 2x under-priced
    m.record_drift(100.0, 50.0)    # 2x over-priced
    d = m.drift_summary()
    assert d["n"] == 2
    assert d["ratio_geomean"] == pytest.approx(1.0)  # log-space symmetry
    assert d["ratio_min"] == pytest.approx(0.5)
    assert d["ratio_max"] == pytest.approx(2.0)
    assert d["calibrated"] == 0.0
    m.record_drift(0.0, 5.0)       # degenerate predictions are ignored
    assert m.drift_summary()["n"] == 2
    # fitting a new latency model invalidates drift observed under the old
    m.calibrate_from_timings([
        GroupTiming("vta", 4, 100, pack_s=0.01, sim_s=0.02),
        GroupTiming("vta", 8, 200, pack_s=0.02, sim_s=0.04),
    ])
    assert m.drift_summary() is None
    assert m.latency  # the fit itself landed


# ---------------------------------------------------------------------------
# instrumented layers
# ---------------------------------------------------------------------------


def test_executor_summaries_are_registry_views():
    import repro.accel  # noqa: F401  (registers the bundled targets)
    from repro.core import ir
    from repro.core.codegen import Executor

    rng = np.random.default_rng(0)
    w = (rng.standard_normal((8, 16)) * 0.1).astype(np.float32)
    b = np.zeros((8,), np.float32)
    prog = ir.call("fasr_linear", ir.Var("x", (4, 16)),
                   ir.Var("w", w.shape), ir.Var("b", b.shape))
    env = {"x": rng.standard_normal((4, 16)).astype(np.float32),
           "w": w, "b": b}
    ex = Executor("ila", engine="pipelined")
    ex.run_many(prog, [env])
    stages = ex.stage_seconds
    assert set(stages) == {"pack_s", "dispatch_s", "readback_s"}
    assert stages["pack_s"] > 0 and stages["dispatch_s"] > 0
    # the dict view IS the registry counters
    by_name = {e["name"]: e for e in ex.metrics.snapshot()}
    for k, v in stages.items():
        assert by_name[f"pipeline.{k}"]["value"] == v
    summ = ex.stats_summary()
    assert summ["flexasr"]["invocations"] == 1
    assert by_name["executor.invocations"]["value"] == 1
    assert summ["flexasr"]["commands"] == by_name["executor.commands"]["value"]
    assert ex.pipeline_summary()["groups"] == by_name["pipeline.groups"]["value"]
    ex.reset_stats()
    assert sum(ex.stage_seconds.values()) == 0.0
    assert ex.stats_summary().get("flexasr", {}).get("invocations", 0) == 0
    assert ex.metrics.names()  # metrics survive reset (zeroed, not dropped)


def test_serving_reject_reasons_are_aggregated_counters():
    import repro.accel  # noqa: F401  (registers the bundled targets)
    from repro.core import ir
    from repro.core.serving import CosimServer

    rng = np.random.default_rng(0)
    w = (rng.standard_normal((8, 16)) * 0.1).astype(np.float32)
    b = np.zeros((8,), np.float32)
    prog = ir.call("fasr_linear", ir.Var("x", (4, 16)),
                   ir.Var("w", w.shape), ir.Var("b", b.shape))
    srv = CosimServer(engine="compiled", queue_depth=1, seed=0)
    srv.add_program("tiny", prog, {"w": w, "b": b})
    # no dispatch thread started: submissions stay queued, so the second
    # and third hit the depth-1 admission bound
    h1 = srv.submit("tiny")
    h2 = srv.submit("tiny")
    h3 = srv.submit("tiny")
    assert h1.status == "queued"
    assert h2.rejected and h3.rejected
    assert srv.summary()["rejected"] == {"queue_full": 2}
    by_name = {}
    for e in srv.metrics.snapshot():
        by_name.setdefault(e["name"], []).append(e)
    (rej,) = by_name["serving.rejected"]
    assert rej["labels"] == {"reason": "queue_full"} and rej["value"] == 2.0
    assert by_name["serving.queue_depth"][0]["value"] == 1.0
    assert by_name["serving.submitted"][0]["value"] == 3.0
    srv.close(drain=False)


def test_sharded_campaign_merges_worker_spans():
    """A real spawn-worker campaign with tracing on: the workers' tier
    spans come back through the result queue and land in the parent's
    buffer on per-worker lanes, trace-correlated by mutant key."""
    from repro.core import campaign as campaign_mod

    TELEMETRY.reset()
    TELEMETRY.enable()
    try:
        result = campaign_mod.run_campaign_sharded(
            workers=1, mutant_timeout=300.0, trace_spans=True,
            targets=("vecunit",), faults=("identity",), apps=(),
            engine="compiled", devices_per_target=1,
            op_samples=1, vt2_n=2, seed=0, stat_calib_seeds=0,
        )
        assert len(result.reports) == 1
        spans = [s for s in TELEMETRY.spans() if s["name"] == "campaign.tier"]
        assert spans, "worker tier spans did not reach the parent"
        key = result.reports[0].key
        assert all(s["trace_id"] == key for s in spans)
        assert {s["args"]["tier"] for s in spans} >= {"static", "vt2"}
        assert all(s["tid_key"][1].startswith("worker") for s in spans)
        # escape-matrix counters aggregated parent-side
        snap = {(e["name"], tuple(sorted(e["labels"].items()))): e
                for e in TELEMETRY.metrics_snapshot()}
        assert snap[("campaign.mutants", ())]["value"] >= 1.0
        assert ("campaign.escaped", ()) in snap  # identity escapes
    finally:
        TELEMETRY.disable()
        TELEMETRY.reset()
