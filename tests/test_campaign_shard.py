"""The fault-tolerant sharded campaign runner and checkpoint/resume.

* determinism: the sharded runner's escape matrix hashes identically
  (`matrix_digest`) to the serial runner's for the same config — worker
  count is a throughput knob, never a semantics knob;
* robustness (the PR 6 acceptance scenario): a campaign containing a
  deliberately crashing mutant AND a deliberately hanging mutant
  completes every other mutant, records `crash`/`timeout` outcomes, and
  leaves the parent registries bit-identical;
* checkpoint/resume: a campaign resumed from a partial checkpoint
  produces a bit-identical matrix digest; a checkpoint from a different
  config (fingerprint mismatch) refuses to resume;
* seed reproducibility: same seed -> same digest, different seed ->
  different fingerprint.

Sharded tests run apps-free (per-worker app training would dominate on
small CI hosts); the statistical tier has its own suite
(test_campaign_stat.py).
"""
import json
import os

import pytest

from repro.core import campaign as campaign_mod, ir
from repro.core.ila import TARGETS


def _registry_snapshot():
    return (
        [(name, id(t)) for name, t in TARGETS._targets.items()],
        {op: (id(t), id(i)) for op, (t, i) in TARGETS._by_op.items()},
        {op: id(spec) for op, spec in ir._ACCEL_EXT.items()},
        set(ir.ACCEL_OPS),
    )


_BASE = dict(
    targets=("vecunit",),
    faults=("identity", "drop_cfg", "trunc_width"),
    apps=(), engine="compiled", devices_per_target=1,
    op_samples=1, vt2_n=2, seed=0, stat_calib_seeds=0,
)


def test_sharded_matrix_digest_matches_serial():
    serial = campaign_mod.run_campaign(**_BASE)
    sharded = campaign_mod.run_campaign_sharded(
        workers=2, mutant_timeout=300.0, **_BASE)
    assert campaign_mod.matrix_digest(serial) == \
        campaign_mod.matrix_digest(sharded)
    # the digest survives a JSON round-trip (what the CI legs compare)
    assert campaign_mod.matrix_digest(json.loads(
        json.dumps(sharded.to_json()))) == campaign_mod.matrix_digest(serial)


def test_sharded_campaign_survives_crash_and_hang(tmp_path):
    """The acceptance scenario: one mutant raises, one hangs; the campaign
    completes the rest, records crash/timeout outcomes, checkpoints, and
    the parent process registries are untouched."""
    before = _registry_snapshot()
    ck = str(tmp_path / "CAMPAIGN.json")
    result = campaign_mod.run_campaign_sharded(
        workers=2, mutant_timeout=6.0, checkpoint=ck,
        **dict(_BASE, faults=("identity", "drop_cfg", "crash_inject",
                              "hang_inject")),
    )
    assert _registry_snapshot() == before
    by_fault = {r.fault: r for r in result.reports}
    assert len(result.reports) == 4
    assert by_fault["crash_inject"].outcome == "crash"
    assert by_fault["crash_inject"].detected_at == "crash"
    assert "crash_inject" in by_fault["crash_inject"].error
    assert by_fault["hang_inject"].outcome == "timeout"
    assert by_fault["hang_inject"].detected_at == "timeout"
    # the healthy mutants completed normally around the failures
    assert by_fault["identity"].outcome == "ok"
    assert by_fault["identity"].detected_at is None
    assert by_fault["drop_cfg"].outcome == "ok"
    assert by_fault["drop_cfg"].detected_at is not None
    s = result.summary()
    assert s["crashes"] == ["vecunit:crash_inject@wr_a"]
    assert s["timeouts"] == ["vecunit:hang_inject@wr_a"]
    # final checkpoint is the complete (non-partial) result
    data = json.load(open(ck))
    assert data["partial"] is False
    assert campaign_mod.matrix_digest(data) == \
        campaign_mod.matrix_digest(result)


def test_resume_from_partial_checkpoint_is_bit_identical(tmp_path):
    ck = str(tmp_path / "CAMPAIGN.json")
    full = campaign_mod.run_campaign(checkpoint=ck, **_BASE)
    data = json.load(open(ck))
    assert data["partial"] is False and len(data["mutants"]) == 3
    # craft the checkpoint an interrupted run would have left behind
    partial = dict(data, partial=True, mutants=data["mutants"][:1])
    with open(ck, "w") as f:
        json.dump(partial, f)
    resumed = campaign_mod.run_campaign(checkpoint=ck, resume=True, **_BASE)
    assert campaign_mod.matrix_digest(resumed) == \
        campaign_mod.matrix_digest(full)
    assert json.load(open(ck))["partial"] is False
    # a fully-completed checkpoint resumes without re-running anything
    again = campaign_mod.run_campaign(checkpoint=ck, resume=True, **_BASE)
    assert campaign_mod.matrix_digest(again) == \
        campaign_mod.matrix_digest(full)


def test_resume_refuses_foreign_fingerprint(tmp_path):
    ck = str(tmp_path / "CAMPAIGN.json")
    campaign_mod.run_campaign(checkpoint=ck, **_BASE)
    with pytest.raises(ValueError, match="fingerprint"):
        campaign_mod.run_campaign(checkpoint=ck, resume=True,
                                  **dict(_BASE, seed=1))


def test_same_seed_reproduces_digest_different_seed_changes_fingerprint():
    a = campaign_mod.run_campaign(**_BASE)
    b = campaign_mod.run_campaign(**_BASE)
    assert campaign_mod.matrix_digest(a) == campaign_mod.matrix_digest(b)
    c = campaign_mod.run_campaign(**dict(_BASE, seed=1))
    assert a.to_json()["fingerprint"] != c.to_json()["fingerprint"]
    assert campaign_mod.matrix_digest(a) != campaign_mod.matrix_digest(c)


def test_checkpoint_write_is_atomic(tmp_path):
    """The tmp file never survives a successful save, and the checkpoint
    parses even though it is rewritten after every mutant."""
    ck = str(tmp_path / "CAMPAIGN.json")
    campaign_mod.run_campaign(checkpoint=ck, **_BASE)
    assert os.path.exists(ck)
    assert not os.path.exists(ck + ".tmp")
    json.load(open(ck))
