"""Fused fast-path tier: target-specific coverage.

The registry conformance suite (test_target_conformance.py) asserts
engine="fused" parity within each intrinsic's *declared* tolerance for
every target, lowering, and device count without naming any backend. This
file pins down the stronger per-target guarantees the fused runners
actually make:

* FlexASR LinearLayer and HLSCNN conv2d XLA-fallback runners replicate the
  compiled tier's arithmetic step for step — bit-exact, not just in-tol;
* the FlexASR LSTM runner hoists the input projection out of the scan
  (fp32 reassociation), so it is held to a tight rel-Frobenius bound far
  below the intrinsic tolerance rather than exactness;
* the Pallas lowerings (forced via REPRO_FUSED_PALLAS=1, interpret-mode on
  CPU) track compiled within the same tight bound;
* runner resolution plumbing: ``declare_fused`` factories fire per
  fragment signature, the memo is lowering-keyed, foreign-ILA fragments
  (campaign mutants sharing a golden key) never take the fast path, and
  ``REPRO_ENGINE=fused`` selects the engine process-wide.
"""
import numpy as np
import pytest

from repro.accel import flexasr as fa, hlscnn as hc
from repro.core import ir, validate
from repro.core.codegen import Executor
from repro.core.ila import ILA, CompiledFragment

#: fused-vs-compiled bound for reassociated (non-bit-exact) lowerings:
#: both sides quantize to the same lattice, so only fp32 summation-order
#: noise below the lattice step survives
TIGHT = 1e-4


def _run(op, env_args, attrs, engine, options, **kw):
    vs = tuple(ir.Var(f"_{i}", a.shape) for i, a in enumerate(env_args))
    expr = ir.call(op, *vs, **attrs)
    env = {f"_{i}": a for i, a in enumerate(env_args)}
    ex = Executor("ila", engine=engine, target_options=options, **kw)
    return np.asarray(ex.run(expr, env)), ex


def _flexasr_linear_args(seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((64, 96)).astype(np.float32)
    w = (rng.standard_normal((48, 96)) * 0.1).astype(np.float32)
    b = rng.standard_normal((48,)).astype(np.float32)
    return [x, w, b], {}


def _flexasr_lstm_args(seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.standard_normal((24, 1, 48)).astype(np.float32)
    wi = (rng.standard_normal((4 * 32, 48)) * 0.2).astype(np.float32)
    wh = (rng.standard_normal((4 * 32, 32)) * 0.2).astype(np.float32)
    b = rng.standard_normal((4 * 32,)).astype(np.float32)
    return [xs, wi, wh, b], {}


def _hlscnn_conv_args(seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((1, 10, 10, 6)).astype(np.float32)
    w = (rng.standard_normal((3, 3, 6, 8)) * 0.1).astype(np.float32)
    return [x, w], {"strides": (1, 1), "padding": (0, 0)}


CASES = [
    pytest.param("fasr_linear", _flexasr_linear_args,
                 {"flexasr": {}}, True, id="flexasr-linear"),
    pytest.param("fasr_lstm", _flexasr_lstm_args,
                 {"flexasr": {}}, False, id="flexasr-lstm"),
    pytest.param("hlscnn_conv2d", _hlscnn_conv_args,
                 {"hlscnn": {"wgt_bits": 16}}, True, id="hlscnn-conv2d"),
]


@pytest.mark.parametrize("op,make,options,exact", CASES)
def test_xla_fallback_replicates_compiled(op, make, options, exact, monkeypatch):
    """Forced XLA lowering: linear/conv replicate the compiled arithmetic
    bit-for-bit; the LSTM's hoisted projection stays within TIGHT."""
    monkeypatch.setenv("REPRO_FUSED_FALLBACK", "1")
    args, attrs = make()
    ref, _ = _run(op, args, attrs, "compiled", options)
    got, ex = _run(op, args, attrs, "fused", options)
    if exact:
        np.testing.assert_array_equal(ref, got)
    else:
        assert validate.frob_rel_err(ref, got) <= TIGHT
    # the fast path actually fired: the owning target resolved a runner
    tname = next(iter(options))
    assert ex.cache_info()[tname]["fused_runners"] >= 1


@pytest.mark.parametrize("op,make,options,exact", CASES)
def test_pallas_lowering_tracks_compiled(op, make, options, exact, monkeypatch):
    """Forced Pallas lowering (interpret-mode on CPU hosts): af_gemm /
    fx_gemm legs track the compiled oracle within TIGHT. The LSTM runner
    has no Pallas leg (no gate re-quantization to fuse) and rides its XLA
    lowering — covered here for the parity contract all the same."""
    monkeypatch.setenv("REPRO_FUSED_PALLAS", "1")
    args, attrs = make()
    ref, _ = _run(op, args, attrs, "compiled", options)
    got, _ = _run(op, args, attrs, "fused", options)
    assert validate.frob_rel_err(ref, got) <= TIGHT


def test_fused_batch_matches_per_sample_numerics():
    """run_many through the fused engine keeps per-sample numerics: each
    sample's exponent windows travel in its own data stream, so a batch
    mixing two distinct samples reproduces the singleton runs exactly."""
    args1, attrs = _flexasr_linear_args(1)
    args2, _ = _flexasr_linear_args(2)
    x1, w, b = args1
    x2 = args2[0]
    vs = (ir.Var("_0", x1.shape), ir.Var("_1", w.shape), ir.Var("_2", b.shape))
    expr = ir.call("fasr_linear", *vs)
    envs = [{"_0": x1, "_1": w, "_2": b}, {"_0": x2, "_1": w, "_2": b}]
    singles = [
        np.asarray(Executor("ila", engine="fused").run(expr, e)) for e in envs
    ]
    batched = Executor("ila", engine="fused").run_many(expr, envs)
    for s, m in zip(singles, batched):
        np.testing.assert_array_equal(s, np.asarray(m))


def test_fused_runner_refuses_foreign_ila():
    """A fragment bound to a different ILA instance (the fault campaign's
    mutant clones share the golden fragment key) must not resolve a fused
    runner — the runner is built from golden build-time meta and would mask
    the mutation."""
    args, _ = _flexasr_linear_args()
    _x, w, b = args
    frag = fa.linear_fragment(w, b)
    assert fa.TARGET.fused_runner(frag) is not None
    foreign = CompiledFragment(
        ILA("foreign", vwidth=16), frag.key, frag.setup, dict(frag.meta)
    )
    assert fa.TARGET.fused_runner(foreign) is None


def test_fused_memo_is_lowering_keyed(monkeypatch):
    """Flipping REPRO_FUSED_FALLBACK re-resolves the runner: the memo key
    includes the active lowering, so env changes between prepare and
    dispatch never serve a stale lowering."""
    args, _ = _hlscnn_conv_args()
    _x, w = args
    frag = hc.conv2d_fragment(w, (10, 10, 6), (1, 1), wgt_bits=16)
    monkeypatch.setenv("REPRO_FUSED_FALLBACK", "1")
    r_xla = hc.TARGET.fused_runner(frag)
    assert r_xla is not None and r_xla.lowering == "xla"
    monkeypatch.delenv("REPRO_FUSED_FALLBACK")
    monkeypatch.setenv("REPRO_FUSED_PALLAS", "1")
    r_pl = hc.TARGET.fused_runner(frag)
    assert r_pl is not None and r_pl.lowering == "pallas"
    assert r_pl is not r_xla


def test_repro_engine_env_selects_fused(monkeypatch):
    """REPRO_ENGINE=fused is picked up by every Executor constructed
    without an explicit engine (the cosim/serving helpers' path)."""
    monkeypatch.setenv("REPRO_ENGINE", "fused")
    ex = Executor("ila")
    assert ex.engine == "fused"
    args, attrs = _hlscnn_conv_args()
    ref, _ = _run("hlscnn_conv2d", args, attrs, "compiled",
                  {"hlscnn": {"wgt_bits": 16}})
    vs = tuple(ir.Var(f"_{i}", a.shape) for i, a in enumerate(args))
    env = {f"_{i}": a for i, a in enumerate(args)}
    got = np.asarray(
        Executor("ila", target_options={"hlscnn": {"wgt_bits": 16}}).run(
            ir.call("hlscnn_conv2d", *vs, **attrs), env
        )
    )
    assert validate.frob_rel_err(ref, got) <= TIGHT
