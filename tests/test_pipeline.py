"""Pipelined-engine unit tests: the Executor's stage plumbing, the
latency-calibrated cost model, stats reset semantics, the host/dispatch
split of the batched simulation tiers, and the bench JSON writer.

Target-parameterized pipelined bit-exactness/determinism/mesh coverage
lives in tests/test_target_conformance.py; these tests pin the pieces the
conformance suite exercises only indirectly.
"""
import json
import types

import numpy as np
import pytest

from repro.accel import flexasr as fa
from repro.accel.target import CostModel, GroupTiming
from repro.core import ila as ila_mod, ir
from repro.core.codegen import Executor


def _linear_program(T=8, I=32, O=16, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((T, I)).astype(np.float32)
    w = (rng.standard_normal((O, I)) * 0.1).astype(np.float32)
    b = (rng.standard_normal((O,)) * 0.1).astype(np.float32)
    expr = ir.call("fasr_linear", ir.Var("x", x.shape), ir.Var("w", w.shape),
                   ir.Var("b", b.shape))
    return expr, {"x": x, "w": w, "b": b}


# ---------------------------------------------------------------------------
# host/dispatch split (ILA + CompiledFragment)
# ---------------------------------------------------------------------------


def test_prepare_batch_run_prepared_matches_run_batch():
    """The two-phase (pack worker / dispatch thread) path is the same
    computation as the one-shot run_batch."""
    rng = np.random.default_rng(0)
    w = (rng.standard_normal((16, 32)) * 0.1).astype(np.float32)
    b = np.zeros((16,), np.float32)
    frag = fa.linear_fragment(w, b)
    datas = [fa.pack_linear_data(
        frag, rng.standard_normal((8, 32)).astype(np.float32)) for _ in range(3)]
    import jax

    ref = np.asarray(jax.vmap(fa.read_full)(frag.run_batch(datas)))
    prepared = frag.prepare_batch(datas)
    out = np.asarray(jax.vmap(fa.read_full)(frag.run_prepared(prepared)))
    np.testing.assert_array_equal(ref, out)


def test_mesh_pad_rounds_to_mesh_multiple(monkeypatch):
    """Batch bucketing under a mesh pads to a multiple of the mesh size."""
    assert ila_mod.mesh_pad(8) == 8  # no mesh: identity
    fake = types.SimpleNamespace(devices=np.zeros(3))
    monkeypatch.setattr(ila_mod, "_STREAM_MESH", fake)
    assert ila_mod.mesh_pad(8) == 9
    assert ila_mod.mesh_pad(3) == 3
    assert ila_mod.mesh_pad(1) == 3


def test_set_stream_mesh_single_device_disables(monkeypatch):
    """spec=1 can never shard: the mesh is disabled, not built trivially."""
    assert ila_mod.set_stream_mesh(1) is None
    assert ila_mod.stream_mesh() is None


# ---------------------------------------------------------------------------
# Executor stats: reset semantics and stage timers
# ---------------------------------------------------------------------------


def test_reset_stats_clears_devices_timers_and_timings():
    """reset_stats() must also zero per-device accumulated cycles/jobs and
    the per-stage timers, so post-reset stats_summary() utilization only
    reflects post-reset work (the serving path resets between warmup and
    measured requests)."""
    expr, env = _linear_program()
    ex = Executor("ila", engine="compiled", devices_per_target=2)
    ex.run_many(expr, [env, env, env])
    assert ex.stats and ex.group_timings
    assert sum(ex.stage_seconds.values()) > 0
    devs = [d for ds in ex.devices._devices.values() for d in ds]
    assert sum(d.n_jobs for d in devs) >= 3
    ex.reset_stats()
    assert not ex.stats and not ex.group_timings
    assert sum(ex.stage_seconds.values()) == 0.0
    for d in devs:
        assert d.busy_cycles == 0.0 and d.n_jobs == 0 and d.n_groups == 0
    summary = ex.stats_summary()
    for row in summary.values():
        for dev_row in row.get("devices", {}).values():
            assert dev_row["jobs"] == 0 and dev_row["est_cycles"] == 0.0
    # the warm caches survive the reset: a re-run records fresh stats
    ex.run_many(expr, [env])
    assert ex.stats
    devs_after = ex.stats_summary()["flexasr"]["devices"]
    assert sum(r["jobs"] for r in devs_after.values()) == 1


def test_pipeline_summary_reports_stage_seconds():
    expr, env = _linear_program()
    ex = Executor("ila", engine="pipelined", pipeline_chunk=2)
    ex.run_many(expr, [env] * 4)
    stages = ex.pipeline_summary()
    assert stages["pack_s"] > 0 and stages["dispatch_s"] > 0
    assert stages["groups"] >= 1
    assert stages["overlap_s"] <= stages["pack_s"]


# ---------------------------------------------------------------------------
# latency-calibrated cost model
# ---------------------------------------------------------------------------


def test_calibrate_from_timings_fits_affine_stage_models():
    """Synthetic timings with known slopes/intercepts are recovered (in us,
    1 cycle == 1 us for the fitted job_cycles model) and the pipelined job
    price is max(pack, sim) vs their serial sum. cycles_per_command stays
    in analytic units: estimate() feeds cross-target extraction, which must
    not compare microseconds against competitors' analytic cycles."""
    m = CostModel("synth", cycles_per_command=7.0)
    timings = [
        GroupTiming("synth", 1, n, pack_s=3e-6 * n + 1e-4,
                    sim_s=1e-6 * n + 2e-4)
        for n in (100, 500, 1000, 4000)
    ] + [GroupTiming("other", 1, 50, pack_s=1.0, sim_s=1.0)]  # ignored
    fit = m.calibrate_from_timings(timings)
    assert fit["sim_us_per_command"] == pytest.approx(1.0, rel=1e-3)
    assert fit["sim_overhead_us"] == pytest.approx(200.0, rel=1e-3)
    assert fit["pack_us_per_command"] == pytest.approx(3.0, rel=1e-3)
    assert m.cycles_per_command == 7.0  # analytic units untouched
    n = 1000.0
    serial = m.job_cycles(n)
    overlapped = m.job_cycles(n, pipelined=True)
    assert serial == pytest.approx((1000 + 200) + (3000 + 100), rel=1e-3)
    assert overlapped == pytest.approx(3000 + 100, rel=1e-3)  # pack-bound


def test_calibrate_from_timings_single_group_falls_back_to_ratio():
    m = CostModel("synth")
    fit = m.calibrate_from_timings([GroupTiming("synth", 1, 200, sim_s=4e-4)])
    assert fit["sim_us_per_command"] == pytest.approx(2.0, rel=1e-6)
    assert fit["sim_overhead_us"] == 0.0
    assert m.calibrate_from_timings([]) == fit  # no new data: fit unchanged


def test_executor_calibrate_from_timings_end_to_end():
    """Synchronous runs record per-group sim timings; calibration turns
    them into a measured-latency cost model for the owning target."""
    expr, env = _linear_program()
    ex = Executor("ila", engine="compiled")
    ex.run_many(expr, [env, env])
    fits = ex.calibrate_from_timings()
    assert "flexasr" in fits
    assert fits["flexasr"]["sim_us_per_command"] > 0
    assert fits["flexasr"]["pack_us_per_command"] > 0
    from repro.core.ila import TARGETS

    target_model = TARGETS.get("flexasr").cost_model
    try:
        assert target_model.latency  # stored on the model for the scheduler
    finally:
        target_model.latency.clear()  # leave the process-wide model clean


# ---------------------------------------------------------------------------
# bench JSON writer
# ---------------------------------------------------------------------------


def test_write_bench_json_merges_and_rewrites(tmp_path):
    import sys

    sys.path.insert(0, "benchmarks")
    try:
        from _bench_io import write_bench_json
    finally:
        sys.path.pop(0)
    path = str(tmp_path / "BENCH_cosim.json")
    write_bench_json([("a", 1.0, "one"), ("b", 2.0, "two")], path=path)
    write_bench_json([("b", 3.0, "updated")], path=path)  # merge
    data = json.load(open(path))
    assert data["schema"] == 1 and "generated_unix" in data
    assert data["rows"]["a"]["us_per_call"] == 1.0
    assert data["rows"]["b"] == {"us_per_call": 3.0, "derived": "updated"}
    write_bench_json([("c", 4.0, "only")], path=path, fresh=True)
    data = json.load(open(path))
    assert set(data["rows"]) == {"c"}
