"""Optional-`hypothesis` shim: property tests skip cleanly when absent.

``from hypothesis import given, settings, strategies as st`` made three test
modules fail at *collection* on machines without hypothesis, taking their
plain unit tests down with them. Import the same names from this module
instead: with hypothesis installed they are the real thing; without it,
``@given(...)`` turns the test into a clean skip and the rest of the module
still runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    _skip = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_args, **_kwargs):
        return lambda fn: _skip(fn)

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Stands in for `strategies`; only evaluated while building the
        decorator arguments of tests that will be skipped anyway."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
