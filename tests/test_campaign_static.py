"""Campaign tier 0 (static verifier) integration.

Protocol faults that PR 5/6 could only catch by simulating — decode
aliasing and command reordering — must now be flagged by the static tier
with zero simulated commands, and under ``ladder="escalate"`` every
simulated tier below is skipped. Faults outside static scope (ILA-update
wrappers, bulk numeric payload corruption) must keep their established
tier placements: the static tier passes them down the ladder.
"""
import pytest

import repro.accel  # noqa: F401
from repro.core.campaign import TIER_ORDER, format_matrix, run_campaign


@pytest.fixture(scope="module")
def static_campaign():
    return run_campaign(
        targets=["vecunit", "hlscnn"],
        faults=["identity", "decode_alias", "cmd_reorder", "drop_cfg"],
        apps=(),
        engine="compiled",
        devices_per_target=1,
        ladder="escalate",
        op_samples=1,
        stat_calib_seeds=0,
        seed=0,
    )


def test_static_is_tier_zero():
    assert TIER_ORDER[0] == "static"


def test_every_decode_alias_mutant_caught_statically(static_campaign):
    aliases = [m for m in static_campaign.reports if m.fault == "decode_alias"]
    assert aliases, "fault library produced no decode_alias mutants"
    for m in aliases:
        assert m.detected_at == "static", (
            f"{m.key} first detected at {m.detected_at}"
        )
        assert m.tiers["static"].detected is True
        assert "opcode stream rewritten" in m.tiers["static"].detail


def test_cmd_reorder_sensitive_mutant_caught_statically(static_campaign):
    reorders = [m for m in static_campaign.reports if m.fault == "cmd_reorder"]
    assert reorders, "fault library produced no cmd_reorder mutants"
    caught = [m for m in reorders if m.detected_at == "static"]
    assert caught, "no cmd_reorder mutant detected at the static tier"
    assert any("order-sensitive" in m.tiers["static"].detail for m in caught)


def test_static_detection_skips_every_simulated_tier(static_campaign):
    for m in static_campaign.reports:
        if m.detected_at != "static":
            continue
        for tier in ("vt2", "frag_sim", "op_diff", "app", "stat"):
            r = m.tiers.get(tier)
            assert r is None or r.detected is None, (
                f"{m.key}: simulated tier {tier} ran after static detection"
            )
            if r is not None:
                assert "skipped" in r.detail


def test_identity_passes_static_tier(static_campaign):
    idents = [m for m in static_campaign.reports if m.fault == "identity"]
    assert idents
    for m in idents:
        assert m.tiers["static"].detected is False
        assert m.detected_at is None


def test_wrapper_faults_stay_out_of_static_scope(static_campaign):
    drops = [m for m in static_campaign.reports if m.fault == "drop_cfg"]
    assert drops
    for m in drops:
        assert m.tiers["static"].detected is False
        assert "out of static scope" in m.tiers["static"].detail
        # the simulated ladder still catches the dropped configuration
        assert m.detected_at not in (None, "static"), (
            f"{m.key}: expected a simulated-tier detection, "
            f"got {m.detected_at}"
        )


def test_matrix_and_json_gain_static_column(static_campaign):
    matrix = format_matrix(static_campaign)
    assert "static" in matrix.splitlines()[0] or "static" in matrix
    d = static_campaign.to_json()
    tiers_seen = {t for m in d["mutants"] for t in m["tiers"]}
    assert "static" in tiers_seen
    assert "static" in d["summary"]["first_detection_by_tier"]
    n_static = d["summary"]["first_detection_by_tier"]["static"]
    n_alias = sum(1 for m in static_campaign.reports
                  if m.fault == "decode_alias")
    assert n_static >= n_alias + 1  # all aliases + >= 1 reorder


def test_golden_ilas_not_simulated_by_static_tier(static_campaign):
    """The static tier classifies numpy streams; a fresh analysis of the
    same mutants must not advance any golden ILA trace counter."""
    from repro.core import faults, ilalint
    from repro.core.ila import TARGETS

    t = TARGETS.get("vecunit")
    probes = ilalint.probe_streams(t, seed=0, samples=1)
    before = (t.ila.n_traces_single, t.ila.n_traces_batch)
    for inst in faults.fault_instances(t, ["decode_alias", "cmd_reorder"]):
        hx = inst.host_xform()
        assert hx is not None
        ilalint.analyze_mutation(t, probes, hx)
    assert (t.ila.n_traces_single, t.ila.n_traces_batch) == before
