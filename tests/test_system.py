"""End-to-end behaviour tests for the D2A system (paper pipeline)."""
import numpy as np

from repro.core import apps, ir
from repro.core.codegen import Executor
from repro.core.compile import compile_program


def test_end_to_end_compile_and_cosim_tiny():
    """Compile a tiny MLP app through flexible matching, execute it on the
    ILA co-simulation path, and check the result tracks the fp32 host run."""
    expr, params = apps.build_resmlp(layers=1, n_patch=4, d=32)
    res = compile_program(expr, targets=("flexasr", "vta"), flexible=True)
    assert sum(res.accelerator_calls.values()) > 0
    rng = np.random.default_rng(0)
    env = dict(params)
    env["x"] = rng.standard_normal((4, 32)).astype(np.float32)
    ref = np.asarray(Executor("ideal").run(res.program, env)).reshape(-1)
    ila = Executor("ila")
    got = np.asarray(ila.run(res.program, env)).reshape(-1)
    # numerics deviate a few percent but the argmax class is stable
    rel = np.linalg.norm(ref - got) / np.linalg.norm(ref)
    assert rel < 0.25
    assert len(ila.stats) == sum(res.accelerator_calls.values())


def test_kernel_mode_matches_ila_mode_for_linear():
    """Deployment fast path (Pallas) == co-simulation path (ILA) bit-for-bit
    on the FlexASR linear op."""
    a = ir.Var("a", (8, 32))
    w = ir.Var("w", (16, 32))
    c = ir.Var("c", (16,))
    prog = ir.call("fasr_linear", a, w, c)
    rng = np.random.default_rng(1)
    env = {"a": rng.standard_normal((8, 32)).astype(np.float32),
           "w": (rng.standard_normal((16, 32)) * 0.1).astype(np.float32),
           "c": (rng.standard_normal((16,)) * 0.1).astype(np.float32)}
    out_ila = np.asarray(Executor("ila").run(prog, env))
    out_kern = np.asarray(Executor("kernel").run(prog, env))
    np.testing.assert_array_equal(out_ila, out_kern)


def test_invocation_stats_collected():
    """The per-invocation debugging stats of Section 4.4.2 are recorded."""
    expr, params = apps.build_resnet20(blocks=1)
    res = compile_program(expr, targets=("hlscnn",), flexible=True)
    rng = np.random.default_rng(0)
    env = dict(params)
    env["x"] = rng.standard_normal((1, 12, 12, 8)).astype(np.float32)
    ex = Executor("ila", target_options={"hlscnn": {"wgt_bits": 8}})
    ex.run(res.program, env)
    convs = [s for s in ex.stats if s.op == "hlscnn_conv2d"]
    assert convs and all(s.rel_err > 0 for s in convs)
    assert all(np.isfinite((s.out_min, s.out_max)).all() for s in convs)
