"""Compilation-results validation tests (VT1 / VT2 / VT3, Table 2/3 analogues)."""
import pytest

from repro.core import ir, validate


class TestVT1:
    @pytest.mark.parametrize("op", list(validate.VT1_CASES))
    def test_ir_ila_vs_independent_impl(self, op):
        assert validate.vt1_check(op, n=5)


class TestVT2:
    @pytest.mark.parametrize("case", validate.vt2_cases(8, 32), ids=lambda c: c.name)
    def test_fragment_equivalence_abstract_types(self, case):
        assert validate.vt2_check(case, n=5)

    def test_exhaustive_finite_domain(self):
        """Complete check over the full {-1,0,1} lattice (Table 3 analogue)."""
        T = ir.Var("T", (2, 2))
        case = validate.VT2Case(
            "maxpool-2x2",
            ir.call("reduce_max", ir.call("windows", T, wh=2, ww=1, sh=2, sw=1), axis=(2, 3)),
            ir.call("fasr_load", ir.call("fasr_maxpool", ir.call("fasr_store", T))),
            {"T": (2, 2)},
        )
        ok, n = validate.vt2_exhaustive(case, (-1.0, 0.0, 1.0))
        assert ok and n == 3 ** 4

    def test_exhaustive_catches_wrong_mapping(self):
        """Soundness of the checker: a deliberately wrong mapping fails."""
        T = ir.Var("T", (2, 2))
        case = validate.VT2Case(
            "wrong",
            ir.call("reduce_max", ir.call("windows", T, wh=2, ww=1, sh=2, sw=1), axis=(2, 3)),
            ir.call("fasr_load", ir.call("fasr_meanpool", ir.call("fasr_store", T))),
            {"T": (2, 2)},
        )
        ok, _ = validate.vt2_exhaustive(case, (-1.0, 0.0, 1.0))
        assert not ok


class TestVT3:
    def test_all_declared_ila_vs_implementation_checks(self):
        """Every VT3 check each registered target declares must pass (e.g.
        VTA GEMM and FlexASR LinearLayer agree bit-exactly with their
        numerics-matched Pallas kernels)."""
        results = validate.vt3_results()
        assert any(checks for checks in results.values())
        for tname, checks in results.items():
            for cname, (ok, worst) in checks.items():
                assert ok, f"{tname}:{cname} worst abs deviation {worst}"


class TestMappingValidation:
    def test_table2_magnitudes(self):
        """Quick (n=5) version of Table 2: VTA GEMM exact; FlexASR ops a few
        percent; maxpool exact on device-representable inputs."""
        rows = validate.validate_mappings(n_inputs=5)
        by_op = {(r.accelerator, r.operation): r for r in rows}
        assert by_op[("VTA", "GEMM")].avg_err == 0.0
        assert by_op[("FlexASR", "MaxPool")].avg_err == 0.0
        assert 0 < by_op[("FlexASR", "LinearLayer")].avg_err < 0.06
        assert 0 < by_op[("FlexASR", "Attention")].avg_err < 0.10
        assert 0 < by_op[("HLSCNN", "Conv2D")].avg_err < 0.05
