"""Registry conformance suite: every registered AcceleratorTarget, zero
bespoke per-backend tests.

Parameterized over **all** targets in ``repro.core.ila.TARGETS`` and every
intrinsic they declare (via each intrinsic's ``sample`` generator, which
draws random operands within the target's declared capability limits):

* ideal-vs-numerics (VT1-style): the ILA co-simulation of each intrinsic
  tracks the fp32 IR-interpreter oracle within the intrinsic's declared
  tolerance;
* engine parity: eager per-command simulation == jit scan == compiled
  fragment fast path == batched ``run_many``, bit-for-bit;
* rewrite soundness: each target-declared VT2 fragment pair agrees under
  ideal semantics, and compiling the compiler-IR side against that target
  alone extracts the intrinsic while preserving interpretation;
* coverage: every registered target receives >= 1 offload from at least one
  of the stock applications under a default (all-targets) compile;
* cost conformance: every registered target declares a CostModel pricing
  every intrinsic it claims; costs are positive and monotone in batch size;
  calibration fits predicted command counts to the Executor's observations;
* selection policy: when two targets claim one op, the default policy picks
  the target whose CostModel is cheaper and ``forbid``/``prefer`` flip the
  mapping (checked with synthetic competing targets, registered and
  unregistered inside the test — no bundled backend is named);
* multi-device scheduling: with ``devices_per_target=2`` results stay
  bit-exact and ``stats_summary`` reports per-device utilization;
* pipelined engine: async pack/sim pipelining is bit-exact vs the compiled
  engine for every target and device count, deterministic across runs
  (identical results AND stable assemble/stat order), and the mesh-sharded
  batch tier matches unsharded execution (skipped on single-device hosts —
  CI forces 4 virtual devices with XLA_FLAGS).

* fused engine: ``engine="fused"`` matches the compiled oracle within each
  intrinsic's declared tolerance for every target and device count —
  targets registering fused runners (``declare_fused``) take the fast
  path, everything else falls back to per-group compiled execution — and
  the forced XLA-fallback lowering (``REPRO_FUSED_FALLBACK=1``) is
  exercised explicitly so the ``jnp`` leg stays covered even on hosts
  where Pallas lowers natively.

Set ``REPRO_DEVICES_PER_TARGET=2`` (as CI does in a dedicated step) to run
the *whole* suite through the multi-device scheduler path, and/or
``REPRO_ENGINE=pipelined`` / ``REPRO_ENGINE=fused`` (every Executor
constructed without an explicit engine — including the ones inside
cosim/serving helpers — picks it up) to run it through the async pipeline
or the fused fast path.

A new backend that registers through ``repro.accel.target`` is covered here
automatically — this file never names a target.
"""
import os

import numpy as np
import pytest

from repro.core import apps, ir, validate
from repro.core.codegen import Executor
from repro.core.compile import SelectionPolicy, compile_program
from repro.core.ila import ILA, TARGETS

_DEVICES = int(os.environ.get("REPRO_DEVICES_PER_TARGET", "1"))


def _intrinsic_params():
    out = []
    for t in TARGETS.all():
        for op, intr in t.intrinsics.items():
            if intr.sample is not None:
                out.append(pytest.param(t, intr, id=f"{t.name}:{op}"))
    return out


def _case(t, intr, seed):
    rng = np.random.default_rng(seed)
    args, attrs = intr.sample(rng)
    vs = tuple(ir.Var(f"_{i}", a.shape) for i, a in enumerate(args))
    expr = ir.call(intr.op, *vs, **attrs)
    env = {f"_{i}": a for i, a in enumerate(args)}
    return expr, env


def _executor(t, intr, **kw):
    kw.setdefault("devices_per_target", _DEVICES)
    return Executor("ila", target_options={t.name: intr.options}, **kw)


@pytest.mark.parametrize("t,intr", _intrinsic_params())
def test_ideal_vs_numerics_within_declared_tol(t, intr):
    """Custom-numerics co-simulation tracks the fp32 oracle (VT1-style)."""
    for seed in (0, 1):
        expr, env = _case(t, intr, seed)
        ideal = np.asarray(Executor("ideal").run(expr, env))
        got = np.asarray(_executor(t, intr).run(expr, env))
        assert got.shape == ideal.shape
        err = validate.frob_rel_err(ideal, got)
        assert err <= intr.tol, f"{t.name}:{intr.op} rel err {err} > tol {intr.tol}"


@pytest.mark.parametrize("t,intr", _intrinsic_params())
def test_engines_bit_exact(t, intr):
    """eager per-command == jit scan == compiled fast path == pipelined
    == run_many."""
    expr, env = _case(t, intr, 2)
    _, env2 = _case(t, intr, 3)
    out_c = np.asarray(_executor(t, intr, engine="compiled").run(expr, env))
    out_j = np.asarray(_executor(t, intr, engine="jit").run(expr, env))
    out_e = np.asarray(_executor(t, intr, engine="eager").run(expr, env))
    out_p = np.asarray(_executor(t, intr, engine="pipelined").run(expr, env))
    np.testing.assert_array_equal(out_c, out_j, err_msg=f"{t.name}:{intr.op} compiled != jit")
    np.testing.assert_array_equal(out_c, out_e, err_msg=f"{t.name}:{intr.op} compiled != eager")
    np.testing.assert_array_equal(out_c, out_p, err_msg=f"{t.name}:{intr.op} compiled != pipelined")
    # batched path: same env twice through one vmapped call per node
    outs_m = _executor(t, intr, engine="compiled").run_many(expr, [env, env])
    for o in outs_m:
        np.testing.assert_array_equal(
            out_c, np.asarray(o), err_msg=f"{t.name}:{intr.op} run_many != run"
        )
    # a second distinct sample keeps its own numerics when batched
    ref2 = np.asarray(_executor(t, intr).run(expr, env2))
    outs_m2 = _executor(t, intr).run_many(expr, [env, env2])
    np.testing.assert_array_equal(ref2, np.asarray(outs_m2[1]))


@pytest.mark.parametrize("mode", ("auto", "fallback"))
@pytest.mark.parametrize("ndev", (1, 2), ids=("1dev", "2dev"))
@pytest.mark.parametrize("t,intr", _intrinsic_params())
def test_fused_parity_within_declared_tol(t, intr, ndev, mode, monkeypatch):
    """engine="fused" tracks the compiled oracle within the intrinsic's
    declared tolerance (bit-exact where the fused numerics round-trip the
    compiled arithmetic — asserted per-target in test_fused.py), through
    run and run_many, across device counts. ``mode=fallback`` forces the
    XLA-fused lowering (``REPRO_FUSED_FALLBACK=1``) so the ``jnp`` leg is
    covered even on hosts where Pallas lowers natively; targets without a
    registered fused runner execute per-group compiled and must stay
    bit-exact either way."""
    if intr.planner is None:
        pytest.skip("pass-through intrinsic: nothing to fuse")
    if mode == "fallback":
        monkeypatch.setenv("REPRO_FUSED_FALLBACK", "1")
    expr, env = _case(t, intr, 2)
    _, env2 = _case(t, intr, 3)
    ref = np.asarray(_executor(t, intr, engine="compiled").run(expr, env))
    got = np.asarray(
        _executor(t, intr, engine="fused", devices_per_target=ndev).run(expr, env)
    )
    assert got.shape == ref.shape
    err = validate.frob_rel_err(ref, got)
    assert err <= intr.tol, (
        f"{t.name}:{intr.op} fused-vs-compiled rel err {err} > tol {intr.tol}"
    )
    # batched parity through run_many (the vmapped/fused-dispatch path)
    refs = _executor(t, intr, engine="compiled").run_many(expr, [env, env2, env])
    outs = _executor(t, intr, engine="fused",
                     devices_per_target=ndev).run_many(expr, [env, env2, env])
    for r, o in zip(refs, outs):
        e = validate.frob_rel_err(np.asarray(r), np.asarray(o))
        assert e <= intr.tol, (
            f"{t.name}:{intr.op} fused run_many rel err {e} > tol {intr.tol}"
        )


def _vt2_params():
    out = []
    for t in TARGETS.all():
        for case in t.vt2_cases(8, 32):
            out.append(pytest.param(t, case, id=f"{t.name}:{case.name}"))
    return out


@pytest.mark.parametrize("t,case", _vt2_params())
def test_rewrite_soundness_vt2_and_extraction(t, case):
    """VT2 over abstract types + interpret-before/after compile equality."""
    assert validate.vt2_check(case, n=5)
    res = compile_program(case.ir_fragment, targets=(t.name,), flexible=True)
    assert res.accelerator_calls.get(t.name, 0) >= 1, (
        f"{t.name}:{case.name} did not extract an intrinsic"
    )
    rng = np.random.default_rng(0)
    env = {k: rng.standard_normal(s).astype(np.float32)
           for k, s in case.var_shapes.items()}
    np.testing.assert_allclose(
        np.asarray(ir.interpret(case.ir_fragment, env)),
        np.asarray(ir.interpret(res.program, env)),
        rtol=1e-5, atol=1e-5,
    )


@pytest.fixture(scope="module")
def app_offloads():
    out = {}
    for name, (builder, _) in apps.APPLICATIONS.items():
        expr, _params = builder()
        out[name] = compile_program(expr).accelerator_calls
    return out


@pytest.mark.parametrize("tname", TARGETS.names())
def test_every_target_offloaded_by_some_app(app_offloads, tname):
    """Default (all-targets) compiles exercise every registered backend —
    a new target starts receiving offloads with zero compiler edits."""
    hits = {app: calls.get(tname, 0) for app, calls in app_offloads.items()}
    assert any(n >= 1 for n in hits.values()), f"{tname} never offloaded: {hits}"


# ---------------------------------------------------------------------------
# CostModel conformance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t", TARGETS.all(), ids=TARGETS.names())
def test_cost_model_prices_every_claimed_intrinsic(t):
    """Every registered target declares a CostModel covering every intrinsic
    it claims, and pricing realistic operands yields positive cycles.
    Sampled intrinsics price their sample's shapes; pass-through markers
    (no sample) price a generic tensor."""
    assert t.cost_model is not None, f"{t.name} declares no CostModel"
    rng = np.random.default_rng(0)
    for op, intr in t.intrinsics.items():
        assert t.cost_model.covers(op), f"{t.name} does not price {op!r}"
        if intr.sample is not None:
            args, attrs = intr.sample(rng)
            shapes = [np.shape(a) for a in args]
        else:
            shapes, attrs = [(8, 8)], {}
        est = t.cost_model.estimate(op, attrs, shapes)
        assert est.cycles > 0, f"{t.name}:{op} non-positive cycles {est}"
        assert est.commands >= 0 and est.bytes_moved >= 0


@pytest.mark.parametrize("t,intr", _intrinsic_params())
def test_cost_monotone_in_batch_size(t, intr):
    """Scaling the data operand's leading dimension (the batch/row axis for
    every declared intrinsic) must strictly increase estimated cycles and
    never decrease commands or bytes."""
    rng = np.random.default_rng(0)
    args, attrs = intr.sample(rng)
    shapes = [np.shape(a) for a in args]

    def scaled(k):
        # scale the data operand's leading dim; elementwise mates (operands
        # sharing the data operand's full shape) scale with it so the op
        # stays broadcast-legal
        out = [
            ((s[0] * k,) + tuple(s[1:]))
            if (i == 0 or tuple(s) == tuple(shapes[0])) else tuple(s)
            for i, s in enumerate(shapes)
        ]
        return out

    e1 = t.cost_model.estimate(intr.op, attrs, scaled(1))
    e4 = t.cost_model.estimate(intr.op, attrs, scaled(4))
    assert e1.cycles > 0 and e1.commands > 0
    assert e4.cycles > e1.cycles, f"{t.name}:{intr.op} cycles not monotone"
    assert e4.commands >= e1.commands and e4.bytes_moved >= e1.bytes_moved


@pytest.mark.parametrize("t,intr", _intrinsic_params())
def test_calibration_fits_observed_commands(t, intr):
    """CostModel.calibrate fits per-op command scales so predictions match
    the interface command counts the Executor actually observed."""
    expr, env = _case(t, intr, 5)
    ex = _executor(t, intr)
    ex.run(expr, env)
    observed = sum(s.n_commands for s in ex.stats if s.op == intr.op)
    if observed == 0:
        pytest.skip("intrinsic records no commands")
    saved = dict(t.cost_model.command_scale)
    try:
        ex.calibrate_cost_models()
        shapes = [np.shape(env[f"_{i}"]) for i in range(len(env))]
        attrs = dict(expr.attrs)
        refit = t.cost_model.estimate(intr.op, attrs, shapes)
        assert refit.commands == pytest.approx(observed, rel=1e-6), (
            f"{t.name}:{intr.op} calibrated commands {refit.commands} "
            f"!= observed {observed}"
        )
    finally:
        t.cost_model.command_scale.clear()
        t.cost_model.command_scale.update(saved)


# ---------------------------------------------------------------------------
# Selection policy: two targets claim one op
# ---------------------------------------------------------------------------


@pytest.fixture
def competing_targets():
    """Two synthetic targets both claiming the (otherwise unclaimed) host op
    ``maximum``, with cost models an order of magnitude apart. Registered
    for the duration of the test only — the registry is restored after."""
    from repro.accel.target import (
        AcceleratorTarget, CostModel, Intrinsic, register_target,
        unregister_target,
    )
    from repro.core.egraph import P, Rewrite, V as PV

    def build(name, op, cycles_per_elem):
        target = AcceleratorTarget(name, ILA(name))
        target.add_intrinsic(Intrinsic(
            op,
            shape=lambda attrs, cs: tuple(np.broadcast_shapes(cs[0], cs[1])),
            ideal=lambda attrs, a: np.maximum(a[0], a[1]),
        ))
        costs = CostModel(name)

        def price(attrs, shapes, c=cycles_per_elem):
            n = int(np.prod(np.broadcast_shapes(*shapes)))
            return 2 * n // 16 + 1, 12 * n, c * n

        costs.op(op)(price)
        target.add_cost_model(costs)
        target.add_rewrites(lambda op=op: [
            Rewrite(f"{name}-max", P("maximum", PV("a"), PV("b")),
                    P(op, PV("a"), PV("b")))
        ])
        return register_target(target)

    cheap = build("t_cheap", "tcheap_max", 1.0)
    pricey = build("t_pricey", "tpricey_max", 50.0)
    try:
        yield cheap, pricey
    finally:
        unregister_target(cheap)
        unregister_target(pricey)


def test_policy_picks_cheaper_target_and_overrides_flip(competing_targets):
    """Two targets claim one op: the default (cheapest) policy selects the
    target whose CostModel predicts fewer cycles; ``forbid`` removes it and
    flips the mapping; ``prefer`` overrides the cost ranking."""
    cheap, pricey = competing_targets
    a, b = ir.Var("a", (8, 8)), ir.Var("b", (8, 8))
    prog = ir.call("maximum", a, b)
    names = (cheap.name, pricey.name)

    res = compile_program(prog, targets=names)
    assert res.accelerator_calls[cheap.name] == 1
    assert res.accelerator_calls[pricey.name] == 0

    res = compile_program(prog, targets=names,
                          policy=SelectionPolicy(forbid=(cheap.name,)))
    assert res.accelerator_calls[cheap.name] == 0
    assert res.accelerator_calls[pricey.name] == 1

    res = compile_program(prog, targets=names,
                          policy=SelectionPolicy(prefer=(pricey.name,)))
    assert res.accelerator_calls[cheap.name] == 0
    assert res.accelerator_calls[pricey.name] == 1


# ---------------------------------------------------------------------------
# Multi-device scheduling
# ---------------------------------------------------------------------------


def _first_sampled_intrinsic(t):
    for intr in t.intrinsics.values():
        if intr.sample is not None and intr.planner is not None:
            return intr
    return None


@pytest.mark.parametrize("t", TARGETS.all(), ids=TARGETS.names())
def test_multi_device_bit_exact_and_utilization_reported(t):
    """devices_per_target=2: scheduled execution stays bit-identical to the
    single-device run, and stats_summary grows per-device rows with
    estimated cycles and utilization."""
    intr = _first_sampled_intrinsic(t)
    if intr is None:
        pytest.skip(f"{t.name} declares no runnable sampled intrinsic")
    expr, env = _case(t, intr, 7)
    _, env2 = _case(t, intr, 8)
    ex1 = _executor(t, intr, devices_per_target=1)
    ex2 = _executor(t, intr, devices_per_target=2)
    outs1 = ex1.run_many(expr, [env, env2, env])
    outs2 = ex2.run_many(expr, [env, env2, env])
    for o1, o2 in zip(outs1, outs2):
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    summary = ex2.stats_summary()[t.name]
    assert summary["est_cycles"] > 0
    devs = summary["devices"]
    assert len(devs) == 2
    for row in devs.values():
        assert {"jobs", "groups", "est_cycles", "utilization"} <= set(row)
    assert any(r["utilization"] == 1.0 for r in devs.values())
    assert sum(r["jobs"] for r in devs.values()) >= 3


# ---------------------------------------------------------------------------
# Pipelined engine: bit-exactness, determinism, mesh sharding
# ---------------------------------------------------------------------------


def _pipelined_envs(t, intr, n=5):
    """n environments (two distinct samples interleaved) for one intrinsic,
    plus the compiled-engine reference outputs."""
    expr, env = _case(t, intr, 11)
    _, env2 = _case(t, intr, 12)
    envs = [env, env2, env, env2, env][:n]
    ref = _executor(t, intr, engine="compiled").run_many(expr, envs)
    return expr, envs, ref


@pytest.mark.parametrize("ndev", (1, 2), ids=("1dev", "2dev"))
@pytest.mark.parametrize("t,intr", _intrinsic_params())
def test_pipelined_bit_exact_across_device_counts(t, intr, ndev):
    """engine="pipelined" matches the compiled engine bit-for-bit through
    run_many, for every registered target and device count (chunked
    planning + async dispatch + LPT scheduling must not change results).
    pipeline_chunk=2 forces several pack/sim pipeline stages."""
    if intr.planner is None:
        pytest.skip("pass-through intrinsic: nothing to pipeline")
    expr, envs, ref = _pipelined_envs(t, intr)
    ex = _executor(t, intr, engine="pipelined", devices_per_target=ndev,
                   pipeline_chunk=2)
    outs = ex.run_many(expr, envs)
    for r, o in zip(ref, outs):
        np.testing.assert_array_equal(
            np.asarray(r), np.asarray(o),
            err_msg=f"{t.name}:{intr.op} pipelined != compiled ({ndev} devices)",
        )


@pytest.mark.parametrize("t,intr", _intrinsic_params())
def test_pipelined_deterministic_and_stable_order(t, intr):
    """Two pipelined runs produce identical results AND identical stat
    sequences: materialization and stat recording follow submission order
    at the assemble barrier, never pack-worker timing."""
    if intr.planner is None:
        pytest.skip("pass-through intrinsic: nothing to pipeline")
    expr, envs, _ = _pipelined_envs(t, intr)
    ex1 = _executor(t, intr, engine="pipelined", pipeline_chunk=2)
    ex2 = _executor(t, intr, engine="pipelined", pipeline_chunk=2)
    outs1 = ex1.run_many(expr, envs)
    outs2 = ex2.run_many(expr, envs)
    for a, b in zip(outs1, outs2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    trace1 = [(s.op, s.backend, s.n_commands) for s in ex1.stats]
    trace2 = [(s.op, s.backend, s.n_commands) for s in ex2.stats]
    assert trace1 == trace2 and trace1, (
        f"{t.name}:{intr.op} pipelined stat order is not stable"
    )


@pytest.mark.parametrize("t,intr", _intrinsic_params())
def test_mesh_sharded_batch_parity(t, intr):
    """run_data_batch/simulate_batch with the batch axis sharded over a
    host-device Mesh is bit-exact vs unsharded execution. Skips gracefully
    on single-device hosts; CI runs it with
    XLA_FLAGS=--xla_force_host_platform_device_count=4."""
    import jax

    from repro.core import ila as ila_mod

    if len(jax.devices()) < 2:
        pytest.skip("single-device host: stream mesh disabled")
    if intr.planner is None:
        pytest.skip("pass-through intrinsic: nothing to batch")
    expr, envs, ref = _pipelined_envs(t, intr)
    mesh = ila_mod.set_stream_mesh("auto")
    assert mesh is not None
    try:
        outs = _executor(t, intr, engine="compiled").run_many(expr, envs)
        outs_p = _executor(t, intr, engine="pipelined").run_many(expr, envs)
        outs_f = _executor(t, intr, engine="fused").run_many(expr, envs)
    finally:
        ila_mod.set_stream_mesh(None)
    for r, o, p, f in zip(ref, outs, outs_p, outs_f):
        np.testing.assert_array_equal(
            np.asarray(r), np.asarray(o),
            err_msg=f"{t.name}:{intr.op} mesh-sharded batch != unsharded",
        )
        np.testing.assert_array_equal(
            np.asarray(r), np.asarray(p),
            err_msg=f"{t.name}:{intr.op} mesh+pipelined != unsharded",
        )
        err = validate.frob_rel_err(np.asarray(r), np.asarray(f))
        assert err <= intr.tol, (
            f"{t.name}:{intr.op} mesh+fused rel err {err} > tol {intr.tol}"
        )
